//! `ligo-analyze` — engine-invariant lints over the `ligo` source tree.
//!
//! A deliberately dumb, dependency-free scanner (no syn, no rustc
//! internals: the environment is offline) that enforces three invariants
//! the type system cannot:
//!
//! * **fresh_alloc** — the training hot path (`model/tape.rs`,
//!   `model/text.rs`, `model/vision.rs`, `tensor/ops.rs`,
//!   `util/allreduce.rs`, `coordinator/parallel.rs`) must draw f32 buffers
//!   from `tensor/arena.rs`, never allocate fresh ones: `vec![0.0…]` and
//!   `Vec::with_capacity` are rejected outside `#[cfg(test)]` regions
//!   unless the line (or the line above) carries
//!   `// lint:allow(fresh_alloc) <reason>`. `tensor/arena.rs` itself is
//!   exempt by construction — its `vec![…]` fallbacks *are* the pool-miss
//!   paths.
//! * **env_var** — every `env::var(` read lives in `util/knobs.rs`; the
//!   rest of the crate goes through the typed knob accessors (which warn
//!   once on mis-parses instead of silently ignoring them).
//! * **knobs** — the `util/knobs.rs` `REGISTRY`, the `EXPERIMENTS.md`
//!   environment-knob table and the `"LIGO_*"` literals in source agree:
//!   every registered knob is documented and actually read somewhere;
//!   every literal names a registered knob (`LIGO_TEST_*` fixtures in test
//!   regions excepted).
//!
//! Exit status 0 when every lint passes, 1 with one line per finding
//! otherwise — `cargo run -p ligo-analyze` is the CI entry point.

use std::fs;
use std::path::{Path, PathBuf};

/// Hot-path modules under `rust/src` covered by the fresh_alloc lint.
/// `tensor/arena.rs` is deliberately absent: it is the allocator.
const HOT_FILES: &[&str] = &[
    "model/tape.rs",
    "model/text.rs",
    "model/vision.rs",
    "tensor/ops.rs",
    "util/allreduce.rs",
    "coordinator/parallel.rs",
];

const ALLOC_PATTERNS: &[&str] = &["vec![0.0", "Vec::with_capacity"];
const ALLOW_MARKER: &str = "lint:allow(fresh_alloc)";

fn main() {
    // analyze/ -> rust/ -> repo root
    let crate_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let rust_root = crate_dir.parent().expect("analyze sits inside rust/").to_path_buf();
    let repo_root = rust_root.parent().expect("rust/ sits inside the repo").to_path_buf();

    let mut files = Vec::new();
    for dir in ["src", "benches", "tests"] {
        collect_rs(&rust_root.join(dir), &mut files);
    }
    collect_rs(&repo_root.join("examples"), &mut files);
    files.sort();

    let mut findings = Vec::new();
    lint_fresh_alloc(&rust_root, &mut findings);
    lint_env_var(&rust_root, &files, &mut findings);
    lint_knobs(&rust_root, &repo_root, &files, &mut findings);

    if findings.is_empty() {
        println!(
            "ligo-analyze: {} files scanned, 3 lints (fresh_alloc on {} hot modules, \
             env_var, knobs), 0 findings",
            files.len(),
            HOT_FILES.len()
        );
    } else {
        for f in &findings {
            eprintln!("error: {f}");
        }
        eprintln!("ligo-analyze: {} finding(s)", findings.len());
        std::process::exit(1);
    }
}

/// Recursively gather `.rs` files (skipping any `vendor` subtree).
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            if path.file_name().is_some_and(|n| n == "vendor") {
                continue;
            }
            collect_rs(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

fn read(path: &Path) -> String {
    fs::read_to_string(path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

/// The non-test prefix of a file: everything before the first
/// `#[cfg(test)]` line (the crate convention puts the test module last).
fn non_test_region(text: &str) -> impl Iterator<Item = (usize, &str)> {
    text.lines()
        .enumerate()
        .take_while(|(_, l)| l.trim_start() != "#[cfg(test)]")
}

fn is_comment(line: &str) -> bool {
    let t = line.trim_start();
    t.starts_with("//") || t.starts_with("*")
}

fn lint_fresh_alloc(rust_root: &Path, findings: &mut Vec<String>) {
    for rel in HOT_FILES {
        let path = rust_root.join("src").join(rel);
        let text = read(&path);
        let lines: Vec<&str> = text.lines().collect();
        for (i, line) in non_test_region(&text) {
            if is_comment(line) || !ALLOC_PATTERNS.iter().any(|p| line.contains(p)) {
                continue;
            }
            let allowed = line.contains(ALLOW_MARKER)
                || (i > 0 && lines[i - 1].contains(ALLOW_MARKER));
            if !allowed {
                findings.push(format!(
                    "fresh_alloc: src/{rel}:{}: hot-path allocation `{}` — use \
                     tensor/arena.rs (alloc_zeroed/alloc_scratch/alloc_copy) or mark \
                     `// {ALLOW_MARKER} <reason>`",
                    i + 1,
                    line.trim()
                ));
            }
        }
    }
}

fn lint_env_var(rust_root: &Path, files: &[PathBuf], findings: &mut Vec<String>) {
    let knobs = rust_root.join("src").join("util").join("knobs.rs");
    for path in files {
        if *path == knobs {
            continue;
        }
        let text = read(path);
        for (i, line) in text.lines().enumerate() {
            if is_comment(line) {
                continue;
            }
            if line.contains("env::var(") {
                findings.push(format!(
                    "env_var: {}:{}: raw environment read — route it through \
                     util/knobs.rs so mis-parses warn and `ligo inspect knobs` sees it",
                    path.display(),
                    i + 1
                ));
            }
        }
    }
}

/// Pull every `LIGO_[A-Z0-9_]+` token out of a line, untrimmed — a
/// trailing `_` marks a family reference (`LIGO_DECODE_*` in prose) that
/// the caller resolves against the registry by prefix.
fn knob_tokens(line: &str, out: &mut Vec<String>) {
    let bytes = line.as_bytes();
    let mut i = 0;
    while let Some(off) = line[i..].find("LIGO_") {
        let start = i + off;
        let mut end = start + "LIGO_".len();
        let is_knob_char =
            |b: u8| b.is_ascii_uppercase() || b.is_ascii_digit() || b == b'_';
        while end < bytes.len() && is_knob_char(bytes[end]) {
            end += 1;
        }
        if end > start + "LIGO_".len() {
            out.push(line[start..end].to_string());
        }
        i = end;
    }
}

fn lint_knobs(rust_root: &Path, repo_root: &Path, files: &[PathBuf], findings: &mut Vec<String>) {
    let knobs_path = rust_root.join("src").join("util").join("knobs.rs");
    let knobs_src = read(&knobs_path);

    // registered names: the `name: "LIGO_…"` rows of REGISTRY
    let mut registry = Vec::new();
    for (_, line) in non_test_region(&knobs_src) {
        let t = line.trim_start();
        if let Some(rest) = t.strip_prefix("name: \"") {
            if let Some(name) = rest.split('"').next() {
                registry.push(name.to_string());
            }
        }
    }
    if registry.is_empty() {
        findings.push("knobs: no REGISTRY rows parsed from util/knobs.rs".to_string());
        return;
    }

    // every registered knob has an EXPERIMENTS.md row
    let experiments = read(&repo_root.join("EXPERIMENTS.md"));
    for name in &registry {
        if !experiments.contains(name.as_str()) {
            findings.push(format!(
                "knobs: {name} is registered in util/knobs.rs but has no row in \
                 EXPERIMENTS.md's environment-knob table"
            ));
        }
    }

    // every knob literal in source names a registered knob, and every
    // registered knob is read somewhere outside its own registry row
    let mut used = Vec::new();
    for path in files {
        let text = read(path);
        let own_registry = *path == knobs_path;
        for (_, line) in non_test_region(&text) {
            let mut toks = Vec::new();
            knob_tokens(line, &mut toks);
            for raw in toks {
                if raw.ends_with('_') && registry.iter().any(|n| n.starts_with(raw.as_str())) {
                    // `LIGO_DECODE_*`-style family reference in prose: it
                    // names a registered prefix, not a knob read
                    continue;
                }
                let tok = raw.trim_end_matches('_').to_string();
                if tok.starts_with("LIGO_TEST") {
                    continue; // accessor-contract fixtures, deliberately unregistered
                }
                if !registry.contains(&tok) {
                    findings.push(format!(
                        "knobs: {}: literal {tok} is not in the util/knobs.rs REGISTRY",
                        path.display()
                    ));
                } else if !(own_registry && line.trim_start().starts_with("name:")) {
                    used.push(tok);
                }
            }
        }
    }
    for name in &registry {
        if !used.contains(name) {
            findings.push(format!(
                "knobs: {name} is registered but never read anywhere in the crate"
            ));
        }
    }
}
