//! End-to-end integration over the real artifacts: PJRT load/compile,
//! manifest binding, golden checks against python, training steps, growth
//! operators through the real forward, and the LiGO manager.
//!
//! Requires `make artifacts` to have run (skipped otherwise).

use ligo::config::{artifacts_dir, Registry, TrainConfig};
use ligo::coordinator::trainer::{Batches, Trainer};
use ligo::growth::{GrowthContext, LigoOptions};
use ligo::data::batches::mlm_batch;
use ligo::data::corpus::Corpus;
use ligo::runtime::Runtime;
use ligo::tensor::store::Store;
use ligo::util::json::Json;
use ligo::util::rng::Rng;

fn runtime() -> Option<(Runtime, Registry)> {
    let dir = artifacts_dir();
    if !dir.join("configs.json").exists() {
        eprintln!("artifacts not built; skipping integration test");
        return None;
    }
    let rt = Runtime::cpu(&dir).expect("pjrt cpu client");
    let reg = Registry::load(&dir).expect("registry");
    Some((rt, reg))
}

/// Deterministic batch matching python aot.emit_goldens's _det_batch.
fn golden_batch(cfg: &ligo::ModelConfig, seed: i64) -> Store {
    use ligo::tensor::Tensor;
    let mut st = Store::new();
    let (b, s) = (cfg.batch, cfg.seq);
    if cfg.is_vision() {
        let n = b * cfg.img * cfg.img * 3;
        let vals: Vec<f32> = (0..n as i64)
            .map(|i| ((i * 1103515245 + seed) % 1000) as f32 / 1000.0 - 0.5)
            .collect();
        st.insert("images", Tensor::from_f32(&[b, cfg.img, cfg.img, 3], vals));
        let labels: Vec<i32> = (0..b as i64)
            .map(|i| ((i * 2654435761i64 + seed) % (cfg.n_classes.max(2) as i64)) as i32)
            .collect();
        st.insert("labels", Tensor::from_i32(&[b], labels));
    } else {
        let n = (b * s) as i64;
        let tokens: Vec<i32> = (0..n)
            .map(|i| ((i * 2654435761i64 + seed) % cfg.vocab as i64) as i32)
            .collect();
        // python golden labels use hi = max(n_classes, 2) = 2 for LM configs
        let labels: Vec<i32> = (0..n)
            .map(|i| if i % 7 == 0 { ((i * 2654435761i64 + seed) % 2) as i32 } else { -1 })
            .collect();
        st.insert("tokens", Tensor::from_i32(&[b, s], tokens));
        st.insert("labels", Tensor::from_i32(&[b, s], labels));
    }
    st
}

#[test]
fn golden_losses_match_python() {
    let Some((rt, reg)) = runtime() else { return };
    let goldens = std::fs::read_to_string(artifacts_dir().join("goldens.json")).unwrap();
    let goldens = Json::parse(&goldens).unwrap();
    for name in ["bert_small", "gpt_base", "vit_s"] {
        let cfg = reg.model(name).unwrap();
        let exe = rt.load(&format!("fwd_{name}")).unwrap();
        let params = Store::det_init(&exe.manifest.shapes_of("params"), 0);
        let batch = golden_batch(cfg, 7);
        let out = exe.run(&[("params", &params), ("batch", &batch)]).unwrap();
        let got = out.scalar("loss").unwrap();
        let want = goldens
            .get(&format!("fwd_{name}"))
            .and_then(|g| g.get("loss"))
            .and_then(Json::as_f64)
            .unwrap() as f32;
        assert!(
            (got - want).abs() < 2e-3 * want.abs().max(1.0),
            "{name}: rust loss {got} vs python golden {want}"
        );
    }
}

#[test]
fn train_steps_reduce_loss() {
    let Some((rt, reg)) = runtime() else { return };
    let cfg = reg.model("bert_small").unwrap().clone();
    let corpus = Corpus::new(cfg.vocab, 0);
    let params = Trainer::scratch_params(&rt, &cfg, 0).unwrap();
    let tc = TrainConfig {
        lr: 3e-3,
        total_steps: 80,
        warmup_steps: 5,
        eval_every: 80,
        ..Default::default()
    };
    let mut tr = Trainer::new(&rt, &cfg, tc, params).unwrap();
    let c1 = corpus.clone();
    let cfg1 = cfg.clone();
    let mut batches = Batches::shared(
        move |step| mlm_batch(&c1, &cfg1, &mut Rng::new(step as u64)),
        {
            let c = corpus.clone();
            let cfg = cfg.clone();
            move |i| mlm_batch(&c, &cfg, &mut Rng::new(0x77AA + i as u64))
        },
    );
    let curve = tr.run("smoke", &mut batches, 80).unwrap();
    let first = curve.loss[0];
    let last = *curve.loss.last().unwrap();
    assert!(
        last < first - 0.3,
        "loss did not drop: {first} -> {last}"
    );
}

#[test]
fn growth_operators_produce_runnable_models() {
    let Some((rt, reg)) = runtime() else { return };
    let small_cfg = reg.model("bert_small").unwrap().clone();
    let large_cfg = reg.model("bert_base").unwrap().clone();
    let small_exe = rt.load("grad_bert_small").unwrap();
    let small_params = Store::det_init(&small_exe.manifest.shapes_of("params"), 3);
    let fwd_large = rt.load("fwd_bert_base").unwrap();
    let corpus = Corpus::new(small_cfg.vocab, 0);
    let batch = mlm_batch(&corpus, &large_cfg, &mut Rng::new(5));
    for op_name in ligo::growth::ALL {
        let op = ligo::growth::by_name(op_name).unwrap();
        let big =
            ligo::growth::grow_params(op.as_ref(), &small_params, &small_cfg, &large_cfg).unwrap();
        let out = fwd_large.run(&[("params", &big), ("batch", &batch)]).unwrap();
        let loss = out.scalar("loss").unwrap();
        assert!(loss.is_finite(), "{op_name}: non-finite loss");
        assert!(loss < 20.0, "{op_name}: absurd loss {loss}");
    }
}

#[test]
fn ligo_growth_improves_over_init() {
    let Some((rt, reg)) = runtime() else { return };
    let small = reg.model("bert_small").unwrap().clone();
    let large = reg.model("bert_base").unwrap().clone();
    // lightly pretrain the small model so M has knowledge to map
    let corpus = Corpus::new(small.vocab, 0);
    let params = Trainer::scratch_params(&rt, &small, 0).unwrap();
    let tc = TrainConfig {
        lr: 1e-3,
        total_steps: 40,
        warmup_steps: 4,
        eval_every: 40,
        ..Default::default()
    };
    let mut tr = Trainer::new(&rt, &small, tc, params).unwrap();
    for step in 0..40 {
        let c = corpus.clone();
        let cfgc = small.clone();
        tr.train_step(&mut move |s| mlm_batch(&c, &cfgc, &mut Rng::new((step * 100 + s) as u64)))
            .unwrap();
    }
    let small_params = tr.params.clone();
    // grow with LiGO (few steps to keep the test fast) through the unified
    // entry point: runtime handle + batch source -> artifact or task-native
    let opts = LigoOptions { steps: 12, ..Default::default() };
    let c2 = corpus.clone();
    let lcfg = large.clone();
    let mut mk = move |s: usize| mlm_batch(&c2, &lcfg, &mut Rng::new(900 + s as u64));
    let ctx = GrowthContext::new(&small_params, &small, &large)
        .with_runtime(&rt)
        .with_batches(&mut mk)
        .with_opts(opts);
    let grown = ligo::growth::by_name("ligo").unwrap().grow(ctx).unwrap();
    assert!(grown.metrics.final_m_loss.is_finite());
    assert!(grown.metrics.extra_flops > 0.0);
    assert!(!grown.route.is_empty(), "route log must record the decision");
    // the grown model evaluates sanely
    let fwd = rt.load("fwd_bert_base").unwrap();
    let eval_batch = mlm_batch(&corpus, &large, &mut Rng::new(31337));
    let out = fwd.run(&[("params", &grown.params), ("batch", &eval_batch)]).unwrap();
    let ligo_loss = out.scalar("loss").unwrap();
    // compare against a scratch-init large model on the same batch
    let scratch =
        Store::det_init(&rt.load("grad_bert_base").unwrap().manifest.shapes_of("params"), 1);
    let scratch_loss = fwd
        .run(&[("params", &scratch), ("batch", &eval_batch)])
        .unwrap()
        .scalar("loss")
        .unwrap();
    assert!(
        ligo_loss < scratch_loss,
        "LiGO init ({ligo_loss}) should beat scratch init ({scratch_loss})"
    );
}
