//! Acceptance tests for the static-analysis subsystem (`ligo analyze`):
//! every builtin preset, every registry growth pair × operator and every
//! plan stage must verify *symbolically* — correct shapes proven, FLOPs
//! and peak-arena bytes estimated — while malformed configs and plans die
//! with typed diagnostics naming the offending stage and node. Throughout,
//! the arena's thread-local fresh-allocation counter proves no kernel ever
//! ran (the counters are per-thread and each #[test] runs on its own
//! thread, so the probes don't race each other).

use std::time::Instant;

use ligo::config::Registry;
use ligo::coordinator::plan::GrowthPlan;
use ligo::growth::{self, verify};
use ligo::model::shape;
use ligo::tensor::arena;

/// No kernel buffer was requested on this thread since `reset_stats`.
fn assert_no_kernel_allocs(what: &str) {
    if arena::enabled() {
        assert_eq!(arena::stats().0, 0, "{what} must not allocate kernel buffers");
        assert_eq!(arena::peak_request(), 0, "{what} must not request kernel buffers");
    }
}

#[test]
fn every_builtin_preset_replays_symbolically_with_zero_kernels() {
    arena::reset_stats();
    let reg = Registry::builtin();
    assert_eq!(reg.models.len(), 16, "preset inventory drifted");
    for (name, cfg) in &reg.models {
        let s = shape::summarize(cfg).unwrap_or_else(|e| panic!("preset {name}: {e:#}"));
        assert!(s.node_count() > 0, "{name}");
        assert!(s.params > 0, "{name}");
        assert!(s.fwd_flops > 0.0 && s.bwd_flops > s.fwd_flops, "{name}");
        assert!(s.peak_bytes > 0, "{name}");
        // the engine's own param inventory is the cross-check
        assert_eq!(s.params, reg.param_counts[name], "{name}");
    }
    assert_no_kernel_allocs("preset replay");
}

#[test]
fn every_registry_pair_verifies_under_every_operator() {
    arena::reset_stats();
    let t0 = Instant::now();
    let reg = Registry::builtin();
    let (mut ok, mut lemon_miss) = (0usize, 0usize);
    for (s, t) in &reg.pairs {
        let from = reg.model(s).unwrap();
        let to = reg.model(t).unwrap();
        for op in growth::KNOWN {
            match verify::verify_pair(op, from, to) {
                Ok(pv) => {
                    ok += 1;
                    assert!(pv.large.params > pv.small.params, "{s} -> {t} via {op}");
                    assert!(pv.large.fwd_flops > pv.small.fwd_flops, "{s} -> {t} via {op}");
                }
                Err(e) => {
                    // only LEMON constrains the pair shape; everything else
                    // must verify every paper pair
                    assert_eq!(op, "lemon", "{s} -> {t} via {op}: {e:#}");
                    lemon_miss += 1;
                    let msg = e.to_string();
                    assert!(msg.contains("lemon"), "{msg}");
                    assert!(msg.contains("operator regime"), "{msg}");
                }
            }
        }
    }
    assert_eq!(ok + lemon_miss, reg.pairs.len() * growth::KNOWN.len());
    assert!(lemon_miss > 0, "some paper pairs sit outside LEMON's exact regime");
    assert!(
        ok >= reg.pairs.len() * (growth::KNOWN.len() - 1),
        "only lemon may reject a registry pair (ok {ok}, misses {lemon_miss})"
    );
    assert_no_kernel_allocs("pair sweep");
    // the acceptance budget is <5s for the whole CLI sweep in release;
    // the in-test bound is generous for debug builds and loaded runners
    assert!(t0.elapsed().as_secs() < 30, "symbolic sweep took {:?}", t0.elapsed());
}

#[test]
fn malformed_plans_fail_statically_with_typed_diagnostics() {
    arena::reset_stats();
    let reg = Registry::builtin();
    let small = reg.model("bert_small").unwrap().clone();
    let base = reg.model("bert_base").unwrap().clone();

    // non-growing target
    let err = GrowthPlan::builder(&small)
        .grow_at(5, &small, "stackbert")
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("not larger"), "{err}");
    assert!(err.contains("growth plan stage 0"), "{err}");

    // depth/width shrink
    let err = GrowthPlan::builder(&base)
        .grow_at(5, &small, "net2net")
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("shrink"), "{err}");

    // odd head split: the symbolic attention node cannot divide 72 by 5
    let mut odd = base.clone();
    odd.name = "bert_oddheads".into();
    odd.heads = 5;
    let err = GrowthPlan::builder(&small)
        .grow_at(5, &odd, "stackbert")
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("divisible"), "{err}");
    assert!(err.contains("attention"), "{err}");
    assert!(err.contains("growth plan stage 0"), "{err}");

    // operator regime: bert_small -> bert_base is not an integer width factor
    let err = GrowthPlan::builder(&small)
        .grow_at(5, &base, "lemon")
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("integer factor"), "{err}");

    assert_no_kernel_allocs("plan rejection");
}

#[test]
fn valid_plans_expose_per_stage_summaries() {
    arena::reset_stats();
    let reg = Registry::builtin();
    let small = reg.model("bert_small").unwrap().clone();
    let mid = reg.model("bert_d6w48").unwrap().clone();
    let large = reg.model("bert_base").unwrap().clone();
    let plan = GrowthPlan::builder(&small)
        .grow_at(10, &mid, "stackbert")
        .grow_at(20, &large, "ligo")
        .build()
        .unwrap();
    let stages = verify::verify_plan(&plan).unwrap();
    assert_eq!(stages.len(), 2);
    assert_eq!(stages[0].small.name, "bert_small");
    assert_eq!(stages[0].large.name, "bert_d6w48");
    assert_eq!(stages[1].large.name, "bert_base");
    // the chain is monotone in cost at every stage boundary
    assert!(stages[0].large.params > stages[0].small.params);
    assert!(stages[1].large.peak_bytes > stages[1].small.peak_bytes);
    assert!(stages[1].peak_ratio() > 1.0);
    assert_no_kernel_allocs("plan verification");
}
