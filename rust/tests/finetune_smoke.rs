//! Smoke coverage for the public fine-tune entry points (`eval/finetune`)
//! and the paper-table driver (`experiments/tables`) on the probe preset,
//! over the synthesized native engine. The claim is small: the entry
//! points run end to end from a clean checkout and report finite,
//! in-range metrics — the accuracy *values* belong to the experiments
//! ledger, not to CI.

use ligo::config::{Registry, TrainConfig};
use ligo::data::corpus::Corpus;
use ligo::data::downstream::{Probe, ProbeKind};
use ligo::eval::finetune::{attach_head, finetune_probe};
use ligo::model::param_shapes;
use ligo::runtime::Runtime;
use ligo::tensor::store::Store;
use ligo::util::knobs;
use ligo::util::rng::Rng;

fn native_runtime() -> Option<Runtime> {
    let rt = Runtime::cpu(std::env::temp_dir().join("ligo_finetune_smoke")).unwrap();
    if rt.backend_name() != "native" {
        // pjrt build with a live XLA client: the artifact suite covers it
        return None;
    }
    Some(rt)
}

#[test]
fn attach_head_carries_the_body_and_det_inits_the_head() {
    let reg = Registry::builtin();
    let probe_cfg = reg.model("probe_bert_small").unwrap().clone();
    let body_cfg = reg.model("bert_small").unwrap().clone();
    let shapes = param_shapes(&probe_cfg);
    let body = Store::det_init(&param_shapes(&body_cfg), 3);
    let full = attach_head(&shapes, &body, 9);
    for (name, shape) in &shapes {
        assert_eq!(&full.get(name).unwrap().shape, shape, "missing or misshaped '{name}'");
    }
    // body tensors ride along bit-for-bit; the head is deterministic in
    // the seed (a rerun must reproduce it exactly)
    let carried = "L00_q_w";
    assert_eq!(
        full.get(carried).unwrap().f32s(),
        body.get(carried).unwrap().f32s(),
        "body tensor must be carried verbatim"
    );
    let again = attach_head(&shapes, &body, 9);
    assert_eq!(
        full.get("head_w").unwrap().f32s(),
        again.get("head_w").unwrap().f32s(),
        "head init must be deterministic in the seed"
    );
}

#[test]
fn finetune_probe_reports_finite_metrics_on_the_probe_preset() {
    let Some(rt) = native_runtime() else { return };
    let reg = Registry::builtin();
    let probe_cfg = reg.model("probe_bert_small").unwrap().clone();
    let body_cfg = reg.model("bert_small").unwrap().clone();
    // a det-init body stands in for a pretrained checkpoint: the smoke
    // claim is that the entry point trains a head and evaluates it
    let body = Store::det_init(&param_shapes(&body_cfg), 17);
    let corpus = Corpus::new(probe_cfg.vocab, 0);
    let probe = Probe::new(ProbeKind::Sst2, corpus);
    let tc = TrainConfig::finetune(5);
    let p1 = probe.clone();
    let c1 = probe_cfg.clone();
    let mut trb = move |s: usize| p1.batch(&c1, &mut Rng::new(0xF7 + s as u64));
    let c2 = probe_cfg.clone();
    let mut evb = move |s: usize| probe.batch(&c2, &mut Rng::new(0xE7A1 + s as u64));
    let res = finetune_probe(&rt, "probe_bert_small", "sst2_smoke", &body, &tc, &mut trb, &mut evb)
        .unwrap();
    assert_eq!(res.task, "sst2_smoke");
    assert!(res.final_loss.is_finite() && res.final_loss > 0.0, "{res:?}");
    assert!((0.0..=1.0).contains(&res.accuracy), "{res:?}");
}

#[test]
fn table5_finetune_transfer_runs_end_to_end() {
    // Minutes-scale in debug builds: the CI e2e-serve job runs it in
    // release under LIGO_TEST_HEAVY=1; plain `cargo test` skips it.
    if !knobs::is_set("LIGO_TEST_HEAVY") {
        return;
    }
    let Some(rt) = native_runtime() else { return };
    let reg = Registry::builtin();
    let out = std::env::temp_dir().join("ligo_table5_smoke");
    std::fs::create_dir_all(&out).unwrap();
    ligo::experiments::tables::table5(&rt, &reg, 0.0, &out).unwrap();
}
