//! Prop. 1 equivalence suite: the native LiGO operator with a noise-free
//! selection-pattern M must reproduce the non-learned zoo operators
//! *bit-for-bit* on the testutil configs. The width maps in play only
//! duplicate features with power-of-two multiplicities (8 -> 12, 32 -> 48:
//! counts 1 and 2), and the selection matmuls reduce to exact copies /
//! exact halvings, so f32 equality is the correct assertion — any drift
//! means the native port no longer contains the baselines as special cases.
//!
//! Pure rust — no artifacts required.

use ligo::coordinator::growth_manager::LigoOptions;
use ligo::growth::ligo::{ligo_apply, ligo_init, selection_m, DepthInit, Ligo};
use ligo::growth::net2net::Net2Net;
use ligo::growth::testutil::{assert_store_eq, mk_cfg, small_store};
use ligo::growth::{self, GrowthContext, Objective};
use ligo::tensor::store::Store;
use ligo::tensor::Tensor;
use ligo::util::rng::Rng;
use ligo::ModelConfig;

/// Grow via the registry through the unified entry point (param-only).
fn zoo_grow(name: &str, small: &Store, cs: &ModelConfig, cl: &ModelConfig) -> Store {
    let op = growth::by_name(name).unwrap();
    growth::grow_params(op.as_ref(), small, cs, cl).unwrap()
}

#[test]
fn selection_ligo_reproduces_stackbert_width_and_depth() {
    let cs = mk_cfg(2, 8, 2);
    let cl = mk_cfg(4, 12, 3);
    let small = small_store(&cs);
    let want = zoo_grow("stackbert", &small, &cs, &cl);
    let m = selection_m(&cs, &cl, DepthInit::Stack, true);
    let got = ligo_apply(&m, &small, &cs, &cl);
    assert_store_eq(&got, &want, "stackbert");
}

#[test]
fn selection_ligo_reproduces_interpolation() {
    let cs = mk_cfg(2, 8, 2);
    let cl = mk_cfg(4, 12, 3);
    let small = small_store(&cs);
    let want = zoo_grow("interpolation", &small, &cs, &cl);
    let m = selection_m(&cs, &cl, DepthInit::Interpolate, true);
    let got = ligo_apply(&m, &small, &cs, &cl);
    assert_store_eq(&got, &want, "interpolation");
}

#[test]
fn selection_ligo_reproduces_net2net() {
    // Net2Net's depth growth appends near-identity blocks (zeroed residual
    // writers); in LiGO that is the NearIdentity depth pattern, and its
    // D^-1-normalized width selection is the untied A_* instance.
    let cs = mk_cfg(2, 8, 2);
    let cl = mk_cfg(4, 12, 3);
    let small = small_store(&cs);
    let want = Net2Net { cyclic: true }.expand(&small, &cs, &cl);
    let m = selection_m(&cs, &cl, DepthInit::NearIdentity, true);
    let got = ligo_apply(&m, &small, &cs, &cl);
    assert_store_eq(&got, &want, "net2net");
}

#[test]
fn selection_ligo_reproduces_mslt_top_duplication() {
    let cs = mk_cfg(2, 8, 2);
    let cl = mk_cfg(4, 12, 3);
    let small = small_store(&cs);
    let want = zoo_grow("mslt", &small, &cs, &cl);
    let m = selection_m(&cs, &cl, DepthInit::TopDup, true);
    let got = ligo_apply(&m, &small, &cs, &cl);
    assert_store_eq(&got, &want, "mslt");
}

#[test]
fn non_divisible_depth_ratio_2_to_5() {
    // depth-only: M has no width matrices (identity fallback) and a 5x2
    // blend; the 2 -> 5 ratio exercises the clamped selection rows.
    let cs = mk_cfg(2, 8, 2);
    let cl = mk_cfg(5, 8, 2);
    let small = small_store(&cs);
    for (depth, name) in [
        (DepthInit::Stack, "stackbert"),
        (DepthInit::Interpolate, "interpolation"),
        (DepthInit::TopDup, "mslt"),
    ] {
        let want = zoo_grow(name, &small, &cs, &cl);
        let m = selection_m(&cs, &cl, depth, true);
        assert!(!m.contains("B_emb"), "depth-only M must omit width matrices");
        let got = ligo_apply(&m, &small, &cs, &cl);
        assert_store_eq(&got, &want, &format!("{name} 2->5"));
    }
}

#[test]
fn non_divisible_depth_with_width_growth_2_to_5() {
    let cs = mk_cfg(2, 8, 2);
    let cl = mk_cfg(5, 12, 3);
    let small = small_store(&cs);
    let want = zoo_grow("stackbert", &small, &cs, &cl);
    let m = selection_m(&cs, &cl, DepthInit::Stack, true);
    let got = ligo_apply(&m, &small, &cs, &cl);
    assert_store_eq(&got, &want, "stackbert 2->5 wide");
}

#[test]
fn width_only_selection_reproduces_net2net() {
    let cs = mk_cfg(2, 8, 2);
    let cl = mk_cfg(2, 12, 3); // layers fixed: no depth blends in M
    let small = small_store(&cs);
    let want = Net2Net { cyclic: true }.expand(&small, &cs, &cl);
    let m = selection_m(&cs, &cl, DepthInit::NearIdentity, true);
    assert!(!m.contains("w_q"), "width-only M must omit depth blends");
    let got = ligo_apply(&m, &small, &cs, &cl);
    assert_store_eq(&got, &want, "net2net width-only");
}

#[test]
fn noise_free_init_with_zero_steps_is_the_stacking_baseline_family() {
    // The learned operator's own init (tied, unnormalized) applied with no
    // learning is still a valid member of the family: exact target shapes,
    // finite values, and the stacking depth pattern over tied width copies.
    let cs = mk_cfg(2, 8, 2);
    let cl = mk_cfg(4, 12, 3);
    let small = small_store(&cs);
    let op = Ligo { steps: 0, noise: 0.0, ..Default::default() };
    let (got, _loss) = op.grow_with_loss(&small, &cs, &cl);
    let init = ligo_init(&cs, &cl, 0.0, 0);
    let direct = ligo_apply(&init, &small, &cs, &cl);
    assert_store_eq(&got, &direct, "zero-step grow == apply(init)");
    // depth stacking: layer 2 repeats layer 0, layer 3 repeats layer 1
    assert_eq!(got.expect("L02_q_w"), got.expect("L00_q_w"));
    assert_eq!(got.expect("L03_q_w"), got.expect("L01_q_w"));
}

fn mlm_like_batch(cfg: &ModelConfig, seed: u64) -> Store {
    let mut rng = Rng::new(seed);
    let (b, s) = (cfg.batch, cfg.seq);
    let tokens: Vec<i32> = (0..b * s).map(|_| rng.below(cfg.vocab) as i32).collect();
    let labels: Vec<i32> = tokens
        .iter()
        .map(|&t| if rng.coin(0.3) { t } else { -1 })
        .collect();
    let mut st = Store::new();
    st.insert("tokens", Tensor::from_i32(&[b, s], tokens));
    st.insert("labels", Tensor::from_i32(&[b, s], labels));
    st
}

#[test]
fn task_loss_learned_m_beats_the_step0_eval_loss() {
    // The acceptance check for native M-learning: descending the expanded
    // model's *task loss* must reach a lower held-out eval loss than the
    // shared starting point (apply(init M) — which is also the surrogate's
    // step-0 model, since both objectives share ligo_init).
    fn grow_with(
        small: &Store,
        cs: &ModelConfig,
        cl: &ModelConfig,
        batches: &mut dyn FnMut(usize) -> Store,
        steps: usize,
    ) -> ligo::growth::GrowthOutcome {
        let ctx = GrowthContext::new(small, cs, cl)
            .with_batches(batches)
            .with_opts(LigoOptions { steps, ..Default::default() });
        growth::by_name("ligo").unwrap().grow(ctx).unwrap()
    }
    let cs = mk_cfg(2, 8, 2);
    let cl = mk_cfg(4, 12, 3);
    let small = small_store(&cs);
    let cl2 = cl.clone();
    let mut batches = move |s: usize| mlm_like_batch(&cl2, 1000 + s as u64);
    let g0 = grow_with(&small, &cs, &cl, &mut batches, 0);
    let gn = grow_with(&small, &cs, &cl, &mut batches, 30);
    assert_eq!(gn.objective, Objective::TaskNative);
    // held-out batches (disjoint seeds from the 1000.. training stream)
    let eval = |params: &Store| -> f32 {
        (0..3)
            .map(|i| {
                let batch = mlm_like_batch(&cl, 9000 + i as u64);
                ligo::model::loss_only(&cl, params, &batch).unwrap().0
            })
            .sum::<f32>()
            / 3.0
    };
    let (l0, ln) = (eval(&g0.params), eval(&gn.params));
    assert!(l0.is_finite() && ln.is_finite());
    assert!(
        ln < l0,
        "task-loss-learned M must beat the step-0 eval loss: {l0} -> {ln}"
    );
}

#[test]
fn learned_ligo_stays_in_shape_family_and_beats_nothing_silently() {
    // The end-to-end learned operator (by_name path, param-only context ->
    // surrogate route) produces the exact tensor set of a native large
    // store and only finite values.
    let cs = mk_cfg(2, 8, 2);
    let cl = mk_cfg(4, 12, 3);
    let small = small_store(&cs);
    let op = growth::by_name("ligo").unwrap();
    let ctx = GrowthContext::new(&small, &cs, &cl)
        .with_opts(LigoOptions { steps: 30, ..Default::default() });
    let outcome = op.grow(ctx).unwrap();
    assert_eq!(outcome.objective, Objective::Surrogate);
    let big = outcome.params;
    let native = small_store(&cl);
    assert_eq!(big.len(), native.len());
    for (name, t) in native.iter() {
        let g = big.expect(name);
        assert_eq!(g.shape, t.shape, "{name}");
        assert!(g.f32s().iter().all(|x| x.is_finite()), "{name}");
    }
}
