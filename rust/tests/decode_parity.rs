//! Decode-vs-training parity harness — the acceptance suite for the
//! KV-cached incremental decode path and the continuous-batching scheduler.
//!
//! Four claims are pinned here:
//!
//! * **(a) decode == training.** `Decoder::forward_full` is the training
//!   forward (bitwise, via the fused head loss), and prefill + N
//!   incremental `decode_step`s reproduce it — bitwise on dot-path shapes
//!   (the tiny and LEMON-grown models) and to ≤1e-5 relative on every GPT
//!   registry preset, with greedy-token agreement.
//! * **(b) scheduler determinism.** Any admission order, concurrency cap,
//!   or staggered interleaving of S sessions yields per-session token
//!   streams identical to each session decoded alone — including under
//!   page-pool backpressure (capped pools serialize admission but never
//!   change a stream or panic) and per-session deadline budgets (expired
//!   sessions are evicted with a partial completion that prefixes their
//!   solo stream, at the same cut point under every interleaving).
//! * **(c) paged allocator safety.** Random alloc/free workloads never
//!   leak or alias pages, and a warm decode loop performs zero fresh
//!   arena allocations and zero fresh pool pages.
//! * **(d) sampling parity.** Streaming `lm_head_sample` is exactly
//!   `lm_head_argmax` at `top_k = 1` (multi-tile vocab), and matches a
//!   materialized-softmax nucleus reference on a small vocabulary.

use ligo::config::{ModelConfig, Registry};
use ligo::coordinator::serve::{Completion, Request, Scheduler, ServeOptions};
use ligo::growth::lemon::Lemon;
use ligo::model::decode::{Decoder, KvCache, StepInput};
use ligo::model::{loss_only, param_shapes};
use ligo::tensor::arena;
use ligo::tensor::ops::{self, Act, SampleSpec};
use ligo::tensor::paged::PagePool;
use ligo::tensor::store::Store;
use ligo::tensor::Tensor;
use ligo::util::knobs;
use ligo::util::rng::Rng;

fn tiny_gpt(name: &str, layers: usize, dim: usize, heads: usize, vocab: usize, seq: usize) -> ModelConfig {
    ModelConfig {
        name: name.into(),
        family: "gpt".into(),
        layers,
        dim,
        heads,
        vocab,
        seq,
        batch: 2,
        img: 0,
        patch: 0,
        channels: 3,
        n_classes: 0,
        cls_layers: 0,
        ffn_mult: 4,
    }
}

fn gpt_presets(reg: &Registry) -> Vec<ModelConfig> {
    reg.models
        .values()
        .filter(|c| c.family == "gpt" && c.n_classes == 0)
        .cloned()
        .collect()
}

fn rel_err(a: f32, b: f32) -> f32 {
    (a - b).abs() / a.abs().max(b.abs()).max(1.0)
}

/// One logit of the tied head: `dot(xrow, w[id]) + b[id]`.
fn head_logit(xrow: &[f32], w: &Tensor, b: &Tensor, id: usize) -> f32 {
    let d = xrow.len();
    let wrow = &w.f32s()[id * d..(id + 1) * d];
    let s: f32 = xrow.iter().zip(wrow).map(|(a, c)| a * c).sum();
    s + b.f32s()[id]
}

// ---------------------------------------------------------------- (a) ---

#[test]
fn forward_full_is_the_training_forward_bitwise() {
    // Project forward_full's final hidden states through the fused head
    // and compare the loss to the training tape's, bitwise: both paths
    // must run the *same* kernels in the same order at batch 1.
    let reg = Registry::builtin();
    ops::set_fused_override(Some(true));
    ops::set_fused_xent_override(Some(true));
    for preset in gpt_presets(&reg) {
        let mut cfg = preset.clone();
        cfg.batch = 1;
        let params = Store::det_init(&param_shapes(&cfg), 7);
        let mut rng = Rng::new(11);
        let tokens: Vec<i32> = (0..cfg.seq).map(|_| rng.below(cfg.vocab) as i32).collect();
        let mut labels: Vec<i32> = tokens[1..].to_vec();
        labels.push(-1);
        let mut batch = Store::new();
        batch.insert("tokens", Tensor::from_i32(&[1, cfg.seq], tokens.clone()));
        batch.insert("labels", Tensor::from_i32(&[1, cfg.seq], labels.clone()));
        let (train_loss, _) = loss_only(&cfg, &params, &batch).unwrap();
        let dec = Decoder::new(&cfg, &params).unwrap();
        let xf = dec.forward_full(&tokens).unwrap();
        let (w, b) = dec.head();
        let (head_loss, _count, stats) = ops::lm_head_xent_fwd(&xf, w, Some(b), &labels);
        arena::recycle_buf(stats);
        arena::recycle(xf);
        assert_eq!(
            train_loss.to_bits(),
            head_loss.to_bits(),
            "'{}': training loss {train_loss} != decode-anchor loss {head_loss}",
            cfg.name
        );
    }
    ops::set_fused_override(None);
    ops::set_fused_xent_override(None);
}

#[test]
fn incremental_decode_matches_full_forward_on_every_gpt_preset() {
    let reg = Registry::builtin();
    let presets = gpt_presets(&reg);
    assert!(presets.len() >= 2, "registry lost its gpt presets");
    for (pi, cfg) in presets.iter().enumerate() {
        let params = Store::det_init(&param_shapes(cfg), 9);
        let dec = Decoder::new(cfg, &params).unwrap();
        let mut rng = Rng::new(0xD0 + pi as u64);
        let t = cfg.seq.min(16);
        let tokens: Vec<i32> = (0..t).map(|_| rng.below(cfg.vocab) as i32).collect();
        let full = dec.forward_full(&tokens).unwrap();
        // odd page size: steps cross page boundaries mid-run
        let page_tokens = 3;
        let mut pool = PagePool::new(page_tokens * cfg.dim);
        let mut cache = KvCache::new(cfg.layers, page_tokens, cfg.dim, cfg.seq);
        let prefix = (t / 2).max(1);
        let pre = dec.prefill(&tokens[..prefix], &mut cache, &mut pool).unwrap();
        for (i, (g, e)) in pre.f32s().iter().zip(full.f32s()).enumerate() {
            assert!(
                rel_err(*g, *e) <= 1e-5,
                "'{}' prefill elem {i}: {g} vs {e}",
                cfg.name
            );
        }
        arena::recycle(pre);
        let (w, b) = dec.head();
        for (pos, &tok) in tokens.iter().enumerate().skip(prefix) {
            let feeds = [StepInput { token: tok, pos }];
            let xf = dec
                .decode_step(&feeds, std::slice::from_mut(&mut cache), &mut pool)
                .unwrap();
            let want = &full.f32s()[pos * cfg.dim..(pos + 1) * cfg.dim];
            for (i, (g, e)) in xf.f32s().iter().zip(want).enumerate() {
                assert!(
                    rel_err(*g, *e) <= 1e-5,
                    "'{}' step {pos} elem {i}: {g} vs {e}",
                    cfg.name
                );
            }
            // greedy-token parity against the full forward's row; a
            // near-tie (top-2 gap inside float noise) is the only
            // acceptable divergence
            let inc = ops::lm_head_sample(&xf, w, Some(b), &[SampleSpec::greedy()])[0];
            let row = Tensor::from_f32(&[1, cfg.dim], want.to_vec());
            let am = ops::lm_head_argmax(&row, w, Some(b))[0];
            if inc != am {
                let zi = head_logit(xf.f32s(), w, b, inc);
                let za = head_logit(want, w, b, am);
                assert!(
                    (zi - za).abs() <= 1e-4 * zi.abs().max(za.abs()).max(1.0),
                    "'{}' step {pos}: greedy {inc} != argmax {am} and logits differ ({zi} vs {za})",
                    cfg.name
                );
            }
            arena::recycle(xf);
        }
        arena::recycle(full);
        cache.release(&mut pool);
        assert_eq!(pool.live(), 0, "'{}' leaked KV pages", cfg.name);
        pool.check_invariants().unwrap();
    }
}

#[test]
fn lemon_grown_model_decodes_bitwise_like_its_full_forward() {
    // A grown model must serve exactly like a scratch one: LEMON-expand
    // the in-regime tiny pair and require *bitwise* prefill/step parity
    // (all shapes sit on the shared dot-product kernel path).
    let cfg_s = tiny_gpt("lemon_gpt_s", 2, 8, 2, 24, 6);
    let cfg_l = tiny_gpt("lemon_gpt_l", 3, 16, 4, 24, 6);
    Lemon::check_pair(&cfg_s, &cfg_l).unwrap();
    let small = Store::det_init(&param_shapes(&cfg_s), 21);
    let grown = Lemon.expand(&small, &cfg_s, &cfg_l).unwrap();
    let dec = Decoder::new(&cfg_l, &grown).unwrap();
    let tokens: Vec<i32> = vec![2, 7, 1, 19, 0, 23];
    let full = dec.forward_full(&tokens).unwrap();
    let mut pool = PagePool::new(2 * cfg_l.dim);
    let mut cache = KvCache::new(cfg_l.layers, 2, cfg_l.dim, cfg_l.seq);
    let prefix = 3;
    let pre = dec.prefill(&tokens[..prefix], &mut cache, &mut pool).unwrap();
    for (g, e) in pre.f32s().iter().zip(full.f32s()) {
        assert_eq!(g.to_bits(), e.to_bits(), "grown prefill must be bitwise");
    }
    arena::recycle(pre);
    let (w, b) = dec.head();
    for (pos, &tok) in tokens.iter().enumerate().skip(prefix) {
        let feeds = [StepInput { token: tok, pos }];
        let xf = dec
            .decode_step(&feeds, std::slice::from_mut(&mut cache), &mut pool)
            .unwrap();
        let want = &full.f32s()[pos * cfg_l.dim..(pos + 1) * cfg_l.dim];
        for (g, e) in xf.f32s().iter().zip(want) {
            assert_eq!(g.to_bits(), e.to_bits(), "grown step {pos} must be bitwise");
        }
        let inc = ops::lm_head_sample(&xf, w, Some(b), &[SampleSpec::greedy()])[0];
        let row = Tensor::from_f32(&[1, cfg_l.dim], want.to_vec());
        assert_eq!(inc, ops::lm_head_argmax(&row, w, Some(b))[0]);
        arena::recycle(xf);
    }
    arena::recycle(full);
    cache.release(&mut pool);
    assert_eq!(pool.live(), 0);
}

// ---------------------------------------------------------------- (b) ---

#[test]
fn any_admission_interleaving_reproduces_solo_token_streams() {
    let cfg = tiny_gpt("sched_gpt", 2, 8, 2, 48, 16);
    let params = Store::det_init(&param_shapes(&cfg), 33);
    let dec = Decoder::new(&cfg, &params).unwrap();
    let plens = [2usize, 5, 3, 7, 1];
    let news = [6usize, 3, 8, 2, 5];
    let ks = [1usize, 4, 8, 2, 6];
    let ps = [1.0f32, 0.9, 0.6, 1.0, 0.8];
    let mut rng = Rng::new(0xAB);
    let reqs: Vec<Request> = (0..5)
        .map(|i| Request {
            id: i as u64,
            prompt: (0..plens[i]).map(|_| rng.below(cfg.vocab) as i32).collect(),
            max_new: news[i],
            top_k: ks[i],
            top_p: ps[i],
            seed: 100 + i as u64,
            deadline_steps: 0,
        })
        .collect();
    let opts = |ms: usize| ServeOptions { max_sessions: ms, page_tokens: 4, max_pages: 0 };

    // ground truth: each session decoded entirely alone
    let mut solo: Vec<Completion> = reqs
        .iter()
        .map(|r| {
            let mut s = Scheduler::new(&dec, opts(1));
            s.submit(r.clone()).unwrap();
            s.run().unwrap();
            assert_eq!(s.pool().live(), 0);
            let mut done = s.take_done();
            assert_eq!(done.len(), 1);
            done.pop().unwrap()
        })
        .collect();
    solo.sort_by_key(|c| c.id);

    let check = |mut done: Vec<Completion>, what: &str| {
        done.sort_by_key(|c| c.id);
        assert_eq!(done, solo, "{what} changed a token stream");
    };

    for ms in [2usize, 3, 5] {
        let mut s = Scheduler::new(&dec, opts(ms));
        for r in &reqs {
            s.submit(r.clone()).unwrap();
        }
        s.run().unwrap();
        assert_eq!(s.pool().live(), 0);
        check(s.take_done(), &format!("batched run (max_sessions {ms})"));

        let mut s = Scheduler::new(&dec, opts(ms));
        for r in reqs.iter().rev() {
            s.submit(r.clone()).unwrap();
        }
        s.run().unwrap();
        check(s.take_done(), &format!("reversed admission (max_sessions {ms})"));
    }

    // staggered admissions: late arrivals join mid-flight sessions
    let mut s = Scheduler::new(&dec, opts(3));
    for r in &reqs[..2] {
        s.submit(r.clone()).unwrap();
    }
    s.step().unwrap();
    s.step().unwrap();
    for r in &reqs[2..] {
        s.submit(r.clone()).unwrap();
    }
    s.run().unwrap();
    assert_eq!(s.pool().live(), 0);
    check(s.take_done(), "staggered admission");
}

#[test]
fn backpressure_and_deadlines_preserve_scheduler_determinism() {
    let cfg = tiny_gpt("robust_gpt", 2, 8, 2, 48, 16);
    let params = Store::det_init(&param_shapes(&cfg), 33);
    let dec = Decoder::new(&cfg, &params).unwrap();
    let plens = [2usize, 5, 3, 7, 1];
    let news = [6usize, 3, 8, 2, 5];
    let mut rng = Rng::new(0xAC);
    let reqs: Vec<Request> = (0..5)
        .map(|i| Request {
            id: i as u64,
            prompt: (0..plens[i]).map(|_| rng.below(cfg.vocab) as i32).collect(),
            max_new: news[i],
            top_k: [1usize, 4, 8, 2, 6][i],
            top_p: [1.0f32, 0.9, 0.6, 1.0, 0.8][i],
            seed: 200 + i as u64,
            deadline_steps: 0,
        })
        .collect();
    let opts = ServeOptions { max_sessions: 3, page_tokens: 4, max_pages: 0 };

    // ground truth: each session decoded alone on an uncapped pool
    let mut solo: Vec<Completion> = reqs
        .iter()
        .map(|r| {
            let mut s = Scheduler::new(&dec, ServeOptions { max_sessions: 1, ..opts });
            s.submit(r.clone()).unwrap();
            s.run().unwrap();
            s.take_done().pop().unwrap()
        })
        .collect();
    solo.sort_by_key(|c| c.id);

    // the largest session needs layers*2*ceil((3+8)/4) = 12 pages; caps
    // from barely-one-session up to comfortable must all reproduce the
    // solo streams, with the pool never growing past its cap
    for cap in [12usize, 16, 20, 48] {
        let mut s = Scheduler::new(&dec, ServeOptions { max_pages: cap, ..opts });
        for r in &reqs {
            s.submit(r.clone()).unwrap();
        }
        while s.step().unwrap() {
            assert!(s.pool().total() <= cap, "cap {cap}: pool grew past its cap");
        }
        assert_eq!(s.pool().live(), 0, "cap {cap}: leaked pages");
        let mut done = s.take_done();
        done.sort_by_key(|c| c.id);
        assert_eq!(done, solo, "cap {cap} changed a token stream");
    }

    // a request that can never fit is a typed submit error, not a panic
    let mut s = Scheduler::new(&dec, ServeOptions { max_pages: 4, ..opts });
    let err = s.submit(reqs[2].clone()).unwrap_err().to_string();
    assert!(err.contains("capped at 4"), "{err}");

    // deadline budgets: capping session 2 (max_new 8) at 3 decode steps
    // must yield a 4-token prefix of its solo stream (complete == false)
    // while every peer is untouched — under every concurrency level
    let deadline = 3u64;
    for ms in [1usize, 2, 3, 5] {
        let mut s = Scheduler::new(&dec, ServeOptions { max_sessions: ms, ..opts });
        for r in &reqs {
            let mut r = r.clone();
            if r.id == 2 {
                r.deadline_steps = deadline;
            }
            s.submit(r).unwrap();
        }
        s.run().unwrap();
        assert_eq!(s.pool().live(), 0, "ms {ms}: deadline eviction leaked pages");
        let mut done = s.take_done();
        done.sort_by_key(|c| c.id);
        for (got, want) in done.iter().zip(&solo) {
            if got.id == 2 {
                assert!(!got.complete, "ms {ms}: expired session marked complete");
                assert_eq!(got.tokens.len(), 1 + deadline as usize);
                assert_eq!(
                    got.tokens[..],
                    want.tokens[..got.tokens.len()],
                    "ms {ms}: partial stream is not a solo prefix"
                );
            } else {
                assert_eq!(got, want, "ms {ms}: deadline on session 2 disturbed session {}", got.id);
            }
        }
    }
}

// ---------------------------------------------------------------- (c) ---

#[test]
fn page_pool_random_workloads_never_leak_or_alias() {
    // Seeded random alloc/free against a mirror model; a unique sentinel
    // fill per allocation catches aliasing, live-count tracking catches
    // leaks, and check_invariants runs after every operation.
    let seeds: Vec<u64> = match knobs::u64_env("LIGO_PROP_SEED") {
        Some(s) => vec![s],
        None => (0..8).collect(),
    };
    for seed in seeds {
        let mut pool = PagePool::new(16);
        let mut rng = Rng::new(seed);
        let mut live: Vec<(usize, f32)> = Vec::new();
        for op in 0..400u32 {
            if live.is_empty() || rng.coin(0.55) {
                let idx = pool.alloc();
                let sentinel = (seed as u32 * 1000 + op) as f32;
                pool.page_mut(idx).fill(sentinel);
                live.push((idx, sentinel));
            } else {
                let j = rng.below(live.len());
                let (idx, sentinel) = live.swap_remove(j);
                assert!(
                    pool.page(idx).iter().all(|&x| x == sentinel),
                    "seed {seed} op {op}: page {idx} clobbered (aliased)"
                );
                pool.free(idx);
            }
            pool.check_invariants().unwrap_or_else(|e| panic!("seed {seed} op {op}: {e}"));
            assert_eq!(pool.live(), live.len(), "seed {seed} op {op}: leak");
            if op % 16 == 0 {
                for &(idx, sentinel) in &live {
                    assert!(
                        pool.page(idx).iter().all(|&x| x == sentinel),
                        "seed {seed} op {op}: live page {idx} lost its sentinel"
                    );
                }
            }
        }
        // steady state: a drained pool re-serves everything from the free
        // list — the fresh-page counter must not move
        let total = pool.total();
        for (idx, _) in live.drain(..) {
            pool.free(idx);
        }
        assert_eq!(pool.live(), 0);
        let (fresh, _) = pool.stats();
        let held: Vec<usize> = (0..total).map(|_| pool.alloc()).collect();
        assert_eq!(pool.stats().0, fresh, "seed {seed}: steady-state alloc went fresh");
        for idx in held {
            pool.free(idx);
        }
        pool.check_invariants().unwrap();
        pool.clear();
    }
}

#[test]
fn warm_decode_loop_performs_zero_fresh_allocations() {
    if !arena::enabled() {
        return;
    }
    let cfg = tiny_gpt("steady_gpt", 2, 8, 2, 24, 8);
    let params = Store::det_init(&param_shapes(&cfg), 41);
    let dec = Decoder::new(&cfg, &params).unwrap();
    let mut pool = PagePool::new(2 * cfg.dim);
    let run = |pool: &mut PagePool| {
        let mut cache = KvCache::new(cfg.layers, 2, cfg.dim, cfg.seq);
        arena::recycle(dec.prefill(&[1, 2, 3], &mut cache, pool).unwrap());
        let (w, b) = dec.head();
        let mut tok = 5i32;
        for pos in 3..cfg.seq {
            let feeds = [StepInput { token: tok, pos }];
            let xf = dec.decode_step(&feeds, std::slice::from_mut(&mut cache), pool).unwrap();
            tok = ops::lm_head_sample(&xf, w, Some(b), &[SampleSpec::greedy()])[0] as i32;
            arena::recycle(xf);
        }
        cache.release(pool);
    };
    run(&mut pool); // warm: populate the recycle pools and the page pool
    arena::reset_stats();
    let fresh_pages = pool.stats().0;
    run(&mut pool);
    let (fresh, reused) = arena::stats();
    assert_eq!(fresh, 0, "warm decode loop allocated {fresh} fresh arena buffers");
    assert!(reused > 0, "warm decode loop must be recycling buffers");
    assert_eq!(pool.stats().0, fresh_pages, "warm decode loop allocated fresh pages");
    assert_eq!(pool.live(), 0);
}

// ---------------------------------------------------------------- (d) ---

#[test]
fn greedy_sampling_is_argmax_on_a_multi_tile_vocab() {
    // vocab 300 spans three streaming tiles; top_k = 1 must reproduce
    // lm_head_argmax exactly, whatever top_p/u say.
    let (n, d, v) = (5usize, 16usize, 300usize);
    let mut rng = Rng::new(0x5A);
    let x = Tensor::from_f32(&[n, d], (0..n * d).map(|_| rng.normal()).collect());
    let w = Tensor::from_f32(&[v, d], (0..v * d).map(|_| rng.normal()).collect());
    let b = Tensor::from_f32(&[v], (0..v).map(|_| rng.normal()).collect());
    let am = ops::lm_head_argmax(&x, &w, Some(&b));
    let greedy = ops::lm_head_sample(&x, &w, Some(&b), &vec![SampleSpec::greedy(); n]);
    assert_eq!(greedy, am);
    let tricky: Vec<SampleSpec> =
        (0..n).map(|_| SampleSpec { top_k: 1, top_p: 0.01, u: 0.97 }).collect();
    assert_eq!(
        ops::lm_head_sample(&x, &w, Some(&b), &tricky),
        am,
        "top_k = 1 is greedy regardless of top_p/u"
    );
}

#[test]
fn top_p_sampling_matches_a_materialized_softmax_reference() {
    // Packed-path shape (m*k*v hits the packing threshold) so the
    // materialized linear_fused logits are bitwise the streamed tiles;
    // one-tile vocab so the reference can replay the online-LSE
    // arithmetic exactly. The reference materializes the softmax, builds
    // the descending candidate list (stable sort keeps the earliest
    // column on ties, like the streaming insert), truncates to the
    // nucleus, and draws — every pick must agree exactly.
    let (n, d, v) = (8usize, 32usize, 64usize);
    let mut rng = Rng::new(0x7E);
    let x = Tensor::from_f32(&[n, d], (0..n * d).map(|_| rng.normal()).collect());
    let w = Tensor::from_f32(&[v, d], (0..v * d).map(|_| rng.normal()).collect());
    let b = Tensor::from_f32(&[v], (0..v).map(|_| rng.normal()).collect());
    let ks = [64usize, 5, 3, 1, 8, 64, 2, 7];
    let ps = [1.0f32, 0.9, 0.5, 0.7, 0.2, 1e-6, 0.85, 0.65];
    let us = [0.0f32, 0.37, 0.93, 0.5, 0.99, 0.1, 0.77, 0.42];
    let specs: Vec<SampleSpec> =
        (0..n).map(|i| SampleSpec { top_k: ks[i], top_p: ps[i], u: us[i] }).collect();
    let got = ops::lm_head_sample(&x, &w, Some(&b), &specs);

    let (logits, _) = ops::linear_fused(&x, &w, Some(&b), Act::None);
    let am = ops::lm_head_argmax(&x, &w, Some(&b));
    for (i, spec) in specs.iter().enumerate() {
        let row = &logits.f32s()[i * v..(i + 1) * v];
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &z| a.max(z));
        let l: f32 = row.iter().map(|&z| (z - m).exp()).sum();
        let lse = m + l.ln();
        let mut order: Vec<usize> = (0..v).collect();
        order.sort_by(|&a, &c| row[c].partial_cmp(&row[a]).unwrap());
        let keep = spec.top_k.clamp(1, ops::SAMPLE_MAX_TOPK).min(v);
        let cand = &order[..keep];
        let mut take = keep;
        let mut cum = 0.0f32;
        for (c, &id) in cand.iter().enumerate() {
            cum += (row[id] - lse).exp();
            if cum >= spec.top_p {
                take = c + 1;
                break;
            }
        }
        let mass: f32 = cand[..take].iter().map(|&id| (row[id] - lse).exp()).sum();
        let target = spec.u * mass;
        let mut acc = 0.0f32;
        let mut expect = cand[take - 1];
        for &id in &cand[..take] {
            acc += (row[id] - lse).exp();
            if target < acc {
                expect = id;
                break;
            }
        }
        assert_eq!(got[i], expect, "row {i} ({spec:?})");
        if spec.top_p <= 1e-6 {
            assert_eq!(got[i], am[i], "row {i}: tiny nucleus must collapse to argmax");
        }
    }
    arena::recycle(logits);
}
