//! Crash-safe training acceptance suite: a run killed at an arbitrary
//! step and resumed from its latest checkpoint must be **bit-identical**
//! to the uninterrupted run — every eval loss, every FLOPs point, every
//! growth mark, and every final parameter byte. The kill points straddle
//! both stages of a 2-stage growth plan (before the first growth, exactly
//! at each stage boundary, and after the last), the worker-sharded step
//! loop (`LIGO_WORKERS` 1 and 2), and a corrupted-newest checkpoint that
//! forces the resume to fall back one snapshot and replay further.
//!
//! Runs on the synthesized native engine only (like `native_engine.rs`);
//! a pjrt build with a live XLA client skips.

use std::path::PathBuf;

use ligo::config::{ModelConfig, Registry, TrainConfig};
use ligo::coordinator::checkpoint;
use ligo::coordinator::metrics::Curve;
use ligo::coordinator::parallel;
use ligo::coordinator::plan::GrowthPlan;
use ligo::coordinator::trainer::{Batches, Trainer};
use ligo::data::batches::mlm_batch;
use ligo::data::corpus::Corpus;
use ligo::growth::LigoOptions;
use ligo::runtime::Runtime;
use ligo::tensor::store::Store;
use ligo::util::fault::{self, Fault};
use ligo::util::rng::Rng;

/// Original step budget of every run in this suite; the plan's stages at
/// 10 and 20 split it into three config regimes.
const STEPS: usize = 30;

fn native_runtime() -> Option<Runtime> {
    let rt = Runtime::cpu(std::env::temp_dir().join("ligo_ckpt_resume")).unwrap();
    if rt.backend_name() != "native" {
        // pjrt build with a live XLA client: the artifact suite covers it
        return None;
    }
    Some(rt)
}

fn tc() -> TrainConfig {
    TrainConfig { lr: 3e-3, total_steps: STEPS, warmup_steps: 3, eval_every: 5, ..Default::default() }
}

/// The two-stage fixture: stack bert_small's depth at step 10, then
/// LiGO-grow the width at step 20 (a short M-learning fit — enough to
/// exercise the growth-replay path, cheap enough for CI).
fn fixture(reg: &Registry) -> (ModelConfig, GrowthPlan, Corpus) {
    let small = reg.model("bert_small").unwrap().clone();
    let mid = reg.model("bert_d6w48").unwrap().clone();
    let large = reg.model("bert_base").unwrap().clone();
    let plan = GrowthPlan::builder(&small)
        .grow_at(10, &mid, "stackbert")
        .grow_at_with(20, &large, "ligo", LigoOptions { steps: 3, ..Default::default() })
        .build()
        .unwrap();
    let corpus = Corpus::new(small.vocab, 0);
    (small, plan, corpus)
}

/// Index-pure batch source — the property that makes the step counter the
/// entire data cursor, so both runs see byte-identical microbatches.
fn mk_batches(corpus: &Corpus, cfg: &ModelConfig) -> Batches {
    let c1 = corpus.clone();
    let s1 = cfg.clone();
    let c2 = corpus.clone();
    let s2 = cfg.clone();
    Batches::shared(
        move |step| mlm_batch(&c1, &s1, &mut Rng::new(step as u64)),
        move |i| mlm_batch(&c2, &s2, &mut Rng::new(0x55AA + i as u64)),
    )
}

fn reference_run(
    rt: &Runtime,
    small: &ModelConfig,
    plan: &GrowthPlan,
    corpus: &Corpus,
) -> (Curve, Store) {
    let params = Trainer::scratch_params(rt, small, 0).unwrap();
    let mut tr = Trainer::new(rt, small, tc(), params).unwrap();
    let mut b = mk_batches(corpus, small);
    let curve = tr.run_plan(rt, "run", &mut b, STEPS, plan).unwrap();
    (curve, tr.params)
}

/// Train with a `every`-step checkpoint cadence, die at `kill_at`, resume
/// from the latest good snapshot, and finish the original budget.
fn kill_and_resume(
    rt: &Runtime,
    small: &ModelConfig,
    plan: &GrowthPlan,
    corpus: &Corpus,
    kill_at: usize,
    every: usize,
    dir: &PathBuf,
) -> (Curve, Store) {
    std::fs::remove_dir_all(dir).ok();
    let params = Trainer::scratch_params(rt, small, 0).unwrap();
    let mut tr = Trainer::new(rt, small, tc(), params).unwrap();
    tr.checkpoint_every(every, dir.clone());
    let mut b = mk_batches(corpus, small);
    fault::set_override(Some(Fault::KillAtStep(kill_at)));
    let err = tr.run_plan(rt, "run", &mut b, STEPS, plan).unwrap_err();
    assert!(err.to_string().contains("fault injection"), "{err}");
    fault::clear_override();
    drop(tr); // the crashed process is gone; only the disk survives

    let (mut tr, resumed) = Trainer::resume_latest(rt, tc(), dir).unwrap();
    assert_eq!(
        tr.step_count(),
        (kill_at / every) * every,
        "kill@{kill_at}: resumed from the wrong snapshot"
    );
    let mut b = mk_batches(corpus, small);
    let curve = tr.run_plan_resumed(rt, "run", &mut b, STEPS, plan, resumed).unwrap();
    (curve, tr.params)
}

/// Bitwise curve equality on everything the invariant covers (wall time is
/// real time and exempt).
fn assert_curves_bitwise(got: &Curve, want: &Curve, what: &str) {
    assert_eq!(got.steps, want.steps, "{what}: eval steps diverged");
    assert_eq!(got.marks, want.marks, "{what}: growth marks diverged");
    assert_eq!(got.metric.len(), want.metric.len(), "{what}: metric series length");
    for (i, (a, b)) in got.loss.iter().zip(&want.loss).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: loss[{i}] {a} vs {b}");
    }
    for (i, (a, b)) in got.flops.iter().zip(&want.flops).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: flops[{i}] {a} vs {b}");
    }
    for (i, (a, b)) in got.metric.iter().zip(&want.metric).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: metric[{i}] {a} vs {b}");
    }
}

fn assert_stores_bitwise(got: &Store, want: &Store, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: tensor count");
    for ((ka, ta), (kb, tb)) in got.iter().zip(want.iter()) {
        assert_eq!(ka, kb, "{what}: tensor name order");
        assert_eq!(ta.shape, tb.shape, "{what}: '{ka}' shape");
        for (i, (x, y)) in ta.f32s().iter().zip(tb.f32s()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: '{ka}'[{i}] {x} vs {y}");
        }
    }
}

#[test]
fn kill_and_resume_is_bitwise_across_growth_boundaries() {
    let Some(rt) = native_runtime() else { return };
    let reg = Registry::builtin();
    let (small, plan, corpus) = fixture(&reg);
    let (ref_curve, ref_params) = reference_run(&rt, &small, &plan, &corpus);
    let dir = std::env::temp_dir().join("ligo_ckpt_resume").join("kills");
    // before the first growth, exactly at each stage boundary (the
    // checkpoint precedes the stage, so resume replays the growth once),
    // and after the plan completes
    for kill_at in [7usize, 10, 20, 25] {
        let (curve, params) =
            kill_and_resume(&rt, &small, &plan, &corpus, kill_at, 1, &dir);
        assert_curves_bitwise(&curve, &ref_curve, &format!("kill@{kill_at}"));
        assert_stores_bitwise(&params, &ref_params, &format!("kill@{kill_at}"));
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn resume_is_bitwise_under_worker_sharding() {
    let Some(rt) = native_runtime() else { return };
    let reg = Registry::builtin();
    let (small, plan, corpus) = fixture(&reg);
    let dir = std::env::temp_dir().join("ligo_ckpt_resume").join("workers");
    let mut finals: Vec<Store> = Vec::new();
    for w in [1usize, 2] {
        parallel::set_workers_override(Some(w));
        let (ref_curve, ref_params) = reference_run(&rt, &small, &plan, &corpus);
        let (curve, params) = kill_and_resume(&rt, &small, &plan, &corpus, 15, 5, &dir);
        parallel::set_workers_override(None);
        assert_curves_bitwise(&curve, &ref_curve, &format!("workers {w}"));
        assert_stores_bitwise(&params, &ref_params, &format!("workers {w}"));
        finals.push(ref_params);
    }
    // and the sharded path itself is worker-count invariant, so the two
    // reference runs agree with each other too
    assert_stores_bitwise(&finals[1], &finals[0], "workers 2 vs 1");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn resume_falls_back_past_a_corrupted_newest_checkpoint() {
    let Some(rt) = native_runtime() else { return };
    let reg = Registry::builtin();
    let (small, plan, corpus) = fixture(&reg);
    let (ref_curve, ref_params) = reference_run(&rt, &small, &plan, &corpus);
    let dir = std::env::temp_dir().join("ligo_ckpt_resume").join("fallback");
    std::fs::remove_dir_all(&dir).ok();

    let params = Trainer::scratch_params(&rt, &small, 0).unwrap();
    let mut tr = Trainer::new(&rt, &small, tc(), params).unwrap();
    tr.checkpoint_every(5, dir.clone());
    let mut b = mk_batches(&corpus, &small);
    fault::set_override(Some(Fault::KillAtStep(17)));
    tr.run_plan(&rt, "run", &mut b, STEPS, &plan).unwrap_err();
    fault::clear_override();
    drop(tr);

    // flip one byte mid-file in the newest snapshot (step 15): its CRC
    // check must fail and the resume must fall back to step 10
    let newest = checkpoint::checkpoint_path(&dir, 15);
    let mut bytes = std::fs::read(&newest).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    std::fs::write(&newest, &bytes).unwrap();

    let (mut tr, resumed) = Trainer::resume_latest(&rt, tc(), &dir).unwrap();
    assert_eq!(tr.step_count(), 10, "resume must skip the corrupted snapshot");
    let mut b = mk_batches(&corpus, &small);
    let curve = tr.run_plan_resumed(&rt, "run", &mut b, STEPS, &plan, resumed).unwrap();
    assert_curves_bitwise(&curve, &ref_curve, "fallback resume");
    assert_stores_bitwise(&tr.params, &ref_params, "fallback resume");
    std::fs::remove_dir_all(dir).ok();
}
