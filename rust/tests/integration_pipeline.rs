//! Pipeline integration: checkpoint save/load through real training state,
//! the fine-tuning harnesses over the probe artifacts, gated (Fig. 5)
//! artifacts, and the KD trainer path. Requires `make artifacts`.

use ligo::config::{artifacts_dir, Registry, TrainConfig};
use ligo::coordinator::trainer::{Batches, Trainer};
use ligo::data::batches::{gated_batch, mlm_batch};
use ligo::data::corpus::Corpus;
use ligo::data::downstream::{Probe, ProbeKind, SpanProbe};
use ligo::eval::finetune::{finetune_adapters, finetune_probe, finetune_span};
use ligo::runtime::Runtime;
use ligo::tensor::io;
use ligo::util::rng::Rng;

fn runtime() -> Option<(Runtime, Registry)> {
    let dir = artifacts_dir();
    if !dir.join("configs.json").exists() {
        eprintln!("artifacts not built; skipping");
        return None;
    }
    Some((Runtime::cpu(&dir).unwrap(), Registry::load(&dir).unwrap()))
}

#[test]
fn checkpoint_roundtrip_through_training() {
    let Some((rt, reg)) = runtime() else { return };
    let cfg = reg.model("bert_small").unwrap().clone();
    let corpus = Corpus::new(cfg.vocab, 0);
    let params = Trainer::scratch_params(&rt, &cfg, 0).unwrap();
    let tc = TrainConfig { total_steps: 5, warmup_steps: 1, eval_every: 5, ..Default::default() };
    let mut tr = Trainer::new(&rt, &cfg, tc, params).unwrap();
    let c = corpus.clone();
    let cc = cfg.clone();
    for _ in 0..5 {
        tr.train_step(&mut |s| mlm_batch(&c, &cc, &mut Rng::new(s as u64))).unwrap();
    }
    let path = std::env::temp_dir().join("ligo_integ_ckpt.lgck");
    io::save(&tr.params, &path).unwrap();
    let loaded = io::load(&path).unwrap();
    assert_eq!(tr.params, loaded);
    // loaded params produce the identical loss through the runtime
    let fwd = rt.load("fwd_bert_small").unwrap();
    let batch = mlm_batch(&corpus, &cfg, &mut Rng::new(99));
    let a = fwd.run(&[("params", &tr.params), ("batch", &batch)]).unwrap().scalar("loss").unwrap();
    let b = fwd.run(&[("params", &loaded), ("batch", &batch)]).unwrap().scalar("loss").unwrap();
    assert_eq!(a, b);
    std::fs::remove_file(path).ok();
}

#[test]
fn probe_finetune_learns_topic_task() {
    let Some((rt, reg)) = runtime() else { return };
    let probe_cfg = reg.model("probe_bert_base").unwrap().clone();
    let corpus = Corpus::new(512, 0);
    // body: det-init bert_base (untrained is fine; the probe head can still
    // pick up topical signal — we assert above-chance, not paper accuracy)
    let body = Trainer::scratch_params(&rt, reg.model("bert_base").unwrap(), 0).unwrap();
    let tc = TrainConfig::finetune(40);
    let p1 = Probe::new(ProbeKind::Sst2, corpus.clone());
    let c1 = probe_cfg.clone();
    let mut trb = move |s: usize| p1.batch(&c1, &mut Rng::new(s as u64));
    let p2 = Probe::new(ProbeKind::Sst2, corpus.clone());
    let c2 = probe_cfg.clone();
    let mut evb = move |s: usize| p2.batch(&c2, &mut Rng::new(0xE0 + s as u64));
    let res = finetune_probe(&rt, "probe_bert_base", "sst2", &body, &tc, &mut trb, &mut evb)
        .unwrap();
    assert!(res.accuracy.is_finite());
    assert!(res.accuracy > 0.4, "acc {}", res.accuracy); // not degenerate
}

#[test]
fn span_finetune_runs() {
    let Some((rt, reg)) = runtime() else { return };
    let probe_cfg = reg.model("probe_bert_base").unwrap().clone();
    let corpus = Corpus::new(512, 0);
    let body = Trainer::scratch_params(&rt, reg.model("bert_base").unwrap(), 0).unwrap();
    let tc = TrainConfig::finetune(15);
    let pr = SpanProbe::v1(corpus.clone());
    let c1 = probe_cfg.clone();
    let mut trb = move |s: usize| pr.batch(&c1, &mut Rng::new(s as u64));
    let pr2 = SpanProbe::v1(corpus);
    let mut evb = move |s: usize| pr2.batch(&probe_cfg, &mut Rng::new(0xE0 + s as u64));
    let res = finetune_span(&rt, "squad", &body, &tc, &mut trb, &mut evb).unwrap();
    assert!(res.final_loss.is_finite());
}

#[test]
fn adapter_finetune_touches_only_adapters() {
    let Some((rt, reg)) = runtime() else { return };
    let probe_cfg = reg.model("probe_bert_base").unwrap().clone();
    let corpus = Corpus::new(512, 0);
    let body = Trainer::scratch_params(&rt, reg.model("bert_base").unwrap(), 0).unwrap();
    let tc = TrainConfig::finetune(10);
    let p1 = Probe::new(ProbeKind::Qnli, corpus.clone());
    let c1 = probe_cfg.clone();
    let mut trb = move |s: usize| p1.batch(&c1, &mut Rng::new(s as u64));
    let p2 = Probe::new(ProbeKind::Qnli, corpus);
    let mut evb = move |s: usize| p2.batch(&probe_cfg, &mut Rng::new(0xE0 + s as u64));
    let res = finetune_adapters(&rt, "qnli", &body, &tc, &mut trb, &mut evb).unwrap();
    assert!(res.accuracy.is_finite() && res.final_loss.is_finite());
}

#[test]
fn gated_artifact_accepts_gates() {
    let Some((rt, reg)) = runtime() else { return };
    let cfg = reg.model("bert_base").unwrap().clone();
    let corpus = Corpus::new(cfg.vocab, 0);
    let exe = rt.load("grad_gated_bert_base").unwrap();
    let params = ligo::tensor::store::Store::det_init(&exe.manifest.shapes_of("params"), 0);
    // all gates on vs one layer off must change the loss
    let b_on = gated_batch(&corpus, &cfg, &mut Rng::new(1), 0.0, 0.0);
    let mut b_off = gated_batch(&corpus, &cfg, &mut Rng::new(1), 0.0, 0.0);
    let mut gates = vec![1.0f32; cfg.layers];
    gates[0] = 0.0;
    b_off.insert("gates", ligo::tensor::Tensor::from_f32(&[cfg.layers], gates));
    let l_on = exe.run(&[("params", &params), ("batch", &b_on)]).unwrap().scalar("loss").unwrap();
    let l_off = exe.run(&[("params", &params), ("batch", &b_off)]).unwrap().scalar("loss").unwrap();
    assert!(l_on.is_finite() && l_off.is_finite());
    assert_ne!(l_on, l_off);
}

#[test]
fn kd_trainer_path_works() {
    let Some((rt, reg)) = runtime() else { return };
    let small = reg.model("bert_small").unwrap().clone();
    let large = reg.model("bert_base").unwrap().clone();
    let corpus = Corpus::new(large.vocab, 0);
    let teacher = Trainer::scratch_params(&rt, &small, 0).unwrap();
    let student = Trainer::scratch_params(&rt, &large, 1).unwrap();
    let tc = TrainConfig { total_steps: 3, warmup_steps: 1, eval_every: 3, ..Default::default() };
    let mut tr = Trainer::with_artifacts(
        &rt, "kd_grad_bert_small__bert_base", "fwd_bert_base", &large, tc, student,
    )
    .unwrap();
    tr.extra = vec![("teacher".to_string(), teacher)];
    let mut b = Batches::shared(
        {
            let c = corpus.clone();
            let l = large.clone();
            move |s| mlm_batch(&c, &l, &mut Rng::new(s as u64))
        },
        {
            let c = corpus.clone();
            let l = large.clone();
            move |s| mlm_batch(&c, &l, &mut Rng::new(0xE0 + s as u64))
        },
    );
    let curve = tr.run("kd", &mut b, 3).unwrap();
    assert!(curve.loss.iter().all(|l| l.is_finite()));
}

#[test]
fn grad_accumulation_matches_recipe() {
    let Some((rt, reg)) = runtime() else { return };
    let cfg = reg.model("bert_small").unwrap().clone();
    let corpus = Corpus::new(cfg.vocab, 0);
    let params = Trainer::scratch_params(&rt, &cfg, 0).unwrap();
    let tc = TrainConfig { grad_accum: 4, total_steps: 2, warmup_steps: 1, ..Default::default() };
    let mut tr = Trainer::new(&rt, &cfg, tc, params).unwrap();
    let mut seen = std::collections::BTreeSet::new();
    let c = corpus.clone();
    let loss = tr
        .train_step(&mut |s| {
            seen.insert(s);
            mlm_batch(&c, &cfg, &mut Rng::new(s as u64))
        })
        .unwrap();
    assert!(loss.is_finite());
    assert_eq!(seen.len(), 4, "4 microbatches per accumulated step");
}
