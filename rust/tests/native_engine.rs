//! End-to-end integration over the synthesized native engine: from a clean
//! checkout (no artifacts, no XLA), the default runtime must train, eval
//! and grow — the workload the old NullBackend default could not execute.

use ligo::config::{Registry, TrainConfig};
use ligo::coordinator::trainer::{eval_store, Batches, Trainer};
use ligo::data::batches::mlm_batch;
use ligo::data::corpus::Corpus;
use ligo::data::vision::VisionTask;
use ligo::runtime::Runtime;
use ligo::util::rng::Rng;

fn native_runtime() -> Option<Runtime> {
    let rt = Runtime::cpu(std::env::temp_dir().join("ligo_native_e2e")).unwrap();
    if rt.backend_name() != "native" {
        // pjrt build with a live XLA client: the artifact suite covers it
        return None;
    }
    Some(rt)
}

#[test]
fn trainer_reduces_loss_on_the_native_backend() {
    let Some(rt) = native_runtime() else { return };
    let reg = Registry::builtin();
    let cfg = reg.model("bert_small").unwrap().clone();
    let corpus = Corpus::new(cfg.vocab, 0);
    let params = Trainer::scratch_params(&rt, &cfg, 0).unwrap();
    let tc = TrainConfig {
        lr: 3e-3,
        total_steps: 25,
        warmup_steps: 3,
        eval_every: 25,
        ..Default::default()
    };
    let mut tr = Trainer::new(&rt, &cfg, tc, params).unwrap();
    let c1 = corpus.clone();
    let cfg1 = cfg.clone();
    let mut batches = Batches {
        train: Box::new(move |step| mlm_batch(&c1, &cfg1, &mut Rng::new(step as u64))),
        eval: Box::new({
            let c = corpus.clone();
            let cfg = cfg.clone();
            move |i| mlm_batch(&c, &cfg, &mut Rng::new(0x77AA + i as u64))
        }),
    };
    let curve = tr.run("native_smoke", &mut batches, 25).unwrap();
    assert!(curve.loss.iter().all(|l| l.is_finite()), "{:?}", curve.loss);
    let (first, last) = (curve.loss[0], *curve.loss.last().unwrap());
    assert!(
        last < first - 0.05,
        "native training must reduce loss: {first} -> {last}"
    );
}

#[test]
fn vision_fwd_reports_loss_and_accuracy_metric() {
    let Some(rt) = native_runtime() else { return };
    let reg = Registry::builtin();
    let cfg = reg.model("vit_s").unwrap().clone();
    let fwd = rt.load("fwd_vit_s").unwrap();
    let params = Trainer::scratch_params(&rt, &cfg, 1).unwrap();
    let task = VisionTask::pretrain();
    let cfg2 = cfg.clone();
    let mut eb = move |i: usize| task.batch(&cfg2, &mut Rng::new(0xBEEF + i as u64));
    let (loss, metric) = eval_store(&fwd, &params, &mut eb, 2).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    let acc = metric.expect("vision fwd must report the accuracy metric");
    assert!((0.0..=1.0).contains(&acc), "acc {acc}");
}

#[test]
fn fused_and_unfused_kernels_agree_end_to_end() {
    // The fused linear+bias(+GELU) lowering only reassociates reductions:
    // a whole-model eval must agree with the unfused chain to float noise.
    let Some(rt) = native_runtime() else { return };
    let reg = Registry::builtin();
    let cfg = reg.model("bert_small").unwrap().clone();
    let fwd = rt.load("fwd_bert_small").unwrap();
    let params = Trainer::scratch_params(&rt, &cfg, 3).unwrap();
    let corpus = Corpus::new(cfg.vocab, 0);
    let mut eb = |i: usize| mlm_batch(&corpus, &cfg, &mut Rng::new(0xF00D + i as u64));
    ligo::tensor::ops::set_fused_override(Some(true));
    let (lf, _) = eval_store(&fwd, &params, &mut eb, 2).unwrap();
    ligo::tensor::ops::set_fused_override(Some(false));
    let (lu, _) = eval_store(&fwd, &params, &mut eb, 2).unwrap();
    ligo::tensor::ops::set_fused_override(None);
    assert!(lf.is_finite() && lu.is_finite());
    assert!((lf - lu).abs() <= 1e-4 * lf.abs().max(1.0), "fused {lf} vs unfused {lu}");
}

#[test]
fn probe_preset_synthesizes_with_metric() {
    let Some(rt) = native_runtime() else { return };
    let exe = rt.load("fwd_probe_bert_small").unwrap();
    assert!(exe.manifest.output_index("metric").is_some());
    assert_eq!(exe.manifest.inputs_of("batch")[1].shape, vec![16]);
}
