//! End-to-end integration over the synthesized native engine: from a clean
//! checkout (no artifacts, no XLA), the default runtime must train, eval
//! and grow — the workload the old NullBackend default could not execute.

use ligo::config::{Registry, TrainConfig};
use ligo::coordinator::plan::GrowthPlan;
use ligo::coordinator::trainer::{eval_store, Batches, Trainer};
use ligo::data::batches::mlm_batch;
use ligo::data::corpus::Corpus;
use ligo::data::vision::VisionTask;
use ligo::growth::LigoOptions;
use ligo::runtime::Runtime;
use ligo::util::rng::Rng;

fn native_runtime() -> Option<Runtime> {
    let rt = Runtime::cpu(std::env::temp_dir().join("ligo_native_e2e")).unwrap();
    if rt.backend_name() != "native" {
        // pjrt build with a live XLA client: the artifact suite covers it
        return None;
    }
    Some(rt)
}

#[test]
fn trainer_reduces_loss_on_the_native_backend() {
    let Some(rt) = native_runtime() else { return };
    let reg = Registry::builtin();
    let cfg = reg.model("bert_small").unwrap().clone();
    let corpus = Corpus::new(cfg.vocab, 0);
    let params = Trainer::scratch_params(&rt, &cfg, 0).unwrap();
    let tc = TrainConfig {
        lr: 3e-3,
        total_steps: 25,
        warmup_steps: 3,
        eval_every: 25,
        ..Default::default()
    };
    let mut tr = Trainer::new(&rt, &cfg, tc, params).unwrap();
    let c1 = corpus.clone();
    let cfg1 = cfg.clone();
    let mut batches = Batches::shared(
        move |step| mlm_batch(&c1, &cfg1, &mut Rng::new(step as u64)),
        {
            let c = corpus.clone();
            let cfg = cfg.clone();
            move |i| mlm_batch(&c, &cfg, &mut Rng::new(0x77AA + i as u64))
        },
    );
    let curve = tr.run("native_smoke", &mut batches, 25).unwrap();
    assert!(curve.loss.iter().all(|l| l.is_finite()), "{:?}", curve.loss);
    let (first, last) = (curve.loss[0], *curve.loss.last().unwrap());
    assert!(
        last < first - 0.05,
        "native training must reduce loss: {first} -> {last}"
    );
}

#[test]
fn two_stage_growth_plan_runs_mid_training_with_visible_growth_steps() {
    // the api_redesign acceptance scenario: one trainer, one batch source,
    // a 2-stage GrowthPlan (stack the depth, then LiGO-grow the width)
    // executed mid-run — the curve must stay finite, descend overall, and
    // carry the growth steps as marks.
    let Some(rt) = native_runtime() else { return };
    let reg = Registry::builtin();
    let small = reg.model("bert_small").unwrap().clone(); // 3 x 48
    let mid = reg.model("bert_d6w48").unwrap().clone(); // 6 x 48
    let large = reg.model("bert_base").unwrap().clone(); // 6 x 72
    let plan = GrowthPlan::builder(&small)
        .grow_at(10, &mid, "stackbert")
        .grow_at_with(20, &large, "ligo", LigoOptions { steps: 3, ..Default::default() })
        .build()
        .unwrap();
    let corpus = Corpus::new(small.vocab, 0);
    let params = Trainer::scratch_params(&rt, &small, 0).unwrap();
    let tc = TrainConfig {
        lr: 3e-3,
        total_steps: 30,
        warmup_steps: 3,
        eval_every: 5,
        ..Default::default()
    };
    let mut tr = Trainer::new(&rt, &small, tc, params).unwrap();
    let c1 = corpus.clone();
    let s1 = small.clone();
    let mut batches = Batches::shared(
        move |step| mlm_batch(&c1, &s1, &mut Rng::new(step as u64)),
        {
            let c = corpus.clone();
            let cfg = small.clone();
            move |i| mlm_batch(&c, &cfg, &mut Rng::new(0x55AA + i as u64))
        },
    );
    // a stage beyond this run's budget is rejected up front, not skipped
    let far = GrowthPlan::builder(&small)
        .grow_at(100, &mid, "stackbert")
        .build()
        .unwrap();
    let err = tr.run_plan(&rt, "far", &mut batches, 30, &far).unwrap_err();
    assert!(err.to_string().contains("unreachable"), "{err}");
    let curve = tr.run_plan(&rt, "plan_smoke", &mut batches, 30, &plan).unwrap();
    // the trainer ended on the final config with its shapes
    assert_eq!(tr.cfg.name, "bert_base");
    assert_eq!(tr.params.expect("L05_q_w").shape, vec![72, 72]);
    // growth steps are visible in the metrics
    assert_eq!(curve.marks.len(), 2, "marks: {:?}", curve.marks);
    assert_eq!(curve.marks[0].0, 10);
    assert_eq!(curve.marks[1].0, 20);
    assert!(curve.marks[0].1.contains("stackbert"), "{:?}", curve.marks);
    assert!(curve.marks[1].1.contains("ligo"), "{:?}", curve.marks);
    // non-trivial curve: finite everywhere, descending overall
    assert!(curve.loss.iter().all(|l| l.is_finite()), "{:?}", curve.loss);
    let (first, last) = (curve.loss[0], *curve.loss.last().unwrap());
    assert!(
        last < first - 0.05,
        "plan run must reduce loss end to end: {first} -> {last}"
    );
    // the growth FLOPs were charged to the ledger: the series is monotone
    // (stackbert's param-only stage adds 0) and strictly grew overall
    for w in curve.flops.windows(2) {
        assert!(w[1] >= w[0], "flops must be monotone: {:?}", curve.flops);
    }
    assert!(curve.flops.last().unwrap() > &0.0);
}

#[test]
fn vision_fwd_reports_loss_and_accuracy_metric() {
    let Some(rt) = native_runtime() else { return };
    let reg = Registry::builtin();
    let cfg = reg.model("vit_s").unwrap().clone();
    let fwd = rt.load("fwd_vit_s").unwrap();
    let params = Trainer::scratch_params(&rt, &cfg, 1).unwrap();
    let task = VisionTask::pretrain();
    let cfg2 = cfg.clone();
    let mut eb = move |i: usize| task.batch(&cfg2, &mut Rng::new(0xBEEF + i as u64));
    let (loss, metric) = eval_store(&fwd, &params, &mut eb, 2).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    let acc = metric.expect("vision fwd must report the accuracy metric");
    assert!((0.0..=1.0).contains(&acc), "acc {acc}");
}

#[test]
fn fused_and_unfused_kernels_agree_end_to_end() {
    // The fused linear+bias(+GELU) lowering only reassociates reductions:
    // a whole-model eval must agree with the unfused chain to float noise.
    let Some(rt) = native_runtime() else { return };
    let reg = Registry::builtin();
    let cfg = reg.model("bert_small").unwrap().clone();
    let fwd = rt.load("fwd_bert_small").unwrap();
    let params = Trainer::scratch_params(&rt, &cfg, 3).unwrap();
    let corpus = Corpus::new(cfg.vocab, 0);
    let mut eb = |i: usize| mlm_batch(&corpus, &cfg, &mut Rng::new(0xF00D + i as u64));
    ligo::tensor::ops::set_fused_override(Some(true));
    let (lf, _) = eval_store(&fwd, &params, &mut eb, 2).unwrap();
    ligo::tensor::ops::set_fused_override(Some(false));
    let (lu, _) = eval_store(&fwd, &params, &mut eb, 2).unwrap();
    ligo::tensor::ops::set_fused_override(None);
    assert!(lf.is_finite() && lu.is_finite());
    assert!((lf - lu).abs() <= 1e-4 * lf.abs().max(1.0), "fused {lf} vs unfused {lu}");
}

#[test]
fn streaming_and_materialized_lm_head_agree_end_to_end() {
    // The streaming fused LM head (LIGO_FUSED_XENT) only reassociates the
    // softmax reduction: a whole-model eval must agree with the
    // materialized linear+masked_xent chain to float noise, on both a
    // tied-head LM preset and a vision classifier (which also reports the
    // streamed accuracy metric).
    let _guard = XENT_OVERRIDE.lock().unwrap_or_else(|e| e.into_inner());
    let Some(rt) = native_runtime() else { return };
    let reg = Registry::builtin();
    let cfg = reg.model("bert_small").unwrap().clone();
    let fwd = rt.load("fwd_bert_small").unwrap();
    let params = Trainer::scratch_params(&rt, &cfg, 5).unwrap();
    let corpus = Corpus::new(cfg.vocab, 0);
    let mut eb = |i: usize| mlm_batch(&corpus, &cfg, &mut Rng::new(0xABCD + i as u64));
    ligo::tensor::ops::set_fused_xent_override(Some(true));
    let (lf, _) = eval_store(&fwd, &params, &mut eb, 2).unwrap();
    ligo::tensor::ops::set_fused_xent_override(Some(false));
    let (lu, _) = eval_store(&fwd, &params, &mut eb, 2).unwrap();
    ligo::tensor::ops::set_fused_xent_override(None);
    assert!(lf.is_finite() && lu.is_finite());
    assert!((lf - lu).abs() <= 1e-4 * lf.abs().max(1.0), "streamed {lf} vs materialized {lu}");

    let vcfg = reg.model("vit_s").unwrap().clone();
    let vfwd = rt.load("fwd_vit_s").unwrap();
    let vparams = Trainer::scratch_params(&rt, &vcfg, 6).unwrap();
    let task = VisionTask::pretrain();
    let vcfg2 = vcfg.clone();
    let mut vb = move |i: usize| task.batch(&vcfg2, &mut Rng::new(0xD00D + i as u64));
    ligo::tensor::ops::set_fused_xent_override(Some(true));
    let (vlf, vmf) = eval_store(&vfwd, &vparams, &mut vb, 2).unwrap();
    ligo::tensor::ops::set_fused_xent_override(Some(false));
    let (vlu, vmu) = eval_store(&vfwd, &vparams, &mut vb, 2).unwrap();
    ligo::tensor::ops::set_fused_xent_override(None);
    assert!((vlf - vlu).abs() <= 1e-4 * vlf.abs().max(1.0), "vision {vlf} vs {vlu}");
    assert_eq!(vmf, vmu, "the streamed accuracy metric must not depend on the lowering");
}

/// Serializes tests that flip the process-global LIGO_FUSED_XENT override.
static XENT_OVERRIDE: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[test]
fn unfused_xent_all_masked_batch_has_exactly_zero_loss_and_grads() {
    // The count = 0 edge of the *materialized* masked_xent lowering (the
    // fused path's all-masked guard is pinned in ops.rs unit tests; this
    // is the missing unfused counterpart): with every label masked the
    // loss is exactly 0.0, every gradient is exactly 0.0, and perturbing
    // parameters moves nothing — the finite-difference view of "no
    // supervised rows means no signal", not merely "small loss".
    let _guard = XENT_OVERRIDE.lock().unwrap_or_else(|e| e.into_inner());
    let reg = Registry::builtin();
    let mut cfg = reg.model("bert_small").unwrap().clone();
    cfg.batch = 2; // keep the debug-mode tape cheap
    let params = ligo::tensor::store::Store::det_init(&ligo::model::param_shapes(&cfg), 13);
    let corpus = Corpus::new(cfg.vocab, 0);
    let mut batch = mlm_batch(&corpus, &cfg, &mut Rng::new(4));
    let shape = batch.get("labels").unwrap().shape.clone();
    let n = batch.get("labels").unwrap().numel();
    batch.insert("labels", ligo::tensor::Tensor::from_i32(&shape, vec![-1; n]));
    ligo::tensor::ops::set_fused_xent_override(Some(false));
    let (loss, grads, _) = ligo::model::loss_and_grads(&cfg, &params, &batch).unwrap();
    assert_eq!(loss.to_bits(), 0.0f32.to_bits(), "all-masked loss must be exactly 0, got {loss}");
    for (name, g) in grads.iter() {
        if let ligo::tensor::TensorData::F32(_) = g.data {
            assert!(
                g.f32s().iter().all(|&v| v == 0.0),
                "all-masked grad '{name}' must be exactly zero"
            );
        }
    }
    // FD: a perturbed parameter set sees the same exactly-zero loss
    let mut p2 = params.clone();
    let t = p2.get("L00_q_w").unwrap();
    let mut v = t.f32s().to_vec();
    v[0] += 0.75;
    v[7] -= 0.5;
    let shape_w = t.shape.clone();
    p2.insert("L00_q_w", ligo::tensor::Tensor::from_f32(&shape_w, v));
    let (loss2, _) = ligo::model::loss_only(&cfg, &p2, &batch).unwrap();
    assert_eq!(loss2.to_bits(), 0.0f32.to_bits(), "perturbation changed an all-masked loss");
    ligo::tensor::ops::set_fused_xent_override(None);
}

#[test]
fn probe_preset_synthesizes_with_metric() {
    let Some(rt) = native_runtime() else { return };
    let exe = rt.load("fwd_probe_bert_small").unwrap();
    assert!(exe.manifest.output_index("metric").is_some());
    assert_eq!(exe.manifest.inputs_of("batch")[1].shape, vec![16]);
}

/// Serializes the LIGO_WORKERS tests: workers flush their buffers into the
/// process-global shared arena pool, and two sharded tests interleaving
/// would make the per-worker fresh/reuse counters nondeterministic.
static SHARDED: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[test]
fn sharded_training_is_bit_identical_across_worker_counts() {
    // the ISSUE 6 acceptance scenario: the same 6-step run — including a
    // 2-stage GrowthPlan with optimizer-shard resharding mid-run — must
    // produce the same loss curve and the same parameter bytes for
    // LIGO_WORKERS in {1, 2, 4}
    let _guard = SHARDED.lock().unwrap_or_else(|e| e.into_inner());
    let Some(rt) = native_runtime() else { return };
    let reg = Registry::builtin();
    let small = reg.model("bert_small").unwrap().clone();
    let mid = reg.model("bert_d6w48").unwrap().clone();
    let large = reg.model("bert_base").unwrap().clone();
    let corpus = Corpus::new(small.vocab, 0);

    let run_with = |workers: usize| -> (Vec<u32>, Vec<(String, Vec<u32>)>) {
        ligo::coordinator::parallel::set_workers_override(Some(workers));
        let plan = GrowthPlan::builder(&small)
            .grow_at(2, &mid, "stackbert")
            .grow_at_with(4, &large, "ligo", LigoOptions { steps: 2, ..Default::default() })
            .build()
            .unwrap();
        let params = Trainer::scratch_params(&rt, &small, 0).unwrap();
        let tc = TrainConfig {
            lr: 3e-3,
            total_steps: 6,
            warmup_steps: 2,
            eval_every: 1,
            grad_accum: 4,
            ..Default::default()
        };
        let mut tr = Trainer::new(&rt, &small, tc, params).unwrap();
        let c1 = corpus.clone();
        let s1 = small.clone();
        let mut batches = Batches::shared(
            move |step| mlm_batch(&c1, &s1, &mut Rng::new(step as u64)),
            {
                let c = corpus.clone();
                let cfg = small.clone();
                move |i| mlm_batch(&c, &cfg, &mut Rng::new(0x33AA + i as u64))
            },
        );
        let curve = tr.run_plan(&rt, &format!("w{workers}"), &mut batches, 6, &plan).unwrap();
        ligo::coordinator::parallel::set_workers_override(None);
        assert_eq!(tr.cfg.name, "bert_base", "both growth stages must have fired");
        let losses = curve.loss.iter().map(|l| l.to_bits()).collect();
        let param_bits = tr
            .params
            .iter()
            .filter(|(_, t)| matches!(t.data, ligo::tensor::TensorData::F32(_)))
            .map(|(n, t)| (n.clone(), t.f32s().iter().map(|v| v.to_bits()).collect()))
            .collect();
        (losses, param_bits)
    };

    let serial = run_with(1);
    for workers in [2, 4] {
        let sharded = run_with(workers);
        assert_eq!(
            serial.0, sharded.0,
            "loss curve must be bit-identical: 1 vs {workers} workers"
        );
        assert_eq!(
            serial.1, sharded.1,
            "final parameters must be bit-identical: 1 vs {workers} workers"
        );
    }
}

#[test]
fn sharded_steps_reach_zero_fresh_alloc_steady_state() {
    // satellite of the same ISSUE: after warmup, every worker's step must
    // be served entirely from recycled buffers (thread-local pool + shared
    // overflow pool), extending the serial zero-fresh-alloc regression to
    // the multi-worker path
    let _guard = SHARDED.lock().unwrap_or_else(|e| e.into_inner());
    if !ligo::tensor::arena::enabled() {
        return;
    }
    let Some(rt) = native_runtime() else { return };
    let reg = Registry::builtin();
    let cfg = reg.model("bert_small").unwrap().clone();
    let corpus = Corpus::new(cfg.vocab, 0);
    let params = Trainer::scratch_params(&rt, &cfg, 0).unwrap();
    let tc = TrainConfig {
        lr: 3e-3,
        total_steps: 8,
        warmup_steps: 2,
        eval_every: 8,
        grad_accum: 4,
        ..Default::default()
    };
    let mut tr = Trainer::new(&rt, &cfg, tc, params).unwrap();
    let c1 = corpus.clone();
    let cfg1 = cfg.clone();
    let batches: ligo::coordinator::parallel::SharedBatchFn =
        std::sync::Arc::new(move |step| mlm_batch(&c1, &cfg1, &mut Rng::new(step as u64)));
    // warmup: the first steps populate the shared overflow pool
    for _ in 0..4 {
        tr.train_step_sharded(&batches, 2).unwrap();
    }
    tr.train_step_sharded(&batches, 2).unwrap();
    let stats = tr.worker_arena_stats();
    assert_eq!(stats.len(), 2, "one stats entry per active worker");
    for s in stats {
        assert_eq!(s.microbatches, 2, "accum 4 over 2 workers: 2 leaves each ({s:?})");
        assert_eq!(
            s.fresh, 0,
            "steady-state worker {} must allocate nothing fresh: {s:?}",
            s.worker
        );
        assert!(s.reused > 0, "worker {} must be reusing buffers: {s:?}", s.worker);
    }
}
