//! Acceptance tests for the growth-policy search subsystem (`ligo search`):
//! the enumerated space over-generates, the static filter kills every
//! invalid candidate with a typed diagnostic *before any kernel runs*
//! (proven by the thread-local arena counters), probe scores are bitwise
//! deterministic — across repeated runs and across `LIGO_WORKERS` — and
//! the winning plan round-trips through its JSON file back into
//! `Trainer::run_plan`.

use ligo::coordinator::parallel::set_workers_override;
use ligo::coordinator::plan::GrowthPlan;
use ligo::growth::testutil::mk_cfg;
use ligo::search::{probe, ProbeConfig, SearchSpace};
use ligo::tensor::arena;

/// The CI smoke configuration: the real bert_small -> bert_base ladder
/// with the smoke operator set. Static phases only here — probing presets
/// is the e2e CI job's business, not a unit-speed test's.
fn smoke_space() -> SearchSpace {
    let reg = ligo::config::Registry::builtin();
    SearchSpace::ladder(
        &reg.models["bert_small"],
        &reg.models["bert_base"],
        &["stackbert", "net2net", "ligo", "lemon"],
    )
}

/// A probe-speed space over tiny test configs (vocab 64, seq 16, batch 4).
fn tiny_space() -> SearchSpace {
    SearchSpace::ladder(&mk_cfg(2, 8, 2), &mk_cfg(3, 12, 3), &["stackbert", "net2net"])
}

fn tiny_probe() -> ProbeConfig {
    ProbeConfig { horizon: 4, topk: 2, budget_steps: 200, m_steps: 2, seed: 11 }
}

#[test]
fn smoke_space_prunes_over_half_statically_with_zero_kernels() {
    let space = smoke_space();
    let raw = space.enumerate();
    assert!(raw.len() >= 20, "smoke space must enumerate >=20 candidates, got {}", raw.len());

    arena::reset_stats();
    let e = space.filter(raw).unwrap();
    if arena::enabled() {
        assert_eq!(arena::stats().0, 0, "static filter must not allocate kernel buffers");
        assert_eq!(arena::peak_request(), 0, "static filter must not request kernel buffers");
    }

    assert!(e.prune_rate() >= 0.5, "prune rate {:.3} below the 50% floor", e.prune_rate());
    assert!(!e.survivors.is_empty(), "the filter must not kill the whole space");

    // every rejection carries a typed, non-empty diagnostic
    for p in &e.pruned {
        assert!(!p.reason.is_empty(), "#{} pruned without a reason", p.candidate.id);
    }
    // the three engineered failure classes are all present and named
    let reasons: Vec<&str> = e.pruned.iter().map(|p| p.reason.as_str()).collect();
    assert!(reasons.iter().any(|r| r.contains("divisible")), "odd head split: {reasons:#?}");
    assert!(reasons.iter().any(|r| r.contains("not larger")), "lateral rung: {reasons:#?}");
    assert!(reasons.iter().any(|r| r.contains("integer factor")), "lemon regime: {reasons:#?}");
    // lemon cannot reach bert_base from bert_small (72 = 1.5 * 48): every
    // lemon candidate must die statically
    assert!(!e.survivors.iter().any(|c| c.operator == "lemon"));
}

#[test]
fn search_ranking_is_identical_across_runs_and_worker_counts() {
    let space = tiny_space();
    let pc = tiny_probe();

    let run = || ligo::search::run(&space, &pc).unwrap();
    let a = run();
    let b = run();
    assert_eq!(a.ranked.len(), b.ranked.len());
    for (x, y) in a.ranked.iter().zip(&b.ranked) {
        assert_eq!(x.candidate.id, y.candidate.id, "repeat run reordered the ranking");
        assert_eq!(
            x.score.final_loss.to_bits(),
            y.score.final_loss.to_bits(),
            "candidate #{} rescored differently on a repeat run",
            x.candidate.id
        );
        assert_eq!(x.score.flops.to_bits(), y.score.flops.to_bits());
    }

    // LIGO_WORKERS must not perturb scores or order: probes pin
    // grad_accum = 1 and use index-pure seeded batch sources
    set_workers_override(Some(2));
    let sharded = run();
    set_workers_override(None);
    for (x, y) in a.ranked.iter().zip(&sharded.ranked) {
        assert_eq!(x.candidate.id, y.candidate.id, "worker count reordered the ranking");
        assert_eq!(
            x.score.final_loss.to_bits(),
            y.score.final_loss.to_bits(),
            "candidate #{} scores differently under LIGO_WORKERS=2",
            x.candidate.id
        );
    }
}

#[test]
fn winner_plan_file_round_trips_and_reexecutes_with_marks() {
    let space = tiny_space();
    let pc = tiny_probe();
    let out = std::env::temp_dir().join("ligo_search_smoke_test");
    let _ = std::fs::remove_dir_all(&out);

    let plan_horizon = 8;
    let rep = ligo::search::run_and_write(&space, &pc, plan_horizon, &out).unwrap();
    let winner = rep.winner().expect("tiny space has survivors").clone();

    // the persisted file is exactly the winner's plan at the emit horizon
    let plan_path = out.join("search").join("best_plan.json");
    let loaded = GrowthPlan::load(&plan_path).unwrap();
    let expected = winner
        .candidate
        .plan_for(&space.initial, plan_horizon, pc.m_steps, pc.seed)
        .unwrap();
    assert_eq!(loaded, expected, "plan file must round-trip to builder equality");

    // and it executes end-to-end: every scheduled stage leaves a mark
    let rt = probe::runtime_for(
        std::iter::once(loaded.initial()).chain(loaded.stages().iter().map(|s| &s.target)),
    );
    let curve = probe::execute_plan(&rt, "winner", &loaded, plan_horizon, pc.seed).unwrap();
    assert_eq!(curve.marks.len(), loaded.stages().len());
    assert!(curve.flops.last().copied().unwrap_or(0.0) > 0.0);

    // report artifact exists alongside the plan
    assert!(out.join("search").join("report.json").exists());
    let _ = std::fs::remove_dir_all(&out);
}

#[test]
fn plan_file_drives_the_progressive_experiment() {
    let out = std::env::temp_dir().join("ligo_search_plan_exp_test");
    let _ = std::fs::remove_dir_all(&out);

    // hand-write a plan file the way `ligo search` would emit one
    let small = mk_cfg(2, 8, 2);
    let big = mk_cfg(3, 12, 3);
    let plan = GrowthPlan::builder(&small).grow_at(5, &big, "stackbert").build().unwrap();
    std::fs::create_dir_all(&out).unwrap();
    let plan_path = out.join("best_plan.json");
    plan.save(&plan_path).unwrap();

    // tiny scale: `scaled` floors at 20 steps, both runs stay test-sized
    ligo::experiments::progressive::from_plan_file(&plan_path, 0.01, &out).unwrap();
    assert!(out.join("progressive_plan.json").exists());
    let _ = std::fs::remove_dir_all(&out);
}
