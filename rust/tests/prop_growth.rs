//! Property tests over the growth-operator zoo and coordinator invariants:
//! shape correctness for arbitrary (L1<=L2, D1<=D2) pairs, structural
//! guarantees per operator, Prop. 1 relationships, and checkpoint/loader
//! invariants. Pure rust — no artifacts required.

use ligo::coordinator::growth_manager::ligo_init_store;
use ligo::growth::testutil::{mk_cfg, small_store};
use ligo::growth::{self, layer_key};
use ligo::tensor::{io, ops, store::Store, Tensor};
use ligo::util::prop;
use ligo::util::rng::Rng;

#[test]
fn every_operator_produces_exact_target_shapes() {
    prop::check("operator shapes", 12, |g| {
        let l1 = g.usize_in(1, 4);
        let d1h = g.usize_in(1, 4); // heads-sized units
        let l2 = l1 + g.usize_in(0, 4);
        let d2h = d1h + g.usize_in(0, 3);
        let cs = mk_cfg(l1, d1h * 8, d1h);
        let cl = mk_cfg(l2, d2h * 8, d2h);
        let small = small_store(&cs);
        for name in growth::ALL {
            let op = growth::by_name(name).unwrap();
            let big = growth::grow_params(op.as_ref(), &small, &cs, &cl).unwrap();
            assert_eq!(big.expect("emb_tok").shape, vec![cl.vocab, cl.dim], "{name}");
            for l in 0..cl.layers {
                assert_eq!(
                    big.expect(&layer_key(l, "q_w")).shape,
                    vec![cl.dim, cl.dim],
                    "{name} layer {l}"
                );
                assert_eq!(
                    big.expect(&layer_key(l, "fc1_w")).shape,
                    vec![cl.ffn(), cl.dim],
                    "{name} layer {l}"
                );
            }
            // exact tensor-set parity with a natively-initialized large store
            let native = small_store(&cl);
            assert_eq!(big.len(), native.len(), "{name}: tensor count");
        }
    });
}

#[test]
fn operators_preserve_small_information() {
    // Every operator must embed the small weights somewhere: the grown
    // store cannot be independent of the source.
    prop::check("information preserved", 8, |g| {
        let cs = mk_cfg(2, 16, 2);
        let cl = mk_cfg(3, 24, 3);
        let small = small_store(&cs);
        let mut small2 = small.clone();
        let t = small2.get_mut("L00_q_w").unwrap();
        for x in t.f32s_mut() {
            *x += 1.0;
        }
        let name = *g.pick(&growth::ALL);
        let op = growth::by_name(name).unwrap();
        let a = growth::grow_params(op.as_ref(), &small, &cs, &cl).unwrap();
        let b = growth::grow_params(op.as_ref(), &small2, &cs, &cl).unwrap();
        assert_ne!(
            a.expect("L00_q_w").f32s(),
            b.expect("L00_q_w").f32s(),
            "{name} ignores source weights"
        );
    });
}

#[test]
fn stackbert_equals_ligo_stacking_pattern() {
    // Prop. 1: the noise-free LiGO init (stacking pattern, identity width
    // when dims match) IS StackBERT.
    let cs = mk_cfg(2, 16, 2);
    let cl = mk_cfg(4, 16, 2); // depth-only
    let small = small_store(&cs);
    let stack_op = growth::by_name("stackbert").unwrap();
    let stack = growth::grow_params(stack_op.as_ref(), &small, &cs, &cl).unwrap();
    let shapes = vec![("w_q".to_string(), vec![cl.layers, cs.layers])];
    let m = ligo_init_store(&shapes, 0.0, 0);
    let w = m.expect("w_q");
    for i in 0..cl.layers {
        let blended = ops::weighted_sum(
            &(0..cs.layers).map(|j| w.at2(i, j)).collect::<Vec<_>>(),
            &(0..cs.layers)
                .map(|j| small.expect(&layer_key(j, "q_w")))
                .collect::<Vec<_>>(),
        );
        assert!(
            ops::max_abs_diff(&blended, stack.expect(&layer_key(i, "q_w"))) < 1e-6,
            "layer {i}"
        );
    }
}

#[test]
fn net2net_width_is_function_preserving_per_layer() {
    prop::check("fpi per-layer preservation", 10, |g| {
        let d1 = g.usize_in(2, 8);
        let d2 = d1 + g.usize_in(1, 6);
        let map = growth::width::WidthMap::random(d1, d2, &mut Rng::new(g.seed));
        let w = Tensor::from_f32(&[d1, d1], g.vec_f32(d1 * d1, -1.0, 1.0));
        let grown = map.expand_cols(&map.expand_rows(&w), true);
        let x = g.vec_f32(d1, -1.0, 1.0);
        let xl: Vec<f32> = map.map.iter().map(|&s| x[s]).collect();
        // y_large[j] must equal y_small[map[j]]
        for (j, &src) in map.map.iter().enumerate() {
            let y_small: f32 = (0..d1).map(|k| w.at2(src, k) * x[k]).sum();
            let y_large: f32 = (0..d2).map(|k| grown.at2(j, k) * xl[k]).sum();
            assert!((y_small - y_large).abs() < 1e-4, "j={j}: {y_small} vs {y_large}");
        }
    });
}

#[test]
fn ligo_init_store_pattern() {
    prop::check("ligo init pattern", 20, |g| {
        let rows = g.usize_in(1, 12);
        let cols = g.usize_in(1, 12);
        let m = ligo_init_store(&[("B_x".to_string(), vec![rows, cols])], 0.0, g.seed);
        let t = m.expect("B_x");
        for r in 0..rows {
            for c in 0..cols {
                let want = if c == r % cols { 1.0 } else { 0.0 };
                assert_eq!(t.at2(r, c), want);
            }
        }
    });
}

#[test]
fn checkpoint_roundtrip_arbitrary_stores() {
    prop::check("ckpt roundtrip", 10, |g| {
        let mut s = Store::new();
        let n = g.usize_in(1, 8);
        for i in 0..n {
            let r = g.usize_in(1, 6);
            let c = g.usize_in(1, 6);
            s.insert(
                format!("t{i}"),
                Tensor::from_f32(&[r, c], g.vec_f32(r * c, -10.0, 10.0)),
            );
        }
        let path = std::env::temp_dir().join(format!("ligo_prop_{}.lgck", g.seed));
        io::save(&s, &path).unwrap();
        let l = io::load(&path).unwrap();
        assert_eq!(s, l);
        std::fs::remove_file(path).ok();
    });
}

#[test]
fn weighted_sum_matches_manual_blend() {
    prop::check("depth blend linearity", 15, |g| {
        let n = g.usize_in(1, 5);
        let shape = [g.usize_in(1, 4), g.usize_in(1, 4)];
        let tensors: Vec<Tensor> = (0..n)
            .map(|_| Tensor::from_f32(&shape, g.vec_f32(shape[0] * shape[1], -1.0, 1.0)))
            .collect();
        let ws = g.vec_f32(n, -2.0, 2.0);
        let refs: Vec<&Tensor> = tensors.iter().collect();
        let got = ops::weighted_sum(&ws, &refs);
        for idx in 0..shape[0] * shape[1] {
            let want: f32 = (0..n).map(|i| ws[i] * tensors[i].f32s()[idx]).sum();
            assert!((got.f32s()[idx] - want).abs() < 1e-4);
        }
    });
}

#[test]
fn interpolation_even_layers_recover_source() {
    // Interpolation with k=2: layer 2l duplicates source layer l exactly.
    let cs = mk_cfg(3, 16, 2);
    let cl = mk_cfg(6, 16, 2);
    let small = small_store(&cs);
    let interp = growth::by_name("interpolation").unwrap();
    let big = growth::grow_params(interp.as_ref(), &small, &cs, &cl).unwrap();
    for l in 0..cs.layers {
        assert_eq!(
            big.expect(&layer_key(2 * l, "q_w")),
            small.expect(&layer_key(l, "q_w"))
        );
    }
}
