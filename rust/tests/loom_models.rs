//! Concurrency model tests, compiled only under `RUSTFLAGS="--cfg loom"`
//! (the `loom` CI job):
//!
//! ```sh
//! RUSTFLAGS="--cfg loom" cargo test --test loom_models
//! ```
//!
//! The two shared-state protocols the crate actually runs across threads
//! are driven here through `loom`'s instrumented `sync`/`thread`:
//!
//! * the **shared arena overflow pool** — `tensor/arena.rs`'s
//!   [`OverflowPool`] is deliberately lock-agnostic so this test can wrap
//!   *the exact production logic* in `loom::sync::Mutex` and assert its
//!   accounting invariants hold on every explored interleaving;
//! * the **stride-doubling all-reduce** — `util/allreduce.rs`'s tree has a
//!   shape that depends only on the leaf count, so gradient leaves landing
//!   in any thread-completion order must reduce bit-identically.
//!
//! The vendored `vendor/loom` stub re-runs each model as a stress loop;
//! patch the real loom over it for exhaustive interleaving coverage.

#![cfg(loom)]

use loom::sync::{Arc, Mutex};
use loom::thread;

use ligo::tensor::arena::OverflowPool;
use ligo::util::allreduce::tree_sum_f32;

/// Two threads hammer put/take on one shared pool; the byte accounting and
/// both caps must hold at every quiescent point.
#[test]
fn overflow_pool_accounting_survives_concurrent_put_take() {
    loom::model(|| {
        // tiny caps so the interleavings actually exercise the reject path
        let pool = Arc::new(Mutex::new(OverflowPool::new(2, 4 * 64)));
        let mut handles = Vec::new();
        for t in 0..2usize {
            let p = Arc::clone(&pool);
            handles.push(thread::spawn(move || {
                // offer a buffer, maybe reclaim one, offer again
                let buf = Vec::with_capacity(16 + t);
                let _ = p.lock().unwrap().put(buf);
                thread::yield_now();
                // bind before the if-let: in edition 2021 a guard temporary
                // in the scrutinee would stay locked across the body
                let taken = p.lock().unwrap().take(8);
                if let Some(b) = taken {
                    assert!(b.capacity() >= 8);
                    let _ = p.lock().unwrap().put(b);
                }
                let g = p.lock().unwrap();
                g.check_invariants().unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let g = pool.lock().unwrap();
        g.check_invariants().unwrap();
        // nothing leaked past the caps: at most 2 pooled buffers
        assert!(g.len() <= 2);
    });
}

/// A full pool must reject offers without corrupting the accounting, and
/// `clear` must zero it under contention.
#[test]
fn overflow_pool_caps_hold_under_contention() {
    loom::model(|| {
        let pool = Arc::new(Mutex::new(OverflowPool::new(1, 4 * 8)));
        let a = {
            let p = Arc::clone(&pool);
            thread::spawn(move || {
                let accepted = p.lock().unwrap().put(Vec::with_capacity(8));
                thread::yield_now();
                let over_cap = p.lock().unwrap().put(Vec::with_capacity(64));
                assert!(!over_cap, "a 64-cap buffer can never fit a 32-byte pool");
                accepted
            })
        };
        let b = {
            let p = Arc::clone(&pool);
            thread::spawn(move || {
                let accepted = p.lock().unwrap().put(Vec::with_capacity(8));
                thread::yield_now();
                p.lock().unwrap().check_invariants().unwrap();
                accepted
            })
        };
        let (ra, rb) = (a.join().unwrap(), b.join().unwrap());
        let mut g = pool.lock().unwrap();
        g.check_invariants().unwrap();
        // count cap is 1: at most one of the two 8-cap offers landed
        assert_eq!(g.len(), usize::from(ra) + usize::from(rb));
        assert!(g.len() <= 1);
        g.clear();
        assert!(g.is_empty());
        assert_eq!(g.bytes(), 0);
        g.check_invariants().unwrap();
    });
}

/// Worker threads deliver per-microbatch losses in whatever order the
/// scheduler picks; slotting them by index and reducing through the
/// canonical tree must be bit-identical to the serial reduction.
#[test]
fn tree_reduce_is_bit_identical_across_thread_orders() {
    // order-sensitive values: a different association changes the last
    // bits (3 leaves keeps the model within loom's 4-thread budget)
    const VALS: [f32; 3] = [1.0e8, 1.0, -3.0e7];
    let serial = tree_sum_f32(&VALS);
    loom::model(move || {
        let slots = Arc::new(Mutex::new([0f32; VALS.len()]));
        let mut handles = Vec::new();
        for (i, v) in VALS.iter().copied().enumerate() {
            let s = Arc::clone(&slots);
            handles.push(thread::spawn(move || {
                thread::yield_now();
                s.lock().unwrap()[i] = v;
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let got = tree_sum_f32(&*slots.lock().unwrap());
        assert_eq!(
            got.to_bits(),
            serial.to_bits(),
            "completion order leaked into the reduction"
        );
    });
}
