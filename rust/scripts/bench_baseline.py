#!/usr/bin/env python3
"""Benchmark-ledger tooling for EXPERIMENTS.md (stdlib only).

Subcommands
-----------
mean NAME FILE
    Print the mean (seconds) of bench line NAME from a captured
    `cargo bench` output file.

budget FILE [FACTOR]
    Print FACTOR (default 1.25) x the mean of
    `grow/ligo_task_native[5 M-steps]` from FILE — the calibrated
    LIGO_GROWTH_OPS_BUDGET_S for the host that produced FILE. CI runs the
    serial bench first and feeds this budget to the parallel run, making
    the regression gate self-calibrating (robust to runner speed).

speedup SERIAL_FILE PARALLEL_FILE
    Print a per-host EXPERIMENTS.md table row (markdown) comparing the
    serial and parallel p50 of the tracked bench lines.

lmhead-gate FILE [FACTOR]
    Self-calibrating fused-LM-head gate: the mean of `lm_head/xent_fused`
    in FILE must come in under FACTOR (default 1.25) x the mean of
    `lm_head/xent_unfused` from the same run — the streaming
    linear+cross-entropy kernel may never regress past the materialized
    chain's budget. Exits non-zero on violation (CI runs this on the
    parallel growth_ops output).

workers-gate FILE [FACTOR]
    Self-calibrating LIGO_WORKERS scaling gate: the mean of
    `bert_base/train_step[workers2]` in FILE (a captured `cargo bench
    --bench train_step` output) must come in under the mean of
    `bert_base/train_step[workers1]` / FACTOR (default 1.3) — the 2-worker
    sharded step must actually scale, not just match. Skips (exit 0) on
    hosts with fewer than 4 CPUs, where two workers each fanning out
    kernel threads cannot hit the factor. Exits non-zero on violation.

decode-gate FILE [FACTOR]
    Self-calibrating continuous-batching gate: the mean of
    `decode/batched[s4]` in FILE (a captured `cargo bench --bench
    decode_throughput` output) must come in under the mean of
    `decode/sequential[s4]` / FACTOR (default 1.5) — decoding 4 sessions
    through one batched step must beat decoding them one at a time, or
    the serve scheduler has lost its reason to exist. Skips (exit 0) on
    hosts with fewer than 4 CPUs. Exits non-zero on violation.

search-gate FILE [MIN_RATE]
    Static-filter coverage gate for `ligo search`: FILE is a captured
    `ligo search --smoke` output. Its summary line
    ("search space: R raw candidates, P pruned statically, S probed,
    prune rate F") must report a raw space of at least 20 candidates and
    a prune rate of at least MIN_RATE (default 0.5) — the symbolic filter
    must keep killing at least half the smoke space before any probe
    runs. Also requires a non-empty ranked finalist table and the winner
    re-execution line. Exits non-zero on violation.

ckpt-gate BIN [FACTOR]
    Self-calibrating checkpoint-overhead gate: BIN is the built `ligo`
    binary. Times `BIN train --model bert_small --steps 60` twice with
    checkpointing off and twice with LIGO_CKPT_EVERY=10 (interleaved,
    best-of-two per arm to shed scheduler noise); the checkpointed wall
    must come in under FACTOR (default 1.05) x the uncheckpointed wall
    plus a small absolute grace for sub-second runs where fixed I/O
    costs dominate the ratio. Exits non-zero on violation.

record
    Run the full protocol on this host (requires cargo): serial growth_ops,
    parallel growth_ops, quickstart wall-clock; append the resulting rows
    to ../../EXPERIMENTS.md and print the calibrated budget. Run from
    anywhere; paths resolve relative to this script.
"""

import os
import re
import subprocess
import sys
import time

HERE = os.path.dirname(os.path.abspath(__file__))
RUST = os.path.dirname(HERE)
REPO = os.path.dirname(RUST)
TRACKED = [
    "grow/stackbert",
    "grow/ligo_task_native[5 M-steps]",
    "lm_head/xent_fused",
]
GATE_LINE = "grow/ligo_task_native[5 M-steps]"
LMHEAD_FUSED = "lm_head/xent_fused"
LMHEAD_UNFUSED = "lm_head/xent_unfused"
WORKERS_1 = "bert_base/train_step[workers1]"
WORKERS_2 = "bert_base/train_step[workers2]"
DECODE_SEQ = "decode/sequential[s4]"
DECODE_BATCH = "decode/batched[s4]"

UNIT = {"ns": 1e-9, "µs": 1e-6, "us": 1e-6, "ms": 1e-3, "s": 1.0}
LINE_RE = re.compile(
    r"^(?P<name>.*?)\s+n=\d+\s+mean\s+(?P<mean>[\d.]+)\s+(?P<mu>ns|µs|us|ms|s)"
    r"\s+p50\s+(?P<p50>[\d.]+)\s+(?P<pu>ns|µs|us|ms|s)"
)


def parse(path):
    """{bench name -> (mean_s, p50_s)} from a captured bench output file."""
    out = {}
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            m = LINE_RE.match(line.rstrip())
            if m:
                out[m.group("name").strip()] = (
                    float(m.group("mean")) * UNIT[m.group("mu")],
                    float(m.group("p50")) * UNIT[m.group("pu")],
                )
    return out


def require(stats, name, path):
    if name not in stats:
        sys.exit(f"bench line '{name}' not found in {path} (lines: {sorted(stats)})")
    return stats[name]


def fmt(s):
    return f"{s:.3f} s" if s >= 1 else f"{s * 1e3:.1f} ms"


def row_markdown(serial, parallel, host):
    rows = []
    for name in TRACKED:
        s_p50 = serial[name][1]
        p_p50 = parallel[name][1]
        speedup = s_p50 / p_p50 if p_p50 > 0 else float("nan")
        rows.append(
            f"| {host} | `{name}` | {fmt(s_p50)} | {fmt(p_p50)} | {speedup:.2f}x |"
        )
    return rows


def bench_growth(env_extra):
    env = dict(os.environ, **env_extra)
    out = subprocess.run(
        ["cargo", "bench", "--bench", "growth_ops"],
        cwd=RUST, env=env, capture_output=True, text=True, check=True,
    ).stdout
    tmp = os.path.join(RUST, "target", f"bench_{'serial' if env_extra else 'par'}.txt")
    os.makedirs(os.path.dirname(tmp), exist_ok=True)
    with open(tmp, "w", encoding="utf-8") as fh:
        fh.write(out)
    return tmp


def cmd_lmhead_gate(path, factor=1.25):
    stats = parse(path)
    fused = require(stats, LMHEAD_FUSED, path)[0]
    unfused = require(stats, LMHEAD_UNFUSED, path)[0]
    if fused > unfused * factor:
        sys.exit(
            f"REGRESSION: streaming LM head mean {fused:.4f}s > "
            f"{factor} x materialized chain {unfused:.4f}s"
        )
    print(
        f"lm_head gate ok: fused {fused:.4f}s <= {factor} x unfused {unfused:.4f}s "
        f"({unfused / fused:.2f}x speedup)"
    )


def cmd_workers_gate(path, factor=1.3):
    cores = os.cpu_count() or 1
    if cores < 4:
        print(f"workers gate skipped: only {cores} CPUs (need >= 4 for 2 workers)")
        return
    stats = parse(path)
    serial = require(stats, WORKERS_1, path)[0]
    sharded = require(stats, WORKERS_2, path)[0]
    if sharded > serial / factor:
        sys.exit(
            f"REGRESSION: 2-worker step mean {sharded:.4f}s > serial "
            f"{serial:.4f}s / {factor} (speedup {serial / sharded:.2f}x)"
        )
    print(
        f"workers gate ok: 2-worker {sharded:.4f}s <= serial {serial:.4f}s / {factor} "
        f"({serial / sharded:.2f}x speedup)"
    )


def cmd_decode_gate(path, factor=1.5):
    cores = os.cpu_count() or 1
    if cores < 4:
        print(f"decode gate skipped: only {cores} CPUs (need >= 4)")
        return
    stats = parse(path)
    sequential = require(stats, DECODE_SEQ, path)[0]
    batched = require(stats, DECODE_BATCH, path)[0]
    if batched > sequential / factor:
        sys.exit(
            f"REGRESSION: 4-session batched decode mean {batched:.4f}s > "
            f"sequential {sequential:.4f}s / {factor} "
            f"(speedup {sequential / batched:.2f}x)"
        )
    print(
        f"decode gate ok: batched {batched:.4f}s <= sequential {sequential:.4f}s "
        f"/ {factor} ({sequential / batched:.2f}x speedup)"
    )


SEARCH_RE = re.compile(
    r"^search space: (?P<raw>\d+) raw candidates, (?P<pruned>\d+) pruned statically, "
    r"(?P<probed>\d+) probed, prune rate (?P<rate>[\d.]+)"
)


def cmd_search_gate(path, min_rate=0.5):
    with open(path, encoding="utf-8") as fh:
        lines = [ln.rstrip() for ln in fh]
    summary = None
    for ln in lines:
        m = SEARCH_RE.match(ln)
        if m:
            summary = m
            break
    if summary is None:
        sys.exit(f"no 'search space:' summary line found in {path}")
    raw = int(summary.group("raw"))
    pruned = int(summary.group("pruned"))
    probed = int(summary.group("probed"))
    rate = float(summary.group("rate"))
    if raw < 20:
        sys.exit(f"REGRESSION: smoke space enumerated only {raw} raw candidates (< 20)")
    if pruned + probed != raw:
        sys.exit(f"REGRESSION: pruned {pruned} + probed {probed} != raw {raw}")
    if rate < min_rate:
        sys.exit(
            f"REGRESSION: static filter pruned {pruned}/{raw} candidates "
            f"(rate {rate:.3f} < {min_rate})"
        )
    # ranked finalists: at least one markdown data row under the header
    ranked = [
        ln for ln in lines
        if ln.startswith("|") and not ln.startswith("| rank") and not ln.startswith("|--")
    ]
    if not ranked:
        sys.exit(f"REGRESSION: no ranked finalist rows in {path}")
    if not any(ln.startswith("winner re-executed from") for ln in lines):
        sys.exit(f"REGRESSION: winner plan was not re-executed in {path}")
    print(
        f"search gate ok: {raw} raw, {pruned} pruned statically (rate {rate:.3f} >= "
        f"{min_rate}), {len(ranked)} finalist(s) ranked, winner re-executed"
    )


def cmd_ckpt_gate(bin_path, factor=1.05, grace_s=0.5):
    import shutil
    import tempfile

    base = tempfile.mkdtemp(prefix="ligo_ckpt_gate_")

    def run_train(env_extra, out):
        env = dict(os.environ, **env_extra)
        t0 = time.time()
        subprocess.run(
            [bin_path, "train", "--model", "bert_small", "--steps", "60", "--out", out],
            env=env, check=True, capture_output=True,
        )
        return time.time() - t0

    # interleave the arms so a runner slowdown hits both; best-of-two per
    # arm sheds one-off scheduler noise
    offs, ons = [], []
    for i in range(2):
        offs.append(run_train({}, os.path.join(base, f"off{i}")))
        ons.append(
            run_train({"LIGO_CKPT_EVERY": "10"}, os.path.join(base, f"on{i}"))
        )
    shutil.rmtree(base, ignore_errors=True)
    off, on = min(offs), min(ons)
    budget = off * factor + grace_s
    if on > budget:
        sys.exit(
            f"REGRESSION: checkpointed train wall {on:.3f}s > "
            f"{factor} x uncheckpointed {off:.3f}s + {grace_s}s grace "
            f"(overhead {(on / off - 1) * 100:.1f}%)"
        )
    print(
        f"ckpt gate ok: checkpointed {on:.3f}s <= {factor} x off {off:.3f}s "
        f"+ {grace_s}s grace (overhead {(on / off - 1) * 100:.1f}%)"
    )


def cmd_record():
    host = f"{os.uname().nodename} ({os.cpu_count()} cores)"
    print(f"== recording bench baseline for {host} ==")
    # serial pass only calibrates the gate line: skip the unfused A/B
    serial_f = bench_growth({"LIGO_THREADS": "1", "LIGO_BENCH_FAST": "1"})
    par_f = bench_growth({})
    serial, parallel = parse(serial_f), parse(par_f)
    for name in TRACKED + [GATE_LINE]:
        require(serial, name, serial_f)
        require(parallel, name, par_f)
    budget = serial[GATE_LINE][0] * 1.25
    # build first so the timed number is the binary alone, not cargo
    subprocess.run(
        ["cargo", "build", "--release", "--example", "quickstart"],
        cwd=RUST, check=True, capture_output=True,
    )
    t0 = time.time()
    subprocess.run(
        [os.path.join(RUST, "target", "release", "examples", "quickstart")],
        cwd=RUST, check=True, capture_output=True,
    )
    quick_s = time.time() - t0
    rows = row_markdown(serial, parallel, host)
    rows.append(f"| {host} | `example/quickstart` (wall) | – | {fmt(quick_s)} | – |")
    exp = os.path.join(REPO, "EXPERIMENTS.md")
    with open(exp, "a", encoding="utf-8") as fh:
        fh.write("\n".join(rows) + "\n")
    print("\n".join(rows))
    print(f"\ncalibrated LIGO_GROWTH_OPS_BUDGET_S={budget:.3f}")
    print(f"rows appended to {exp} — move them into the per-host table.")


def main():
    if len(sys.argv) < 2:
        sys.exit(__doc__)
    cmd = sys.argv[1]
    if cmd == "mean":
        name, path = sys.argv[2], sys.argv[3]
        print(f"{require(parse(path), name, path)[0]:.6f}")
    elif cmd == "budget":
        path = sys.argv[2]
        factor = float(sys.argv[3]) if len(sys.argv) > 3 else 1.25
        print(f"{require(parse(path), GATE_LINE, path)[0] * factor:.3f}")
    elif cmd == "speedup":
        serial, parallel = parse(sys.argv[2]), parse(sys.argv[3])
        for name in TRACKED:
            require(serial, name, sys.argv[2])
            require(parallel, name, sys.argv[3])
        host = f"{os.uname().nodename} ({os.cpu_count()} cores)"
        print("\n".join(row_markdown(serial, parallel, host)))
    elif cmd == "lmhead-gate":
        factor = float(sys.argv[3]) if len(sys.argv) > 3 else 1.25
        cmd_lmhead_gate(sys.argv[2], factor)
    elif cmd == "workers-gate":
        factor = float(sys.argv[3]) if len(sys.argv) > 3 else 1.3
        cmd_workers_gate(sys.argv[2], factor)
    elif cmd == "decode-gate":
        factor = float(sys.argv[3]) if len(sys.argv) > 3 else 1.5
        cmd_decode_gate(sys.argv[2], factor)
    elif cmd == "search-gate":
        min_rate = float(sys.argv[3]) if len(sys.argv) > 3 else 0.5
        cmd_search_gate(sys.argv[2], min_rate)
    elif cmd == "ckpt-gate":
        factor = float(sys.argv[3]) if len(sys.argv) > 3 else 1.05
        cmd_ckpt_gate(sys.argv[2], factor)
    elif cmd == "record":
        cmd_record()
    else:
        sys.exit(__doc__)


if __name__ == "__main__":
    main()
