//! Search reporting: the ranked comparison table, the per-candidate prune
//! log, and the winning [`GrowthPlan`] as executable JSON.
//!
//! The winner artifact is the whole point of `ligo search`: a plan file
//! that round-trips through [`GrowthPlan::load`] straight into
//! `ligo experiment progressive --plan <file>` (and into
//! [`crate::coordinator::trainer::Trainer::run_plan`] directly) — search
//! output *is* training input, no transcription step.

use std::fmt::Write as _;
use std::fs;
use std::path::{Path, PathBuf};

use crate::coordinator::plan::GrowthPlan;
use crate::error::{Context, Result};
use crate::util::json::Json;

use super::probe::Scored;
use super::space::{Enumerated, Pruned};

/// Everything one `ligo search` run decided, ready to render and persist.
#[derive(Debug, Clone)]
pub struct SearchReport {
    pub initial: String,
    pub goal: String,
    /// Size of the raw enumerated space.
    pub raw: usize,
    /// Statically-rejected candidates with their typed diagnostics.
    pub pruned: Vec<Pruned>,
    /// Probe finalists, ranked best-first.
    pub ranked: Vec<Scored>,
    /// Full probe horizon the finalists were ranked at.
    pub horizon: usize,
}

impl SearchReport {
    pub fn new(
        initial: &str,
        goal: &str,
        e: &Enumerated,
        ranked: Vec<Scored>,
        horizon: usize,
    ) -> SearchReport {
        SearchReport {
            initial: initial.to_string(),
            goal: goal.to_string(),
            raw: e.raw,
            pruned: e.pruned.clone(),
            ranked,
            horizon,
        }
    }

    pub fn prune_rate(&self) -> f64 {
        if self.raw == 0 {
            return 0.0;
        }
        self.pruned.len() as f64 / self.raw as f64
    }

    /// The machine-parsable one-liner `bench_baseline.py search-gate`
    /// checks (keep the format stable).
    pub fn summary_line(&self) -> String {
        format!(
            "search space: {} raw candidates, {} pruned statically, {} probed, prune rate {:.3}",
            self.raw,
            self.pruned.len(),
            self.raw - self.pruned.len(),
            self.prune_rate()
        )
    }

    /// Markdown comparison table of the ranked finalists.
    pub fn table(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "| rank | operator | schedule | init loss | final loss | Δloss/GFLOP | probe steps |"
        );
        let _ = writeln!(
            s,
            "|------|----------|----------|-----------|------------|-------------|-------------|"
        );
        for (i, sc) in self.ranked.iter().enumerate() {
            let _ = writeln!(
                s,
                "| {} | {} | {} | {:.4} | {:.4} | {:+.4e} | {} |",
                i + 1,
                sc.candidate.operator,
                sc.candidate.schedule(),
                sc.score.init_loss,
                sc.score.final_loss,
                sc.score.per_gflop(),
                sc.score.steps,
            );
        }
        s
    }

    /// Per-candidate prune log: every statically-rejected route and why.
    pub fn prune_log(&self) -> String {
        let mut s = String::new();
        for p in &self.pruned {
            let route = p.candidate.describe();
            let _ = writeln!(s, "  pruned #{:03} {}: {}", p.candidate.id, route, p.reason);
        }
        s
    }

    /// The best finalist, if any candidate survived to the probe phase.
    pub fn winner(&self) -> Option<&Scored> {
        self.ranked.first()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("initial", Json::Str(self.initial.clone())),
            ("goal", Json::Str(self.goal.clone())),
            ("raw", Json::Num(self.raw as f64)),
            ("pruned", Json::Num(self.pruned.len() as f64)),
            ("prune_rate", Json::Num(self.prune_rate())),
            ("horizon", Json::Num(self.horizon as f64)),
            (
                "ranked",
                Json::Arr(
                    self.ranked
                        .iter()
                        .map(|sc| {
                            Json::obj(vec![
                                ("id", Json::Num(sc.candidate.id as f64)),
                                ("operator", Json::Str(sc.candidate.operator.clone())),
                                ("schedule", Json::Str(sc.candidate.schedule())),
                                ("init_loss", Json::Num(sc.score.init_loss as f64)),
                                ("final_loss", Json::Num(sc.score.final_loss as f64)),
                                ("score_per_gflop", Json::Num(sc.score.per_gflop())),
                                ("probe_steps", Json::Num(sc.score.steps as f64)),
                                ("probe_flops", Json::Num(sc.score.flops)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "pruned_log",
                Json::Arr(
                    self.pruned
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("id", Json::Num(p.candidate.id as f64)),
                                ("route", Json::Str(p.candidate.describe())),
                                ("reason", Json::Str(p.reason.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Persist the run: `search/report.json` plus (when a winner exists)
    /// `search/best_plan.json`, the executable plan artifact. Returns the
    /// report path and the plan path.
    pub fn write(
        &self,
        out_dir: &Path,
        winner_plan: Option<&GrowthPlan>,
    ) -> Result<(PathBuf, Option<PathBuf>)> {
        let dir = out_dir.join("search");
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating search output dir {}", dir.display()))?;
        let report_path = dir.join("report.json");
        fs::write(&report_path, self.to_json().to_string())
            .with_context(|| format!("writing {}", report_path.display()))?;
        let plan_path = match winner_plan {
            Some(plan) => {
                let p = dir.join("best_plan.json");
                plan.save(&p)?;
                Some(p)
            }
            None => None,
        };
        Ok((report_path, plan_path))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::growth::testutil::mk_cfg;
    use crate::search::probe::ProbeScore;
    use crate::search::space::{Candidate, CandidateStage};

    fn mk_report() -> SearchReport {
        let big = mk_cfg(3, 12, 3);
        let cand = Candidate {
            id: 4,
            operator: "stackbert".into(),
            stages: vec![CandidateStage { frac: 0.5, target: big.clone() }],
        };
        let bad = Candidate {
            id: 9,
            operator: "lemon".into(),
            stages: vec![CandidateStage { frac: 0.5, target: big }],
        };
        SearchReport {
            initial: "bert_2x8".into(),
            goal: "bert_3x12".into(),
            raw: 10,
            pruned: vec![Pruned {
                candidate: bad,
                reason: "lemon: width must grow by an integer factor".into(),
            }],
            ranked: vec![Scored {
                candidate: cand,
                score: ProbeScore {
                    init_loss: 4.5,
                    final_loss: 4.0,
                    flops: 2.0e9,
                    steps: 8,
                    marks: vec![(4, "stackbert".into())],
                },
            }],
            horizon: 8,
        }
    }

    #[test]
    fn summary_line_and_table_render_the_decision() {
        let r = mk_report();
        let line = r.summary_line();
        assert!(line.contains("10 raw candidates"), "{line}");
        assert!(line.contains("1 pruned statically"), "{line}");
        assert!(line.contains("prune rate 0.1"), "{line}");
        let table = r.table();
        assert!(table.contains("| 1 | stackbert |"), "{table}");
        assert!(table.contains("@0.50->bert_3x12"), "{table}");
        assert!(r.prune_log().contains("integer factor"));
        assert_eq!(r.winner().unwrap().candidate.id, 4);
    }

    #[test]
    fn report_json_serializes_rankings_and_prunes() {
        let r = mk_report();
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.get("raw").and_then(Json::as_usize), Some(10));
        let ranked = j.get("ranked").and_then(Json::as_arr).unwrap();
        assert_eq!(ranked.len(), 1);
        assert_eq!(ranked[0].get("operator").and_then(Json::as_str), Some("stackbert"));
        let pruned = j.get("pruned_log").and_then(Json::as_arr).unwrap();
        assert_eq!(pruned.len(), 1);
        assert!(pruned[0].get("reason").and_then(Json::as_str).unwrap().contains("integer"));
    }

    #[test]
    fn write_persists_report_and_winner_plan() {
        let r = mk_report();
        let small = mk_cfg(2, 8, 2);
        let plan = GrowthPlan::builder(&small)
            .grow_at(4, &mk_cfg(3, 12, 3), "stackbert")
            .build()
            .unwrap();
        let dir = std::env::temp_dir().join("ligo_search_report_test");
        let _ = std::fs::remove_dir_all(&dir);
        let (report_path, plan_path) = r.write(&dir, Some(&plan)).unwrap();
        assert!(report_path.exists());
        let plan_path = plan_path.unwrap();
        let reloaded = GrowthPlan::load(&plan_path).unwrap();
        assert_eq!(reloaded, plan, "persisted winner must round-trip");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
