//! Growth-policy search: enumerate the plan space, statically filter it,
//! probe the survivors, and emit the winning [`GrowthPlan`] as executable
//! JSON — the `ligo search` subsystem.
//!
//! The pipeline is three phases with a hard boundary between the first
//! two and the engine:
//!
//! 1. **Enumerate** ([`space`]) — cross operators x intermediate rungs x
//!    growth fractions into raw [`space::Candidate`]s, enumo-style: plug
//!    everything, including rungs that cannot work.
//! 2. **Filter** ([`space`]) — replay every candidate chain through the
//!    symbolic verifier ([`crate::growth::verify`]) and the shape-level
//!    cost model ([`crate::model::shape::cost_of`]). Purely symbolic: the
//!    driver resets the tensor-arena counters before this phase and
//!    refuses to continue if a single fresh buffer was allocated, so
//!    "invalid candidates die before any kernel runs" is a checked
//!    invariant, not a comment.
//! 3. **Probe** ([`probe`]) — train each survivor through its plan for a
//!    short seeded horizon on the native engine under successive halving,
//!    rank by FLOPs-normalized loss improvement, and report the top-k
//!    ([`report`]); the winner is persisted as a plan file that
//!    `ligo experiment progressive --plan` re-executes.
//!
//! [`GrowthPlan`]: crate::coordinator::plan::GrowthPlan

pub mod probe;
pub mod report;
pub mod space;

use std::path::Path;

use crate::bail;
use crate::error::Result;
use crate::log_info;
use crate::tensor::arena;

pub use probe::{ProbeConfig, Scored};
pub use report::SearchReport;
pub use space::{Candidate, Enumerated, SearchSpace};

/// Run one full search: enumerate, statically filter (asserting the
/// zero-kernel invariant), probe under successive halving, and return the
/// report. Writing artifacts and re-executing the winner are the caller's
/// choice (the CLI does both).
pub fn run(space: &SearchSpace, probe_cfg: &ProbeConfig) -> Result<SearchReport> {
    let raw = space.enumerate();
    log_info!(
        "search: {} -> {}: {} operators x {} rungs x {} fracs = {} raw candidates",
        space.initial.name,
        space.goal.name,
        space.operators.len(),
        space.rungs.len(),
        space.fracs.len(),
        raw.len()
    );
    arena::reset_stats();
    let enumerated = space.filter(raw)?;
    let (fresh, _) = arena::stats();
    if fresh > 0 {
        bail!(
            "static filter allocated {fresh} tensor buffer(s); the \
             enumeration/filter phase must stay symbolic (kernel-free)"
        );
    }
    log_info!(
        "search: statically pruned {}/{} candidates ({} survive; zero kernel buffers)",
        enumerated.pruned.len(),
        enumerated.raw,
        enumerated.survivors.len()
    );
    let rt = probe::runtime_for(
        enumerated
            .survivors
            .iter()
            .flat_map(|c| c.stages.iter().map(|s| &s.target))
            .chain([&space.initial]),
    );
    let ranked = probe::probe_all(&rt, &space.initial, &enumerated.survivors, probe_cfg)?;
    Ok(SearchReport::new(
        &space.initial.name,
        &space.goal.name,
        &enumerated,
        ranked,
        probe_cfg.horizon,
    ))
}

/// Run a search and persist its artifacts under `out_dir/search/`,
/// returning the report and the winner's plan instantiated at
/// `plan_horizon` steps (the horizon the emitted plan file schedules its
/// `at_step`s against).
pub fn run_and_write(
    space: &SearchSpace,
    probe_cfg: &ProbeConfig,
    plan_horizon: usize,
    out_dir: &Path,
) -> Result<SearchReport> {
    let rep = run(space, probe_cfg)?;
    let winner_plan = match rep.winner() {
        Some(sc) => Some(sc.candidate.plan_for(
            &space.initial,
            plan_horizon,
            probe_cfg.m_steps,
            probe_cfg.seed,
        )?),
        None => None,
    };
    let (report_path, plan_path) = rep.write(out_dir, winner_plan.as_ref())?;
    log_info!("search: report at {}", report_path.display());
    if let Some(p) = plan_path {
        log_info!("search: winning plan at {}", p.display());
    }
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::growth::testutil::mk_cfg;

    #[test]
    fn end_to_end_search_ranks_and_the_winner_plan_is_executable() {
        let small = mk_cfg(2, 8, 2);
        let big = mk_cfg(3, 12, 3);
        let mut space = SearchSpace::ladder(&small, &big, &["stackbert", "net2net"]);
        // keep the unit test tiny: no intermediate rungs, single-stage only
        space.rungs.clear();
        let cfg = ProbeConfig { horizon: 4, topk: 2, budget_steps: 64, m_steps: 2, seed: 5 };
        let rep = run(&space, &cfg).unwrap();
        assert_eq!(rep.raw, 4, "2 ops x 2 fracs, no rungs");
        assert!(!rep.ranked.is_empty());
        let winner = rep.winner().unwrap();
        let plan = winner.candidate.plan_for(&small, 6, cfg.m_steps, cfg.seed).unwrap();
        let rt = probe::runtime_for([&small, &big]);
        let curve = probe::execute_plan(&rt, "winner", &plan, 6, cfg.seed).unwrap();
        assert_eq!(curve.marks.len(), 1, "winner re-executes with its growth mark");
    }
}
