//! Plan-space enumeration: the combinator DSL behind `ligo search`.
//!
//! A [`SearchSpace`] describes one growth-policy question — "starting from
//! `initial`, which operator / intermediate-rung / step-fraction schedule
//! reaches `goal` best?" — as three orthogonal axes that are crossed
//! enumo-style: *plug* every combination into a [`Candidate`], then *filter*
//! the raw set through the symbolic verifier before a single kernel runs.
//!
//! The rung ladder is deliberately over-generated: width quarter-points
//! between `initial.dim` and `goal.dim` are synthesized by raw arithmetic
//! (no snapping to head multiples), so geometrically impossible rungs (odd
//! head splits, lateral non-growth, LEMON non-integer factors) are present
//! in the raw space and must be pruned by [`SearchSpace::filter`] with a
//! typed diagnostic — which is exactly what the enumeration smoke test
//! pins. Filtering is 100% static: [`verify::verify_batch`] replays every
//! chain through the symbolic shape checker and [`shape::cost_of`] prices
//! each stage endpoint, so invalid or over-budget candidates die without
//! allocating a tensor (`ligo search` self-asserts the arena fresh-buffer
//! counter is zero across this phase).

use crate::bail;
use crate::config::ModelConfig;
use crate::coordinator::plan::GrowthPlan;
use crate::error::Result;
use crate::growth::{verify, LigoOptions};
use crate::model::shape;

/// One scheduled transition of a candidate: grow into `target` when the
/// run reaches `frac` of its horizon. Fractions (not absolute steps) keep a
/// candidate reusable across probe horizons — successive halving re-probes
/// the same candidate at doubling horizons.
#[derive(Debug, Clone, PartialEq)]
pub struct CandidateStage {
    pub frac: f64,
    pub target: ModelConfig,
}

/// One point of the plan space: an operator plus an ordered stage schedule
/// ending at the space's goal config.
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    /// Stable enumeration index — the tie-break key for ranking, so equal
    /// scores order deterministically.
    pub id: usize,
    pub operator: String,
    pub stages: Vec<CandidateStage>,
}

impl Candidate {
    /// The chain of stage targets (for [`verify::verify_chain`]).
    pub fn targets(&self) -> Vec<ModelConfig> {
        self.stages.iter().map(|s| s.target.clone()).collect()
    }

    /// Human-readable one-liner: `stackbert @0.33->bert_d4w60 @0.67->bert_base`.
    pub fn describe(&self) -> String {
        let mut s = self.operator.clone();
        for st in &self.stages {
            s.push_str(&format!(" @{:.2}->{}", st.frac, st.target.name));
        }
        s
    }

    /// The schedule column of [`Candidate::describe`] (without the operator).
    pub fn schedule(&self) -> String {
        self.stages
            .iter()
            .map(|st| format!("@{:.2}->{}", st.frac, st.target.name))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Instantiate this candidate as an executable [`GrowthPlan`] for a
    /// concrete horizon: fractions map to strictly-increasing `at_step`s in
    /// `1..horizon`, clamped so every later stage still fits (`run_plan`
    /// rejects unreachable stages). Every stage shares one seeded
    /// [`LigoOptions`], so learned-operator candidates probe reproducibly.
    pub fn plan_for(
        &self,
        initial: &ModelConfig,
        horizon: usize,
        m_steps: usize,
        seed: u64,
    ) -> Result<GrowthPlan> {
        let n = self.stages.len();
        if horizon < n + 1 {
            bail!(
                "probe horizon {horizon} cannot schedule {n} growth stage(s) \
                 (needs at least {} steps)",
                n + 1
            );
        }
        let mut b = GrowthPlan::builder(initial);
        let mut prev = 0usize;
        for (i, st) in self.stages.iter().enumerate() {
            let remaining = n - 1 - i;
            // latest step that still leaves room for `remaining` stages
            let hi = horizon - 1 - remaining;
            let ideal = (st.frac * horizon as f64).round() as usize;
            let at = ideal.clamp(prev + 1, hi.max(prev + 1));
            let opts = LigoOptions { steps: m_steps, seed, ..LigoOptions::default() };
            b = b.grow_at_with(at, &st.target, &self.operator, opts);
            prev = at;
        }
        b.build()
    }
}

/// A statically-rejected candidate with its typed diagnostic (the full
/// error chain from the symbolic verifier or the cost budget).
#[derive(Debug, Clone)]
pub struct Pruned {
    pub candidate: Candidate,
    pub reason: String,
}

/// The outcome of the static phase: how big the raw space was, who
/// survived, and why everyone else died.
#[derive(Debug, Clone)]
pub struct Enumerated {
    pub raw: usize,
    pub survivors: Vec<Candidate>,
    pub pruned: Vec<Pruned>,
}

impl Enumerated {
    /// Fraction of the raw space the static filter removed.
    pub fn prune_rate(&self) -> f64 {
        if self.raw == 0 {
            return 0.0;
        }
        self.pruned.len() as f64 / self.raw as f64
    }
}

/// The three crossed axes of one growth-policy search, plus optional
/// static cost caps.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    pub initial: ModelConfig,
    pub goal: ModelConfig,
    /// Registry operator names ([`crate::growth::by_name`] vocabulary).
    pub operators: Vec<String>,
    /// Horizon fractions at which a stage may fire, in (0, 1).
    pub fracs: Vec<f64>,
    /// Intermediate rungs for multi-stage schedules (over-generated; the
    /// static filter owns validity).
    pub rungs: Vec<ModelConfig>,
    /// Per-stage-endpoint peak-arena cap in bytes (symbolic estimate).
    pub max_peak_bytes: Option<usize>,
    /// Per-stage-endpoint fwd+bwd FLOPs/step cap (symbolic estimate).
    pub max_step_flops: Option<f64>,
}

/// Synthesize the rung ladder between two geometries: quarter-point depths
/// x quarter-point widths, raw arithmetic. A width that doesn't divide by
/// the initial per-head dim keeps the initial head count — if that head
/// count doesn't divide the width either, the rung is *intentionally*
/// invalid and exists to exercise the static filter. The goal geometry
/// itself is excluded (it is every candidate's final stage already).
pub fn ladder_rungs(initial: &ModelConfig, goal: &ModelConfig) -> Vec<ModelConfig> {
    let quarter_points = |from: usize, to: usize| -> Vec<usize> {
        let delta = to.saturating_sub(from) as f64;
        let mut v: Vec<usize> = [0.0, 0.25, 0.5, 0.75, 1.0]
            .iter()
            .map(|q| from + (q * delta).round() as usize)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    };
    let per_head = (initial.dim / initial.heads.max(1)).max(1);
    let mut rungs = Vec::new();
    for layers in quarter_points(initial.layers, goal.layers) {
        for dim in quarter_points(initial.dim, goal.dim) {
            if layers == goal.layers && dim == goal.dim {
                continue;
            }
            let heads = if dim % per_head == 0 { dim / per_head } else { initial.heads };
            let mut cfg = initial.clone();
            cfg.name = format!("{}_d{layers}w{dim}", cfg.family);
            cfg.layers = layers;
            cfg.dim = dim;
            cfg.heads = heads;
            rungs.push(cfg);
        }
    }
    rungs
}

impl SearchSpace {
    /// The default ladder space: the given operators x the synthesized
    /// rung ladder x two growth points (1/3 and 2/3 of the horizon).
    pub fn ladder(initial: &ModelConfig, goal: &ModelConfig, operators: &[&str]) -> SearchSpace {
        SearchSpace {
            initial: initial.clone(),
            goal: goal.clone(),
            operators: operators.iter().map(|s| s.to_string()).collect(),
            fracs: vec![1.0 / 3.0, 2.0 / 3.0],
            rungs: ladder_rungs(initial, goal),
            max_peak_bytes: None,
            max_step_flops: None,
        }
    }

    /// Cross the axes into the raw candidate list (plugging; no validity
    /// judgement here — that is [`SearchSpace::filter`]'s job):
    /// per operator, every 1-stage schedule `[(f, goal)]` and every 2-stage
    /// schedule `[(f_i, rung), (f_j, goal)]` with `f_i < f_j`.
    pub fn enumerate(&self) -> Vec<Candidate> {
        let mut out = Vec::new();
        let mut id = 0usize;
        let mut push = |op: &String, stages: Vec<CandidateStage>| {
            out.push(Candidate { id, operator: op.clone(), stages });
            id += 1;
        };
        for op in &self.operators {
            for f in &self.fracs {
                push(op, vec![CandidateStage { frac: *f, target: self.goal.clone() }]);
            }
            for rung in &self.rungs {
                for (i, f1) in self.fracs.iter().enumerate() {
                    for f2 in &self.fracs[i + 1..] {
                        push(
                            op,
                            vec![
                                CandidateStage { frac: *f1, target: rung.clone() },
                                CandidateStage { frac: *f2, target: self.goal.clone() },
                            ],
                        );
                    }
                }
            }
        }
        out
    }

    /// Check one candidate's stage endpoints against the cost caps.
    /// Symbolic prices only ([`shape::cost_of`] memoizes per geometry).
    fn over_budget(&self, cand: &Candidate) -> Result<Option<String>> {
        for st in &cand.stages {
            let cost = shape::cost_of(&st.target)?;
            if let Some(cap) = self.max_peak_bytes {
                if cost.peak_bytes > cap {
                    return Ok(Some(format!(
                        "stage '{}' peak arena {} bytes exceeds the {cap}-byte budget",
                        st.target.name, cost.peak_bytes
                    )));
                }
            }
            if let Some(cap) = self.max_step_flops {
                if cost.step_flops > cap {
                    return Ok(Some(format!(
                        "stage '{}' costs {:.3e} FLOPs/step, over the {cap:.3e} budget",
                        st.target.name, cost.step_flops
                    )));
                }
            }
        }
        Ok(None)
    }

    /// The static filter: split `candidates` into survivors and pruned.
    /// Every chain goes through [`verify::verify_batch`] (symbolic shape
    /// replay, operator-regime checks) and then the cost caps; rejects
    /// carry the full diagnostic chain. No kernels run here.
    pub fn filter(&self, candidates: Vec<Candidate>) -> Result<Enumerated> {
        let raw = candidates.len();
        let chains: Vec<(String, Vec<ModelConfig>)> =
            candidates.iter().map(|c| (c.operator.clone(), c.targets())).collect();
        let verdicts = verify::verify_batch(&self.initial, &chains);
        let mut survivors = Vec::new();
        let mut pruned = Vec::new();
        for (cand, verdict) in candidates.into_iter().zip(verdicts) {
            match verdict {
                Err(e) => pruned.push(Pruned { candidate: cand, reason: format!("{e:#}") }),
                Ok(_) => match self.over_budget(&cand)? {
                    Some(reason) => pruned.push(Pruned { candidate: cand, reason }),
                    None => survivors.push(cand),
                },
            }
        }
        Ok(Enumerated { raw, survivors, pruned })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Registry;
    use crate::tensor::arena;

    fn smoke_space() -> SearchSpace {
        let reg = Registry::builtin();
        SearchSpace::ladder(
            &reg.models["bert_small"],
            &reg.models["bert_base"],
            &["stackbert", "net2net", "ligo", "lemon"],
        )
    }

    #[test]
    fn ladder_over_generates_and_the_filter_prunes_statically() {
        let space = smoke_space();
        // 4x5 quarter-point grid minus the goal geometry
        let names: Vec<&String> = space.rungs.iter().map(|r| &r.name).collect();
        assert_eq!(space.rungs.len(), 19, "{names:?}");
        let raw = space.enumerate();
        assert!(raw.len() >= 20, "smoke space must enumerate >=20 raw, got {}", raw.len());
        // zero-kernel proof: the whole static phase allocates no arena buffer
        arena::reset_stats();
        let e = space.filter(raw).unwrap();
        let (fresh, _) = arena::stats();
        assert_eq!(fresh, 0, "static filter must not execute kernels");
        assert_eq!(e.raw, 4 * (2 + 19));
        assert!(!e.survivors.is_empty());
        assert!(e.prune_rate() >= 0.5, "rate {}", e.prune_rate());
        // every survivor's final stage is the goal
        for c in &e.survivors {
            assert_eq!(c.stages.last().unwrap().target.name, "bert_base");
        }
    }

    #[test]
    fn pruned_candidates_carry_typed_diagnostics() {
        let space = smoke_space();
        let e = space.filter(space.enumerate()).unwrap();
        let reasons: Vec<&str> = e.pruned.iter().map(|p| p.reason.as_str()).collect();
        // odd head split from a raw-arithmetic width rung (54 or 66)
        assert!(
            reasons.iter().any(|r| r.contains("divisible") || r.contains("heads")),
            "{reasons:#?}"
        );
        // lateral rung (initial geometry): growth must strictly grow
        assert!(reasons.iter().any(|r| r.contains("not larger")), "{reasons:#?}");
        // LEMON out-of-regime: 48 -> 72 is not an integer width factor
        assert!(reasons.iter().any(|r| r.contains("integer factor")), "{reasons:#?}");
        // every lemon candidate dies on this ladder (72 = 1.5 * 48)
        assert!(e.pruned.iter().filter(|p| p.candidate.operator == "lemon").count() > 0);
        assert!(!e.survivors.iter().any(|c| c.operator == "lemon"));
        // diagnostics are stage-indexed so multi-stage rejects are locatable
        assert!(reasons.iter().any(|r| r.contains("chain stage")), "{reasons:#?}");
    }

    #[test]
    fn cost_caps_prune_over_budget_survivors() {
        let mut space = smoke_space();
        space.max_step_flops = Some(1.0); // absurdly tight: everything is over
        let e = space.filter(space.enumerate()).unwrap();
        assert!(e.survivors.is_empty());
        assert!(e.pruned.iter().any(|p| p.reason.contains("FLOPs/step")));
    }

    #[test]
    fn plans_schedule_fractions_into_strictly_increasing_reachable_steps() {
        let space = smoke_space();
        let e = space.filter(space.enumerate()).unwrap();
        let two_stage = e
            .survivors
            .iter()
            .find(|c| c.stages.len() == 2)
            .expect("ladder space has 2-stage survivors");
        for horizon in [3usize, 6, 24] {
            let plan = two_stage.plan_for(&space.initial, horizon, 4, 7).unwrap();
            let steps: Vec<usize> = plan.stages().iter().map(|s| s.at_step).collect();
            assert_eq!(steps.len(), 2);
            assert!(steps[0] >= 1 && steps[1] > steps[0], "{steps:?} @ {horizon}");
            assert!(steps[1] < horizon, "{steps:?} @ {horizon}");
            for st in plan.stages() {
                assert_eq!(st.opts.steps, 4);
                assert_eq!(st.opts.seed, 7);
            }
        }
        // too-short horizon is a typed error, not a silent mis-schedule
        let err = two_stage.plan_for(&space.initial, 2, 4, 7).unwrap_err().to_string();
        assert!(err.contains("horizon"), "{err}");
    }
}
