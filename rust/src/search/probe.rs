//! Cheap-probe scoring: execute static survivors for a short seeded
//! horizon and rank them by FLOPs-normalized loss improvement.
//!
//! Each probe trains the *same* det-init small model through the
//! candidate's [`GrowthPlan`] on the native engine, then scores the run
//! LAG-style: short-horizon loss delta divided by the probe's analytic
//! FLOPs (growth cost included, so an expensive learned-M schedule must
//! earn its extra compute). Ranking never reads the wall clock — the FLOPs
//! ledger is deterministic, the wall is not.
//!
//! Probes are bitwise reproducible by construction:
//! * every candidate gets a *fresh* batch source seeded from the probe
//!   seed, pure in the global microbatch index — so probe order, worker
//!   count (`LIGO_WORKERS`), and repeated runs cannot perturb the data a
//!   candidate sees;
//! * the probe recipe pins `grad_accum = 1`, the regime where the serial
//!   and sharded step loops are bit-identical;
//! * scratch params come from [`Trainer::scratch_params`] under the same
//!   seed for every candidate, so schedules (not inits) are what differ.
//!
//! Successive halving keeps the probe bill sublinear in the survivor
//! count: everyone trains at a quarter horizon first, the worse half is
//! discarded, the horizon doubles, until the full horizon ranks the
//! finalists. A step budget (`LIGO_SEARCH_BUDGET`) caps the total; budget
//! clamps are logged, never silent.

use std::cmp::Ordering;
use std::collections::BTreeMap;

use crate::bail;
use crate::config::{artifacts_dir, ModelConfig, Registry};
use crate::coordinator::metrics::Curve;
use crate::coordinator::plan::GrowthPlan;
use crate::coordinator::trainer::{Batches, Trainer};
use crate::data::corpus::Corpus;
use crate::data::vision::VisionTask;
use crate::error::{Context, Result};
use crate::experiments::common;
use crate::log_info;
use crate::runtime::{NativeBackend, Runtime};
use crate::util::knobs;

use super::space::Candidate;

/// Probe-phase configuration, defaulted from the `LIGO_SEARCH_*` knobs.
#[derive(Debug, Clone)]
pub struct ProbeConfig {
    /// Full probe horizon (steps) a finalist trains for.
    pub horizon: usize,
    /// Ranked candidates kept through halving and reported.
    pub topk: usize,
    /// Total probe optimizer steps across all halving rounds.
    pub budget_steps: usize,
    /// M-learning steps per stage for learned-operator candidates.
    pub m_steps: usize,
    /// One seed for scratch params, batch streams and stage options.
    pub seed: u64,
}

impl ProbeConfig {
    pub fn from_env() -> ProbeConfig {
        ProbeConfig {
            horizon: knobs::usize_env("LIGO_SEARCH_PROBE_STEPS").unwrap_or(24).max(1),
            topk: knobs::usize_env("LIGO_SEARCH_TOPK").unwrap_or(4).max(1),
            budget_steps: knobs::usize_env("LIGO_SEARCH_BUDGET").unwrap_or(2000).max(1),
            m_steps: 8,
            seed: 0x5EA2_C411,
        }
    }
}

/// What one probe measured.
#[derive(Debug, Clone)]
pub struct ProbeScore {
    pub init_loss: f32,
    pub final_loss: f32,
    /// Analytic FLOPs the probe spent (training + growth, from the ledger).
    pub flops: f64,
    /// Horizon the final scoring round ran at.
    pub steps: usize,
    /// Growth marks the run recorded, in order.
    pub marks: Vec<(usize, String)>,
}

impl ProbeScore {
    /// The ranking statistic: loss improvement per probe GFLOP.
    pub fn per_gflop(&self) -> f64 {
        (self.init_loss as f64 - self.final_loss as f64) / (self.flops / 1e9).max(1e-9)
    }
}

/// A candidate with its probe verdict.
#[derive(Debug, Clone)]
pub struct Scored {
    pub candidate: Candidate,
    pub score: ProbeScore,
}

/// A native-engine runtime whose backend knows every config in `extra` in
/// addition to the artifact registry — synthesized search rungs are not
/// presets, so the default registry cannot compile them.
pub fn runtime_for<'a>(extra: impl IntoIterator<Item = &'a ModelConfig>) -> Runtime {
    let mut models: BTreeMap<String, ModelConfig> =
        Registry::load_or_builtin(&artifacts_dir()).models;
    for cfg in extra {
        models.insert(cfg.name.clone(), cfg.clone());
    }
    Runtime::with_backend(Box::new(NativeBackend::new(models)), artifacts_dir())
}

/// A probe batch source for `cfg`: pure in the global microbatch index and
/// freshly seeded per call, so scores are identical across `LIGO_WORKERS`
/// settings, probe orders and repeated runs.
pub fn probe_batches(cfg: &ModelConfig, seed: u64) -> Batches {
    if cfg.is_vision() {
        common::vision_batches(&VisionTask::pretrain(), cfg, seed)
    } else {
        let corpus = Corpus::new(cfg.vocab, seed);
        common::text_batches(&corpus, cfg, seed)
    }
}

/// Execute one plan from det-init scratch params for `steps` and return
/// the curve. Shared by the probe loop and the winner re-execution check.
pub fn execute_plan(
    rt: &Runtime,
    label: &str,
    plan: &GrowthPlan,
    steps: usize,
    seed: u64,
) -> Result<Curve> {
    let initial = plan.initial();
    let params = Trainer::scratch_params(rt, initial, seed)?;
    let mut tc = common::recipe_for(initial, steps);
    // grad_accum == 1 keeps serial and sharded loops bit-identical, so
    // probe scores cannot depend on LIGO_WORKERS
    tc.grad_accum = 1;
    tc.eval_every = steps.max(1);
    let mut tr = Trainer::new(rt, initial, tc, params)?;
    let mut batches = probe_batches(initial, seed);
    tr.run_plan(rt, label, &mut batches, steps, plan)
}

fn probe_one(
    rt: &Runtime,
    initial: &ModelConfig,
    cand: &Candidate,
    horizon: usize,
    cfg: &ProbeConfig,
) -> Result<Scored> {
    let plan = cand
        .plan_for(initial, horizon, cfg.m_steps, cfg.seed)
        .with_context(|| format!("candidate #{} ({})", cand.id, cand.describe()))?;
    let label = format!("probe#{:03}", cand.id);
    let curve = execute_plan(rt, &label, &plan, horizon, cfg.seed)
        .with_context(|| format!("probing candidate #{} ({})", cand.id, cand.describe()))?;
    let (first, last) = (
        *curve.loss.first().context("probe curve has no eval points")?,
        *curve.loss.last().context("probe curve has no eval points")?,
    );
    let flops = curve.flops.last().copied().unwrap_or(0.0);
    Ok(Scored {
        candidate: cand.clone(),
        score: ProbeScore {
            init_loss: first,
            final_loss: last,
            flops,
            steps: horizon,
            marks: curve.marks.clone(),
        },
    })
}

/// Deterministic ranking: score descending, enumeration id as tie-break
/// (incomparable scores — NaN from a diverged probe — fall to the id).
fn rank(scored: &mut [Scored]) {
    scored.sort_by(|a, b| {
        b.score
            .per_gflop()
            .partial_cmp(&a.score.per_gflop())
            .unwrap_or(Ordering::Equal)
            .then(a.candidate.id.cmp(&b.candidate.id))
    });
}

/// Probe all survivors under successive halving and return the top-k of
/// the final round, ranked best-first.
pub fn probe_all(
    rt: &Runtime,
    initial: &ModelConfig,
    survivors: &[Candidate],
    cfg: &ProbeConfig,
) -> Result<Vec<Scored>> {
    if survivors.is_empty() {
        bail!("no candidates survived the static filter; nothing to probe");
    }
    let mut active: Vec<Candidate> = survivors.to_vec();
    // shortest horizon any multi-stage plan can schedule into
    let min_h = active.iter().map(|c| c.stages.len()).max().unwrap_or(0) + 1;
    let full_h = cfg.horizon.max(min_h);
    let mut h = (full_h / 4).clamp(min_h, full_h);
    let mut spent = 0usize;
    let mut round = 0usize;
    loop {
        // budget clamp is explicit in the log, never silent
        if spent + active.len() * h > cfg.budget_steps {
            let per = (cfg.budget_steps.saturating_sub(spent) / active.len()).max(min_h);
            if per < h {
                log_info!(
                    "search: probe budget clamps round {round} horizon {h} -> {per} \
                     ({} candidates, {spent}/{} steps spent)",
                    active.len(),
                    cfg.budget_steps
                );
                h = per;
            }
        }
        let mut scored = Vec::with_capacity(active.len());
        for cand in &active {
            scored.push(probe_one(rt, initial, cand, h, cfg)?);
        }
        spent += active.len() * h;
        rank(&mut scored);
        log_info!(
            "search: round {round} probed {} candidates at horizon {h} \
             (best {:+.3e} Δloss/GFLOP, {spent} steps spent)",
            scored.len(),
            scored[0].score.per_gflop()
        );
        if h >= full_h || spent >= cfg.budget_steps {
            if h < full_h {
                log_info!(
                    "search: probe budget {} exhausted at horizon {h} < {full_h}; \
                     ranking finalists from the last completed round",
                    cfg.budget_steps
                );
            }
            scored.truncate(cfg.topk);
            return Ok(scored);
        }
        // halve: drop the worse half, floor at top-k finalists
        let keep = (active.len() / 2).max(cfg.topk).max(1).min(active.len());
        scored.truncate(keep);
        active = scored.into_iter().map(|s| s.candidate).collect();
        h = (h * 2).min(full_h);
        round += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::growth::testutil::mk_cfg;
    use crate::search::space::CandidateStage;

    fn tiny_rt(small: &ModelConfig, cands: &[Candidate]) -> Runtime {
        runtime_for(cands.iter().flat_map(|c| c.stages.iter().map(|s| &s.target)).chain([small]))
    }

    fn tiny_candidates() -> (ModelConfig, Vec<Candidate>) {
        let small = mk_cfg(2, 8, 2);
        let big = mk_cfg(3, 12, 3);
        let cands = vec![
            Candidate {
                id: 0,
                operator: "stackbert".into(),
                stages: vec![CandidateStage { frac: 0.5, target: big.clone() }],
            },
            Candidate {
                id: 1,
                operator: "net2net".into(),
                stages: vec![CandidateStage { frac: 0.5, target: big.clone() }],
            },
        ];
        (small, cands)
    }

    #[test]
    fn probes_train_through_the_plan_and_record_growth_marks() {
        let (small, cands) = tiny_candidates();
        let rt = tiny_rt(&small, &cands);
        let cfg = ProbeConfig { horizon: 4, topk: 2, budget_steps: 100, m_steps: 2, seed: 11 };
        let ranked = probe_all(&rt, &small, &cands, &cfg).unwrap();
        assert_eq!(ranked.len(), 2);
        for s in &ranked {
            assert_eq!(s.score.steps, 4);
            assert_eq!(s.score.marks.len(), 1, "one growth stage -> one mark");
            assert!(s.score.flops > 0.0);
            assert!(s.score.init_loss.is_finite() && s.score.final_loss.is_finite());
        }
    }

    #[test]
    fn identical_probes_score_identically_and_ranking_is_deterministic() {
        let (small, cands) = tiny_candidates();
        let rt = tiny_rt(&small, &cands);
        let cfg = ProbeConfig { horizon: 4, topk: 2, budget_steps: 100, m_steps: 2, seed: 11 };
        let a = probe_all(&rt, &small, &cands, &cfg).unwrap();
        let b = probe_all(&rt, &small, &cands, &cfg).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.candidate.id, y.candidate.id);
            assert_eq!(x.score.final_loss.to_bits(), y.score.final_loss.to_bits());
            assert_eq!(x.score.flops.to_bits(), y.score.flops.to_bits());
        }
    }

    #[test]
    fn probe_scores_are_bitwise_identical_across_worker_counts() {
        use crate::coordinator::parallel::set_workers_override;
        let (small, cands) = tiny_candidates();
        let rt = tiny_rt(&small, &cands);
        let cfg = ProbeConfig { horizon: 4, topk: 2, budget_steps: 100, m_steps: 2, seed: 11 };
        set_workers_override(Some(1));
        let serial = probe_all(&rt, &small, &cands, &cfg).unwrap();
        set_workers_override(Some(2));
        let sharded = probe_all(&rt, &small, &cands, &cfg).unwrap();
        set_workers_override(None);
        for (x, y) in serial.iter().zip(&sharded) {
            assert_eq!(x.candidate.id, y.candidate.id, "ranking must not depend on workers");
            assert_eq!(
                x.score.final_loss.to_bits(),
                y.score.final_loss.to_bits(),
                "candidate #{} loss must be bit-identical across LIGO_WORKERS",
                x.candidate.id
            );
        }
    }

    #[test]
    fn budget_clamp_still_returns_a_full_ranking() {
        let (small, cands) = tiny_candidates();
        let rt = tiny_rt(&small, &cands);
        // budget forces horizon below the requested 16 on the first round
        let cfg = ProbeConfig { horizon: 16, topk: 2, budget_steps: 8, m_steps: 2, seed: 3 };
        let ranked = probe_all(&rt, &small, &cands, &cfg).unwrap();
        assert_eq!(ranked.len(), 2);
        assert!(ranked[0].score.steps < 16, "clamped horizon, got {}", ranked[0].score.steps);
    }

    #[test]
    fn empty_survivor_set_is_a_typed_error() {
        let (small, _) = tiny_candidates();
        let rt = runtime_for([&small]);
        let err = probe_all(&rt, &small, &[], &ProbeConfig::from_env()).unwrap_err().to_string();
        assert!(err.contains("static filter"), "{err}");
    }
}
