//! Dense linear algebra on [`Tensor`]s — the substrate for the growth
//! operator zoo (Net2Net, AKI, native LiGO) and for the native model
//! engine's NN kernels.
//!
//! # Kernel layer and its numerics contract
//!
//! Hot paths use blocked, cache-friendly loops that go multicore
//! (scoped-thread row partitioning via [`crate::util::par`]) above
//! [`PAR_MIN_MACS`] / [`PAR_MIN_KERNEL`]; everything is f32. Three
//! guarantees hold for every kernel in this module:
//!
//! 1. **Serial/parallel bit-identity.** Work is partitioned by *output
//!    rows* only; the per-element accumulation order never depends on the
//!    worker count, so `LIGO_THREADS=1` and all-core runs produce
//!    bit-identical tensors.
//! 2. **Deterministic accumulation order.** Each kernel fixes one
//!    summation order (the k-blocked order of [`matmul`] for the matmul
//!    family). [`linear_fused`] and the packed [`matmul_nt`] path sum in
//!    that same k-blocked order, which *reassociates* the reduction
//!    relative to the naive dot-product form — outputs agree with the
//!    unfused composition to ≤1e-5 relative error (asserted in tests), not
//!    bitwise. Within one binary and one knob setting, results are
//!    bit-reproducible run to run.
//! 3. **IEEE non-finite propagation.** Only [`matmul`] has a zero-skip
//!    fast path, and it disables itself when the right operand contains
//!    non-finite values; [`matmul_nt`] and [`linear_fused`] never skip, so
//!    0 × NaN/Inf propagates as NaN everywhere.
//!
//! The fused linear kernel ([`linear_fused`]) computes `x @ w^T (+ bias)
//! (+ GELU)` in one pass: it packs `w^T` once per call (amortized over the
//! activation rows), initializes each output row with the bias, and runs
//! an auto-vectorizable blocked i-k-j microkernel whose inner loop is an
//! independent elementwise FMA over contiguous output columns — the shape
//! LLVM vectorizes without `-ffast-math`. The naive dot-product form is a
//! serial reduction LLVM must *not* vectorize, which is why the packed
//! kernel wins despite the transpose. `LIGO_FUSED=0` (or
//! [`set_fused_override`]) routes the tape back to the unfused
//! linear/add/GELU composition for A/B runs.
//!
//! Output buffers come from the thread-local recycling pool in
//! [`crate::tensor::arena`] (disable with `LIGO_ARENA=0`); kernels recycle
//! their internal scratch (e.g. the packed `w^T`) before returning.
//!
//! ```
//! use ligo::tensor::ops::{self, Act};
//! use ligo::tensor::Tensor;
//! let x = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
//! let w = Tensor::from_f32(&[2, 3], vec![0.5, 0., 0., 0., 0.5, 0.]); // (out, in)
//! let b = Tensor::from_f32(&[2], vec![1.0, -1.0]);
//! let (y, pre) = ops::linear_fused(&x, &w, Some(&b), Act::None);
//! assert_eq!(y.f32s(), &[1.5, 0.0, 3.0, 1.5]); // x @ w^T + b
//! assert!(pre.is_none(), "pre-activation is saved only under Act::Gelu");
//! ```

use std::cell::Cell;
use std::sync::OnceLock;

use crate::tensor::paged::PagedRows;
use crate::util::par;

use super::{arena, numel, Tensor};

/// Multiply-accumulate count above which matmuls fan out across cores.
/// Below it, thread spawn/join overhead dominates (and tests stay serial).
pub const PAR_MIN_MACS: usize = 1 << 21;

/// Output rows processed together by the register-blocked dense microkernel:
/// each streamed row of B is loaded once and FMA'd into [`MM_ROW_BLOCK`]
/// independent accumulator rows (4x the arithmetic intensity of the
/// row-at-a-time loop).
const MM_ROW_BLOCK: usize = 4;

/// Blocked i-k-j kernel over a contiguous row chunk of C (rows starting at
/// global row `row0`). `skip_zeros` enables the sparse fast path: legal only
/// when every element of `b` is finite, since 0 * NaN/Inf must stay NaN.
///
/// The dense (`!skip_zeros`) path — what [`matmul_nt`]'s packed kernel and
/// [`linear_fused`] run — is 4x-row register-blocked: four output rows share
/// every load of a B row, and the inner j-loop is four independent
/// elementwise FMA streams over contiguous memory, the shape LLVM
/// auto-vectorizes. Per output element the accumulation order (ascending k
/// within ascending k-blocks) is identical to the single-row loop, so the
/// blocked results are **bitwise** equal to the unblocked ones. The sparse
/// path keeps the per-row zero-skip (growth selection matrices are mostly
/// zeros) and therefore stays row-at-a-time.
fn matmul_rows(
    av: &[f32],
    bv: &[f32],
    c: &mut [f32],
    row0: usize,
    k: usize,
    n: usize,
    skip_zeros: bool,
) {
    const BK: usize = 64;
    let rows = c.len() / n;
    for k0 in (0..k).step_by(BK) {
        let k1 = (k0 + BK).min(k);
        let mut r = 0;
        if !skip_zeros {
            while r + MM_ROW_BLOCK <= rows {
                let block = &mut c[r * n..(r + MM_ROW_BLOCK) * n];
                let (c0, rest) = block.split_at_mut(n);
                let (c1, rest) = rest.split_at_mut(n);
                let (c2, c3) = rest.split_at_mut(n);
                for kk in k0..k1 {
                    let brow = &bv[kk * n..(kk + 1) * n];
                    let a0 = av[(row0 + r) * k + kk];
                    let a1 = av[(row0 + r + 1) * k + kk];
                    let a2 = av[(row0 + r + 2) * k + kk];
                    let a3 = av[(row0 + r + 3) * k + kk];
                    for (j, &bj) in brow.iter().enumerate() {
                        c0[j] += a0 * bj;
                        c1[j] += a1 * bj;
                        c2[j] += a2 * bj;
                        c3[j] += a3 * bj;
                    }
                }
                r += MM_ROW_BLOCK;
            }
        }
        for rr in r..rows {
            let i = row0 + rr;
            let crow = &mut c[rr * n..(rr + 1) * n];
            for kk in k0..k1 {
                let aik = av[i * k + kk];
                if skip_zeros && aik == 0.0 {
                    continue;
                }
                let brow = &bv[kk * n..(kk + 1) * n];
                for (cj, bj) in crow.iter_mut().zip(brow.iter()) {
                    *cj += aik * bj;
                }
            }
        }
    }
}

/// C = A @ B for (m,k) x (k,n). Blocked i-k-j loop (k-major inner) — the
/// classic cache-friendly ordering — parallelized over output rows for
/// growth-time work. Rows of A that are exactly zero are skipped, but only
/// when B is all-finite: with NaN/Inf in B the full accumulation runs so
/// that 0 * NaN propagates as IEEE 754 demands.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
    let (av, bv) = (a.f32s(), b.f32s());
    let mut c = arena::alloc_zeroed(m * n);
    if m == 0 || n == 0 {
        return Tensor::from_f32(&[m, n], c);
    }
    let skip_zeros = bv.iter().all(|x| x.is_finite());
    if m * k * n >= PAR_MIN_MACS && m > 1 {
        par::par_row_chunks(&mut c, n, |row0, chunk| {
            matmul_rows(av, bv, chunk, row0, k, n, skip_zeros)
        });
    } else {
        matmul_rows(av, bv, &mut c, 0, k, n, skip_zeros);
    }
    Tensor::from_f32(&[m, n], c)
}

/// MAC count above which [`matmul_nt`] packs `Y^T` once and runs the
/// auto-vectorizable blocked i-k-j kernel. Below it the direct dot-product
/// form wins (no packing cost on tiny operands).
pub const NT_PACK_MIN_MACS: usize = 1 << 14;

/// Blocked transpose of `w` (rows, cols) into a (cols, rows) arena buffer
/// — the packing step of [`linear_fused`] and the packed [`matmul_nt`].
/// Every element is written, so the scratch skips zeroing.
fn pack_transposed(w: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    const BT: usize = 32;
    let mut wt = arena::alloc_scratch(rows * cols);
    for j0 in (0..rows).step_by(BT) {
        let j1 = (j0 + BT).min(rows);
        for k0 in (0..cols).step_by(BT) {
            let k1 = (k0 + BT).min(cols);
            for j in j0..j1 {
                for (kk, &wjk) in (k0..k1).zip(&w[j * cols + k0..j * cols + k1]) {
                    wt[kk * rows + j] = wjk;
                }
            }
        }
    }
    wt
}

/// C = X @ Y^T for (m,k) x (n,k) — the layout of every stored projection
/// (`y = W x` on (out, in) weights) and of the LiGO in-expansion (`... A^T`).
/// Above [`NT_PACK_MIN_MACS`] it packs `Y^T` and reuses [`matmul`]'s
/// k-blocked vectorizable kernel (packing is amortized over the m rows);
/// below, it streams direct dot products. Never skips zeros, so NaN/Inf
/// always propagate.
pub fn matmul_nt(x: &Tensor, y: &Tensor) -> Tensor {
    let (m, k) = (x.shape[0], x.shape[1]);
    let (n, k2) = (y.shape[0], y.shape[1]);
    assert_eq!(k, k2, "matmul_nt inner dims: {k} vs {k2}");
    let (xv, yv) = (x.f32s(), y.f32s());
    let mut c = arena::alloc_zeroed(m * n);
    if m == 0 || n == 0 {
        return Tensor::from_f32(&[m, n], c);
    }
    let macs = m * k * n;
    if m > 1 && macs >= NT_PACK_MIN_MACS {
        let yt = pack_transposed(yv, n, k);
        if macs >= PAR_MIN_MACS {
            par::par_row_chunks(&mut c, n, |row0, chunk| {
                matmul_rows(xv, &yt, chunk, row0, k, n, false)
            });
        } else {
            matmul_rows(xv, &yt, &mut c, 0, k, n, false);
        }
        arena::recycle_buf(yt);
        return Tensor::from_f32(&[m, n], c);
    }
    let kernel = |row0: usize, chunk: &mut [f32]| {
        for (r, crow) in chunk.chunks_exact_mut(n).enumerate() {
            let xrow = &xv[(row0 + r) * k..(row0 + r + 1) * k];
            for (j, cj) in crow.iter_mut().enumerate() {
                let yrow = &yv[j * k..(j + 1) * k];
                *cj = xrow.iter().zip(yrow.iter()).map(|(a, b)| a * b).sum();
            }
        }
    };
    if macs >= PAR_MIN_MACS && m > 1 {
        par::par_row_chunks(&mut c, n, kernel);
    } else {
        kernel(0, &mut c);
    }
    Tensor::from_f32(&[m, n], c)
}

/// B^T as a new tensor (blocked; the buffer comes from the arena).
pub fn transpose(a: &Tensor) -> Tensor {
    let (m, n) = (a.shape[0], a.shape[1]);
    let out = pack_transposed(a.f32s(), m, n);
    Tensor::from_f32(&[n, m], out)
}

// ---------------------------------------------------------------------------
// Fused linear (+bias, +GELU) — the SIMD-friendly microkernel behind the
// tape's `linear_bias` / `linear_bias_gelu` ops.
// ---------------------------------------------------------------------------

/// Activation fused into the [`linear_fused`] epilogue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    /// Plain affine output.
    None,
    /// GELU (tanh approximation) applied in the epilogue; the
    /// pre-activation is returned for the backward pass.
    Gelu,
}

thread_local! {
    /// 0 = follow the env default, 1 = force unfused, 2 = force fused.
    static FUSED_OVERRIDE: Cell<u8> = const { Cell::new(0) };
}

/// Whether the tape lowers linear+bias(+GELU) to [`linear_fused`]
/// (default) or to the unfused linear/add/GELU node chain. Process default
/// comes from `LIGO_FUSED` (`0` disables); [`set_fused_override`] overrides
/// per thread for in-process A/B comparisons.
pub fn fused_enabled() -> bool {
    match FUSED_OVERRIDE.with(|c| c.get()) {
        1 => false,
        2 => true,
        _ => {
            static FUSED: OnceLock<bool> = OnceLock::new();
            *FUSED.get_or_init(|| !crate::util::knobs::flag_disabled("LIGO_FUSED"))
        }
    }
}

/// Thread-local override of [`fused_enabled`]: `Some(on)` pins the lowering,
/// `None` restores the env default. Benches and equivalence tests use this
/// to A/B both code paths in one process.
pub fn set_fused_override(v: Option<bool>) {
    FUSED_OVERRIDE.with(|c| {
        c.set(match v {
            None => 0,
            Some(false) => 1,
            Some(true) => 2,
        })
    });
}

thread_local! {
    /// 0 = follow the env default, 1 = force unfused, 2 = force fused.
    static FUSED_XENT_OVERRIDE: Cell<u8> = const { Cell::new(0) };
}

/// Whether the tape lowers the LM/classifier head to the streaming fused
/// linear+cross-entropy kernel ([`lm_head_xent_fwd`] / [`lm_head_xent_bwd`],
/// default — the `(rows, vocab)` logits are never materialized) or to the
/// unfused linear_bias + masked_xent node chain. Process default comes from
/// `LIGO_FUSED_XENT` (`0` disables); [`set_fused_xent_override`] overrides
/// per thread, mirroring the `LIGO_FUSED` knob exactly.
pub fn fused_xent_enabled() -> bool {
    match FUSED_XENT_OVERRIDE.with(|c| c.get()) {
        1 => false,
        2 => true,
        _ => {
            static FUSED: OnceLock<bool> = OnceLock::new();
            *FUSED.get_or_init(|| !crate::util::knobs::flag_disabled("LIGO_FUSED_XENT"))
        }
    }
}

/// Thread-local override of [`fused_xent_enabled`]: `Some(on)` pins the
/// lowering, `None` restores the env default (the `LIGO_FUSED_XENT`
/// equivalent of [`set_fused_override`]).
pub fn set_fused_xent_override(v: Option<bool>) {
    FUSED_XENT_OVERRIDE.with(|c| {
        c.set(match v {
            None => 0,
            Some(false) => 1,
            Some(true) => 2,
        })
    });
}

/// The blocked i-k-j microkernel over a contiguous row chunk of the output
/// (rows starting at global row `row0`): initializes each output row with
/// the bias, then accumulates `x @ wt` in k-blocks. The inner j-loop is an
/// independent elementwise FMA over contiguous memory — auto-vectorizable.
fn linear_rows(
    xv: &[f32],
    wtv: &[f32],
    bias: Option<&[f32]>,
    c: &mut [f32],
    row0: usize,
    k: usize,
    n: usize,
) {
    if let Some(b) = bias {
        for crow in c.chunks_exact_mut(n) {
            crow.copy_from_slice(b);
        }
    }
    matmul_rows(xv, wtv, c, row0, k, n, false)
}

/// `y = x @ w^T (+ bias) (+ GELU)` in one fused pass — x (m, k) against the
/// stored-projection layout w (n, k). Above [`NT_PACK_MIN_MACS`] it packs
/// `w^T` once (arena scratch, recycled before returning) and runs the
/// blocked microkernel, row-parallel above [`PAR_MIN_MACS`]; below the
/// threshold it streams direct dot products (bias added after each sum),
/// which is **bitwise** equal to the unfused chain. Returns `(y, pre)`:
/// `pre` is the saved pre-activation, present only under [`Act::Gelu`]
/// (the backward needs it). The packed path's accumulation is k-blocked
/// (the [`matmul`] order): serial/parallel bit-identical, and within
/// ≤1e-5 relative error of the unfused matmul_nt/add/GELU chain.
pub fn linear_fused(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&Tensor>,
    act: Act,
) -> (Tensor, Option<Tensor>) {
    let (m, k) = (x.shape[0], x.shape[1]);
    let (n, k2) = (w.shape[0], w.shape[1]);
    assert_eq!(k, k2, "linear_fused inner dims: {k} vs {k2}");
    if let Some(b) = bias {
        assert_eq!(b.numel(), n, "linear_fused bias dim");
    }
    let (xv, wv) = (x.f32s(), w.f32s());
    let bv = bias.map(|b| b.f32s());
    if m == 0 || n == 0 {
        let pre = matches!(act, Act::Gelu).then(|| Tensor::from_f32(&[m, n], vec![]));
        return (Tensor::from_f32(&[m, n], Vec::new()), pre);
    }
    if m == 1 || m * k.max(1) * n < NT_PACK_MIN_MACS {
        // single-row or tiny operands: packing would cost as much as the
        // product itself (same guard as matmul_nt's).
        // Direct dot products in the unfused matmul_nt order (+ bias after
        // the sum), so this path is *bitwise* equal to the unfused chain.
        // dot_row assigns every element, so both buffers skip zeroing.
        let mut y = arena::alloc_scratch(m * n);
        let dot_row = |r: usize, out: &mut [f32]| {
            let xrow = &xv[r * k..(r + 1) * k];
            for (j, o) in out.iter_mut().enumerate() {
                let wrow = &wv[j * k..(j + 1) * k];
                let s: f32 = xrow.iter().zip(wrow.iter()).map(|(a, b)| a * b).sum();
                *o = match bv {
                    Some(b) => s + b[j],
                    None => s,
                };
            }
        };
        let pre = match act {
            Act::None => {
                for r in 0..m {
                    dot_row(r, &mut y[r * n..(r + 1) * n]);
                }
                None
            }
            Act::Gelu => {
                let mut z = arena::alloc_scratch(m * n);
                for r in 0..m {
                    dot_row(r, &mut z[r * n..(r + 1) * n]);
                }
                for (yj, &zj) in y.iter_mut().zip(z.iter()) {
                    *yj = gelu_scalar(zj);
                }
                Some(Tensor::from_f32(&[m, n], z))
            }
        };
        return (Tensor::from_f32(&[m, n], y), pre);
    }
    // Packed path. linear_rows fully overwrites its target when a bias is
    // present (bias rows are copied in before accumulation) and the GELU
    // epilogue fully overwrites y — zeroing is only needed for a target
    // linear_rows accumulates into from nothing (no bias).
    let pre_target = |has_bias: bool| {
        if has_bias {
            arena::alloc_scratch(m * n)
        } else {
            arena::alloc_zeroed(m * n)
        }
    };
    let wt = pack_transposed(wv, n, k);
    let parallel = m * k.max(1) * n >= PAR_MIN_MACS;
    let (y, pre) = match act {
        Act::None => {
            let mut y = pre_target(bv.is_some());
            let kern = |row0: usize, c: &mut [f32]| linear_rows(xv, &wt, bv, c, row0, k, n);
            if parallel {
                par::par_row_chunks(&mut y, n, kern);
            } else {
                kern(0, &mut y);
            }
            (y, None)
        }
        Act::Gelu => {
            let mut y = arena::alloc_scratch(m * n);
            let mut z = pre_target(bv.is_some());
            let kern = |row0: usize, ychunk: &mut [f32], zchunk: &mut [f32]| {
                linear_rows(xv, &wt, bv, zchunk, row0, k, n);
                for (yj, &zj) in ychunk.iter_mut().zip(zchunk.iter()) {
                    *yj = gelu_scalar(zj);
                }
            };
            if parallel {
                par::par_row_chunks2(&mut y, n, &mut z, n, kern);
            } else {
                kern(0, &mut y, &mut z);
            }
            (y, Some(Tensor::from_f32(&[m, n], z)))
        }
    };
    arena::recycle_buf(wt);
    (Tensor::from_f32(&[m, n], y), pre)
}

/// Decode-side linear: `x @ w^T (+ bias) (+ GELU)` via per-row dot
/// products in the exact accumulation order of [`linear_fused`]'s
/// dot-product path (k-ascending sum, bias added *after* the sum), for
/// **any** row count. Two properties the decode path needs that the packed
/// kernel cannot give:
///
/// 1. **Batch invariance.** Every output row depends only on its own input
///    row and the weight, with one fixed summation order — so a session
///    decoded alone and the same session decoded inside a batch produce
///    bit-identical rows (the scheduler's determinism guarantee).
/// 2. **Bit-parity with the tiny-operand training forward.** On shapes
///    under [`NT_PACK_MIN_MACS`] (every decode-parity test model),
///    [`linear_fused`] takes the same dot-product path, so incremental
///    decode is bitwise equal to the full-sequence forward.
///
/// Rows are processed in [`MM_ROW_BLOCK`]-row groups with the j-loop
/// outside: one streamed pass over `w` serves the whole group, which is
/// where batched decode's throughput win over per-session sequential
/// decode comes from (the weight matrix is the traffic; activations are
/// resident).
pub fn linear_dot(x: &Tensor, w: &Tensor, bias: Option<&Tensor>, act: Act) -> Tensor {
    let (m, k) = (x.shape[0], x.shape[1]);
    let (n, k2) = (w.shape[0], w.shape[1]);
    assert_eq!(k, k2, "linear_dot inner dims: {k} vs {k2}");
    if let Some(b) = bias {
        assert_eq!(b.numel(), n, "linear_dot bias dim");
    }
    let (xv, wv) = (x.f32s(), w.f32s());
    let bv = bias.map(|b| b.f32s());
    let mut y = arena::alloc_scratch(m * n);
    let mut r0 = 0;
    while r0 < m {
        let r1 = (r0 + MM_ROW_BLOCK).min(m);
        for j in 0..n {
            let wrow = &wv[j * k..(j + 1) * k];
            for r in r0..r1 {
                let xrow = &xv[r * k..(r + 1) * k];
                let s: f32 = xrow.iter().zip(wrow.iter()).map(|(a, b)| a * b).sum();
                y[r * n + j] = match bv {
                    Some(b) => s + b[j],
                    None => s,
                };
            }
        }
        r0 = r1;
    }
    if matches!(act, Act::Gelu) {
        for yj in y.iter_mut() {
            *yj = gelu_scalar(*yj);
        }
    }
    Tensor::from_f32(&[m, n], y)
}

/// The n x n identity matrix (width-expansion fallback when dims match).
pub fn eye(n: usize) -> Tensor {
    // lint:allow(fresh_alloc) growth-time helper, off the training hot path
    let mut v = vec![0.0f32; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    Tensor::from_f32(&[n, n], v)
}

/// y = A @ x for (m,n) x (n,).
pub fn matvec(a: &Tensor, x: &Tensor) -> Tensor {
    let (m, n) = (a.shape[0], a.shape[1]);
    assert_eq!(numel(&x.shape), n);
    let (av, xv) = (a.f32s(), x.f32s());
    // lint:allow(fresh_alloc) growth-time helper, off the training hot path
    let mut y = vec![0.0f32; m];
    for i in 0..m {
        y[i] = av[i * n..(i + 1) * n].iter().zip(xv).map(|(a, b)| a * b).sum();
    }
    Tensor::from_f32(&[m], y)
}

/// Elementwise dot product of two equally-shaped tensors.
pub fn dot(a: &Tensor, b: &Tensor) -> f32 {
    assert_eq!(a.shape, b.shape);
    a.f32s().iter().zip(b.f32s()).map(|(x, y)| x * y).sum()
}

/// The LiGO triple product Omega = B @ W @ A^T (paper Eq. 4's width pass).
/// The fused second stage streams A row-major (`matmul_nt`), so both halves
/// parallelize over rows.
pub fn expand(b: &Tensor, w: &Tensor, a: &Tensor) -> Tensor {
    matmul_nt(&matmul(b, w), a)
}

/// Elementwise a + s * b (in place on a pool-backed copy — residual adds
/// run this every step).
pub fn axpy(a: &Tensor, s: f32, b: &Tensor) -> Tensor {
    assert_eq!(a.shape, b.shape);
    let mut out = Tensor::from_f32(&a.shape, arena::alloc_copy(a.f32s()));
    for (x, y) in out.f32s_mut().iter_mut().zip(b.f32s()) {
        *x += s * y;
    }
    out
}

pub fn scale(a: &Tensor, s: f32) -> Tensor {
    let mut out = a.clone();
    for x in out.f32s_mut() {
        *x *= s;
    }
    out
}

/// Weighted sum of equally-shaped tensors: sum_i w_i T_i. A zero weight
/// means "excluded from the blend" (the depth-selection patterns rely on
/// this), so w_i == 0 terms are skipped rather than multiplied through.
pub fn weighted_sum(ws: &[f32], ts: &[&Tensor]) -> Tensor {
    assert_eq!(ws.len(), ts.len());
    assert!(!ts.is_empty());
    let mut out = Tensor::zeros(&ts[0].shape);
    let ov = out.f32s_mut();
    for (w, t) in ws.iter().zip(ts) {
        if *w == 0.0 {
            continue;
        }
        for (o, x) in ov.iter_mut().zip(t.f32s()) {
            *o += w * x;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Neural-net kernels (forward + backward) for the native model engine
// (`crate::model`): layernorm, GELU, softmax attention, cross-entropy.
// All row-parallel via `util::par` above PAR_MIN_KERNEL work units.
// ---------------------------------------------------------------------------

/// Estimated work (output elements x inner cost) above which the NN kernels
/// fan out across cores; below it thread spawn/join overhead dominates.
pub const PAR_MIN_KERNEL: usize = 1 << 17;

/// Dispatch a row kernel serially or via [`par`] based on estimated work.
fn run_rows<F: Fn(usize, &mut [f32]) + Sync>(out: &mut [f32], n_cols: usize, work: usize, f: F) {
    if work >= PAR_MIN_KERNEL {
        par::par_row_chunks(out, n_cols, f);
    } else {
        f(0, out);
    }
}

/// LayerNorm epsilon shared by forward and backward (matches the python L2).
pub const LN_EPS: f32 = 1e-5;

/// Row-wise layer normalization of a 2-D tensor:
/// `y = (x - mean) / sqrt(var + eps) * g + b`. Returns y plus the per-row
/// `(mean, rstd)` pairs (interleaved), saved for [`layernorm_bwd`].
pub fn layernorm_fwd(x: &Tensor, g: &Tensor, b: &Tensor) -> (Tensor, Vec<f32>) {
    let (n, d) = (x.shape[0], x.shape[1]);
    assert_eq!(g.numel(), d, "layernorm gain dim");
    assert_eq!(b.numel(), d, "layernorm bias dim");
    let (xv, gv, bv) = (x.f32s(), g.f32s(), b.f32s());
    let mut y = arena::alloc_zeroed(n * d);
    let mut stats = arena::alloc_zeroed(n * 2);
    let kernel = |row0: usize, yc: &mut [f32], sc: &mut [f32]| {
        for (r, yrow) in yc.chunks_exact_mut(d).enumerate() {
            let xrow = &xv[(row0 + r) * d..(row0 + r + 1) * d];
            let mean = xrow.iter().sum::<f32>() / d as f32;
            let var = xrow.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
            let rstd = 1.0 / (var + LN_EPS).sqrt();
            for j in 0..d {
                yrow[j] = (xrow[j] - mean) * rstd * gv[j] + bv[j];
            }
            sc[r * 2] = mean;
            sc[r * 2 + 1] = rstd;
        }
    };
    if n * d >= PAR_MIN_KERNEL {
        par::par_row_chunks2(&mut y, d, &mut stats, 2, kernel);
    } else {
        kernel(0, &mut y, &mut stats);
    }
    (Tensor::from_f32(&x.shape, y), stats)
}

/// Backward of [`layernorm_fwd`]: returns (dx, dg, db). `stats` is the
/// interleaved (mean, rstd) buffer the forward produced.
pub fn layernorm_bwd(
    x: &Tensor,
    g: &Tensor,
    stats: &[f32],
    dout: &Tensor,
) -> (Tensor, Tensor, Tensor) {
    let (n, d) = (x.shape[0], x.shape[1]);
    assert_eq!(dout.shape, x.shape, "layernorm dout shape");
    assert_eq!(stats.len(), n * 2, "layernorm stats length");
    let (xv, gv, dov) = (x.f32s(), g.f32s(), dout.f32s());
    let mut dx = arena::alloc_zeroed(n * d);
    let kernel = |row0: usize, chunk: &mut [f32]| {
        for (r, dxrow) in chunk.chunks_exact_mut(d).enumerate() {
            let i = row0 + r;
            let (mean, rstd) = (stats[i * 2], stats[i * 2 + 1]);
            let xrow = &xv[i * d..(i + 1) * d];
            let dorow = &dov[i * d..(i + 1) * d];
            let mut sum_dxh = 0.0f32;
            let mut sum_dxh_xh = 0.0f32;
            for j in 0..d {
                let xh = (xrow[j] - mean) * rstd;
                let dxh = dorow[j] * gv[j];
                sum_dxh += dxh;
                sum_dxh_xh += dxh * xh;
            }
            let inv_d = 1.0 / d as f32;
            for j in 0..d {
                let xh = (xrow[j] - mean) * rstd;
                let dxh = dorow[j] * gv[j];
                dxrow[j] = rstd * (dxh - inv_d * sum_dxh - xh * inv_d * sum_dxh_xh);
            }
        }
    };
    run_rows(&mut dx, d, n * d, kernel);
    // dg/db are column reductions over all rows — O(n d), kept serial.
    let mut dg = arena::alloc_zeroed(d);
    let mut db = arena::alloc_zeroed(d);
    for i in 0..n {
        let (mean, rstd) = (stats[i * 2], stats[i * 2 + 1]);
        for j in 0..d {
            let xh = (xv[i * d + j] - mean) * rstd;
            dg[j] += dov[i * d + j] * xh;
            db[j] += dov[i * d + j];
        }
    }
    (Tensor::from_f32(&x.shape, dx), Tensor::from_f32(&[d], dg), Tensor::from_f32(&[d], db))
}

const GELU_C: f32 = 0.797_884_6; // sqrt(2/pi)
const GELU_A: f32 = 0.044_715;

/// Scalar GELU (tanh approximation) — shared by [`gelu_fwd`] and the
/// [`linear_fused`] epilogue so both paths agree bitwise.
#[inline]
fn gelu_scalar(t: f32) -> f32 {
    let u = GELU_C * (t + GELU_A * t * t * t);
    0.5 * t * (1.0 + u.tanh())
}

/// Scalar GELU derivative — shared by [`gelu_bwd`] and the fused backward.
#[inline]
fn gelu_deriv(t: f32) -> f32 {
    let u = GELU_C * (t + GELU_A * t * t * t);
    let th = u.tanh();
    let du = GELU_C * (1.0 + 3.0 * GELU_A * t * t);
    0.5 * (1.0 + th) + 0.5 * t * (1.0 - th * th) * du
}

/// GELU activation (tanh approximation — the jax.nn.gelu default the AOT
/// path lowers): `0.5 x (1 + tanh(sqrt(2/pi)(x + 0.044715 x^3)))`.
pub fn gelu_fwd(x: &Tensor) -> Tensor {
    let xv = x.f32s();
    let mut y = arena::alloc_zeroed(xv.len());
    let kernel = |off: usize, chunk: &mut [f32]| {
        for (i, v) in chunk.iter_mut().enumerate() {
            *v = gelu_scalar(xv[off + i]);
        }
    };
    run_rows(&mut y, 1, xv.len(), kernel);
    Tensor::from_f32(&x.shape, y)
}

/// Backward of [`gelu_fwd`]: dx = dout * gelu'(x). Also the epilogue
/// backward of [`linear_fused`] under [`Act::Gelu`] (x = the saved
/// pre-activation).
pub fn gelu_bwd(x: &Tensor, dout: &Tensor) -> Tensor {
    assert_eq!(x.shape, dout.shape, "gelu dout shape");
    let (xv, dov) = (x.f32s(), dout.f32s());
    let mut dx = arena::alloc_zeroed(xv.len());
    let kernel = |off: usize, chunk: &mut [f32]| {
        for (i, v) in chunk.iter_mut().enumerate() {
            *v = dov[off + i] * gelu_deriv(xv[off + i]);
        }
    };
    run_rows(&mut dx, 1, xv.len(), kernel);
    Tensor::from_f32(&x.shape, dx)
}

/// Row-wise softmax of a 2-D tensor (max-subtracted, numerically safe).
pub fn softmax_rows(x: &Tensor) -> Tensor {
    let (n, d) = (x.shape[0], x.shape[1]);
    let xv = x.f32s();
    let mut y = arena::alloc_zeroed(n * d);
    let kernel = |row0: usize, chunk: &mut [f32]| {
        for (r, yrow) in chunk.chunks_exact_mut(d).enumerate() {
            let xrow = &xv[(row0 + r) * d..(row0 + r + 1) * d];
            let m = xrow.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut z = 0.0f32;
            for (o, &v) in yrow.iter_mut().zip(xrow) {
                *o = (v - m).exp();
                z += *o;
            }
            let inv = 1.0 / z;
            for o in yrow.iter_mut() {
                *o *= inv;
            }
        }
    };
    run_rows(&mut y, d, n * d, kernel);
    Tensor::from_f32(&x.shape, y)
}

/// Multi-head attention shape descriptor: `q` is (batch*s_q, dim), `k`/`v`
/// are (batch*s_k, dim) with dim = heads * head_dim. `causal` masks j > i
/// (GPT order; requires s_q == s_k); cross-attention (CaiT class-attention)
/// uses s_q != s_k.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AttnShape {
    pub batch: usize,
    pub heads: usize,
    pub s_q: usize,
    pub s_k: usize,
    pub causal: bool,
}

impl AttnShape {
    fn head_dim(&self, dim: usize) -> usize {
        assert_eq!(dim % self.heads, 0, "dim {dim} not divisible by {} heads", self.heads);
        dim / self.heads
    }
}

/// Softmax attention forward: out = softmax(q k^T / sqrt(dh)) v per
/// (batch, head). Returns (out (batch*s_q, dim), probs
/// (batch*heads*s_q, s_k)); probs is the saved state for [`attention_bwd`].
pub fn attention_fwd(q: &Tensor, k: &Tensor, v: &Tensor, sh: &AttnShape) -> (Tensor, Tensor) {
    let dim = q.shape[1];
    let dh = sh.head_dim(dim);
    assert_eq!(q.shape, vec![sh.batch * sh.s_q, dim], "attention q shape");
    assert_eq!(k.shape, vec![sh.batch * sh.s_k, dim], "attention k shape");
    assert_eq!(v.shape, k.shape, "attention v shape");
    if sh.causal {
        assert_eq!(sh.s_q, sh.s_k, "causal attention needs square scores");
    }
    let scale = 1.0 / (dh as f32).sqrt();
    let (qv, kv, vv) = (q.f32s(), k.f32s(), v.f32s());
    // probs rows are (b, h, i) triples — each fully independent.
    let mut probs = arena::alloc_zeroed(sh.batch * sh.heads * sh.s_q * sh.s_k);
    let pk = |row0: usize, chunk: &mut [f32]| {
        for (r, prow) in chunk.chunks_exact_mut(sh.s_k).enumerate() {
            let row = row0 + r;
            let i = row % sh.s_q;
            let bh = row / sh.s_q;
            let (b, h) = (bh / sh.heads, bh % sh.heads);
            let qrow = &qv[(b * sh.s_q + i) * dim + h * dh..][..dh];
            let jmax = if sh.causal { i + 1 } else { sh.s_k };
            let mut m = f32::NEG_INFINITY;
            for (j, p) in prow[..jmax].iter_mut().enumerate() {
                let krow = &kv[(b * sh.s_k + j) * dim + h * dh..][..dh];
                let s: f32 = qrow.iter().zip(krow).map(|(a, c)| a * c).sum();
                *p = s * scale;
                m = m.max(*p);
            }
            let mut z = 0.0f32;
            for p in prow[..jmax].iter_mut() {
                *p = (*p - m).exp();
                z += *p;
            }
            let inv = 1.0 / z;
            for p in prow[..jmax].iter_mut() {
                *p *= inv;
            }
            for p in prow[jmax..].iter_mut() {
                *p = 0.0;
            }
        }
    };
    let rows_p = sh.batch * sh.heads * sh.s_q;
    run_rows(&mut probs, sh.s_k, rows_p * sh.s_k * dh, pk);
    // out rows are (b, i): out[b,i,h,:] = sum_j probs[b,h,i,j] v[b,j,h,:]
    let mut out = arena::alloc_zeroed(sh.batch * sh.s_q * dim);
    let ok = |row0: usize, chunk: &mut [f32]| {
        for (r, orow) in chunk.chunks_exact_mut(dim).enumerate() {
            let row = row0 + r;
            let (b, i) = (row / sh.s_q, row % sh.s_q);
            for h in 0..sh.heads {
                let prow = &probs[((b * sh.heads + h) * sh.s_q + i) * sh.s_k..][..sh.s_k];
                for (j, &p) in prow.iter().enumerate() {
                    if p == 0.0 {
                        continue;
                    }
                    let vrow = &vv[(b * sh.s_k + j) * dim + h * dh..][..dh];
                    for (o, &vj) in orow[h * dh..(h + 1) * dh].iter_mut().zip(vrow) {
                        *o += p * vj;
                    }
                }
            }
        }
    };
    run_rows(&mut out, dim, sh.batch * sh.s_q * dim * sh.s_k, ok);
    (
        Tensor::from_f32(&[sh.batch * sh.s_q, dim], out),
        Tensor::from_f32(&[rows_p, sh.s_k], probs),
    )
}

/// Backward of [`attention_fwd`] from the saved probs: returns (dq, dk, dv).
pub fn attention_bwd(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    probs: &Tensor,
    dout: &Tensor,
    sh: &AttnShape,
) -> (Tensor, Tensor, Tensor) {
    let dim = q.shape[1];
    let dh = sh.head_dim(dim);
    assert_eq!(dout.shape, q.shape, "attention dout shape");
    assert_eq!(probs.shape, vec![sh.batch * sh.heads * sh.s_q, sh.s_k]);
    let scale = 1.0 / (dh as f32).sqrt();
    let (qv, kv, vv, pv, dov) = (q.f32s(), k.f32s(), v.f32s(), probs.f32s(), dout.f32s());
    // dscores = probs .* (dp - <dp, probs>) with dp[j] = <dout[b,i,h], v[b,j,h]>;
    // the 1/sqrt(dh) scale is folded in here so dq/dk below are plain sums.
    let mut ds = arena::alloc_zeroed(pv.len());
    let dsk = |row0: usize, chunk: &mut [f32]| {
        for (r, dsrow) in chunk.chunks_exact_mut(sh.s_k).enumerate() {
            let row = row0 + r;
            let i = row % sh.s_q;
            let bh = row / sh.s_q;
            let (b, h) = (bh / sh.heads, bh % sh.heads);
            let dorow = &dov[(b * sh.s_q + i) * dim + h * dh..][..dh];
            let prow = &pv[row * sh.s_k..][..sh.s_k];
            let mut inner = 0.0f32;
            for (j, d) in dsrow.iter_mut().enumerate() {
                let vrow = &vv[(b * sh.s_k + j) * dim + h * dh..][..dh];
                let dp: f32 = dorow.iter().zip(vrow).map(|(a, c)| a * c).sum();
                *d = dp;
                inner += dp * prow[j];
            }
            for (d, &p) in dsrow.iter_mut().zip(prow) {
                *d = p * (*d - inner) * scale;
            }
        }
    };
    run_rows(&mut ds, sh.s_k, pv.len() * dh, dsk);
    // dq rows are (b, i); dk/dv rows are (b, j) — all independent.
    let mut dq = arena::alloc_zeroed(qv.len());
    let dqk = |row0: usize, chunk: &mut [f32]| {
        for (r, dqrow) in chunk.chunks_exact_mut(dim).enumerate() {
            let row = row0 + r;
            let (b, i) = (row / sh.s_q, row % sh.s_q);
            for h in 0..sh.heads {
                let dsrow = &ds[((b * sh.heads + h) * sh.s_q + i) * sh.s_k..][..sh.s_k];
                for (j, &dsj) in dsrow.iter().enumerate() {
                    if dsj == 0.0 {
                        continue;
                    }
                    let krow = &kv[(b * sh.s_k + j) * dim + h * dh..][..dh];
                    for (o, &kj) in dqrow[h * dh..(h + 1) * dh].iter_mut().zip(krow) {
                        *o += dsj * kj;
                    }
                }
            }
        }
    };
    run_rows(&mut dq, dim, qv.len() * sh.s_k, dqk);
    let mut dk = arena::alloc_zeroed(kv.len());
    let dkk = |row0: usize, chunk: &mut [f32]| {
        for (r, dkrow) in chunk.chunks_exact_mut(dim).enumerate() {
            let row = row0 + r;
            let (b, j) = (row / sh.s_k, row % sh.s_k);
            for h in 0..sh.heads {
                for i in 0..sh.s_q {
                    let dsj = ds[((b * sh.heads + h) * sh.s_q + i) * sh.s_k + j];
                    if dsj == 0.0 {
                        continue;
                    }
                    let qrow = &qv[(b * sh.s_q + i) * dim + h * dh..][..dh];
                    for (o, &qi) in dkrow[h * dh..(h + 1) * dh].iter_mut().zip(qrow) {
                        *o += dsj * qi;
                    }
                }
            }
        }
    };
    run_rows(&mut dk, dim, kv.len() * sh.s_q, dkk);
    let mut dvv = arena::alloc_zeroed(vv.len());
    let dvk = |row0: usize, chunk: &mut [f32]| {
        for (r, dvrow) in chunk.chunks_exact_mut(dim).enumerate() {
            let row = row0 + r;
            let (b, j) = (row / sh.s_k, row % sh.s_k);
            for h in 0..sh.heads {
                for i in 0..sh.s_q {
                    let p = pv[((b * sh.heads + h) * sh.s_q + i) * sh.s_k + j];
                    if p == 0.0 {
                        continue;
                    }
                    let dorow = &dov[(b * sh.s_q + i) * dim + h * dh..][..dh];
                    for (o, &doi) in dvrow[h * dh..(h + 1) * dh].iter_mut().zip(dorow) {
                        *o += p * doi;
                    }
                }
            }
        }
    };
    run_rows(&mut dvv, dim, vv.len() * sh.s_q, dvk);
    arena::recycle_buf(ds);
    (
        Tensor::from_f32(&q.shape, dq),
        Tensor::from_f32(&k.shape, dk),
        Tensor::from_f32(&v.shape, dvv),
    )
}

/// Single-query attention for incremental decode: one new query row
/// against `s_k` cached K/V rows scattered across a [`PagedRows`] view.
/// Writes softmax(q k^T / sqrt(dh)) v into `out` (dim floats); `scores` is
/// caller-provided scratch (>= s_k floats — the decode loop reuses one
/// buffer across layers and sessions, keeping this kernel allocation-free).
///
/// The arithmetic replicates [`attention_fwd`]'s last causal row exactly:
/// the same k-ascending score dots, the same running max, the same
/// `exp`/normalize passes, and the same h-outer j-ascending output
/// accumulation — so given bitwise-equal q/k/v rows, the decode output row
/// is bitwise equal to the full-sequence forward's final row.
pub fn attention_decode(
    q: &[f32],
    k: &PagedRows<'_>,
    v: &PagedRows<'_>,
    heads: usize,
    scores: &mut [f32],
    out: &mut [f32],
) {
    let dim = q.len();
    assert_eq!(dim % heads, 0, "dim {dim} not divisible by {heads} heads");
    let dh = dim / heads;
    let s_k = k.len();
    assert_eq!(v.len(), s_k, "K/V cache length mismatch");
    assert_eq!(k.dim(), dim, "attention_decode k dim");
    assert_eq!(v.dim(), dim, "attention_decode v dim");
    assert!(s_k > 0, "attention_decode over an empty cache");
    assert!(scores.len() >= s_k, "scores scratch too small");
    assert_eq!(out.len(), dim, "attention_decode out dim");
    let scale = 1.0 / (dh as f32).sqrt();
    out.fill(0.0);
    for h in 0..heads {
        let qrow = &q[h * dh..(h + 1) * dh];
        let prow = &mut scores[..s_k];
        let mut m = f32::NEG_INFINITY;
        for (j, p) in prow.iter_mut().enumerate() {
            let krow = &k.row(j)[h * dh..(h + 1) * dh];
            let s: f32 = qrow.iter().zip(krow).map(|(a, c)| a * c).sum();
            *p = s * scale;
            m = m.max(*p);
        }
        let mut z = 0.0f32;
        for p in prow.iter_mut() {
            *p = (*p - m).exp();
            z += *p;
        }
        let inv = 1.0 / z;
        for p in prow.iter_mut() {
            *p *= inv;
        }
        let orow = &mut out[h * dh..(h + 1) * dh];
        for (j, &p) in prow.iter().enumerate() {
            if p == 0.0 {
                continue;
            }
            let vrow = &v.row(j)[h * dh..(h + 1) * dh];
            for (o, &vj) in orow.iter_mut().zip(vrow) {
                *o += p * vj;
            }
        }
    }
}

/// Masked mean cross-entropy over the rows of `logits` (n, v): rows with
/// label < 0 are ignored; loss = mean over active rows of
/// (logsumexp - logit[label]). Returns (loss, active_count). Mirrors the
/// python `_masked_xent` exactly (including the max(count, 1) guard).
pub fn masked_xent_fwd(logits: &Tensor, labels: &[i32]) -> (f32, f32) {
    let (n, vsz) = (logits.shape[0], logits.shape[1]);
    assert_eq!(labels.len(), n, "one label per logit row");
    let lv = logits.f32s();
    let mut nll = arena::alloc_zeroed(n);
    let kernel = |row0: usize, chunk: &mut [f32]| {
        for (r, out) in chunk.iter_mut().enumerate() {
            let i = row0 + r;
            let lbl = labels[i];
            if lbl < 0 {
                continue;
            }
            let row = &lv[i * vsz..(i + 1) * vsz];
            let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let z: f32 = row.iter().map(|&x| (x - m).exp()).sum();
            *out = m + z.ln() - row[lbl as usize];
        }
    };
    run_rows(&mut nll, 1, n * vsz, kernel);
    let count = labels.iter().filter(|&&l| l >= 0).count() as f32;
    let loss = nll.iter().sum::<f32>() / count.max(1.0);
    arena::recycle_buf(nll);
    (loss, count)
}

/// Backward of [`masked_xent_fwd`]:
/// dlogits = dloss * (softmax - onehot) / max(count, 1) on active rows.
/// The output buffer is arena scratch: active rows are fully overwritten by
/// the softmax pass and inactive rows get one explicit zero stripe — no
/// whole-buffer zeroing pass runs first, so rows with label < 0 (~85% of an
/// MLM batch at the paper's 15% mask density) are written exactly once.
pub fn masked_xent_bwd(logits: &Tensor, labels: &[i32], count: f32, dloss: f32) -> Tensor {
    let (n, vsz) = (logits.shape[0], logits.shape[1]);
    assert_eq!(labels.len(), n, "one label per logit row");
    let lv = logits.f32s();
    let s = dloss / count.max(1.0);
    let mut dl = arena::alloc_scratch(n * vsz);
    let kernel = |row0: usize, chunk: &mut [f32]| {
        for (r, drow) in chunk.chunks_exact_mut(vsz).enumerate() {
            let i = row0 + r;
            let lbl = labels[i];
            if lbl < 0 {
                drow.fill(0.0);
                continue;
            }
            let row = &lv[i * vsz..(i + 1) * vsz];
            let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut z = 0.0f32;
            for (d, &x) in drow.iter_mut().zip(row) {
                *d = (x - m).exp();
                z += *d;
            }
            let inv = s / z;
            for d in drow.iter_mut() {
                *d *= inv;
            }
            drow[lbl as usize] -= s;
        }
    };
    run_rows(&mut dl, vsz, n * vsz, kernel);
    Tensor::from_f32(&logits.shape, dl)
}

// ---------------------------------------------------------------------------
// Streaming fused LM head: linear + masked cross-entropy over vocab tiles
// with an online log-sum-exp (FlashAttention-style rescaling) — the
// (rows, vocab) logits are never materialized, forward or backward.
// ---------------------------------------------------------------------------

/// Vocab-tile width of the streaming LM-head kernels: one tile row is 512 B
/// of f32 accumulators, so a whole [`XENT_ROW_BLOCK`]-row tile lives in L1
/// next to the streamed packed-`w^T` rows.
pub const XENT_TILE_V: usize = 128;

/// Activation rows processed together by the LM-head tile microkernel: each
/// streamed `w^T` row is loaded once and FMA'd into four independent
/// accumulator rows (the same register-blocking as the dense
/// [`matmul_rows`] path).
const XENT_ROW_BLOCK: usize = 4;

/// One logits tile on the stack: `acc[r][jj] = x[idx[r]] . w[j0 + jj] (+ b)`.
type XentTile = [[f32; XENT_TILE_V]; XENT_ROW_BLOCK];

/// Shared read-only state of one streaming LM-head call: the activation
/// rows, the packed (d-major) `w^T`, the optional bias, the head dims and
/// the per-row labels. Borrowed by every tile worker (all fields are shared
/// slices, so a `&HeadCtx` crosses the scoped-thread boundary).
struct HeadCtx<'a> {
    xv: &'a [f32],
    wt: &'a [f32],
    bv: Option<&'a [f32]>,
    d: usize,
    v: usize,
    labels: &'a [i32],
}

/// Compute the logits tile for the (up to [`XENT_ROW_BLOCK`]) activation
/// rows listed in `idx` over vocab columns `[j0, j1)`. `ctx.wt` is the
/// packed (d-major) transpose of the head weight; accumulation initializes
/// with the bias and sums ascending k — the exact per-element order of the
/// packed [`linear_fused`] path, so a streamed tile is bitwise equal to the
/// corresponding slice of materialized logits.
fn lm_head_tile(ctx: &HeadCtx<'_>, idx: &[usize], j0: usize, j1: usize, acc: &mut XentTile) {
    let (xv, wt, bv, d, v) = (ctx.xv, ctx.wt, ctx.bv, ctx.d, ctx.v);
    let tv = j1 - j0;
    for arow in acc.iter_mut().take(idx.len()) {
        match bv {
            Some(b) => arow[..tv].copy_from_slice(&b[j0..j1]),
            None => arow[..tv].fill(0.0),
        }
    }
    for kk in 0..d {
        let wrow = &wt[kk * v + j0..kk * v + j1];
        if let [i0, i1, i2, i3] = *idx {
            // register-blocked: one load of the w^T row feeds four rows
            let (x0, x1, x2, x3) = (
                xv[i0 * d + kk],
                xv[i1 * d + kk],
                xv[i2 * d + kk],
                xv[i3 * d + kk],
            );
            let (a0, rest) = acc.split_at_mut(1);
            let (a1, rest) = rest.split_at_mut(1);
            let (a2, a3) = rest.split_at_mut(1);
            let a0 = &mut a0[0][..tv];
            let a1 = &mut a1[0][..tv];
            let a2 = &mut a2[0][..tv];
            let a3 = &mut a3[0][..tv];
            for (j, &wj) in wrow.iter().enumerate() {
                a0[j] += x0 * wj;
                a1[j] += x1 * wj;
                a2[j] += x2 * wj;
                a3[j] += x3 * wj;
            }
        } else {
            for (r, &i) in idx.iter().enumerate() {
                let xik = xv[i * d + kk];
                let arow = &mut acc[r][..tv];
                for (aj, &wj) in arow.iter_mut().zip(wrow) {
                    *aj += xik * wj;
                }
            }
        }
    }
}

/// Forward over one block of active rows: stream the vocab tiles through an
/// online log-sum-exp (running max `m`, rescaled running sum `l`), catch the
/// label logit as its tile passes by, then write the per-row NLL and the
/// `[max, lse, label_logit]` stats triple (what the backward needs to
/// recompute each tile's softmax).
fn lm_head_fwd_block(
    ctx: &HeadCtx<'_>,
    idx: &[usize],
    row0: usize,
    nc: &mut [f32],
    sc: &mut [f32],
) {
    let mut acc = [[0.0f32; XENT_TILE_V]; XENT_ROW_BLOCK];
    let mut m = [f32::NEG_INFINITY; XENT_ROW_BLOCK];
    let mut l = [0.0f32; XENT_ROW_BLOCK];
    let mut lbl_logit = [0.0f32; XENT_ROW_BLOCK];
    let mut j0 = 0;
    while j0 < ctx.v {
        let j1 = (j0 + XENT_TILE_V).min(ctx.v);
        lm_head_tile(ctx, idx, j0, j1, &mut acc);
        for (r, &i) in idx.iter().enumerate() {
            let row = &acc[r][..j1 - j0];
            let tm = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let new_m = m[r].max(tm);
            let mut tl = 0.0f32;
            for &z in row {
                tl += (z - new_m).exp();
            }
            // rescale the sum accumulated under the old max, then fold the
            // tile in ((-inf).exp() == 0 makes the first tile a plain init)
            l[r] = l[r] * (m[r] - new_m).exp() + tl;
            m[r] = new_m;
            let lbl = ctx.labels[i] as usize;
            if lbl >= j0 && lbl < j1 {
                lbl_logit[r] = row[lbl - j0];
            }
        }
        j0 = j1;
    }
    for (r, &i) in idx.iter().enumerate() {
        let lse = m[r] + l[r].ln();
        nc[i - row0] = lse - lbl_logit[r];
        let srow = &mut sc[(i - row0) * 3..(i - row0) * 3 + 3];
        srow[0] = m[r];
        srow[1] = lse;
        srow[2] = lbl_logit[r];
    }
}

/// Streaming fused LM-head forward: masked mean cross-entropy of
/// `x @ w^T (+ b)` for x (n, d) against the stored-projection head w (v, d),
/// computed one vocab tile at a time — **no `(n, v)` logits buffer exists**,
/// and rows with label < 0 are skipped outright (they cost nothing, not
/// even a matmul row). Returns `(loss, active_count, stats)`; `stats` holds
/// one `[running max, logsumexp, label logit]` triple per row (zeros for
/// masked rows). The backward reads the logsumexp slot to rebuild each
/// tile's softmax; the max and label-logit slots make the row's numerics
/// auditable (`nll = lse - label_logit`) without another vocab sweep.
/// Matches
/// [`masked_xent_fwd`] over materialized logits to ≤1e-5 relative (the
/// online rescaling reassociates the softmax sum), including the
/// `max(count, 1)` all-masked guard.
pub fn lm_head_xent_fwd(
    x: &Tensor,
    w: &Tensor,
    b: Option<&Tensor>,
    labels: &[i32],
) -> (f32, f32, Vec<f32>) {
    let (n, d) = (x.shape[0], x.shape[1]);
    let (v, d2) = (w.shape[0], w.shape[1]);
    assert_eq!(d, d2, "lm_head_xent inner dims: {d} vs {d2}");
    assert_eq!(labels.len(), n, "one label per row");
    if let Some(bb) = b {
        assert_eq!(bb.numel(), v, "lm_head_xent bias dim");
    }
    let count = labels.iter().filter(|&&l| l >= 0).count() as f32;
    if n == 0 || v == 0 || count == 0.0 {
        return (0.0, count, arena::alloc_zeroed(n * 3));
    }
    let (xv, wv) = (x.f32s(), w.f32s());
    let bv = b.map(|t| t.f32s());
    let wt = pack_transposed(wv, v, d);
    let ctx = HeadCtx { xv, wt: &wt, bv, d, v, labels };
    let mut nll = arena::alloc_zeroed(n);
    let mut stats = arena::alloc_zeroed(n * 3);
    let kernel = |row0: usize, nc: &mut [f32], sc: &mut [f32]| {
        let mut idx = [0usize; XENT_ROW_BLOCK];
        let mut cnt = 0usize;
        for i in row0..row0 + nc.len() {
            let lbl = labels[i];
            if lbl < 0 {
                continue;
            }
            assert!((lbl as usize) < v, "label {lbl} outside vocab {v}");
            idx[cnt] = i;
            cnt += 1;
            if cnt == XENT_ROW_BLOCK {
                lm_head_fwd_block(&ctx, &idx, row0, nc, sc);
                cnt = 0;
            }
        }
        if cnt > 0 {
            lm_head_fwd_block(&ctx, &idx[..cnt], row0, nc, sc);
        }
    };
    if n * v * d.max(1) >= PAR_MIN_KERNEL {
        par::par_row_chunks2(&mut nll, 1, &mut stats, 3, kernel);
    } else {
        kernel(0, &mut nll, &mut stats);
    }
    let loss = nll.iter().sum::<f32>() / count.max(1.0);
    arena::recycle_buf(nll);
    arena::recycle_buf(wt);
    (loss, count, stats)
}

/// In place on a freshly computed logits tile: `acc -> s * (softmax -
/// onehot)` per row, using the forward's saved per-row logsumexp
/// (`softmax = exp(logit - lse)`).
fn tile_softmax_grad(
    acc: &mut XentTile,
    ctx: &HeadCtx<'_>,
    idx: &[usize],
    stats: &[f32],
    s: f32,
    j0: usize,
    j1: usize,
) {
    for (r, &i) in idx.iter().enumerate() {
        let lse = stats[i * 3 + 1];
        let row = &mut acc[r][..j1 - j0];
        for z in row.iter_mut() {
            *z = (*z - lse).exp() * s;
        }
        let lbl = ctx.labels[i] as usize;
        if lbl >= j0 && lbl < j1 {
            row[lbl - j0] -= s;
        }
    }
}

/// dX pass over one block of active rows: recompute each vocab tile, turn it
/// into `s * (softmax - onehot)`, and fold `sum_j p_ij * w_j` into the
/// block's dX rows (contiguous d-wide FMA streams over the w rows). `wv` is
/// the un-packed (v, d) head weight the dX axpys read.
#[allow(clippy::too_many_arguments)]
fn lm_head_dx_block(
    ctx: &HeadCtx<'_>,
    wv: &[f32],
    idx: &[usize],
    stats: &[f32],
    s: f32,
    row0: usize,
    chunk: &mut [f32],
) {
    let d = ctx.d;
    let mut acc = [[0.0f32; XENT_TILE_V]; XENT_ROW_BLOCK];
    let mut j0 = 0;
    while j0 < ctx.v {
        let j1 = (j0 + XENT_TILE_V).min(ctx.v);
        lm_head_tile(ctx, idx, j0, j1, &mut acc);
        tile_softmax_grad(&mut acc, ctx, idx, stats, s, j0, j1);
        for (r, &i) in idx.iter().enumerate() {
            let dxrow = &mut chunk[(i - row0) * d..(i - row0 + 1) * d];
            for (jj, j) in (j0..j1).enumerate() {
                let pj = acc[r][jj];
                let wrow = &wv[j * d..(j + 1) * d];
                for (o, &wq) in dxrow.iter_mut().zip(wrow) {
                    *o += pj * wq;
                }
            }
        }
        j0 = j1;
    }
}

/// dW/db pass over one block of active rows restricted to vocab columns
/// `[t0, t1)` of a worker-owned dW row chunk starting at global vocab row
/// `jr0`: recompute the tile, form `s * (softmax - onehot)`, and fold
/// `p_ij * x_i` into dW's rows and `p_ij` into db.
#[allow(clippy::too_many_arguments)]
fn lm_head_dw_block(
    ctx: &HeadCtx<'_>,
    idx: &[usize],
    stats: &[f32],
    s: f32,
    t0: usize,
    t1: usize,
    jr0: usize,
    dwc: &mut [f32],
    dbc: &mut [f32],
) {
    let d = ctx.d;
    let mut acc = [[0.0f32; XENT_TILE_V]; XENT_ROW_BLOCK];
    lm_head_tile(ctx, idx, t0, t1, &mut acc);
    tile_softmax_grad(&mut acc, ctx, idx, stats, s, t0, t1);
    for (jj, j) in (t0..t1).enumerate() {
        let dwrow = &mut dwc[(j - jr0) * d..(j - jr0 + 1) * d];
        let mut dbj = 0.0f32;
        for (r, &i) in idx.iter().enumerate() {
            let pj = acc[r][jj];
            dbj += pj;
            let xrow = &ctx.xv[i * d..(i + 1) * d];
            for (o, &xq) in dwrow.iter_mut().zip(xrow) {
                *o += pj * xq;
            }
        }
        dbc[j - jr0] += dbj;
    }
}

/// Streaming backward of [`lm_head_xent_fwd`] from the saved per-row stats:
/// each vocab tile's logits are **recomputed** from x and w, converted in
/// place to `s * (softmax - onehot)` (s = dloss / max(count, 1)), and
/// accumulated straight into the outputs — `dlogits` is never materialized.
/// Returns `(dx, dw, db)` with `db = None` when no bias is given (the
/// bias then also doesn't enter the recomputed logits). Two row-parallel
/// passes keep the serial/parallel bit-identity guarantee: dX partitions
/// over activation rows, dW/db over vocab rows, and every output element's
/// accumulation order is independent of the partitioning.
pub fn lm_head_xent_bwd(
    x: &Tensor,
    w: &Tensor,
    b: Option<&Tensor>,
    labels: &[i32],
    stats: &[f32],
    count: f32,
    dloss: f32,
) -> (Tensor, Tensor, Option<Tensor>) {
    let (n, d) = (x.shape[0], x.shape[1]);
    let (v, d2) = (w.shape[0], w.shape[1]);
    assert_eq!(d, d2, "lm_head_xent inner dims: {d} vs {d2}");
    assert_eq!(labels.len(), n, "one label per row");
    assert_eq!(stats.len(), n * 3, "lm_head_xent stats length");
    let mut dx = Tensor::from_f32(&x.shape, arena::alloc_zeroed(n * d));
    let mut dw = Tensor::from_f32(&w.shape, arena::alloc_zeroed(v * d));
    let mut db = b.map(|t| Tensor::from_f32(&t.shape, arena::alloc_zeroed(v)));
    if n == 0 || v == 0 || count == 0.0 {
        return (dx, dw, db);
    }
    let (xv, wv) = (x.f32s(), w.f32s());
    let bv = b.map(|t| t.f32s());
    let s = dloss / count.max(1.0);
    let wt = pack_transposed(wv, v, d);
    let ctx = HeadCtx { xv, wt: &wt, bv, d, v, labels };
    let parallel = n * v * d.max(1) >= PAR_MIN_KERNEL;
    // pass A: dX, partitioned over activation rows
    {
        let kernel = |row0: usize, chunk: &mut [f32]| {
            let mut idx = [0usize; XENT_ROW_BLOCK];
            let mut cnt = 0usize;
            for i in row0..row0 + chunk.len() / d {
                if labels[i] < 0 {
                    continue;
                }
                idx[cnt] = i;
                cnt += 1;
                if cnt == XENT_ROW_BLOCK {
                    lm_head_dx_block(&ctx, wv, &idx, stats, s, row0, chunk);
                    cnt = 0;
                }
            }
            if cnt > 0 {
                lm_head_dx_block(&ctx, wv, &idx[..cnt], stats, s, row0, chunk);
            }
        };
        if parallel {
            par::par_row_chunks(dx.f32s_mut(), d, kernel);
        } else {
            kernel(0, dx.f32s_mut());
        }
    }
    // pass B: dW and db, partitioned over vocab rows; every worker streams
    // all activation rows through its own slice of the vocab
    {
        let kernel = |jr0: usize, dwc: &mut [f32], dbc: &mut [f32]| {
            let jend = jr0 + dwc.len() / d;
            let mut t0 = jr0;
            while t0 < jend {
                let t1 = (t0 + XENT_TILE_V).min(jend);
                let mut idx = [0usize; XENT_ROW_BLOCK];
                let mut cnt = 0usize;
                for i in 0..n {
                    if labels[i] < 0 {
                        continue;
                    }
                    idx[cnt] = i;
                    cnt += 1;
                    if cnt == XENT_ROW_BLOCK {
                        lm_head_dw_block(&ctx, &idx, stats, s, t0, t1, jr0, dwc, dbc);
                        cnt = 0;
                    }
                }
                if cnt > 0 {
                    lm_head_dw_block(&ctx, &idx[..cnt], stats, s, t0, t1, jr0, dwc, dbc);
                }
                t0 = t1;
            }
        };
        // db is one column; when there is no bias a scratch column absorbs
        // the (unused) sums so both shapes share one kernel
        let mut scratch_db = match &db {
            Some(_) => Vec::new(),
            None => arena::alloc_zeroed(v),
        };
        let dbs: &mut [f32] = match &mut db {
            Some(t) => t.f32s_mut(),
            None => &mut scratch_db[..],
        };
        if parallel {
            par::par_row_chunks2(dw.f32s_mut(), d, dbs, 1, kernel);
        } else {
            kernel(0, dw.f32s_mut(), dbs);
        }
        arena::recycle_buf(scratch_db);
    }
    arena::recycle_buf(wt);
    (dx, dw, db)
}

/// Row-wise argmax of `x @ w^T (+ b)` computed over vocab tiles — the
/// eval-side companion of [`lm_head_xent_fwd`] (classification accuracy of
/// a large-vocab head without a `(rows, vocab)` buffer). Tie-breaking
/// matches [`argmax_rows`] over materialized logits: the first maximal
/// column wins, and the streamed tiles are bitwise equal to the packed
/// [`linear_fused`] logits, so the winners agree exactly. Deliberately
/// serial: every caller passes batch-sized row counts (probe/vision
/// classifier metrics), where thread spawn/join would dominate.
pub fn lm_head_argmax(x: &Tensor, w: &Tensor, b: Option<&Tensor>) -> Vec<usize> {
    let (n, d) = (x.shape[0], x.shape[1]);
    let (v, d2) = (w.shape[0], w.shape[1]);
    assert_eq!(d, d2, "lm_head_argmax inner dims: {d} vs {d2}");
    if let Some(bb) = b {
        assert_eq!(bb.numel(), v, "lm_head_argmax bias dim");
    }
    // lint:allow(fresh_alloc) usize result buffer — the pool is f32-only
    let mut best = vec![0usize; n];
    if n == 0 || v == 0 {
        return best;
    }
    let (xv, wv) = (x.f32s(), w.f32s());
    let bv = b.map(|t| t.f32s());
    let wt = pack_transposed(wv, v, d);
    let ctx = HeadCtx { xv, wt: &wt, bv, d, v, labels: &[] };
    let mut acc = [[0.0f32; XENT_TILE_V]; XENT_ROW_BLOCK];
    let mut best_val = [f32::NEG_INFINITY; XENT_ROW_BLOCK];
    let mut idxbuf = [0usize; XENT_ROW_BLOCK];
    let mut i0 = 0;
    while i0 < n {
        let i1 = (i0 + XENT_ROW_BLOCK).min(n);
        for (r, i) in (i0..i1).enumerate() {
            idxbuf[r] = i;
        }
        let idx = &idxbuf[..i1 - i0];
        for bvl in best_val[..idx.len()].iter_mut() {
            *bvl = f32::NEG_INFINITY;
        }
        let mut j0 = 0;
        while j0 < v {
            let j1 = (j0 + XENT_TILE_V).min(v);
            lm_head_tile(&ctx, idx, j0, j1, &mut acc);
            for (r, &i) in idx.iter().enumerate() {
                for (jj, &z) in acc[r][..j1 - j0].iter().enumerate() {
                    if z > best_val[r] {
                        best_val[r] = z;
                        best[i] = j0 + jj;
                    }
                }
            }
            j0 = j1;
        }
        i0 = i1;
    }
    arena::recycle_buf(wt);
    best
}

/// Per-row sampling spec for [`lm_head_sample`]: keep the `top_k` highest
/// logits (clamped to [`SAMPLE_MAX_TOPK`]), restrict to the smallest
/// descending-probability prefix whose cumulative softmax mass reaches
/// `top_p`, then pick via the uniform draw `u` in [0, 1). `top_k = 1`
/// is greedy decoding regardless of `top_p`/`u`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SampleSpec {
    pub top_k: usize,
    pub top_p: f32,
    pub u: f32,
}

impl SampleSpec {
    /// Greedy (argmax) decoding.
    pub fn greedy() -> SampleSpec {
        SampleSpec { top_k: 1, top_p: 1.0, u: 0.0 }
    }
}

/// Candidate-list capacity of [`lm_head_sample`]: top-k requests are
/// clamped here so the per-row state stays a fixed stack array inside the
/// streaming tile loop.
pub const SAMPLE_MAX_TOPK: usize = 64;

/// Streamed per-row top-k candidates + online logsumexp for one row block.
struct SampleRow {
    vals: [f32; SAMPLE_MAX_TOPK],
    ids: [usize; SAMPLE_MAX_TOPK],
    cnt: usize,
    keep: usize,
    m: f32,
    l: f32,
}

impl SampleRow {
    fn new(keep: usize) -> SampleRow {
        SampleRow {
            vals: [f32::NEG_INFINITY; SAMPLE_MAX_TOPK],
            ids: [0; SAMPLE_MAX_TOPK],
            cnt: 0,
            keep,
            m: f32::NEG_INFINITY,
            l: 0.0,
        }
    }

    /// Fold one logits tile in: update the online LSE (exactly the
    /// [`lm_head_fwd_block`] recurrence) and merge the tile's entries into
    /// the descending candidate list. Strict `>` on insertion keeps the
    /// earliest column on ties — the [`lm_head_argmax`] tie-break, so
    /// `top_k = 1` reproduces argmax exactly.
    fn fold_tile(&mut self, row: &[f32], j0: usize) {
        let tm = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let new_m = self.m.max(tm);
        let mut tl = 0.0f32;
        for &z in row {
            tl += (z - new_m).exp();
        }
        self.l = self.l * (self.m - new_m).exp() + tl;
        self.m = new_m;
        for (jj, &z) in row.iter().enumerate() {
            if self.cnt == self.keep && z <= self.vals[self.cnt - 1] {
                continue;
            }
            let mut pos = self.cnt.min(self.keep - 1);
            while pos > 0 && z > self.vals[pos - 1] {
                self.vals[pos] = self.vals[pos - 1];
                self.ids[pos] = self.ids[pos - 1];
                pos -= 1;
            }
            self.vals[pos] = z;
            self.ids[pos] = j0 + jj;
            self.cnt = (self.cnt + 1).min(self.keep);
        }
    }

    /// Nucleus-restricted categorical draw over the surviving candidates.
    fn pick(&self, top_p: f32, u: f32) -> usize {
        let lse = self.m + self.l.ln();
        // smallest descending prefix with cumulative full-vocab softmax
        // mass >= top_p (every candidate when the kept mass falls short)
        let mut take = self.cnt;
        let mut cum = 0.0f32;
        for (c, &z) in self.vals[..self.cnt].iter().enumerate() {
            cum += (z - lse).exp();
            if cum >= top_p {
                take = c + 1;
                break;
            }
        }
        let mass: f32 = self.vals[..take].iter().map(|&z| (z - lse).exp()).sum();
        let target = u * mass;
        let mut acc = 0.0f32;
        for (&id, &z) in self.ids[..take].iter().zip(&self.vals[..take]) {
            acc += (z - lse).exp();
            if target < acc {
                return id;
            }
        }
        self.ids[take - 1] // float exhaustion: last survivor
    }
}

/// Streaming top-k/top-p sampling over `x @ w^T (+ b)` — the decode-side
/// companion of [`lm_head_argmax`]: one vocab-tile pass keeps, per row, the
/// top-k logits and an online logsumexp, so the `(rows, vocab)` logits are
/// never materialized and the softmax normalizer is exact over the *full*
/// vocabulary (truncation only restricts which candidates may be drawn,
/// not their probabilities). Tiles are bitwise equal to the packed
/// [`linear_fused`] logits; with `top_k = 1` the result is exactly
/// [`lm_head_argmax`]. Serial like argmax: callers pass batch-sized row
/// counts.
pub fn lm_head_sample(
    x: &Tensor,
    w: &Tensor,
    b: Option<&Tensor>,
    specs: &[SampleSpec],
) -> Vec<usize> {
    let (n, d) = (x.shape[0], x.shape[1]);
    let (v, d2) = (w.shape[0], w.shape[1]);
    assert_eq!(d, d2, "lm_head_sample inner dims: {d} vs {d2}");
    assert_eq!(specs.len(), n, "one sampling spec per row");
    if let Some(bb) = b {
        assert_eq!(bb.numel(), v, "lm_head_sample bias dim");
    }
    // lint:allow(fresh_alloc) usize result buffer — the pool is f32-only
    let mut out = vec![0usize; n];
    if n == 0 || v == 0 {
        return out;
    }
    let (xv, wv) = (x.f32s(), w.f32s());
    let bv = b.map(|t| t.f32s());
    let wt = pack_transposed(wv, v, d);
    let ctx = HeadCtx { xv, wt: &wt, bv, d, v, labels: &[] };
    let mut acc = [[0.0f32; XENT_TILE_V]; XENT_ROW_BLOCK];
    let mut idxbuf = [0usize; XENT_ROW_BLOCK];
    let mut i0 = 0;
    while i0 < n {
        let i1 = (i0 + XENT_ROW_BLOCK).min(n);
        for (r, i) in (i0..i1).enumerate() {
            idxbuf[r] = i;
        }
        let idx = &idxbuf[..i1 - i0];
        let mut rows: [SampleRow; XENT_ROW_BLOCK] = std::array::from_fn(|r| {
            let keep = if i0 + r < n {
                specs[i0 + r].top_k.clamp(1, SAMPLE_MAX_TOPK).min(v)
            } else {
                1
            };
            SampleRow::new(keep)
        });
        let mut j0 = 0;
        while j0 < v {
            let j1 = (j0 + XENT_TILE_V).min(v);
            lm_head_tile(&ctx, idx, j0, j1, &mut acc);
            for (r, row) in rows[..idx.len()].iter_mut().enumerate() {
                row.fold_tile(&acc[r][..j1 - j0], j0);
            }
            j0 = j1;
        }
        for (r, &i) in idx.iter().enumerate() {
            let p = specs[i].top_p.clamp(f32::MIN_POSITIVE, 1.0);
            out[i] = rows[r].pick(p, specs[i].u);
        }
        i0 = i1;
    }
    arena::recycle_buf(wt);
    out
}

/// Row-wise argmax of a 2-D tensor (classification-metric helper).
pub fn argmax_rows(x: &Tensor) -> Vec<usize> {
    let (n, d) = (x.shape[0], x.shape[1]);
    let xv = x.f32s();
    (0..n)
        .map(|i| {
            let row = &xv[i * d..(i + 1) * d];
            let mut best = 0;
            for (j, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = j;
                }
            }
            best
        })
        .collect()
}

/// Max absolute difference between two tensors (test helper).
pub fn max_abs_diff(a: &Tensor, b: &Tensor) -> f32 {
    assert_eq!(a.shape, b.shape);
    a.f32s()
        .iter()
        .zip(b.f32s())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn t2(shape: [usize; 2], v: Vec<f32>) -> Tensor {
        Tensor::from_f32(&shape, v)
    }

    #[test]
    fn matmul_hand_case() {
        let a = t2([2, 2], vec![1., 2., 3., 4.]);
        let b = t2([2, 2], vec![5., 6., 7., 8.]);
        let c = matmul(&a, &b);
        assert_eq!(c.f32s(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn matmul_identity() {
        let a = t2([2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let eye3 = eye(3);
        assert_eq!(matmul(&a, &eye3).f32s(), a.f32s());
    }

    #[test]
    fn matmul_zero_skip_propagates_nan_and_inf() {
        // Regression: the aik == 0 fast path used to drop 0 * NaN / 0 * Inf
        // from the right operand; IEEE 754 requires NaN.
        let a = t2([1, 2], vec![0.0, 1.0]);
        let b_nan = t2([2, 1], vec![f32::NAN, 2.0]);
        assert!(matmul(&a, &b_nan).f32s()[0].is_nan(), "0 * NaN must stay NaN");
        let b_inf = t2([2, 1], vec![f32::INFINITY, 2.0]);
        assert!(matmul(&a, &b_inf).f32s()[0].is_nan(), "0 * Inf must stay NaN");
        let b_ninf = t2([2, 1], vec![f32::NEG_INFINITY, 2.0]);
        assert!(matmul(&a, &b_ninf).f32s()[0].is_nan());
    }

    #[test]
    fn matmul_zero_skip_fast_path_still_exact() {
        // With a finite right operand the skip path must change nothing.
        let a = t2([2, 3], vec![0.0, 1.0, 0.0, 2.0, 0.0, -1.0]);
        let b = t2([3, 2], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(matmul(&a, &b).f32s(), &[3.0, 4.0, -3.0, -2.0]);
    }

    #[test]
    fn matmul_nan_in_left_operand_propagates() {
        let a = t2([1, 2], vec![f32::NAN, 0.0]);
        let b = t2([2, 1], vec![1.0, 1.0]);
        assert!(matmul(&a, &b).f32s()[0].is_nan());
    }

    #[test]
    fn matmul_nt_matches_explicit_transpose() {
        prop::check("X Y^T = X @ transpose(Y)", 20, |g| {
            let m = g.usize_in(1, 10);
            let k = g.usize_in(1, 8);
            let n = g.usize_in(1, 10);
            let x = t2([m, k], g.vec_f32(m * k, -2.0, 2.0));
            let y = t2([n, k], g.vec_f32(n * k, -2.0, 2.0));
            let got = matmul_nt(&x, &y);
            let want = matmul(&x, &transpose(&y));
            assert!(max_abs_diff(&got, &want) < 1e-4);
        });
    }

    #[test]
    fn matmul_nt_packed_path_matches_dot_form() {
        // 32*40*24 = 30720 MACs > NT_PACK_MIN_MACS: exercises the packed
        // i-k-j kernel against the naive transpose composition.
        let (m, k, n) = (32, 40, 24);
        assert!(m * k * n >= NT_PACK_MIN_MACS);
        let mut g = crate::util::rng::Rng::new(31);
        let x = t2([m, k], (0..m * k).map(|_| g.range_f32(-1.0, 1.0)).collect());
        let y = t2([n, k], (0..n * k).map(|_| g.range_f32(-1.0, 1.0)).collect());
        let got = matmul_nt(&x, &y);
        let want = matmul(&x, &transpose(&y));
        // same sums in a reassociated order: tight but not bitwise
        assert!(max_abs_diff(&got, &want) < 1e-4, "{}", max_abs_diff(&got, &want));
    }

    #[test]
    fn matmul_nt_packed_path_propagates_nan() {
        let (m, k, n) = (32, 40, 24);
        let mut g = crate::util::rng::Rng::new(32);
        let x = t2([m, k], (0..m * k).map(|_| g.range_f32(-1.0, 1.0)).collect());
        let mut y = t2([n, k], vec![0.0; n * k]);
        y.f32s_mut()[5] = f32::NAN;
        let c = matmul_nt(&x, &y);
        assert!(c.f32s().iter().any(|v| v.is_nan()), "NaN must survive the packed kernel");
    }

    #[test]
    fn linear_fused_matches_unfused_composition() {
        // (7, 10, 5): below NT_PACK_MIN_MACS — the direct-dot path, which
        // is bitwise-equal to the unfused chain. (32, 40, 24): above it —
        // the packed microkernel, equal up to reassociation (≤1e-5 rel).
        for (m, k, n, seed) in [(7usize, 10usize, 5usize, 33u64), (32, 40, 24, 34)] {
            let mut g = crate::util::rng::Rng::new(seed);
            let x = t2([m, k], (0..m * k).map(|_| g.range_f32(-2.0, 2.0)).collect());
            let w = t2([n, k], (0..n * k).map(|_| g.range_f32(-1.0, 1.0)).collect());
            let b = Tensor::from_f32(&[n], (0..n).map(|_| g.range_f32(-0.5, 0.5)).collect());
            // reference: matmul_nt + broadcast add + gelu
            let mut want_pre = matmul_nt(&x, &w);
            for row in want_pre.f32s_mut().chunks_exact_mut(n) {
                for (o, &bb) in row.iter_mut().zip(b.f32s()) {
                    *o += bb;
                }
            }
            let want = gelu_fwd(&want_pre);
            let (got, pre) = linear_fused(&x, &w, Some(&b), Act::Gelu);
            let pre = pre.expect("GELU saves the pre-activation");
            for (a, e) in got.f32s().iter().zip(want.f32s()) {
                let rel = (a - e).abs() / a.abs().max(e.abs()).max(1.0);
                assert!(rel <= 1e-5, "fused {a} vs unfused {e} ({m}x{k}x{n})");
            }
            for (a, e) in pre.f32s().iter().zip(want_pre.f32s()) {
                let rel = (a - e).abs() / a.abs().max(e.abs()).max(1.0);
                assert!(rel <= 1e-5, "pre {a} vs {e} ({m}x{k}x{n})");
            }
            // no bias, no activation: plain projection parity
            let (plain, none) = linear_fused(&x, &w, None, Act::None);
            assert!(none.is_none());
            assert!(max_abs_diff(&plain, &matmul_nt(&x, &w)) <= 1e-4);
        }
    }

    #[test]
    fn linear_fused_degenerate_shapes() {
        // zero rows and k = 0 must not panic and must keep the bias
        let x0 = t2([0, 3], vec![]);
        let w = t2([2, 3], vec![1.0; 6]);
        let (y, pre) = linear_fused(&x0, &w, None, Act::Gelu);
        assert_eq!(y.shape, vec![0, 2]);
        assert_eq!(pre.unwrap().shape, vec![0, 2]);
        let xk0 = t2([2, 0], vec![]);
        let wk0 = t2([3, 0], vec![]);
        let b = Tensor::from_f32(&[3], vec![1.0, 2.0, 3.0]);
        let (y2, _) = linear_fused(&xk0, &wk0, Some(&b), Act::None);
        assert_eq!(y2.f32s(), &[1.0, 2.0, 3.0, 1.0, 2.0, 3.0], "k=0 output is the bias");
    }

    #[test]
    fn fused_override_toggles_and_restores() {
        set_fused_override(Some(false));
        assert!(!fused_enabled());
        set_fused_override(Some(true));
        assert!(fused_enabled());
        set_fused_override(None);
    }

    #[test]
    fn parallel_matmul_matches_naive_above_threshold() {
        // 160^3 = 4.1M MACs > PAR_MIN_MACS: exercises the threaded path.
        let n = 160;
        assert!(n * n * n >= PAR_MIN_MACS);
        let mut g = crate::util::rng::Rng::new(11);
        let a = t2([n, n], (0..n * n).map(|_| g.range_f32(-1.0, 1.0)).collect());
        let b = t2([n, n], (0..n * n).map(|_| g.range_f32(-1.0, 1.0)).collect());
        let c = matmul(&a, &b);
        // serial reference on a sampled set of entries
        for (i, j) in [(0, 0), (1, 77), (80, 3), (159, 159), (42, 101)] {
            let want: f32 = (0..n).map(|x| a.at2(i, x) * b.at2(x, j)).sum();
            assert!((c.at2(i, j) - want).abs() < 1e-3, "({i},{j})");
        }
    }

    #[test]
    fn transpose_involution() {
        prop::check("transpose^2 = id", 25, |g| {
            let m = g.usize_in(1, 12);
            let n = g.usize_in(1, 12);
            let a = t2([m, n], g.vec_f32(m * n, -2.0, 2.0));
            assert_eq!(transpose(&transpose(&a)), a);
        });
    }

    #[test]
    fn eye_is_identity_for_matmul_nt() {
        let a = t2([2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(matmul_nt(&a, &eye(3)).f32s(), a.f32s());
    }

    #[test]
    fn expand_matches_naive_triple() {
        prop::check("expand = B W A^T", 20, |g| {
            let m = g.usize_in(1, 10);
            let k = g.usize_in(1, 8);
            let n = g.usize_in(1, 8);
            let p = g.usize_in(1, 10);
            let b = t2([m, k], g.vec_f32(m * k, -1.0, 1.0));
            let w = t2([k, n], g.vec_f32(k * n, -1.0, 1.0));
            let a = t2([p, n], g.vec_f32(p * n, -1.0, 1.0));
            let got = expand(&b, &w, &a);
            // naive reference
            let mut want = vec![0.0f32; m * p];
            for i in 0..m {
                for j in 0..p {
                    let mut s = 0.0;
                    for x in 0..k {
                        for y in 0..n {
                            s += b.at2(i, x) * w.at2(x, y) * a.at2(j, y);
                        }
                    }
                    want[i * p + j] = s;
                }
            }
            assert!(max_abs_diff(&got, &t2([m, p], want)) < 1e-4);
        });
    }

    #[test]
    fn weighted_sum_linear() {
        let a = t2([1, 2], vec![1., 2.]);
        let b = t2([1, 2], vec![10., 20.]);
        let s = weighted_sum(&[0.5, 0.25], &[&a, &b]);
        assert_eq!(s.f32s(), &[3.0, 6.0]);
    }

    #[test]
    fn matmul_associativity_prop() {
        prop::check("(AB)C = A(BC)", 10, |g| {
            let m = g.usize_in(1, 6);
            let k = g.usize_in(1, 6);
            let n = g.usize_in(1, 6);
            let p = g.usize_in(1, 6);
            let a = t2([m, k], g.vec_f32(m * k, -1.0, 1.0));
            let b = t2([k, n], g.vec_f32(k * n, -1.0, 1.0));
            let c = t2([n, p], g.vec_f32(n * p, -1.0, 1.0));
            let lhs = matmul(&matmul(&a, &b), &c);
            let rhs = matmul(&a, &matmul(&b, &c));
            assert!(max_abs_diff(&lhs, &rhs) < 1e-3);
        });
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = t2([2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let x = Tensor::from_f32(&[3], vec![1., 0., -1.]);
        assert_eq!(matvec(&a, &x).f32s(), &[-2.0, -2.0]);
    }

    #[test]
    fn dot_matches_manual() {
        let a = t2([2, 2], vec![1., 2., 3., 4.]);
        let b = t2([2, 2], vec![5., 6., 7., 8.]);
        assert_eq!(dot(&a, &b), 5.0 + 12.0 + 21.0 + 32.0);
    }

    // ---- finite-difference checks for the NN kernels ----------------------

    /// |a - b| relative to max(|a|, |b|, 1): the ≤1e-3 FD criterion with a
    /// unit floor so near-zero gradients compare absolutely.
    fn rel_err(a: f32, b: f32) -> f32 {
        (a - b).abs() / a.abs().max(b.abs()).max(1.0)
    }

    /// Central-difference derivative of `f` w.r.t. entry `i` of `x`.
    fn fd_entry(x: &Tensor, i: usize, eps: f32, mut f: impl FnMut(&Tensor) -> f32) -> f32 {
        let mut xp = x.clone();
        xp.f32s_mut()[i] += eps;
        let lp = f(&xp);
        let mut xm = x.clone();
        xm.f32s_mut()[i] -= eps;
        let lm = f(&xm);
        (lp - lm) / (2.0 * eps)
    }

    /// Weighted-sum objective L = <w, y>: turns a tensor-valued kernel into
    /// a scalar whose backward seed is exactly `w` (accumulated in f64 so
    /// the FD signal is not drowned by summation noise).
    fn obj(w: &Tensor, y: &Tensor) -> f32 {
        w.f32s()
            .iter()
            .zip(y.f32s())
            .map(|(a, b)| *a as f64 * *b as f64)
            .sum::<f64>() as f32
    }

    fn rand_t(shape: &[usize], lo: f32, hi: f32, rng: &mut crate::util::rng::Rng) -> Tensor {
        let n = crate::tensor::numel(shape);
        Tensor::from_f32(shape, (0..n).map(|_| rng.range_f32(lo, hi)).collect())
    }

    #[test]
    fn layernorm_fd_gradients() {
        let mut rng = crate::util::rng::Rng::new(42);
        let (n, d) = (4, 6);
        let x = rand_t(&[n, d], -1.5, 1.5, &mut rng);
        let g = rand_t(&[d], 0.5, 1.5, &mut rng);
        let b = rand_t(&[d], -0.5, 0.5, &mut rng);
        let w = rand_t(&[n, d], -1.0, 1.0, &mut rng);
        let (_y, stats) = layernorm_fwd(&x, &g, &b);
        let (dx, dg, db) = layernorm_bwd(&x, &g, &stats, &w);
        let eps = 1e-2;
        for i in 0..n * d {
            let fd = fd_entry(&x, i, eps, |xx| obj(&w, &layernorm_fwd(xx, &g, &b).0));
            assert!(rel_err(dx.f32s()[i], fd) < 1e-3, "dx[{i}]: {} vs {fd}", dx.f32s()[i]);
        }
        for i in 0..d {
            let fdg = fd_entry(&g, i, eps, |gg| obj(&w, &layernorm_fwd(&x, gg, &b).0));
            assert!(rel_err(dg.f32s()[i], fdg) < 1e-3, "dg[{i}]: {} vs {fdg}", dg.f32s()[i]);
            let fdb = fd_entry(&b, i, eps, |bb| obj(&w, &layernorm_fwd(&x, &g, bb).0));
            assert!(rel_err(db.f32s()[i], fdb) < 1e-3, "db[{i}]: {} vs {fdb}", db.f32s()[i]);
        }
    }

    #[test]
    fn gelu_fd_gradient_and_known_values() {
        assert_eq!(gelu_fwd(&t2([1, 1], vec![0.0])).f32s()[0], 0.0);
        // gelu(x) -> x for large x, -> 0 for very negative x
        assert!((gelu_fwd(&t2([1, 1], vec![5.0])).f32s()[0] - 5.0).abs() < 1e-3);
        assert!(gelu_fwd(&t2([1, 1], vec![-5.0])).f32s()[0].abs() < 1e-3);
        let mut rng = crate::util::rng::Rng::new(7);
        let x = rand_t(&[3, 5], -2.0, 2.0, &mut rng);
        let w = rand_t(&[3, 5], -1.0, 1.0, &mut rng);
        let dx = gelu_bwd(&x, &w);
        for i in 0..x.numel() {
            let fd = fd_entry(&x, i, 1e-2, |xx| obj(&w, &gelu_fwd(xx)));
            assert!(rel_err(dx.f32s()[i], fd) < 1e-3, "dx[{i}]: {} vs {fd}", dx.f32s()[i]);
        }
    }

    #[test]
    fn softmax_rows_sum_to_one_and_order() {
        let x = t2([2, 3], vec![1.0, 2.0, 3.0, -1.0, -1.0, -1.0]);
        let y = softmax_rows(&x);
        for r in 0..2 {
            let s: f32 = (0..3).map(|c| y.at2(r, c)).sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!(y.at2(0, 2) > y.at2(0, 1) && y.at2(0, 1) > y.at2(0, 0));
        assert!((y.at2(1, 0) - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn attention_uniform_query_averages_values() {
        // q = 0 -> uniform probs -> out = mean of v rows (per batch element).
        let sh = AttnShape { batch: 1, heads: 1, s_q: 2, s_k: 3, causal: false };
        let q = Tensor::zeros(&[2, 2]);
        let mut rng = crate::util::rng::Rng::new(3);
        let k = rand_t(&[3, 2], -1.0, 1.0, &mut rng);
        let v = t2([3, 2], vec![3.0, 0.0, 0.0, 3.0, 3.0, 3.0]);
        let (out, probs) = attention_fwd(&q, &k, &v, &sh);
        for r in 0..2 {
            assert!((out.at2(r, 0) - 2.0).abs() < 1e-5);
            assert!((out.at2(r, 1) - 2.0).abs() < 1e-5);
        }
        for r in 0..2 {
            for c in 0..3 {
                assert!((probs.at2(r, c) - 1.0 / 3.0).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn causal_first_position_attends_only_to_itself() {
        let sh = AttnShape { batch: 2, heads: 2, s_q: 3, s_k: 3, causal: true };
        let mut rng = crate::util::rng::Rng::new(11);
        let q = rand_t(&[6, 4], -1.0, 1.0, &mut rng);
        let k = rand_t(&[6, 4], -1.0, 1.0, &mut rng);
        let v = rand_t(&[6, 4], -1.0, 1.0, &mut rng);
        let (out, probs) = attention_fwd(&q, &k, &v, &sh);
        // probs rows for i = 0 are one-hot on j = 0
        for bh in 0..4 {
            assert_eq!(probs.at2(bh * 3, 0), 1.0);
            assert_eq!(probs.at2(bh * 3, 1), 0.0);
        }
        // out at position 0 equals v at position 0 for each batch element
        for b in 0..2 {
            for c in 0..4 {
                assert!((out.at2(b * 3, c) - v.at2(b * 3, c)).abs() < 1e-6);
            }
        }
    }

    fn attn_fd_case(sh: AttnShape, dim: usize, seed: u64) {
        let mut rng = crate::util::rng::Rng::new(seed);
        let q = rand_t(&[sh.batch * sh.s_q, dim], -1.0, 1.0, &mut rng);
        let k = rand_t(&[sh.batch * sh.s_k, dim], -1.0, 1.0, &mut rng);
        let v = rand_t(&[sh.batch * sh.s_k, dim], -1.0, 1.0, &mut rng);
        let w = rand_t(&[sh.batch * sh.s_q, dim], -1.0, 1.0, &mut rng);
        let (_out, probs) = attention_fwd(&q, &k, &v, &sh);
        let (dq, dk, dv) = attention_bwd(&q, &k, &v, &probs, &w, &sh);
        let eps = 1e-2;
        for i in 0..q.numel() {
            let fd = fd_entry(&q, i, eps, |t| obj(&w, &attention_fwd(t, &k, &v, &sh).0));
            assert!(rel_err(dq.f32s()[i], fd) < 1e-3, "dq[{i}]: {} vs {fd}", dq.f32s()[i]);
        }
        for i in 0..k.numel() {
            let fd = fd_entry(&k, i, eps, |t| obj(&w, &attention_fwd(&q, t, &v, &sh).0));
            assert!(rel_err(dk.f32s()[i], fd) < 1e-3, "dk[{i}]: {} vs {fd}", dk.f32s()[i]);
            let fdv = fd_entry(&v, i, eps, |t| obj(&w, &attention_fwd(&q, &k, t, &sh).0));
            assert!(rel_err(dv.f32s()[i], fdv) < 1e-3, "dv[{i}]: {} vs {fdv}", dv.f32s()[i]);
        }
    }

    #[test]
    fn attention_fd_gradients_bidirectional() {
        attn_fd_case(AttnShape { batch: 2, heads: 2, s_q: 3, s_k: 3, causal: false }, 4, 21);
    }

    #[test]
    fn attention_fd_gradients_causal() {
        attn_fd_case(AttnShape { batch: 2, heads: 2, s_q: 3, s_k: 3, causal: true }, 4, 22);
    }

    #[test]
    fn attention_fd_gradients_cross_class_attention_shape() {
        // CaiT class-attention: one query over s_k = 4 keys.
        attn_fd_case(AttnShape { batch: 2, heads: 2, s_q: 1, s_k: 4, causal: false }, 4, 23);
    }

    #[test]
    fn masked_xent_uniform_logits_is_log_v() {
        let logits = Tensor::zeros(&[3, 8]);
        let (loss, count) = masked_xent_fwd(&logits, &[1, -1, 5]);
        assert_eq!(count, 2.0);
        assert!((loss - (8.0f32).ln()).abs() < 1e-5, "{loss}");
        // all-masked: loss 0, no NaN (the max(count,1) guard)
        let (l0, c0) = masked_xent_fwd(&logits, &[-1, -1, -1]);
        assert_eq!(c0, 0.0);
        assert_eq!(l0, 0.0);
    }

    #[test]
    fn masked_xent_fd_gradient() {
        let mut rng = crate::util::rng::Rng::new(9);
        let logits = rand_t(&[5, 7], -2.0, 2.0, &mut rng);
        let labels = [2i32, -1, 0, 6, -1];
        let (_l, count) = masked_xent_fwd(&logits, &labels);
        let dl = masked_xent_bwd(&logits, &labels, count, 1.0);
        for i in 0..logits.numel() {
            let fd = fd_entry(&logits, i, 1e-2, |t| masked_xent_fwd(t, &labels).0);
            assert!(rel_err(dl.f32s()[i], fd) < 1e-3, "dl[{i}]: {} vs {fd}", dl.f32s()[i]);
        }
        // masked rows receive exactly zero gradient
        for c in 0..7 {
            assert_eq!(dl.at2(1, c), 0.0);
            assert_eq!(dl.at2(4, c), 0.0);
        }
    }

    #[test]
    fn argmax_rows_picks_max() {
        let x = t2([2, 3], vec![0.1, 0.9, 0.5, 2.0, -1.0, 1.0]);
        assert_eq!(argmax_rows(&x), vec![1, 0]);
    }

    // ---- streaming fused LM head -----------------------------------------

    /// Reference: materialize logits through the packed fused linear, then
    /// run the unfused masked-xent fwd/bwd and the tape's Linear backward
    /// composition (dx = dlogits @ w, dw = dlogits^T @ x, db = col sums).
    #[allow(clippy::type_complexity)]
    fn unfused_head(
        x: &Tensor,
        w: &Tensor,
        b: Option<&Tensor>,
        labels: &[i32],
        dloss: f32,
    ) -> (f32, f32, Tensor, Tensor, Option<Tensor>) {
        let (logits, _) = linear_fused(x, w, b, Act::None);
        let (loss, count) = masked_xent_fwd(&logits, labels);
        let dl = masked_xent_bwd(&logits, labels, count, dloss);
        let dx = matmul(&dl, w);
        let dw = matmul(&transpose(&dl), x);
        let db = b.map(|bb| {
            let d = dl.shape[1];
            let mut sums = vec![0.0f32; d];
            for row in dl.f32s().chunks_exact(d) {
                for (a, &vv) in sums.iter_mut().zip(row) {
                    *a += vv;
                }
            }
            Tensor::from_f32(&bb.shape, sums)
        });
        (loss, count, dx, dw, db)
    }

    fn assert_close(got: &Tensor, want: &Tensor, tol: f32, what: &str) {
        assert_eq!(got.shape, want.shape, "{what} shape");
        for (i, (a, e)) in got.f32s().iter().zip(want.f32s()).enumerate() {
            let rel = (a - e).abs() / a.abs().max(e.abs()).max(1.0);
            assert!(rel <= tol, "{what}[{i}]: fused {a} vs unfused {e} (rel {rel})");
        }
    }

    #[test]
    fn lm_head_xent_matches_unfused_composition() {
        // v = 300 spans three vocab tiles (128 + 128 + 44); n = 7 exercises
        // a full 4-row block plus a 3-row remainder; labels mix masked rows
        // and labels in every tile.
        let (n, d, v) = (7usize, 10usize, 300usize);
        let mut g = crate::util::rng::Rng::new(41);
        let x = t2([n, d], (0..n * d).map(|_| g.range_f32(-2.0, 2.0)).collect());
        let w = t2([v, d], (0..v * d).map(|_| g.range_f32(-1.0, 1.0)).collect());
        let b = Tensor::from_f32(&[v], (0..v).map(|_| g.range_f32(-0.5, 0.5)).collect());
        let labels = [3i32, -1, 130, 299, 0, -1, 255];
        for bias in [Some(&b), None] {
            let (lf, cf, stats) = lm_head_xent_fwd(&x, &w, bias, &labels);
            let (lu, cu, dx_u, dw_u, db_u) = unfused_head(&x, &w, bias, &labels, 1.0);
            assert_eq!(cf, cu);
            assert!((lf - lu).abs() <= 1e-5 * lf.abs().max(1.0), "{lf} vs {lu}");
            let (dx_f, dw_f, db_f) = lm_head_xent_bwd(&x, &w, bias, &labels, &stats, cf, 1.0);
            assert_close(&dx_f, &dx_u, 1e-5, "dx");
            assert_close(&dw_f, &dw_u, 1e-5, "dw");
            match (db_f, db_u) {
                (Some(a), Some(e)) => assert_close(&a, &e, 1e-5, "db"),
                (None, None) => {}
                other => panic!("bias gradient presence mismatch: {other:?}"),
            }
            // masked rows get exactly zero dx
            for c in 0..d {
                assert_eq!(dx_f.at2(1, c), 0.0);
                assert_eq!(dx_f.at2(5, c), 0.0);
            }
            arena::recycle_buf(stats);
        }
    }

    #[test]
    fn lm_head_xent_fd_gradients() {
        let (n, d, v) = (5usize, 6usize, 9usize);
        let mut rng = crate::util::rng::Rng::new(43);
        let x = rand_t(&[n, d], -1.5, 1.5, &mut rng);
        let w = rand_t(&[v, d], -1.0, 1.0, &mut rng);
        let b = rand_t(&[v], -0.5, 0.5, &mut rng);
        let labels = [2i32, -1, 0, 8, 4];
        let (_l, count, stats) = lm_head_xent_fwd(&x, &w, Some(&b), &labels);
        let (dx, dw, db) = lm_head_xent_bwd(&x, &w, Some(&b), &labels, &stats, count, 1.0);
        let db = db.expect("bias gradient");
        let eps = 1e-2;
        let f_x = |t: &Tensor| lm_head_xent_fwd(t, &w, Some(&b), &labels).0;
        for i in 0..x.numel() {
            let fd = fd_entry(&x, i, eps, f_x);
            assert!(rel_err(dx.f32s()[i], fd) < 1e-3, "dx[{i}]: {} vs {fd}", dx.f32s()[i]);
        }
        let f_w = |t: &Tensor| lm_head_xent_fwd(&x, t, Some(&b), &labels).0;
        for i in 0..w.numel() {
            let fd = fd_entry(&w, i, eps, f_w);
            assert!(rel_err(dw.f32s()[i], fd) < 1e-3, "dw[{i}]: {} vs {fd}", dw.f32s()[i]);
        }
        let f_b = |t: &Tensor| lm_head_xent_fwd(&x, &w, Some(t), &labels).0;
        for i in 0..b.numel() {
            let fd = fd_entry(&b, i, eps, f_b);
            assert!(rel_err(db.f32s()[i], fd) < 1e-3, "db[{i}]: {} vs {fd}", db.f32s()[i]);
        }
    }

    #[test]
    fn lm_head_xent_all_masked_guard() {
        // labels all < 0: loss 0, count 0, and every gradient exactly zero
        // (the max(count, 1) guard — no NaN anywhere).
        let mut rng = crate::util::rng::Rng::new(44);
        let x = rand_t(&[3, 4], -1.0, 1.0, &mut rng);
        let w = rand_t(&[5, 4], -1.0, 1.0, &mut rng);
        let b = rand_t(&[5], -1.0, 1.0, &mut rng);
        let labels = [-1i32, -1, -1];
        let (loss, count, stats) = lm_head_xent_fwd(&x, &w, Some(&b), &labels);
        assert_eq!(loss, 0.0);
        assert_eq!(count, 0.0);
        let (dx, dw, db) = lm_head_xent_bwd(&x, &w, Some(&b), &labels, &stats, count, 1.0);
        assert!(dx.f32s().iter().all(|&z| z == 0.0));
        assert!(dw.f32s().iter().all(|&z| z == 0.0));
        assert!(db.unwrap().f32s().iter().all(|&z| z == 0.0));
    }

    #[test]
    fn lm_head_xent_single_tile_matches_masked_xent_exactly() {
        // v < XENT_TILE_V: the online LSE sees one tile, so max and sum are
        // the plain masked_xent quantities — the losses agree to float noise.
        let mut rng = crate::util::rng::Rng::new(45);
        let x = rand_t(&[4, 5], -2.0, 2.0, &mut rng);
        let w = rand_t(&[7, 5], -1.0, 1.0, &mut rng);
        let labels = [0i32, 6, -1, 3];
        let (lf, _c, stats) = lm_head_xent_fwd(&x, &w, None, &labels);
        let (logits, _) = linear_fused(&x, &w, None, Act::None);
        let (lu, _cu) = masked_xent_fwd(&logits, &labels);
        assert!((lf - lu).abs() <= 1e-6 * lf.abs().max(1.0), "{lf} vs {lu}");
        arena::recycle_buf(stats);
    }

    #[test]
    fn lm_head_argmax_matches_materialized_logits() {
        // 16*8*200 MACs > NT_PACK_MIN_MACS: linear_fused takes the packed
        // path, whose logits are bitwise equal to the streamed tiles, so
        // exact argmax equality is well-defined.
        let (n, d, v) = (16usize, 8usize, 200usize);
        assert!(n * d * v >= NT_PACK_MIN_MACS);
        let mut g = crate::util::rng::Rng::new(46);
        let x = t2([n, d], (0..n * d).map(|_| g.range_f32(-2.0, 2.0)).collect());
        let w = t2([v, d], (0..v * d).map(|_| g.range_f32(-1.0, 1.0)).collect());
        let b = Tensor::from_f32(&[v], (0..v).map(|_| g.range_f32(-0.5, 0.5)).collect());
        for bias in [Some(&b), None] {
            let (logits, _) = linear_fused(&x, &w, bias, Act::None);
            assert_eq!(lm_head_argmax(&x, &w, bias), argmax_rows(&logits));
        }
    }

    #[test]
    fn fused_xent_override_toggles_and_restores() {
        set_fused_xent_override(Some(false));
        assert!(!fused_xent_enabled());
        set_fused_xent_override(Some(true));
        assert!(fused_xent_enabled());
        set_fused_xent_override(None);
    }

    #[test]
    fn linear_dot_matches_dot_path_bitwise_and_packed_close() {
        let mut rng = crate::util::rng::Rng::new(47);
        // tiny shape: linear_fused takes the dot path -> bitwise equality
        let x = rand_t(&[3, 5], -2.0, 2.0, &mut rng);
        let w = rand_t(&[4, 5], -1.0, 1.0, &mut rng);
        let b = rand_t(&[4], -0.5, 0.5, &mut rng);
        for (bias, act) in
            [(Some(&b), Act::None), (None, Act::None), (Some(&b), Act::Gelu), (None, Act::Gelu)]
        {
            let (want, _) = linear_fused(&x, &w, bias, act);
            let got = linear_dot(&x, &w, bias, act);
            assert_eq!(got.shape, want.shape);
            for (g, e) in got.f32s().iter().zip(want.f32s()) {
                assert_eq!(g.to_bits(), e.to_bits(), "dot-path bit parity");
            }
        }
        // packed-path shape (16*8*200 MACs >= NT_PACK_MIN_MACS): the packed
        // kernel reassociates, so agreement is <= 1e-5 relative, not bitwise
        let (n, d, v) = (16usize, 8usize, 200usize);
        assert!(n * d * v >= NT_PACK_MIN_MACS);
        let x = rand_t(&[n, d], -2.0, 2.0, &mut rng);
        let w = rand_t(&[v, d], -1.0, 1.0, &mut rng);
        let (want, _) = linear_fused(&x, &w, None, Act::None);
        let got = linear_dot(&x, &w, None, Act::None);
        assert_close(&got, &want, 1e-5, "linear_dot vs packed linear_fused");
    }

    #[test]
    fn linear_dot_is_batch_invariant() {
        // row r of an m-row call is bitwise equal to a 1-row call on row r —
        // the property the decode scheduler's determinism rests on
        let mut rng = crate::util::rng::Rng::new(48);
        let x = rand_t(&[5, 6], -2.0, 2.0, &mut rng);
        let w = rand_t(&[7, 6], -1.0, 1.0, &mut rng);
        let b = rand_t(&[7], -0.5, 0.5, &mut rng);
        let all = linear_dot(&x, &w, Some(&b), Act::Gelu);
        for r in 0..5 {
            let xr = t2([1, 6], x.f32s()[r * 6..(r + 1) * 6].to_vec());
            let solo = linear_dot(&xr, &w, Some(&b), Act::Gelu);
            for (g, e) in solo.f32s().iter().zip(&all.f32s()[r * 7..(r + 1) * 7]) {
                assert_eq!(g.to_bits(), e.to_bits(), "row {r} batch invariance");
            }
        }
    }

    #[test]
    fn attention_decode_matches_last_causal_row_bitwise() {
        use crate::tensor::paged::{PagePool, PagedRows};
        let (heads, dh, s) = (2usize, 3usize, 5usize);
        let dim = heads * dh;
        let mut rng = crate::util::rng::Rng::new(49);
        let q = rand_t(&[s, dim], -1.0, 1.0, &mut rng);
        let k = rand_t(&[s, dim], -1.0, 1.0, &mut rng);
        let v = rand_t(&[s, dim], -1.0, 1.0, &mut rng);
        let sh = AttnShape { batch: 1, heads, s_q: s, s_k: s, causal: true };
        let (full, _probs) = attention_fwd(&q, &k, &v, &sh);
        // scatter K/V into 2-row pages and decode the final position
        let rows_per_page = 2;
        let mut pool = PagePool::new(rows_per_page * dim);
        let table: Vec<usize> = (0..s.div_ceil(rows_per_page)).map(|_| pool.alloc()).collect();
        for t in 0..s {
            let page = pool.page_mut(table[t / rows_per_page]);
            let off = (t % rows_per_page) * dim;
            page[off..off + dim].copy_from_slice(&k.f32s()[t * dim..(t + 1) * dim]);
        }
        let mut vpool = PagePool::new(rows_per_page * dim);
        let vtable: Vec<usize> = (0..s.div_ceil(rows_per_page)).map(|_| vpool.alloc()).collect();
        for t in 0..s {
            let page = vpool.page_mut(vtable[t / rows_per_page]);
            let off = (t % rows_per_page) * dim;
            page[off..off + dim].copy_from_slice(&v.f32s()[t * dim..(t + 1) * dim]);
        }
        let kview = PagedRows::new(&pool, &table, rows_per_page, dim, s);
        let vview = PagedRows::new(&vpool, &vtable, rows_per_page, dim, s);
        let mut scores = [0.0f32; 8];
        let mut out = [0.0f32; 6];
        let qlast = &q.f32s()[(s - 1) * dim..s * dim];
        attention_decode(qlast, &kview, &vview, heads, &mut scores, &mut out);
        for (g, e) in out.iter().zip(&full.f32s()[(s - 1) * dim..s * dim]) {
            assert_eq!(g.to_bits(), e.to_bits(), "decode vs last causal row");
        }
    }

    #[test]
    fn lm_head_sample_greedy_matches_argmax() {
        // v spans 3 tiles and n exercises both the full 4-row block and the
        // remainder path; top_k = 1 must reproduce argmax exactly.
        let (n, d, v) = (7usize, 6usize, 300usize);
        let mut rng = crate::util::rng::Rng::new(50);
        let x = rand_t(&[n, d], -2.0, 2.0, &mut rng);
        let w = rand_t(&[v, d], -1.0, 1.0, &mut rng);
        let b = rand_t(&[v], -0.5, 0.5, &mut rng);
        for bias in [Some(&b), None] {
            let specs = vec![SampleSpec::greedy(); n];
            assert_eq!(lm_head_sample(&x, &w, bias, &specs), lm_head_argmax(&x, &w, bias));
            // a nonzero draw must not change greedy decoding
            let specs = vec![SampleSpec { top_k: 1, top_p: 0.3, u: 0.999 }; n];
            assert_eq!(lm_head_sample(&x, &w, bias, &specs), lm_head_argmax(&x, &w, bias));
        }
    }

    #[test]
    fn lm_head_sample_nucleus_hand_case() {
        // identity head on a 1x4 "logit" row: softmax of [2, 1, 0, -1].
        // descending probs ~ [.644, .237, .087, .032]; top_p = 0.7 keeps
        // {2, 1}, so u below .644/.881 picks column 0, above picks column 1.
        let x = t2([1, 4], vec![2.0, 1.0, 0.0, -1.0]);
        let w = eye(4);
        let pick = |top_p: f32, u: f32| {
            lm_head_sample(&x, &w, None, &[SampleSpec { top_k: 4, top_p, u }])[0]
        };
        assert_eq!(pick(0.7, 0.0), 0);
        assert_eq!(pick(0.7, 0.5), 0);
        assert_eq!(pick(0.7, 0.99), 1); // nucleus kept column 1 alive
        assert_eq!(pick(0.5, 0.99), 0); // p=0.5: only column 0 survives
        assert_eq!(pick(1.0, 0.95), 2); // full nucleus: tail reachable
    }
}
