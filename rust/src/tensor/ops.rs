//! Dense linear algebra on [`Tensor`]s — the substrate for the growth
//! operator zoo (Net2Net, AKI, native LiGO) and for tests.
//!
//! Hot paths use a blocked, cache-friendly matmul that goes multicore
//! (scoped-thread row partitioning via [`crate::util::par`]) above
//! [`PAR_MIN_MACS`]; everything is f32. Row partitioning keeps per-element
//! accumulation order fixed, so parallel results are bit-identical to
//! serial ones.

use crate::util::par;

use super::{numel, Tensor};

/// Multiply-accumulate count above which matmuls fan out across cores.
/// Below it, thread spawn/join overhead dominates (and tests stay serial).
pub const PAR_MIN_MACS: usize = 1 << 21;

/// Blocked i-k-j kernel over a contiguous row chunk of C (rows starting at
/// global row `row0`). `skip_zeros` enables the sparse fast path: legal only
/// when every element of `b` is finite, since 0 * NaN/Inf must stay NaN.
fn matmul_rows(
    av: &[f32],
    bv: &[f32],
    c: &mut [f32],
    row0: usize,
    k: usize,
    n: usize,
    skip_zeros: bool,
) {
    const BK: usize = 64;
    let rows = c.len() / n;
    for k0 in (0..k).step_by(BK) {
        let k1 = (k0 + BK).min(k);
        for r in 0..rows {
            let i = row0 + r;
            let crow = &mut c[r * n..(r + 1) * n];
            for kk in k0..k1 {
                let aik = av[i * k + kk];
                if skip_zeros && aik == 0.0 {
                    continue;
                }
                let brow = &bv[kk * n..(kk + 1) * n];
                for (cj, bj) in crow.iter_mut().zip(brow.iter()) {
                    *cj += aik * bj;
                }
            }
        }
    }
}

/// C = A @ B for (m,k) x (k,n). Blocked i-k-j loop (k-major inner) — the
/// classic cache-friendly ordering — parallelized over output rows for
/// growth-time work. Rows of A that are exactly zero are skipped, but only
/// when B is all-finite: with NaN/Inf in B the full accumulation runs so
/// that 0 * NaN propagates as IEEE 754 demands.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
    let (av, bv) = (a.f32s(), b.f32s());
    let mut c = vec![0.0f32; m * n];
    if m == 0 || n == 0 {
        return Tensor::from_f32(&[m, n], c);
    }
    let skip_zeros = bv.iter().all(|x| x.is_finite());
    if m * k * n >= PAR_MIN_MACS && m > 1 {
        par::par_row_chunks(&mut c, n, |row0, chunk| {
            matmul_rows(av, bv, chunk, row0, k, n, skip_zeros)
        });
    } else {
        matmul_rows(av, bv, &mut c, 0, k, n, skip_zeros);
    }
    Tensor::from_f32(&[m, n], c)
}

/// C = X @ Y^T for (m,k) x (n,k): both operands stream row-major, so this is
/// the cache-friendly way to apply the LiGO in-expansion (`... A^T`) without
/// materializing a transpose. Full dot products — no zero skipping — so
/// NaN/Inf always propagate.
pub fn matmul_nt(x: &Tensor, y: &Tensor) -> Tensor {
    let (m, k) = (x.shape[0], x.shape[1]);
    let (n, k2) = (y.shape[0], y.shape[1]);
    assert_eq!(k, k2, "matmul_nt inner dims: {k} vs {k2}");
    let (xv, yv) = (x.f32s(), y.f32s());
    let mut c = vec![0.0f32; m * n];
    if m == 0 || n == 0 {
        return Tensor::from_f32(&[m, n], c);
    }
    let kernel = |row0: usize, chunk: &mut [f32]| {
        for (r, crow) in chunk.chunks_exact_mut(n).enumerate() {
            let xrow = &xv[(row0 + r) * k..(row0 + r + 1) * k];
            for (j, cj) in crow.iter_mut().enumerate() {
                let yrow = &yv[j * k..(j + 1) * k];
                *cj = xrow.iter().zip(yrow.iter()).map(|(a, b)| a * b).sum();
            }
        }
    };
    if m * k * n >= PAR_MIN_MACS && m > 1 {
        par::par_row_chunks(&mut c, n, kernel);
    } else {
        kernel(0, &mut c);
    }
    Tensor::from_f32(&[m, n], c)
}

/// B^T as a new tensor.
pub fn transpose(a: &Tensor) -> Tensor {
    let (m, n) = (a.shape[0], a.shape[1]);
    let av = a.f32s();
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            out[j * m + i] = av[i * n + j];
        }
    }
    Tensor::from_f32(&[n, m], out)
}

/// The n x n identity matrix (width-expansion fallback when dims match).
pub fn eye(n: usize) -> Tensor {
    let mut v = vec![0.0f32; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    Tensor::from_f32(&[n, n], v)
}

/// y = A @ x for (m,n) x (n,).
pub fn matvec(a: &Tensor, x: &Tensor) -> Tensor {
    let (m, n) = (a.shape[0], a.shape[1]);
    assert_eq!(numel(&x.shape), n);
    let (av, xv) = (a.f32s(), x.f32s());
    let mut y = vec![0.0f32; m];
    for i in 0..m {
        y[i] = av[i * n..(i + 1) * n].iter().zip(xv).map(|(a, b)| a * b).sum();
    }
    Tensor::from_f32(&[m], y)
}

/// Elementwise dot product of two equally-shaped tensors.
pub fn dot(a: &Tensor, b: &Tensor) -> f32 {
    assert_eq!(a.shape, b.shape);
    a.f32s().iter().zip(b.f32s()).map(|(x, y)| x * y).sum()
}

/// The LiGO triple product Omega = B @ W @ A^T (paper Eq. 4's width pass).
/// The fused second stage streams A row-major (`matmul_nt`), so both halves
/// parallelize over rows.
pub fn expand(b: &Tensor, w: &Tensor, a: &Tensor) -> Tensor {
    matmul_nt(&matmul(b, w), a)
}

/// Elementwise a + s * b (in place on a copy).
pub fn axpy(a: &Tensor, s: f32, b: &Tensor) -> Tensor {
    assert_eq!(a.shape, b.shape);
    let mut out = a.clone();
    for (x, y) in out.f32s_mut().iter_mut().zip(b.f32s()) {
        *x += s * y;
    }
    out
}

pub fn scale(a: &Tensor, s: f32) -> Tensor {
    let mut out = a.clone();
    for x in out.f32s_mut() {
        *x *= s;
    }
    out
}

/// Weighted sum of equally-shaped tensors: sum_i w_i T_i. A zero weight
/// means "excluded from the blend" (the depth-selection patterns rely on
/// this), so w_i == 0 terms are skipped rather than multiplied through.
pub fn weighted_sum(ws: &[f32], ts: &[&Tensor]) -> Tensor {
    assert_eq!(ws.len(), ts.len());
    assert!(!ts.is_empty());
    let mut out = Tensor::zeros(&ts[0].shape);
    let ov = out.f32s_mut();
    for (w, t) in ws.iter().zip(ts) {
        if *w == 0.0 {
            continue;
        }
        for (o, x) in ov.iter_mut().zip(t.f32s()) {
            *o += w * x;
        }
    }
    out
}

/// Max absolute difference between two tensors (test helper).
pub fn max_abs_diff(a: &Tensor, b: &Tensor) -> f32 {
    assert_eq!(a.shape, b.shape);
    a.f32s()
        .iter()
        .zip(b.f32s())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn t2(shape: [usize; 2], v: Vec<f32>) -> Tensor {
        Tensor::from_f32(&shape, v)
    }

    #[test]
    fn matmul_hand_case() {
        let a = t2([2, 2], vec![1., 2., 3., 4.]);
        let b = t2([2, 2], vec![5., 6., 7., 8.]);
        let c = matmul(&a, &b);
        assert_eq!(c.f32s(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn matmul_identity() {
        let a = t2([2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let eye3 = eye(3);
        assert_eq!(matmul(&a, &eye3).f32s(), a.f32s());
    }

    #[test]
    fn matmul_zero_skip_propagates_nan_and_inf() {
        // Regression: the aik == 0 fast path used to drop 0 * NaN / 0 * Inf
        // from the right operand; IEEE 754 requires NaN.
        let a = t2([1, 2], vec![0.0, 1.0]);
        let b_nan = t2([2, 1], vec![f32::NAN, 2.0]);
        assert!(matmul(&a, &b_nan).f32s()[0].is_nan(), "0 * NaN must stay NaN");
        let b_inf = t2([2, 1], vec![f32::INFINITY, 2.0]);
        assert!(matmul(&a, &b_inf).f32s()[0].is_nan(), "0 * Inf must stay NaN");
        let b_ninf = t2([2, 1], vec![f32::NEG_INFINITY, 2.0]);
        assert!(matmul(&a, &b_ninf).f32s()[0].is_nan());
    }

    #[test]
    fn matmul_zero_skip_fast_path_still_exact() {
        // With a finite right operand the skip path must change nothing.
        let a = t2([2, 3], vec![0.0, 1.0, 0.0, 2.0, 0.0, -1.0]);
        let b = t2([3, 2], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(matmul(&a, &b).f32s(), &[3.0, 4.0, -3.0, -2.0]);
    }

    #[test]
    fn matmul_nan_in_left_operand_propagates() {
        let a = t2([1, 2], vec![f32::NAN, 0.0]);
        let b = t2([2, 1], vec![1.0, 1.0]);
        assert!(matmul(&a, &b).f32s()[0].is_nan());
    }

    #[test]
    fn matmul_nt_matches_explicit_transpose() {
        prop::check("X Y^T = X @ transpose(Y)", 20, |g| {
            let m = g.usize_in(1, 10);
            let k = g.usize_in(1, 8);
            let n = g.usize_in(1, 10);
            let x = t2([m, k], g.vec_f32(m * k, -2.0, 2.0));
            let y = t2([n, k], g.vec_f32(n * k, -2.0, 2.0));
            let got = matmul_nt(&x, &y);
            let want = matmul(&x, &transpose(&y));
            assert!(max_abs_diff(&got, &want) < 1e-4);
        });
    }

    #[test]
    fn parallel_matmul_matches_naive_above_threshold() {
        // 160^3 = 4.1M MACs > PAR_MIN_MACS: exercises the threaded path.
        let n = 160;
        assert!(n * n * n >= PAR_MIN_MACS);
        let mut g = crate::util::rng::Rng::new(11);
        let a = t2([n, n], (0..n * n).map(|_| g.range_f32(-1.0, 1.0)).collect());
        let b = t2([n, n], (0..n * n).map(|_| g.range_f32(-1.0, 1.0)).collect());
        let c = matmul(&a, &b);
        // serial reference on a sampled set of entries
        for (i, j) in [(0, 0), (1, 77), (80, 3), (159, 159), (42, 101)] {
            let want: f32 = (0..n).map(|x| a.at2(i, x) * b.at2(x, j)).sum();
            assert!((c.at2(i, j) - want).abs() < 1e-3, "({i},{j})");
        }
    }

    #[test]
    fn transpose_involution() {
        prop::check("transpose^2 = id", 25, |g| {
            let m = g.usize_in(1, 12);
            let n = g.usize_in(1, 12);
            let a = t2([m, n], g.vec_f32(m * n, -2.0, 2.0));
            assert_eq!(transpose(&transpose(&a)), a);
        });
    }

    #[test]
    fn eye_is_identity_for_matmul_nt() {
        let a = t2([2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(matmul_nt(&a, &eye(3)).f32s(), a.f32s());
    }

    #[test]
    fn expand_matches_naive_triple() {
        prop::check("expand = B W A^T", 20, |g| {
            let m = g.usize_in(1, 10);
            let k = g.usize_in(1, 8);
            let n = g.usize_in(1, 8);
            let p = g.usize_in(1, 10);
            let b = t2([m, k], g.vec_f32(m * k, -1.0, 1.0));
            let w = t2([k, n], g.vec_f32(k * n, -1.0, 1.0));
            let a = t2([p, n], g.vec_f32(p * n, -1.0, 1.0));
            let got = expand(&b, &w, &a);
            // naive reference
            let mut want = vec![0.0f32; m * p];
            for i in 0..m {
                for j in 0..p {
                    let mut s = 0.0;
                    for x in 0..k {
                        for y in 0..n {
                            s += b.at2(i, x) * w.at2(x, y) * a.at2(j, y);
                        }
                    }
                    want[i * p + j] = s;
                }
            }
            assert!(max_abs_diff(&got, &t2([m, p], want)) < 1e-4);
        });
    }

    #[test]
    fn weighted_sum_linear() {
        let a = t2([1, 2], vec![1., 2.]);
        let b = t2([1, 2], vec![10., 20.]);
        let s = weighted_sum(&[0.5, 0.25], &[&a, &b]);
        assert_eq!(s.f32s(), &[3.0, 6.0]);
    }

    #[test]
    fn matmul_associativity_prop() {
        prop::check("(AB)C = A(BC)", 10, |g| {
            let m = g.usize_in(1, 6);
            let k = g.usize_in(1, 6);
            let n = g.usize_in(1, 6);
            let p = g.usize_in(1, 6);
            let a = t2([m, k], g.vec_f32(m * k, -1.0, 1.0));
            let b = t2([k, n], g.vec_f32(k * n, -1.0, 1.0));
            let c = t2([n, p], g.vec_f32(n * p, -1.0, 1.0));
            let lhs = matmul(&matmul(&a, &b), &c);
            let rhs = matmul(&a, &matmul(&b, &c));
            assert!(max_abs_diff(&lhs, &rhs) < 1e-3);
        });
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = t2([2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let x = Tensor::from_f32(&[3], vec![1., 0., -1.]);
        assert_eq!(matvec(&a, &x).f32s(), &[-2.0, -2.0]);
    }

    #[test]
    fn dot_matches_manual() {
        let a = t2([2, 2], vec![1., 2., 3., 4.]);
        let b = t2([2, 2], vec![5., 6., 7., 8.]);
        assert_eq!(dot(&a, &b), 5.0 + 12.0 + 21.0 + 32.0);
    }
}
