//! Dense linear algebra on [`Tensor`]s — the substrate for the growth
//! operator zoo (Net2Net, AKI, LiGO-apply checks) and for tests.
//!
//! Hot paths use a blocked, cache-friendly matmul; everything is f32.

use super::{numel, Tensor};

/// C = A @ B for (m,k) x (k,n). Blocked i-k-j loop (k-major inner) —
/// the classic cache-friendly ordering; good enough for growth-time work.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
    let (av, bv) = (a.f32s(), b.f32s());
    let mut c = vec![0.0f32; m * n];
    const BK: usize = 64;
    for k0 in (0..k).step_by(BK) {
        let k1 = (k0 + BK).min(k);
        for i in 0..m {
            let crow = &mut c[i * n..(i + 1) * n];
            for kk in k0..k1 {
                let aik = av[i * k + kk];
                if aik == 0.0 {
                    continue;
                }
                let brow = &bv[kk * n..(kk + 1) * n];
                for (cj, bj) in crow.iter_mut().zip(brow.iter()) {
                    *cj += aik * bj;
                }
            }
        }
    }
    Tensor::from_f32(&[m, n], c)
}

/// B^T as a new tensor.
pub fn transpose(a: &Tensor) -> Tensor {
    let (m, n) = (a.shape[0], a.shape[1]);
    let av = a.f32s();
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            out[j * m + i] = av[i * n + j];
        }
    }
    Tensor::from_f32(&[n, m], out)
}

/// y = A @ x for (m,n) x (n,).
pub fn matvec(a: &Tensor, x: &Tensor) -> Tensor {
    let (m, n) = (a.shape[0], a.shape[1]);
    assert_eq!(numel(&x.shape), n);
    let (av, xv) = (a.f32s(), x.f32s());
    let mut y = vec![0.0f32; m];
    for i in 0..m {
        y[i] = av[i * n..(i + 1) * n].iter().zip(xv).map(|(a, b)| a * b).sum();
    }
    Tensor::from_f32(&[m], y)
}

/// The LiGO triple product Omega = B @ W @ A^T (reference path used by
/// rust-side verification of `ligo_apply` artifacts and by AKI/Net2Net when
/// expressed as selection matrices).
pub fn expand(b: &Tensor, w: &Tensor, a: &Tensor) -> Tensor {
    matmul(&matmul(b, w), &transpose(a))
}

/// Elementwise a + s * b (in place on a copy).
pub fn axpy(a: &Tensor, s: f32, b: &Tensor) -> Tensor {
    assert_eq!(a.shape, b.shape);
    let mut out = a.clone();
    for (x, y) in out.f32s_mut().iter_mut().zip(b.f32s()) {
        *x += s * y;
    }
    out
}

pub fn scale(a: &Tensor, s: f32) -> Tensor {
    let mut out = a.clone();
    for x in out.f32s_mut() {
        *x *= s;
    }
    out
}

/// Weighted sum of equally-shaped tensors: sum_i w_i T_i.
pub fn weighted_sum(ws: &[f32], ts: &[&Tensor]) -> Tensor {
    assert_eq!(ws.len(), ts.len());
    assert!(!ts.is_empty());
    let mut out = Tensor::zeros(&ts[0].shape);
    let ov = out.f32s_mut();
    for (w, t) in ws.iter().zip(ts) {
        if *w == 0.0 {
            continue;
        }
        for (o, x) in ov.iter_mut().zip(t.f32s()) {
            *o += w * x;
        }
    }
    out
}

/// Max absolute difference between two tensors (test helper).
pub fn max_abs_diff(a: &Tensor, b: &Tensor) -> f32 {
    assert_eq!(a.shape, b.shape);
    a.f32s()
        .iter()
        .zip(b.f32s())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn t2(shape: [usize; 2], v: Vec<f32>) -> Tensor {
        Tensor::from_f32(&shape, v)
    }

    #[test]
    fn matmul_hand_case() {
        let a = t2([2, 2], vec![1., 2., 3., 4.]);
        let b = t2([2, 2], vec![5., 6., 7., 8.]);
        let c = matmul(&a, &b);
        assert_eq!(c.f32s(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn matmul_identity() {
        let a = t2([2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let eye = t2([3, 3], vec![1., 0., 0., 0., 1., 0., 0., 0., 1.]);
        assert_eq!(matmul(&a, &eye).f32s(), a.f32s());
    }

    #[test]
    fn transpose_involution() {
        prop::check("transpose^2 = id", 25, |g| {
            let m = g.usize_in(1, 12);
            let n = g.usize_in(1, 12);
            let a = t2([m, n], g.vec_f32(m * n, -2.0, 2.0));
            assert_eq!(transpose(&transpose(&a)), a);
        });
    }

    #[test]
    fn expand_matches_naive_triple() {
        prop::check("expand = B W A^T", 20, |g| {
            let m = g.usize_in(1, 10);
            let k = g.usize_in(1, 8);
            let n = g.usize_in(1, 8);
            let p = g.usize_in(1, 10);
            let b = t2([m, k], g.vec_f32(m * k, -1.0, 1.0));
            let w = t2([k, n], g.vec_f32(k * n, -1.0, 1.0));
            let a = t2([p, n], g.vec_f32(p * n, -1.0, 1.0));
            let got = expand(&b, &w, &a);
            // naive reference
            let mut want = vec![0.0f32; m * p];
            for i in 0..m {
                for j in 0..p {
                    let mut s = 0.0;
                    for x in 0..k {
                        for y in 0..n {
                            s += b.at2(i, x) * w.at2(x, y) * a.at2(j, y);
                        }
                    }
                    want[i * p + j] = s;
                }
            }
            assert!(max_abs_diff(&got, &t2([m, p], want)) < 1e-4);
        });
    }

    #[test]
    fn weighted_sum_linear() {
        let a = t2([1, 2], vec![1., 2.]);
        let b = t2([1, 2], vec![10., 20.]);
        let s = weighted_sum(&[0.5, 0.25], &[&a, &b]);
        assert_eq!(s.f32s(), &[3.0, 6.0]);
    }

    #[test]
    fn matmul_associativity_prop() {
        prop::check("(AB)C = A(BC)", 10, |g| {
            let m = g.usize_in(1, 6);
            let k = g.usize_in(1, 6);
            let n = g.usize_in(1, 6);
            let p = g.usize_in(1, 6);
            let a = t2([m, k], g.vec_f32(m * k, -1.0, 1.0));
            let b = t2([k, n], g.vec_f32(k * n, -1.0, 1.0));
            let c = t2([n, p], g.vec_f32(n * p, -1.0, 1.0));
            let lhs = matmul(&matmul(&a, &b), &c);
            let rhs = matmul(&a, &matmul(&b, &c));
            assert!(max_abs_diff(&lhs, &rhs) < 1e-3);
        });
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = t2([2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let x = Tensor::from_f32(&[3], vec![1., 0., -1.]);
        assert_eq!(matvec(&a, &x).f32s(), &[-2.0, -2.0]);
    }
}
