//! The named tensor store: an ordered map of parameter name -> [`Tensor`].
//!
//! Sorted-key iteration order is the contract shared with the AOT manifests
//! (JAX flattens dicts in sorted-key order), so a store can be bound to a
//! PJRT executable positionally.

use std::collections::BTreeMap;

use super::{init::det_fill, Tensor};

/// An ordered parameter/tensor collection.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Store {
    map: BTreeMap<String, Tensor>,
}

impl Store {
    pub fn new() -> Store {
        Store::default()
    }

    /// Deterministically initialize from a {name -> shape} spec (the
    /// manifest's params entries), matching python detinit exactly.
    pub fn det_init(shapes: &[(String, Vec<usize>)], seed: u64) -> Store {
        let mut s = Store::new();
        for (name, shape) in shapes {
            s.insert(name.clone(), det_fill(name, shape, seed));
        }
        s
    }

    pub fn insert(&mut self, name: impl Into<String>, t: Tensor) {
        self.map.insert(name.into(), t);
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.map.get(name)
    }

    pub fn expect(&self, name: &str) -> &Tensor {
        self.map
            .get(name)
            .unwrap_or_else(|| panic!("missing tensor '{name}'"))
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut Tensor> {
        self.map.get_mut(name)
    }

    pub fn remove(&mut self, name: &str) -> Option<Tensor> {
        self.map.remove(name)
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Sorted-name iteration (the manifest order).
    pub fn iter(&self) -> impl Iterator<Item = (&String, &Tensor)> {
        self.map.iter()
    }

    /// Consume the store, yielding owned (name, tensor) pairs in sorted
    /// order (e.g. to recycle a dead store's buffers via
    /// [`crate::tensor::arena::recycle_store`]).
    pub fn into_entries(self) -> impl Iterator<Item = (String, Tensor)> {
        self.map.into_iter()
    }

    pub fn iter_mut(&mut self) -> impl Iterator<Item = (&String, &mut Tensor)> {
        self.map.iter_mut()
    }

    pub fn names(&self) -> Vec<&str> {
        self.map.keys().map(|s| s.as_str()).collect()
    }

    /// Total number of scalar parameters (f32 + i32).
    pub fn param_count(&self) -> usize {
        self.map.values().map(|t| t.numel()).sum()
    }

    /// Keys with a given prefix, e.g. all of layer "L03_".
    pub fn with_prefix(&self, prefix: &str) -> Vec<&str> {
        self.map
            .keys()
            .filter(|k| k.starts_with(prefix))
            .map(|s| s.as_str())
            .collect()
    }

    /// Global L2 norm over all f32 tensors (diagnostics, grad clipping).
    pub fn global_norm(&self) -> f32 {
        self.map
            .values()
            .filter(|t| matches!(t.data, super::TensorData::F32(_)))
            .map(|t| t.f32s().iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>())
            .sum::<f64>()
            .sqrt() as f32
    }
}

impl FromIterator<(String, Tensor)> for Store {
    fn from_iter<I: IntoIterator<Item = (String, Tensor)>>(iter: I) -> Self {
        Store { map: iter.into_iter().collect() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sorted_iteration_order() {
        let mut s = Store::new();
        s.insert("b", Tensor::zeros(&[1]));
        s.insert("a", Tensor::zeros(&[1]));
        s.insert("L10_x", Tensor::zeros(&[1]));
        s.insert("L02_x", Tensor::zeros(&[1]));
        let names: Vec<_> = s.iter().map(|(n, _)| n.clone()).collect();
        assert_eq!(names, vec!["L02_x", "L10_x", "a", "b"]);
    }

    #[test]
    fn det_init_fills_all() {
        let shapes = vec![
            ("emb_tok".to_string(), vec![16, 4]),
            ("L00_ln1_g".to_string(), vec![4]),
        ];
        let s = Store::det_init(&shapes, 0);
        assert_eq!(s.param_count(), 68);
        assert!(s.expect("L00_ln1_g").f32s().iter().all(|&x| x == 1.0));
    }

    #[test]
    fn prefix_query() {
        let mut s = Store::new();
        s.insert("L00_q_w", Tensor::zeros(&[1]));
        s.insert("L00_k_w", Tensor::zeros(&[1]));
        s.insert("L01_q_w", Tensor::zeros(&[1]));
        assert_eq!(s.with_prefix("L00_").len(), 2);
    }

    #[test]
    fn global_norm_pythagorean() {
        let mut s = Store::new();
        s.insert("a", Tensor::from_f32(&[1], vec![3.0]));
        s.insert("b", Tensor::from_f32(&[1], vec![4.0]));
        assert!((s.global_norm() - 5.0).abs() < 1e-6);
    }
}
