//! Named dense tensors — the coordinator's native parameter representation.
//!
//! The runtime converts these to/from PJRT literals; the growth-operator zoo
//! and the optimizer operate on them directly.

pub mod arena;
pub mod init;
pub mod io;
pub mod ops;
pub mod paged;
pub mod store;

/// Element type of a tensor (mirrors the manifest dtypes we emit).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> crate::error::Result<DType> {
        match s {
            "float32" | "f32" => Ok(DType::F32),
            "int32" | "i32" => Ok(DType::I32),
            other => crate::bail!("unsupported dtype {other}"),
        }
    }
}

/// A dense tensor: shape + row-major data (f32 or i32).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: TensorData::F32(vec![0.0; numel(shape)]) }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(numel(shape), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data: TensorData::F32(data) }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(numel(shape), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data: TensorData::I32(data) }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor { shape: vec![], data: TensorData::F32(vec![v]) }
    }

    pub fn numel(&self) -> usize {
        numel(&self.shape)
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
        }
    }

    /// Borrow as f32 slice; panics on dtype mismatch.
    pub fn f32s(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn f32s_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            TensorData::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn i32s(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            _ => panic!("tensor is not i32"),
        }
    }

    pub fn i32s_mut(&mut self) -> &mut [i32] {
        match &mut self.data {
            TensorData::I32(v) => v,
            _ => panic!("tensor is not i32"),
        }
    }

    /// 2D accessor (row, col); panics unless rank-2 f32.
    pub fn at2(&self, r: usize, c: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.f32s()[r * self.shape[1] + c]
    }

    /// Frobenius norm (f32 tensors).
    pub fn norm(&self) -> f32 {
        self.f32s().iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Scalar value of a 0-d (or 1-element) tensor.
    pub fn item(&self) -> f32 {
        debug_assert_eq!(self.numel(), 1);
        self.f32s()[0]
    }
}

/// Number of elements: empty shape (a scalar) has one element.
pub fn numel(shape: &[usize]) -> usize {
    if shape.is_empty() {
        1
    } else {
        shape.iter().product()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numel_scalar_is_one() {
        assert_eq!(numel(&[]), 1);
        assert_eq!(numel(&[3, 4]), 12);
        assert_eq!(numel(&[0, 4]), 0);
    }

    #[test]
    fn constructors_check_shape() {
        let t = Tensor::from_f32(&[2, 3], vec![0.0; 6]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.dtype(), DType::F32);
    }

    #[test]
    #[should_panic]
    fn mismatched_shape_panics() {
        Tensor::from_f32(&[2, 3], vec![0.0; 5]);
    }

    #[test]
    fn at2_indexes_row_major() {
        let t = Tensor::from_f32(&[2, 3], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(t.at2(1, 2), 5.0);
        assert_eq!(t.at2(0, 1), 1.0);
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("float32").unwrap(), DType::F32);
        assert_eq!(DType::parse("int32").unwrap(), DType::I32);
        assert!(DType::parse("bfloat16").is_err());
    }
}
