//! Checkpoint I/O: a simple self-describing binary format (LGCK).
//!
//! Layout:  magic "LGCK" | u32 version | u32 n_tensors | per tensor:
//!   u32 name_len | name bytes | u8 dtype (0=f32,1=i32) | u32 rank |
//!   u64 dims[rank] | raw little-endian data.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::bail;
use crate::error::{Context, Result};

use super::store::Store;
use super::{numel, Tensor, TensorData};

const MAGIC: &[u8; 4] = b"LGCK";
const VERSION: u32 = 1;

pub fn save(store: &Store, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = BufWriter::new(File::create(path).with_context(|| format!("create {path:?}"))?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(store.len() as u32).to_le_bytes())?;
    for (name, t) in store.iter() {
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name.as_bytes())?;
        let dtype = match t.data {
            TensorData::F32(_) => 0u8,
            TensorData::I32(_) => 1u8,
        };
        w.write_all(&[dtype])?;
        w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for d in &t.shape {
            w.write_all(&(*d as u64).to_le_bytes())?;
        }
        match &t.data {
            TensorData::F32(v) => {
                for x in v {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
            TensorData::I32(v) => {
                for x in v {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
        }
    }
    w.flush()?;
    Ok(())
}

pub fn load(path: impl AsRef<Path>) -> Result<Store> {
    let path = path.as_ref();
    let mut r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: not a LGCK checkpoint");
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("{path:?}: unsupported checkpoint version {version}");
    }
    let n = read_u32(&mut r)? as usize;
    let mut store = Store::new();
    for _ in 0..n {
        let name_len = read_u32(&mut r)? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name)?;
        let mut dtype = [0u8; 1];
        r.read_exact(&mut dtype)?;
        let rank = read_u32(&mut r)? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            let mut b = [0u8; 8];
            r.read_exact(&mut b)?;
            shape.push(u64::from_le_bytes(b) as usize);
        }
        let count = numel(&shape);
        let t = match dtype[0] {
            0 => {
                let mut raw = vec![0u8; count * 4];
                r.read_exact(&mut raw)?;
                let data = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Tensor::from_f32(&shape, data)
            }
            1 => {
                let mut raw = vec![0u8; count * 4];
                r.read_exact(&mut raw)?;
                let data = raw
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Tensor::from_i32(&shape, data)
            }
            d => bail!("bad dtype tag {d}"),
        };
        store.insert(name, t);
    }
    Ok(store)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut s = Store::new();
        s.insert("w", Tensor::from_f32(&[2, 3], vec![1., -2., 3., 4., 5.5, -6.]));
        s.insert("idx", Tensor::from_i32(&[4], vec![1, 2, 3, -4]));
        s.insert("scalar", Tensor::scalar_f32(7.25));
        let dir = std::env::temp_dir().join("ligo_io_test");
        let path = dir.join("ck.lgck");
        save(&s, &path).unwrap();
        let l = load(&path).unwrap();
        assert_eq!(s, l);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("ligo_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.bin");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(load("/nonexistent/path/x.lgck").is_err());
    }
}
