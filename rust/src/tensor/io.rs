//! Checkpoint I/O: the LGCK v2 sectioned binary format.
//!
//! Layout:
//!
//! ```text
//! magic "LGCK" | u32 version=2 | u32 n_sections | per section:
//!   u32 name_len | name bytes | u64 payload_len | payload | u32 crc32(payload)
//! ```
//!
//! A bare parameter [`Store`] saves as one `tensors` section; full training
//! snapshots (`coordinator/checkpoint`) add `meta` / optimizer-moment /
//! curve sections on top of the same primitives. The `tensors` payload is
//! the self-describing v1 tensor stream (`u32 n | per tensor: u32 name_len
//! | name | u8 dtype (0=f32,1=i32) | u32 rank | u64 dims[rank] | raw
//! little-endian data`), now CRC-guarded and bounds-checked.
//!
//! Robustness contract (the crash-safety tentpole):
//!
//! - **Atomic, durable writes** — [`write_atomic`] writes a temp file in
//!   the destination directory, `fsync`s it, then `rename`s over the
//!   target, so a crash mid-save can never leave a half-written file under
//!   the checkpoint's name.
//! - **Integrity-checked reads** — every section payload carries a CRC32;
//!   corruption errors name the damaged section. All header lengths are
//!   validated against the actual file size *before* any allocation, so a
//!   malformed file yields a typed [`crate::error::Error`], never a panic
//!   or an absurd allocation.
//! - **Fault hooks** — [`write_atomic`] consults `util/fault` so the test
//!   harness can inject torn or bit-flipped writes and assert that the
//!   next load detects them.

use std::fs::File;
use std::io::Write;
use std::path::Path;
use std::sync::OnceLock;

use crate::bail;
use crate::error::{Context, Error, Result};
use crate::util::fault::{self, Fault};
use crate::util::json::Json;

use super::store::Store;
use super::{Tensor, TensorData};

const MAGIC: &[u8; 4] = b"LGCK";
const VERSION: u32 = 2;

/// Maximum tensor rank a checkpoint may declare; real models use ≤ 4, and
/// the cap keeps a corrupted rank field from driving a huge shape loop.
const MAX_RANK: usize = 32;

// ---------------------------------------------------------------------------
// CRC32 (IEEE, poly 0xEDB88320) — the zlib/PNG checksum.

fn crc32_table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    })
}

/// CRC32 of a byte slice (IEEE polynomial, as in zlib).
pub fn crc32(bytes: &[u8]) -> u32 {
    let t = crc32_table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = t[((c ^ u32::from(b)) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------------
// Bounds-checked cursor over an in-memory file image.

struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Cur<'a> {
        Cur { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if n > self.remaining() {
            bail!(
                "corrupt checkpoint: truncated reading {what} ({n} bytes needed at offset {}, {} available)",
                self.pos,
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }
}

// ---------------------------------------------------------------------------
// Atomic durable writes.

/// Write `bytes` to `path` atomically and durably: temp file in the same
/// directory → `fsync` → `rename`. Honors an armed `util/fault` write
/// fault (torn write / bit flip) for the crash-safety harness.
pub fn write_atomic(path: impl AsRef<Path>, bytes: &[u8]) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).with_context(|| format!("create dir {dir:?}"))?;
        }
    }
    let name = path
        .file_name()
        .with_context(|| format!("checkpoint path {path:?} has no file name"))?;
    let tmp = path.with_file_name(format!(".{}.tmp", name.to_string_lossy()));
    {
        let mut f = File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
        match fault::take_write_fault() {
            Some(Fault::TornWrite) => f.write_all(&bytes[..bytes.len() * 2 / 3])?,
            Some(Fault::BitFlip) if !bytes.is_empty() => {
                let mut b = bytes.to_vec();
                let i = b.len() * 2 / 3;
                b[i] ^= 0x40;
                f.write_all(&b)?;
            }
            _ => f.write_all(bytes)?,
        }
        f.sync_all().with_context(|| format!("fsync {tmp:?}"))?;
    }
    std::fs::rename(&tmp, path).with_context(|| format!("rename {tmp:?} -> {path:?}"))?;
    // Durability of the rename itself needs a directory fsync; best-effort
    // (some filesystems reject opening a directory for sync).
    #[cfg(unix)]
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            if let Ok(d) = File::open(dir) {
                let _ = d.sync_all();
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Section layer.

/// Write named sections to `path` in LGCK v2 framing (atomic + CRC32).
pub fn write_sections(path: impl AsRef<Path>, sections: &[(&str, Vec<u8>)]) -> Result<()> {
    let total: usize = sections.iter().map(|(n, p)| 16 + n.len() + p.len()).sum();
    let mut out = Vec::with_capacity(12 + total);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(sections.len() as u32).to_le_bytes());
    for (name, payload) in sections {
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        out.extend_from_slice(payload);
        out.extend_from_slice(&crc32(payload).to_le_bytes());
    }
    write_atomic(path, &out)
}

fn parse_sections(bytes: &[u8]) -> Result<Vec<(String, Vec<u8>)>> {
    let mut c = Cur::new(bytes);
    if c.take(4, "magic").map_err(|_| Error::msg("not a LGCK checkpoint (too short)"))? != MAGIC {
        bail!("not a LGCK checkpoint (bad magic)");
    }
    let version = c.u32("format version")?;
    if version != VERSION {
        bail!("unsupported checkpoint version {version} (this build reads v{VERSION})");
    }
    let n = c.u32("section count")? as usize;
    // Every section occupies ≥ 16 header/CRC bytes, so a count that cannot
    // fit in the remaining file is rejected before any per-section work.
    if n > c.remaining() / 16 {
        bail!("corrupt checkpoint: section count {n} exceeds file size");
    }
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let name_len = c.u32("section name length")? as usize;
        if name_len > c.remaining() {
            bail!("corrupt checkpoint: section {i} name length {name_len} exceeds file size");
        }
        let name = std::str::from_utf8(c.take(name_len, "section name")?)
            .map_err(|e| Error::msg(format!("corrupt checkpoint: section {i} name is not UTF-8: {e}")))?
            .to_string();
        let payload_len = c.u64("section payload length")?;
        if payload_len > c.remaining() as u64 {
            bail!("corrupt checkpoint: section '{name}' length {payload_len} exceeds file size");
        }
        let payload = c.take(payload_len as usize, "section payload")?;
        let stored = c.u32("section CRC")?;
        let actual = crc32(payload);
        if actual != stored {
            bail!(
                "corrupt checkpoint: section '{name}' CRC mismatch (stored {stored:#010x}, computed {actual:#010x})"
            );
        }
        out.push((name, payload.to_vec()));
    }
    Ok(out)
}

/// Read and CRC-verify all sections of an LGCK v2 file. Any malformation —
/// truncation, impossible lengths, checksum mismatch — is a typed error
/// naming the file and (where known) the damaged section.
pub fn read_sections(path: impl AsRef<Path>) -> Result<Vec<(String, Vec<u8>)>> {
    let path = path.as_ref();
    let bytes = std::fs::read(path).with_context(|| format!("open {path:?}"))?;
    parse_sections(&bytes).with_context(|| format!("load {path:?}"))
}

// ---------------------------------------------------------------------------
// Tensor-stream payload codec.

/// Encode a [`Store`] as the self-describing tensor-stream payload.
pub fn encode_store(store: &Store) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&(store.len() as u32).to_le_bytes());
    for (name, t) in store.iter() {
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        let dtype = match t.data {
            TensorData::F32(_) => 0u8,
            TensorData::I32(_) => 1u8,
        };
        out.push(dtype);
        out.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
        for d in &t.shape {
            out.extend_from_slice(&(*d as u64).to_le_bytes());
        }
        match &t.data {
            TensorData::F32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            TensorData::I32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }
    out
}

/// Decode a tensor-stream payload, validating every length against the
/// payload size before allocating.
pub fn decode_store(bytes: &[u8]) -> Result<Store> {
    let mut c = Cur::new(bytes);
    let n = c.u32("tensor count")? as usize;
    // Each tensor record occupies ≥ 9 bytes of header.
    if n > c.remaining() / 9 {
        bail!("corrupt checkpoint: tensor count {n} exceeds payload size");
    }
    let mut store = Store::new();
    for i in 0..n {
        let name_len = c.u32("tensor name length")? as usize;
        if name_len > c.remaining() {
            bail!("corrupt checkpoint: tensor {i} name length {name_len} exceeds payload");
        }
        let name = std::str::from_utf8(c.take(name_len, "tensor name")?)
            .map_err(|e| Error::msg(format!("corrupt checkpoint: tensor {i} name is not UTF-8: {e}")))?
            .to_string();
        let dtype = c.u8("dtype tag")?;
        let rank = c.u32("tensor rank")? as usize;
        if rank > MAX_RANK {
            bail!("corrupt checkpoint: tensor '{name}' rank {rank} exceeds limit {MAX_RANK}");
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            let d = c.u64("tensor dim")?;
            shape.push(usize::try_from(d).map_err(|_| {
                Error::msg(format!("corrupt checkpoint: tensor '{name}' dim {d} overflows usize"))
            })?);
        }
        let nbytes = shape
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .and_then(|count| count.checked_mul(4))
            .with_context(|| format!("corrupt checkpoint: tensor '{name}' shape {shape:?} overflows"))?;
        if nbytes > c.remaining() {
            bail!(
                "corrupt checkpoint: tensor '{name}' needs {nbytes} data bytes, {} available",
                c.remaining()
            );
        }
        let raw = c.take(nbytes, "tensor data")?;
        let t = match dtype {
            0 => Tensor::from_f32(
                &shape,
                raw.chunks_exact(4).map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])).collect(),
            ),
            1 => Tensor::from_i32(
                &shape,
                raw.chunks_exact(4).map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]])).collect(),
            ),
            d => bail!("corrupt checkpoint: tensor '{name}' has bad dtype tag {d}"),
        };
        store.insert(name, t);
    }
    if c.remaining() != 0 {
        bail!("corrupt checkpoint: {} trailing bytes after last tensor", c.remaining());
    }
    Ok(store)
}

// ---------------------------------------------------------------------------
// Store-level API.

/// Save a parameter [`Store`] (one `tensors` section), atomically.
pub fn save(store: &Store, path: impl AsRef<Path>) -> Result<()> {
    write_sections(path, &[("tensors", encode_store(store))])
}

/// Save a [`Store`] plus a JSON `meta` section (provenance: config,
/// pretrain steps, …) in one atomic file.
pub fn save_with_meta(store: &Store, path: impl AsRef<Path>, meta: &Json) -> Result<()> {
    write_sections(
        path,
        &[("meta", meta.to_string().into_bytes()), ("tensors", encode_store(store))],
    )
}

/// Load a parameter [`Store`], verifying framing and CRCs.
pub fn load(path: impl AsRef<Path>) -> Result<Store> {
    Ok(load_with_meta(path)?.0)
}

/// Load a [`Store`] along with its `meta` section (if present). Unknown
/// sections are ignored for forward compatibility.
pub fn load_with_meta(path: impl AsRef<Path>) -> Result<(Store, Option<Json>)> {
    let path = path.as_ref();
    let mut store = None;
    let mut meta = None;
    for (name, payload) in read_sections(path)? {
        match name.as_str() {
            "tensors" => {
                store = Some(
                    decode_store(&payload).with_context(|| format!("{path:?}: section 'tensors'"))?,
                );
            }
            "meta" => {
                let text = std::str::from_utf8(&payload)
                    .map_err(|e| Error::msg(format!("{path:?}: section 'meta' is not UTF-8: {e}")))?;
                meta = Some(
                    Json::parse(text)
                        .map_err(|e| Error::msg(format!("{path:?}: section 'meta': {e}")))?,
                );
            }
            _ => {}
        }
    }
    let store = store.with_context(|| format!("{path:?}: checkpoint has no 'tensors' section"))?;
    Ok((store, meta))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn sample_store() -> Store {
        let mut s = Store::new();
        s.insert("w", Tensor::from_f32(&[2, 3], vec![1., -2., 3., 4., 5.5, -6.]));
        s.insert("idx", Tensor::from_i32(&[4], vec![1, 2, 3, -4]));
        s.insert("scalar", Tensor::scalar_f32(7.25));
        s
    }

    fn test_dir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("ligo_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrip() {
        let s = sample_store();
        let path = test_dir().join("ck.lgck");
        save(&s, &path).unwrap();
        let l = load(&path).unwrap();
        assert_eq!(s, l);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn meta_roundtrip_and_plain_load_ignores_meta() {
        let s = sample_store();
        let path = test_dir().join("ck_meta.lgck");
        let meta = Json::obj(vec![("steps", Json::Num(40.0)), ("name", Json::Str("m".into()))]);
        save_with_meta(&s, &path, &meta).unwrap();
        let (l, m) = load_with_meta(&path).unwrap();
        assert_eq!(s, l);
        assert_eq!(m.unwrap().to_string(), meta.to_string());
        assert_eq!(load(&path).unwrap(), s);
        // A bare save has no meta.
        save(&s, &path).unwrap();
        assert!(load_with_meta(&path).unwrap().1.is_none());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = test_dir().join("junk.bin");
        std::fs::write(&path, b"NOPE....").unwrap();
        let e = load(&path).unwrap_err().to_string();
        assert!(e.contains("not a LGCK checkpoint"), "{e}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_v1_files_with_version_error() {
        let path = test_dir().join("v1.lgck");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&0u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let e = load(&path).unwrap_err().to_string();
        assert!(e.contains("unsupported checkpoint version 1"), "{e}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(load("/nonexistent/path/x.lgck").is_err());
    }

    #[test]
    fn bit_flip_on_disk_is_detected_with_section_name() {
        let s = sample_store();
        let path = test_dir().join("flip.lgck");
        save(&s, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2; // lands inside the tensors payload
        bytes[mid] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let e = load(&path).unwrap_err().to_string();
        assert!(e.contains("CRC mismatch") && e.contains("'tensors'"), "{e}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn truncation_is_a_typed_error_not_a_panic() {
        let s = sample_store();
        let path = test_dir().join("trunc.lgck");
        save(&s, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        let e = load(&path).unwrap_err().to_string();
        assert!(e.contains("corrupt checkpoint"), "{e}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn injected_torn_write_is_caught_on_load() {
        let s = sample_store();
        let path = test_dir().join("torn.lgck");
        crate::util::fault::set_override(Some(Fault::TornWrite));
        save(&s, &path).unwrap(); // reports success — the tear is silent
        crate::util::fault::clear_override();
        assert!(load(&path).is_err(), "torn checkpoint must fail verification");
        // The fault is one-shot: a re-save heals the file.
        save(&s, &path).unwrap();
        assert_eq!(load(&path).unwrap(), s);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn injected_bit_flip_is_caught_on_load() {
        let s = sample_store();
        let path = test_dir().join("bitflip.lgck");
        crate::util::fault::set_override(Some(Fault::BitFlip));
        save(&s, &path).unwrap();
        crate::util::fault::clear_override();
        let e = load(&path).unwrap_err().to_string();
        assert!(e.contains("corrupt checkpoint"), "{e}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn garbage_corpus_never_panics_and_mutations_are_detected() {
        let s = sample_store();
        let valid = {
            let path = test_dir().join("prop_base.lgck");
            save(&s, &path).unwrap();
            let b = std::fs::read(&path).unwrap();
            std::fs::remove_file(path).ok();
            b
        };
        prop::check("io_garbage", 32, |g| {
            let path = test_dir().join(format!("prop_{}.lgck", g.seed));
            let bytes = match g.usize_in(0, 2) {
                // Pure random garbage (sometimes starting with the magic).
                0 => {
                    let n = g.usize_in(0, 96);
                    let mut b: Vec<u8> =
                        (0..n).map(|_| (g.rng.next_u64() & 0xFF) as u8).collect();
                    if g.bool() && b.len() >= 4 {
                        b[..4].copy_from_slice(MAGIC);
                    }
                    b
                }
                // A valid checkpoint with one byte flipped: every byte is
                // covered by magic/version/length validation or a CRC, so
                // any single flip must be detected.
                1 => {
                    let mut b = valid.clone();
                    let i = g.usize_in(0, b.len() - 1);
                    let bit = 1u8 << g.usize_in(0, 7);
                    b[i] ^= bit;
                    b
                }
                // A valid checkpoint truncated at a random point.
                _ => {
                    let cut = g.usize_in(0, valid.len() - 1);
                    valid[..cut].to_vec()
                }
            };
            std::fs::write(&path, &bytes).unwrap();
            let r = load(&path); // must return, never panic
            assert!(r.is_err(), "mutated/garbage checkpoint accepted at seed {}", g.seed);
            std::fs::remove_file(path).ok();
        });
    }

    #[test]
    fn decode_store_rejects_absurd_lengths_without_allocating() {
        // Tensor count far beyond payload size.
        let mut b = Vec::new();
        b.extend_from_slice(&u32::MAX.to_le_bytes());
        let e = decode_store(&b).unwrap_err().to_string();
        assert!(e.contains("tensor count"), "{e}");
        // One tensor whose dims multiply past usize.
        let mut b = Vec::new();
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&1u32.to_le_bytes());
        b.push(b'x');
        b.push(0); // dtype f32
        b.extend_from_slice(&2u32.to_le_bytes()); // rank 2
        b.extend_from_slice(&(u64::from(u32::MAX)).to_le_bytes());
        b.extend_from_slice(&(u64::from(u32::MAX)).to_le_bytes());
        let e = decode_store(&b).unwrap_err().to_string();
        assert!(e.contains("overflow") || e.contains("needs"), "{e}");
    }
}
