//! Deterministic parameter initialization — bit-identical to
//! `python/compile/detinit.py` so that goldens emitted at AOT time validate
//! the whole cross-language path.
//!
//! Scheme: seed = low32(FNV-1a(name) ^ global_seed); value_i derived from
//! counter-based mix32(seed + i * GOLDEN); scale chosen by name suffix.

use super::Tensor;
use crate::util::rng::{fnv1a, mix32};

const GOLDEN: u32 = 0x9E3779B9;

/// The per-tensor init rule, by parameter name (mirrors detinit.tensor_scale).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum InitRule {
    ConstOne,
    ConstTenth,
    Zero,
    Uniform(f32),
}

pub fn rule_for(name: &str, shape: &[usize]) -> InitRule {
    if name.ends_with("_g") {
        return InitRule::ConstOne;
    }
    if name.ends_with("ls1") || name.ends_with("ls2") {
        return InitRule::ConstTenth;
    }
    if name.ends_with("_b") || name == "mlm_bias" {
        return InitRule::Zero;
    }
    if name.starts_with("emb_") || name == "head_w" || name == "span_w" {
        return InitRule::Uniform(0.02);
    }
    if shape.len() == 2 {
        let (fan_out, fan_in) = (shape[0] as f32, shape[1] as f32);
        return InitRule::Uniform((6.0 / (fan_in + fan_out)).sqrt());
    }
    InitRule::Uniform(0.02)
}

/// Deterministically fill a named tensor (identical to python det_fill).
pub fn det_fill(name: &str, shape: &[usize], global_seed: u64) -> Tensor {
    let n = super::numel(shape);
    match rule_for(name, shape) {
        InitRule::ConstOne => Tensor::from_f32(shape, vec![1.0; n]),
        InitRule::ConstTenth => Tensor::from_f32(shape, vec![0.1; n]),
        InitRule::Zero => Tensor::zeros(shape),
        InitRule::Uniform(scale) => {
            let seed = ((fnv1a(name) ^ global_seed) & 0xFFFF_FFFF) as u32;
            let mut data = Vec::with_capacity(n);
            for i in 0..n as u32 {
                let z = mix32(seed.wrapping_add(i.wrapping_mul(GOLDEN)));
                let u = z as f64 / 4294967296.0;
                data.push(((u - 0.5) * 2.0 * scale as f64) as f32);
            }
            Tensor::from_f32(shape, data)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rules_by_suffix() {
        assert_eq!(rule_for("L00_ln1_g", &[48]), InitRule::ConstOne);
        assert_eq!(rule_for("L00_q_b", &[48]), InitRule::Zero);
        assert_eq!(rule_for("mlm_bias", &[512]), InitRule::Zero);
        assert_eq!(rule_for("L03_ls1", &[48]), InitRule::ConstTenth);
        assert_eq!(rule_for("emb_tok", &[512, 48]), InitRule::Uniform(0.02));
        match rule_for("L00_q_w", &[48, 48]) {
            InitRule::Uniform(s) => assert!((s - (6.0f32 / 96.0).sqrt()).abs() < 1e-6),
            r => panic!("{r:?}"),
        }
    }

    #[test]
    fn deterministic_and_name_dependent() {
        let a = det_fill("L00_q_w", &[8, 8], 0);
        let b = det_fill("L00_q_w", &[8, 8], 0);
        let c = det_fill("L00_k_w", &[8, 8], 0);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn seed_changes_values() {
        let a = det_fill("L00_q_w", &[8, 8], 0);
        let b = det_fill("L00_q_w", &[8, 8], 1);
        assert_ne!(a, b);
    }

    #[test]
    fn values_within_scale() {
        let t = det_fill("emb_tok", &[32, 16], 0);
        for v in t.f32s() {
            assert!(v.abs() <= 0.02 + 1e-6);
        }
        // roughly centered
        let mean: f32 = t.f32s().iter().sum::<f32>() / t.numel() as f32;
        assert!(mean.abs() < 0.005);
    }
}
