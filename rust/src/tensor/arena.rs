//! Thread-local f32 buffer arena — activation/gradient recycling for the
//! native engine's hot loop.
//!
//! Every forward/backward over the tape (and every growth expansion)
//! produces a burst of short-lived `Vec<f32>` buffers of the *same* size
//! multiset step after step. Instead of round-tripping each one through the
//! allocator (malloc + page-zeroing per microbatch), the tensor kernels
//! draw buffers from this pool ([`alloc_zeroed`], [`alloc_copy`],
//! [`alloc_scratch`]) and the owners hand them back when a tape or a
//! gradient store dies
//! ([`recycle`], [`recycle_store`], [`recycle_buf`]). Between two
//! `Trainer::train_step` calls the pool therefore holds about one step's
//! worth of buffers and the steady state allocates nothing fresh (asserted
//! by `model::tests::forward_borrows_params_and_reuses_arena_buffers`);
//! the pool is hard-capped by count *and* bytes, so buffers that flow in
//! from outside the arena (plain-allocated tensors are pooled too) cannot
//! grow it without bound.
//!
//! The pool is **thread-local**: the coordinator, the native engine and the
//! growth manager all run their allocating code on the calling thread (the
//! `util::par` workers only fill caller-owned buffers), so no locking is
//! needed and tests stay isolated. Best-fit matching (smallest sufficient
//! capacity) keeps a heterogeneous multiset reusable in any request order.
//!
//! One exception: the `LIGO_WORKERS` data-parallel trainer runs each step's
//! microbatches on *fresh scoped threads*, whose thread-local pools start
//! empty. A mutex-guarded **shared overflow pool** bridges the steps:
//! worker threads opt in ([`set_shared_draw`]) to fall back to it on a
//! local miss, flush their local pool into it when their task ends
//! ([`flush_to_shared`]), and the coordinator recycles dead reduced
//! gradient stores into it ([`recycle_store_shared`]) — so step `k+1`'s
//! workers reuse step `k`'s buffers and the multi-worker steady state also
//! allocates nothing fresh (per-worker counters: [`worker_stats`]). Threads
//! that never opt in never touch the mutex.
//!
//! Knob: `LIGO_ARENA=0` disables pooling (every request is a fresh
//! allocation, every recycle a plain drop) for A/B runs — see
//! EXPERIMENTS.md. Correctness never depends on the pool: a recycled
//! buffer is resized and re-zeroed before it is handed out again.

use std::cell::{Cell, RefCell};
use std::sync::{Mutex, OnceLock};

use super::{Tensor, TensorData};
use crate::tensor::store::Store;

/// Pool count bound: buffers past this are dropped on recycle instead of
/// pooled (a runaway guard; one train step needs far fewer).
const MAX_POOLED: usize = 1024;

/// Pool byte bound (256 MiB): recycling drops buffers that would push the
/// pooled total past this, so a long run's steady-state memory is capped
/// even when more buffers flow in (plain-allocated tensors are accepted
/// into the pool too) than the kernels draw out.
const MAX_POOLED_BYTES: usize = 256 << 20;

#[derive(Default)]
struct Pool {
    free: Vec<Vec<f32>>,
    bytes: usize,
    fresh: u64,
    reused: u64,
    /// Largest single request (in f32 elements) since [`reset_stats`] — the
    /// high-water mark memory-discipline tests assert against (e.g. "no
    /// `(rows, vocab)` logits buffer is ever requested with the streaming
    /// LM head on").
    peak_request: usize,
}

/// Best-fit extraction: the smallest buffer with capacity >= n.
fn best_fit(free: &mut Vec<Vec<f32>>, n: usize) -> Option<Vec<f32>> {
    let mut best: Option<(usize, usize)> = None;
    for (i, b) in free.iter().enumerate() {
        let cap = b.capacity();
        let better = match best {
            None => true,
            Some((_, best_cap)) => cap < best_cap,
        };
        if cap >= n && better {
            best = Some((i, cap));
            if cap == n {
                break;
            }
        }
    }
    best.map(|(i, _)| free.swap_remove(i))
}

fn take_fit(pool: &mut Pool, n: usize) -> Option<Vec<f32>> {
    let b = best_fit(&mut pool.free, n)?;
    pool.bytes -= b.capacity() * 4;
    Some(b)
}

/// Local-first extraction with the shared-pool fallback for opted-in
/// threads (the parallel trainer's scoped workers).
fn take_any(pool: &mut Pool, n: usize) -> Option<Vec<f32>> {
    take_fit(pool, n).or_else(|| shared_take(n))
}

thread_local! {
    static POOL: RefCell<Pool> = RefCell::new(Pool::default());

    /// Whether allocations on this thread may fall back to [`SHARED`] on a
    /// local-pool miss. Off by default so ordinary (serial) threads never
    /// touch the mutex and never steal another task's buffers.
    static DRAW_SHARED: Cell<bool> = const { Cell::new(false) };
}

/// The cross-thread overflow pool's lock-agnostic core (see the module
/// docs). `bytes` tracks the pooled capacity so the same byte cap applies
/// as to a local pool. The type carries no lock of its own: the process
/// pool wraps it in [`SHARED`]'s `std::sync::Mutex`, and the concurrency
/// model tests (`tests/loom_models.rs`, `--cfg loom`) drive *this exact
/// logic* under `loom::sync::Mutex` across explored interleavings — which
/// is why the invariants (`bytes` = 4 × summed capacity, both caps) are
/// public methods here rather than properties of the lock site.
pub struct OverflowPool {
    free: Vec<Vec<f32>>,
    bytes: usize,
    max_pooled: usize,
    max_bytes: usize,
}

impl OverflowPool {
    pub const fn new(max_pooled: usize, max_bytes: usize) -> Self {
        OverflowPool { free: Vec::new(), bytes: 0, max_pooled, max_bytes }
    }

    /// Best-fit extraction of a buffer with capacity >= `n`.
    pub fn take(&mut self, n: usize) -> Option<Vec<f32>> {
        let b = best_fit(&mut self.free, n)?;
        self.bytes -= b.capacity() * 4;
        Some(b)
    }

    /// Offer a buffer to the pool; returns `false` (dropping the buffer)
    /// when either the count or the byte cap would be exceeded.
    pub fn put(&mut self, buf: Vec<f32>) -> bool {
        let bytes = buf.capacity() * 4;
        if self.free.len() < self.max_pooled && self.bytes + bytes <= self.max_bytes {
            self.bytes += bytes;
            self.free.push(buf);
            true
        } else {
            false
        }
    }

    pub fn len(&self) -> usize {
        self.free.len()
    }

    pub fn is_empty(&self) -> bool {
        self.free.is_empty()
    }

    /// Pooled capacity in bytes (the cap accounting).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    pub fn clear(&mut self) {
        self.free.clear();
        self.bytes = 0;
    }

    /// Check the pool's internal accounting invariants — what the loom
    /// model tests assert after every explored interleaving.
    pub fn check_invariants(&self) -> Result<(), String> {
        let sum: usize = self.free.iter().map(|b| b.capacity() * 4).sum();
        if sum != self.bytes {
            return Err(format!("bytes accounting drifted: tracked {} real {sum}", self.bytes));
        }
        if self.free.len() > self.max_pooled {
            return Err(format!("count cap exceeded: {} > {}", self.free.len(), self.max_pooled));
        }
        if self.bytes > self.max_bytes {
            return Err(format!("byte cap exceeded: {} > {}", self.bytes, self.max_bytes));
        }
        Ok(())
    }
}

static SHARED: Mutex<OverflowPool> =
    Mutex::new(OverflowPool::new(SHARED_MAX_POOLED, MAX_POOLED_BYTES));

/// Count bound for [`SHARED`]: it aggregates every worker's flushed pool,
/// so it gets more headroom than a single thread-local pool.
const SHARED_MAX_POOLED: usize = 4 * MAX_POOLED;

fn shared(guarded: &Mutex<OverflowPool>) -> std::sync::MutexGuard<'_, OverflowPool> {
    // a worker panicking mid-recycle poisons nothing worse than a buffer
    // list; keep serving the surviving threads
    guarded.lock().unwrap_or_else(|e| e.into_inner())
}

/// Opt this thread in/out of drawing from the shared overflow pool on a
/// local-pool miss. Worker threads of the data-parallel trainer enable
/// this; everything else stays purely thread-local.
pub fn set_shared_draw(on: bool) {
    DRAW_SHARED.with(|c| c.set(on));
}

fn shared_take(n: usize) -> Option<Vec<f32>> {
    if !DRAW_SHARED.with(|c| c.get()) {
        return None;
    }
    shared(&SHARED).take(n)
}

/// Return a raw buffer directly to the shared pool (the coordinator
/// recycling reduced gradient stores for the *next* step's workers).
pub fn recycle_buf_shared(buf: Vec<f32>) {
    if !enabled() || buf.capacity() == 0 {
        return;
    }
    shared(&SHARED).put(buf);
}

/// Recycle every f32 tensor of a dead store into the *shared* pool (the
/// tree all-reduce's consumed leaves, the optimizer-consumed accumulator).
pub fn recycle_store_shared(s: Store) {
    for (_name, t) in s.into_entries() {
        if let TensorData::F32(v) = t.data {
            recycle_buf_shared(v);
        }
    }
}

/// Move this thread's entire local pool into the shared pool (a parallel
/// worker handing its buffers to the next step's workers before its scoped
/// thread dies). Buffers past the shared caps are dropped.
pub fn flush_to_shared() {
    if !enabled() {
        return;
    }
    POOL.with(|p| {
        let mut pool = p.borrow_mut();
        if pool.free.is_empty() {
            return;
        }
        let mut sh = shared(&SHARED);
        while let Some(b) = pool.free.pop() {
            pool.bytes -= b.capacity() * 4;
            sh.put(b);
        }
    });
}

/// (buffer count, pooled bytes) of the shared overflow pool — diagnostics.
pub fn shared_stats() -> (usize, usize) {
    let sh = shared(&SHARED);
    (sh.len(), sh.bytes())
}

/// Drop every buffer in the shared overflow pool (tests; memory pressure).
pub fn clear_shared() {
    shared(&SHARED).clear();
}

/// Pool enabled unless `LIGO_ARENA=0` (knob registry; read once per
/// process).
pub fn enabled() -> bool {
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| !crate::util::knobs::flag_disabled("LIGO_ARENA"))
}

/// A zeroed f32 buffer of length `n`: best-fit reuse from the pool when
/// possible, fresh allocation otherwise. Counted in [`stats`].
pub fn alloc_zeroed(n: usize) -> Vec<f32> {
    if !enabled() || n == 0 {
        return vec![0.0; n];
    }
    POOL.with(|p| {
        let mut pool = p.borrow_mut();
        pool.peak_request = pool.peak_request.max(n);
        match take_any(&mut pool, n) {
            Some(mut b) => {
                b.clear();
                b.resize(n, 0.0);
                pool.reused += 1;
                b
            }
            None => {
                pool.fresh += 1;
                vec![0.0; n]
            }
        }
    })
}

/// A pool-backed buffer of length `n` with **unspecified contents** (stale
/// f32 values from a previous use; zeros when freshly allocated) — for
/// consumers that overwrite every element before reading, e.g. the packed
/// transpose scratch. Skips the re-zeroing pass [`alloc_zeroed`] pays on
/// reuse. Counted in [`stats`].
pub fn alloc_scratch(n: usize) -> Vec<f32> {
    if !enabled() || n == 0 {
        return vec![0.0; n];
    }
    POOL.with(|p| {
        let mut pool = p.borrow_mut();
        pool.peak_request = pool.peak_request.max(n);
        match take_any(&mut pool, n) {
            Some(mut b) => {
                if b.len() >= n {
                    b.truncate(n); // keep stale values: caller overwrites all
                } else {
                    b.resize(n, 0.0); // only the tail is written here
                }
                pool.reused += 1;
                b
            }
            None => {
                pool.fresh += 1;
                vec![0.0; n]
            }
        }
    })
}

/// A pool-backed buffer initialized as a copy of `src` (no zeroing pass) —
/// what the tape's clone-then-mutate ops (residual adds, broadcasts) use
/// instead of `Vec::clone`, so their per-step traffic stays inside the
/// pool. Counted in [`stats`].
pub fn alloc_copy(src: &[f32]) -> Vec<f32> {
    if !enabled() || src.is_empty() {
        return src.to_vec();
    }
    POOL.with(|p| {
        let mut pool = p.borrow_mut();
        pool.peak_request = pool.peak_request.max(src.len());
        match take_any(&mut pool, src.len()) {
            Some(mut b) => {
                b.clear();
                b.extend_from_slice(src);
                pool.reused += 1;
                b
            }
            None => {
                pool.fresh += 1;
                src.to_vec()
            }
        }
    })
}

/// Return a raw buffer to the pool (kernels recycling internal scratch,
/// e.g. a packed transpose; also accepts buffers that were allocated
/// outside the arena — the pool takes any capacity).
pub fn recycle_buf(buf: Vec<f32>) {
    if !enabled() || buf.capacity() == 0 {
        return;
    }
    POOL.with(|p| {
        let mut pool = p.borrow_mut();
        let bytes = buf.capacity() * 4;
        if pool.free.len() < MAX_POOLED && pool.bytes + bytes <= MAX_POOLED_BYTES {
            pool.bytes += bytes;
            pool.free.push(buf);
        }
    });
}

/// Return a dead tensor's storage to the pool (f32 only; i32 just drops).
pub fn recycle(t: Tensor) {
    if let TensorData::F32(v) = t.data {
        recycle_buf(v);
    }
}

/// Recycle every f32 tensor of a dead store (e.g. the per-microbatch
/// gradient store after the optimizer consumed it).
pub fn recycle_store(s: Store) {
    for (_name, t) in s.into_entries() {
        recycle(t);
    }
}

/// (fresh allocations, pool reuses) on this thread since [`reset_stats`].
pub fn stats() -> (u64, u64) {
    POOL.with(|p| {
        let pool = p.borrow();
        (pool.fresh, pool.reused)
    })
}

/// Largest single buffer request (f32 elements) on this thread since
/// [`reset_stats`] — fresh or reused alike. Memory-discipline regression
/// tests assert this stays strictly below `rows * vocab` when the streaming
/// LM head is on (no materialized logits anywhere in a train step).
pub fn peak_request() -> usize {
    POOL.with(|p| p.borrow().peak_request)
}

/// Zero this thread's counters (the pool contents stay).
pub fn reset_stats() {
    POOL.with(|p| {
        let mut pool = p.borrow_mut();
        pool.fresh = 0;
        pool.reused = 0;
        pool.peak_request = 0;
    });
}

/// Drop every pooled buffer on this thread (tests; memory pressure).
pub fn clear() {
    POOL.with(|p| {
        let mut pool = p.borrow_mut();
        pool.free.clear();
        pool.bytes = 0;
    });
}

/// Arena counters of one data-parallel worker for one task. Scoped worker
/// threads are born with zeroed counters, so a snapshot at task end *is*
/// the per-step measurement — no reset bookkeeping needed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerStats {
    /// Worker index within the step's pool.
    pub worker: usize,
    /// Microbatches this worker processed.
    pub microbatches: usize,
    /// Fresh allocations (0 in the multi-worker steady state — the
    /// regression the parallel zero-fresh-alloc test asserts).
    pub fresh: u64,
    /// Pool reuses (local pool or shared-pool fallback).
    pub reused: u64,
    /// Largest single buffer request, in f32 elements.
    pub peak_request: usize,
}

/// Snapshot this thread's counters as a worker's per-task stats (called by
/// a `coordinator::parallel` worker right before it flushes and exits).
pub fn worker_stats(worker: usize, microbatches: usize) -> WorkerStats {
    POOL.with(|p| {
        let pool = p.borrow();
        WorkerStats {
            worker,
            microbatches,
            fresh: pool.fresh,
            reused: pool.reused,
            peak_request: pool.peak_request,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_recycle_alloc_reuses_the_buffer() {
        if !enabled() {
            return; // LIGO_ARENA=0 run: nothing to assert
        }
        clear();
        reset_stats();
        let a = alloc_zeroed(64);
        let (f1, _) = stats();
        assert!(f1 >= 1);
        recycle_buf(a);
        let b = alloc_zeroed(64);
        let (f2, r2) = stats();
        assert_eq!(f2, f1, "second alloc must come from the pool");
        assert!(r2 >= 1);
        assert_eq!(b.len(), 64);
        assert!(b.iter().all(|&x| x == 0.0), "recycled buffers are re-zeroed");
    }

    #[test]
    fn best_fit_prefers_the_smallest_sufficient_buffer() {
        if !enabled() {
            return;
        }
        clear();
        recycle_buf(vec![1.0; 256]);
        recycle_buf(vec![1.0; 32]);
        let b = alloc_zeroed(20);
        assert!(b.capacity() < 256, "small request must not burn the big buffer");
        clear();
    }

    #[test]
    fn peak_request_tracks_high_water_and_resets() {
        if !enabled() {
            return;
        }
        reset_stats();
        let a = alloc_zeroed(16);
        let b = alloc_scratch(64);
        let c = alloc_copy(&[1.0; 32]);
        assert_eq!(peak_request(), 64, "largest request wins");
        recycle_buf(a);
        recycle_buf(b);
        recycle_buf(c);
        reset_stats();
        assert_eq!(peak_request(), 0, "reset clears the high-water mark");
    }

    #[test]
    fn shared_pool_bridges_threads_for_opted_in_workers() {
        if !enabled() {
            return;
        }
        // An odd, large capacity no other concurrently-running test
        // requests, so the cross-thread handoff is observable even though
        // the shared pool is process-global.
        const N: usize = 1_000_003;
        recycle_buf_shared(Vec::with_capacity(N));
        // a thread that does NOT opt in must not see the shared buffer
        let stole = std::thread::spawn(|| {
            clear();
            reset_stats();
            let b = alloc_zeroed(N);
            let (fresh, _) = stats();
            recycle_buf(b); // stays local, dropped with the thread
            fresh == 0
        })
        .join()
        .unwrap();
        assert!(!stole, "non-worker threads must never draw from the shared pool");
        // an opted-in worker thread reuses it (fresh stays 0 for this size)
        let reused_from_shared = std::thread::spawn(|| {
            clear();
            reset_stats();
            set_shared_draw(true);
            let b = alloc_zeroed(N);
            let (fresh, reused) = stats();
            let got = b.capacity() >= N && fresh == 0 && reused >= 1;
            recycle_buf(b);
            flush_to_shared(); // hand it back for whoever runs next
            got
        })
        .join()
        .unwrap();
        assert!(reused_from_shared, "opted-in worker must draw from the shared pool");
    }

    #[test]
    fn worker_stats_snapshot_counts_this_thread_only() {
        if !enabled() {
            return;
        }
        let st = std::thread::spawn(|| {
            let a = alloc_zeroed(48);
            recycle_buf(a);
            let b = alloc_zeroed(40); // best-fit reuse of the 48-cap buffer
            recycle_buf(b);
            worker_stats(3, 2)
        })
        .join()
        .unwrap();
        assert_eq!(st.worker, 3);
        assert_eq!(st.microbatches, 2);
        assert_eq!((st.fresh, st.reused), (1, 1));
        assert_eq!(st.peak_request, 48);
    }

    #[test]
    fn overflow_pool_enforces_caps_and_accounting() {
        let mut p = OverflowPool::new(2, 64);
        assert!(p.put(Vec::with_capacity(4))); // 16 bytes
        assert!(p.put(Vec::with_capacity(8))); // 48 bytes
        assert!(!p.put(Vec::with_capacity(1)), "count cap must reject a third buffer");
        p.check_invariants().unwrap();
        let b = p.take(5).expect("8-cap buffer satisfies a 5-element request");
        assert!(b.capacity() >= 5);
        assert!(!p.put(Vec::with_capacity(16)), "byte cap: 16 + 64 > 64");
        assert!(p.put(Vec::with_capacity(8)));
        p.check_invariants().unwrap();
        assert_eq!(p.len(), 2);
        assert_eq!(p.bytes(), 48);
        p.clear();
        assert!(p.is_empty());
        assert_eq!(p.bytes(), 0);
        p.check_invariants().unwrap();
    }

    #[test]
    fn recycle_ignores_i32_and_zero_len() {
        clear();
        recycle(Tensor::from_i32(&[2], vec![1, 2]));
        recycle(Tensor::from_f32(&[0], vec![]));
        let n = POOL.with(|p| p.borrow().free.len());
        assert_eq!(n, 0);
    }
}
