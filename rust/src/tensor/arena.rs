//! Thread-local f32 buffer arena — activation/gradient recycling for the
//! native engine's hot loop.
//!
//! Every forward/backward over the tape (and every growth expansion)
//! produces a burst of short-lived `Vec<f32>` buffers of the *same* size
//! multiset step after step. Instead of round-tripping each one through the
//! allocator (malloc + page-zeroing per microbatch), the tensor kernels
//! draw buffers from this pool ([`alloc_zeroed`], [`alloc_copy`],
//! [`alloc_scratch`]) and the owners hand them back when a tape or a
//! gradient store dies
//! ([`recycle`], [`recycle_store`], [`recycle_buf`]). Between two
//! `Trainer::train_step` calls the pool therefore holds about one step's
//! worth of buffers and the steady state allocates nothing fresh (asserted
//! by `model::tests::forward_borrows_params_and_reuses_arena_buffers`);
//! the pool is hard-capped by count *and* bytes, so buffers that flow in
//! from outside the arena (plain-allocated tensors are pooled too) cannot
//! grow it without bound.
//!
//! The pool is **thread-local**: the coordinator, the native engine and the
//! growth manager all run their allocating code on the calling thread (the
//! `util::par` workers only fill caller-owned buffers), so no locking is
//! needed and tests stay isolated. Best-fit matching (smallest sufficient
//! capacity) keeps a heterogeneous multiset reusable in any request order.
//!
//! Knob: `LIGO_ARENA=0` disables pooling (every request is a fresh
//! allocation, every recycle a plain drop) for A/B runs — see
//! EXPERIMENTS.md. Correctness never depends on the pool: a recycled
//! buffer is resized and re-zeroed before it is handed out again.

use std::cell::RefCell;
use std::sync::OnceLock;

use super::{Tensor, TensorData};
use crate::tensor::store::Store;

/// Pool count bound: buffers past this are dropped on recycle instead of
/// pooled (a runaway guard; one train step needs far fewer).
const MAX_POOLED: usize = 1024;

/// Pool byte bound (256 MiB): recycling drops buffers that would push the
/// pooled total past this, so a long run's steady-state memory is capped
/// even when more buffers flow in (plain-allocated tensors are accepted
/// into the pool too) than the kernels draw out.
const MAX_POOLED_BYTES: usize = 256 << 20;

#[derive(Default)]
struct Pool {
    free: Vec<Vec<f32>>,
    bytes: usize,
    fresh: u64,
    reused: u64,
    /// Largest single request (in f32 elements) since [`reset_stats`] — the
    /// high-water mark memory-discipline tests assert against (e.g. "no
    /// `(rows, vocab)` logits buffer is ever requested with the streaming
    /// LM head on").
    peak_request: usize,
}

/// Best-fit extraction: the smallest pooled buffer with capacity >= n.
fn take_fit(pool: &mut Pool, n: usize) -> Option<Vec<f32>> {
    let mut best: Option<(usize, usize)> = None;
    for (i, b) in pool.free.iter().enumerate() {
        let cap = b.capacity();
        let better = match best {
            None => true,
            Some((_, best_cap)) => cap < best_cap,
        };
        if cap >= n && better {
            best = Some((i, cap));
            if cap == n {
                break;
            }
        }
    }
    best.map(|(i, cap)| {
        pool.bytes -= cap * 4;
        pool.free.swap_remove(i)
    })
}

thread_local! {
    static POOL: RefCell<Pool> = RefCell::new(Pool::default());
}

/// Pool enabled unless `LIGO_ARENA=0` (read once per process).
pub fn enabled() -> bool {
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| !matches!(std::env::var("LIGO_ARENA").as_deref(), Ok("0")))
}

/// A zeroed f32 buffer of length `n`: best-fit reuse from the pool when
/// possible, fresh allocation otherwise. Counted in [`stats`].
pub fn alloc_zeroed(n: usize) -> Vec<f32> {
    if !enabled() || n == 0 {
        return vec![0.0; n];
    }
    POOL.with(|p| {
        let mut pool = p.borrow_mut();
        pool.peak_request = pool.peak_request.max(n);
        match take_fit(&mut pool, n) {
            Some(mut b) => {
                b.clear();
                b.resize(n, 0.0);
                pool.reused += 1;
                b
            }
            None => {
                pool.fresh += 1;
                vec![0.0; n]
            }
        }
    })
}

/// A pool-backed buffer of length `n` with **unspecified contents** (stale
/// f32 values from a previous use; zeros when freshly allocated) — for
/// consumers that overwrite every element before reading, e.g. the packed
/// transpose scratch. Skips the re-zeroing pass [`alloc_zeroed`] pays on
/// reuse. Counted in [`stats`].
pub fn alloc_scratch(n: usize) -> Vec<f32> {
    if !enabled() || n == 0 {
        return vec![0.0; n];
    }
    POOL.with(|p| {
        let mut pool = p.borrow_mut();
        pool.peak_request = pool.peak_request.max(n);
        match take_fit(&mut pool, n) {
            Some(mut b) => {
                if b.len() >= n {
                    b.truncate(n); // keep stale values: caller overwrites all
                } else {
                    b.resize(n, 0.0); // only the tail is written here
                }
                pool.reused += 1;
                b
            }
            None => {
                pool.fresh += 1;
                vec![0.0; n]
            }
        }
    })
}

/// A pool-backed buffer initialized as a copy of `src` (no zeroing pass) —
/// what the tape's clone-then-mutate ops (residual adds, broadcasts) use
/// instead of `Vec::clone`, so their per-step traffic stays inside the
/// pool. Counted in [`stats`].
pub fn alloc_copy(src: &[f32]) -> Vec<f32> {
    if !enabled() || src.is_empty() {
        return src.to_vec();
    }
    POOL.with(|p| {
        let mut pool = p.borrow_mut();
        pool.peak_request = pool.peak_request.max(src.len());
        match take_fit(&mut pool, src.len()) {
            Some(mut b) => {
                b.clear();
                b.extend_from_slice(src);
                pool.reused += 1;
                b
            }
            None => {
                pool.fresh += 1;
                src.to_vec()
            }
        }
    })
}

/// Return a raw buffer to the pool (kernels recycling internal scratch,
/// e.g. a packed transpose; also accepts buffers that were allocated
/// outside the arena — the pool takes any capacity).
pub fn recycle_buf(buf: Vec<f32>) {
    if !enabled() || buf.capacity() == 0 {
        return;
    }
    POOL.with(|p| {
        let mut pool = p.borrow_mut();
        let bytes = buf.capacity() * 4;
        if pool.free.len() < MAX_POOLED && pool.bytes + bytes <= MAX_POOLED_BYTES {
            pool.bytes += bytes;
            pool.free.push(buf);
        }
    });
}

/// Return a dead tensor's storage to the pool (f32 only; i32 just drops).
pub fn recycle(t: Tensor) {
    if let TensorData::F32(v) = t.data {
        recycle_buf(v);
    }
}

/// Recycle every f32 tensor of a dead store (e.g. the per-microbatch
/// gradient store after the optimizer consumed it).
pub fn recycle_store(s: Store) {
    for (_name, t) in s.into_entries() {
        recycle(t);
    }
}

/// (fresh allocations, pool reuses) on this thread since [`reset_stats`].
pub fn stats() -> (u64, u64) {
    POOL.with(|p| {
        let pool = p.borrow();
        (pool.fresh, pool.reused)
    })
}

/// Largest single buffer request (f32 elements) on this thread since
/// [`reset_stats`] — fresh or reused alike. Memory-discipline regression
/// tests assert this stays strictly below `rows * vocab` when the streaming
/// LM head is on (no materialized logits anywhere in a train step).
pub fn peak_request() -> usize {
    POOL.with(|p| p.borrow().peak_request)
}

/// Zero this thread's counters (the pool contents stay).
pub fn reset_stats() {
    POOL.with(|p| {
        let mut pool = p.borrow_mut();
        pool.fresh = 0;
        pool.reused = 0;
        pool.peak_request = 0;
    });
}

/// Drop every pooled buffer on this thread (tests; memory pressure).
pub fn clear() {
    POOL.with(|p| {
        let mut pool = p.borrow_mut();
        pool.free.clear();
        pool.bytes = 0;
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_recycle_alloc_reuses_the_buffer() {
        if !enabled() {
            return; // LIGO_ARENA=0 run: nothing to assert
        }
        clear();
        reset_stats();
        let a = alloc_zeroed(64);
        let (f1, _) = stats();
        assert!(f1 >= 1);
        recycle_buf(a);
        let b = alloc_zeroed(64);
        let (f2, r2) = stats();
        assert_eq!(f2, f1, "second alloc must come from the pool");
        assert!(r2 >= 1);
        assert_eq!(b.len(), 64);
        assert!(b.iter().all(|&x| x == 0.0), "recycled buffers are re-zeroed");
    }

    #[test]
    fn best_fit_prefers_the_smallest_sufficient_buffer() {
        if !enabled() {
            return;
        }
        clear();
        recycle_buf(vec![1.0; 256]);
        recycle_buf(vec![1.0; 32]);
        let b = alloc_zeroed(20);
        assert!(b.capacity() < 256, "small request must not burn the big buffer");
        clear();
    }

    #[test]
    fn peak_request_tracks_high_water_and_resets() {
        if !enabled() {
            return;
        }
        reset_stats();
        let a = alloc_zeroed(16);
        let b = alloc_scratch(64);
        let c = alloc_copy(&[1.0; 32]);
        assert_eq!(peak_request(), 64, "largest request wins");
        recycle_buf(a);
        recycle_buf(b);
        recycle_buf(c);
        reset_stats();
        assert_eq!(peak_request(), 0, "reset clears the high-water mark");
    }

    #[test]
    fn recycle_ignores_i32_and_zero_len() {
        clear();
        recycle(Tensor::from_i32(&[2], vec![1, 2]));
        recycle(Tensor::from_f32(&[0], vec![]));
        let n = POOL.with(|p| p.borrow().free.len());
        assert_eq!(n, 0);
    }
}
