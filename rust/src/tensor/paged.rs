//! Paged f32 buffer pool for KV caches.
//!
//! Decode sessions come and go continuously, so the KV cache cannot be one
//! monolithic buffer per session: a finished session must hand its memory
//! straight to the next admit without touching the allocator. `PagePool`
//! carves fixed-size pages out of `tensor/arena.rs` buffers and recycles
//! them through a free list — after warm-up, admitting/evicting sessions
//! performs **zero** fresh allocations (the same discipline the trainer's
//! steady-state tests enforce on the training arena, observable here via
//! `PagePool::stats`).
//!
//! Sessions never hold pages directly; they hold *page tables* (`Vec<usize>`
//! of page indices) and read rows through the [`PagedRows`] view, which maps
//! a logical row index to `(page, offset)` on the fly. That keeps the K/V
//! layout fully scattered — growing a session by one page never moves
//! existing rows.

use crate::tensor::arena;

/// Fixed-size page pool. Every page holds `page_floats` f32s drawn from the
/// arena; freed pages go on a free list and are reused before any new page
/// is created.
#[derive(Debug, Default)]
pub struct PagePool {
    page_floats: usize,
    pages: Vec<Vec<f32>>,
    free: Vec<usize>,
    live: Vec<bool>,
    fresh: u64,
    reused: u64,
    /// Maximum pages this pool may ever hold; 0 = unbounded.
    max_pages: usize,
}

impl PagePool {
    pub fn new(page_floats: usize) -> PagePool {
        assert!(page_floats > 0, "page size must be positive");
        PagePool { page_floats, ..Default::default() }
    }

    /// A pool capped at `max_pages` pages (0 = unbounded). At the cap,
    /// [`try_alloc`](Self::try_alloc) returns `None` instead of growing —
    /// the serve scheduler's backpressure signal.
    pub fn with_capacity(page_floats: usize, max_pages: usize) -> PagePool {
        let mut p = PagePool::new(page_floats);
        p.max_pages = max_pages;
        p
    }

    /// Floats per page.
    pub fn page_floats(&self) -> usize {
        self.page_floats
    }

    /// The page cap (0 = unbounded).
    pub fn capacity(&self) -> usize {
        self.max_pages
    }

    /// Allocate a page, reusing the free list when possible. Reused pages
    /// are zeroed so a new session never observes a dead session's K/V.
    /// Returns `None` when the pool is capped, fully live, and has nothing
    /// on the free list — exhaustion is a typed condition here, never a
    /// panic.
    pub fn try_alloc(&mut self) -> Option<usize> {
        if let Some(idx) = self.free.pop() {
            debug_assert!(!self.live[idx]);
            self.pages[idx].fill(0.0);
            self.live[idx] = true;
            self.reused += 1;
            return Some(idx);
        }
        if self.max_pages > 0 && self.pages.len() >= self.max_pages {
            return None;
        }
        self.fresh += 1;
        self.pages.push(arena::alloc_zeroed(self.page_floats));
        self.live.push(true);
        Some(self.pages.len() - 1)
    }

    /// [`try_alloc`](Self::try_alloc) for callers that sized their demand
    /// up front (uncapped pools, tests). Panics on exhaustion.
    pub fn alloc(&mut self) -> usize {
        self.try_alloc().unwrap_or_else(|| {
            panic!(
                "page pool exhausted: {} pages live at the {} page cap",
                self.live(),
                self.max_pages
            )
        })
    }

    /// Return a page to the free list. Panics on double-free.
    pub fn free(&mut self, idx: usize) {
        assert!(self.live[idx], "double free of page {idx}");
        self.live[idx] = false;
        self.free.push(idx);
    }

    pub fn page(&self, idx: usize) -> &[f32] {
        debug_assert!(self.live[idx], "read of freed page {idx}");
        &self.pages[idx]
    }

    pub fn page_mut(&mut self, idx: usize) -> &mut [f32] {
        debug_assert!(self.live[idx], "write to freed page {idx}");
        &mut self.pages[idx]
    }

    /// Number of currently-live pages.
    pub fn live(&self) -> usize {
        self.live.iter().filter(|l| **l).count()
    }

    /// Total pages ever created (live + free).
    pub fn total(&self) -> usize {
        self.pages.len()
    }

    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    /// `(fresh, reused)` page-allocation counters — at steady state only
    /// `reused` moves.
    pub fn stats(&self) -> (u64, u64) {
        (self.fresh, self.reused)
    }

    /// Structural self-check: the free list and the live flags must be
    /// exact complements of each other.
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.pages.len() != self.live.len() {
            return Err("pages/live length mismatch".into());
        }
        let mut on_free = vec![false; self.pages.len()];
        for &idx in &self.free {
            if idx >= self.pages.len() {
                return Err(format!("free-list entry {idx} out of range"));
            }
            if on_free[idx] {
                return Err(format!("page {idx} appears twice on the free list"));
            }
            on_free[idx] = true;
        }
        for (idx, (&live, &free)) in self.live.iter().zip(&on_free).enumerate() {
            if live == free {
                return Err(format!("page {idx}: live={live} but on_free={free}"));
            }
            if self.pages[idx].len() != self.page_floats {
                return Err(format!("page {idx} has wrong size"));
            }
        }
        Ok(())
    }

    /// Drop every page back into the arena. All pages must be freed first.
    pub fn clear(&mut self) {
        assert_eq!(self.live(), 0, "clear with live pages");
        for page in self.pages.drain(..) {
            arena::recycle_buf(page);
        }
        self.free.clear();
        self.live.clear();
    }
}

/// Read-only view of `len` rows of width `dim` scattered across a page
/// table. Row `t` lives in page `table[t / rows_per_page]` at row offset
/// `t % rows_per_page`.
pub struct PagedRows<'a> {
    pool: &'a PagePool,
    table: &'a [usize],
    rows_per_page: usize,
    dim: usize,
    len: usize,
}

impl<'a> PagedRows<'a> {
    pub fn new(
        pool: &'a PagePool,
        table: &'a [usize],
        rows_per_page: usize,
        dim: usize,
        len: usize,
    ) -> PagedRows<'a> {
        assert!(rows_per_page * dim <= pool.page_floats(), "rows overflow the page");
        assert!(len <= table.len() * rows_per_page, "len exceeds the page table");
        PagedRows { pool, table, rows_per_page, dim, len }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The `dim` floats of logical row `t`.
    pub fn row(&self, t: usize) -> &[f32] {
        debug_assert!(t < self.len, "row {t} out of {}", self.len);
        let page = self.pool.page(self.table[t / self.rows_per_page]);
        &page[(t % self.rows_per_page) * self.dim..][..self.dim]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_reuses_pages() {
        let mut pool = PagePool::new(8);
        let a = pool.alloc();
        let b = pool.alloc();
        assert_eq!(pool.stats(), (2, 0));
        pool.page_mut(a)[0] = 7.0;
        pool.free(a);
        let c = pool.alloc();
        assert_eq!(c, a, "free list is LIFO");
        assert_eq!(pool.page(c)[0], 0.0, "reused pages are zeroed");
        assert_eq!(pool.stats(), (2, 1));
        pool.free(b);
        pool.free(c);
        pool.check_invariants().unwrap();
        pool.clear();
        assert_eq!(pool.total(), 0);
    }

    #[test]
    fn capped_pool_signals_exhaustion_and_recovers_after_free() {
        let mut pool = PagePool::with_capacity(4, 2);
        assert_eq!(pool.capacity(), 2);
        let a = pool.try_alloc().unwrap();
        let _b = pool.try_alloc().unwrap();
        assert_eq!(pool.try_alloc(), None, "at cap with nothing free");
        pool.free(a);
        assert_eq!(pool.try_alloc(), Some(a), "freed page satisfies the next alloc");
        assert_eq!(pool.try_alloc(), None);
        pool.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "page pool exhausted")]
    fn infallible_alloc_panics_at_the_cap() {
        let mut pool = PagePool::with_capacity(4, 1);
        let _a = pool.alloc();
        let _b = pool.alloc();
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut pool = PagePool::new(4);
        let a = pool.alloc();
        pool.free(a);
        pool.free(a);
    }

    #[test]
    fn paged_rows_maps_rows_across_pages() {
        let mut pool = PagePool::new(12); // 3 rows of dim 4 per page
        let table = [pool.alloc(), pool.alloc()];
        for (p, &idx) in table.iter().enumerate() {
            for (i, x) in pool.page_mut(idx).iter_mut().enumerate() {
                *x = (p * 12 + i) as f32;
            }
        }
        let rows = PagedRows::new(&pool, &table, 3, 4, 5);
        assert_eq!(rows.len(), 5);
        assert_eq!(rows.dim(), 4);
        assert_eq!(rows.row(0), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(rows.row(2), &[8.0, 9.0, 10.0, 11.0]);
        assert_eq!(rows.row(3), &[12.0, 13.0, 14.0, 15.0]); // second page
        assert_eq!(rows.row(4), &[16.0, 17.0, 18.0, 19.0]);
    }

    #[test]
    fn invariants_catch_corruption() {
        let mut pool = PagePool::new(4);
        let a = pool.alloc();
        pool.check_invariants().unwrap();
        pool.free(a);
        pool.check_invariants().unwrap();
        pool.free.push(a); // corrupt: duplicate free entry
        assert!(pool.check_invariants().is_err());
    }
}
