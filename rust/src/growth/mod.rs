//! The growth-operator zoo: every baseline the paper compares against,
//! implemented natively on the named tensor store (§3.1 and Fig. 6).
//!
//! * [`direct_copy`] — copy into the top-left corner, random elsewhere (Wei et al. 2016)
//! * [`net2net`] — function-preserving width expansion (FPI; Chen et al. 2015 / bert2BERT)
//! * [`aki`] — advanced knowledge initialization (bert2BERT, Chen et al. 2021)
//! * [`stacking`] — StackBERT / interpolation / MSLT depth growth (Gong et al. 2019 etc.)
//! * [`ligo`] — the paper's *learned* operator, ported natively: Prop. 1
//!   init, the fused `B W A^T` width pass with Appendix B.1 tying, learned
//!   depth blends, the expansion's analytic backward (dL/dM), and a
//!   surrogate M-learning loop. True task-loss M-learning (native engine or
//!   the `ligo_grad_*` artifacts under `pjrt`) lives in
//!   coordinator::growth_manager.
//!
//! Prop. 1 tests (tests/prop_ligo.rs) verify the zoo's operators are exact
//! special cases of the LiGO family.

pub mod aki;
pub mod direct_copy;
pub mod ligo;
pub mod net2net;
pub mod stacking;
#[doc(hidden)]
pub mod testutil;
pub mod width;

use crate::config::ModelConfig;
use crate::tensor::store::Store;

/// A parameter-space growth operator: small params -> large params.
pub trait GrowthOperator {
    fn name(&self) -> &'static str;
    /// Grow `small` (trained under `small_cfg`) into `large_cfg`'s shapes.
    fn grow(&self, small: &Store, small_cfg: &ModelConfig, large_cfg: &ModelConfig) -> Store;
}

/// Operator registry by CLI name. "ligo" resolves to the native learned
/// operator (surrogate M-learning — this interface has no task batches);
/// the task-loss variants stay behind
/// `coordinator::growth_manager::ligo_grow`.
pub fn by_name(name: &str) -> Option<Box<dyn GrowthOperator>> {
    match name {
        "direct_copy" => Some(Box::new(direct_copy::DirectCopy::default())),
        "net2net" | "fpi" => Some(Box::new(net2net::Net2Net::default())),
        "aki" | "bert2bert" => Some(Box::new(aki::Aki::default())),
        "stackbert" => Some(Box::new(stacking::StackBert)),
        "interpolation" | "interbert" => Some(Box::new(stacking::Interpolation)),
        "msl" | "mslt" => Some(Box::new(stacking::Mslt)),
        "ligo" => Some(Box::new(ligo::Ligo::default())),
        _ => None,
    }
}

/// All *non-learned* zoo names (for `ligo inspect operators` and the
/// shape/property sweeps; the learned "ligo" operator is registered in
/// [`by_name`] but benchmarked separately).
pub const ALL: [&str; 6] = [
    "direct_copy",
    "net2net",
    "aki",
    "stackbert",
    "interpolation",
    "mslt",
];

/// Names of per-layer tensor suffixes for a family (used by every operator).
pub fn layer_suffixes(cfg: &ModelConfig) -> Vec<&'static str> {
    let mut v = vec![
        "q_w", "q_b", "k_w", "k_b", "v_w", "v_b", "o_w", "o_b", "ln1_g", "ln1_b",
        "fc1_w", "fc1_b", "fc2_w", "fc2_b", "ln2_g", "ln2_b",
    ];
    if cfg.family == "cait" {
        v.push("ls1");
        v.push("ls2");
    }
    v
}

pub fn layer_key(l: usize, suffix: &str) -> String {
    format!("L{l:02}_{suffix}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all() {
        for name in ALL {
            assert!(by_name(name).is_some(), "{name}");
        }
        assert!(by_name("ligo").is_some(), "native LiGO is registered");
        assert!(by_name("bogus").is_none());
    }

    #[test]
    fn layer_keys_zero_padded() {
        assert_eq!(layer_key(3, "q_w"), "L03_q_w");
        assert_eq!(layer_key(11, "ln1_g"), "L11_ln1_g");
    }
}
