//! The growth-operator zoo behind **one capability-negotiated entry point**.
//!
//! Every operator — the paper's baselines (§3.1, Fig. 6), the learned LiGO
//! operator and the LEMON-style lossless expansion — implements the same
//! [`GrowthOperator`] trait: `grow(ctx)` takes a [`GrowthContext`] (borrowed
//! small params + configs, optional runtime handle, optional task-batch
//! source, M-learning options) and returns a typed [`GrowthOutcome`]
//! (grown [`Store`] + [`Objective`] + metrics + the route-selection log).
//! [`GrowthOperator::capabilities`] advertises what an operator *can*
//! exploit; the operator itself negotiates the best route from what the
//! context actually provides — callers never choose artifact vs. native vs.
//! surrogate by hand.
//!
//! The zoo:
//! * [`direct_copy`] — copy into the top-left corner, random elsewhere
//!   (Wei et al. 2016)
//! * [`net2net`] — function-preserving width expansion (FPI; Chen et al.
//!   2015 / bert2BERT)
//! * [`aki`] — advanced knowledge initialization (bert2BERT, Chen et al.
//!   2021)
//! * [`stacking`] — StackBERT / Interpolation / MSLT depth growth
//! * [`lemon`] — LEMON-style **exactly loss-preserving** expansion (Wang et
//!   al. 2023) built on the untied [`ligo::selection_m`] machinery
//! * [`ligo`] — the paper's *learned* operator. Its `grow(ctx)` selects the
//!   M-learning route exactly once: the fused `ligo_grad_*` artifact when
//!   the context's runtime can compile it, else task-loss M-learning
//!   through the native engine when task batches are present, else the
//!   surrogate least-squares fit — with the fallback chain recorded in
//!   [`GrowthOutcome::route`].
//!
//! Multi-stage schedules (grow mid-run, repeatedly — "Stacking Your
//! Transformers", Du et al. 2024) are built on top of this entry point by
//! [`crate::coordinator::plan::GrowthPlan`], which
//! [`crate::coordinator::trainer::Trainer::run_plan`] executes mid-run.
//!
//! Prop. 1 tests (tests/prop_ligo.rs) verify the zoo's operators are exact
//! special cases of the LiGO family; `growth_manager` unit tests pin each
//! legacy `ligo_grow_*` route bit-for-bit to its context configuration.

pub mod aki;
pub mod context;
pub mod direct_copy;
pub mod lemon;
pub mod ligo;
pub mod net2net;
pub mod stacking;
#[doc(hidden)]
pub mod testutil;
pub mod verify;
pub mod width;

use crate::bail;
use crate::config::ModelConfig;
use crate::error::Result;
use crate::tensor::store::Store;

pub use context::{
    Capability, GrowthContext, GrowthMetrics, GrowthOutcome, LigoOptions, Objective,
};

/// A growth operator: small params -> large params, negotiated through one
/// [`GrowthContext`] entry point.
pub trait GrowthOperator {
    fn name(&self) -> &'static str;

    /// What this operator can exploit from a context. Every operator grows
    /// from a param-only context; extra capabilities only unlock better
    /// objectives when the context provides the inputs.
    fn capabilities(&self) -> &'static [Capability] {
        &[Capability::ParamOnly]
    }

    /// Grow `ctx.small` (trained under `ctx.small_cfg`) into
    /// `ctx.large_cfg`'s shapes, choosing the route from the context.
    fn grow(&self, ctx: GrowthContext<'_, '_>) -> Result<GrowthOutcome>;
}

/// Implements [`GrowthOperator`] for a non-learned parameter-space operator
/// whose whole job is an inherent `expand(small, cfg_s, cfg_l) -> Store`.
macro_rules! param_only_operator {
    ($ty:ty, $name:literal) => {
        impl crate::growth::GrowthOperator for $ty {
            fn name(&self) -> &'static str {
                $name
            }

            fn grow(
                &self,
                ctx: crate::growth::GrowthContext<'_, '_>,
            ) -> crate::error::Result<crate::growth::GrowthOutcome> {
                let timer = crate::util::timer::Timer::new();
                let params = self.expand(ctx.small, ctx.small_cfg, ctx.large_cfg);
                Ok(crate::growth::GrowthOutcome::param_only(params, timer.elapsed()))
            }
        }
    };
}
pub(crate) use param_only_operator;

/// Canonical registry names, one per operator (aliases not listed) — what
/// [`by_name`]'s error message reports.
pub const KNOWN: [&str; 8] = [
    "direct_copy",
    "net2net",
    "aki",
    "stackbert",
    "interpolation",
    "mslt",
    "lemon",
    "ligo",
];

/// Operator registry by CLI name. Unknown names are a real error listing
/// the known operators (so the CLI and examples surface actionable
/// diagnostics instead of a bare `None`). "ligo" resolves to the learned
/// operator whose `grow(ctx)` negotiates artifact / task-native / surrogate
/// from the context.
pub fn by_name(name: &str) -> Result<Box<dyn GrowthOperator>> {
    match name {
        "direct_copy" => Ok(Box::new(direct_copy::DirectCopy::default())),
        "net2net" | "fpi" => Ok(Box::new(net2net::Net2Net::default())),
        "aki" | "bert2bert" => Ok(Box::new(aki::Aki)),
        "stackbert" => Ok(Box::new(stacking::StackBert)),
        "interpolation" | "interbert" => Ok(Box::new(stacking::Interpolation)),
        "msl" | "mslt" => Ok(Box::new(stacking::Mslt)),
        "lemon" => Ok(Box::new(lemon::Lemon)),
        "ligo" => Ok(Box::new(ligo::Ligo::default())),
        other => bail!(
            "unknown growth operator '{other}'; known operators:\n{}",
            registry_summary()
        ),
    }
}

/// The registry listing with each operator's one-line static-regime
/// summary — what [`by_name`]'s unknown-operator diagnostic, `ligo inspect
/// operators` and the `ligo search` prune log all print, so every surface
/// describes an operator's constraints in the same words.
pub fn registry_summary() -> String {
    KNOWN
        .iter()
        .map(|name| format!("  {name:<14} {}", verify::regime_summary(name)))
        .collect::<Vec<_>>()
        .join("\n")
}

/// One-shot parameter-space growth through the unified entry point: builds
/// a param-only [`GrowthContext`] and returns just the grown store.
pub fn grow_params(
    op: &dyn GrowthOperator,
    small: &Store,
    cfg_s: &ModelConfig,
    cfg_l: &ModelConfig,
) -> Result<Store> {
    Ok(op.grow(GrowthContext::new(small, cfg_s, cfg_l))?.params)
}

/// All *non-learned, shape-unconstrained* zoo names (for `ligo inspect
/// operators` and the shape/property sweeps over arbitrary size pairs).
/// "lemon" is registered in [`by_name`] but excluded here: it accepts only
/// integer-multiple expansions (and reports why). The learned "ligo" is
/// benchmarked separately.
pub const ALL: [&str; 6] = [
    "direct_copy",
    "net2net",
    "aki",
    "stackbert",
    "interpolation",
    "mslt",
];

/// Names of per-layer tensor suffixes for a family (used by every operator).
pub fn layer_suffixes(cfg: &ModelConfig) -> Vec<&'static str> {
    let mut v = vec![
        "q_w", "q_b", "k_w", "k_b", "v_w", "v_b", "o_w", "o_b", "ln1_g", "ln1_b",
        "fc1_w", "fc1_b", "fc2_w", "fc2_b", "ln2_g", "ln2_b",
    ];
    if cfg.family == "cait" {
        v.push("ls1");
        v.push("ls2");
    }
    v
}

pub fn layer_key(l: usize, suffix: &str) -> String {
    format!("L{l:02}_{suffix}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all() {
        for name in KNOWN {
            let op = by_name(name).unwrap();
            assert_eq!(op.name(), name);
            assert!(!op.capabilities().is_empty(), "{name}");
        }
        // aliases resolve to their canonical operator
        assert_eq!(by_name("bert2bert").unwrap().name(), "aki");
        assert_eq!(by_name("fpi").unwrap().name(), "net2net");
    }

    #[test]
    fn unknown_operator_error_lists_known_names() {
        let err = by_name("bogus").unwrap_err().to_string();
        assert!(err.contains("bogus"), "{err}");
        for name in KNOWN {
            assert!(err.contains(name), "error must list '{name}': {err}");
        }
        // the listing carries each operator's static-regime summary, so the
        // diagnostic and `ligo inspect operators` agree on the constraints
        assert!(err.contains("integer width factors"), "{err}");
    }

    #[test]
    fn capabilities_are_negotiated_not_assumed() {
        // non-learned operators are param-only; ligo can exploit everything
        for name in ALL {
            let caps = by_name(name).unwrap().capabilities().to_vec();
            assert_eq!(caps, vec![Capability::ParamOnly], "{name}");
        }
        let ligo_caps = by_name("ligo").unwrap().capabilities().to_vec();
        assert!(ligo_caps.contains(&Capability::NeedsBatches));
        assert!(ligo_caps.contains(&Capability::NeedsRuntime));
    }

    #[test]
    fn grow_params_runs_every_zoo_operator() {
        use crate::growth::testutil::{mk_cfg, small_store};
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let small = small_store(&cs);
        for name in ALL {
            let op = by_name(name).unwrap();
            let big = grow_params(op.as_ref(), &small, &cs, &cl).unwrap();
            assert_eq!(big.len(), small_store(&cl).len(), "{name}");
        }
    }

    #[test]
    fn layer_keys_zero_padded() {
        assert_eq!(layer_key(3, "q_w"), "L03_q_w");
        assert_eq!(layer_key(11, "ln1_g"), "L11_ln1_g");
    }
}
