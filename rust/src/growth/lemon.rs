//! LEMON-style **exactly loss-preserving** expansion (Wang et al. 2023,
//! "LEMON: Lossless Model Expansion"), built entirely on the untied
//! [`selection_m`](super::ligo::selection_m) machinery — the ROADMAP's
//! "lossless-expansion baselines" item.
//!
//! The construction is the Prop. 1 Net2Net instance restricted to the
//! regime where it is *exact at the model level*, plus one tied-head
//! correction:
//!
//! * **Width** — cyclic duplication on every out-expansion (`B_*`) and
//!   multiplicity-normalized duplication on the untied in-expansions
//!   (`A_emb`/`A_v`/`A_fc1`, Net2Net's `D^-1`). With an *integer*
//!   expansion ratio every feature is duplicated with equal multiplicity,
//!   so LayerNorm statistics (mean, variance, even the `eps` term) are
//!   preserved exactly — the thing that makes plain Net2Net only
//!   approximately preserving. Keeping the per-head dimension fixed
//!   (heads grow with the width) makes each large attention head an exact
//!   copy of a small head, so the `1/sqrt(d_head)` scale and the softmax
//!   are untouched.
//! * **Depth** — near-identity blocks (zeroed `o`/`fc2` projections, the
//!   [`DepthInit::NearIdentity`](super::ligo::DepthInit) pattern): new
//!   blocks write nothing into the residual stream.
//! * **Tied LM head** — the token table must duplicate columns
//!   (unnormalized) for the embedding read, so the tied logit dot-product
//!   picks up one factor of the expansion ratio `k`; the final LayerNorm's
//!   `g`/`b` are scaled by `1/k` to cancel it (its output feeds only the
//!   head). Vision heads (`head_w`) ride the normalized in-expansion and
//!   need no correction.
//!
//! The result: `loss(grown, batch) == loss(small, batch)` to float
//! round-off (≤1e-5, asserted against [`crate::model::loss_only`] below).
//! Pairs outside the exact regime (non-integer width ratio, changed
//! per-head dim, shrinking depth) are rejected with a diagnostic rather
//! than silently degrading to "approximately preserving".

use crate::bail;
use crate::config::ModelConfig;
use crate::error::Result;
use crate::tensor::store::Store;
use crate::util::timer::Timer;

use super::ligo::{ligo_apply, selection_m, DepthInit};
use super::{Capability, GrowthContext, GrowthOperator, GrowthOutcome};

/// The LEMON-style exact expansion operator.
#[derive(Debug, Default)]
pub struct Lemon;

impl Lemon {
    /// Is `(cfg_s -> cfg_l)` inside the exact-preservation regime? Errors
    /// name the violated requirement.
    pub fn check_pair(cfg_s: &ModelConfig, cfg_l: &ModelConfig) -> Result<()> {
        if cfg_s.family != cfg_l.family {
            bail!("lemon: family mismatch ({} vs {})", cfg_s.family, cfg_l.family);
        }
        if cfg_l.dim % cfg_s.dim != 0 {
            bail!(
                "lemon: width must grow by an integer factor (dim {} -> {}); \
                 unequal duplication multiplicities would shift LayerNorm statistics",
                cfg_s.dim,
                cfg_l.dim
            );
        }
        if cfg_l.ffn() % cfg_s.ffn() != 0 {
            bail!("lemon: FFN dim must grow by an integer factor ({} -> {})",
                cfg_s.ffn(), cfg_l.ffn());
        }
        if cfg_s.dim % cfg_s.heads != 0 || cfg_l.dim % cfg_l.heads != 0 {
            bail!("lemon: head count must divide the model dim");
        }
        if cfg_s.dim / cfg_s.heads != cfg_l.dim / cfg_l.heads {
            bail!(
                "lemon: per-head dim must stay fixed ({} -> {}); a changed \
                 1/sqrt(d_head) scale breaks exactness",
                cfg_s.dim / cfg_s.heads,
                cfg_l.dim / cfg_l.heads
            );
        }
        if cfg_l.layers < cfg_s.layers {
            bail!("lemon: cannot shrink depth ({} -> {})", cfg_s.layers, cfg_l.layers);
        }
        if cfg_s.is_vision() {
            let geom = |c: &ModelConfig| (c.img, c.patch, c.n_classes);
            if geom(cfg_s) != geom(cfg_l) {
                bail!("lemon: vision img/patch/classes must match");
            }
            if cfg_s.cls_layers != cfg_l.cls_layers {
                bail!("lemon: class-attention depth must match");
            }
        } else if (cfg_s.vocab, cfg_s.seq) != (cfg_l.vocab, cfg_l.seq) {
            bail!("lemon: vocab/seq must match");
        }
        Ok(())
    }

    /// The exact expansion; errors when the pair is outside the exact
    /// regime (see [`Lemon::check_pair`]).
    pub fn expand(
        &self,
        small: &Store,
        cfg_s: &ModelConfig,
        cfg_l: &ModelConfig,
    ) -> Result<Store> {
        Self::check_pair(cfg_s, cfg_l)?;
        let m = selection_m(cfg_s, cfg_l, DepthInit::NearIdentity, true);
        let mut out = ligo_apply(&m, small, cfg_s, cfg_l);
        // Tied LM head correction: the duplicated residual stream dotted
        // with the duplicated token table over-counts by k = d2/d1; cancel
        // it in the final LN, whose output feeds only the head. Probe/
        // vision heads ride the normalized in-expansion instead.
        let k = (cfg_l.dim / cfg_s.dim) as f32;
        if !cfg_s.is_vision() && cfg_s.n_classes == 0 && k > 1.0 {
            for name in ["final_ln_g", "final_ln_b"] {
                for v in out.get_mut(name).expect("text models carry a final LN").f32s_mut() {
                    *v /= k;
                }
            }
        }
        Ok(out)
    }
}

impl GrowthOperator for Lemon {
    fn name(&self) -> &'static str {
        "lemon"
    }

    fn capabilities(&self) -> &'static [Capability] {
        &[Capability::ParamOnly]
    }

    fn grow(&self, ctx: GrowthContext<'_, '_>) -> Result<GrowthOutcome> {
        let timer = Timer::new();
        let params = self.expand(ctx.small, ctx.small_cfg, ctx.large_cfg)?;
        let mut outcome = GrowthOutcome::param_only(params, timer.elapsed());
        outcome.route = vec!["param-only: exact (loss-preserving) expansion".into()];
        Ok(outcome)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::growth::testutil::{full_store, mk_cfg, mk_vision_cfg, small_store};
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn text_batch(cfg: &ModelConfig, seed: u64) -> Store {
        let mut rng = Rng::new(seed);
        let (b, s) = (cfg.batch, cfg.seq);
        let tokens: Vec<i32> = (0..b * s).map(|_| rng.below(cfg.vocab) as i32).collect();
        let labels: Vec<i32> = tokens
            .iter()
            .map(|&t| if rng.coin(0.3) { t } else { -1 })
            .collect();
        let mut st = Store::new();
        st.insert("tokens", Tensor::from_i32(&[b, s], tokens));
        st.insert("labels", Tensor::from_i32(&[b, s], labels));
        st
    }

    fn vision_batch(cfg: &ModelConfig, seed: u64) -> Store {
        let mut rng = Rng::new(seed);
        let n = cfg.batch * cfg.img * cfg.img * cfg.channels;
        let images: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let labels: Vec<i32> =
            (0..cfg.batch).map(|_| rng.below(cfg.n_classes) as i32).collect();
        let mut st = Store::new();
        st.insert(
            "images",
            Tensor::from_f32(&[cfg.batch, cfg.img, cfg.img, cfg.channels], images),
        );
        st.insert("labels", Tensor::from_i32(&[cfg.batch], labels));
        st
    }

    /// The ROADMAP acceptance check: small vs. grown loss equal to ≤1e-5
    /// through the native engine, on depth-only, width-only and combined
    /// text expansions.
    #[test]
    fn text_expansion_preserves_the_loss_exactly() {
        let cs = mk_cfg(2, 8, 2);
        let small = small_store(&cs);
        let batch = text_batch(&cs, 11);
        let (l_small, _) = crate::model::loss_only(&cs, &small, &batch).unwrap();
        for cl in [
            mk_cfg(4, 8, 2),  // depth-only (near-identity blocks)
            mk_cfg(2, 16, 4), // width-only (k = 2, fixed d_head)
            mk_cfg(4, 16, 4), // combined
            mk_cfg(3, 24, 6), // k = 3, non-power-of-two multiplicity
        ] {
            let big = Lemon.expand(&small, &cs, &cl).unwrap();
            let (l_big, _) = crate::model::loss_only(&cl, &big, &batch).unwrap();
            assert!(
                (l_small - l_big).abs() <= 1e-5,
                "{}: loss must be preserved: {l_small} vs {l_big}",
                cl.name
            );
        }
    }

    #[test]
    fn gpt_and_vision_expansions_preserve_the_loss() {
        // causal text
        let mut cs = mk_cfg(2, 8, 2);
        cs.family = "gpt".into();
        let small = small_store(&cs);
        let batch = text_batch(&cs, 13);
        let (ls, _) = crate::model::loss_only(&cs, &small, &batch).unwrap();
        let mut cl = mk_cfg(3, 16, 4);
        cl.family = "gpt".into();
        let big = Lemon.expand(&small, &cs, &cl).unwrap();
        let (lb, _) = crate::model::loss_only(&cl, &big, &batch).unwrap();
        assert!((ls - lb).abs() <= 1e-5, "gpt: {ls} vs {lb}");
        // vision (vit + cait incl. the class-attention stage)
        for family in ["vit", "cait"] {
            let cs = mk_vision_cfg(family, 2, 8, 2);
            let cl = mk_vision_cfg(family, 3, 16, 4);
            let small = full_store(&cs);
            let batch = vision_batch(&cs, 17);
            let (ls, ms) = crate::model::loss_only(&cs, &small, &batch).unwrap();
            let big = Lemon.expand(&small, &cs, &cl).unwrap();
            let (lb, mb) = crate::model::loss_only(&cl, &big, &batch).unwrap();
            assert!((ls - lb).abs() <= 1e-5, "{family}: {ls} vs {lb}");
            assert_eq!(ms, mb, "{family}: accuracy metric must be preserved too");
        }
    }

    #[test]
    fn rejects_pairs_outside_the_exact_regime() {
        let cs = mk_cfg(2, 8, 2);
        // non-integer width ratio
        let err = Lemon::check_pair(&cs, &mk_cfg(2, 12, 3)).unwrap_err().to_string();
        assert!(err.contains("integer factor"), "{err}");
        // changed per-head dim (heads fixed while width doubles)
        let err = Lemon::check_pair(&cs, &mk_cfg(2, 16, 2)).unwrap_err().to_string();
        assert!(err.contains("per-head"), "{err}");
        // shrinking depth
        let err = Lemon::check_pair(&cs, &mk_cfg(1, 8, 2)).unwrap_err().to_string();
        assert!(err.contains("shrink"), "{err}");
        // and the trait entry point surfaces the same diagnostics
        let small = small_store(&cs);
        let cl = mk_cfg(2, 12, 3);
        let ctx = GrowthContext::new(&small, &cs, &cl);
        assert!(Lemon.grow(ctx).is_err());
    }

    #[test]
    fn grown_params_are_trainable_not_degenerate() {
        // exactness must not come from an all-zero model: the expansion
        // keeps the small weights (duplicated) in every original slot
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(2, 16, 4);
        let small = small_store(&cs);
        let big = Lemon.expand(&small, &cs, &cl).unwrap();
        let w = big.expect("L00_q_w");
        assert_eq!(w.shape, vec![16, 16]);
        assert!(w.f32s().iter().any(|&x| x != 0.0));
        // duplicated rows: row d+r equals row r
        let s = big.expect("L00_q_b");
        for r in 0..8 {
            assert_eq!(s.f32s()[r], s.f32s()[8 + r], "bias duplication row {r}");
        }
    }
}
