//! AKI — Advanced Knowledge Initialization (bert2BERT, Chen et al. 2021).
//!
//! Like FPI, but the *new* neurons of layer l are taken from layer l+1's
//! (width-grown) weights instead of duplicating layer l's own: this breaks
//! the symmetry that slows FPI convergence and injects "advanced" (deeper)
//! knowledge. Depth growth duplicates the top blocks (stacking), as
//! bert2BERT does.

use crate::config::ModelConfig;
use crate::tensor::{store::Store, Tensor};
use crate::util::rng::Rng;

use super::net2net::grow_width;
use super::width::WidthMap;
use super::{layer_key, layer_suffixes, param_only_operator};

#[derive(Debug, Default)]
pub struct Aki;

/// Overwrite the duplicated (j >= d_small) rows of layer `l`'s matrices
/// with the same rows of layer `l+1` (clamped at the top).
fn advance_new_rows(out: &mut Store, cfg_s: &ModelConfig, emb: &WidthMap, ffn: &WidthMap) {
    let suffix_rows: &[(&str, bool)] = &[
        ("q_w", false),
        ("k_w", false),
        ("v_w", false),
        ("o_w", false),
        ("fc1_w", true), // rows indexed by the FFN map
        ("fc2_w", false),
    ];
    for l in 0..cfg_s.layers {
        let next = (l + 1).min(cfg_s.layers - 1);
        if next == l {
            continue;
        }
        for (suffix, is_ffn_rows) in suffix_rows {
            let map = if *is_ffn_rows { ffn } else { emb };
            let donor = out.expect(&layer_key(next, suffix)).clone();
            let t = out.get_mut(&layer_key(l, suffix)).unwrap();
            let cols = t.shape[1];
            let data = t.f32s_mut();
            for (j, &src) in map.map.iter().enumerate() {
                if j < map.d_small {
                    continue; // original rows stay
                }
                let _ = src;
                let donor_row = &donor.f32s()[j * cols..(j + 1) * cols];
                data[j * cols..(j + 1) * cols].copy_from_slice(donor_row);
            }
        }
    }
}

impl Aki {
    /// The parameter-space expansion (the whole operator; `grow(ctx)` wraps
    /// it into a [`super::GrowthOutcome`]).
    pub fn expand(&self, small: &Store, cfg_s: &ModelConfig, cfg_l: &ModelConfig) -> Store {
        let mut rng = Rng::new(0xA41);
        let emb = WidthMap::random(cfg_s.dim, cfg_l.dim, &mut rng);
        let ffn = WidthMap::random(cfg_s.ffn(), cfg_l.ffn(), &mut rng);
        let mut out = grow_width(small, cfg_s, cfg_l, &emb, &ffn, true);
        advance_new_rows(&mut out, cfg_s, &emb, &ffn);
        // depth: stack (duplicate from the bottom, as StackBERT does)
        for l in cfg_s.layers..cfg_l.layers {
            let src = l % cfg_s.layers;
            for suffix in layer_suffixes(cfg_s) {
                let t: Tensor = out.expect(&layer_key(src, suffix)).clone();
                out.insert(layer_key(l, suffix), t);
            }
        }
        out
    }
}

param_only_operator!(Aki, "aki");

#[cfg(test)]
mod tests {
    use super::*;
    use crate::growth::testutil::{mk_cfg, small_store};

    #[test]
    fn shapes_and_depth_stacking() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let big = Aki.expand(&small_store(&cs), &cs, &cl);
        assert_eq!(big.expect(&layer_key(0, "q_w")).shape, vec![12, 12]);
        // stacked layers duplicate lower ones
        assert_eq!(
            big.expect(&layer_key(2, "q_w")),
            big.expect(&layer_key(0, "q_w"))
        );
        assert_eq!(
            big.expect(&layer_key(3, "fc1_w")),
            big.expect(&layer_key(1, "fc1_w"))
        );
    }

    #[test]
    fn new_rows_differ_from_fpi_duplication() {
        // Layer 0's new rows should come from layer 1, so they differ from
        // plain duplication of layer 0's own rows.
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(2, 12, 3);
        let big = Aki.expand(&small_store(&cs), &cs, &cl);
        let l0 = big.expect(&layer_key(0, "q_w"));
        let l1 = big.expect(&layer_key(1, "q_w"));
        // rows 8..12 of layer0 equal rows 8..12 of layer1 (donor copy)
        for j in 8..12 {
            for c in 0..12 {
                assert_eq!(l0.at2(j, c), l1.at2(j, c));
            }
        }
    }
}
