//! Net2Net / FPI (function-preserving initialization) — Chen et al. 2015,
//! as adapted to transformers by bert2BERT (paper Eq. 2).
//!
//! Width: every feature dimension grows by neuron duplication through a
//! selection map; in-dimensions are normalized by multiplicity (D^-1 in
//! Eq. 2) so each layer's function is preserved. One map is used for the
//! residual stream (like the paper's B_emb tying) and one for the FFN inner
//! dim. LayerNorm makes preservation approximate at the model level
//! (duplicated features shift LN statistics); tests assert closeness, not
//! equality.
//!
//! Depth: new layers are near-identity blocks (zeroed output projections),
//! the transformer analog of Net2Net's identity layers.

use crate::config::ModelConfig;
use crate::tensor::{store::Store, Tensor};
use crate::util::rng::Rng;

use super::width::WidthMap;
use super::{layer_key, layer_suffixes, param_only_operator};

#[derive(Debug, Default)]
pub struct Net2Net {
    /// Use the deterministic cyclic map instead of random selection.
    pub cyclic: bool,
}

/// Width-grow every tensor of `small` into the large dims, preserving layer
/// count. Shared by Net2Net / AKI / the stacking family.
pub fn grow_width(
    small: &Store,
    cfg_s: &ModelConfig,
    cfg_l: &ModelConfig,
    emb_map: &WidthMap,
    ffn_map: &WidthMap,
    normalize: bool,
) -> Store {
    let mut out = Store::new();
    for (name, t) in small.iter() {
        let grown = grow_width_tensor(name, t, cfg_s, emb_map, ffn_map, normalize);
        out.insert(name.clone(), grown);
    }
    let _ = cfg_l;
    out
}

/// Width-grow a single named tensor according to its role.
pub fn grow_width_tensor(
    name: &str,
    t: &Tensor,
    cfg_s: &ModelConfig,
    emb: &WidthMap,
    ffn: &WidthMap,
    normalize: bool,
) -> Tensor {
    let d1 = cfg_s.dim;
    let key = name.split_once('_').map(|(_, k)| k).unwrap_or(name);
    match key {
        // (V, D) / (S, D) / (T, D): grow the column (feature) dim
        _ if name == "emb_tok" || name == "emb_pos" => emb.expand_cols(t, false),
        _ if name == "mlm_bias" || name == "head_b" || name == "span_b" => t.clone(),
        _ if name == "emb_cls" || name == "emb_patch_b" => emb.expand_vec(t),
        _ if name == "emb_patch_w" => emb.expand_rows(t),
        _ if name == "head_w" || name == "span_w" => emb.expand_cols(t, normalize),
        _ if name == "final_ln_g" || name == "final_ln_b" => emb.expand_vec(t),
        // per-layer tensors (prefix "Lxx_" / "Cxx_")
        "q_w" | "k_w" | "v_w" => emb.expand_cols(&emb.expand_rows(t), normalize),
        "o_w" => emb.expand_cols(&emb.expand_rows(t), normalize),
        "q_b" | "k_b" | "v_b" | "o_b" | "ln1_g" | "ln1_b" | "ln2_g" | "ln2_b" | "ls1" | "ls2" => {
            emb.expand_vec(t)
        }
        "fc1_w" => emb_then_ffn(t, emb, ffn, normalize),
        "fc1_b" => ffn.expand_vec(t),
        "fc2_w" => ffn_then_emb(t, emb, ffn, normalize),
        "fc2_b" => emb.expand_vec(t),
        other => panic!("grow_width: unknown tensor '{name}' (key '{other}', d1={d1})"),
    }
}

fn emb_then_ffn(t: &Tensor, emb: &WidthMap, ffn: &WidthMap, normalize: bool) -> Tensor {
    // (F, D): rows by ffn map, cols by emb map
    ffn.expand_rows(&emb.expand_cols(t, normalize))
}

fn ffn_then_emb(t: &Tensor, emb: &WidthMap, ffn: &WidthMap, normalize: bool) -> Tensor {
    // (D, F): rows by emb map, cols by ffn map
    emb.expand_rows(&ffn.expand_cols(t, normalize))
}

/// Build a near-identity transformer block at layer `l` from a template:
/// copies the template's LN/in-projections but zeroes the output
/// projections, making the residual branch a no-op.
fn identity_block(out: &mut Store, template_layer: usize, l: usize, cfg: &ModelConfig) {
    for suffix in layer_suffixes(cfg) {
        let src = out.expect(&layer_key(template_layer, suffix)).clone();
        let t = if suffix == "o_w" || suffix == "fc2_w" || suffix == "o_b" || suffix == "fc2_b" {
            Tensor::zeros(&src.shape)
        } else {
            src
        };
        out.insert(layer_key(l, suffix), t);
    }
}

impl Net2Net {
    /// The parameter-space expansion (the whole operator; `grow(ctx)` wraps
    /// it into a [`super::GrowthOutcome`]).
    pub fn expand(&self, small: &Store, cfg_s: &ModelConfig, cfg_l: &ModelConfig) -> Store {
        let mut rng = Rng::new(0xFB1);
        let emb_map = if self.cyclic {
            WidthMap::cyclic(cfg_s.dim, cfg_l.dim)
        } else {
            WidthMap::random(cfg_s.dim, cfg_l.dim, &mut rng)
        };
        let ffn_map = if self.cyclic {
            WidthMap::cyclic(cfg_s.ffn(), cfg_l.ffn())
        } else {
            WidthMap::random(cfg_s.ffn(), cfg_l.ffn(), &mut rng)
        };
        let mut out = grow_width(small, cfg_s, cfg_l, &emb_map, &ffn_map, true);
        // depth: append near-identity blocks
        for l in cfg_s.layers..cfg_l.layers {
            identity_block(&mut out, cfg_s.layers - 1, l, cfg_s);
        }
        out
    }
}

param_only_operator!(Net2Net, "net2net");

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::init::det_fill;

    fn cfgs() -> (ModelConfig, ModelConfig) {
        let mk = |layers, dim, heads| ModelConfig {
            name: "t".into(),
            family: "bert".into(),
            layers,
            dim,
            heads,
            vocab: 64,
            seq: 16,
            batch: 4,
            img: 0,
            patch: 0,
            channels: 3,
            n_classes: 0,
            cls_layers: 0,
            ffn_mult: 4,
        };
        (mk(2, 8, 2), mk(4, 12, 3))
    }

    fn small_store(cfg: &ModelConfig) -> Store {
        let mut s = Store::new();
        s.insert("emb_tok", det_fill("emb_tok", &[cfg.vocab, cfg.dim], 0));
        s.insert("emb_pos", det_fill("emb_pos", &[cfg.seq, cfg.dim], 0));
        s.insert("mlm_bias", det_fill("mlm_bias", &[cfg.vocab], 0));
        s.insert("final_ln_g", det_fill("final_ln_g", &[cfg.dim], 0));
        s.insert("final_ln_b", det_fill("final_ln_b", &[cfg.dim], 0));
        for l in 0..cfg.layers {
            for suf in layer_suffixes(cfg) {
                let shape: Vec<usize> = match suf {
                    "q_w" | "k_w" | "v_w" | "o_w" => vec![cfg.dim, cfg.dim],
                    "fc1_w" => vec![cfg.ffn(), cfg.dim],
                    "fc2_w" => vec![cfg.dim, cfg.ffn()],
                    "fc1_b" => vec![cfg.ffn()],
                    _ => vec![cfg.dim],
                };
                s.insert(layer_key(l, suf), det_fill(&layer_key(l, suf), &shape, 0));
            }
        }
        s
    }

    #[test]
    fn grows_to_target_shapes() {
        let (cs, cl) = cfgs();
        let small = small_store(&cs);
        let big = Net2Net::default().expand(&small, &cs, &cl);
        assert_eq!(big.expect("emb_tok").shape, vec![64, 12]);
        assert_eq!(big.expect(&layer_key(3, "fc1_w")).shape, vec![48, 12]);
        assert_eq!(big.expect(&layer_key(0, "q_w")).shape, vec![12, 12]);
        // all 4 layers present
        assert_eq!(big.with_prefix("L03_").len(), 16);
    }

    #[test]
    fn new_layers_are_identity_blocks() {
        let (cs, cl) = cfgs();
        let big = Net2Net::default().expand(&small_store(&cs), &cs, &cl);
        assert!(big.expect(&layer_key(2, "o_w")).f32s().iter().all(|&x| x == 0.0));
        assert!(big.expect(&layer_key(2, "fc2_w")).f32s().iter().all(|&x| x == 0.0));
        assert!(big.expect(&layer_key(2, "q_w")).f32s().iter().any(|&x| x != 0.0));
    }

    #[test]
    fn width_growth_preserves_linear_function() {
        // y = W x preserved through duplicate-inputs + normalized columns:
        // simulate the residual stream: x_large[j] = x[map[j]]
        let (cs, cl) = cfgs();
        let small = small_store(&cs);
        let emb = WidthMap::cyclic(cs.dim, cl.dim);
        let ffn = WidthMap::cyclic(cs.ffn(), cl.ffn());
        let grown = grow_width(&small, &cs, &cl, &emb, &ffn, true);
        let w = small.expect(&layer_key(0, "q_w"));
        let wl = grown.expect(&layer_key(0, "q_w"));
        let x: Vec<f32> = (0..cs.dim).map(|i| (i as f32 * 0.37).sin()).collect();
        let xl: Vec<f32> = emb.map.iter().map(|&s| x[s]).collect();
        for i in 0..cs.dim {
            let orig: f32 = (0..cs.dim).map(|j| w.at2(i, j) * x[j]).sum();
            let grown_v: f32 = (0..cl.dim).map(|j| wl.at2(i, j) * xl[j]).sum();
            assert!((orig - grown_v).abs() < 1e-4);
        }
    }

    #[test]
    fn cyclic_mode_is_deterministic() {
        let (cs, cl) = cfgs();
        let small = small_store(&cs);
        let op = Net2Net { cyclic: true };
        let a = op.expand(&small, &cs, &cl);
        let b = op.expand(&small, &cs, &cl);
        assert_eq!(a.expect("emb_tok"), b.expect("emb_tok"));
    }
}
