//! Width-expansion machinery shared by Net2Net/AKI/DirectCopy: selection
//! maps over feature dimensions and row/column expansion with optional
//! Net2Net multiplicity normalization (paper Eq. 2).

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// A map from each of the `d_large` output features to a source feature in
/// `[0, d_small)`. The first `d_small` entries are the identity; the
/// remainder select which source neuron each new neuron duplicates.
#[derive(Debug, Clone)]
pub struct WidthMap {
    pub d_small: usize,
    pub map: Vec<usize>,
    /// counts[i] = how many large features copy small feature i (>= 1).
    pub counts: Vec<usize>,
}

impl WidthMap {
    /// Random selection (Net2Net's random neuron duplication).
    pub fn random(d_small: usize, d_large: usize, rng: &mut Rng) -> WidthMap {
        assert!(d_large >= d_small);
        let mut map: Vec<usize> = (0..d_small).collect();
        for _ in d_small..d_large {
            map.push(rng.below(d_small));
        }
        Self::from_map(d_small, map)
    }

    /// Deterministic cyclic selection (new feature j copies j mod d_small) —
    /// the pattern LiGO's M is initialized with (Prop. 1).
    pub fn cyclic(d_small: usize, d_large: usize) -> WidthMap {
        let map = (0..d_large).map(|j| j % d_small).collect();
        Self::from_map(d_small, map)
    }

    fn from_map(d_small: usize, map: Vec<usize>) -> WidthMap {
        let mut counts = vec![0usize; d_small];
        for &s in &map {
            counts[s] += 1;
        }
        WidthMap { d_small, map, counts }
    }

    pub fn d_large(&self) -> usize {
        self.map.len()
    }

    /// Expand the row (out) dimension: new_row[j] = row[map[j]].
    pub fn expand_rows(&self, t: &Tensor) -> Tensor {
        let (r, c) = (t.shape[0], t.shape[1]);
        assert_eq!(r, self.d_small, "row dim mismatch");
        let src = t.f32s();
        let mut out = Vec::with_capacity(self.d_large() * c);
        for &s in &self.map {
            out.extend_from_slice(&src[s * c..(s + 1) * c]);
        }
        Tensor::from_f32(&[self.d_large(), c], out)
    }

    /// Expand the column (in) dimension; if `normalize`, each copied column
    /// is divided by its source's multiplicity (function preservation,
    /// Eq. 2's D^-1).
    pub fn expand_cols(&self, t: &Tensor, normalize: bool) -> Tensor {
        let (r, c) = (t.shape[0], t.shape[1]);
        assert_eq!(c, self.d_small, "col dim mismatch");
        let src = t.f32s();
        let dl = self.d_large();
        let mut out = vec![0.0f32; r * dl];
        for i in 0..r {
            for (j, &s) in self.map.iter().enumerate() {
                let v = src[i * c + s];
                out[i * dl + j] = if normalize { v / self.counts[s] as f32 } else { v };
            }
        }
        Tensor::from_f32(&[r, dl], out)
    }

    /// Expand a vector (bias / LN parameter) along its only dimension.
    pub fn expand_vec(&self, t: &Tensor) -> Tensor {
        assert_eq!(t.numel(), self.d_small);
        let src = t.f32s();
        let out: Vec<f32> = self.map.iter().map(|&s| src[s]).collect();
        Tensor::from_f32(&[self.d_large()], out)
    }
}

/// Grow a (rows, cols) matrix into (r2, c2) copying into the top-left corner
/// and filling the rest with scaled uniform noise (DirectCopy).
pub fn corner_embed(t: &Tensor, r2: usize, c2: usize, scale: f32, rng: &mut Rng) -> Tensor {
    let (r, c) = (t.shape[0], t.shape[1]);
    assert!(r2 >= r && c2 >= c);
    let src = t.f32s();
    let mut out = vec![0.0f32; r2 * c2];
    for (i, row) in out.chunks_exact_mut(c2).enumerate() {
        for (j, v) in row.iter_mut().enumerate() {
            *v = if i < r && j < c {
                src[i * c + j]
            } else {
                rng.range_f32(-scale, scale)
            };
        }
    }
    Tensor::from_f32(&[r2, c2], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn cyclic_map_counts() {
        let m = WidthMap::cyclic(4, 6);
        assert_eq!(m.map, vec![0, 1, 2, 3, 0, 1]);
        assert_eq!(m.counts, vec![2, 2, 1, 1]);
    }

    #[test]
    fn expand_rows_copies() {
        let t = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let m = WidthMap::cyclic(2, 3);
        let e = m.expand_rows(&t);
        assert_eq!(e.shape, vec![3, 3]);
        assert_eq!(&e.f32s()[6..9], &[1., 2., 3.]); // row 2 copies row 0
    }

    #[test]
    fn expand_cols_normalized_preserves_rowsum_functionality() {
        // sum over duplicated+normalized in-dims equals the original matvec
        // against a duplicated input vector.
        prop::check("net2net col normalization", 25, |g| {
            let ds = g.usize_in(2, 6);
            let dl = g.usize_in(ds, 10);
            let r = g.usize_in(1, 5);
            let m = WidthMap::random(ds, dl, &mut crate::util::rng::Rng::new(g.seed));
            let t = Tensor::from_f32(&[r, ds], g.vec_f32(r * ds, -1.0, 1.0));
            let x: Vec<f32> = g.vec_f32(ds, -1.0, 1.0);
            // duplicated input: x_large[j] = x[map[j]]
            let xl: Vec<f32> = m.map.iter().map(|&s| x[s]).collect();
            let e = m.expand_cols(&t, true);
            for i in 0..r {
                let orig: f32 = (0..ds).map(|j| t.at2(i, j) * x[j]).sum();
                let grown: f32 = (0..dl).map(|j| e.at2(i, j) * xl[j]).sum();
                assert!((orig - grown).abs() < 1e-4, "{orig} vs {grown}");
            }
        });
    }

    #[test]
    fn expand_vec_maps() {
        let t = Tensor::from_f32(&[3], vec![7., 8., 9.]);
        let m = WidthMap::cyclic(3, 5);
        assert_eq!(m.expand_vec(&t).f32s(), &[7., 8., 9., 7., 8.]);
    }

    #[test]
    fn corner_embed_preserves_block() {
        let t = Tensor::from_f32(&[2, 2], vec![1., 2., 3., 4.]);
        let e = corner_embed(&t, 3, 4, 0.01, &mut Rng::new(0));
        assert_eq!(e.at2(0, 0), 1.0);
        assert_eq!(e.at2(1, 1), 4.0);
        assert!(e.at2(2, 3).abs() <= 0.01);
    }
}
