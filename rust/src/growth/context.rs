//! The unified growth API: one capability-negotiated entry point for every
//! operator in the zoo.
//!
//! A [`GrowthContext`] bundles everything a growth operator *may* use —
//! borrowed small-model parameters and configs (always), an optional
//! [`Runtime`] handle (artifact fast paths), an optional task-batch source
//! (task-loss M-learning) and the M-learning budget ([`LigoOptions`]). Each
//! operator's [`capabilities`](super::GrowthOperator::capabilities)
//! advertises which of those it can exploit; `grow(ctx)` decides the actual
//! route exactly once from what the context provides and records the
//! decision chain in the returned [`GrowthOutcome`] — callers never pick
//! artifact-vs-native-vs-surrogate themselves.

use std::fmt;

use crate::config::ModelConfig;
use crate::runtime::Runtime;
use crate::tensor::store::Store;

/// What a growth operator can make use of (not what it demands): every
/// operator must work from a param-only context; the extra capabilities
/// unlock better objectives when the context provides the inputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Capability {
    /// Grows from the small parameters alone.
    ParamOnly,
    /// Can exploit a task-batch source (M-learning on the true task loss).
    NeedsBatches,
    /// Can exploit a runtime handle (AOT `ligo_grad_*`/`ligo_apply_*`
    /// artifact fast paths).
    NeedsRuntime,
}

impl Capability {
    pub fn as_str(&self) -> &'static str {
        match self {
            Capability::ParamOnly => "param-only",
            Capability::NeedsBatches => "batches",
            Capability::NeedsRuntime => "runtime",
        }
    }
}

impl fmt::Display for Capability {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Hyperparameters of the M-learning phase (learned operators only; the
/// non-learned zoo ignores them). `PartialEq` because plan files embed
/// these and the round-trip tests compare whole plans.
#[derive(Debug, Clone, PartialEq)]
pub struct LigoOptions {
    pub steps: usize,
    pub lr: f32,
    pub momentum: f32,
    pub init_noise: f32,
    pub seed: u64,
}

impl Default for LigoOptions {
    fn default() -> Self {
        // 100 steps of SGD, as in the paper (§3.2 "Training").
        LigoOptions { steps: 100, lr: 0.02, momentum: 0.9, init_noise: 0.01, seed: 0 }
    }
}

/// Which M-learning objective produced the grown parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// M trained on the task loss through the fused `ligo_grad_*` artifact.
    TaskArtifact,
    /// M trained on the task loss through the native engine.
    TaskNative,
    /// M trained on the surrogate least-squares fit (no task batches).
    Surrogate,
    /// No M-learning: a non-learned parameter-space operator.
    ParamOnly,
}

impl Objective {
    pub fn as_str(&self) -> &'static str {
        match self {
            Objective::TaskArtifact => "task-artifact",
            Objective::TaskNative => "task-native",
            Objective::Surrogate => "surrogate",
            Objective::ParamOnly => "param-only",
        }
    }
}

impl fmt::Display for Objective {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Cost accounting of one growth.
#[derive(Debug, Clone, Copy)]
pub struct GrowthMetrics {
    /// FLOPs spent growing (M-steps + the final apply); charge this to the
    /// trainer's `flops_offset`.
    pub extra_flops: f64,
    pub wall_s: f64,
    /// Final M-learning loss (`NaN` for non-learned operators).
    pub final_m_loss: f32,
    /// M-steps actually taken (0 for non-learned operators).
    pub m_steps: usize,
}

/// Typed result of a growth: the grown parameters, which objective produced
/// them, cost metrics, and the route-selection log (one line per considered
/// route, in decision order) — replaces the old stringly-typed `Grown`.
pub struct GrowthOutcome {
    pub params: Store,
    pub objective: Objective,
    pub metrics: GrowthMetrics,
    /// Why this route: every considered route with the reason it was taken
    /// or passed over, e.g. `["task-artifact: unavailable (no ligo_grad
    /// artifact...)", "task-native: selected"]`.
    pub route: Vec<String>,
}

impl GrowthOutcome {
    /// Outcome of a non-learned parameter-space operator.
    pub fn param_only(params: Store, wall_s: f64) -> GrowthOutcome {
        GrowthOutcome {
            params,
            objective: Objective::ParamOnly,
            metrics: GrowthMetrics {
                extra_flops: 0.0,
                wall_s,
                final_m_loss: f32::NAN,
                m_steps: 0,
            },
            route: vec!["param-only: direct expansion".into()],
        }
    }

    /// The route log as one printable line.
    pub fn route_summary(&self) -> String {
        self.route.join(" -> ")
    }
}

/// Everything a growth operator may consume, borrowed from the caller:
/// the small model (params + config), the target config, and — optionally —
/// a runtime handle, a task-batch source (`step -> batch`) and the
/// M-learning options. Build one with [`GrowthContext::new`] and the
/// `with_*` methods; a bare `new` context is param-only.
///
/// The batch source carries its own lifetime `'b`: the `&mut dyn FnMut`
/// trait-object bound is invariant behind the mutable reference, so tying
/// it to the (covariant) data lifetime `'a` would force every caller's
/// parameter borrow to outlive the batch closure's — which a function that
/// borrows its own fields (e.g. `Trainer::run_plan`'s stage execution)
/// cannot promise.
pub struct GrowthContext<'a, 'b> {
    pub small: &'a Store,
    pub small_cfg: &'a ModelConfig,
    pub large_cfg: &'a ModelConfig,
    /// Runtime handle for artifact fast paths (capability
    /// [`Capability::NeedsRuntime`]).
    pub runtime: Option<&'a Runtime>,
    /// Task-batch source, `step -> batch` (capability
    /// [`Capability::NeedsBatches`]).
    pub batches: Option<&'b mut dyn FnMut(usize) -> Store>,
    /// M-learning budget and hyperparameters (learned operators only).
    /// `None` means "not specified": the operator falls back to its own
    /// configuration (e.g. [`super::ligo::Ligo`]'s fields) rather than
    /// silently overriding it with defaults.
    pub opts: Option<LigoOptions>,
    /// RNG-seed override, merged into whichever options win (explicit or
    /// operator-owned) — so seeding a run never drags default options in.
    pub seed: Option<u64>,
}

impl<'a, 'b> GrowthContext<'a, 'b> {
    /// A param-only context: enough for every operator's fallback route.
    pub fn new(
        small: &'a Store,
        small_cfg: &'a ModelConfig,
        large_cfg: &'a ModelConfig,
    ) -> GrowthContext<'a, 'b> {
        GrowthContext {
            small,
            small_cfg,
            large_cfg,
            runtime: None,
            batches: None,
            opts: None,
            seed: None,
        }
    }

    /// Offer a runtime handle (unlocks artifact fast paths).
    pub fn with_runtime(mut self, rt: &'a Runtime) -> GrowthContext<'a, 'b> {
        self.runtime = Some(rt);
        self
    }

    /// Offer a task-batch source (unlocks task-loss M-learning).
    pub fn with_batches(
        mut self,
        batches: &'b mut dyn FnMut(usize) -> Store,
    ) -> GrowthContext<'a, 'b> {
        self.batches = Some(batches);
        self
    }

    /// Set the M-learning budget/options explicitly (overrides the
    /// operator's own configuration).
    pub fn with_opts(mut self, opts: LigoOptions) -> GrowthContext<'a, 'b> {
        self.opts = Some(opts);
        self
    }

    /// Override the RNG seed without touching the rest of the options:
    /// the seed is merged into whichever [`LigoOptions`] the operator
    /// resolves (the context's, else its own).
    pub fn with_seed(mut self, seed: u64) -> GrowthContext<'a, 'b> {
        self.seed = Some(seed);
        self
    }

    /// Statically verify this context's transition under `operator` before
    /// running it: schedule compatibility, operator regime, and a symbolic
    /// shape replay of both endpoint configs — no kernels, no data (see
    /// [`crate::growth::verify::verify_pair`]). Callers that are about to
    /// `grow(ctx)` use this to fail fast with a plan-time diagnostic
    /// instead of a kernel panic.
    pub fn verify(
        &self,
        operator: &str,
    ) -> crate::error::Result<crate::growth::verify::PairVerification> {
        crate::growth::verify::verify_pair(operator, self.small_cfg, self.large_cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::growth::testutil::{mk_cfg, small_store};

    #[test]
    fn default_context_is_param_only() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let small = small_store(&cs);
        let ctx = GrowthContext::new(&small, &cs, &cl);
        assert!(ctx.runtime.is_none());
        assert!(ctx.batches.is_none());
        assert!(ctx.opts.is_none(), "unset options defer to the operator");
    }

    #[test]
    fn builder_attaches_batches_and_seed() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let small = small_store(&cs);
        let mut mk = |_s: usize| Store::new();
        let ctx = GrowthContext::new(&small, &cs, &cl).with_batches(&mut mk).with_seed(7);
        assert!(ctx.batches.is_some());
        assert_eq!(ctx.seed, Some(7));
        // seeding must NOT forge full default options over the operator's
        assert!(ctx.opts.is_none());
    }

    #[test]
    fn objective_and_capability_labels_are_stable() {
        // route logs and reports print these; keep them stable
        assert_eq!(Objective::TaskArtifact.to_string(), "task-artifact");
        assert_eq!(Objective::TaskNative.to_string(), "task-native");
        assert_eq!(Objective::Surrogate.to_string(), "surrogate");
        assert_eq!(Objective::ParamOnly.to_string(), "param-only");
        assert_eq!(Capability::NeedsBatches.to_string(), "batches");
    }

    #[test]
    fn context_verify_runs_the_static_checks() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let small = small_store(&cs);
        let ctx = GrowthContext::new(&small, &cs, &cl);
        let pv = ctx.verify("stackbert").unwrap();
        assert!(pv.large.params > pv.small.params);
        assert!(ctx.verify("nope").unwrap_err().to_string().contains("unknown"));
    }

    #[test]
    fn param_only_outcome_shape() {
        let o = GrowthOutcome::param_only(Store::new(), 0.5);
        assert_eq!(o.objective, Objective::ParamOnly);
        assert_eq!(o.metrics.extra_flops, 0.0);
        assert!(o.metrics.final_m_loss.is_nan());
        assert!(o.route_summary().contains("param-only"));
    }
}
