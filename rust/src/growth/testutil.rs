//! Shared fixtures for growth-operator tests (and the operator benches):
//! synthetic configs and deterministically-filled parameter stores with the
//! exact naming scheme the L2 models use.

use crate::config::ModelConfig;
use crate::tensor::init::det_fill;
use crate::tensor::store::Store;

use super::{layer_key, layer_suffixes};

/// A bert-family config with the given size.
pub fn mk_cfg(layers: usize, dim: usize, heads: usize) -> ModelConfig {
    ModelConfig {
        name: format!("bert_{layers}x{dim}"),
        family: "bert".into(),
        layers,
        dim,
        heads,
        vocab: 64,
        seq: 16,
        batch: 4,
        img: 0,
        patch: 0,
        channels: 3,
        n_classes: 0,
        cls_layers: 0,
        ffn_mult: 4,
    }
}

/// A vision-family config (vit or cait) with the given size. Image 8x8 with
/// patch 4 keeps the token count at 4 (+CLS for vit), so vision tests stay
/// fast.
pub fn mk_vision_cfg(family: &str, layers: usize, dim: usize, heads: usize) -> ModelConfig {
    ModelConfig {
        name: format!("{family}_{layers}x{dim}"),
        family: family.into(),
        layers,
        dim,
        heads,
        vocab: 0,
        seq: 0,
        batch: 2,
        img: 8,
        patch: 4,
        channels: 3,
        n_classes: 3,
        cls_layers: usize::from(family == "cait"),
        ffn_mult: 4,
    }
}

/// Deterministic full parameter store for *any* family, via the native
/// engine's parameter inventory (`model::param_shapes`) — always exactly
/// the tensor set the forward pass and the AOT manifests use.
pub fn full_store(cfg: &ModelConfig) -> Store {
    Store::det_init(&crate::model::param_shapes(cfg), 0)
}

/// Assert two stores are identical: same tensor set, same shapes, equal
/// (f32 ==) values everywhere — the bit-for-bit check shared by the
/// Prop. 1 suite and the growth-route equivalence tests.
pub fn assert_store_eq(got: &Store, want: &Store, label: &str) {
    assert_eq!(got.len(), want.len(), "{label}: tensor count");
    for (name, w) in want.iter() {
        let g = got
            .get(name)
            .unwrap_or_else(|| panic!("{label}: missing '{name}'"));
        assert_eq!(g.shape, w.shape, "{label}: shape of '{name}'");
        assert_eq!(g, w, "{label}: values of '{name}'");
    }
}

/// Deterministic full parameter store for a bert-family config.
pub fn small_store(cfg: &ModelConfig) -> Store {
    let mut s = Store::new();
    s.insert("emb_tok", det_fill("emb_tok", &[cfg.vocab, cfg.dim], 0));
    s.insert("emb_pos", det_fill("emb_pos", &[cfg.seq, cfg.dim], 0));
    s.insert("mlm_bias", det_fill("mlm_bias", &[cfg.vocab], 0));
    s.insert("final_ln_g", det_fill("final_ln_g", &[cfg.dim], 0));
    s.insert("final_ln_b", det_fill("final_ln_b", &[cfg.dim], 0));
    for l in 0..cfg.layers {
        for suf in layer_suffixes(cfg) {
            let shape: Vec<usize> = match suf {
                "q_w" | "k_w" | "v_w" | "o_w" => vec![cfg.dim, cfg.dim],
                "fc1_w" => vec![cfg.ffn(), cfg.dim],
                "fc2_w" => vec![cfg.dim, cfg.ffn()],
                "fc1_b" => vec![cfg.ffn()],
                _ => vec![cfg.dim],
            };
            s.insert(layer_key(l, suf), det_fill(&layer_key(l, suf), &shape, 0));
        }
    }
    s
}
