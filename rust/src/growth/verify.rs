//! Static growth verification: prove a growth transition is executable
//! before touching a single kernel.
//!
//! [`verify_pair`] stacks three layers of plan-time checking for one
//! `(operator, small config, large config)` transition:
//!
//! 1. **Schedule compatibility** — [`check_growth_step`]: the target must
//!    genuinely grow (never shrink, never stand still), stay in the same
//!    family, and keep the batch geometry fixed so one batch source can
//!    feed the whole run.
//! 2. **Operator regime** — the operator name must resolve in the registry
//!    ([`super::by_name`]'s diagnostic lists the known names), and LEMON's
//!    exactness preconditions (integer width factors, fixed per-head dim,
//!    matching vocab/seq or image geometry — see
//!    [`Lemon::check_pair`](super::lemon::Lemon::check_pair)) are surfaced
//!    as static diagnostics instead of a mid-run failure.
//! 3. **Symbolic execution** — both endpoint configs are replayed through
//!    the abstract interpreter ([`shape::summarize`]): every tape node's
//!    shapes are checked by the same rules the real tape enforces, and the
//!    resulting [`GraphSummary`] pair reports node/param counts, FLOPs and
//!    the peak-arena estimate for the small and grown model.
//!
//! [`GrowthPlanBuilder::build`](crate::coordinator::plan::GrowthPlanBuilder)
//! runs `verify_pair` on every stage, so *a plan that builds is a plan
//! whose every stage target has already survived a full symbolic
//! forward/backward* — and `ligo analyze` (plus [`verify_plan`]) reuses the
//! same entry point to print what the trainer would execute.

use crate::config::ModelConfig;
use crate::coordinator::plan::GrowthPlan;
use crate::error::{Context, Result};
use crate::model::shape::{self, GraphSummary};

use super::lemon::Lemon;

/// One stage's config transition must genuinely grow and stay compatible
/// with the run's batch source.
pub fn check_growth_step(from: &ModelConfig, to: &ModelConfig) -> Result<()> {
    if from.family != to.family {
        crate::bail!("family must not change ({} -> {})", from.family, to.family);
    }
    if to.layers < from.layers || to.dim < from.dim || to.ffn() < from.ffn() {
        crate::bail!(
            "target must not shrink (layers {} -> {}, dim {} -> {}, ffn {} -> {})",
            from.layers, to.layers, from.dim, to.dim, from.ffn(), to.ffn()
        );
    }
    if to.layers == from.layers && to.dim == from.dim && to.ffn() == from.ffn() {
        crate::bail!("target is not larger in any dimension");
    }
    let batch_geom = |c: &ModelConfig| {
        (c.vocab, c.seq, c.batch, c.img, c.patch, c.channels, c.n_classes)
    };
    if batch_geom(from) != batch_geom(to) {
        crate::bail!(
            "batch geometry must match across stages (one batch source feeds \
             the whole run): {:?} -> {:?}",
            batch_geom(from),
            batch_geom(to)
        );
    }
    Ok(())
}

/// The two [`GraphSummary`]s a verified transition produces: what the
/// trainer executes before the growth step and after it.
#[derive(Debug, Clone)]
pub struct PairVerification {
    pub small: GraphSummary,
    pub large: GraphSummary,
}

impl PairVerification {
    /// Peak-arena growth factor of the transition (large / small).
    pub fn peak_ratio(&self) -> f64 {
        self.large.peak_bytes as f64 / (self.small.peak_bytes.max(1)) as f64
    }
}

/// Statically verify one growth transition (see the module docs for the
/// three layers). No kernels run and no parameter data is touched — only
/// shapes flow. Errors carry the violated requirement and, for symbolic
/// failures, the offending node.
pub fn verify_pair(
    operator: &str,
    from: &ModelConfig,
    to: &ModelConfig,
) -> Result<PairVerification> {
    check_growth_step(from, to)
        .with_context(|| format!("growth step {} -> {}", from.name, to.name))?;
    // resolve now so a typo fails statically with the registry's own
    // diagnostic (listing the known operators)
    let op = super::by_name(operator)?;
    if op.name() == "lemon" {
        Lemon::check_pair(from, to)
            .with_context(|| format!("operator regime for {} -> {}", from.name, to.name))?;
    }
    let small = shape::summarize(from)?;
    let large = shape::summarize(to)?;
    Ok(PairVerification { small, large })
}

/// Statically verify every stage of a built plan and return the per-stage
/// summaries, in stage order. A [`GrowthPlan`] that came out of the builder
/// has already passed this (the builder calls [`verify_pair`] per stage);
/// `ligo analyze` re-runs it to print the summaries.
pub fn verify_plan(plan: &GrowthPlan) -> Result<Vec<PairVerification>> {
    let mut prev = plan.initial();
    let mut out = Vec::with_capacity(plan.stages().len());
    for (i, stage) in plan.stages().iter().enumerate() {
        out.push(
            verify_pair(&stage.operator, prev, &stage.target)
                .with_context(|| format!("growth plan stage {i}"))?,
        );
        prev = &stage.target;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::growth::testutil::{mk_cfg, mk_vision_cfg};

    #[test]
    fn verified_pair_reports_both_summaries() {
        let a = mk_cfg(2, 8, 2);
        let b = mk_cfg(4, 16, 4);
        let pv = verify_pair("stackbert", &a, &b).unwrap();
        assert_eq!(pv.small.name, a.name);
        assert_eq!(pv.large.name, b.name);
        assert!(pv.large.params > pv.small.params);
        assert!(pv.large.fwd_flops > pv.small.fwd_flops);
        assert!(pv.peak_ratio() > 1.0, "{}", pv.peak_ratio());
    }

    #[test]
    fn every_zoo_operator_verifies_a_growing_pair() {
        let a = mk_cfg(2, 8, 2);
        let b = mk_cfg(4, 16, 2);
        for name in crate::growth::ALL {
            verify_pair(name, &a, &b).unwrap();
        }
        // integer width factor + fixed per-head dim: inside lemon's regime
        verify_pair("lemon", &a, &mk_cfg(4, 16, 4)).unwrap();
    }

    #[test]
    fn lemon_regime_violations_are_static_diagnostics() {
        let a = mk_cfg(2, 8, 2);
        // 8 -> 12 is not an integer width factor
        let err = verify_pair("lemon", &a, &mk_cfg(2, 12, 3)).unwrap_err().to_string();
        assert!(err.contains("integer factor"), "{err}");
        assert!(err.contains("operator regime"), "{err}");
        // the same pair passes under the shape-unconstrained zoo
        verify_pair("net2net", &a, &mk_cfg(2, 12, 3)).unwrap();
    }

    #[test]
    fn schedule_violations_name_the_requirement() {
        let a = mk_cfg(4, 12, 3);
        let err = verify_pair("stackbert", &a, &mk_cfg(2, 8, 2)).unwrap_err().to_string();
        assert!(err.contains("shrink"), "{err}");
        let err = verify_pair("stackbert", &a, &a).unwrap_err().to_string();
        assert!(err.contains("not larger"), "{err}");
        let mut geo = mk_cfg(6, 16, 4);
        geo.vocab = 128;
        let err = verify_pair("stackbert", &a, &geo).unwrap_err().to_string();
        assert!(err.contains("batch geometry"), "{err}");
        let err = verify_pair("nope", &a, &mk_cfg(6, 16, 4)).unwrap_err().to_string();
        assert!(err.contains("unknown growth operator"), "{err}");
    }

    #[test]
    fn symbolic_failures_surface_the_offending_node() {
        let a = mk_cfg(2, 8, 2);
        let mut b = mk_cfg(4, 16, 4);
        b.heads = 3; // 16 % 3 != 0: the attention node cannot split heads
        let err = verify_pair("stackbert", &a, &b).unwrap_err().to_string();
        assert!(err.contains("divisible"), "{err}");
        assert!(err.contains("attention"), "{err}");
    }

    #[test]
    fn vision_pairs_verify_and_respect_lemon_geometry() {
        let s = mk_vision_cfg("cait", 2, 8, 2);
        let l = mk_vision_cfg("cait", 4, 16, 4);
        let pv = verify_pair("lemon", &s, &l).unwrap();
        assert!(pv.large.node_count() > pv.small.node_count());
        let mut bad = l.clone();
        bad.cls_layers = 2; // class-attention depth must match for exactness
        let err = verify_pair("lemon", &s, &bad).unwrap_err().to_string();
        assert!(err.contains("class-attention"), "{err}");
    }
}
