//! Static growth verification: prove a growth transition is executable
//! before touching a single kernel.
//!
//! [`verify_pair`] stacks three layers of plan-time checking for one
//! `(operator, small config, large config)` transition:
//!
//! 1. **Schedule compatibility** — [`check_growth_step`]: the target must
//!    genuinely grow (never shrink, never stand still), stay in the same
//!    family, and keep the batch geometry fixed so one batch source can
//!    feed the whole run.
//! 2. **Operator regime** — the operator name must resolve in the registry
//!    ([`super::by_name`]'s diagnostic lists the known names), and LEMON's
//!    exactness preconditions (integer width factors, fixed per-head dim,
//!    matching vocab/seq or image geometry — see
//!    [`Lemon::check_pair`](super::lemon::Lemon::check_pair)) are surfaced
//!    as static diagnostics instead of a mid-run failure.
//! 3. **Symbolic execution** — both endpoint configs are replayed through
//!    the abstract interpreter ([`shape::summarize`]): every tape node's
//!    shapes are checked by the same rules the real tape enforces, and the
//!    resulting [`GraphSummary`] pair reports node/param counts, FLOPs and
//!    the peak-arena estimate for the small and grown model.
//!
//! [`GrowthPlanBuilder::build`](crate::coordinator::plan::GrowthPlanBuilder)
//! runs `verify_pair` on every stage, so *a plan that builds is a plan
//! whose every stage target has already survived a full symbolic
//! forward/backward* — and `ligo analyze` (plus [`verify_plan`]) reuses the
//! same entry point to print what the trainer would execute.

use crate::config::ModelConfig;
use crate::coordinator::plan::GrowthPlan;
use crate::error::{Context, Result};
use crate::model::shape::{self, GraphSummary};

use super::lemon::Lemon;

/// One stage's config transition must genuinely grow and stay compatible
/// with the run's batch source.
pub fn check_growth_step(from: &ModelConfig, to: &ModelConfig) -> Result<()> {
    if from.family != to.family {
        crate::bail!("family must not change ({} -> {})", from.family, to.family);
    }
    if to.layers < from.layers || to.dim < from.dim || to.ffn() < from.ffn() {
        crate::bail!(
            "target must not shrink (layers {} -> {}, dim {} -> {}, ffn {} -> {})",
            from.layers, to.layers, from.dim, to.dim, from.ffn(), to.ffn()
        );
    }
    if to.layers == from.layers && to.dim == from.dim && to.ffn() == from.ffn() {
        crate::bail!("target is not larger in any dimension");
    }
    let batch_geom = |c: &ModelConfig| {
        (c.vocab, c.seq, c.batch, c.img, c.patch, c.channels, c.n_classes)
    };
    if batch_geom(from) != batch_geom(to) {
        crate::bail!(
            "batch geometry must match across stages (one batch source feeds \
             the whole run): {:?} -> {:?}",
            batch_geom(from),
            batch_geom(to)
        );
    }
    Ok(())
}

/// One-line static-regime summary per registry operator: which transitions
/// the operator accepts *beyond* the schedule checks every stage passes.
/// `ligo inspect operators`, [`super::by_name`]'s unknown-operator
/// diagnostic and the `ligo search` prune log all print these, so the CLI
/// and the search reports agree on why a candidate was rejected.
pub fn regime_summary(name: &str) -> &'static str {
    match name {
        "direct_copy" => "any growing pair (copy into the corner, random elsewhere)",
        "net2net" => "any growing pair (function-preserving width, stacked depth)",
        "aki" => "any growing pair (width FPI + advanced knowledge from layer i+1)",
        "stackbert" => "any growing pair (depth by block duplication, width FPI)",
        "interpolation" => "any growing pair (depth by interleaving, width FPI)",
        "mslt" => "any growing pair (depth appended on top, width FPI)",
        "lemon" => {
            "exact only on integer width factors with fixed per-head dim \
             (and matching vocab/seq or image geometry)"
        }
        "ligo" => "any growing pair (learned M; route negotiated from the context)",
        _ => "unknown operator (see `ligo inspect operators`)",
    }
}

/// The two [`GraphSummary`]s a verified transition produces: what the
/// trainer executes before the growth step and after it.
#[derive(Debug, Clone)]
pub struct PairVerification {
    pub small: GraphSummary,
    pub large: GraphSummary,
}

impl PairVerification {
    /// Peak-arena growth factor of the transition (large / small).
    pub fn peak_ratio(&self) -> f64 {
        self.large.peak_bytes as f64 / (self.small.peak_bytes.max(1)) as f64
    }
}

/// Statically verify one growth transition (see the module docs for the
/// three layers). No kernels run and no parameter data is touched — only
/// shapes flow. Errors carry the violated requirement and, for symbolic
/// failures, the offending node.
pub fn verify_pair(
    operator: &str,
    from: &ModelConfig,
    to: &ModelConfig,
) -> Result<PairVerification> {
    check_growth_step(from, to)
        .with_context(|| format!("growth step {} -> {}", from.name, to.name))?;
    // resolve now so a typo fails statically with the registry's own
    // diagnostic (listing the known operators)
    let op = super::by_name(operator)?;
    if op.name() == "lemon" {
        Lemon::check_pair(from, to)
            .with_context(|| format!("operator regime for {} -> {}", from.name, to.name))?;
    }
    let small = shape::summarize(from)?;
    let large = shape::summarize(to)?;
    Ok(PairVerification { small, large })
}

/// Statically verify every stage of a built plan and return the per-stage
/// summaries, in stage order. A [`GrowthPlan`] that came out of the builder
/// has already passed this (the builder calls [`verify_pair`] per stage);
/// `ligo analyze` re-runs it to print the summaries.
pub fn verify_plan(plan: &GrowthPlan) -> Result<Vec<PairVerification>> {
    let mut prev = plan.initial();
    let mut out = Vec::with_capacity(plan.stages().len());
    for (i, stage) in plan.stages().iter().enumerate() {
        out.push(
            verify_pair(&stage.operator, prev, &stage.target)
                .with_context(|| format!("growth plan stage {i}"))?,
        );
        prev = &stage.target;
    }
    Ok(out)
}

/// Statically verify one *chain* of transitions `initial -> targets[0] ->
/// targets[1] -> …`, all under `operator` — the shape of one growth-search
/// candidate before it has step numbers. Returns the per-transition
/// summaries in chain order; the first violated requirement aborts the
/// chain with a stage-indexed diagnostic.
pub fn verify_chain(
    operator: &str,
    initial: &ModelConfig,
    targets: &[ModelConfig],
) -> Result<Vec<PairVerification>> {
    let mut prev = initial;
    let mut out = Vec::with_capacity(targets.len());
    for (i, target) in targets.iter().enumerate() {
        out.push(
            verify_pair(operator, prev, target)
                .with_context(|| format!("chain stage {i} ({} -> {})", prev.name, target.name))?,
        );
        prev = target;
    }
    Ok(out)
}

/// Batch verification over many candidate chains: every chain gets its own
/// verdict (no early exit across candidates), so an enumerated search space
/// can be partitioned into survivors and typed rejections in one pass —
/// entirely symbolically, before any kernel runs.
pub fn verify_batch(
    initial: &ModelConfig,
    chains: &[(String, Vec<ModelConfig>)],
) -> Vec<Result<Vec<PairVerification>>> {
    chains
        .iter()
        .map(|(operator, targets)| verify_chain(operator, initial, targets))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::growth::testutil::{mk_cfg, mk_vision_cfg};

    #[test]
    fn verified_pair_reports_both_summaries() {
        let a = mk_cfg(2, 8, 2);
        let b = mk_cfg(4, 16, 4);
        let pv = verify_pair("stackbert", &a, &b).unwrap();
        assert_eq!(pv.small.name, a.name);
        assert_eq!(pv.large.name, b.name);
        assert!(pv.large.params > pv.small.params);
        assert!(pv.large.fwd_flops > pv.small.fwd_flops);
        assert!(pv.peak_ratio() > 1.0, "{}", pv.peak_ratio());
    }

    #[test]
    fn every_zoo_operator_verifies_a_growing_pair() {
        let a = mk_cfg(2, 8, 2);
        let b = mk_cfg(4, 16, 2);
        for name in crate::growth::ALL {
            verify_pair(name, &a, &b).unwrap();
        }
        // integer width factor + fixed per-head dim: inside lemon's regime
        verify_pair("lemon", &a, &mk_cfg(4, 16, 4)).unwrap();
    }

    #[test]
    fn lemon_regime_violations_are_static_diagnostics() {
        let a = mk_cfg(2, 8, 2);
        // 8 -> 12 is not an integer width factor
        let err = verify_pair("lemon", &a, &mk_cfg(2, 12, 3)).unwrap_err().to_string();
        assert!(err.contains("integer factor"), "{err}");
        assert!(err.contains("operator regime"), "{err}");
        // the same pair passes under the shape-unconstrained zoo
        verify_pair("net2net", &a, &mk_cfg(2, 12, 3)).unwrap();
    }

    #[test]
    fn schedule_violations_name_the_requirement() {
        let a = mk_cfg(4, 12, 3);
        let err = verify_pair("stackbert", &a, &mk_cfg(2, 8, 2)).unwrap_err().to_string();
        assert!(err.contains("shrink"), "{err}");
        let err = verify_pair("stackbert", &a, &a).unwrap_err().to_string();
        assert!(err.contains("not larger"), "{err}");
        let mut geo = mk_cfg(6, 16, 4);
        geo.vocab = 128;
        let err = verify_pair("stackbert", &a, &geo).unwrap_err().to_string();
        assert!(err.contains("batch geometry"), "{err}");
        let err = verify_pair("nope", &a, &mk_cfg(6, 16, 4)).unwrap_err().to_string();
        assert!(err.contains("unknown growth operator"), "{err}");
    }

    #[test]
    fn symbolic_failures_surface_the_offending_node() {
        let a = mk_cfg(2, 8, 2);
        let mut b = mk_cfg(4, 16, 4);
        b.heads = 3; // 16 % 3 != 0: the attention node cannot split heads
        let err = verify_pair("stackbert", &a, &b).unwrap_err().to_string();
        assert!(err.contains("divisible"), "{err}");
        assert!(err.contains("attention"), "{err}");
    }

    #[test]
    fn chains_verify_in_order_and_batches_keep_per_chain_verdicts() {
        let a = mk_cfg(2, 8, 2);
        let b = mk_cfg(4, 8, 2);
        let c = mk_cfg(4, 12, 3);
        let pvs = verify_chain("stackbert", &a, &[b.clone(), c.clone()]).unwrap();
        assert_eq!(pvs.len(), 2);
        assert_eq!(pvs[0].small.name, a.name);
        assert_eq!(pvs[1].large.name, c.name);
        // a later-stage violation names its stage index
        let err = verify_chain("stackbert", &a, &[b.clone(), b.clone()])
            .unwrap_err()
            .to_string();
        assert!(err.contains("chain stage 1"), "{err}");
        assert!(err.contains("not larger"), "{err}");
        // batch: one bad chain does not sink the others
        let chains = vec![
            ("stackbert".to_string(), vec![b.clone(), c.clone()]),
            ("lemon".to_string(), vec![c.clone()]), // 8 -> 12: not integer
            ("net2net".to_string(), vec![c.clone()]),
        ];
        let verdicts = verify_batch(&a, &chains);
        assert!(verdicts[0].is_ok() && verdicts[2].is_ok());
        let err = verdicts[1].as_ref().unwrap_err().to_string();
        assert!(err.contains("integer factor"), "{err}");
    }

    #[test]
    fn every_known_operator_has_a_regime_summary() {
        for name in crate::growth::KNOWN {
            let s = regime_summary(name);
            assert!(!s.contains("unknown"), "{name}: {s}");
        }
        assert!(regime_summary("lemon").contains("integer"));
        assert!(regime_summary("bogus").contains("unknown"));
    }

    #[test]
    fn vision_pairs_verify_and_respect_lemon_geometry() {
        let s = mk_vision_cfg("cait", 2, 8, 2);
        let l = mk_vision_cfg("cait", 4, 16, 4);
        let pv = verify_pair("lemon", &s, &l).unwrap();
        assert!(pv.large.node_count() > pv.small.node_count());
        let mut bad = l.clone();
        bad.cls_layers = 2; // class-attention depth must match for exactness
        let err = verify_pair("lemon", &s, &bad).unwrap_err().to_string();
        assert!(err.contains("class-attention"), "{err}");
    }
}
