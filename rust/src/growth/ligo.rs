//! Native LiGO — the paper's learned Linear Growth Operator (§3.2-3.3,
//! Algorithm 1) ported from `python/compile/ligo.py` onto the named tensor
//! store, so `growth::by_name("ligo")` works end to end with no AOT
//! artifacts and no XLA.
//!
//! The growth map  vec(Theta_new) = (w (x) I) . blockdiag(A_l (x) B_l)
//! vec(Theta)  is applied exactly as Algorithm 1: a width pass that grows
//! every small-model tensor via the fused triple product `B W A^T`
//! ([`crate::tensor::ops::expand`]), followed by a depth pass that forms
//! each large layer as a learned linear blend of the width-grown small
//! layers ([`crate::tensor::ops::weighted_sum`]). Both halves of the
//! triple product ride the vectorizable blocked matmul kernels (the
//! `matmul_nt` packed path), and [`ligo_apply_backward`] recycles its
//! large-model-sized temporaries through [`crate::tensor::arena`], so the
//! per-M-step cost of the task-native route is compute-, not
//! allocator-bound.
//!
//! Weight tying (Appendix B.1), which makes M learnable from ~100 steps:
//!   * `A^k = B_emb^T` for k in {Q, K, V, fc1}  (residual-stream inputs)
//!   * `A^O = B_V^T`,  `A^fc2 = B_fc1^T`        (inner-dim alignment)
//!   * `B^O = B^fc2 = B_emb`                    (residual-stream outputs)
//!   * biases / LayerNorms grow with their module's out-expansion matrix
//!   * output head: `A^out = B_emb^T`, no out-expansion
//!
//! Learned LiGO parameters (a flat [`Store`], same names as the AOT
//! manifests' "ligo" group): `B_emb, B_q, B_k, B_v` (D2, D1), `B_fc1`
//! (F2, F1), and per-module depth blends `w_q .. w_ln2` (L2, L1). The
//! *untied* general form of the operator additionally admits `A_emb, A_v,
//! A_fc1` in-expansion matrices; Prop. 1's exact-equivalence instances
//! (Net2Net's multiplicity-normalized selection) live in that form, while
//! the learned path keeps the tied parameterization above.
//!
//! M-learning routes through the **one** public entry point,
//! [`Ligo`]'s `grow(ctx)`: given a [`GrowthContext`] with a batch source, M
//! trains against the expanded model's **task loss** — the native engine
//! (`crate::model`) computes dL/dTheta_large and [`ligo_apply_backward`]
//! chains it through the expansion into dL/dM (a context that also carries
//! a runtime handle tries the fused `ligo_grad_*` artifact first, the
//! `pjrt` fast path for the same objective). A param-only context falls
//! back to a *surrogate* objective — a least-squares fit of the expanded
//! weight matrices (plus text/vision embedding anchors and CaiT
//! class-attention terms) to an ensemble of the strongest non-learned
//! baselines (StackBERT + Interpolation), with exact analytic gradients
//! through the `B W A^T` factorization and the depth blends. The route
//! decision is made exactly once, in `coordinator::growth_manager`, and is
//! logged in the returned [`GrowthOutcome`].

use crate::config::ModelConfig;
use crate::tensor::ops;
use crate::tensor::store::Store;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::stacking::{Interpolation, StackBert};
use super::{layer_key, layer_suffixes, Capability, GrowthContext, GrowthOperator, GrowthOutcome};

/// Per-module depth-blend families, in python `ligo.DEPTH_MODULES` order.
pub const DEPTH_MODULES: [&str; 8] = ["q", "k", "v", "o", "ln1", "fc1", "fc2", "ln2"];
/// Extra CaiT per-layer scales that also get depth blends.
pub const CAIT_DEPTH_MODULES: [&str; 2] = ["ls1", "ls2"];

/// Per-layer suffixes of the CaiT class-attention stage (width-grown only;
/// its depth is fixed, mirroring python `ligo_apply`).
const CLS_SUFFIXES: [&str; 16] = [
    "q_w", "q_b", "k_w", "k_b", "v_w", "v_b", "o_w", "o_b", "ln1_g", "ln1_b",
    "fc1_w", "fc1_b", "fc2_w", "fc2_b", "ln2_g", "ln2_b",
];

fn depth_modules(cfg: &ModelConfig) -> Vec<&'static str> {
    let mut v = DEPTH_MODULES.to_vec();
    if cfg.family == "cait" {
        v.extend(CAIT_DEPTH_MODULES);
    }
    v
}

/// Depth-blend module of a per-layer suffix: "q_w" -> "q", "ln1_g" -> "ln1",
/// "ls1" -> "ls1".
fn module_of(suffix: &str) -> &str {
    suffix.rsplit_once('_').map(|(m, _)| m).unwrap_or(suffix)
}

// ---------------------------------------------------------------------------
// Initialization of M (stacking + neuron-duplication pattern, Prop. 1)
// ---------------------------------------------------------------------------

/// (rows, cols) selection matrix whose row i selects small index (i mod
/// cols): the Net2Net neuron-duplication / StackBERT stacking pattern.
pub fn dup_matrix(rows: usize, cols: usize) -> Tensor {
    let mut t = Tensor::zeros(&[rows, cols]);
    let v = t.f32s_mut();
    for r in 0..rows {
        v[r * cols + (r % cols)] = 1.0;
    }
    t
}

/// The duplication pattern with each column scaled by 1/multiplicity —
/// the in-expansion (`A`) side of Net2Net's function-preserving growth
/// (paper Eq. 2's D^-1).
pub fn normalized_dup_matrix(rows: usize, cols: usize) -> Tensor {
    let mut counts = vec![0usize; cols];
    for r in 0..rows {
        counts[r % cols] += 1;
    }
    let mut t = Tensor::zeros(&[rows, cols]);
    let v = t.f32s_mut();
    for r in 0..rows {
        let c = r % cols;
        v[r * cols + c] = 1.0 / counts[c] as f32;
    }
    t
}

fn noisy_dup(rows: usize, cols: usize, noise: f32, rng: &mut Rng) -> Tensor {
    let mut t = dup_matrix(rows, cols);
    if noise != 0.0 {
        for v in t.f32s_mut() {
            *v += noise * rng.normal();
        }
    }
    t
}

/// Initialize the LiGO parameter store M from the config pair: width
/// matrices get the cyclic duplication pattern, depth matrices the stacking
/// pattern (both + symmetry-breaking noise) — mirrors python `ligo_init`.
/// Width params are omitted when dims match (depth-only growth, Fig. 6);
/// depth params are omitted when layer counts match (width-only growth).
pub fn ligo_init(cfg_s: &ModelConfig, cfg_l: &ModelConfig, noise: f32, seed: u64) -> Store {
    let mut rng = Rng::new(seed ^ 0x11C0);
    let mut m = Store::new();
    let (d1, d2) = (cfg_s.dim, cfg_l.dim);
    let (f1, f2) = (cfg_s.ffn(), cfg_l.ffn());
    if d1 != d2 || f1 != f2 {
        m.insert("B_emb", noisy_dup(d2, d1, noise, &mut rng));
        m.insert("B_q", noisy_dup(d2, d1, noise, &mut rng));
        m.insert("B_k", noisy_dup(d2, d1, noise, &mut rng));
        m.insert("B_v", noisy_dup(d2, d1, noise, &mut rng));
        m.insert("B_fc1", noisy_dup(f2, f1, noise, &mut rng));
    }
    if cfg_s.layers != cfg_l.layers {
        for module in depth_modules(cfg_s) {
            m.insert(
                format!("w_{module}"),
                noisy_dup(cfg_l.layers, cfg_s.layers, noise, &mut rng),
            );
        }
    }
    m
}

/// Depth-blend initialization patterns for the Prop. 1 special cases.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DepthInit {
    /// StackBERT: layer l blends from layer (l mod L1).
    Stack,
    /// Interpolation / InterBERT: layer l blends from floor(l / ceil(L2/L1)).
    Interpolate,
    /// MSLT: new layers duplicate the top small layer.
    TopDup,
    /// Net2Net-style near-identity depth: existing layers keep themselves,
    /// new layers copy the top layer but zero the residual-writing modules
    /// (o, fc2) so the new blocks start as no-ops.
    NearIdentity,
}

fn depth_pattern(init: DepthInit, module: &str, l2: usize, l1: usize) -> Tensor {
    let mut w = Tensor::zeros(&[l2, l1]);
    let k = l2.div_ceil(l1);
    let v = w.f32s_mut();
    for i in 0..l2 {
        let src = match init {
            DepthInit::Stack => i % l1,
            DepthInit::Interpolate => (i / k.max(1)).min(l1 - 1),
            DepthInit::TopDup => i.min(l1 - 1),
            DepthInit::NearIdentity => {
                if i >= l1 && (module == "o" || module == "fc2") {
                    continue; // zero row: the new block's residual branch is a no-op
                }
                i.min(l1 - 1)
            }
        };
        v[i * l1 + src] = 1.0;
    }
    w
}

/// Noise-free selection-pattern M (Prop. 1): plain duplication on the
/// out-expansions, optionally multiplicity-normalized duplication on the
/// untied in-expansions (`A_emb`/`A_v`/`A_fc1`, matching Net2Net's D^-1),
/// and the chosen depth pattern. With `normalize_inputs` these instances
/// reproduce the non-learned zoo operators exactly (see tests/prop_ligo.rs).
pub fn selection_m(
    cfg_s: &ModelConfig,
    cfg_l: &ModelConfig,
    depth: DepthInit,
    normalize_inputs: bool,
) -> Store {
    let mut m = Store::new();
    let (d1, d2) = (cfg_s.dim, cfg_l.dim);
    let (f1, f2) = (cfg_s.ffn(), cfg_l.ffn());
    if d1 != d2 || f1 != f2 {
        m.insert("B_emb", dup_matrix(d2, d1));
        m.insert("B_q", dup_matrix(d2, d1));
        m.insert("B_k", dup_matrix(d2, d1));
        m.insert("B_v", dup_matrix(d2, d1));
        m.insert("B_fc1", dup_matrix(f2, f1));
        if normalize_inputs {
            m.insert("A_emb", normalized_dup_matrix(d2, d1));
            m.insert("A_v", normalized_dup_matrix(d2, d1));
            m.insert("A_fc1", normalized_dup_matrix(f2, f1));
        }
    }
    if cfg_s.layers != cfg_l.layers {
        for module in depth_modules(cfg_s) {
            m.insert(
                format!("w_{module}"),
                depth_pattern(depth, module, cfg_l.layers, cfg_s.layers),
            );
        }
    }
    m
}

// ---------------------------------------------------------------------------
// Applying M: width pass (fused B W A^T) + depth pass (learned blends)
// ---------------------------------------------------------------------------

/// Resolved width-expansion matrices (identity fallback for depth-only M,
/// tied fallback `A_x = B_x` when no untied in-expansion is present).
struct WidthCtx {
    b_emb: Tensor,
    b_q: Tensor,
    b_k: Tensor,
    b_v: Tensor,
    b_fc1: Tensor,
    a_emb: Tensor,
    a_v: Tensor,
    a_fc1: Tensor,
}

fn get_b(m: &Store, name: &str, rows: usize, cols: usize) -> Tensor {
    match m.get(name) {
        Some(t) => {
            assert_eq!(t.shape, vec![rows, cols], "LiGO width matrix {name}");
            t.clone()
        }
        None => {
            assert_eq!(rows, cols, "missing LiGO matrix {name} but dims differ: {rows} vs {cols}");
            ops::eye(rows)
        }
    }
}

fn get_a(m: &Store, untied: &str, tied: &Tensor, rows: usize, cols: usize) -> Tensor {
    match m.get(untied) {
        Some(t) => {
            assert_eq!(t.shape, vec![rows, cols], "LiGO width matrix {untied}");
            t.clone()
        }
        None => tied.clone(),
    }
}

fn width_ctx(m: &Store, cfg_s: &ModelConfig, cfg_l: &ModelConfig) -> WidthCtx {
    let (d1, d2) = (cfg_s.dim, cfg_l.dim);
    let (f1, f2) = (cfg_s.ffn(), cfg_l.ffn());
    let b_emb = get_b(m, "B_emb", d2, d1);
    let b_q = get_b(m, "B_q", d2, d1);
    let b_k = get_b(m, "B_k", d2, d1);
    let b_v = get_b(m, "B_v", d2, d1);
    let b_fc1 = get_b(m, "B_fc1", f2, f1);
    let a_emb = get_a(m, "A_emb", &b_emb, d2, d1);
    let a_v = get_a(m, "A_v", &b_v, d2, d1);
    let a_fc1 = get_a(m, "A_fc1", &b_fc1, f2, f1);
    WidthCtx { b_emb, b_q, b_k, b_v, b_fc1, a_emb, a_v, a_fc1 }
}

/// Width-grow one per-layer tensor: fused `B W A^T` for matrices (A tied
/// per Appendix B.1), the module's out-expansion for biases/LayerNorms.
fn expand_one(ctx: &WidthCtx, suffix: &str, t: &Tensor) -> Tensor {
    match suffix {
        "q_w" => ops::expand(&ctx.b_q, t, &ctx.a_emb),
        "k_w" => ops::expand(&ctx.b_k, t, &ctx.a_emb),
        "v_w" => ops::expand(&ctx.b_v, t, &ctx.a_emb),
        "o_w" => ops::expand(&ctx.b_emb, t, &ctx.a_v),
        "fc1_w" => ops::expand(&ctx.b_fc1, t, &ctx.a_emb),
        "fc2_w" => ops::expand(&ctx.b_emb, t, &ctx.a_fc1),
        "q_b" => ops::matvec(&ctx.b_q, t),
        "k_b" => ops::matvec(&ctx.b_k, t),
        "v_b" => ops::matvec(&ctx.b_v, t),
        "fc1_b" => ops::matvec(&ctx.b_fc1, t),
        "o_b" | "fc2_b" | "ln1_g" | "ln1_b" | "ln2_g" | "ln2_b" | "ls1" | "ls2" => {
            ops::matvec(&ctx.b_emb, t)
        }
        other => panic!("ligo_apply: unknown per-layer suffix '{other}'"),
    }
}

/// Width-grow a non-layer tensor by its role (mirrors python `ligo_apply`'s
/// tail; the head reads the residual stream, so it rides the in-expansion).
fn expand_nonlayer(ctx: &WidthCtx, name: &str, t: &Tensor) -> Tensor {
    match name {
        "emb_tok" | "emb_pos" => ops::matmul_nt(t, &ctx.b_emb),
        "mlm_bias" | "head_b" | "span_b" => t.clone(),
        "head_w" | "span_w" => ops::matmul_nt(t, &ctx.a_emb),
        "final_ln_g" | "final_ln_b" | "emb_cls" | "emb_patch_b" => ops::matvec(&ctx.b_emb, t),
        "emb_patch_w" => ops::matmul(&ctx.b_emb, t),
        other => panic!("ligo_apply: unknown non-layer tensor '{other}'"),
    }
}

/// Materialize the large model's parameters: Theta_new = M(Theta).
///
/// Width pass first (every small tensor through its expansion), then the
/// per-module depth blends. Missing width matrices fall back to identity
/// (depth-only M); missing depth blends require equal layer counts
/// (width-only M).
pub fn ligo_apply(m: &Store, small: &Store, cfg_s: &ModelConfig, cfg_l: &ModelConfig) -> Store {
    let ctx = width_ctx(m, cfg_s, cfg_l);
    let mut out = Store::new();
    // ---- body layers: width pass, then depth blends ----
    for suffix in layer_suffixes(cfg_s) {
        let wide: Vec<Tensor> = (0..cfg_s.layers)
            .map(|l| expand_one(&ctx, suffix, small.expect(&layer_key(l, suffix))))
            .collect();
        match m.get(&format!("w_{}", module_of(suffix))) {
            Some(w) => {
                assert_eq!(
                    w.shape,
                    vec![cfg_l.layers, cfg_s.layers],
                    "LiGO depth blend w_{}",
                    module_of(suffix)
                );
                let refs: Vec<&Tensor> = wide.iter().collect();
                for i in 0..cfg_l.layers {
                    let row: Vec<f32> = (0..cfg_s.layers).map(|j| w.at2(i, j)).collect();
                    out.insert(layer_key(i, suffix), ops::weighted_sum(&row, &refs));
                }
            }
            None => {
                assert_eq!(
                    cfg_s.layers, cfg_l.layers,
                    "missing depth blend w_{} but layer counts differ",
                    module_of(suffix)
                );
                for (i, t) in wide.into_iter().enumerate() {
                    out.insert(layer_key(i, suffix), t);
                }
            }
        }
    }
    // ---- non-layer tensors ----
    for (name, t) in small.iter() {
        if name.starts_with('L') || name.starts_with('C') {
            continue;
        }
        out.insert(name.clone(), expand_nonlayer(&ctx, name, t));
    }
    // ---- CaiT class-attention stage: widths grow, depth is fixed ----
    if cfg_s.family == "cait" {
        assert_eq!(cfg_s.cls_layers, cfg_l.cls_layers, "CaiT class-attention depth is fixed");
        for l in 0..cfg_s.cls_layers {
            for suffix in CLS_SUFFIXES {
                let key = format!("C{l:02}_{suffix}");
                out.insert(key.clone(), expand_one(&ctx, suffix, small.expect(&key)));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Backward through the expansion: dL/dTheta_large -> dL/dM
// ---------------------------------------------------------------------------

/// Resolved out-expansion of a per-layer suffix: the tensor applied by
/// [`expand_one`] plus the learned parameter name it came from.
fn b_of<'a>(ctx: &'a WidthCtx, suffix: &str) -> (&'a Tensor, &'static str) {
    match suffix {
        "q_w" | "q_b" => (&ctx.b_q, "B_q"),
        "k_w" | "k_b" => (&ctx.b_k, "B_k"),
        "v_w" | "v_b" => (&ctx.b_v, "B_v"),
        "fc1_w" | "fc1_b" => (&ctx.b_fc1, "B_fc1"),
        "o_w" | "fc2_w" | "o_b" | "fc2_b" | "ln1_g" | "ln1_b" | "ln2_g" | "ln2_b" | "ls1"
        | "ls2" => (&ctx.b_emb, "B_emb"),
        other => panic!("ligo backward: unknown suffix '{other}'"),
    }
}

/// Resolved in-expansion of a weight suffix: the tensor [`expand_one`]
/// applies plus its (untied, tied) parameter names.
fn a_of<'a>(ctx: &'a WidthCtx, suffix: &str) -> (&'a Tensor, &'static str, &'static str) {
    match suffix {
        "q_w" | "k_w" | "v_w" | "fc1_w" => (&ctx.a_emb, "A_emb", "B_emb"),
        "o_w" => (&ctx.a_v, "A_v", "B_v"),
        "fc2_w" => (&ctx.a_fc1, "A_fc1", "B_fc1"),
        other => panic!("ligo backward: '{other}' has no in-expansion"),
    }
}

/// Name the in-expansion gradient accumulates into: the untied matrix when
/// M carries one, else the tied partner, else none (identity fallback).
fn a_target(m: &Store, untied: &'static str, tied: &'static str) -> Option<&'static str> {
    if m.contains(untied) {
        Some(untied)
    } else if m.contains(tied) {
        Some(tied)
    } else {
        None
    }
}

/// [`add_scaled`] for an owned contribution: the first write to a slot
/// *moves* the tensor in (scaled in place, no copy); later writes
/// accumulate and recycle the consumed buffer into the arena. The
/// expansion backward builds one large-model-sized temporary per layer per
/// M-step; this keeps the task-native M-learning loop allocation-flat.
fn add_scaled_owned(grads: &mut Store, name: &str, mut t: Tensor, s: f32) {
    if grads.contains(name) {
        add_scaled(grads, name, &t, s);
        crate::tensor::arena::recycle(t);
    } else {
        if s != 1.0 {
            for v in t.f32s_mut() {
                *v *= s;
            }
        }
        grads.insert(name.to_string(), t);
    }
}

/// Rank-1 outer product e x^T (the vector families' B-gradient shape).
fn outer(e: &Tensor, x: &Tensor) -> Tensor {
    let (rows, cols) = (e.numel(), x.numel());
    let mut t = Tensor::zeros(&[rows, cols]);
    let tv = t.f32s_mut();
    for (i, &ei) in e.f32s().iter().enumerate() {
        for (j, &xj) in x.f32s().iter().enumerate() {
            tv[i * cols + j] = ei * xj;
        }
    }
    t
}

const WEIGHT_SUFFIXES: [&str; 6] = ["q_w", "k_w", "v_w", "o_w", "fc1_w", "fc2_w"];

/// Backward of [`ligo_apply`]: chain dL/dTheta_large (the native engine's
/// gradient store for the expanded model) through the depth blends, the
/// fused `B W A^T` width pass and the Appendix B.1 tying, producing dL/dM
/// for every *learned* entry of M (identity fallbacks get no gradient).
/// This is what makes the paper's true task-loss M-learning possible with
/// no XLA: `Theta_i = sum_j w_ij B W_j A^T` gives
/// `dw_ij = <E_i, B W_j A^T>`, `dB = sum_i E_i A W_hat_i^T`,
/// `dA = sum_i E_i^T B W_hat_i` with `W_hat_i = sum_j w_ij W_j`, and tied
/// in-expansions accumulate into their shared matrix.
pub fn ligo_apply_backward(
    m: &Store,
    small: &Store,
    grads_large: &Store,
    cfg_s: &ModelConfig,
    cfg_l: &ModelConfig,
) -> Store {
    let ctx = width_ctx(m, cfg_s, cfg_l);
    let (l1, l2) = (cfg_s.layers, cfg_l.layers);
    let mut gm = Store::new();
    for suffix in layer_suffixes(cfg_s) {
        let is_weight = WEIGHT_SUFFIXES.contains(&suffix);
        let (b, bname) = b_of(&ctx, suffix);
        let b_learned = m.contains(bname);
        let a_info = if is_weight { Some(a_of(&ctx, suffix)) } else { None };
        let a_name = a_info.and_then(|(_, u, t)| a_target(m, u, t));
        let smalls: Vec<&Tensor> = (0..l1).map(|j| small.expect(&layer_key(j, suffix))).collect();
        let ps: Vec<Tensor> = smalls.iter().map(|t| expand_one(&ctx, suffix, t)).collect();
        let blend = format!("w_{}", module_of(suffix));
        let w = m.get(&blend);
        let mut gw = w.map(|_| Tensor::zeros(&[l2, l1]));
        for i in 0..l2 {
            let e = grads_large.expect(&layer_key(i, suffix));
            let row: Vec<f32> = match w {
                Some(wt) => (0..l1).map(|j| wt.at2(i, j)).collect(),
                None => (0..l1).map(|j| if j == i { 1.0 } else { 0.0 }).collect(),
            };
            if let Some(g) = gw.as_mut() {
                let gv = g.f32s_mut();
                for (j, pj) in ps.iter().enumerate() {
                    gv[i * l1 + j] += ops::dot(e, pj);
                }
            }
            if !b_learned && a_name.is_none() {
                continue; // depth-only M: nothing else learns here
            }
            let w_hat = ops::weighted_sum(&row, &smalls);
            if is_weight {
                let (a, _, _) = a_info.expect("weight suffixes carry an in-expansion");
                if b_learned {
                    let ea = ops::matmul(e, a);
                    let gb = ops::matmul_nt(&ea, &w_hat);
                    crate::tensor::arena::recycle(ea);
                    add_scaled_owned(&mut gm, bname, gb, 1.0);
                }
                if let Some(an) = a_name {
                    let et = ops::transpose(e);
                    let bw = ops::matmul(b, &w_hat);
                    let ga = ops::matmul(&et, &bw);
                    crate::tensor::arena::recycle(et);
                    crate::tensor::arena::recycle(bw);
                    add_scaled_owned(&mut gm, an, ga, 1.0);
                }
            } else if b_learned {
                add_scaled_owned(&mut gm, bname, outer(e, &w_hat), 1.0);
            }
            crate::tensor::arena::recycle(w_hat);
        }
        if let Some(g) = gw {
            add_scaled(&mut gm, &blend, &g, 1.0);
        }
        for p in ps {
            crate::tensor::arena::recycle(p);
        }
    }
    // ---- non-layer tensors (mirror expand_nonlayer) ----
    for (name, x) in small.iter() {
        if name.starts_with('L') || name.starts_with('C') {
            continue;
        }
        let e = grads_large.expect(name);
        match name.as_str() {
            "emb_tok" | "emb_pos" => {
                if m.contains("B_emb") {
                    // Y = X B^T  =>  dB = E^T X
                    let et = ops::transpose(e);
                    let gb = ops::matmul(&et, x);
                    crate::tensor::arena::recycle(et);
                    add_scaled_owned(&mut gm, "B_emb", gb, 1.0);
                }
            }
            "mlm_bias" | "head_b" | "span_b" => {}
            "head_w" | "span_w" => {
                if let Some(an) = a_target(m, "A_emb", "B_emb") {
                    add_scaled(&mut gm, an, &ops::matmul(&ops::transpose(e), x), 1.0);
                }
            }
            "final_ln_g" | "final_ln_b" | "emb_cls" | "emb_patch_b" => {
                if m.contains("B_emb") {
                    add_scaled(&mut gm, "B_emb", &outer(e, x), 1.0);
                }
            }
            "emb_patch_w" => {
                if m.contains("B_emb") {
                    // Y = B X  =>  dB = E X^T
                    add_scaled(&mut gm, "B_emb", &ops::matmul_nt(e, x), 1.0);
                }
            }
            other => panic!("ligo_apply_backward: unknown non-layer tensor '{other}'"),
        }
    }
    // ---- CaiT class-attention stage: width-grown, depth fixed ----
    if cfg_s.family == "cait" {
        for l in 0..cfg_s.cls_layers {
            for suffix in CLS_SUFFIXES {
                let key = format!("C{l:02}_{suffix}");
                let x = small.expect(&key);
                let e = grads_large.expect(&key);
                let (b, bname) = b_of(&ctx, suffix);
                if WEIGHT_SUFFIXES.contains(&suffix) {
                    let (a, untied, tied) = a_of(&ctx, suffix);
                    if m.contains(bname) {
                        let gb = ops::matmul_nt(&ops::matmul(e, a), x);
                        add_scaled(&mut gm, bname, &gb, 1.0);
                    }
                    if let Some(an) = a_target(m, untied, tied) {
                        let ga = ops::matmul(&ops::transpose(e), &ops::matmul(b, x));
                        add_scaled(&mut gm, an, &ga, 1.0);
                    }
                } else if m.contains(bname) {
                    add_scaled(&mut gm, bname, &outer(e, x), 1.0);
                }
            }
        }
    }
    gm
}

// ---------------------------------------------------------------------------
// Native M-learning: SGD-momentum on the surrogate least-squares objective
// ---------------------------------------------------------------------------

/// The surrogate fit target: the average of the two strongest non-learned
/// depth-growth baselines (StackBERT and Interpolation). Fitting M to the
/// ensemble couples every layer through the shared width matrices, which is
/// exactly the structure the paper's M-learning exploits.
pub fn surrogate_target(small: &Store, cfg_s: &ModelConfig, cfg_l: &ModelConfig) -> Store {
    let stack = StackBert.expand(small, cfg_s, cfg_l);
    let interp = Interpolation.expand(small, cfg_s, cfg_l);
    stack
        .iter()
        .map(|(name, t)| {
            (name.clone(), ops::weighted_sum(&[0.5, 0.5], &[t, interp.expect(name)]))
        })
        .collect()
}

fn sum_sq(t: &Tensor) -> f32 {
    t.f32s().iter().map(|x| x * x).sum()
}

fn add_scaled(grads: &mut Store, name: &str, t: &Tensor, s: f32) {
    if let Some(g) = grads.get_mut(name) {
        for (gv, tv) in g.f32s_mut().iter_mut().zip(t.f32s()) {
            *gv += s * tv;
        }
        return;
    }
    grads.insert(name.to_string(), ops::scale(t, s));
}

/// One width family's resolved expansion matrices for the surrogate
/// objective (learned B / untied-or-tied A / identity fallbacks).
struct FamilyW {
    b: Tensor,
    a: Tensor,
    b_learned: bool,
    a_name: Option<&'static str>,
}

#[allow(clippy::too_many_arguments)]
fn resolve_family(
    m: &Store,
    bname: &'static str,
    a_untied: &'static str,
    a_tied: &'static str,
    o2: usize,
    o1: usize,
    i2: usize,
    i1: usize,
) -> FamilyW {
    let b_learned = m.contains(bname);
    let b = if b_learned {
        m.expect(bname).clone()
    } else {
        assert_eq!(o2, o1, "missing {bname} but out dims differ");
        ops::eye(o1)
    };
    let a_name = if m.contains(a_untied) {
        Some(a_untied)
    } else if m.contains(a_tied) {
        Some(a_tied)
    } else {
        None
    };
    let a = match a_name {
        Some(n) => m.expect(n).clone(),
        None => {
            assert_eq!(i2, i1, "missing {a_tied} but in dims differ");
            ops::eye(i1)
        }
    };
    FamilyW { b, a, b_learned, a_name }
}

/// Surrogate loss `L(M) = sum_mod mean 0.5 ||Theta_mod(M) - T_mod||^2` over
/// the six weight-matrix families, the embedding anchors for B_emb's out
/// role (`emb_tok`/`emb_pos` for text, `emb_patch_w`/`emb_cls` for vision)
/// and — for CaiT — the class-attention stage's width families, with exact
/// analytic gradients w.r.t. every learned entry of M. Tied in-expansions
/// accumulate their gradient into the shared matrix.
pub fn surrogate_loss_and_grads(
    m: &Store,
    small: &Store,
    target: &Store,
    cfg_s: &ModelConfig,
    cfg_l: &ModelConfig,
) -> (f32, Store) {
    let (d1, d2) = (cfg_s.dim, cfg_l.dim);
    let (f1, f2) = (cfg_s.ffn(), cfg_l.ffn());
    let (l1, l2) = (cfg_s.layers, cfg_l.layers);
    // (suffix, blend, B name, untied A, tied A, (o2, o1), (i2, i1))
    let families = [
        ("q_w", "w_q", "B_q", "A_emb", "B_emb", (d2, d1), (d2, d1)),
        ("k_w", "w_k", "B_k", "A_emb", "B_emb", (d2, d1), (d2, d1)),
        ("v_w", "w_v", "B_v", "A_emb", "B_emb", (d2, d1), (d2, d1)),
        ("o_w", "w_o", "B_emb", "A_v", "B_v", (d2, d1), (d2, d1)),
        ("fc1_w", "w_fc1", "B_fc1", "A_emb", "B_emb", (f2, f1), (d2, d1)),
        ("fc2_w", "w_fc2", "B_emb", "A_fc1", "B_fc1", (d2, d1), (f2, f1)),
    ];
    let mut grads = Store::new();
    let mut loss = 0.0f32;
    for (suffix, blend, bname, a_untied, a_tied, (o2, o1), (i2, i1)) in families {
        let fam = resolve_family(m, bname, a_untied, a_tied, o2, o1, i2, i1);
        let (b, a, b_learned, a_name) = (fam.b, fam.a, fam.b_learned, fam.a_name);
        let w = m.get(blend);
        if w.is_none() {
            assert_eq!(l1, l2, "missing {blend} but layer counts differ");
        }
        let smalls: Vec<&Tensor> = (0..l1).map(|j| small.expect(&layer_key(j, suffix))).collect();
        let qs: Vec<Tensor> = smalls.iter().map(|wj| ops::matmul(&b, wj)).collect();
        let ps: Vec<Tensor> = qs.iter().map(|qj| ops::matmul_nt(qj, &a)).collect();
        let q_refs: Vec<&Tensor> = qs.iter().collect();
        let p_refs: Vec<&Tensor> = ps.iter().collect();
        let s = 1.0 / (l2 * ps[0].numel()) as f32;
        let mut gw = w.map(|_| Tensor::zeros(&[l2, l1]));
        for i in 0..l2 {
            let row: Vec<f32> = match w {
                Some(wt) => (0..l1).map(|j| wt.at2(i, j)).collect(),
                None => (0..l1).map(|j| if j == i { 1.0 } else { 0.0 }).collect(),
            };
            let expanded = ops::weighted_sum(&row, &p_refs);
            let e = ops::axpy(&expanded, -1.0, target.expect(&layer_key(i, suffix)));
            loss += 0.5 * s * sum_sq(&e);
            if b_learned {
                // dL/dB = E A W_hat^T
                let w_hat = ops::weighted_sum(&row, &smalls);
                let gb = ops::matmul_nt(&ops::matmul(&e, &a), &w_hat);
                add_scaled(&mut grads, bname, &gb, s);
            }
            if let Some(n) = a_name {
                // dL/dA = E^T (B W_hat)
                let bw_hat = ops::weighted_sum(&row, &q_refs);
                let ga = ops::matmul(&ops::transpose(&e), &bw_hat);
                add_scaled(&mut grads, n, &ga, s);
            }
            if let Some(g) = gw.as_mut() {
                // dL/dw[i,j] = <E_i, B W_j A^T>
                let gv = g.f32s_mut();
                for (j, pj) in ps.iter().enumerate() {
                    gv[i * l1 + j] += s * ops::dot(&e, pj);
                }
            }
        }
        if let Some(g) = gw {
            add_scaled(&mut grads, blend, &g, 1.0);
        }
    }
    // Embedding anchors ground B_emb's residual-stream out role — text
    // token/position tables and (vision parity) the patch projection and
    // CLS token, each with its exact gradient.
    if let Some(b_emb) = m.get("B_emb") {
        for name in ["emb_tok", "emb_pos"] {
            let (Some(x), Some(t)) = (small.get(name), target.get(name)) else { continue };
            if x.shape.len() != 2 {
                continue;
            }
            // rows ride the out-expansion from the right: Y = X B^T
            let y = ops::matmul_nt(x, b_emb);
            let e = ops::axpy(&y, -1.0, t);
            let s = 1.0 / e.numel() as f32;
            loss += 0.5 * s * sum_sq(&e);
            // dL/dB_emb = E^T X
            let gb = ops::matmul(&ops::transpose(&e), x);
            add_scaled(&mut grads, "B_emb", &gb, s);
        }
        if let (Some(x), Some(t)) = (small.get("emb_patch_w"), target.get("emb_patch_w")) {
            // the patch projection grows by rows: Y = B X
            let y = ops::matmul(b_emb, x);
            let e = ops::axpy(&y, -1.0, t);
            let s = 1.0 / e.numel() as f32;
            loss += 0.5 * s * sum_sq(&e);
            // dL/dB_emb = E X^T
            add_scaled(&mut grads, "B_emb", &ops::matmul_nt(&e, x), s);
        }
        if let (Some(x), Some(t)) = (small.get("emb_cls"), target.get("emb_cls")) {
            // the CLS token is a residual-stream vector: y = B x
            let y = ops::matvec(b_emb, x);
            let e = ops::axpy(&y, -1.0, t);
            let s = 1.0 / e.numel() as f32;
            loss += 0.5 * s * sum_sq(&e);
            // dL/dB_emb = e x^T
            add_scaled(&mut grads, "B_emb", &outer(&e, x), s);
        }
    }
    // CaiT class-attention stage: width-grown only (depth fixed), so each
    // C-layer weight family contributes a direct `B W A^T ~ T` term.
    if cfg_s.family == "cait" {
        for (suffix, _blend, bname, a_untied, a_tied, (o2, o1), (i2, i1)) in families {
            let fam = resolve_family(m, bname, a_untied, a_tied, o2, o1, i2, i1);
            if !fam.b_learned && fam.a_name.is_none() {
                continue;
            }
            for l in 0..cfg_s.cls_layers {
                let key = format!("C{l:02}_{suffix}");
                let (Some(x), Some(t)) = (small.get(&key), target.get(&key)) else { continue };
                let p = ops::expand(&fam.b, x, &fam.a);
                let e = ops::axpy(&p, -1.0, t);
                let s = 1.0 / e.numel() as f32;
                loss += 0.5 * s * sum_sq(&e);
                if fam.b_learned {
                    // dL/dB = E A W^T
                    let gb = ops::matmul_nt(&ops::matmul(&e, &fam.a), x);
                    add_scaled(&mut grads, bname, &gb, s);
                }
                if let Some(n) = fam.a_name {
                    // dL/dA = E^T (B W)
                    let ga = ops::matmul(&ops::transpose(&e), &ops::matmul(&fam.b, x));
                    add_scaled(&mut grads, n, &ga, s);
                }
            }
        }
    }
    (loss, grads)
}

/// The M-phase learning-rate schedule (cosine-ish decay over the short
/// phase) — one definition shared by this native loop and the artifact
/// M-training loop in `coordinator::growth_manager`, so the two paths
/// cannot silently diverge.
pub fn m_lr_at(lr: f32, step: usize, steps: usize) -> f32 {
    lr * (1.0 - 0.5 * step as f32 / steps.max(1) as f32)
}

/// Train M in place with SGD-momentum on the surrogate objective (the
/// paper's M-optimizer, §3.2 "Training"; lr follows the same cosine-ish
/// decay as the artifact path). Returns the last evaluated loss (the
/// initial loss when `steps == 0`).
pub fn learn_m(
    m: &mut Store,
    small: &Store,
    cfg_s: &ModelConfig,
    cfg_l: &ModelConfig,
    steps: usize,
    lr: f32,
    momentum: f32,
) -> f32 {
    let target = surrogate_target(small, cfg_s, cfg_l);
    let mut vel: Store = m.iter().map(|(n, t)| (n.clone(), Tensor::zeros(&t.shape))).collect();
    let mut last = f32::NAN;
    for step in 0..steps {
        let (loss, grads) = surrogate_loss_and_grads(m, small, &target, cfg_s, cfg_l);
        last = loss;
        let lr_t = m_lr_at(lr, step, steps);
        for (name, g) in grads.iter() {
            let Some(p) = m.get_mut(name) else { continue };
            let v = vel.get_mut(name).expect("velocity").f32s_mut();
            let pv = p.f32s_mut();
            for (i, gi) in g.f32s().iter().enumerate() {
                v[i] = momentum * v[i] + gi;
                pv[i] -= lr_t * v[i];
            }
        }
    }
    if steps == 0 {
        last = surrogate_loss_and_grads(m, small, &target, cfg_s, cfg_l).0;
    }
    last
}

// ---------------------------------------------------------------------------
// The operator
// ---------------------------------------------------------------------------

/// The learned LiGO operator. Its [`GrowthOperator::grow`] entry point
/// negotiates the M-learning route from the [`GrowthContext`] exactly once
/// (artifact fast path -> native task loss -> surrogate; see
/// `coordinator::growth_manager`).
///
/// The M-learning budget comes from `ctx.opts` when the context sets it,
/// else from these fields ([`Ligo::options`]) — so a hand-configured
/// `Ligo { steps: 5, .. }` is honored by `grow(ctx)` unless explicitly
/// overridden. The fields also drive the *direct surrogate* API
/// ([`Ligo::grow_with_loss`], the no-context lower level the growth
/// manager and the benches call).
#[derive(Debug, Clone)]
pub struct Ligo {
    pub steps: usize,
    pub lr: f32,
    pub momentum: f32,
    pub noise: f32,
    pub seed: u64,
}

impl Default for Ligo {
    fn default() -> Self {
        Ligo { steps: 30, lr: 0.05, momentum: 0.9, noise: 0.01, seed: 0 }
    }
}

impl Ligo {
    /// This operator's own M-learning options — the budget `grow(ctx)`
    /// falls back to when the context does not set
    /// [`LigoOptions`](super::LigoOptions) explicitly.
    pub fn options(&self) -> super::LigoOptions {
        super::LigoOptions {
            steps: self.steps,
            lr: self.lr,
            momentum: self.momentum,
            init_noise: self.noise,
            seed: self.seed,
        }
    }

    /// Grow and also report the final M-learning loss (for the growth
    /// manager's accounting).
    pub fn grow_with_loss(
        &self,
        small: &Store,
        cfg_s: &ModelConfig,
        cfg_l: &ModelConfig,
    ) -> (Store, f32) {
        let mut m = ligo_init(cfg_s, cfg_l, self.noise, self.seed);
        let loss = learn_m(&mut m, small, cfg_s, cfg_l, self.steps, self.lr, self.momentum);
        (ligo_apply(&m, small, cfg_s, cfg_l), loss)
    }
}

impl GrowthOperator for Ligo {
    fn name(&self) -> &'static str {
        "ligo"
    }

    /// LiGO can exploit everything a context offers: artifacts through a
    /// runtime handle, task-loss M-learning through a batch source, and a
    /// param-only surrogate fallback.
    fn capabilities(&self) -> &'static [Capability] {
        &[Capability::ParamOnly, Capability::NeedsBatches, Capability::NeedsRuntime]
    }

    /// The one public grow entry point: route selection (artifact vs.
    /// native task loss vs. surrogate) happens here, exactly once, from
    /// what `ctx` provides; the decision chain is recorded in
    /// [`GrowthOutcome::route`]. The M-learning budget is `ctx.opts` when
    /// set, else this operator's own fields ([`Ligo::options`]).
    fn grow(&self, ctx: GrowthContext<'_, '_>) -> crate::error::Result<GrowthOutcome> {
        crate::coordinator::growth_manager::ligo_route(self, ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::growth::testutil::{mk_cfg, small_store};

    #[test]
    fn init_patterns_and_omissions() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let m = ligo_init(&cs, &cl, 0.0, 0);
        let b = m.expect("B_emb");
        assert_eq!(b.shape, vec![12, 8]);
        for r in 0..12 {
            for c in 0..8 {
                let want = if c == r % 8 { 1.0 } else { 0.0 };
                assert_eq!(b.at2(r, c), want, "B_emb[{r},{c}]");
            }
        }
        assert_eq!(m.expect("B_fc1").shape, vec![48, 32]);
        assert_eq!(m.expect("w_q").shape, vec![4, 2]);
        assert_eq!(m.expect("w_ln2").shape, vec![4, 2]);
        assert!(!m.contains("A_emb"), "learned M is tied");
        // depth-only: width matrices omitted
        let depth_only = ligo_init(&cs, &mk_cfg(5, 8, 2), 0.0, 0);
        assert!(!depth_only.contains("B_emb"));
        assert!(depth_only.contains("w_o"));
        // width-only: depth blends omitted
        let width_only = ligo_init(&cs, &mk_cfg(2, 12, 3), 0.0, 0);
        assert!(width_only.contains("B_emb"));
        assert!(!width_only.contains("w_q"));
    }

    #[test]
    fn init_noise_is_deterministic_per_seed() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let a = ligo_init(&cs, &cl, 0.01, 7);
        let b = ligo_init(&cs, &cl, 0.01, 7);
        let c = ligo_init(&cs, &cl, 0.01, 8);
        assert_eq!(a.expect("B_emb"), b.expect("B_emb"));
        assert_ne!(a.expect("B_emb"), c.expect("B_emb"));
    }

    #[test]
    fn normalized_dup_rows_sum_counts_to_one() {
        let a = normalized_dup_matrix(12, 8);
        // each small column's copies sum to 1 (the D^-1 normalization)
        for c in 0..8 {
            let sum: f32 = (0..12).map(|r| a.at2(r, c)).sum();
            assert!((sum - 1.0).abs() < 1e-6, "col {c}: {sum}");
        }
    }

    #[test]
    fn apply_produces_exact_target_shapes_and_names() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let small = small_store(&cs);
        let m = ligo_init(&cs, &cl, 0.01, 3);
        let big = ligo_apply(&m, &small, &cs, &cl);
        let native = small_store(&cl);
        assert_eq!(big.len(), native.len(), "tensor-set parity");
        for (name, t) in native.iter() {
            assert_eq!(&big.expect(name).shape, &t.shape, "{name}");
        }
    }

    #[test]
    fn surrogate_learning_reduces_loss() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let small = small_store(&cs);
        let mut m = ligo_init(&cs, &cl, 0.02, 1);
        let l0 = learn_m(&mut m.clone(), &small, &cs, &cl, 0, 0.05, 0.9);
        let ln = learn_m(&mut m, &small, &cs, &cl, 60, 0.05, 0.9);
        assert!(l0.is_finite() && ln.is_finite(), "{l0} {ln}");
        assert!(l0 > 0.0, "noisy init cannot be at the optimum: {l0}");
        assert!(ln < l0, "M-learning must descend: {l0} -> {ln}");
    }

    #[test]
    fn depth_only_learning_moves_only_blends() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(5, 8, 2);
        let small = small_store(&cs);
        let mut m = ligo_init(&cs, &cl, 0.02, 2);
        let before = m.expect("w_q").clone();
        let loss = learn_m(&mut m, &small, &cs, &cl, 10, 0.05, 0.9);
        assert!(loss.is_finite());
        assert_ne!(m.expect("w_q"), &before, "depth blends must receive gradient");
        assert!(!m.contains("B_emb"));
    }

    /// Sampled central-difference check of `analytic` against `loss_of`
    /// over every tensor of `m`: |a - fd| <= 1e-3 * max(|a|, |fd|, 1).
    fn fd_check_m(m: &Store, analytic: &Store, mut loss_of: impl FnMut(&Store) -> f32, seed: u64) {
        let eps = 1e-2f32;
        let mut rng = crate::util::rng::Rng::new(seed);
        for (name, g) in analytic.iter() {
            assert_eq!(g.shape, m.expect(name).shape, "{name}: gradient shape");
            for _ in 0..2 {
                let i = rng.below(g.numel());
                let mut plus = m.clone();
                plus.get_mut(name).unwrap().f32s_mut()[i] += eps;
                let mut minus = m.clone();
                minus.get_mut(name).unwrap().f32s_mut()[i] -= eps;
                let fd = (loss_of(&plus) - loss_of(&minus)) / (2.0 * eps);
                let a = g.f32s()[i];
                let rel = (a - fd).abs() / a.abs().max(fd.abs()).max(1.0);
                assert!(rel < 1e-3, "{name}[{i}]: analytic {a} vs fd {fd} (rel {rel})");
            }
        }
    }

    fn text_batch_for(cfg: &ModelConfig, seed: u64) -> Store {
        let mut rng = crate::util::rng::Rng::new(seed);
        let (b, s) = (cfg.batch, cfg.seq);
        let tokens: Vec<i32> = (0..b * s).map(|_| rng.below(cfg.vocab) as i32).collect();
        let labels: Vec<i32> = tokens
            .iter()
            .map(|&t| if rng.coin(0.3) { t } else { -1 })
            .collect();
        let mut st = Store::new();
        st.insert("tokens", crate::tensor::Tensor::from_i32(&[b, s], tokens));
        st.insert("labels", crate::tensor::Tensor::from_i32(&[b, s], labels));
        st
    }

    #[test]
    fn task_loss_dm_matches_finite_differences_text() {
        // dL/dM through the full chain: depth blends + fused B W A^T +
        // tying + the native bert forward/backward.
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(3, 12, 3);
        let small = small_store(&cs);
        let m = ligo_init(&cs, &cl, 0.02, 3);
        let batch = text_batch_for(&cl, 9);
        let theta = ligo_apply(&m, &small, &cs, &cl);
        let (_l, gtheta, _) = crate::model::loss_and_grads(&cl, &theta, &batch).unwrap();
        let dm = ligo_apply_backward(&m, &small, &gtheta, &cs, &cl);
        // every learned entry of M receives a gradient slot
        for (name, _t) in m.iter() {
            assert!(dm.contains(name), "missing dL/dM for '{name}'");
        }
        fd_check_m(&m, &dm, |mm| {
            let th = ligo_apply(mm, &small, &cs, &cl);
            crate::model::loss_only(&cl, &th, &batch).unwrap().0
        }, 31);
    }

    #[test]
    fn task_loss_dm_matches_finite_differences_cait() {
        use crate::growth::testutil::{full_store, mk_vision_cfg};
        let cs = mk_vision_cfg("cait", 2, 8, 2);
        let cl = mk_vision_cfg("cait", 3, 12, 3);
        let small = full_store(&cs);
        let m = ligo_init(&cs, &cl, 0.02, 4);
        let mut rng = crate::util::rng::Rng::new(12);
        let n = cl.batch * cl.img * cl.img * cl.channels;
        let images: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let labels: Vec<i32> = (0..cl.batch).map(|_| rng.below(cl.n_classes) as i32).collect();
        let mut batch = Store::new();
        batch.insert(
            "images",
            crate::tensor::Tensor::from_f32(&[cl.batch, cl.img, cl.img, cl.channels], images),
        );
        batch.insert("labels", crate::tensor::Tensor::from_i32(&[cl.batch], labels));
        let theta = ligo_apply(&m, &small, &cs, &cl);
        let (_l, gtheta, _) = crate::model::loss_and_grads(&cl, &theta, &batch).unwrap();
        let dm = ligo_apply_backward(&m, &small, &gtheta, &cs, &cl);
        assert!(dm.contains("w_ls1"), "CaiT layerscale blends get gradient");
        fd_check_m(&m, &dm, |mm| {
            let th = ligo_apply(mm, &small, &cs, &cl);
            crate::model::loss_only(&cl, &th, &batch).unwrap().0
        }, 32);
    }

    #[test]
    fn task_loss_dm_depth_only_moves_only_blends() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 8, 2);
        let small = small_store(&cs);
        let m = ligo_init(&cs, &cl, 0.02, 5);
        let batch = text_batch_for(&cl, 10);
        let theta = ligo_apply(&m, &small, &cs, &cl);
        let (_l, gtheta, _) = crate::model::loss_and_grads(&cl, &theta, &batch).unwrap();
        let dm = ligo_apply_backward(&m, &small, &gtheta, &cs, &cl);
        for (name, _) in dm.iter() {
            assert!(name.starts_with("w_"), "depth-only M must only get blend grads: {name}");
        }
        fd_check_m(&m, &dm, |mm| {
            let th = ligo_apply(mm, &small, &cs, &cl);
            crate::model::loss_only(&cl, &th, &batch).unwrap().0
        }, 33);
    }

    #[test]
    fn surrogate_vision_anchors_are_exact_and_learnable() {
        use crate::growth::testutil::{full_store, mk_vision_cfg};
        let cs = mk_vision_cfg("cait", 2, 8, 2);
        let cl = mk_vision_cfg("cait", 3, 12, 3);
        let small = full_store(&cs);
        let m = ligo_init(&cs, &cl, 0.02, 6);
        let target = surrogate_target(&small, &cs, &cl);
        let (loss, grads) = surrogate_loss_and_grads(&m, &small, &target, &cs, &cl);
        assert!(loss.is_finite() && loss > 0.0);
        // the new anchors feed B_emb beyond the body families: FD-verify
        // every surrogate gradient (incl. patch/cls anchors + C-layer terms)
        fd_check_m(&m, &grads, |mm| {
            surrogate_loss_and_grads(mm, &small, &target, &cs, &cl).0
        }, 34);
        // and the surrogate still descends on the vision pair
        let mut m2 = ligo_init(&cs, &cl, 0.02, 6);
        let l0 = learn_m(&mut m2.clone(), &small, &cs, &cl, 0, 0.05, 0.9);
        let ln = learn_m(&mut m2, &small, &cs, &cl, 40, 0.05, 0.9);
        assert!(ln < l0, "vision surrogate must descend: {l0} -> {ln}");
    }

    #[test]
    fn operator_end_to_end_is_finite_and_deterministic() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let small = small_store(&cs);
        let op = Ligo { steps: 8, ..Default::default() };
        let (a, loss_a) = op.grow_with_loss(&small, &cs, &cl);
        let (b, _) = op.grow_with_loss(&small, &cs, &cl);
        assert_eq!(a, b, "native LiGO is deterministic");
        assert!(loss_a.is_finite());
        for (name, t) in a.iter() {
            assert!(t.f32s().iter().all(|x| x.is_finite()), "{name} has non-finite values");
        }
    }
}
