//! Native LiGO — the paper's learned Linear Growth Operator (§3.2-3.3,
//! Algorithm 1) ported from `python/compile/ligo.py` onto the named tensor
//! store, so `growth::by_name("ligo")` works end to end with no AOT
//! artifacts and no XLA.
//!
//! The growth map  vec(Theta_new) = (w (x) I) . blockdiag(A_l (x) B_l)
//! vec(Theta)  is applied exactly as Algorithm 1: a width pass that grows
//! every small-model tensor via the fused triple product `B W A^T`
//! ([`crate::tensor::ops::expand`]), followed by a depth pass that forms
//! each large layer as a learned linear blend of the width-grown small
//! layers ([`crate::tensor::ops::weighted_sum`]).
//!
//! Weight tying (Appendix B.1), which makes M learnable from ~100 steps:
//!   * `A^k = B_emb^T` for k in {Q, K, V, fc1}  (residual-stream inputs)
//!   * `A^O = B_V^T`,  `A^fc2 = B_fc1^T`        (inner-dim alignment)
//!   * `B^O = B^fc2 = B_emb`                    (residual-stream outputs)
//!   * biases / LayerNorms grow with their module's out-expansion matrix
//!   * output head: `A^out = B_emb^T`, no out-expansion
//!
//! Learned LiGO parameters (a flat [`Store`], same names as the AOT
//! manifests' "ligo" group): `B_emb, B_q, B_k, B_v` (D2, D1), `B_fc1`
//! (F2, F1), and per-module depth blends `w_q .. w_ln2` (L2, L1). The
//! *untied* general form of the operator additionally admits `A_emb, A_v,
//! A_fc1` in-expansion matrices; Prop. 1's exact-equivalence instances
//! (Net2Net's multiplicity-normalized selection) live in that form, while
//! the learned path keeps the tied parameterization above.
//!
//! M-learning: the artifact path (feature `pjrt`) trains M against the
//! expanded model's task loss via `ligo_grad_*`. This native path trains M
//! with SGD-momentum on a *surrogate* objective — a least-squares fit of
//! the expanded weight matrices (and embeddings) to an ensemble of the
//! strongest non-learned baselines (StackBERT + Interpolation), with exact
//! analytic gradients through the `B W A^T` factorization and the depth
//! blends. Learning M against the native task loss needs a native forward
//! pass (ROADMAP open item).

use crate::config::ModelConfig;
use crate::tensor::ops;
use crate::tensor::store::Store;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::stacking::{Interpolation, StackBert};
use super::{layer_key, layer_suffixes, GrowthOperator};

/// Per-module depth-blend families, in python `ligo.DEPTH_MODULES` order.
pub const DEPTH_MODULES: [&str; 8] = ["q", "k", "v", "o", "ln1", "fc1", "fc2", "ln2"];
/// Extra CaiT per-layer scales that also get depth blends.
pub const CAIT_DEPTH_MODULES: [&str; 2] = ["ls1", "ls2"];

/// Per-layer suffixes of the CaiT class-attention stage (width-grown only;
/// its depth is fixed, mirroring python `ligo_apply`).
const CLS_SUFFIXES: [&str; 16] = [
    "q_w", "q_b", "k_w", "k_b", "v_w", "v_b", "o_w", "o_b", "ln1_g", "ln1_b",
    "fc1_w", "fc1_b", "fc2_w", "fc2_b", "ln2_g", "ln2_b",
];

fn depth_modules(cfg: &ModelConfig) -> Vec<&'static str> {
    let mut v = DEPTH_MODULES.to_vec();
    if cfg.family == "cait" {
        v.extend(CAIT_DEPTH_MODULES);
    }
    v
}

/// Depth-blend module of a per-layer suffix: "q_w" -> "q", "ln1_g" -> "ln1",
/// "ls1" -> "ls1".
fn module_of(suffix: &str) -> &str {
    suffix.rsplit_once('_').map(|(m, _)| m).unwrap_or(suffix)
}

// ---------------------------------------------------------------------------
// Initialization of M (stacking + neuron-duplication pattern, Prop. 1)
// ---------------------------------------------------------------------------

/// (rows, cols) selection matrix whose row i selects small index (i mod
/// cols): the Net2Net neuron-duplication / StackBERT stacking pattern.
pub fn dup_matrix(rows: usize, cols: usize) -> Tensor {
    let mut t = Tensor::zeros(&[rows, cols]);
    let v = t.f32s_mut();
    for r in 0..rows {
        v[r * cols + (r % cols)] = 1.0;
    }
    t
}

/// The duplication pattern with each column scaled by 1/multiplicity —
/// the in-expansion (`A`) side of Net2Net's function-preserving growth
/// (paper Eq. 2's D^-1).
pub fn normalized_dup_matrix(rows: usize, cols: usize) -> Tensor {
    let mut counts = vec![0usize; cols];
    for r in 0..rows {
        counts[r % cols] += 1;
    }
    let mut t = Tensor::zeros(&[rows, cols]);
    let v = t.f32s_mut();
    for r in 0..rows {
        let c = r % cols;
        v[r * cols + c] = 1.0 / counts[c] as f32;
    }
    t
}

fn noisy_dup(rows: usize, cols: usize, noise: f32, rng: &mut Rng) -> Tensor {
    let mut t = dup_matrix(rows, cols);
    if noise != 0.0 {
        for v in t.f32s_mut() {
            *v += noise * rng.normal();
        }
    }
    t
}

/// Initialize the LiGO parameter store M from the config pair: width
/// matrices get the cyclic duplication pattern, depth matrices the stacking
/// pattern (both + symmetry-breaking noise) — mirrors python `ligo_init`.
/// Width params are omitted when dims match (depth-only growth, Fig. 6);
/// depth params are omitted when layer counts match (width-only growth).
pub fn ligo_init(cfg_s: &ModelConfig, cfg_l: &ModelConfig, noise: f32, seed: u64) -> Store {
    let mut rng = Rng::new(seed ^ 0x11C0);
    let mut m = Store::new();
    let (d1, d2) = (cfg_s.dim, cfg_l.dim);
    let (f1, f2) = (cfg_s.ffn(), cfg_l.ffn());
    if d1 != d2 || f1 != f2 {
        m.insert("B_emb", noisy_dup(d2, d1, noise, &mut rng));
        m.insert("B_q", noisy_dup(d2, d1, noise, &mut rng));
        m.insert("B_k", noisy_dup(d2, d1, noise, &mut rng));
        m.insert("B_v", noisy_dup(d2, d1, noise, &mut rng));
        m.insert("B_fc1", noisy_dup(f2, f1, noise, &mut rng));
    }
    if cfg_s.layers != cfg_l.layers {
        for module in depth_modules(cfg_s) {
            m.insert(
                format!("w_{module}"),
                noisy_dup(cfg_l.layers, cfg_s.layers, noise, &mut rng),
            );
        }
    }
    m
}

/// Depth-blend initialization patterns for the Prop. 1 special cases.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DepthInit {
    /// StackBERT: layer l blends from layer (l mod L1).
    Stack,
    /// Interpolation / InterBERT: layer l blends from floor(l / ceil(L2/L1)).
    Interpolate,
    /// MSLT: new layers duplicate the top small layer.
    TopDup,
    /// Net2Net-style near-identity depth: existing layers keep themselves,
    /// new layers copy the top layer but zero the residual-writing modules
    /// (o, fc2) so the new blocks start as no-ops.
    NearIdentity,
}

fn depth_pattern(init: DepthInit, module: &str, l2: usize, l1: usize) -> Tensor {
    let mut w = Tensor::zeros(&[l2, l1]);
    let k = l2.div_ceil(l1);
    let v = w.f32s_mut();
    for i in 0..l2 {
        let src = match init {
            DepthInit::Stack => i % l1,
            DepthInit::Interpolate => (i / k.max(1)).min(l1 - 1),
            DepthInit::TopDup => i.min(l1 - 1),
            DepthInit::NearIdentity => {
                if i >= l1 && (module == "o" || module == "fc2") {
                    continue; // zero row: the new block's residual branch is a no-op
                }
                i.min(l1 - 1)
            }
        };
        v[i * l1 + src] = 1.0;
    }
    w
}

/// Noise-free selection-pattern M (Prop. 1): plain duplication on the
/// out-expansions, optionally multiplicity-normalized duplication on the
/// untied in-expansions (`A_emb`/`A_v`/`A_fc1`, matching Net2Net's D^-1),
/// and the chosen depth pattern. With `normalize_inputs` these instances
/// reproduce the non-learned zoo operators exactly (see tests/prop_ligo.rs).
pub fn selection_m(
    cfg_s: &ModelConfig,
    cfg_l: &ModelConfig,
    depth: DepthInit,
    normalize_inputs: bool,
) -> Store {
    let mut m = Store::new();
    let (d1, d2) = (cfg_s.dim, cfg_l.dim);
    let (f1, f2) = (cfg_s.ffn(), cfg_l.ffn());
    if d1 != d2 || f1 != f2 {
        m.insert("B_emb", dup_matrix(d2, d1));
        m.insert("B_q", dup_matrix(d2, d1));
        m.insert("B_k", dup_matrix(d2, d1));
        m.insert("B_v", dup_matrix(d2, d1));
        m.insert("B_fc1", dup_matrix(f2, f1));
        if normalize_inputs {
            m.insert("A_emb", normalized_dup_matrix(d2, d1));
            m.insert("A_v", normalized_dup_matrix(d2, d1));
            m.insert("A_fc1", normalized_dup_matrix(f2, f1));
        }
    }
    if cfg_s.layers != cfg_l.layers {
        for module in depth_modules(cfg_s) {
            m.insert(
                format!("w_{module}"),
                depth_pattern(depth, module, cfg_l.layers, cfg_s.layers),
            );
        }
    }
    m
}

// ---------------------------------------------------------------------------
// Applying M: width pass (fused B W A^T) + depth pass (learned blends)
// ---------------------------------------------------------------------------

/// Resolved width-expansion matrices (identity fallback for depth-only M,
/// tied fallback `A_x = B_x` when no untied in-expansion is present).
struct WidthCtx {
    b_emb: Tensor,
    b_q: Tensor,
    b_k: Tensor,
    b_v: Tensor,
    b_fc1: Tensor,
    a_emb: Tensor,
    a_v: Tensor,
    a_fc1: Tensor,
}

fn get_b(m: &Store, name: &str, rows: usize, cols: usize) -> Tensor {
    match m.get(name) {
        Some(t) => {
            assert_eq!(t.shape, vec![rows, cols], "LiGO width matrix {name}");
            t.clone()
        }
        None => {
            assert_eq!(rows, cols, "missing LiGO matrix {name} but dims differ: {rows} vs {cols}");
            ops::eye(rows)
        }
    }
}

fn get_a(m: &Store, untied: &str, tied: &Tensor, rows: usize, cols: usize) -> Tensor {
    match m.get(untied) {
        Some(t) => {
            assert_eq!(t.shape, vec![rows, cols], "LiGO width matrix {untied}");
            t.clone()
        }
        None => tied.clone(),
    }
}

fn width_ctx(m: &Store, cfg_s: &ModelConfig, cfg_l: &ModelConfig) -> WidthCtx {
    let (d1, d2) = (cfg_s.dim, cfg_l.dim);
    let (f1, f2) = (cfg_s.ffn(), cfg_l.ffn());
    let b_emb = get_b(m, "B_emb", d2, d1);
    let b_q = get_b(m, "B_q", d2, d1);
    let b_k = get_b(m, "B_k", d2, d1);
    let b_v = get_b(m, "B_v", d2, d1);
    let b_fc1 = get_b(m, "B_fc1", f2, f1);
    let a_emb = get_a(m, "A_emb", &b_emb, d2, d1);
    let a_v = get_a(m, "A_v", &b_v, d2, d1);
    let a_fc1 = get_a(m, "A_fc1", &b_fc1, f2, f1);
    WidthCtx { b_emb, b_q, b_k, b_v, b_fc1, a_emb, a_v, a_fc1 }
}

/// Width-grow one per-layer tensor: fused `B W A^T` for matrices (A tied
/// per Appendix B.1), the module's out-expansion for biases/LayerNorms.
fn expand_one(ctx: &WidthCtx, suffix: &str, t: &Tensor) -> Tensor {
    match suffix {
        "q_w" => ops::expand(&ctx.b_q, t, &ctx.a_emb),
        "k_w" => ops::expand(&ctx.b_k, t, &ctx.a_emb),
        "v_w" => ops::expand(&ctx.b_v, t, &ctx.a_emb),
        "o_w" => ops::expand(&ctx.b_emb, t, &ctx.a_v),
        "fc1_w" => ops::expand(&ctx.b_fc1, t, &ctx.a_emb),
        "fc2_w" => ops::expand(&ctx.b_emb, t, &ctx.a_fc1),
        "q_b" => ops::matvec(&ctx.b_q, t),
        "k_b" => ops::matvec(&ctx.b_k, t),
        "v_b" => ops::matvec(&ctx.b_v, t),
        "fc1_b" => ops::matvec(&ctx.b_fc1, t),
        "o_b" | "fc2_b" | "ln1_g" | "ln1_b" | "ln2_g" | "ln2_b" | "ls1" | "ls2" => {
            ops::matvec(&ctx.b_emb, t)
        }
        other => panic!("ligo_apply: unknown per-layer suffix '{other}'"),
    }
}

/// Width-grow a non-layer tensor by its role (mirrors python `ligo_apply`'s
/// tail; the head reads the residual stream, so it rides the in-expansion).
fn expand_nonlayer(ctx: &WidthCtx, name: &str, t: &Tensor) -> Tensor {
    match name {
        "emb_tok" | "emb_pos" => ops::matmul_nt(t, &ctx.b_emb),
        "mlm_bias" | "head_b" | "span_b" => t.clone(),
        "head_w" | "span_w" => ops::matmul_nt(t, &ctx.a_emb),
        "final_ln_g" | "final_ln_b" | "emb_cls" | "emb_patch_b" => ops::matvec(&ctx.b_emb, t),
        "emb_patch_w" => ops::matmul(&ctx.b_emb, t),
        other => panic!("ligo_apply: unknown non-layer tensor '{other}'"),
    }
}

/// Materialize the large model's parameters: Theta_new = M(Theta).
///
/// Width pass first (every small tensor through its expansion), then the
/// per-module depth blends. Missing width matrices fall back to identity
/// (depth-only M); missing depth blends require equal layer counts
/// (width-only M).
pub fn ligo_apply(m: &Store, small: &Store, cfg_s: &ModelConfig, cfg_l: &ModelConfig) -> Store {
    let ctx = width_ctx(m, cfg_s, cfg_l);
    let mut out = Store::new();
    // ---- body layers: width pass, then depth blends ----
    for suffix in layer_suffixes(cfg_s) {
        let wide: Vec<Tensor> = (0..cfg_s.layers)
            .map(|l| expand_one(&ctx, suffix, small.expect(&layer_key(l, suffix))))
            .collect();
        match m.get(&format!("w_{}", module_of(suffix))) {
            Some(w) => {
                assert_eq!(
                    w.shape,
                    vec![cfg_l.layers, cfg_s.layers],
                    "LiGO depth blend w_{}",
                    module_of(suffix)
                );
                let refs: Vec<&Tensor> = wide.iter().collect();
                for i in 0..cfg_l.layers {
                    let row: Vec<f32> = (0..cfg_s.layers).map(|j| w.at2(i, j)).collect();
                    out.insert(layer_key(i, suffix), ops::weighted_sum(&row, &refs));
                }
            }
            None => {
                assert_eq!(
                    cfg_s.layers, cfg_l.layers,
                    "missing depth blend w_{} but layer counts differ",
                    module_of(suffix)
                );
                for (i, t) in wide.into_iter().enumerate() {
                    out.insert(layer_key(i, suffix), t);
                }
            }
        }
    }
    // ---- non-layer tensors ----
    for (name, t) in small.iter() {
        if name.starts_with('L') || name.starts_with('C') {
            continue;
        }
        out.insert(name.clone(), expand_nonlayer(&ctx, name, t));
    }
    // ---- CaiT class-attention stage: widths grow, depth is fixed ----
    if cfg_s.family == "cait" {
        assert_eq!(cfg_s.cls_layers, cfg_l.cls_layers, "CaiT class-attention depth is fixed");
        for l in 0..cfg_s.cls_layers {
            for suffix in CLS_SUFFIXES {
                let key = format!("C{l:02}_{suffix}");
                out.insert(key.clone(), expand_one(&ctx, suffix, small.expect(&key)));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Native M-learning: SGD-momentum on the surrogate least-squares objective
// ---------------------------------------------------------------------------

/// The surrogate fit target: the average of the two strongest non-learned
/// depth-growth baselines (StackBERT and Interpolation). Fitting M to the
/// ensemble couples every layer through the shared width matrices, which is
/// exactly the structure the paper's M-learning exploits.
pub fn surrogate_target(small: &Store, cfg_s: &ModelConfig, cfg_l: &ModelConfig) -> Store {
    let stack = StackBert.grow(small, cfg_s, cfg_l);
    let interp = Interpolation.grow(small, cfg_s, cfg_l);
    stack
        .iter()
        .map(|(name, t)| {
            (name.clone(), ops::weighted_sum(&[0.5, 0.5], &[t, interp.expect(name)]))
        })
        .collect()
}

fn sum_sq(t: &Tensor) -> f32 {
    t.f32s().iter().map(|x| x * x).sum()
}

fn add_scaled(grads: &mut Store, name: &str, t: &Tensor, s: f32) {
    if let Some(g) = grads.get_mut(name) {
        for (gv, tv) in g.f32s_mut().iter_mut().zip(t.f32s()) {
            *gv += s * tv;
        }
        return;
    }
    grads.insert(name.to_string(), ops::scale(t, s));
}

/// Surrogate loss `L(M) = sum_mod mean 0.5 ||Theta_mod(M) - T_mod||^2` over
/// the six weight-matrix families (+ embedding anchors for B_emb's out
/// role), with exact analytic gradients w.r.t. every learned entry of M.
/// Tied in-expansions accumulate their gradient into the shared matrix.
pub fn surrogate_loss_and_grads(
    m: &Store,
    small: &Store,
    target: &Store,
    cfg_s: &ModelConfig,
    cfg_l: &ModelConfig,
) -> (f32, Store) {
    let (d1, d2) = (cfg_s.dim, cfg_l.dim);
    let (f1, f2) = (cfg_s.ffn(), cfg_l.ffn());
    let (l1, l2) = (cfg_s.layers, cfg_l.layers);
    // (suffix, blend, B name, untied A, tied A, (o2, o1), (i2, i1))
    let families = [
        ("q_w", "w_q", "B_q", "A_emb", "B_emb", (d2, d1), (d2, d1)),
        ("k_w", "w_k", "B_k", "A_emb", "B_emb", (d2, d1), (d2, d1)),
        ("v_w", "w_v", "B_v", "A_emb", "B_emb", (d2, d1), (d2, d1)),
        ("o_w", "w_o", "B_emb", "A_v", "B_v", (d2, d1), (d2, d1)),
        ("fc1_w", "w_fc1", "B_fc1", "A_emb", "B_emb", (f2, f1), (d2, d1)),
        ("fc2_w", "w_fc2", "B_emb", "A_fc1", "B_fc1", (d2, d1), (f2, f1)),
    ];
    let mut grads = Store::new();
    let mut loss = 0.0f32;
    for (suffix, blend, bname, a_untied, a_tied, (o2, o1), (i2, i1)) in families {
        let b_learned = m.contains(bname);
        let b = if b_learned {
            m.expect(bname).clone()
        } else {
            assert_eq!(o2, o1, "missing {bname} but out dims differ");
            ops::eye(o1)
        };
        let a_name = if m.contains(a_untied) {
            Some(a_untied)
        } else if m.contains(a_tied) {
            Some(a_tied)
        } else {
            None
        };
        let a = match a_name {
            Some(n) => m.expect(n).clone(),
            None => {
                assert_eq!(i2, i1, "missing {a_tied} but in dims differ");
                ops::eye(i1)
            }
        };
        let w = m.get(blend);
        if w.is_none() {
            assert_eq!(l1, l2, "missing {blend} but layer counts differ");
        }
        let smalls: Vec<&Tensor> = (0..l1).map(|j| small.expect(&layer_key(j, suffix))).collect();
        let qs: Vec<Tensor> = smalls.iter().map(|wj| ops::matmul(&b, wj)).collect();
        let ps: Vec<Tensor> = qs.iter().map(|qj| ops::matmul_nt(qj, &a)).collect();
        let q_refs: Vec<&Tensor> = qs.iter().collect();
        let p_refs: Vec<&Tensor> = ps.iter().collect();
        let s = 1.0 / (l2 * ps[0].numel()) as f32;
        let mut gw = w.map(|_| Tensor::zeros(&[l2, l1]));
        for i in 0..l2 {
            let row: Vec<f32> = match w {
                Some(wt) => (0..l1).map(|j| wt.at2(i, j)).collect(),
                None => (0..l1).map(|j| if j == i { 1.0 } else { 0.0 }).collect(),
            };
            let expanded = ops::weighted_sum(&row, &p_refs);
            let e = ops::axpy(&expanded, -1.0, target.expect(&layer_key(i, suffix)));
            loss += 0.5 * s * sum_sq(&e);
            if b_learned {
                // dL/dB = E A W_hat^T
                let w_hat = ops::weighted_sum(&row, &smalls);
                let gb = ops::matmul_nt(&ops::matmul(&e, &a), &w_hat);
                add_scaled(&mut grads, bname, &gb, s);
            }
            if let Some(n) = a_name {
                // dL/dA = E^T (B W_hat)
                let bw_hat = ops::weighted_sum(&row, &q_refs);
                let ga = ops::matmul(&ops::transpose(&e), &bw_hat);
                add_scaled(&mut grads, n, &ga, s);
            }
            if let Some(g) = gw.as_mut() {
                // dL/dw[i,j] = <E_i, B W_j A^T>
                let gv = g.f32s_mut();
                for (j, pj) in ps.iter().enumerate() {
                    gv[i * l1 + j] += s * ops::dot(&e, pj);
                }
            }
        }
        if let Some(g) = gw {
            add_scaled(&mut grads, blend, &g, 1.0);
        }
    }
    // Embedding anchors ground B_emb's residual-stream out role.
    if let Some(b_emb) = m.get("B_emb") {
        for name in ["emb_tok", "emb_pos"] {
            let (Some(x), Some(t)) = (small.get(name), target.get(name)) else { continue };
            if x.shape.len() != 2 {
                continue;
            }
            let y = ops::matmul_nt(x, b_emb);
            let e = ops::axpy(&y, -1.0, t);
            let s = 1.0 / e.numel() as f32;
            loss += 0.5 * s * sum_sq(&e);
            // dL/dB_emb = E^T X
            let gb = ops::matmul(&ops::transpose(&e), x);
            add_scaled(&mut grads, "B_emb", &gb, s);
        }
    }
    (loss, grads)
}

/// The M-phase learning-rate schedule (cosine-ish decay over the short
/// phase) — one definition shared by this native loop and the artifact
/// M-training loop in `coordinator::growth_manager`, so the two paths
/// cannot silently diverge.
pub fn m_lr_at(lr: f32, step: usize, steps: usize) -> f32 {
    lr * (1.0 - 0.5 * step as f32 / steps.max(1) as f32)
}

/// Train M in place with SGD-momentum on the surrogate objective (the
/// paper's M-optimizer, §3.2 "Training"; lr follows the same cosine-ish
/// decay as the artifact path). Returns the last evaluated loss (the
/// initial loss when `steps == 0`).
pub fn learn_m(
    m: &mut Store,
    small: &Store,
    cfg_s: &ModelConfig,
    cfg_l: &ModelConfig,
    steps: usize,
    lr: f32,
    momentum: f32,
) -> f32 {
    let target = surrogate_target(small, cfg_s, cfg_l);
    let mut vel: Store = m.iter().map(|(n, t)| (n.clone(), Tensor::zeros(&t.shape))).collect();
    let mut last = f32::NAN;
    for step in 0..steps {
        let (loss, grads) = surrogate_loss_and_grads(m, small, &target, cfg_s, cfg_l);
        last = loss;
        let lr_t = m_lr_at(lr, step, steps);
        for (name, g) in grads.iter() {
            let Some(p) = m.get_mut(name) else { continue };
            let v = vel.get_mut(name).expect("velocity").f32s_mut();
            let pv = p.f32s_mut();
            for (i, gi) in g.f32s().iter().enumerate() {
                v[i] = momentum * v[i] + gi;
                pv[i] -= lr_t * v[i];
            }
        }
    }
    if steps == 0 {
        last = surrogate_loss_and_grads(m, small, &target, cfg_s, cfg_l).0;
    }
    last
}

// ---------------------------------------------------------------------------
// The operator
// ---------------------------------------------------------------------------

/// The learned LiGO operator, natively: init M (Prop. 1 pattern + noise),
/// run the M-learning steps on the surrogate objective, apply.
#[derive(Debug, Clone)]
pub struct Ligo {
    pub steps: usize,
    pub lr: f32,
    pub momentum: f32,
    pub noise: f32,
    pub seed: u64,
}

impl Default for Ligo {
    fn default() -> Self {
        Ligo { steps: 30, lr: 0.05, momentum: 0.9, noise: 0.01, seed: 0 }
    }
}

impl Ligo {
    /// Grow and also report the final M-learning loss (for the growth
    /// manager's accounting).
    pub fn grow_with_loss(
        &self,
        small: &Store,
        cfg_s: &ModelConfig,
        cfg_l: &ModelConfig,
    ) -> (Store, f32) {
        let mut m = ligo_init(cfg_s, cfg_l, self.noise, self.seed);
        let loss = learn_m(&mut m, small, cfg_s, cfg_l, self.steps, self.lr, self.momentum);
        (ligo_apply(&m, small, cfg_s, cfg_l), loss)
    }
}

impl GrowthOperator for Ligo {
    fn name(&self) -> &'static str {
        "ligo"
    }

    fn grow(&self, small: &Store, cfg_s: &ModelConfig, cfg_l: &ModelConfig) -> Store {
        self.grow_with_loss(small, cfg_s, cfg_l).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::growth::testutil::{mk_cfg, small_store};

    #[test]
    fn init_patterns_and_omissions() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let m = ligo_init(&cs, &cl, 0.0, 0);
        let b = m.expect("B_emb");
        assert_eq!(b.shape, vec![12, 8]);
        for r in 0..12 {
            for c in 0..8 {
                let want = if c == r % 8 { 1.0 } else { 0.0 };
                assert_eq!(b.at2(r, c), want, "B_emb[{r},{c}]");
            }
        }
        assert_eq!(m.expect("B_fc1").shape, vec![48, 32]);
        assert_eq!(m.expect("w_q").shape, vec![4, 2]);
        assert_eq!(m.expect("w_ln2").shape, vec![4, 2]);
        assert!(!m.contains("A_emb"), "learned M is tied");
        // depth-only: width matrices omitted
        let depth_only = ligo_init(&cs, &mk_cfg(5, 8, 2), 0.0, 0);
        assert!(!depth_only.contains("B_emb"));
        assert!(depth_only.contains("w_o"));
        // width-only: depth blends omitted
        let width_only = ligo_init(&cs, &mk_cfg(2, 12, 3), 0.0, 0);
        assert!(width_only.contains("B_emb"));
        assert!(!width_only.contains("w_q"));
    }

    #[test]
    fn init_noise_is_deterministic_per_seed() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let a = ligo_init(&cs, &cl, 0.01, 7);
        let b = ligo_init(&cs, &cl, 0.01, 7);
        let c = ligo_init(&cs, &cl, 0.01, 8);
        assert_eq!(a.expect("B_emb"), b.expect("B_emb"));
        assert_ne!(a.expect("B_emb"), c.expect("B_emb"));
    }

    #[test]
    fn normalized_dup_rows_sum_counts_to_one() {
        let a = normalized_dup_matrix(12, 8);
        // each small column's copies sum to 1 (the D^-1 normalization)
        for c in 0..8 {
            let sum: f32 = (0..12).map(|r| a.at2(r, c)).sum();
            assert!((sum - 1.0).abs() < 1e-6, "col {c}: {sum}");
        }
    }

    #[test]
    fn apply_produces_exact_target_shapes_and_names() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let small = small_store(&cs);
        let m = ligo_init(&cs, &cl, 0.01, 3);
        let big = ligo_apply(&m, &small, &cs, &cl);
        let native = small_store(&cl);
        assert_eq!(big.len(), native.len(), "tensor-set parity");
        for (name, t) in native.iter() {
            assert_eq!(&big.expect(name).shape, &t.shape, "{name}");
        }
    }

    #[test]
    fn surrogate_learning_reduces_loss() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let small = small_store(&cs);
        let mut m = ligo_init(&cs, &cl, 0.02, 1);
        let l0 = learn_m(&mut m.clone(), &small, &cs, &cl, 0, 0.05, 0.9);
        let ln = learn_m(&mut m, &small, &cs, &cl, 60, 0.05, 0.9);
        assert!(l0.is_finite() && ln.is_finite(), "{l0} {ln}");
        assert!(l0 > 0.0, "noisy init cannot be at the optimum: {l0}");
        assert!(ln < l0, "M-learning must descend: {l0} -> {ln}");
    }

    #[test]
    fn depth_only_learning_moves_only_blends() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(5, 8, 2);
        let small = small_store(&cs);
        let mut m = ligo_init(&cs, &cl, 0.02, 2);
        let before = m.expect("w_q").clone();
        let loss = learn_m(&mut m, &small, &cs, &cl, 10, 0.05, 0.9);
        assert!(loss.is_finite());
        assert_ne!(m.expect("w_q"), &before, "depth blends must receive gradient");
        assert!(!m.contains("B_emb"));
    }

    #[test]
    fn operator_end_to_end_is_finite_and_deterministic() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let small = small_store(&cs);
        let op = Ligo { steps: 8, ..Default::default() };
        let (a, loss_a) = op.grow_with_loss(&small, &cs, &cl);
        let (b, _) = op.grow_with_loss(&small, &cs, &cl);
        assert_eq!(a, b, "native LiGO is deterministic");
        assert!(loss_a.is_finite());
        for (name, t) in a.iter() {
            assert!(t.f32s().iter().all(|x| x.is_finite()), "{name} has non-finite values");
        }
    }
}
