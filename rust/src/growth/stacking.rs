//! Depth-growth family (paper Eq. 1):
//!
//! * **StackBERT** (Gong et al. 2019): W_l_new = W_{l mod L1} — duplicate the
//!   whole small model on top of itself.
//! * **Interpolation** (InterBERT; Chang et al. 2017, Dong et al. 2020):
//!   W_l_new = W_{floor(l/k)} — interleave each layer k times (the neural-ODE
//!   "finer time-step" view).
//! * **MSLT** (Yang et al. 2020): top-layer duplication; the multi-stage
//!   freeze schedule lives in the trainer (`coordinator::strategies`), this
//!   operator provides its initialization.
//!
//! When the pair also grows width (e.g. BERT-Small -> BERT-Base), these
//! operators first apply deterministic cyclic FPI width growth — the
//! convention the paper's baselines need to produce valid shapes.

use crate::config::ModelConfig;
use crate::tensor::store::Store;

use super::net2net::grow_width;
use super::width::WidthMap;
use super::{layer_key, layer_suffixes, param_only_operator};

/// Width-grow first (cyclic FPI) if dims differ; identity otherwise.
fn width_stage(small: &Store, cfg_s: &ModelConfig, cfg_l: &ModelConfig) -> Store {
    if cfg_s.dim == cfg_l.dim && cfg_s.ffn() == cfg_l.ffn() {
        return small.clone();
    }
    let emb = WidthMap::cyclic(cfg_s.dim, cfg_l.dim);
    let ffn = WidthMap::cyclic(cfg_s.ffn(), cfg_l.ffn());
    grow_width(small, cfg_s, cfg_l, &emb, &ffn, true)
}

/// Assemble the large store taking layer l from `src_layer(l)`.
fn depth_map(
    wide: &Store,
    cfg_s: &ModelConfig,
    cfg_l: &ModelConfig,
    src: impl Fn(usize) -> usize,
) -> Store {
    let mut out = Store::new();
    // non-layer tensors copy through
    for (name, t) in wide.iter() {
        if !name.starts_with('L') {
            out.insert(name.clone(), t.clone());
        }
    }
    for l in 0..cfg_l.layers {
        let s = src(l).min(cfg_s.layers - 1);
        for suffix in layer_suffixes(cfg_s) {
            out.insert(layer_key(l, suffix), wide.expect(&layer_key(s, suffix)).clone());
        }
    }
    out
}

/// StackBERT: duplicate the whole block stack (W_l = W_{l mod L1}).
#[derive(Debug)]
pub struct StackBert;

impl StackBert {
    /// The parameter-space expansion (the whole operator; `grow(ctx)` wraps
    /// it into a [`super::GrowthOutcome`]).
    pub fn expand(&self, small: &Store, cfg_s: &ModelConfig, cfg_l: &ModelConfig) -> Store {
        let wide = width_stage(small, cfg_s, cfg_l);
        depth_map(&wide, cfg_s, cfg_l, |l| l % cfg_s.layers)
    }
}

param_only_operator!(StackBert, "stackbert");

/// Interpolation: interleave (W_l = W_{floor(l/k)}).
#[derive(Debug)]
pub struct Interpolation;

impl Interpolation {
    /// The parameter-space expansion (the whole operator; `grow(ctx)` wraps
    /// it into a [`super::GrowthOutcome`]).
    pub fn expand(&self, small: &Store, cfg_s: &ModelConfig, cfg_l: &ModelConfig) -> Store {
        let wide = width_stage(small, cfg_s, cfg_l);
        let k = cfg_l.layers.div_ceil(cfg_s.layers);
        depth_map(&wide, cfg_s, cfg_l, move |l| l / k.max(1))
    }
}

param_only_operator!(Interpolation, "interpolation");

/// MSLT initialization: keep the small stack at the bottom, duplicate the
/// *top* layer into the new slots (the layers MSLT's stages then train).
#[derive(Debug)]
pub struct Mslt;

impl Mslt {
    /// The parameter-space expansion (the whole operator; `grow(ctx)` wraps
    /// it into a [`super::GrowthOutcome`]).
    pub fn expand(&self, small: &Store, cfg_s: &ModelConfig, cfg_l: &ModelConfig) -> Store {
        let wide = width_stage(small, cfg_s, cfg_l);
        let top = cfg_s.layers - 1;
        depth_map(&wide, cfg_s, cfg_l, move |l| l.min(top))
    }
}

param_only_operator!(Mslt, "mslt");

#[cfg(test)]
mod tests {
    use super::*;
    use crate::growth::testutil::{mk_cfg, small_store};

    #[test]
    fn stackbert_pattern() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 8, 2);
        let big = StackBert.expand(&small_store(&cs), &cs, &cl);
        assert_eq!(big.expect("L02_q_w"), big.expect("L00_q_w"));
        assert_eq!(big.expect("L03_q_w"), big.expect("L01_q_w"));
        assert_ne!(big.expect("L02_q_w"), big.expect("L03_q_w"));
    }

    #[test]
    fn interpolation_pattern() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 8, 2);
        let big = Interpolation.expand(&small_store(&cs), &cs, &cl);
        // k = 2: layers [0,0,1,1]
        assert_eq!(big.expect("L01_q_w"), big.expect("L00_q_w"));
        assert_eq!(big.expect("L03_q_w"), big.expect("L02_q_w"));
        assert_ne!(big.expect("L00_q_w"), big.expect("L02_q_w"));
    }

    #[test]
    fn mslt_duplicates_top() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 8, 2);
        let big = Mslt.expand(&small_store(&cs), &cs, &cl);
        assert_eq!(big.expect("L02_q_w"), big.expect("L01_q_w"));
        assert_eq!(big.expect("L03_q_w"), big.expect("L01_q_w"));
    }

    #[test]
    fn combined_width_and_depth() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let big = StackBert.expand(&small_store(&cs), &cs, &cl);
        assert_eq!(big.expect("L03_q_w").shape, vec![12, 12]);
        assert_eq!(big.expect("emb_tok").shape, vec![64, 12]);
        assert_eq!(big.expect("L03_fc1_w").shape, vec![48, 12]);
    }

    #[test]
    fn depth_only_keeps_width_tensors_identical() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(6, 8, 2);
        let small = small_store(&cs);
        let big = StackBert.expand(&small, &cs, &cl);
        assert_eq!(big.expect("emb_tok"), small.expect("emb_tok"));
    }

    #[test]
    fn non_divisible_depth_ratio_clamps() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(5, 8, 2); // 2 -> 5 layers
        let big = Interpolation.expand(&small_store(&cs), &cs, &cl);
        assert_eq!(big.with_prefix("L04_").len(), 16);
    }
}
