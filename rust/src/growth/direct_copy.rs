//! DirectCopy (network morphism baseline; Wei et al. 2016, Fig. 6b):
//! the small matrices are copied into the top-left corner of the large
//! ones and the new entries are small random values — no duplication, no
//! normalization, no learning.

use crate::config::ModelConfig;
use crate::tensor::{store::Store, Tensor};
use crate::util::rng::Rng;

use super::width::corner_embed;
use super::{layer_key, layer_suffixes, param_only_operator};

#[derive(Debug)]
pub struct DirectCopy {
    pub noise: f32,
}

impl Default for DirectCopy {
    fn default() -> Self {
        DirectCopy { noise: 0.01 }
    }
}

fn grow_vec(t: &Tensor, d2: usize, noise: f32, rng: &mut Rng) -> Tensor {
    let mut out = t.f32s().to_vec();
    while out.len() < d2 {
        out.push(rng.range_f32(-noise, noise));
    }
    Tensor::from_f32(&[d2], out)
}

impl DirectCopy {
    /// The parameter-space expansion (the whole operator; `grow(ctx)` wraps
    /// it into a [`super::GrowthOutcome`]).
    pub fn expand(&self, small: &Store, cfg_s: &ModelConfig, cfg_l: &ModelConfig) -> Store {
        let mut rng = Rng::new(0xD1DC);
        let d2 = cfg_l.dim;
        let f2 = cfg_l.ffn();
        let mut out = Store::new();
        for (name, t) in small.iter() {
            if name.starts_with('L') || name.starts_with('C') {
                continue; // layers handled below
            }
            let grown = match name.as_str() {
                "emb_tok" | "emb_pos" => corner_embed(t, t.shape[0], d2, self.noise, &mut rng),
                "mlm_bias" | "head_b" | "span_b" => t.clone(),
                "final_ln_g" => grow_ln(t, d2, 1.0),
                "final_ln_b" => grow_ln(t, d2, 0.0),
                "head_w" | "span_w" => corner_embed(t, t.shape[0], d2, self.noise, &mut rng),
                "emb_patch_w" => corner_embed(t, d2, t.shape[1], self.noise, &mut rng),
                "emb_patch_b" | "emb_cls" => grow_vec(t, d2, self.noise, &mut rng),
                _ => t.clone(),
            };
            out.insert(name.clone(), grown);
        }
        for l in 0..cfg_l.layers {
            let src = l % cfg_s.layers; // stack pattern for extra depth
            for suffix in layer_suffixes(cfg_s) {
                let t = small.expect(&layer_key(src, suffix));
                let grown = match suffix {
                    "q_w" | "k_w" | "v_w" | "o_w" => corner_embed(t, d2, d2, self.noise, &mut rng),
                    "fc1_w" => corner_embed(t, f2, d2, self.noise, &mut rng),
                    "fc2_w" => corner_embed(t, d2, f2, self.noise, &mut rng),
                    "fc1_b" => grow_vec(t, f2, self.noise, &mut rng),
                    "ln1_g" | "ln2_g" => grow_ln(t, d2, 1.0),
                    "ln1_b" | "ln2_b" => grow_ln(t, d2, 0.0),
                    _ => grow_vec(t, d2, self.noise, &mut rng),
                };
                out.insert(layer_key(l, suffix), grown);
            }
        }
        out
    }
}

param_only_operator!(DirectCopy, "direct_copy");

/// LN parameters extend with their neutral element (gain 1, bias 0).
fn grow_ln(t: &Tensor, d2: usize, neutral: f32) -> Tensor {
    let mut out = t.f32s().to_vec();
    out.resize(d2, neutral);
    Tensor::from_f32(&[d2], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::growth::testutil::{mk_cfg, small_store};

    #[test]
    fn corner_preserved_noise_bounded() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(2, 12, 3);
        let small = small_store(&cs);
        let big = DirectCopy::default().expand(&small, &cs, &cl);
        let (s, b) = (small.expect("L00_q_w"), big.expect("L00_q_w"));
        for i in 0..8 {
            for j in 0..8 {
                assert_eq!(s.at2(i, j), b.at2(i, j));
            }
        }
        assert!(b.at2(10, 10).abs() <= 0.01);
    }

    #[test]
    fn ln_gains_extend_with_ones() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(2, 12, 3);
        let big = DirectCopy::default().expand(&small_store(&cs), &cs, &cl);
        let g = big.expect("L00_ln1_g");
        assert_eq!(&g.f32s()[8..], &[1.0, 1.0, 1.0, 1.0]);
        let b = big.expect("L01_ln2_b");
        assert_eq!(&b.f32s()[8..], &[0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn depth_growth_stacks() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 8, 2);
        let big = DirectCopy { noise: 0.0 }.expand(&small_store(&cs), &cs, &cl);
        assert_eq!(big.expect("L02_fc1_b"), big.expect("L00_fc1_b"));
    }

    #[test]
    fn all_target_tensors_present() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(3, 12, 3);
        let big = DirectCopy::default().expand(&small_store(&cs), &cs, &cl);
        assert_eq!(big.with_prefix("L02_").len(), 16);
        assert_eq!(big.expect("emb_tok").shape, vec![64, 12]);
    }
}
