//! Deterministic tree all-reduce over gradient [`Store`]s — the reduction
//! half of the `LIGO_WORKERS` data-parallel trainer.
//!
//! [`tree_sum`] combines the per-microbatch gradient leaves pairwise in a
//! fixed binary tree: round 1 adds leaf 1 into leaf 0, 3 into 2, ...;
//! round 2 adds slot 2 into slot 0, 6 into 4, ...; and so on (stride
//! doubling). The tree's *shape* depends only on the number of leaves —
//! never on which worker produced which leaf — so an N-worker run sums the
//! same floats in the same order as a 1-worker run and the result is
//! bit-identical for every worker count. This is the same discipline
//! `util::par` applies inside kernels (row partitioning never reassociates
//! a per-element reduction), lifted to the gradient-store level.
//!
//! Consumed leaves are recycled into the *shared* arena pool
//! ([`crate::tensor::arena::recycle_store_shared`]) because the next step's
//! worker threads — fresh scoped threads with empty thread-local pools —
//! draw from it; this is what keeps the multi-worker steady state at zero
//! fresh allocations.
//!
//! The serial `Trainer::train_step` path (env `LIGO_WORKERS` unset) keeps
//! its historical left-fold-with-prescaled-leaves accumulation untouched;
//! the two paths agree to float noise but not bitwise when
//! `grad_accum > 1` (they associate the sum differently). Bit-identity is
//! guaranteed *across worker counts*, which is the invariant the tests pin.

use crate::tensor::store::Store;
use crate::tensor::TensorData;
use crate::util::par;

/// Below this many elements a pairwise tensor add runs on the calling
/// thread; above it, `par_row_chunks` splits the elementwise add (which is
/// bit-identical by construction — no cross-element reduction).
const PAR_MIN_ELEMS: usize = 1 << 15;

/// Elementwise `acc += src` over every f32 tensor the two stores share.
/// Shapes must match; names in `src` missing from `acc` are a caller bug
/// for gradient leaves (all leaves come from the same executable) but are
/// tolerated here to mirror [`crate::coordinator::optim::accumulate`].
pub fn add_into(acc: &mut Store, src: &Store) {
    for (name, t) in acc.iter_mut() {
        let Some(s) = src.get(name) else { continue };
        if !matches!(t.data, TensorData::F32(_)) {
            continue;
        }
        let dv = t.f32s_mut();
        let sv = s.f32s();
        assert_eq!(dv.len(), sv.len(), "tree-sum length mismatch on '{name}'");
        if dv.len() < PAR_MIN_ELEMS || par::threads() == 1 {
            for (d, x) in dv.iter_mut().zip(sv) {
                *d += x;
            }
        } else {
            par::par_row_chunks(dv, 1, |row0, chunk| {
                for (d, x) in chunk.iter_mut().zip(&sv[row0..row0 + chunk.len()]) {
                    *d += x;
                }
            });
        }
    }
}

/// Sum the gradient leaves in the canonical stride-doubling binary tree
/// and return the total. The reduction order is a pure function of
/// `leaves.len()`, so any partition of the leaves across workers produces
/// bit-identical results. Consumed leaves go to the shared arena pool.
///
/// Panics on an empty input: a train step always has >= 1 microbatch.
pub fn tree_sum(leaves: Vec<Store>) -> Store {
    assert!(!leaves.is_empty(), "tree_sum needs at least one leaf");
    let n = leaves.len();
    let mut slots: Vec<Option<Store>> = leaves.into_iter().map(Some).collect();
    let mut stride = 1;
    while stride < n {
        let mut i = 0;
        while i + stride < n {
            let right = slots[i + stride].take().expect("each slot is consumed once");
            let left = slots[i].as_mut().expect("left slot is live");
            add_into(left, &right);
            crate::tensor::arena::recycle_store_shared(right);
            i += 2 * stride;
        }
        stride *= 2;
    }
    slots[0].take().expect("root slot holds the sum")
}

/// The scalar (per-microbatch loss) analog of [`tree_sum`]: same canonical
/// tree, same worker-count independence.
pub fn tree_sum_f32(vals: &[f32]) -> f32 {
    assert!(!vals.is_empty(), "tree_sum_f32 needs at least one value");
    let n = vals.len();
    let mut slots = vals.to_vec();
    let mut stride = 1;
    while stride < n {
        let mut i = 0;
        while i + stride < n {
            slots[i] += slots[i + stride];
            i += 2 * stride;
        }
        stride *= 2;
    }
    slots[0]
}

/// In-place `t *= scale` over every f32 tensor — the single post-reduction
/// `1/grad_accum` pass of the sharded step (one multiply per element, after
/// the tree, so the scaling order is also worker-count independent).
pub fn scale_store(s: &mut Store, scale: f32) {
    for (_name, t) in s.iter_mut() {
        if let TensorData::F32(v) = &mut t.data {
            for x in v.iter_mut() {
                *x *= scale;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn leaf(vals: &[f32]) -> Store {
        let mut s = Store::new();
        s.insert("w", Tensor::from_f32(&[vals.len()], vals.to_vec()));
        s
    }

    #[test]
    fn tree_sum_adds_all_leaves() {
        for n in 1..=9 {
            let leaves: Vec<Store> = (0..n).map(|i| leaf(&[i as f32, 1.0])).collect();
            let total = tree_sum(leaves);
            let expect = (0..n).sum::<usize>() as f32;
            assert_eq!(total.expect("w").f32s(), &[expect, n as f32], "n={n}");
        }
    }

    #[test]
    fn tree_shape_is_a_function_of_leaf_count_only() {
        // Values chosen so float addition is non-associative: a left fold
        // and the balanced tree disagree in the last bits. The tree result
        // must equal the explicitly-bracketed pairwise sum.
        let vals = [1.0e8f32, 1.0, -1.0e8, 1.0, 3.0e7, 1.0, -3.0e7];
        let tree = tree_sum_f32(&vals);
        // stride 1: (0+1)(2+3)(4+5); stride 2: (0+2)(4+6); stride 4: (0+4)
        let s01 = vals[0] + vals[1];
        let s23 = vals[2] + vals[3];
        let s45 = vals[4] + vals[5];
        let s03 = s01 + s23;
        let s46 = s45 + vals[6];
        assert_eq!(tree.to_bits(), (s03 + s46).to_bits());
        let fold: f32 = vals.iter().sum();
        // sanity: the orders genuinely differ on this input
        assert_ne!(tree.to_bits(), fold.to_bits(), "input must be order-sensitive");
    }

    #[test]
    fn store_tree_matches_scalar_tree_bitwise() {
        let raw = [1.0e8f32, 1.0, -1.0e8, 1.0, 3.0e7];
        let leaves: Vec<Store> = raw.iter().map(|&v| leaf(&[v])).collect();
        let total = tree_sum(leaves);
        assert_eq!(
            total.expect("w").f32s()[0].to_bits(),
            tree_sum_f32(&raw).to_bits(),
            "store reduction must use the same tree as the scalar one"
        );
    }

    #[test]
    fn add_into_parallel_path_is_exact() {
        // Above PAR_MIN_ELEMS the add is chunked; chunking an elementwise
        // op must be invisible.
        let n = PAR_MIN_ELEMS + 37;
        let a: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = (0..n).map(|i| (i as f32).cos()).collect();
        let mut acc = Store::new();
        acc.insert("w", Tensor::from_f32(&[n], a.clone()));
        let mut src = Store::new();
        src.insert("w", Tensor::from_f32(&[n], b.clone()));
        add_into(&mut acc, &src);
        for (i, x) in acc.expect("w").f32s().iter().enumerate() {
            assert_eq!(x.to_bits(), (a[i] + b[i]).to_bits(), "element {i}");
        }
    }

    #[test]
    fn scale_store_scales_every_element() {
        let mut s = leaf(&[2.0, -4.0]);
        scale_store(&mut s, 0.5);
        assert_eq!(s.expect("w").f32s(), &[1.0, -2.0]);
    }
}
