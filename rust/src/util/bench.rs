//! Criterion-style micro-benchmark harness (criterion is unavailable
//! offline). Warms up, collects N samples, reports mean/p50/p95 and
//! throughput; used by every target under rust/benches/.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub samples: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchStats {
    pub fn report(&self) {
        println!(
            "{:<44} {:>10}  mean {:>12}  p50 {:>12}  p95 {:>12}  min {:>12}",
            self.name,
            format!("n={}", self.samples),
            fmt_t(self.mean_s),
            fmt_t(self.p50_s),
            fmt_t(self.p95_s),
            fmt_t(self.min_s),
        );
    }

    /// Report with an items/sec throughput line (e.g. tokens, params).
    pub fn report_throughput(&self, items_per_iter: f64, unit: &str) {
        self.report();
        println!(
            "{:<44} {:>10}  {:>12.3e} {unit}/s",
            "", "", items_per_iter / self.mean_s
        );
    }
}

pub fn fmt_t(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

/// Run `f` repeatedly: `warmup` unmeasured iterations, then `samples`
/// measured ones. The closure result is black-boxed via volatile read.
pub fn bench<T>(name: &str, warmup: usize, samples: usize, mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        black_box(f());
        times.push(t.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let stats = BenchStats {
        name: name.to_string(),
        samples,
        mean_s: times.iter().sum::<f64>() / samples as f64,
        p50_s: times[samples / 2],
        p95_s: times[(samples * 95 / 100).min(samples - 1)],
        min_s: times[0],
    };
    stats.report();
    stats
}

/// Prevent the optimizer from eliding a computation.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let s = bench("noop", 2, 20, || 1 + 1);
        assert!(s.min_s <= s.p50_s && s.p50_s <= s.p95_s);
        assert_eq!(s.samples, 20);
    }

    #[test]
    fn formats_units() {
        assert!(fmt_t(2e-9).contains("ns"));
        assert!(fmt_t(2e-6).contains("µs"));
        assert!(fmt_t(2e-3).contains("ms"));
        assert!(fmt_t(2.0).contains(" s"));
    }
}
