//! Tiny argv parser: subcommands + `--key value` / `--flag` options.

use std::collections::BTreeMap;

/// Parsed command line: positional args and --options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f32(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = argv("train --model bert_base --steps 100 --fresh");
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("model"), Some("bert_base"));
        assert_eq!(a.get_usize("steps", 0), 100);
        assert!(a.has_flag("fresh"));
    }

    #[test]
    fn equals_form() {
        let a = argv("x --lr=0.001 --n=5");
        assert_eq!(a.get_f32("lr", 0.0), 0.001);
        assert_eq!(a.get_usize("n", 0), 5);
    }

    #[test]
    fn defaults_apply() {
        let a = argv("x");
        assert_eq!(a.get_usize("missing", 7), 7);
        assert!(!a.has_flag("nope"));
    }

    #[test]
    fn trailing_flag() {
        let a = argv("run --verbose");
        assert!(a.has_flag("verbose"));
    }
}
