//! Minimal JSON parser/serializer (RFC 8259 subset sufficient for manifests,
//! configs and metric reports; no external crates available offline).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are kept as f64 (manifest shapes fit exactly).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let b = s.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(b, &mut pos)?;
        skip_ws(b, &mut pos);
        if pos != b.len() {
            return Err(format!("trailing data at byte {pos}"));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        write_value(self, &mut out);
        out
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected eof".into());
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_str(b, pos)?)),
        b't' => lit(b, pos, "true", Json::Bool(true)),
        b'f' => lit(b, pos, "false", Json::Bool(false)),
        b'n' => lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn lit(b: &[u8], pos: &mut usize, word: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(v)
    } else {
        Err(format!("bad literal at byte {}", *pos))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| format!("bad number at byte {start}"))
}

fn parse_str(b: &[u8], pos: &mut usize) -> Result<String, String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    break;
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| "bad \\u escape".to_string())?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| "bad \\u escape".to_string())?;
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => return Err(format!("bad escape \\{}", c as char)),
                }
                *pos += 1;
            }
            _ => {
                // fast path: consume a run of plain bytes
                let start = *pos;
                while *pos < b.len() && b[*pos] != b'"' && b[*pos] != b'\\' {
                    *pos += 1;
                }
                out.push_str(std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?);
            }
        }
    }
    Err("unterminated string".into())
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // '['
    let mut items = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => return Err(format!("expected , or ] at byte {}", *pos)),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // '{'
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_str(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(format!("expected : at byte {}", *pos));
        }
        *pos += 1;
        map.insert(key, parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            _ => return Err(format!("expected , or }} at byte {}", *pos)),
        }
    }
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Json::Str(s) => write_str(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(x, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, x)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(k, out);
                out.push(':');
                write_value(x, out);
            }
            out.push('}');
        }
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3], "b": "hi\n", "c": null, "d": true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "hi\n");
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"x": {"y": [[1], [2, 3]]}}"#).unwrap();
        let y = v.get("x").unwrap().get("y").unwrap().as_arr().unwrap();
        assert_eq!(y[1].as_arr().unwrap()[1].as_f64(), Some(3.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(4.25).to_string(), "4.25");
    }

    #[test]
    fn parses_scientific_notation() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("-2.5E-2").unwrap().as_f64(), Some(-0.025));
    }
}
