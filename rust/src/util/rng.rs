//! Deterministic RNGs: a fast xoshiro-style stream RNG for data generation
//! and the counter-based `mix32` scheme shared with python (detinit.py) for
//! parameter initialization.

/// SplitMix64-based stream RNG. Deterministic, seedable, fast.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// The raw stream position, for checkpointing. Restoring a stream with
    /// [`Rng::from_state`] continues it bit-for-bit.
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Rebuild an RNG at an exact stream position captured by [`Rng::state`]
    /// (note: this is the raw state, not a seed for [`Rng::new`]).
    pub fn from_state(state: u64) -> Self {
        Rng { state }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.next_f32() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n.max(1) as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f32().max(1e-7);
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut t = self.next_f32() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Bernoulli with probability p.
    #[inline]
    pub fn coin(&mut self, p: f32) -> bool {
        self.next_f32() < p
    }
}

/// FNV-1a 64-bit hash (matches python detinit.fnv1a).
pub fn fnv1a(name: &str) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325;
    for b in name.as_bytes() {
        h = (h ^ (*b as u64)).wrapping_mul(0x100000001B3);
    }
    h
}

/// Counter-based mix32 (matches python detinit.det_fill).
#[inline]
pub fn mix32(mut z: u32) -> u32 {
    z ^= z >> 16;
    z = z.wrapping_mul(0x45D9F3B);
    z ^= z >> 16;
    z = z.wrapping_mul(0x45D9F3B);
    z ^ (z >> 16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let (mut a, mut b) = (Rng::new(42), Rng::new(42));
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_roundtrip_continues_the_stream_bitwise() {
        let mut a = Rng::new(42);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(1);
        let mut sum = 0.0f64;
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let xs: Vec<f32> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        assert!((counts[2] as f64 / 30_000.0 - 0.7).abs() < 0.03);
    }

    #[test]
    fn fnv_matches_python_reference() {
        // python: fnv1a("emb_tok") computed from detinit.py
        assert_eq!(fnv1a(""), 0xCBF29CE484222325);
        assert_eq!(fnv1a("a"), 0xAF63DC4C8601EC8C);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }
}
