//! Minimal data parallelism on scoped threads (rayon is unavailable
//! offline): split a row-major output buffer into contiguous row chunks and
//! fill each chunk on its own worker. Used by the hot `tensor::ops` paths
//! (`matmul`, `matmul_nt`) so growing a BERT-Base-sized store is multicore.
//!
//! Row partitioning never changes per-element accumulation order, so the
//! parallel results are bit-identical to the serial ones.

use std::cell::Cell;
use std::num::NonZeroUsize;
use std::sync::OnceLock;

thread_local! {
    /// Per-thread kernel fan-out budget (see [`set_thread_budget`]).
    static BUDGET: Cell<Option<usize>> = const { Cell::new(None) };
}

/// Worker count: this thread's budget override when set
/// ([`set_thread_budget`]), else `LIGO_THREADS` (via the
/// [`crate::util::knobs`] registry — a non-numeric value warns once and
/// falls back), else `available_parallelism`.
pub fn threads() -> usize {
    if let Some(n) = BUDGET.with(|c| c.get()) {
        return n.max(1);
    }
    static THREADS: OnceLock<usize> = OnceLock::new();
    *THREADS.get_or_init(|| {
        if let Some(n) = super::knobs::usize_env("LIGO_THREADS") {
            return n.max(1);
        }
        std::thread::available_parallelism()
            .map(NonZeroUsize::get)
            .unwrap_or(1)
    })
}

/// Cap this thread's kernel fan-out: `Some(n)` makes [`threads`] (and with
/// it every `par_row_chunks` call on this thread) use at most `n` workers;
/// `None` restores the process default. The data-parallel trainer
/// (`coordinator::parallel`) sets `threads()/workers` on each worker thread
/// so `LIGO_WORKERS=N` does not oversubscribe the host by `N x`. Chunk
/// *sizing* never changes per-element accumulation order, so the budget
/// affects wall-clock only, never bits.
pub fn set_thread_budget(v: Option<usize>) {
    BUDGET.with(|c| c.set(v));
}

/// Run `f(first_row, chunk)` over contiguous whole-row chunks of `out`
/// (row width `n_cols`), one chunk per worker. `f` must derive everything it
/// writes from `first_row` and the chunk itself, so chunking is transparent.
pub fn par_row_chunks<F>(out: &mut [f32], n_cols: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    if out.is_empty() || n_cols == 0 {
        return;
    }
    let rows = out.len() / n_cols;
    let nt = threads().min(rows);
    if nt <= 1 {
        f(0, out);
        return;
    }
    let rows_per = rows.div_ceil(nt);
    std::thread::scope(|s| {
        for (idx, chunk) in out.chunks_mut(rows_per * n_cols).enumerate() {
            let f = &f;
            s.spawn(move || f(idx * rows_per, chunk));
        }
    });
}

/// Like [`par_row_chunks`], but fills *two* row-aligned output buffers in
/// lock-step (`a` with `a_cols` columns, `b` with `b_cols` columns, same row
/// count). Used by kernels that produce a value plus per-row statistics
/// (layernorm's (mean, rstd)) in one pass.
pub fn par_row_chunks2<F>(a: &mut [f32], a_cols: usize, b: &mut [f32], b_cols: usize, f: F)
where
    F: Fn(usize, &mut [f32], &mut [f32]) + Sync,
{
    if a.is_empty() || a_cols == 0 || b_cols == 0 {
        return;
    }
    let rows = a.len() / a_cols;
    assert_eq!(b.len() / b_cols, rows, "row-count mismatch between buffers");
    let nt = threads().min(rows);
    if nt <= 1 {
        f(0, a, b);
        return;
    }
    let rows_per = rows.div_ceil(nt);
    std::thread::scope(|s| {
        let bs = b.chunks_mut(rows_per * b_cols);
        for (idx, (ca, cb)) in a.chunks_mut(rows_per * a_cols).zip(bs).enumerate() {
            let f = &f;
            s.spawn(move || f(idx * rows_per, ca, cb));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threads_at_least_one() {
        assert!(threads() >= 1);
    }

    #[test]
    fn thread_budget_caps_and_restores() {
        let ambient = threads();
        set_thread_budget(Some(1));
        assert_eq!(threads(), 1);
        set_thread_budget(Some(0)); // clamped, never zero
        assert_eq!(threads(), 1);
        set_thread_budget(None);
        assert_eq!(threads(), ambient);
    }

    #[test]
    fn chunks_cover_every_row_exactly_once() {
        let (rows, cols) = (37, 5);
        let mut out = vec![0.0f32; rows * cols];
        par_row_chunks(&mut out, cols, |row0, chunk| {
            for (r, row) in chunk.chunks_exact_mut(cols).enumerate() {
                for (c, v) in row.iter_mut().enumerate() {
                    *v += ((row0 + r) * cols + c) as f32;
                }
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f32, "element {i}");
        }
    }

    #[test]
    fn paired_chunks_stay_row_aligned() {
        let (rows, ac, bc) = (23, 4, 2);
        let mut a = vec![0.0f32; rows * ac];
        let mut b = vec![0.0f32; rows * bc];
        par_row_chunks2(&mut a, ac, &mut b, bc, |row0, ca, cb| {
            assert_eq!(ca.len() / ac, cb.len() / bc, "chunks must pair rows");
            for (r, row) in ca.chunks_exact_mut(ac).enumerate() {
                for v in row.iter_mut() {
                    *v = (row0 + r) as f32;
                }
            }
            for (r, row) in cb.chunks_exact_mut(bc).enumerate() {
                for v in row.iter_mut() {
                    *v = -((row0 + r) as f32);
                }
            }
        });
        for r in 0..rows {
            assert_eq!(a[r * ac], r as f32);
            assert_eq!(b[r * bc], -(r as f32));
        }
    }

    #[test]
    fn empty_and_degenerate_inputs_are_noops() {
        let mut empty: Vec<f32> = vec![];
        par_row_chunks(&mut empty, 4, |_, _| panic!("must not be called"));
        let mut one = vec![1.0f32; 3];
        par_row_chunks(&mut one, 3, |row0, chunk| {
            assert_eq!(row0, 0);
            for v in chunk.iter_mut() {
                *v = 2.0;
            }
        });
        assert_eq!(one, vec![2.0; 3]);
    }
}
