//! Wall-clock timing helpers used by the trainer's FLOPs/time ledger.

use std::time::Instant;

/// A simple stopwatch with lap support.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
    last: Instant,
}

impl Default for Timer {
    fn default() -> Self {
        Self::new()
    }
}

impl Timer {
    pub fn new() -> Self {
        let now = Instant::now();
        Timer { start: now, last: now }
    }

    /// Seconds since construction.
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Seconds since the previous lap (or construction).
    pub fn lap(&mut self) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        dt
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Instant::now();
    let r = f();
    (r, t.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elapsed_monotonic() {
        let mut t = Timer::new();
        let a = t.lap();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let b = t.lap();
        assert!(a >= 0.0 && b >= 0.002);
        assert!(t.elapsed() >= b);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
