//! The central `LIGO_*` environment-knob registry.
//!
//! Every environment variable the crate reads is declared once in
//! [`REGISTRY`] and parsed through the typed accessors here — the
//! `rust/analyze` lint pass rejects any `env::var("LIGO_…")` read outside
//! this module, and cross-checks that every registry row has a matching
//! knob row in `EXPERIMENTS.md`. `ligo inspect knobs` prints the registry
//! with each knob's current process value.
//!
//! Mis-parses are never silent: a knob set to a value its type cannot
//! parse emits a one-time `util/logging` warning naming the knob and the
//! rejected value, then behaves as if the knob were unset. (Before this
//! module, a typo'd `LIGO_WORKERS=two` silently fell back to the serial
//! step loop.)

use std::collections::BTreeSet;
use std::sync::{Mutex, OnceLock};

use crate::log_warn;

/// One registered environment knob: the name, a human-readable type and
/// default, and a one-line description (kept in sync with the
/// `EXPERIMENTS.md` knob table by the `rust/analyze` lint).
pub struct Knob {
    pub name: &'static str,
    pub ty: &'static str,
    pub default: &'static str,
    pub doc: &'static str,
}

/// Every `LIGO_*` knob the crate reads, in one place.
pub const REGISTRY: &[Knob] = &[
    Knob {
        name: "LIGO_THREADS",
        ty: "usize >= 1",
        default: "available cores",
        doc: "worker threads for the parallel tensor kernels (1 = strictly serial)",
    },
    Knob {
        name: "LIGO_WORKERS",
        ty: "usize >= 1",
        default: "unset (serial step loop)",
        doc: "sharded data-parallel trainer: microbatch workers per optimizer step",
    },
    Knob {
        name: "LIGO_FUSED",
        ty: "flag (0 disables)",
        default: "fused on",
        doc: "0 lowers linear+bias(+GELU) back to the unfused node chain (A/B runs)",
    },
    Knob {
        name: "LIGO_FUSED_XENT",
        ty: "flag (0 disables)",
        default: "fused on",
        doc: "0 lowers the streaming LM head back to materialized linear+masked_xent",
    },
    Knob {
        name: "LIGO_ARENA",
        ty: "flag (0 disables)",
        default: "arena on",
        doc: "0 disables the activation/gradient buffer recycling pool",
    },
    Knob {
        name: "LIGO_LOG",
        ty: "debug|info|warn|error",
        default: "info",
        doc: "stderr log threshold",
    },
    Knob {
        name: "LIGO_ARTIFACTS",
        ty: "path",
        default: "artifacts",
        doc: "artifacts directory (manifests, HLO, goldens, registry overrides)",
    },
    Knob {
        name: "LIGO_PROP_SEED",
        ty: "u64",
        default: "unset (seed sweep)",
        doc: "replay one property-test seed instead of the seeded sweep",
    },
    Knob {
        name: "LIGO_BENCH_FAST",
        ty: "flag (set skips)",
        default: "unset",
        doc: "growth_ops bench: skip the unfused ligo A/B line (CI calibration runs)",
    },
    Knob {
        name: "LIGO_BENCH_IDS",
        ty: "comma list",
        default: "all experiments",
        doc: "paper_tables bench: restrict the experiment id set (CI time budgets)",
    },
    Knob {
        name: "LIGO_BENCH_WORKERS_ONLY",
        ty: "flag (1 enables)",
        default: "unset",
        doc: "train_step bench: run only the worker-scaling section (CI workers gate)",
    },
    Knob {
        name: "LIGO_GROWTH_OPS_BUDGET_S",
        ty: "f64 seconds",
        default: "unset (no gate)",
        doc: "growth_ops bench: fail when the ligo_task_native mean exceeds the budget",
    },
    Knob {
        name: "LIGO_DECODE_SESSIONS",
        ty: "usize >= 1",
        default: "4",
        doc: "ligo serve: max concurrent decode sessions per batched step",
    },
    Knob {
        name: "LIGO_DECODE_PAGE",
        ty: "usize >= 1",
        default: "16",
        doc: "ligo serve: tokens per KV-cache page (per layer, per K/V side)",
    },
    Knob {
        name: "LIGO_CKPT_EVERY",
        ty: "usize >= 1",
        default: "unset (checkpointing off)",
        doc: "ligo train: write a full-state crash-safe checkpoint every K optimizer steps",
    },
    Knob {
        name: "LIGO_CKPT_KEEP",
        ty: "usize >= 1",
        default: "3",
        doc: "checkpoint retention: newest snapshots kept when pruning after each write",
    },
    Knob {
        name: "LIGO_FAULT",
        ty: "kill@step:K | torn_write | bit_flip",
        default: "unset (no injection)",
        doc: "fault injection for crash-safety tests: die at step K, or corrupt the next atomic write",
    },
    Knob {
        name: "LIGO_SEARCH_BUDGET",
        ty: "usize >= 1",
        default: "2000",
        doc: "ligo search: total probe optimizer steps across all halving rounds",
    },
    Knob {
        name: "LIGO_SEARCH_PROBE_STEPS",
        ty: "usize >= 1",
        default: "24",
        doc: "ligo search: full probe horizon (steps) a finalist candidate trains for",
    },
    Knob {
        name: "LIGO_SEARCH_TOPK",
        ty: "usize >= 1",
        default: "4",
        doc: "ligo search: ranked candidates kept through halving and reported",
    },
];

/// Look a knob up in [`REGISTRY`] (e.g. for doc rendering).
pub fn find(name: &str) -> Option<&'static Knob> {
    REGISTRY.iter().find(|k| k.name == name)
}

/// The raw current value of a knob: the one sanctioned `env::var` read.
/// Non-unicode values are treated as unset.
pub fn raw(name: &str) -> Option<String> {
    std::env::var(name).ok()
}

fn warned() -> &'static Mutex<BTreeSet<String>> {
    static WARNED: OnceLock<Mutex<BTreeSet<String>>> = OnceLock::new();
    WARNED.get_or_init(|| Mutex::new(BTreeSet::new()))
}

/// Warn about a rejected knob value, once per knob per process (a knob read
/// in a hot path or from many worker threads must not spam stderr).
pub fn warn_rejected(name: &str, value: &str, expected: &str) {
    let mut seen = warned().lock().unwrap_or_else(|p| p.into_inner());
    if seen.insert(name.to_string()) {
        log_warn!("ignoring {name}={value:?}: expected {expected}");
    }
}

/// `usize` knob: `None` when unset; a set-but-unparsable value warns once
/// and reads as unset.
pub fn usize_env(name: &str) -> Option<usize> {
    let v = raw(name)?;
    match v.parse::<usize>() {
        Ok(n) => Some(n),
        Err(_) => {
            warn_rejected(name, &v, "an unsigned integer");
            None
        }
    }
}

/// `u64` knob: same contract as [`usize_env`].
pub fn u64_env(name: &str) -> Option<u64> {
    let v = raw(name)?;
    match v.parse::<u64>() {
        Ok(n) => Some(n),
        Err(_) => {
            warn_rejected(name, &v, "a u64");
            None
        }
    }
}

/// `f64` knob: same contract as [`usize_env`].
pub fn f64_env(name: &str) -> Option<f64> {
    let v = raw(name)?;
    match v.parse::<f64>() {
        Ok(n) => Some(n),
        Err(_) => {
            warn_rejected(name, &v, "a number (seconds)");
            None
        }
    }
}

/// Disable-flag knob (`LIGO_FUSED` family): `true` only when set to `"0"`.
/// Values other than `0`/`1` warn once (the caller almost certainly meant
/// to disable) and keep the default-on behavior.
pub fn flag_disabled(name: &str) -> bool {
    match raw(name).as_deref() {
        Some("0") => true,
        None | Some("1") => false,
        Some(other) => {
            warn_rejected(name, other, "0 (disable) or 1");
            false
        }
    }
}

/// Enable-flag knob (`LIGO_BENCH_WORKERS_ONLY`): `true` only when `"1"`.
pub fn flag_enabled(name: &str) -> bool {
    raw(name).as_deref() == Some("1")
}

/// Presence knob (`LIGO_BENCH_FAST`): `true` when set to anything.
pub fn is_set(name: &str) -> bool {
    raw(name).is_some()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_prefixed_and_documented() {
        let mut seen = BTreeSet::new();
        for k in REGISTRY {
            assert!(k.name.starts_with("LIGO_"), "{} must be LIGO_-prefixed", k.name);
            assert!(seen.insert(k.name), "duplicate registry row {}", k.name);
            assert!(!k.doc.is_empty() && !k.ty.is_empty() && !k.default.is_empty());
        }
        assert!(find("LIGO_THREADS").is_some());
        assert!(find("LIGO_NO_SUCH_KNOB").is_none());
    }

    #[test]
    fn typed_accessors_parse_and_reject() {
        // names outside the registry so this test cannot race the knobs
        // other tests (or the harness) read; accessors don't require rows
        std::env::set_var("LIGO_TEST_USIZE", "7");
        assert_eq!(usize_env("LIGO_TEST_USIZE"), Some(7));
        std::env::set_var("LIGO_TEST_USIZE", "seven");
        assert_eq!(usize_env("LIGO_TEST_USIZE"), None);
        assert_eq!(usize_env("LIGO_TEST_UNSET_NEVER"), None);

        std::env::set_var("LIGO_TEST_F64", "1.25");
        assert_eq!(f64_env("LIGO_TEST_F64"), Some(1.25));
        std::env::set_var("LIGO_TEST_U64", "12");
        assert_eq!(u64_env("LIGO_TEST_U64"), Some(12));

        std::env::set_var("LIGO_TEST_FLAG", "0");
        assert!(flag_disabled("LIGO_TEST_FLAG"));
        std::env::set_var("LIGO_TEST_FLAG", "1");
        assert!(!flag_disabled("LIGO_TEST_FLAG"));
        std::env::set_var("LIGO_TEST_FLAG", "off");
        assert!(!flag_disabled("LIGO_TEST_FLAG")); // warns once, stays on
        assert!(!flag_enabled("LIGO_TEST_FLAG"));
        std::env::set_var("LIGO_TEST_FLAG", "1");
        assert!(flag_enabled("LIGO_TEST_FLAG"));
        assert!(is_set("LIGO_TEST_FLAG"));
    }

    #[test]
    fn mis_parsed_worker_knobs_warn_exactly_once_each() {
        // The regression the registry exists for: a typo'd LIGO_WORKERS=two
        // must warn (not silently fall back to the serial loop), and a knob
        // re-read in a hot path must not warn again. The warned-set is the
        // warn hook's once-per-knob record — observable directly here.
        for name in ["LIGO_WORKERS", "LIGO_THREADS"] {
            {
                let seen = warned().lock().unwrap_or_else(|p| p.into_inner());
                assert!(!seen.contains(name), "{name} must start unwarned");
            }
            std::env::set_var(name, if name == "LIGO_WORKERS" { "two" } else { "many" });
            assert_eq!(usize_env(name), None, "{name} mis-parse reads as unset");
            assert_eq!(usize_env(name), None, "second read stays unset");
            std::env::remove_var(name);
            let seen = warned().lock().unwrap_or_else(|p| p.into_inner());
            assert!(seen.contains(name), "{name} must be recorded after the first warn");
        }
    }

    #[test]
    fn rejected_values_warn_exactly_once() {
        let already = warned().lock().unwrap().contains("LIGO_TEST_ONCE");
        assert!(!already, "unique test knob must start unwarned");
        warn_rejected("LIGO_TEST_ONCE", "x", "a number");
        warn_rejected("LIGO_TEST_ONCE", "y", "a number");
        let seen = warned().lock().unwrap();
        assert!(seen.contains("LIGO_TEST_ONCE"));
    }
}
