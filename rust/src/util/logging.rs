//! Leveled stderr logging with an env-controlled threshold (LIGO_LOG=debug).

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static THRESHOLD: AtomicU8 = AtomicU8::new(1);

/// Initialize the threshold from the LIGO_LOG knob (debug|info|warn|error).
/// An unrecognized level warns once (via the knobs registry) and keeps the
/// `info` default.
pub fn init_from_env() {
    let lvl = match super::knobs::raw("LIGO_LOG").as_deref() {
        Some("debug") => 0,
        Some("info") | None => 1,
        Some("warn") => 2,
        Some("error") => 3,
        Some(other) => {
            super::knobs::warn_rejected("LIGO_LOG", other, "debug|info|warn|error");
            1
        }
    };
    THRESHOLD.store(lvl, Ordering::Relaxed);
}

pub fn set_level(level: Level) {
    THRESHOLD.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as u8 >= THRESHOLD.load(Ordering::Relaxed)
}

pub fn log(level: Level, msg: &str) {
    if enabled(level) {
        let tag = match level {
            Level::Debug => "DBG",
            Level::Info => "INF",
            Level::Warn => "WRN",
            Level::Error => "ERR",
        };
        eprintln!("[{tag}] {msg}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_filters() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }
}
