//! Fault injection for the crash-safety harness.
//!
//! A single process-wide knob, `LIGO_FAULT`, arms exactly one fault:
//!
//! - `kill@step:K` — the trainer aborts (with an error, not a panic) right
//!   after completing optimizer step `K`, after any checkpoint due at `K`
//!   has been written. This is the CI kill/resume probe.
//! - `torn_write` — the next atomic checkpoint write stops partway through
//!   the temp file but still renames it into place, simulating a crash
//!   between `write` and `fsync` on a filesystem that reordered the ops.
//! - `bit_flip` — the next checkpoint write lands fully but with one byte
//!   corrupted, simulating media rot. Both write faults must be caught by
//!   the LGCK v2 section CRCs on the next load.
//!
//! Tests arm faults through [`set_override`] (thread-local, like
//! `ops::set_fused_override`) so parallel test threads cannot interfere;
//! the env knob is the CI / command-line path. Every fault fires **once**
//! per arming: a consumed fault stays consumed until re-armed, so a
//! resumed run does not re-kill itself.

use std::cell::Cell;
use std::sync::OnceLock;

use crate::util::knobs;

/// One armed fault, parsed from a `LIGO_FAULT` spec.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Abort training right after optimizer step `K` completes.
    KillAtStep(usize),
    /// Truncate the next checkpoint write but report success.
    TornWrite,
    /// Corrupt one byte of the next checkpoint write.
    BitFlip,
}

/// Parse a `LIGO_FAULT` spec (`kill@step:K` | `torn_write` | `bit_flip`).
pub fn parse(spec: &str) -> Option<Fault> {
    match spec {
        "torn_write" => Some(Fault::TornWrite),
        "bit_flip" => Some(Fault::BitFlip),
        _ => spec
            .strip_prefix("kill@step:")
            .and_then(|k| k.parse::<usize>().ok())
            .map(Fault::KillAtStep),
    }
}

/// The env-armed fault, parsed once per process. An unparsable value warns
/// once (via the knob registry) and reads as unset.
fn env_fault() -> Option<Fault> {
    static ENV: OnceLock<Option<Fault>> = OnceLock::new();
    *ENV.get_or_init(|| {
        let spec = knobs::raw("LIGO_FAULT")?;
        let f = parse(&spec);
        if f.is_none() {
            knobs::warn_rejected("LIGO_FAULT", &spec, "kill@step:K | torn_write | bit_flip");
        }
        f
    })
}

// Thread-local override + fired flags. `OVERRIDE` holds 0 = defer to env,
// 1 = forced off, 2 = forced on (fault in FORCED). The fired flags make
// each arming one-shot; `set_override` re-arms them.
thread_local! {
    static OVERRIDE: Cell<u8> = const { Cell::new(0) };
    static FORCED: Cell<Option<Fault>> = const { Cell::new(None) };
    static KILL_FIRED: Cell<bool> = const { Cell::new(false) };
    static WRITE_FIRED: Cell<bool> = const { Cell::new(false) };
}

/// Test-only arming: `Some(f)` forces fault `f` for this thread, `Some`
/// with no fault is expressed as `set_override(None)` restoring the env
/// default. Re-arming resets the one-shot fired state.
pub fn set_override(f: Option<Fault>) {
    OVERRIDE.with(|o| o.set(if f.is_some() { 2 } else { 0 }));
    FORCED.with(|c| c.set(f));
    KILL_FIRED.with(|c| c.set(false));
    WRITE_FIRED.with(|c| c.set(false));
}

/// Disarm all faults for this thread regardless of the env knob (used by
/// harness code that must not inherit a CI-armed fault, e.g. a resumed run
/// inside one test process).
pub fn clear_override() {
    OVERRIDE.with(|o| o.set(1));
    FORCED.with(|c| c.set(None));
    KILL_FIRED.with(|c| c.set(false));
    WRITE_FIRED.with(|c| c.set(false));
}

fn active() -> Option<Fault> {
    match OVERRIDE.with(|o| o.get()) {
        1 => None,
        2 => FORCED.with(|c| c.get()),
        _ => env_fault(),
    }
}

/// True exactly once per arming when a `kill@step:K` fault is armed and
/// training has just completed optimizer step `step`.
pub fn kill_due(step: usize) -> bool {
    match active() {
        Some(Fault::KillAtStep(k)) if k == step => {
            let fresh = !KILL_FIRED.with(|c| c.get());
            KILL_FIRED.with(|c| c.set(true));
            fresh
        }
        _ => false,
    }
}

/// Consume an armed write fault (`TornWrite` / `BitFlip`), once per arming.
/// Called by the atomic checkpoint writer.
pub fn take_write_fault() -> Option<Fault> {
    match active() {
        Some(f @ (Fault::TornWrite | Fault::BitFlip)) => {
            if WRITE_FIRED.with(|c| c.get()) {
                None
            } else {
                WRITE_FIRED.with(|c| c.set(true));
                Some(f)
            }
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_three_specs() {
        assert_eq!(parse("torn_write"), Some(Fault::TornWrite));
        assert_eq!(parse("bit_flip"), Some(Fault::BitFlip));
        assert_eq!(parse("kill@step:37"), Some(Fault::KillAtStep(37)));
        assert_eq!(parse("kill@step:"), None);
        assert_eq!(parse("kill@step:x"), None);
        assert_eq!(parse("explode"), None);
        assert_eq!(parse(""), None);
    }

    #[test]
    fn kill_fires_once_at_the_armed_step() {
        set_override(Some(Fault::KillAtStep(5)));
        assert!(!kill_due(4));
        assert!(kill_due(5));
        assert!(!kill_due(5), "one-shot: a fired kill stays consumed");
        assert!(!kill_due(6));
        set_override(Some(Fault::KillAtStep(5)));
        assert!(kill_due(5), "re-arming resets the one-shot state");
        clear_override();
        assert!(!kill_due(5));
    }

    #[test]
    fn write_faults_fire_once_and_kill_does_not_leak_into_writes() {
        set_override(Some(Fault::TornWrite));
        assert_eq!(take_write_fault(), Some(Fault::TornWrite));
        assert_eq!(take_write_fault(), None);
        set_override(Some(Fault::BitFlip));
        assert_eq!(take_write_fault(), Some(Fault::BitFlip));
        assert_eq!(take_write_fault(), None);
        set_override(Some(Fault::KillAtStep(3)));
        assert_eq!(take_write_fault(), None, "kill faults never corrupt writes");
        clear_override();
    }
}
