//! In-tree substrates that would normally be external crates.
//!
//! This build environment is offline (the optional `xla` dependency is a
//! vendored stub), so JSON, RNG, CLI parsing, micro-benchmarking, property
//! testing, data parallelism (`par`, in lieu of rayon) and error handling
//! (`crate::error`, in lieu of anyhow) are implemented here as small,
//! well-tested modules.

pub mod allreduce;
pub mod bench;
pub mod cli;
pub mod fault;
pub mod json;
pub mod knobs;
pub mod logging;
pub mod par;
pub mod prop;
pub mod rng;
pub mod timer;
