//! In-tree substrates that would normally be external crates.
//!
//! This build environment is offline (only the `xla` dependency closure is
//! vendored), so JSON, RNG, CLI parsing, micro-benchmarking and property
//! testing are implemented here as small, well-tested modules.

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod timer;
