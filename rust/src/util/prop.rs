//! Mini property-based testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, |g| ...)` runs the closure against `cases` seeded
//! generators; on failure it reports the failing seed so the case can be
//! replayed deterministically with `replay(seed, ...)`.

use super::rng::Rng;

/// Case generator handed to property closures.
pub struct Gen {
    pub rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }
    pub fn bool(&mut self) -> bool {
        self.rng.coin(0.5)
    }
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }
}

/// Run `cases` random cases of a property. Panics (with the seed) on the
/// first failure. Set LIGO_PROP_SEED to replay one specific seed (a
/// non-u64 value warns once via the knobs registry and runs the sweep).
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: u64, mut prop: F) {
    if let Some(seed) = super::knobs::u64_env("LIGO_PROP_SEED") {
        let mut g = Gen { rng: Rng::new(seed), seed };
        prop(&mut g);
        return;
    }
    for i in 0..cases {
        let seed = 0x5EED_0000 + i;
        let mut g = Gen { rng: Rng::new(seed), seed };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            eprintln!("property '{name}' FAILED at seed {seed} (LIGO_PROP_SEED={seed} to replay)");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        check("reflexive", 50, |g| {
            let x = g.f32_in(-10.0, 10.0);
            assert_eq!(x, x);
        });
    }

    #[test]
    #[should_panic]
    fn fails_false_property() {
        check("false", 50, |g| {
            let x = g.usize_in(0, 100);
            assert!(x < 95, "x = {x}");
        });
    }

    #[test]
    fn generators_in_bounds() {
        check("bounds", 100, |g| {
            let a = g.usize_in(3, 7);
            assert!((3..=7).contains(&a));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
            let v = g.vec_f32(5, 0.0, 2.0);
            assert_eq!(v.len(), 5);
        });
    }
}
