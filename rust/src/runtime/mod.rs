//! The pluggable artifact runtime. [`Backend`] turns `artifacts/*.hlo.txt`
//! (AOT-lowered by python at build time) into executables; the front-end
//! [`Runtime`] caches them and binds named tensor stores positionally.
//!
//! Backends:
//! * **pjrt** (feature `pjrt`) — compiles HLO on the XLA CPU PJRT client.
//! * **null** (default) — artifact loads fail with guidance; the native
//!   growth/LiGO/tensor paths keep the crate fully usable without XLA.
//!
//! Python never runs here in either configuration.

pub mod backend;
pub mod client;
pub mod executable;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use backend::{Backend, ExecEngine, NullBackend};
pub use client::Runtime;
pub use executable::{Executable, RunOutputs};
pub use manifest::{Manifest, TensorSpec};
