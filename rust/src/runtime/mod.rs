//! PJRT runtime: loads `artifacts/*.hlo.txt` (AOT-lowered by python at build
//! time), compiles them once on the CPU PJRT client, and executes them from
//! the coordinator's hot path. Python never runs here.

pub mod client;
pub mod executable;
pub mod manifest;

pub use client::Runtime;
pub use executable::Executable;
pub use manifest::{Manifest, TensorSpec};
