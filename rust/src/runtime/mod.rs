//! The pluggable artifact runtime. [`Backend`] turns `artifacts/*.hlo.txt`
//! (AOT-lowered by python at build time) into executables; the front-end
//! [`Runtime`] caches them and binds named tensor stores positionally.
//!
//! Backends:
//! * **pjrt** (feature `pjrt`) — compiles HLO on the XLA CPU PJRT client.
//! * **native** (default) — synthesizes `fwd_*`/`grad_*` executables from
//!   the preset table by running the in-crate transformer engine
//!   ([`crate::model`]); training, eval and growth run end to end from a
//!   clean checkout with no artifacts and no XLA.
//! * **null** — inert fallback (tests / explicit opt-out): artifact loads
//!   fail with guidance.
//!
//! The [`Runtime`] is also the **capability handle** of the unified growth
//! API: a [`crate::growth::GrowthContext`] optionally carries `&Runtime`,
//! and the LiGO route selection probes `Runtime::load` for the
//! `ligo_grad_*`/`ligo_apply_*` pair — a load error is not fatal there, it
//! is the negotiation signal that demotes the grow to the native task-loss
//! route (the error text is preserved in the outcome's route log).
//!
//! Python never runs here in any configuration.

pub mod backend;
pub mod client;
pub mod executable;
pub mod manifest;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use backend::{Backend, ExecEngine, NullBackend};
pub use client::Runtime;
pub use executable::{Executable, RunOutputs};
pub use manifest::{Manifest, TensorSpec};
pub use native::NativeBackend;
