//! The native runtime backend: synthesizes `fwd_{preset}` / `grad_{preset}`
//! executables directly from the [`ModelConfig`] table by running the
//! in-crate transformer engine ([`crate::model`]) — no HLO, no XLA, no AOT
//! artifacts. This is the default backend of the no-`pjrt` build, replacing
//! the old `NullBackend` default that could not execute anything: `Trainer`,
//! the experiment harness and the benches now run end to end from a clean
//! checkout.
//!
//! The synthesized manifests use the exact group/name convention of the AOT
//! ones (`params/<tensor>`, `batch/tokens`, outputs `loss`[, `metric`],
//! `grads/<tensor>`), so [`super::Executable`]'s binding, validation and
//! scatter logic is shared verbatim between the two worlds.
//!
//! Parameter inputs are bound **zero-copy**: the engine hands the
//! positional `&Tensor`s to the model as a borrowed [`model::ParamView`]
//! map, and the tape takes them as borrowed leaves — a `grad_bert_base`
//! call copies no parameter bytes on its way in.

use std::collections::BTreeMap;
use std::path::Path;

use crate::bail;
use crate::config::{ModelConfig, Registry};
use crate::error::{Context, Error, Result};
use crate::model;
use crate::tensor::store::Store;
use crate::tensor::{DType, Tensor};

use super::backend::{Backend, ExecEngine};
use super::manifest::{Manifest, TensorSpec};

/// What a synthesized executable computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    Fwd,
    Grad,
}

/// Backend that synthesizes executables from model presets.
pub struct NativeBackend {
    models: BTreeMap<String, ModelConfig>,
}

impl NativeBackend {
    pub fn new(models: BTreeMap<String, ModelConfig>) -> NativeBackend {
        NativeBackend { models }
    }

    /// Backend over `artifacts/configs.json` when present, else the
    /// built-in preset table (the same rows).
    pub fn with_default_registry() -> NativeBackend {
        let reg = Registry::load_or_builtin(&crate::config::artifacts_dir());
        NativeBackend::new(reg.models)
    }

    fn config_for(&self, artifact: &str) -> Option<(Kind, &ModelConfig)> {
        // grad_gated_* needs the gate/token-keep inputs only the AOT path has
        if artifact.starts_with("grad_gated_") {
            return None;
        }
        if let Some(name) = artifact.strip_prefix("fwd_") {
            return self.models.get(name).map(|c| (Kind::Fwd, c));
        }
        if let Some(name) = artifact.strip_prefix("grad_") {
            return self.models.get(name).map(|c| (Kind::Grad, c));
        }
        None
    }
}

fn spec(name: String, shape: Vec<usize>, dtype: DType) -> TensorSpec {
    TensorSpec { name, shape, dtype }
}

fn batch_specs(cfg: &ModelConfig) -> Vec<TensorSpec> {
    if cfg.is_vision() {
        vec![
            spec(
                "batch/images".into(),
                vec![cfg.batch, cfg.img, cfg.img, cfg.channels],
                DType::F32,
            ),
            spec("batch/labels".into(), vec![cfg.batch], DType::I32),
        ]
    } else if cfg.n_classes > 0 {
        vec![
            spec("batch/tokens".into(), vec![cfg.batch, cfg.seq], DType::I32),
            spec("batch/labels".into(), vec![cfg.batch], DType::I32),
        ]
    } else {
        vec![
            spec("batch/tokens".into(), vec![cfg.batch, cfg.seq], DType::I32),
            spec("batch/labels".into(), vec![cfg.batch, cfg.seq], DType::I32),
        ]
    }
}

fn manifest_for(name: &str, kind: Kind, cfg: &ModelConfig) -> Manifest {
    let params = model::param_shapes(cfg);
    let mut inputs: Vec<TensorSpec> = params
        .iter()
        .map(|(n, s)| spec(format!("params/{n}"), s.clone(), DType::F32))
        .collect();
    inputs.extend(batch_specs(cfg));
    let mut outputs = vec![spec("loss".into(), vec![], DType::F32)];
    if cfg.is_vision() || cfg.n_classes > 0 {
        outputs.push(spec("metric".into(), vec![], DType::F32));
    }
    if kind == Kind::Grad {
        outputs.extend(
            params
                .iter()
                .map(|(n, s)| spec(format!("grads/{n}"), s.clone(), DType::F32)),
        );
    }
    Manifest { name: name.to_string(), inputs, outputs }
}

/// The synthesized execution engine: gathers positional inputs back into
/// named stores, runs the native model engine, scatters positional outputs.
struct NativeEngine {
    cfg: ModelConfig,
    kind: Kind,
    inputs: Vec<TensorSpec>,
}

impl ExecEngine for NativeEngine {
    fn execute(&self, inputs: &[&Tensor], outputs: &[TensorSpec]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.inputs.len() {
            bail!(
                "native engine '{}': got {} inputs, expected {}",
                self.cfg.name,
                inputs.len(),
                self.inputs.len()
            );
        }
        // Parameters bind zero-copy: the model engine borrows them straight
        // into the tape through `model::ParamView`. Only the (small) batch
        // tensors are materialized as an owned Store.
        let mut params: BTreeMap<&str, &Tensor> = BTreeMap::new();
        let mut batch = Store::new();
        for (sp, t) in self.inputs.iter().zip(inputs) {
            match sp.group() {
                "params" => {
                    params.insert(sp.key(), *t);
                }
                "batch" => batch.insert(sp.key(), (*t).clone()),
                other => bail!("native engine: unexpected input group '{other}'"),
            }
        }
        let (loss, mut grads, metric) = match self.kind {
            Kind::Fwd => {
                let (l, m) = model::loss_only(&self.cfg, &params, &batch)?;
                (l, None, m)
            }
            Kind::Grad => {
                let (l, g, m) = model::loss_and_grads(&self.cfg, &params, &batch)?;
                (l, Some(g), m)
            }
        };
        let mut out = Vec::with_capacity(outputs.len());
        for sp in outputs {
            if sp.name == "loss" {
                out.push(Tensor::scalar_f32(loss));
            } else if sp.name == "metric" {
                out.push(Tensor::scalar_f32(metric.unwrap_or(f32::NAN)));
            } else if sp.group() == "grads" {
                // move, don't clone: the grad store is ours and each key
                // scatters exactly once
                let g = grads
                    .as_mut()
                    .and_then(|g| g.remove(sp.key()))
                    .with_context(|| format!("native engine: no gradient for '{}'", sp.name))?;
                out.push(g);
            } else {
                bail!("native engine: unknown output '{}'", sp.name);
            }
        }
        if let Some(rest) = grads {
            crate::tensor::arena::recycle_store(rest);
        }
        Ok(out)
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn compile(&self, manifest: &Manifest, _hlo_path: &Path) -> Result<Box<dyn ExecEngine>> {
        // An on-disk artifact describes the same graph the engine can
        // synthesize; route through synthesis (ignoring the HLO). Unknown
        // names cannot execute without a real PJRT backend.
        match self.synthesize(&manifest.name) {
            Some(Ok((_m, engine))) => Ok(engine),
            Some(Err(e)) => Err(e),
            None => Err(Error::msg(format!(
                "artifact '{}': the native backend synthesizes only fwd_*/grad_* graphs of \
                 known presets and cannot execute AOT HLO (rebuild with `--features pjrt` \
                 and a real `xla` crate for artifact execution)",
                manifest.name
            ))),
        }
    }

    fn synthesize(&self, name: &str) -> Option<Result<(Manifest, Box<dyn ExecEngine>)>> {
        let (kind, cfg) = self.config_for(name)?;
        if !model::supports(cfg) {
            return Some(Err(Error::msg(format!(
                "artifact '{name}': preset '{}' has family '{}', which the native engine \
                 does not implement",
                cfg.name, cfg.family
            ))));
        }
        let manifest = manifest_for(name, kind, cfg);
        let engine = NativeEngine {
            cfg: cfg.clone(),
            kind,
            inputs: manifest.inputs.clone(),
        };
        Some(Ok((manifest, Box::new(engine) as Box<dyn ExecEngine>)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> NativeBackend {
        NativeBackend::new(Registry::builtin().models)
    }

    #[test]
    fn synthesizes_fwd_and_grad_for_known_presets() {
        let b = backend();
        let (m, _e) = b.synthesize("fwd_bert_small").unwrap().unwrap();
        assert_eq!(m.outputs.len(), 1, "LM fwd returns loss only");
        assert_eq!(m.inputs_of("batch").len(), 2);
        let (mg, _e) = b.synthesize("grad_bert_small").unwrap().unwrap();
        let n_params = m.inputs_of("params").len();
        assert_eq!(mg.outputs_of("grads").len(), n_params);
        // vision grads also report the accuracy metric
        let (mv, _e) = b.synthesize("grad_vit_s").unwrap().unwrap();
        assert_eq!(mv.output_index("metric"), Some(1));
        assert_eq!(mv.inputs_of("batch")[0].key(), "images");
    }

    #[test]
    fn unknown_and_unsupported_names_are_refused() {
        let b = backend();
        assert!(b.synthesize("fwd_nonexistent").is_none());
        assert!(b.synthesize("ligo_grad_bert_small__bert_base").is_none());
        assert!(b.synthesize("grad_gated_bert_base").is_none());
        assert!(b.synthesize("kd_grad_bert_small__bert_base").is_none());
    }

    #[test]
    fn engine_runs_a_forward_through_the_manifest_contract() {
        let b = backend();
        let (m, e) = b.synthesize("fwd_bert_small").unwrap().unwrap();
        let params = Store::det_init(&m.shapes_of("params"), 0);
        let cfg = Registry::builtin().models["bert_small"].clone();
        let corpus = crate::data::corpus::Corpus::new(cfg.vocab, 0);
        let batch = crate::data::batches::mlm_batch(
            &corpus,
            &cfg,
            &mut crate::util::rng::Rng::new(1),
        );
        let inputs: Vec<&Tensor> = m
            .inputs
            .iter()
            .map(|sp| {
                if sp.group() == "params" {
                    params.expect(sp.key())
                } else {
                    batch.expect(sp.key())
                }
            })
            .collect();
        let out = e.execute(&inputs, &m.outputs).unwrap();
        assert_eq!(out.len(), 1);
        let loss = out[0].item();
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    }
}
