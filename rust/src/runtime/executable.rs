//! A compiled artifact: manifest + backend execution engine + store binding.
//!
//! `run(&[(group, &Store)])` gathers inputs in manifest order from named
//! stores (validating shape and dtype here, backend-agnostically), hands
//! them to the [`ExecEngine`], and scatters outputs back into named stores
//! by group.

use crate::bail;
use crate::error::{Context, Result};
use crate::tensor::store::Store;

use super::backend::ExecEngine;
use super::manifest::Manifest;

pub struct Executable {
    pub manifest: Manifest,
    engine: Box<dyn ExecEngine>,
}

/// Outputs of a run, grouped: scalars by bare name, tensors by group.
#[derive(Debug, Default)]
pub struct RunOutputs {
    pub scalars: Vec<(String, f32)>,
    pub groups: std::collections::BTreeMap<String, Store>,
}

impl RunOutputs {
    pub fn scalar(&self, name: &str) -> Option<f32> {
        self.scalars.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }
    pub fn group(&self, name: &str) -> Option<&Store> {
        self.groups.get(name)
    }
    pub fn take_group(&mut self, name: &str) -> Option<Store> {
        self.groups.remove(name)
    }
}

impl Executable {
    pub(crate) fn new(manifest: Manifest, engine: Box<dyn ExecEngine>) -> Executable {
        Executable { manifest, engine }
    }

    /// Execute with inputs gathered from `(group, store)` bindings.
    /// Every manifest input must resolve: group must be bound and the store
    /// must contain the key with the manifest's exact shape and dtype.
    pub fn run(&self, bindings: &[(&str, &Store)]) -> Result<RunOutputs> {
        let mut inputs = Vec::with_capacity(self.manifest.inputs.len());
        for spec in &self.manifest.inputs {
            let store = bindings
                .iter()
                .find(|(g, _)| *g == spec.group())
                .map(|(_, s)| *s)
                .with_context(|| format!("no binding for input group '{}'", spec.group()))?;
            let tensor = store.get(spec.key()).with_context(|| {
                format!("store '{}' missing tensor '{}'", spec.group(), spec.key())
            })?;
            if tensor.shape != spec.shape {
                bail!(
                    "tensor '{}' shape {:?} != manifest {:?}",
                    spec.name,
                    tensor.shape,
                    spec.shape
                );
            }
            if tensor.dtype() != spec.dtype {
                bail!("tensor '{}' dtype mismatch with manifest", spec.name);
            }
            inputs.push(tensor);
        }
        let results = self.engine.execute(&inputs, &self.manifest.outputs)?;
        if results.len() != self.manifest.outputs.len() {
            bail!(
                "artifact '{}': {} outputs but manifest lists {}",
                self.manifest.name,
                results.len(),
                self.manifest.outputs.len()
            );
        }
        let mut out = RunOutputs::default();
        for (spec, t) in self.manifest.outputs.iter().zip(results) {
            if spec.group().is_empty() {
                out.scalars.push((spec.name.clone(), t.item()));
            } else {
                out.groups
                    .entry(spec.group().to_string())
                    .or_default()
                    .insert(spec.key().to_string(), t);
            }
        }
        Ok(out)
    }

    /// Total input bytes per call (diagnostics / perf accounting).
    pub fn input_bytes(&self) -> usize {
        self.manifest.inputs.iter().map(|s| s.numel() * 4).sum()
    }

    pub fn output_bytes(&self) -> usize {
        self.manifest.outputs.iter().map(|s| s.numel() * 4).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::TensorSpec;
    use crate::tensor::{Tensor, TensorData};

    /// Test engine: echoes a constant per output spec.
    struct Echo;

    impl ExecEngine for Echo {
        fn execute(&self, inputs: &[&Tensor], outputs: &[TensorSpec]) -> Result<Vec<Tensor>> {
            // sum of all f32 inputs, broadcast to each output shape
            let total: f32 = inputs
                .iter()
                .filter(|t| matches!(t.data, TensorData::F32(_)))
                .map(|t| t.f32s().iter().sum::<f32>())
                .sum();
            Ok(outputs
                .iter()
                .map(|s| Tensor::from_f32(&s.shape, vec![total; s.numel()]))
                .collect())
        }
    }

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"{
              "name": "echo",
              "inputs": [{"name": "params/w", "shape": [2], "dtype": "float32"}],
              "outputs": [
                {"name": "loss", "shape": [], "dtype": "float32"},
                {"name": "grads/w", "shape": [2], "dtype": "float32"}
              ]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn run_binds_validates_and_scatters() {
        let exe = Executable::new(manifest(), Box::new(Echo));
        let mut params = Store::new();
        params.insert("w", Tensor::from_f32(&[2], vec![1.5, 2.5]));
        let out = exe.run(&[("params", &params)]).unwrap();
        assert_eq!(out.scalar("loss"), Some(4.0));
        assert_eq!(out.group("grads").unwrap().expect("w").f32s(), &[4.0, 4.0]);
    }

    #[test]
    fn run_rejects_shape_mismatch_and_missing_groups() {
        let exe = Executable::new(manifest(), Box::new(Echo));
        let mut params = Store::new();
        params.insert("w", Tensor::from_f32(&[3], vec![0.0; 3]));
        assert!(exe.run(&[("params", &params)]).is_err(), "wrong shape");
        assert!(exe.run(&[]).is_err(), "unbound group");
        assert_eq!(exe.input_bytes(), 8);
        assert_eq!(exe.output_bytes(), 12);
    }
}
