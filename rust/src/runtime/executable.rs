//! A compiled artifact: PJRT executable + manifest + literal binding.
//!
//! `run(&[(group, &Store)])` gathers inputs in manifest order from named
//! stores, executes, and scatters outputs back into named stores by group.

use anyhow::{bail, Context, Result};

use super::manifest::{Manifest, TensorSpec};
use crate::tensor::store::Store;
use crate::tensor::{DType, Tensor, TensorData};

pub struct Executable {
    pub manifest: Manifest,
    exe: xla::PjRtLoadedExecutable,
}

/// Outputs of a run, grouped: scalars by bare name, tensors by group.
#[derive(Debug, Default)]
pub struct RunOutputs {
    pub scalars: Vec<(String, f32)>,
    pub groups: std::collections::BTreeMap<String, Store>,
}

impl RunOutputs {
    pub fn scalar(&self, name: &str) -> Option<f32> {
        self.scalars.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }
    pub fn group(&self, name: &str) -> Option<&Store> {
        self.groups.get(name)
    }
    pub fn take_group(&mut self, name: &str) -> Option<Store> {
        self.groups.remove(name)
    }
}

fn to_literal(spec: &TensorSpec, t: &Tensor) -> Result<xla::Literal> {
    if t.shape != spec.shape {
        bail!(
            "tensor '{}' shape {:?} != manifest {:?}",
            spec.name,
            t.shape,
            spec.shape
        );
    }
    let dims: Vec<i64> = spec.shape.iter().map(|d| *d as i64).collect();
    let lit = match (&t.data, spec.dtype) {
        (TensorData::F32(v), DType::F32) => xla::Literal::vec1(v.as_slice()),
        (TensorData::I32(v), DType::I32) => xla::Literal::vec1(v.as_slice()),
        _ => bail!("tensor '{}' dtype mismatch with manifest", spec.name),
    };
    Ok(lit.reshape(&dims)?)
}

fn from_literal(spec: &TensorSpec, lit: &xla::Literal) -> Result<Tensor> {
    Ok(match spec.dtype {
        DType::F32 => Tensor::from_f32(&spec.shape, lit.to_vec::<f32>()?),
        DType::I32 => Tensor::from_i32(&spec.shape, lit.to_vec::<i32>()?),
    })
}

impl Executable {
    pub(super) fn new(manifest: Manifest, exe: xla::PjRtLoadedExecutable) -> Executable {
        Executable { manifest, exe }
    }

    /// Execute with inputs gathered from `(group, store)` bindings.
    /// Every manifest input must resolve: group must be bound and the store
    /// must contain the key.
    pub fn run(&self, bindings: &[(&str, &Store)]) -> Result<RunOutputs> {
        let mut literals = Vec::with_capacity(self.manifest.inputs.len());
        for spec in &self.manifest.inputs {
            let store = bindings
                .iter()
                .find(|(g, _)| *g == spec.group())
                .map(|(_, s)| *s)
                .with_context(|| format!("no binding for input group '{}'", spec.group()))?;
            let tensor = store
                .get(spec.key())
                .with_context(|| format!("store '{}' missing tensor '{}'", spec.group(), spec.key()))?;
            literals.push(to_literal(spec, tensor)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let root = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = root.to_tuple()?;
        if parts.len() != self.manifest.outputs.len() {
            bail!(
                "artifact '{}': {} outputs but manifest lists {}",
                self.manifest.name,
                parts.len(),
                self.manifest.outputs.len()
            );
        }
        let mut out = RunOutputs::default();
        for (spec, lit) in self.manifest.outputs.iter().zip(parts.iter()) {
            let t = from_literal(spec, lit)?;
            if spec.group().is_empty() {
                out.scalars.push((spec.name.clone(), t.item()));
            } else {
                out.groups
                    .entry(spec.group().to_string())
                    .or_default()
                    .insert(spec.key().to_string(), t);
            }
        }
        Ok(out)
    }

    /// Total input bytes per call (diagnostics / perf accounting).
    pub fn input_bytes(&self) -> usize {
        self.manifest.inputs.iter().map(|s| s.numel() * 4).sum()
    }

    pub fn output_bytes(&self) -> usize {
        self.manifest.outputs.iter().map(|s| s.numel() * 4).sum()
    }
}
