//! The runtime front-end: owns a [`Backend`] plus a compile cache of loaded
//! artifacts. With the `pjrt` feature (and a working `xla` crate) the
//! backend is the PJRT CPU client; otherwise the [`NativeBackend`]
//! synthesizes `fwd_*`/`grad_*` executables from the in-crate transformer
//! engine, so the crate trains end to end without XLA.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Mutex;

use crate::error::Result;
use crate::log_info;

use super::backend::Backend;
use super::executable::Executable;
use super::manifest::Manifest;
use super::native::NativeBackend;

/// Owns the backend and a name -> compiled executable cache.
pub struct Runtime {
    backend: Box<dyn Backend>,
    artifacts: PathBuf,
    cache: Mutex<BTreeMap<String, std::sync::Arc<Executable>>>,
}

/// Best backend this build can construct: PJRT when the feature is on and a
/// client comes up, the native transformer engine otherwise (which
/// synthesizes `fwd_*`/`grad_*` executables from the preset table, so the
/// default build trains end to end with no artifacts).
fn default_backend() -> Box<dyn Backend> {
    #[cfg(feature = "pjrt")]
    {
        match super::pjrt::PjrtBackend::cpu() {
            Ok(b) => return Box::new(b),
            Err(e) => crate::log_warn!("PJRT unavailable ({e}); using the native backend"),
        }
    }
    Box::new(NativeBackend::with_default_registry())
}

impl Runtime {
    /// Runtime over an explicit backend, rooted at the artifacts directory.
    pub fn with_backend(backend: Box<dyn Backend>, artifacts: impl Into<PathBuf>) -> Runtime {
        Runtime {
            backend,
            artifacts: artifacts.into(),
            cache: Mutex::new(BTreeMap::new()),
        }
    }

    /// Create a CPU runtime rooted at the artifacts directory. Never fails:
    /// without PJRT the null backend is installed and artifact loads report
    /// an actionable error instead.
    pub fn cpu(artifacts: impl Into<PathBuf>) -> Result<Runtime> {
        Ok(Self::with_backend(default_backend(), artifacts))
    }

    /// Default runtime at ./artifacts (or $LIGO_ARTIFACTS).
    pub fn default_cpu() -> Result<Runtime> {
        Self::cpu(crate::config::artifacts_dir())
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Name of the installed backend ("pjrt" / "null").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Load + compile an artifact by name (cached). Backends that can
    /// synthesize the graph natively (the default `NativeBackend`) take
    /// priority; otherwise the on-disk manifest + HLO is compiled.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let t0 = std::time::Instant::now();
        let (manifest, engine) = match self.backend.synthesize(name) {
            Some(Ok((manifest, engine))) => {
                log_info!(
                    "synthesized executable '{}' on {} ({} inputs, {} outputs)",
                    name,
                    self.backend.name(),
                    manifest.inputs.len(),
                    manifest.outputs.len()
                );
                (manifest, engine)
            }
            Some(Err(e)) => return Err(e),
            None => {
                let manifest = Manifest::load(&self.artifacts, name)?;
                let hlo_path = self.artifacts.join(format!("{name}.hlo.txt"));
                let engine = self.backend.compile(&manifest, &hlo_path)?;
                log_info!(
                    "compiled artifact '{}' on {} in {:.2}s ({} inputs, {} outputs)",
                    name,
                    self.backend.name(),
                    t0.elapsed().as_secs_f64(),
                    manifest.inputs.len(),
                    manifest.outputs.len()
                );
                (manifest, engine)
            }
        };
        let exe = std::sync::Arc::new(Executable::new(manifest, engine));
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Forget a compiled artifact (frees the executable).
    pub fn evict(&self, name: &str) {
        self.cache.lock().unwrap().remove(name);
    }

    pub fn artifacts_dir(&self) -> &std::path::Path {
        &self.artifacts
    }

    /// Names of artifacts present on disk (for `ligo inspect`).
    pub fn available(&self) -> Vec<String> {
        let mut names: Vec<String> = std::fs::read_dir(&self.artifacts)
            .map(|rd| {
                rd.filter_map(|e| {
                    let f = e.ok()?.file_name().into_string().ok()?;
                    f.strip_suffix(".hlo.txt").map(str::to_string)
                })
                .collect()
            })
            .unwrap_or_default();
        names.sort();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_runtime_always_constructs() {
        let rt = Runtime::cpu(std::env::temp_dir().join("ligo_no_artifacts")).unwrap();
        // whichever backend came up, loading a missing artifact must error
        // (no manifest on disk), not panic.
        assert!(rt.load("fwd_nonexistent").is_err());
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn available_empty_for_missing_dir() {
        let rt = Runtime::cpu("/definitely/not/a/dir").unwrap();
        assert!(rt.available().is_empty());
    }

    #[test]
    fn default_runtime_synthesizes_known_presets_without_artifacts() {
        let rt = Runtime::cpu(std::env::temp_dir().join("ligo_no_artifacts")).unwrap();
        if rt.backend_name() != "native" {
            return; // pjrt build with a live client: nothing to assert here
        }
        let exe = rt.load("fwd_bert_small").expect("synthesized executable");
        assert!(!exe.manifest.inputs_of("params").is_empty());
        // the cache serves the same Arc on the second load
        let again = rt.load("fwd_bert_small").unwrap();
        assert!(std::sync::Arc::ptr_eq(&exe, &again));
    }
}
