//! The PJRT CPU client plus a compile cache of loaded artifacts.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Mutex;

use anyhow::{Context, Result};

use super::executable::Executable;
use super::manifest::Manifest;
use crate::log_info;

/// Owns the PJRT client and a name -> compiled executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts: PathBuf,
    cache: Mutex<BTreeMap<String, std::sync::Arc<Executable>>>,
}

impl Runtime {
    /// Create a CPU PJRT runtime rooted at the artifacts directory.
    pub fn cpu(artifacts: impl Into<PathBuf>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime {
            client,
            artifacts: artifacts.into(),
            cache: Mutex::new(BTreeMap::new()),
        })
    }

    /// Default runtime at ./artifacts (or $LIGO_ARTIFACTS).
    pub fn default_cpu() -> Result<Runtime> {
        Self::cpu(crate::config::artifacts_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact by name (cached).
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let manifest = Manifest::load(&self.artifacts, name)?;
        let hlo_path = self.artifacts.join(format!("{name}.hlo.txt"));
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&hlo_path)
            .with_context(|| format!("parse HLO text {hlo_path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile of artifact '{name}'"))?;
        log_info!(
            "compiled artifact '{}' in {:.2}s ({} inputs, {} outputs)",
            name,
            t0.elapsed().as_secs_f64(),
            manifest.inputs.len(),
            manifest.outputs.len()
        );
        let exe = std::sync::Arc::new(Executable::new(manifest, exe));
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Forget a compiled artifact (frees the executable).
    pub fn evict(&self, name: &str) {
        self.cache.lock().unwrap().remove(name);
    }

    pub fn artifacts_dir(&self) -> &std::path::Path {
        &self.artifacts
    }

    /// Names of artifacts present on disk (for `ligo inspect`).
    pub fn available(&self) -> Vec<String> {
        let mut names: Vec<String> = std::fs::read_dir(&self.artifacts)
            .map(|rd| {
                rd.filter_map(|e| {
                    let f = e.ok()?.file_name().into_string().ok()?;
                    f.strip_suffix(".hlo.txt").map(str::to_string)
                })
                .collect()
            })
            .unwrap_or_default();
        names.sort();
        names
    }
}
