//! PJRT backend (feature `pjrt`): loads `artifacts/*.hlo.txt` (AOT-lowered
//! by python at build time), compiles them once on the XLA CPU PJRT client,
//! and executes them from the coordinator's hot path.
//!
//! The vendored `xla` crate is an offline API stub whose client constructor
//! fails; `Runtime::cpu` then degrades to the null backend. Swap a real
//! xla-rs build into `vendor/xla` to execute artifacts (see README.md).

use std::path::Path;

use crate::bail;
use crate::error::{Context, Result};
use crate::tensor::{DType, Tensor, TensorData};

use super::backend::{Backend, ExecEngine};
use super::manifest::{Manifest, TensorSpec};

pub struct PjrtBackend {
    client: xla::PjRtClient,
}

impl PjrtBackend {
    pub fn cpu() -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(PjrtBackend { client })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&self, manifest: &Manifest, hlo_path: &Path) -> Result<Box<dyn ExecEngine>> {
        let proto = xla::HloModuleProto::from_text_file(hlo_path)
            .with_context(|| format!("parse HLO text {hlo_path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile of artifact '{}'", manifest.name))?;
        Ok(Box::new(PjrtEngine { exe }))
    }
}

struct PjrtEngine {
    exe: xla::PjRtLoadedExecutable,
}

fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|d| *d as i64).collect();
    let lit = match &t.data {
        TensorData::F32(v) => xla::Literal::vec1(v.as_slice()),
        TensorData::I32(v) => xla::Literal::vec1(v.as_slice()),
    };
    Ok(lit.reshape(&dims)?)
}

fn from_literal(spec: &TensorSpec, lit: &xla::Literal) -> Result<Tensor> {
    Ok(match spec.dtype {
        DType::F32 => Tensor::from_f32(&spec.shape, lit.to_vec::<f32>()?),
        DType::I32 => Tensor::from_i32(&spec.shape, lit.to_vec::<i32>()?),
    })
}

impl ExecEngine for PjrtEngine {
    fn execute(&self, inputs: &[&Tensor], outputs: &[TensorSpec]) -> Result<Vec<Tensor>> {
        let literals = inputs.iter().map(|t| to_literal(t)).collect::<Result<Vec<_>>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let root = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = root.to_tuple()?;
        if parts.len() != outputs.len() {
            bail!("PJRT returned {} outputs, manifest lists {}", parts.len(), outputs.len());
        }
        outputs
            .iter()
            .zip(parts.iter())
            .map(|(spec, lit)| from_literal(spec, lit))
            .collect()
    }
}
