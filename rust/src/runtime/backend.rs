//! The pluggable runtime backend: everything that turns an AOT artifact
//! (HLO text + manifest) into something executable lives behind [`Backend`],
//! so the coordinator, trainer and growth manager compile and run without
//! XLA. The PJRT implementation (feature `pjrt`) is in `super::pjrt`; the
//! default build installs [`super::native::NativeBackend`], which
//! *synthesizes* `fwd_*`/`grad_*` executables from the preset table via the
//! in-crate transformer engine. [`NullBackend`] remains as the inert
//! variant (tests / explicit opt-out): it reports artifacts as unavailable
//! and leaves only the parameter-space native paths (growth operators,
//! surrogate LiGO) in charge.

use std::path::Path;

use crate::error::{Error, Result};
use crate::tensor::Tensor;

use super::manifest::{Manifest, TensorSpec};

/// A compiled artifact's execution engine: positional tensors in, positional
/// tensors out (one per manifest output spec, in manifest order).
pub trait ExecEngine: Send + Sync {
    fn execute(&self, inputs: &[&Tensor], outputs: &[TensorSpec]) -> Result<Vec<Tensor>>;
}

/// A runtime backend: compiles a loaded artifact into an [`ExecEngine`].
pub trait Backend: Send + Sync {
    /// Short backend identifier ("pjrt", "null", ...).
    fn name(&self) -> &'static str;

    /// Human-readable platform string (PJRT reports the client's platform).
    fn platform(&self) -> String {
        self.name().to_string()
    }

    /// Compile one artifact. `hlo_path` points at the `<name>.hlo.txt` file
    /// next to the manifest.
    fn compile(&self, manifest: &Manifest, hlo_path: &Path) -> Result<Box<dyn ExecEngine>>;

    /// Synthesize an executable (manifest + engine) for `name` without any
    /// on-disk artifact. `None` means this backend cannot synthesize the
    /// name and the runtime should fall back to the artifact path;
    /// `Some(Err(..))` means the name was recognized but building it
    /// failed. The native backend overrides this for `fwd_*`/`grad_*`
    /// graphs of known presets.
    fn synthesize(&self, _name: &str) -> Option<Result<(Manifest, Box<dyn ExecEngine>)>> {
        None
    }
}

/// Backend used when no PJRT client is available: artifact loads fail with
/// an actionable message, while every native path keeps working.
pub struct NullBackend;

impl Backend for NullBackend {
    fn name(&self) -> &'static str {
        "null"
    }

    fn compile(&self, manifest: &Manifest, _hlo_path: &Path) -> Result<Box<dyn ExecEngine>> {
        Err(Error::msg(format!(
            "artifact '{}': no executable runtime backend — this build cannot run AOT \
             artifacts (rebuild with `--features pjrt` and a real `xla` crate); native \
             growth/LiGO paths remain available",
            manifest.name
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn null_backend_refuses_compilation_with_guidance() {
        let m = Manifest { name: "fwd_x".into(), inputs: vec![], outputs: vec![] };
        let err = NullBackend
            .compile(&m, Path::new("artifacts/fwd_x.hlo.txt"))
            .err()
            .expect("null backend must not compile");
        let msg = err.to_string();
        assert!(msg.contains("fwd_x"));
        assert!(msg.contains("pjrt"));
        assert_eq!(NullBackend.platform(), "null");
    }
}
