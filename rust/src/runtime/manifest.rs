//! Artifact manifests: the flattened input/output signature emitted by
//! `python/compile/aot.py` next to each HLO text file.
//!
//! A manifest entry name is "group/tensor" (e.g. "params/L00_q_w",
//! "grads/B_emb", "batch/tokens", "loss"). The order of entries is the
//! positional order of PJRT arguments/results.

use std::path::Path;

use crate::error::{Context, Error, Result};
use crate::tensor::DType;
use crate::util::json::Json;

/// One input or output slot of an executable.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Fully-qualified name: "group/name" or a bare scalar name ("loss").
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    /// The group prefix ("params", "batch", ...) or "" for bare names.
    pub fn group(&self) -> &str {
        self.name.split_once('/').map(|(g, _)| g).unwrap_or("")
    }

    /// The tensor name with the group stripped.
    pub fn key(&self) -> &str {
        self.name.split_once('/').map(|(_, k)| k).unwrap_or(&self.name)
    }

    pub fn numel(&self) -> usize {
        crate::tensor::numel(&self.shape)
    }
}

/// Parsed manifest for one artifact.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

fn parse_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    let arr = j.as_arr().context("spec list")?;
    arr.iter()
        .map(|e| {
            let name = e.get("name").and_then(Json::as_str).context("name")?.to_string();
            let shape = e
                .get("shape")
                .and_then(Json::as_arr)
                .context("shape")?
                .iter()
                .map(|d| d.as_usize().context("dim"))
                .collect::<Result<Vec<_>>>()?;
            let dtype = DType::parse(e.get("dtype").and_then(Json::as_str).context("dtype")?)?;
            Ok(TensorSpec { name, shape, dtype })
        })
        .collect()
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(Error::msg)?;
        Ok(Manifest {
            name: j.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
            inputs: parse_specs(j.get("inputs").context("inputs")?)?,
            outputs: parse_specs(j.get("outputs").context("outputs")?)?,
        })
    }

    pub fn load(artifacts: &Path, artifact: &str) -> Result<Manifest> {
        let path = artifacts.join(format!("{artifact}.manifest.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts`"))?;
        Self::parse(&text)
    }

    /// Input specs belonging to a group, in positional order.
    pub fn inputs_of(&self, group: &str) -> Vec<&TensorSpec> {
        self.inputs.iter().filter(|s| s.group() == group).collect()
    }

    pub fn outputs_of(&self, group: &str) -> Vec<&TensorSpec> {
        self.outputs.iter().filter(|s| s.group() == group).collect()
    }

    /// {name -> shape} for a group (e.g. to det-init a parameter store).
    pub fn shapes_of(&self, group: &str) -> Vec<(String, Vec<usize>)> {
        self.inputs_of(group)
            .into_iter()
            .map(|s| (s.key().to_string(), s.shape.clone()))
            .collect()
    }

    /// Positional index of a named output.
    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|s| s.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name": "grad_bert_small", "src_hash": "x",
      "inputs": [
        {"name": "params/L00_q_w", "shape": [48, 48], "dtype": "float32"},
        {"name": "params/emb_tok", "shape": [512, 48], "dtype": "float32"},
        {"name": "batch/tokens", "shape": [16, 32], "dtype": "int32"}
      ],
      "outputs": [
        {"name": "loss", "shape": [], "dtype": "float32"},
        {"name": "grads/L00_q_w", "shape": [48, 48], "dtype": "float32"}
      ]
    }"#;

    #[test]
    fn parses_and_groups() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "grad_bert_small");
        assert_eq!(m.inputs.len(), 3);
        assert_eq!(m.inputs_of("params").len(), 2);
        assert_eq!(m.inputs_of("batch")[0].key(), "tokens");
        assert_eq!(m.inputs_of("batch")[0].dtype, DType::I32);
        assert_eq!(m.outputs[0].group(), "");
        assert_eq!(m.output_index("loss"), Some(0));
        assert_eq!(m.output_index("grads/L00_q_w"), Some(1));
    }

    #[test]
    fn shapes_of_extracts_keys() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let shapes = m.shapes_of("params");
        assert_eq!(shapes[0], ("L00_q_w".to_string(), vec![48, 48]));
        assert_eq!(shapes[1].1, vec![512, 48]);
    }

    #[test]
    fn scalar_spec_numel_one() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.outputs[0].numel(), 1);
    }
}
