//! Prefetching batch loader: a producer thread keeps a bounded queue of
//! ready batches so batch construction overlaps PJRT execution (the
//! coordinator's event loop never waits on data for the tiny configs, and
//! for the ~100M e2e run prefetch hides the masking cost).

use std::sync::mpsc;
use std::thread::JoinHandle;

use crate::tensor::store::Store;

/// A boxed batch generator: `FnMut(step) -> Store`.
pub type BatchFn = Box<dyn FnMut(usize) -> Store + Send>;

/// One worker's slice of the global microbatch index stream — the single
/// source of truth for the `LIGO_WORKERS` sharding law, used both by
/// [`Loader::spawn_sharded`] and by the parallel trainer's leaf
/// assignment. Worker `w` of `W` owns exactly the global indices
/// `g ≡ w (mod W)`, so for any `W` the shards tile the stream: every
/// global index is owned by exactly one worker (the coverage guarantee)
/// and the batch *content* at a global index is independent of `W` (the
/// determinism guarantee — content is a function of the global index, the
/// shard only selects).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    worker: usize,
    workers: usize,
}

impl Shard {
    pub fn new(worker: usize, workers: usize) -> Shard {
        assert!(workers >= 1, "worker count must be >= 1");
        assert!(worker < workers, "worker {worker} out of range for {workers} workers");
        Shard { worker, workers }
    }

    /// The trivial shard: one worker owning the whole stream.
    pub fn full() -> Shard {
        Shard { worker: 0, workers: 1 }
    }

    pub fn worker(&self) -> usize {
        self.worker
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Does this shard own global index `g`?
    pub fn owns(&self, g: usize) -> bool {
        g % self.workers == self.worker
    }

    /// The `local`-th global index this shard owns.
    pub fn global_at(&self, local: usize) -> usize {
        self.worker + local * self.workers
    }
}

pub struct Loader {
    rx: mpsc::Receiver<Store>,
    handle: Option<JoinHandle<()>>,
    stop_tx: Option<mpsc::Sender<()>>,
}

impl Loader {
    /// Spawn a producer thread with `depth` batches of lookahead.
    pub fn spawn(make: BatchFn, depth: usize) -> Loader {
        Self::spawn_sharded(make, Shard::full(), depth)
    }

    /// Spawn a producer prefetching only this worker's shard of the global
    /// stream: the `local`-th batch produced is `make(shard.global_at(local))`,
    /// so `make` always sees *global* indices and batch content stays a
    /// function of the global index alone, whatever the worker count.
    pub fn spawn_sharded(mut make: BatchFn, shard: Shard, depth: usize) -> Loader {
        let (tx, rx) = mpsc::sync_channel(depth.max(1));
        let (stop_tx, stop_rx) = mpsc::channel();
        let handle = std::thread::spawn(move || {
            let mut local = 0usize;
            loop {
                if stop_rx.try_recv().is_ok() {
                    break;
                }
                let batch = make(shard.global_at(local));
                local += 1;
                if tx.send(batch).is_err() {
                    break; // consumer dropped
                }
            }
        });
        Loader { rx, handle: Some(handle), stop_tx: Some(stop_tx) }
    }

    /// Blocking fetch of the next batch. Returns `None` once the producer
    /// thread has exited (stop requested, batch closure panicked, or a
    /// finite stream ended) and the prefetch queue has drained — callers
    /// decide whether that is the end of an epoch or a hard error, instead
    /// of the loader panicking on their behalf.
    pub fn next(&self) -> Option<Store> {
        self.rx.recv().ok()
    }
}

impl Drop for Loader {
    fn drop(&mut self) {
        if let Some(tx) = self.stop_tx.take() {
            let _ = tx.send(());
        }
        // drain so the producer unblocks from its send
        while self.rx.try_recv().is_ok() {}
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Synchronous fallback (used by tests and tiny sweeps where thread churn
/// outweighs prefetch).
pub struct SyncLoader {
    make: BatchFn,
    step: usize,
}

impl SyncLoader {
    pub fn new(make: BatchFn) -> SyncLoader {
        SyncLoader { make, step: 0 }
    }
    pub fn next(&mut self) -> Store {
        let b = (self.make)(self.step);
        self.step += 1;
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn counter_batch(step: usize) -> Store {
        let mut s = Store::new();
        s.insert("step", Tensor::from_i32(&[1], vec![step as i32]));
        s
    }

    #[test]
    fn loader_produces_in_order() {
        let l = Loader::spawn(Box::new(counter_batch), 4);
        for expect in 0..10 {
            let b = l.next().expect("producer is alive");
            assert_eq!(b.expect("step").i32s()[0], expect);
        }
    }

    #[test]
    fn loader_shuts_down_cleanly() {
        let l = Loader::spawn(Box::new(counter_batch), 2);
        let _ = l.next();
        drop(l); // must not hang
    }

    #[test]
    fn dead_producer_yields_none_not_panic() {
        // Regression: next() used to panic via expect() when the producer
        // thread exited. A producer that dies (here: panics on step 2) must
        // surface as None after the prefetched batches drain.
        let l = Loader::spawn(
            Box::new(|step| {
                assert!(step < 2, "synthetic producer failure");
                counter_batch(step)
            }),
            1,
        );
        let mut seen = 0;
        while let Some(b) = l.next() {
            assert_eq!(b.expect("step").i32s()[0], seen);
            seen += 1;
            assert!(seen <= 2, "producer only made 2 batches");
        }
        assert!(seen <= 2);
    }

    #[test]
    fn shards_tile_the_stream_exactly_once_for_any_worker_count() {
        // coverage: for every worker count, each global index in an epoch
        // is owned by exactly one shard, and global_at enumerates exactly
        // the owned set in order
        for workers in 1..=5 {
            let shards: Vec<Shard> = (0..workers).map(|w| Shard::new(w, workers)).collect();
            for g in 0..40 {
                let owners = shards.iter().filter(|s| s.owns(g)).count();
                assert_eq!(owners, 1, "index {g} with {workers} workers");
            }
            for s in &shards {
                let enumerated: Vec<usize> =
                    (0..40).map(|l| s.global_at(l)).filter(|&g| g < 40).collect();
                let owned: Vec<usize> = (0..40).filter(|&g| s.owns(g)).collect();
                assert_eq!(enumerated, owned, "worker {} of {workers}", s.worker());
            }
        }
    }

    #[test]
    fn sharded_loaders_reassemble_the_serial_stream() {
        // determinism: same generator ⇒ same global batch order whether the
        // stream is produced by 1 loader or reassembled from 3 sharded ones
        let serial = Loader::spawn(Box::new(counter_batch), 4);
        let expect: Vec<i32> =
            (0..12).map(|_| serial.next().unwrap().expect("step").i32s()[0]).collect();
        let workers = 3;
        let sharded: Vec<Loader> = (0..workers)
            .map(|w| Loader::spawn_sharded(Box::new(counter_batch), Shard::new(w, workers), 2))
            .collect();
        let mut got = Vec::new();
        for _round in 0..4 {
            for l in &sharded {
                got.push(l.next().unwrap().expect("step").i32s()[0]);
            }
        }
        assert_eq!(got, expect, "sharded streams must tile the serial one in order");
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn shard_rejects_worker_out_of_range() {
        let _ = Shard::new(2, 2);
    }

    #[test]
    fn sync_loader_counts() {
        let mut l = SyncLoader::new(Box::new(counter_batch));
        assert_eq!(l.next().expect("step").i32s()[0], 0);
        assert_eq!(l.next().expect("step").i32s()[0], 1);
    }
}
