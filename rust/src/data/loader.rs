//! Prefetching batch loader: a producer thread keeps a bounded queue of
//! ready batches so batch construction overlaps PJRT execution (the
//! coordinator's event loop never waits on data for the tiny configs, and
//! for the ~100M e2e run prefetch hides the masking cost).

use std::sync::mpsc;
use std::thread::JoinHandle;

use crate::tensor::store::Store;

/// A boxed batch generator: `FnMut(step) -> Store`.
pub type BatchFn = Box<dyn FnMut(usize) -> Store + Send>;

pub struct Loader {
    rx: mpsc::Receiver<Store>,
    handle: Option<JoinHandle<()>>,
    stop_tx: Option<mpsc::Sender<()>>,
}

impl Loader {
    /// Spawn a producer thread with `depth` batches of lookahead.
    pub fn spawn(mut make: BatchFn, depth: usize) -> Loader {
        let (tx, rx) = mpsc::sync_channel(depth.max(1));
        let (stop_tx, stop_rx) = mpsc::channel();
        let handle = std::thread::spawn(move || {
            let mut step = 0usize;
            loop {
                if stop_rx.try_recv().is_ok() {
                    break;
                }
                let batch = make(step);
                step += 1;
                if tx.send(batch).is_err() {
                    break; // consumer dropped
                }
            }
        });
        Loader { rx, handle: Some(handle), stop_tx: Some(stop_tx) }
    }

    /// Blocking fetch of the next batch. Returns `None` once the producer
    /// thread has exited (stop requested, batch closure panicked, or a
    /// finite stream ended) and the prefetch queue has drained — callers
    /// decide whether that is the end of an epoch or a hard error, instead
    /// of the loader panicking on their behalf.
    pub fn next(&self) -> Option<Store> {
        self.rx.recv().ok()
    }
}

impl Drop for Loader {
    fn drop(&mut self) {
        if let Some(tx) = self.stop_tx.take() {
            let _ = tx.send(());
        }
        // drain so the producer unblocks from its send
        while self.rx.try_recv().is_ok() {}
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Synchronous fallback (used by tests and tiny sweeps where thread churn
/// outweighs prefetch).
pub struct SyncLoader {
    make: BatchFn,
    step: usize,
}

impl SyncLoader {
    pub fn new(make: BatchFn) -> SyncLoader {
        SyncLoader { make, step: 0 }
    }
    pub fn next(&mut self) -> Store {
        let b = (self.make)(self.step);
        self.step += 1;
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn counter_batch(step: usize) -> Store {
        let mut s = Store::new();
        s.insert("step", Tensor::from_i32(&[1], vec![step as i32]));
        s
    }

    #[test]
    fn loader_produces_in_order() {
        let l = Loader::spawn(Box::new(counter_batch), 4);
        for expect in 0..10 {
            let b = l.next().expect("producer is alive");
            assert_eq!(b.expect("step").i32s()[0], expect);
        }
    }

    #[test]
    fn loader_shuts_down_cleanly() {
        let l = Loader::spawn(Box::new(counter_batch), 2);
        let _ = l.next();
        drop(l); // must not hang
    }

    #[test]
    fn dead_producer_yields_none_not_panic() {
        // Regression: next() used to panic via expect() when the producer
        // thread exited. A producer that dies (here: panics on step 2) must
        // surface as None after the prefetched batches drain.
        let l = Loader::spawn(
            Box::new(|step| {
                assert!(step < 2, "synthetic producer failure");
                counter_batch(step)
            }),
            1,
        );
        let mut seen = 0;
        while let Some(b) = l.next() {
            assert_eq!(b.expect("step").i32s()[0], seen);
            seen += 1;
            assert!(seen <= 2, "producer only made 2 batches");
        }
        assert!(seen <= 2);
    }

    #[test]
    fn sync_loader_counts() {
        let mut l = SyncLoader::new(Box::new(counter_batch));
        assert_eq!(l.next().expect("step").i32s()[0], 0);
        assert_eq!(l.next().expect("step").i32s()[0], 1);
    }
}
