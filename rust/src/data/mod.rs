//! Data substrate: synthetic corpora, tokenization-level batch builders,
//! procedural vision datasets, downstream probe suites, and a prefetching
//! loader.
//!
//! The paper trains on Wikipedia/C4/ImageNet; offline we substitute seeded
//! synthetic sources with *learnable, capacity-sensitive* structure (see
//! DESIGN.md §4) so the growth-operator comparisons keep their shape.

pub mod batches;
pub mod corpus;
pub mod downstream;
pub mod loader;
pub mod vision;

/// Reserved token ids shared by every text task.
pub mod special {
    pub const PAD: i32 = 0;
    pub const MASK: i32 = 1;
    pub const CLS: i32 = 2;
    pub const SEP: i32 = 3;
    /// First content token id.
    pub const CONTENT: i32 = 4;
}
