//! Procedural vision dataset — the ImageNet stand-in for DeiT/CaiT runs.
//!
//! Each class is a distinct spatial pattern family (stripes, checker,
//! blobs, rings, gradients, ...) drawn with class-conditioned parameters at
//! a random position/phase over a noise background. Discriminating the
//! classes requires genuinely spatial features, so ViT capacity matters —
//! the property Fig. 4/8 need.

use crate::config::ModelConfig;
use crate::tensor::{store::Store, Tensor};
use crate::util::rng::Rng;

/// A task = (generator seed, number of classes, noise level). Transfer tasks
/// (Table 2) are new seeds / class counts over the same generator family.
#[derive(Debug, Clone)]
pub struct VisionTask {
    pub seed: u64,
    pub n_classes: usize,
    pub noise: f32,
}

impl VisionTask {
    pub fn pretrain() -> VisionTask {
        VisionTask { seed: 0xB16_CAFE, n_classes: 10, noise: 0.9 }
    }

    /// Named transfer tasks, analogs of the paper's Table 2 suite.
    pub fn transfer(name: &str) -> VisionTask {
        match name {
            "cifar10" => VisionTask { seed: 0xC1FA_0010, n_classes: 10, noise: 0.3 },
            "cifar100" => VisionTask { seed: 0xC1FA_0100, n_classes: 20, noise: 0.3 },
            "flowers" => VisionTask { seed: 0xF10_3E25, n_classes: 20, noise: 0.2 },
            "cars" => VisionTask { seed: 0xCA25_0001, n_classes: 20, noise: 0.35 },
            "chestxray" => VisionTask { seed: 0xC4E5_7000, n_classes: 8, noise: 0.5 },
            other => panic!("unknown vision task '{other}'"),
        }
    }

    /// Render one image of class `label` into `img` (side x side x 3, HWC).
    /// A lower-amplitude *distractor* pattern of a random other class is
    /// blended in, so discrimination is genuinely capacity-bound.
    fn render(&self, label: usize, side: usize, rng: &mut Rng, img: &mut [f32]) {
        // background noise
        for px in img.iter_mut() {
            *px = rng.range_f32(-self.noise, self.noise);
        }
        self.paint(label, side, rng, img, 0.35);
        let distractor = (label + 1 + rng.below(self.n_classes.saturating_sub(1).max(1)))
            % self.n_classes;
        self.paint(distractor, side, rng, img, 0.18);
    }

    fn paint(&self, label: usize, side: usize, rng: &mut Rng, img: &mut [f32], amp: f32) {
        // class-conditioned pattern parameters (deterministic per class)
        let mut crng = Rng::new(self.seed ^ (label as u64).wrapping_mul(0x9E37));
        let kind = crng.below(5);
        let freq = 1 + crng.below(3);
        let color = [crng.range_f32(0.4, 1.0), crng.range_f32(0.4, 1.0), crng.range_f32(0.4, 1.0)];
        // per-sample jitter
        let (ox, oy) = (rng.below(side / 2), rng.below(side / 2));
        let phase = rng.next_f32() * std::f32::consts::TAU;
        for y in 0..side {
            for x in 0..side {
                let fx = (x + ox) as f32 / side as f32;
                let fy = (y + oy) as f32 / side as f32;
                let v = match kind {
                    // stripes
                    0 => ((fx * freq as f32 * std::f32::consts::TAU + phase).sin()).signum(),
                    1 => {
                        let cx =
                            ((fx * 2.0 * freq as f32) as i32 + (fy * 2.0 * freq as f32) as i32) % 2;
                        if cx == 0 { 1.0 } else { -1.0 } // checker
                    }
                    2 => {
                        let dx = fx - 0.5;
                        let dy = fy - 0.5;
                        ((dx * dx + dy * dy).sqrt() * freq as f32 * 12.0 + phase).sin() // rings
                    }
                    // gradient
                    3 => (fx * freq as f32 + fy * freq as f32 * 0.5 + phase).fract() * 2.0 - 1.0,
                    _ => {
                        let bx = (fx * freq as f32 * 4.0 + phase).sin();
                        let by = (fy * freq as f32 * 4.0 + phase).cos();
                        bx * by // blobs
                    }
                };
                for c in 0..3 {
                    img[(y * side + x) * 3 + c] += amp * v * color[c];
                }
            }
        }
    }

    /// Build a batch Store with "images" (B,H,W,3) f32 and "labels" (B,) i32.
    pub fn batch(&self, cfg: &ModelConfig, rng: &mut Rng) -> Store {
        let side = cfg.img;
        let b = cfg.batch;
        let mut images = vec![0.0f32; b * side * side * 3];
        let mut labels = Vec::with_capacity(b);
        for i in 0..b {
            let label = rng.below(self.n_classes);
            labels.push(label as i32);
            let px = side * side * 3;
            self.render(label, side, rng, &mut images[i * px..(i + 1) * px]);
        }
        let mut st = Store::new();
        st.insert("images", Tensor::from_f32(&[b, side, side, 3], images));
        st.insert("labels", Tensor::from_i32(&[b], labels));
        st
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "v".into(),
            family: "vit".into(),
            layers: 6,
            dim: 48,
            heads: 4,
            vocab: 0,
            seq: 0,
            batch: 8,
            img: 32,
            patch: 8,
            channels: 3,
            n_classes: 10,
            cls_layers: 0,
            ffn_mult: 4,
        }
    }

    #[test]
    fn batch_shapes() {
        let t = VisionTask::pretrain();
        let b = t.batch(&cfg(), &mut Rng::new(0));
        assert_eq!(b.expect("images").shape, vec![8, 32, 32, 3]);
        assert_eq!(b.expect("labels").shape, vec![8]);
        for l in b.expect("labels").i32s() {
            assert!((0..10).contains(l));
        }
    }

    #[test]
    fn classes_are_visually_distinct() {
        // Mean intra-class L2 distance should be smaller than inter-class,
        // averaged over samples (the distractor pattern adds within-class
        // variance, so single pairs are noisy by design).
        let t = VisionTask::pretrain();
        let side = 16;
        let render = |label: usize, seed: u64| {
            let mut img = vec![0.0f32; side * side * 3];
            t.render(label, side, &mut Rng::new(seed), &mut img);
            img
        };
        let d = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>()
        };
        let (mut intra, mut inter) = (0.0f32, 0.0f32);
        let n = 16;
        for seed in 0..n {
            intra += d(&render(0, seed), &render(0, seed + 100));
            inter += d(&render(0, seed), &render(7, seed + 100));
        }
        assert!(intra < inter, "intra {intra} vs inter {inter}");
    }

    #[test]
    fn transfer_tasks_resolve() {
        for name in ["cifar10", "cifar100", "flowers", "cars", "chestxray"] {
            let t = VisionTask::transfer(name);
            assert!(t.n_classes >= 8 && t.n_classes <= 20);
        }
    }

    #[test]
    #[should_panic]
    fn unknown_task_panics() {
        VisionTask::transfer("imagenet22k");
    }

    #[test]
    fn images_bounded() {
        let t = VisionTask::pretrain();
        let b = t.batch(&cfg(), &mut Rng::new(3));
        for v in b.expect("images").f32s() {
            assert!(v.abs() <= 2.0);
        }
    }
}
