//! Batch builders: MLM masking (BERT), causal LM shifting (GPT), and the
//! gated variants used by the Fig. 5 efficiency strategies.
//!
//! A batch is a [`Store`] whose keys match the artifact's "batch" group
//! ("tokens", "labels", plus "gates"/"token_keep" for gated artifacts).

use crate::config::ModelConfig;
use crate::data::corpus::Corpus;
use crate::data::special;
use crate::tensor::{store::Store, Tensor};
use crate::util::rng::Rng;

/// Standard BERT masking ratios.
pub const MASK_PROB: f32 = 0.15;
const MASK_AS_MASK: f32 = 0.8;
const MASK_AS_RANDOM: f32 = 0.1; // remaining 0.1 keeps the original token

/// Build one MLM batch: 15% positions predicted; of those 80% -> [MASK],
/// 10% -> random token, 10% unchanged. labels = original id or -1.
pub fn mlm_batch(corpus: &Corpus, cfg: &ModelConfig, rng: &mut Rng) -> Store {
    let (b, s) = (cfg.batch, cfg.seq);
    let mut tokens = Vec::with_capacity(b * s);
    let mut labels = Vec::with_capacity(b * s);
    for _ in 0..b {
        let (seq, _topic) = corpus.sample(s, rng);
        for tok in seq {
            if rng.coin(MASK_PROB) {
                labels.push(tok);
                let r = rng.next_f32();
                if r < MASK_AS_MASK {
                    tokens.push(special::MASK);
                } else if r < MASK_AS_MASK + MASK_AS_RANDOM {
                    let content = corpus.vocab - special::CONTENT as usize;
                    tokens.push(special::CONTENT + rng.below(content) as i32);
                } else {
                    tokens.push(tok);
                }
            } else {
                tokens.push(tok);
                labels.push(-1);
            }
        }
    }
    let mut st = Store::new();
    st.insert("tokens", Tensor::from_i32(&[b, s], tokens));
    st.insert("labels", Tensor::from_i32(&[b, s], labels));
    st
}

/// Build one causal-LM batch: labels are the next token (last = -1).
pub fn lm_batch(corpus: &Corpus, cfg: &ModelConfig, rng: &mut Rng) -> Store {
    let (b, s) = (cfg.batch, cfg.seq);
    let mut tokens = Vec::with_capacity(b * s);
    let mut labels = Vec::with_capacity(b * s);
    for _ in 0..b {
        let (seq, _topic) = corpus.sample(s + 1, rng);
        tokens.extend_from_slice(&seq[..s]);
        labels.extend_from_slice(&seq[1..]);
    }
    let mut st = Store::new();
    st.insert("tokens", Tensor::from_i32(&[b, s], tokens));
    st.insert("labels", Tensor::from_i32(&[b, s], labels));
    st
}

/// Attach layer gates + token-keep mask to an MLM batch (Fig. 5 strategies).
/// `layer_drop_p` — probability a layer is dropped this step (progressive
/// schedule computed by the caller); `token_drop_p` — fraction of tokens
/// skipped in the middle third of layers.
pub fn gated_batch(
    corpus: &Corpus,
    cfg: &ModelConfig,
    rng: &mut Rng,
    layer_drop_p: f32,
    token_drop_p: f32,
) -> Store {
    let mut st = mlm_batch(corpus, cfg, rng);
    let gates: Vec<f32> = (0..cfg.layers)
        .map(|_| if rng.coin(layer_drop_p) { 0.0 } else { 1.0 })
        .collect();
    let keep: Vec<f32> = (0..cfg.batch * cfg.seq)
        .map(|_| if rng.coin(token_drop_p) { 0.0 } else { 1.0 })
        .collect();
    st.insert("gates", Tensor::from_f32(&[cfg.layers], gates));
    st.insert("token_keep", Tensor::from_f32(&[cfg.batch, cfg.seq], keep));
    st
}

/// Fraction of positions whose labels are active (for FLOPs-per-label calc).
pub fn active_label_fraction(batch: &Store) -> f32 {
    let labels = batch.expect("labels").i32s();
    labels.iter().filter(|&&l| l >= 0).count() as f32 / labels.len() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            family: "bert".into(),
            layers: 3,
            dim: 48,
            heads: 4,
            vocab: 512,
            seq: 32,
            batch: 16,
            img: 0,
            patch: 0,
            channels: 3,
            n_classes: 0,
            cls_layers: 0,
            ffn_mult: 4,
        }
    }

    #[test]
    fn mlm_shapes_and_mask_rate() {
        let corpus = Corpus::new(512, 0);
        let mut rng = Rng::new(0);
        let b = mlm_batch(&corpus, &cfg(), &mut rng);
        assert_eq!(b.expect("tokens").shape, vec![16, 32]);
        let frac = active_label_fraction(&b);
        assert!((0.08..0.25).contains(&frac), "mask rate {frac}");
    }

    #[test]
    fn mlm_labels_match_originals_only_at_masked() {
        let corpus = Corpus::new(512, 0);
        let mut rng = Rng::new(1);
        let b = mlm_batch(&corpus, &cfg(), &mut rng);
        let tokens = b.expect("tokens").i32s();
        let labels = b.expect("labels").i32s();
        for (t, l) in tokens.iter().zip(labels) {
            if *l >= 0 {
                assert!(*l >= special::CONTENT);
            } else {
                assert!(*t >= special::CONTENT); // unmasked positions keep content
            }
        }
    }

    #[test]
    fn lm_labels_are_shifted() {
        let corpus = Corpus::new(512, 0);
        let mut rng = Rng::new(2);
        let mut c = cfg();
        c.family = "gpt".into();
        let b = lm_batch(&corpus, &c, &mut rng);
        let tokens = b.expect("tokens").i32s();
        let labels = b.expect("labels").i32s();
        // labels[i] == tokens[i+1] within each row
        for row in 0..c.batch {
            for i in 0..c.seq - 1 {
                assert_eq!(labels[row * c.seq + i], tokens[row * c.seq + i + 1]);
            }
        }
    }

    #[test]
    fn gated_batch_has_gates() {
        let corpus = Corpus::new(512, 0);
        let mut rng = Rng::new(3);
        let b = gated_batch(&corpus, &cfg(), &mut rng, 0.5, 0.3);
        assert_eq!(b.expect("gates").shape, vec![3]);
        assert_eq!(b.expect("token_keep").shape, vec![16, 32]);
        for g in b.expect("gates").f32s() {
            assert!(*g == 0.0 || *g == 1.0);
        }
    }

    #[test]
    fn deterministic_given_rng_seed() {
        let corpus = Corpus::new(512, 0);
        let a = mlm_batch(&corpus, &cfg(), &mut Rng::new(5));
        let b = mlm_batch(&corpus, &cfg(), &mut Rng::new(5));
        assert_eq!(a.expect("tokens"), b.expect("tokens"));
    }
}
