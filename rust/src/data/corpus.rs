//! Synthetic Markov corpus — the Wikipedia/C4 stand-in.
//!
//! A *hierarchical* order-2 Markov source designed so that model capacity
//! matters (the property every figure in the paper depends on):
//!
//! * tokens are grouped into `CLASSES` coarse classes (hash of the id);
//! * the candidate successor set (size `SUCCESSORS`) depends on
//!   (class(prev1), topic) — only `CLASSES x TOPICS` contexts, so even a
//!   tiny model learns this first-order structure fast;
//! * the *weights* over candidates are a sharply-peaked Zipf^2 distribution
//!   whose rotation depends on class(prev2) — a second-order refinement
//!   worth ~1 nat that only higher-capacity models capture.
//!
//! The transition structure is implicit (hash-derived): no storage, fully
//! determined by `(seed, vocab)`.

use crate::data::special;
use crate::util::rng::{mix32, Rng};

/// Number of candidate successors per context.
const SUCCESSORS: usize = 6;
/// Coarse token classes driving the candidate sets.
const CLASSES: u32 = 32;
/// Number of latent topics.
pub const TOPICS: usize = 8;

#[derive(Debug, Clone)]
pub struct Corpus {
    pub vocab: usize,
    pub seed: u64,
    content: i32,
}

impl Corpus {
    pub fn new(vocab: usize, seed: u64) -> Corpus {
        assert!(vocab > 16, "vocab too small: {vocab}");
        Corpus { vocab, seed, content: special::CONTENT }
    }

    fn content_range(&self) -> i32 {
        self.vocab as i32 - self.content
    }

    #[inline]
    fn class(&self, tok: i32) -> u32 {
        mix32(tok as u32 ^ self.seed as u32) % CLASSES
    }

    /// The j-th candidate successor of class(prev1) under `topic`.
    #[inline]
    fn successor(&self, prev1: i32, topic: usize, j: usize) -> i32 {
        let h = mix32(
            (self.seed as u32)
                .wrapping_add(self.class(prev1).wrapping_mul(131))
                .wrapping_add((topic as u32).wrapping_mul(1009))
                .wrapping_add((j as u32).wrapping_mul(77)),
        );
        self.content + (h % self.content_range() as u32) as i32
    }

    /// Candidate weights: Zipf^2 rotated by class(prev2) — the second-order
    /// structure only larger models learn.
    #[inline]
    fn weights(&self, prev2: i32, topic: usize) -> [f32; SUCCESSORS] {
        let rot = (mix32(self.class(prev2).wrapping_mul(311) ^ (topic as u32)) as usize)
            % SUCCESSORS;
        let mut ws = [0.0f32; SUCCESSORS];
        for (j, w) in ws.iter_mut().enumerate() {
            let k = (j + SUCCESSORS - rot) % SUCCESSORS;
            *w = 1.0 / ((k as f32 + 1.0) * (k as f32 + 1.0));
        }
        ws
    }

    /// Sample the next token.
    fn next_token(&self, prev2: i32, prev1: i32, topic: usize, rng: &mut Rng) -> i32 {
        let ws = self.weights(prev2, topic);
        let j = rng.categorical(&ws);
        self.successor(prev1, topic, j)
    }

    /// Sample a fresh sequence of `len` content tokens with a random topic.
    pub fn sample(&self, len: usize, rng: &mut Rng) -> (Vec<i32>, usize) {
        let topic = rng.below(TOPICS);
        (self.sample_with_topic(len, topic, rng), topic)
    }

    /// Sample with a fixed topic (probe tasks condition on the topic).
    pub fn sample_with_topic(&self, len: usize, topic: usize, rng: &mut Rng) -> Vec<i32> {
        let mut out = Vec::with_capacity(len);
        let mut prev2 = self.content + rng.below(self.content_range() as usize) as i32;
        let mut prev1 = self.content + rng.below(self.content_range() as usize) as i32;
        for _ in 0..len {
            let tok = self.next_token(prev2, prev1, topic, rng);
            out.push(tok);
            prev2 = prev1;
            prev1 = tok;
        }
        out
    }

    /// Conditional entropy of a perfect order-2 model (Zipf^2 weights —
    /// identical for every context up to rotation).
    pub fn oracle_entropy(&self) -> f32 {
        let ws = self.weights(0, 0);
        let total: f32 = ws.iter().sum();
        -ws.iter().map(|w| (w / total) * (w / total).ln()).sum::<f32>()
    }

    /// Entropy of the best order-1 model (averages over the prev2 rotation):
    /// the gap to `oracle_entropy` is the capacity-sensitive margin.
    pub fn first_order_entropy(&self) -> f32 {
        // mixture of all rotations = uniform over the candidate set
        (SUCCESSORS as f32).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn tokens_in_content_range() {
        let c = Corpus::new(512, 0);
        let mut rng = Rng::new(1);
        let (seq, topic) = c.sample(256, &mut rng);
        assert!(topic < TOPICS);
        for t in seq {
            assert!((special::CONTENT..512).contains(&t));
        }
    }

    #[test]
    fn deterministic_given_seed_and_rng() {
        let c = Corpus::new(512, 7);
        let a = c.sample_with_topic(64, 3, &mut Rng::new(9));
        let b = c.sample_with_topic(64, 3, &mut Rng::new(9));
        assert_eq!(a, b);
    }

    #[test]
    fn different_topics_differ() {
        let c = Corpus::new(512, 7);
        let a = c.sample_with_topic(64, 0, &mut Rng::new(9));
        let b = c.sample_with_topic(64, 5, &mut Rng::new(9));
        assert_ne!(a, b);
    }

    #[test]
    fn structure_is_predictable() {
        // Successors of a fixed context must be a small set: the whole point
        // of the Markov source is that context constrains the next token.
        let c = Corpus::new(512, 0);
        let mut rng = Rng::new(2);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..500 {
            seen.insert(c.next_token(100, 200, 3, &mut rng));
        }
        assert!(seen.len() <= SUCCESSORS);
    }

    #[test]
    fn oracle_entropy_reasonable() {
        let c = Corpus::new(512, 0);
        let h = c.oracle_entropy();
        // entropy of Zipf(6) is ~1.66 nats; must be << ln(508) ~ 6.23
        assert!(h > 1.0 && h < 2.2, "H = {h}");
    }

    #[test]
    fn corpus_entropy_prop() {
        prop::check("sampled tokens valid for any vocab", 20, |g| {
            let vocab = g.usize_in(32, 1024);
            let c = Corpus::new(vocab, g.seed);
            let mut rng = Rng::new(g.seed);
            let (seq, _) = c.sample(32, &mut rng);
            assert!(seq.iter().all(|t| (special::CONTENT..vocab as i32).contains(t)));
        });
    }
}
