//! Downstream probe suites — the GLUE/SQuAD stand-ins for Tables 1/5/6.
//!
//! Seven classification probes + two span probes, all derived from the same
//! Markov corpus the models were pretrained on, each exercising a different
//! capability (topic detection, pair similarity, corruption detection,
//! span matching). What the tables measure is the *transfer delta between
//! initialization methods*, which these probes preserve.

use crate::config::ModelConfig;
use crate::data::corpus::{Corpus, TOPICS};
use crate::data::special;
use crate::tensor::{store::Store, Tensor};
use crate::util::rng::Rng;

/// Classification probe kinds (GLUE analogs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProbeKind {
    /// Binary topic polarity (SST-2 analog).
    Sst2,
    /// 3-way pair relation: same / adjacent / distant topic (MNLI analog).
    Mnli,
    /// Binary: is the second segment a noisy copy? (MRPC analog)
    Mrpc,
    /// Binary: was the sequence corrupted by shuffling? (CoLA analog)
    Cola,
    /// Binary: do the segments share a topic? (QNLI analog)
    Qnli,
    /// Binary near-duplicate detection with heavier noise (QQP analog)
    Qqp,
    /// 4-binned pair similarity (STS-B analog)
    Stsb,
}

pub const GLUE_SUITE: [(ProbeKind, &str); 7] = [
    (ProbeKind::Sst2, "SST-2"),
    (ProbeKind::Mnli, "MNLI"),
    (ProbeKind::Mrpc, "MRPC"),
    (ProbeKind::Cola, "CoLA"),
    (ProbeKind::Qnli, "QNLI"),
    (ProbeKind::Qqp, "QQP"),
    (ProbeKind::Stsb, "STS-B"),
];

/// A classification probe task bound to a corpus.
#[derive(Debug, Clone)]
pub struct Probe {
    pub kind: ProbeKind,
    pub corpus: Corpus,
}

impl Probe {
    pub fn new(kind: ProbeKind, corpus: Corpus) -> Probe {
        Probe { kind, corpus }
    }

    pub fn n_classes(&self) -> usize {
        match self.kind {
            ProbeKind::Mnli => 3,
            ProbeKind::Stsb => 4,
            _ => 2,
        }
    }

    /// One labeled example: (tokens of length `seq`, label).
    fn example(&self, seq: usize, rng: &mut Rng) -> (Vec<i32>, i32) {
        let half = (seq - 2) / 2;
        match self.kind {
            ProbeKind::Sst2 => {
                let topic = rng.below(TOPICS);
                let body = self.corpus.sample_with_topic(seq - 1, topic, rng);
                let mut toks = vec![special::CLS];
                toks.extend(body);
                (toks, i32::from(topic >= TOPICS / 2))
            }
            ProbeKind::Mnli => {
                let t1 = rng.below(TOPICS);
                let (t2, label) = match rng.below(3) {
                    0 => (t1, 0),                            // same
                    1 => ((t1 + 1) % TOPICS, 1),             // adjacent
                    _ => ((t1 + TOPICS / 2) % TOPICS, 2),    // distant
                };
                (self.pair(t1, t2, half, rng, 0.0), label)
            }
            ProbeKind::Mrpc | ProbeKind::Qqp => {
                let noise = if self.kind == ProbeKind::Mrpc { 0.15 } else { 0.3 };
                let t1 = rng.below(TOPICS);
                let a = self.corpus.sample_with_topic(half, t1, rng);
                let positive = rng.coin(0.5);
                let b = if positive {
                    // noisy copy
                    a.iter()
                        .map(|&tok| {
                            if rng.coin(noise) {
                                special::CONTENT
                                    + rng.below(
                                        self.corpus.vocab - special::CONTENT as usize,
                                    ) as i32
                            } else {
                                tok
                            }
                        })
                        .collect()
                } else {
                    self.corpus.sample_with_topic(half, rng.below(TOPICS), rng)
                };
                (Self::join(&a, &b, seq), i32::from(positive))
            }
            ProbeKind::Cola => {
                let topic = rng.below(TOPICS);
                let mut body = self.corpus.sample_with_topic(seq - 1, topic, rng);
                let corrupted = rng.coin(0.5);
                if corrupted {
                    rng.shuffle(&mut body);
                }
                let mut toks = vec![special::CLS];
                toks.extend(body);
                (toks, i32::from(!corrupted))
            }
            ProbeKind::Qnli => {
                let t1 = rng.below(TOPICS);
                let same = rng.coin(0.5);
                let t2 = if same { t1 } else { (t1 + 1 + rng.below(TOPICS - 1)) % TOPICS };
                (self.pair(t1, t2, half, rng, 0.0), i32::from(same))
            }
            ProbeKind::Stsb => {
                let t1 = rng.below(TOPICS);
                let bin = rng.below(4);
                // similarity bin 3 = same topic & low-noise copy ... 0 = unrelated
                let a = self.corpus.sample_with_topic(half, t1, rng);
                let b = match bin {
                    3 => a.clone(),
                    2 => a
                        .iter()
                        .map(|&tok| {
                            if rng.coin(0.3) {
                                special::CONTENT
                                    + rng.below(
                                        self.corpus.vocab - special::CONTENT as usize,
                                    ) as i32
                            } else {
                                tok
                            }
                        })
                        .collect(),
                    1 => self.corpus.sample_with_topic(half, t1, rng),
                    _ => self.corpus.sample_with_topic(half, (t1 + TOPICS / 2) % TOPICS, rng),
                };
                (Self::join(&a, &b, seq), bin as i32)
            }
        }
    }

    fn pair(&self, t1: usize, t2: usize, half: usize, rng: &mut Rng, _noise: f32) -> Vec<i32> {
        let a = self.corpus.sample_with_topic(half, t1, rng);
        let b = self.corpus.sample_with_topic(half, t2, rng);
        Self::join(&a, &b, half * 2 + 2)
    }

    fn join(a: &[i32], b: &[i32], seq: usize) -> Vec<i32> {
        let mut toks = Vec::with_capacity(seq);
        toks.push(special::CLS);
        toks.extend_from_slice(a);
        toks.push(special::SEP);
        toks.extend_from_slice(b);
        toks.resize(seq, special::PAD);
        toks
    }

    /// Build a probe batch: "tokens" (B,S) + "labels" (B,).
    pub fn batch(&self, cfg: &ModelConfig, rng: &mut Rng) -> Store {
        let (b, s) = (cfg.batch, cfg.seq);
        let mut tokens = Vec::with_capacity(b * s);
        let mut labels = Vec::with_capacity(b);
        for _ in 0..b {
            let (mut toks, label) = self.example(s, rng);
            toks.resize(s, special::PAD);
            tokens.extend(toks);
            labels.push(label);
        }
        let mut st = Store::new();
        st.insert("tokens", Tensor::from_i32(&[b, s], tokens));
        st.insert("labels", Tensor::from_i32(&[b], labels));
        st
    }
}

/// Span probe (SQuAD analog): the first content token after CLS is a query;
/// the answer is the single span in the body where that token appears
/// followed by its Markov continuation. Labels = start/end positions.
#[derive(Debug, Clone)]
pub struct SpanProbe {
    pub corpus: Corpus,
    /// SQuADv2 analog: fraction of unanswerable queries (span = CLS position).
    pub unanswerable: f32,
}

impl SpanProbe {
    pub fn v1(corpus: Corpus) -> SpanProbe {
        SpanProbe { corpus, unanswerable: 0.0 }
    }
    pub fn v2(corpus: Corpus) -> SpanProbe {
        SpanProbe { corpus, unanswerable: 0.33 }
    }

    /// "tokens" (B,S), "starts" (B,), "ends" (B,).
    pub fn batch(&self, cfg: &ModelConfig, rng: &mut Rng) -> Store {
        let (b, s) = (cfg.batch, cfg.seq);
        let mut tokens = Vec::with_capacity(b * s);
        let mut starts = Vec::with_capacity(b);
        let mut ends = Vec::with_capacity(b);
        for _ in 0..b {
            let topic = rng.below(TOPICS);
            let mut body = self.corpus.sample_with_topic(s - 2, topic, rng);
            let span_len = 2 + rng.below(3);
            let answerable = !rng.coin(self.unanswerable);
            // choose a span inside the body; the query token is its first token
            let start_in_body = rng.below(body.len().saturating_sub(span_len + 1)).max(1);
            let query = body[start_in_body];
            if !answerable {
                // remove the query token from the body entirely
                for t in body.iter_mut() {
                    if *t == query {
                        *t = special::CONTENT;
                    }
                }
            }
            let mut toks = vec![special::CLS, query];
            toks.extend(body);
            toks.truncate(s);
            toks.resize(s, special::PAD);
            tokens.extend(toks);
            if answerable {
                starts.push((start_in_body + 2).min(s - 1) as i32);
                ends.push((start_in_body + 2 + span_len - 1).min(s - 1) as i32);
            } else {
                starts.push(0);
                ends.push(0);
            }
        }
        let mut st = Store::new();
        st.insert("tokens", Tensor::from_i32(&[b, s], tokens));
        st.insert("starts", Tensor::from_i32(&[b], starts));
        st.insert("ends", Tensor::from_i32(&[b], ends));
        st
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "p".into(),
            family: "bert".into(),
            layers: 6,
            dim: 72,
            heads: 6,
            vocab: 512,
            seq: 32,
            batch: 16,
            img: 0,
            patch: 0,
            channels: 3,
            n_classes: 4,
            cls_layers: 0,
            ffn_mult: 4,
        }
    }

    #[test]
    fn all_probes_produce_valid_labels() {
        let corpus = Corpus::new(512, 0);
        for (kind, _name) in GLUE_SUITE {
            let p = Probe::new(kind, corpus.clone());
            let b = p.batch(&cfg(), &mut Rng::new(1));
            for l in b.expect("labels").i32s() {
                assert!((0..p.n_classes() as i32).contains(l), "{kind:?} label {l}");
            }
            assert_eq!(b.expect("tokens").shape, vec![16, 32]);
        }
    }

    #[test]
    fn tokens_start_with_cls() {
        let corpus = Corpus::new(512, 0);
        let p = Probe::new(ProbeKind::Mnli, corpus);
        let b = p.batch(&cfg(), &mut Rng::new(2));
        let toks = b.expect("tokens").i32s();
        for row in 0..16 {
            assert_eq!(toks[row * 32], special::CLS);
        }
    }

    #[test]
    fn span_labels_in_range() {
        let corpus = Corpus::new(512, 0);
        for probe in [SpanProbe::v1(corpus.clone()), SpanProbe::v2(corpus)] {
            let b = probe.batch(&cfg(), &mut Rng::new(3));
            let starts = b.expect("starts").i32s();
            let ends = b.expect("ends").i32s();
            for (s, e) in starts.iter().zip(ends) {
                assert!((0..32).contains(s));
                assert!(e >= s);
            }
        }
    }

    #[test]
    fn span_v2_has_unanswerable() {
        let corpus = Corpus::new(512, 0);
        let probe = SpanProbe::v2(corpus);
        let mut zero_count = 0;
        for seed in 0..10 {
            let b = probe.batch(&cfg(), &mut Rng::new(seed));
            zero_count += b.expect("starts").i32s().iter().filter(|&&s| s == 0).count();
        }
        assert!(zero_count > 10, "expected unanswerable examples, got {zero_count}");
    }

    #[test]
    fn probe_classes_match_kind() {
        let corpus = Corpus::new(512, 0);
        assert_eq!(Probe::new(ProbeKind::Mnli, corpus.clone()).n_classes(), 3);
        assert_eq!(Probe::new(ProbeKind::Stsb, corpus.clone()).n_classes(), 4);
        assert_eq!(Probe::new(ProbeKind::Sst2, corpus).n_classes(), 2);
    }
}
