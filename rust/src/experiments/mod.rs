//! The experiment harness: one module per paper table/figure (DESIGN.md §5).
//!
//! Each experiment trains the relevant method set on the scaled pairs,
//! prints paper-style rows (including the savings-% headline next to the
//! paper's number), and writes curves as CSV/JSON under `reports/`.

pub mod common;
pub mod figures;
pub mod progressive;
pub mod tables;

use crate::bail;
use crate::config::Registry;
use crate::error::Result;
use crate::runtime::Runtime;

/// All experiment ids: the paper's figures/tables in paper order, then the
/// beyond-the-paper scenarios ("progressive": multi-stage growth plans).
pub const ALL: [&str; 15] = [
    "fig2", "fig2c", "fig3", "fig3c", "fig4", "fig5", "fig6", "fig7", "fig8",
    "table1", "table2", "table3", "table5", "table6", "progressive",
];

/// Run one experiment by id. `scale` multiplies default step counts
/// (0.2 = quick smoke, 1.0 = full reproduction).
pub fn run(
    rt: &Runtime,
    reg: &Registry,
    id: &str,
    scale: f64,
    out_dir: &std::path::Path,
) -> Result<()> {
    match id {
        "fig2" => figures::fig2(rt, reg, scale, out_dir),
        "fig2c" => figures::fig2c(rt, reg, scale, out_dir),
        "fig3" => figures::fig3(rt, reg, scale, out_dir),
        "fig3c" => figures::fig3c(rt, reg, scale, out_dir),
        "fig4" => figures::fig4(rt, reg, scale, out_dir),
        "fig5" => figures::fig5(rt, reg, scale, out_dir),
        "fig6" => figures::fig6(rt, reg, scale, out_dir),
        "fig7" => figures::fig7(rt, reg, scale, out_dir),
        "fig8" => figures::fig8(rt, reg, scale, out_dir),
        "table1" => tables::table1(rt, reg, scale, out_dir),
        "table2" => tables::table2(rt, reg, scale, out_dir),
        "table3" => tables::table3(rt, reg, scale, out_dir),
        "table5" => tables::table5(rt, reg, scale, out_dir),
        "table6" => tables::table6(rt, reg, scale, out_dir),
        "progressive" => progressive::progressive(rt, reg, scale, out_dir),
        "all" => {
            for id in ALL {
                run(rt, reg, id, scale, out_dir)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment '{other}'; known: {ALL:?}"),
    }
}
