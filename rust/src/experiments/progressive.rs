//! Progressive (multi-stage) growth scenarios — the schedules the unified
//! growth API makes data-driven: a 2-stage LiGO run and StackBERT-style
//! progressive stacking ("Stacking Your Transformers", Du et al. 2024),
//! executed mid-run by `Trainer::run_plan` against a from-scratch
//! BERT-Base baseline. Growth steps land in each curve's `marks`, so the
//! report shows exactly where the model grew.

use std::path::Path;

use crate::config::Registry;
use crate::coordinator::strategies::progressive_plan;
use crate::coordinator::trainer::Trainer;
use crate::data::corpus::Corpus;
use crate::error::Result;
use crate::growth::LigoOptions;
use crate::log_info;
use crate::runtime::Runtime;

use super::common::{recipe_for, report, scaled, text_batches, LARGE_TRAIN_STEPS};

/// `bert_small -> bert_d6w48 -> bert_base`, growing at 1/3 and 2/3 of the
/// budget, vs. training BERT-Base from scratch for the whole budget.
pub fn progressive(rt: &Runtime, reg: &Registry, scale: f64, out: &Path) -> Result<()> {
    let small = reg.model("bert_small")?.clone();
    let mid = reg.model("bert_d6w48")?.clone();
    let large = reg.model("bert_base")?.clone();
    let steps = scaled(LARGE_TRAIN_STEPS, scale);
    let corpus = Corpus::new(large.vocab, 0);
    let mut curves = Vec::new();

    // scratch baseline: the large model for the whole budget
    let params = Trainer::scratch_params(rt, &large, 1)?;
    let mut tr = Trainer::new(rt, &large, recipe_for(&large, steps), params)?;
    let mut b = text_batches(&corpus, &large, 0x9A01);
    curves.push(tr.run("Scratch", &mut b, steps)?);

    // multi-stage runs: start small, grow mid-run at 1/3 and 2/3
    let m_opts = LigoOptions { steps: 25, ..Default::default() };
    let grow_every = (steps / 3).max(1);
    for (name, operator) in [("LiGO-2stage", "ligo"), ("StackBERT-prog", "stackbert")] {
        let chain = [small.clone(), mid.clone(), large.clone()];
        let plan = progressive_plan(&chain, grow_every, operator, &m_opts)?;
        let params = Trainer::scratch_params(rt, &small, 0)?;
        let mut tr = Trainer::new(rt, &small, recipe_for(&small, steps), params)?;
        let mut b = text_batches(&corpus, &small, 0x9A02);
        let curve = tr.run_plan(rt, name, &mut b, steps, &plan)?;
        for (step, label) in &curve.marks {
            log_info!("{name} mark @{step}: {label}");
        }
        curves.push(curve);
    }

    report(
        "progressive",
        "Progressive growth schedules (2-stage LiGO / progressive stacking) \
         vs. scratch BERT-Base",
        &curves,
        &[],
        false,
        out,
    )
}
