//! Progressive (multi-stage) growth scenarios — the schedules the unified
//! growth API makes data-driven: a 2-stage LiGO run and StackBERT-style
//! progressive stacking ("Stacking Your Transformers", Du et al. 2024),
//! executed mid-run by `Trainer::run_plan` against a from-scratch
//! BERT-Base baseline. Growth steps land in each curve's `marks`, so the
//! report shows exactly where the model grew.

use std::path::Path;

use crate::config::Registry;
use crate::coordinator::plan::GrowthPlan;
use crate::coordinator::strategies::progressive_plan;
use crate::coordinator::trainer::Trainer;
use crate::data::corpus::Corpus;
use crate::error::Result;
use crate::growth::LigoOptions;
use crate::log_info;
use crate::runtime::Runtime;

use super::common::{recipe_for, report, scaled, text_batches, LARGE_TRAIN_STEPS};

/// Execute a serialized [`GrowthPlan`] file (e.g. `ligo search`'s
/// `best_plan.json`) against the scratch baseline of its final config —
/// the round-trip half of `ligo search`: search output is training input.
///
/// The plan's configs may be synthesized search rungs rather than presets,
/// so this builds its own native runtime that knows every stage target;
/// the run length is the scaled budget, extended if needed so the last
/// scheduled stage stays reachable (`run_plan` rejects unreachable stages).
pub fn from_plan_file(plan_path: &Path, scale: f64, out: &Path) -> Result<()> {
    let plan = GrowthPlan::load(plan_path)?;
    let rt = crate::search::probe::runtime_for(
        std::iter::once(plan.initial()).chain(plan.stages().iter().map(|s| &s.target)),
    );
    let last_at = plan.stages().last().map(|s| s.at_step).unwrap_or(0);
    let steps = scaled(LARGE_TRAIN_STEPS, scale).max(last_at + (last_at / 2).max(10));
    let initial = plan.initial().clone();
    let large = plan.final_config().clone();
    let mut curves = Vec::new();

    // scratch baseline: the plan's final config for the whole budget
    // (probe_batches handles text and vision configs alike)
    let params = Trainer::scratch_params(&rt, &large, 1)?;
    let mut tr = Trainer::new(&rt, &large, recipe_for(&large, steps), params)?;
    let mut b = crate::search::probe::probe_batches(&large, 0x9A01);
    curves.push(tr.run("Scratch", &mut b, steps)?);

    // the plan itself, from the initial config's scratch params
    let params = Trainer::scratch_params(&rt, &initial, 0)?;
    let mut tr = Trainer::new(&rt, &initial, recipe_for(&initial, steps), params)?;
    let mut b = crate::search::probe::probe_batches(&initial, 0x9A02);
    let curve = tr.run_plan(&rt, "PlanFile", &mut b, steps, &plan)?;
    if curve.marks.len() != plan.stages().len() {
        crate::bail!(
            "plan file scheduled {} stage(s) but the run recorded {} growth mark(s)",
            plan.stages().len(),
            curve.marks.len()
        );
    }
    for (step, label) in &curve.marks {
        log_info!("PlanFile mark @{step}: {label}");
    }
    curves.push(curve);

    report(
        "progressive_plan",
        &format!(
            "Serialized growth plan {} ({} -> {}) vs. scratch {}",
            plan_path.display(),
            initial.name,
            large.name,
            large.name
        ),
        &curves,
        &[],
        false,
        out,
    )
}

/// `bert_small -> bert_d6w48 -> bert_base`, growing at 1/3 and 2/3 of the
/// budget, vs. training BERT-Base from scratch for the whole budget.
pub fn progressive(rt: &Runtime, reg: &Registry, scale: f64, out: &Path) -> Result<()> {
    let small = reg.model("bert_small")?.clone();
    let mid = reg.model("bert_d6w48")?.clone();
    let large = reg.model("bert_base")?.clone();
    let steps = scaled(LARGE_TRAIN_STEPS, scale);
    let corpus = Corpus::new(large.vocab, 0);
    let mut curves = Vec::new();

    // scratch baseline: the large model for the whole budget
    let params = Trainer::scratch_params(rt, &large, 1)?;
    let mut tr = Trainer::new(rt, &large, recipe_for(&large, steps), params)?;
    let mut b = text_batches(&corpus, &large, 0x9A01);
    curves.push(tr.run("Scratch", &mut b, steps)?);

    // multi-stage runs: start small, grow mid-run at 1/3 and 2/3
    let m_opts = LigoOptions { steps: 25, ..Default::default() };
    let grow_every = (steps / 3).max(1);
    for (name, operator) in [("LiGO-2stage", "ligo"), ("StackBERT-prog", "stackbert")] {
        let chain = [small.clone(), mid.clone(), large.clone()];
        let plan = progressive_plan(&chain, grow_every, operator, &m_opts)?;
        let params = Trainer::scratch_params(rt, &small, 0)?;
        let mut tr = Trainer::new(rt, &small, recipe_for(&small, steps), params)?;
        let mut b = text_batches(&corpus, &small, 0x9A02);
        let curve = tr.run_plan(rt, name, &mut b, steps, &plan)?;
        for (step, label) in &curve.marks {
            log_info!("{name} mark @{step}: {label}");
        }
        curves.push(curve);
    }

    report(
        "progressive",
        "Progressive growth schedules (2-stage LiGO / progressive stacking) \
         vs. scratch BERT-Base",
        &curves,
        &[],
        false,
        out,
    )
}
