//! Figure reproductions (paper §4.2/§4.3 and Appendix C).
//!
//! Paper savings numbers quoted in each header row come straight from the
//! paper text; ours are computed the same way (FLOPs/wall to reach the
//! scratch run's final quality) on the scaled substrate.

use std::path::Path;

use crate::error::Result;

use crate::config::Registry;
use crate::coordinator::metrics::Curve;
use crate::coordinator::optim::AdamW;
use crate::coordinator::strategies::{layer_drop_p, strategy_flops, MAX_LAYER_DROP, TOKEN_DROP};
use crate::coordinator::trainer::{eval_store, Trainer};
use crate::data::batches::{gated_batch, mlm_batch};
use crate::data::corpus::Corpus;
use crate::runtime::Runtime;
use crate::util::rng::Rng;
use crate::log_info;

use super::common::{
    ensure_pretrained, recipe_for, report, run_pair, scaled, standard_methods, Method,
    LARGE_TRAIN_STEPS, SMALL_PRETRAIN_STEPS,
};

/// Fig. 2(a,b): BERT-Small -> BERT-Base, all methods, loss vs FLOPs & wall.
pub fn fig2(rt: &Runtime, reg: &Registry, scale: f64, out: &Path) -> Result<()> {
    let small = reg.model("bert_small")?.clone();
    let large = reg.model("bert_base")?.clone();
    let curves = run_pair(
        rt, reg, &small, &large,
        &standard_methods(),
        scaled(LARGE_TRAIN_STEPS, scale),
        scaled(SMALL_PRETRAIN_STEPS, scale),
        out,
    )?;
    report(
        "fig2", "BERT-Small -> BERT-Base (log-ppl vs FLOPs / wall time)",
        &curves,
        &[("StackBERT", 0.341), ("MSLT", 0.349), ("KI", -0.057),
          ("bert2BERT", 0.290), ("LiGO", 0.447)],
        false, out,
    )
}

/// Fig. 2(c): growing to BERT-Large from either BERT-Small or BERT-Base.
pub fn fig2c(rt: &Runtime, reg: &Registry, scale: f64, out: &Path) -> Result<()> {
    let large = reg.model("bert_large")?.clone();
    let steps = scaled(LARGE_TRAIN_STEPS, scale);
    let pre = scaled(SMALL_PRETRAIN_STEPS, scale);
    let mut curves = Vec::new();
    // scratch baseline once
    let small = reg.model("bert_small")?.clone();
    let mut c = run_pair(rt, reg, &small, &large, &[Method::Scratch], steps, pre, out)?;
    curves.append(&mut c);
    for (src, label) in [("bert_small", "LiGO(Small)"), ("bert_base", "LiGO(Base)")] {
        let s = reg.model(src)?.clone();
        let mut cs = run_pair(
            rt, reg, &s, &large,
            &[Method::Ligo(super::common::ligo_scaled())],
            steps, pre, out,
        )?;
        cs[0].name = label.to_string();
        curves.append(&mut cs);
    }
    report(
        "fig2c", "BERT-Small/Base -> BERT-Large",
        &curves,
        &[("LiGO(Small)", 0.303), ("LiGO(Base)", 0.452)],
        false, out,
    )
}

/// Fig. 3(a,b): RoBERTa recipe (4x batch via accumulation, 4x LR).
pub fn fig3(rt: &Runtime, reg: &Registry, scale: f64, out: &Path) -> Result<()> {
    let small = reg.model("bert_small")?.clone();
    let large = reg.model("bert_base")?.clone();
    let corpus = Corpus::new(large.vocab, 0);
    let pre = scaled(SMALL_PRETRAIN_STEPS, scale);
    let steps = scaled(LARGE_TRAIN_STEPS / 2, scale); // 4x batch -> fewer steps
    let small_params = ensure_pretrained(rt, &small, &corpus, pre, out)?;
    let mut curves = Vec::new();
    for method in [Method::Scratch, Method::Operator("stackbert"), Method::Operator("aki"),
                   Method::Ligo(super::common::ligo_scaled())] {
        let (params, extra_flops, extra) =
            super::common::init_large(rt, &method, &small, &large, &small_params, &corpus)?;
        let tc = crate::config::TrainConfig::roberta(steps);
        let mut tr = Trainer::new(rt, &large, tc, params)?;
        tr.flops_offset = extra_flops;
        tr.extra = extra;
        let mut b = super::common::text_batches(&corpus, &large, 0x20BE);
        curves.push(tr.run(&method.label(), &mut b, steps)?);
    }
    report(
        "fig3", "RoBERTa-Small -> RoBERTa-Base (4x batch / 4x LR recipe)",
        &curves,
        &[("LiGO", 0.472)],
        false, out,
    )
}

/// Fig. 3(c): GPT2-Base -> GPT2-Medium (causal LM).
pub fn fig3c(rt: &Runtime, reg: &Registry, scale: f64, out: &Path) -> Result<()> {
    let small = reg.model("gpt_base")?.clone();
    let large = reg.model("gpt_medium")?.clone();
    let curves = run_pair(
        rt, reg, &small, &large,
        &[Method::Scratch, Method::Operator("stackbert"), Method::Operator("aki"),
          Method::Ligo(super::common::ligo_scaled())],
        scaled(LARGE_TRAIN_STEPS / 2, scale),
        scaled(SMALL_PRETRAIN_STEPS / 2, scale),
        out,
    )?;
    report(
        "fig3c", "GPT2-Base -> GPT2-Medium (log-ppl vs FLOPs)",
        &curves,
        &[("LiGO", 0.225)],
        false, out,
    )
}

/// Fig. 4: DeiT-S -> DeiT-B on the synthetic-vision ImageNet analog.
pub fn fig4(rt: &Runtime, reg: &Registry, scale: f64, out: &Path) -> Result<()> {
    let small = reg.model("vit_s")?.clone();
    let large = reg.model("vit_b")?.clone();
    let curves = run_pair(
        rt, reg, &small, &large,
        &standard_methods(),
        scaled(LARGE_TRAIN_STEPS, scale),
        scaled(SMALL_PRETRAIN_STEPS, scale),
        out,
    )?;
    report(
        "fig4", "DeiT-S -> DeiT-B (accuracy vs FLOPs / wall time)",
        &curves,
        &[("StackBERT", 0.238), ("MSLT", 0.367), ("KI", -0.112),
          ("bert2BERT", 0.408), ("LiGO", 0.554)],
        true, out,
    )
}

/// Fig. 5: LiGO combined with layer dropping, token dropping, staged training.
pub fn fig5(rt: &Runtime, reg: &Registry, scale: f64, out: &Path) -> Result<()> {
    let small = reg.model("bert_small")?.clone();
    let large = reg.model("bert_base")?.clone();
    let corpus = Corpus::new(large.vocab, 0);
    let steps = scaled(LARGE_TRAIN_STEPS, scale);
    let pre = scaled(SMALL_PRETRAIN_STEPS, scale);
    let small_params = ensure_pretrained(rt, &small, &corpus, pre, out)?;

    let mut curves = Vec::new();
    // scratch + plain LiGO references
    let mut base = run_pair(
        rt, reg, &small, &large,
        &[Method::Scratch, Method::Ligo(super::common::ligo_scaled())],
        steps, pre, out,
    )?;
    curves.append(&mut base);

    // (a/b) LiGO + layer dropping + token dropping via the gated artifact
    for (label, max_drop, tok_drop) in [
        ("LiGO+LayerDrop", MAX_LAYER_DROP, 0.0f32),
        ("LiGO+TokenDrop", 0.0, TOKEN_DROP),
    ] {
        let (params, extra_flops, _) = super::common::init_large(
            rt, &Method::Ligo(super::common::ligo_scaled()), &small, &large, &small_params, &corpus,
        )?;
        let grad = rt.load(&format!("grad_gated_{}", large.name))?;
        let fwd = rt.load(&format!("fwd_{}", large.name))?;
        let tc = recipe_for(&large, steps);
        let mut params = params;
        let mut opt = AdamW::from_train_config(&params, &tc);
        let mut curve = Curve::new(label);
        let mut flops_spent = extra_flops;
        let timer = crate::util::timer::Timer::new();
        for step in 0..steps {
            let p_drop = if max_drop > 0.0 { layer_drop_p(step, steps, max_drop) } else { 0.0 };
            let mut rng = Rng::new(0xF1A + step as u64);
            let batch = gated_batch(&corpus, &large, &mut rng, p_drop, tok_drop);
            let outp = grad.run(&[("params", &params), ("batch", &batch)])?;
            let grads = outp.groups.get("grads").expect("grads");
            opt.step(&mut params, grads, tc.lr_at(step));
            flops_spent += strategy_flops(&large, step, steps, max_drop, tok_drop);
            if (step + 1) % tc.eval_every == 0 || step + 1 == steps || step == 0 {
                let mut eb = {
                    let c = corpus.clone();
                    let l = large.clone();
                    move |i: usize| mlm_batch(&c, &l, &mut Rng::new(0xEEAA_0000 + i as u64))
                };
                let (loss, m) = eval_store(&fwd, &params, &mut eb, 4)?;
                curve.push(step + 1, flops_spent, timer.elapsed(), loss, m);
            }
        }
        curves.push(curve);
    }

    // (c) staged training: train small for 25% of the budget, grow, continue
    for (label, method) in [
        ("LiGO+ST", Method::Ligo(super::common::ligo_scaled())),
        ("bert2BERT+ST", Method::Operator("aki")),
    ] {
        let stage1 = steps / 4;
        let tc1 = recipe_for(&small, stage1);
        let mut tr1 = Trainer::new(rt, &small, tc1, small_params.clone())?;
        let mut b1 = super::common::text_batches(&corpus, &small, 0x57A6);
        let c1 = tr1.run("stage1", &mut b1, stage1)?;
        let stage1_flops = *c1.flops.last().unwrap();
        let (params, extra_flops, _) =
            super::common::init_large(rt, &method, &small, &large, &tr1.params, &corpus)?;
        let tc2 = recipe_for(&large, steps);
        let mut tr2 = Trainer::new(rt, &large, tc2, params)?;
        tr2.flops_offset = stage1_flops + extra_flops;
        let mut b2 = super::common::text_batches(&corpus, &large, 0x57A7);
        let mut curve = tr2.run(label, &mut b2, steps - stage1)?;
        curve.name = label.to_string();
        curves.push(curve);
    }

    report(
        "fig5", "LiGO + orthogonal efficiency strategies (BERT-Base)",
        &curves,
        &[("LiGO", 0.447), ("LiGO+LayerDrop", 0.447 + 0.047),
          ("LiGO+TokenDrop", 0.447 + 0.074), ("LiGO+ST", 0.447 + 0.082)],
        false, out,
    )
}

/// Fig. 6: depth-only and width-only ablations.
pub fn fig6(rt: &Runtime, reg: &Registry, scale: f64, out: &Path) -> Result<()> {
    let steps = scaled(LARGE_TRAIN_STEPS, scale);
    let pre = scaled(SMALL_PRETRAIN_STEPS, scale);
    // (a) depth-only: bert(3,72) -> bert(6,72)
    let src_d = reg.model("bert_d3w72")?.clone();
    let tgt = reg.model("bert_base")?.clone();
    let mut depth_curves = run_pair(
        rt, reg, &src_d, &tgt,
        &[Method::Scratch, Method::Operator("stackbert"), Method::Operator("interpolation"),
          Method::Operator("mslt"), Method::Ligo(super::common::ligo_scaled())],
        steps, pre, out,
    )?;
    for c in &mut depth_curves {
        c.name = format!("depth:{}", c.name);
    }
    // (b) width-only: bert(6,48) -> bert(6,72)
    let src_w = reg.model("bert_d6w48")?.clone();
    let mut width_curves = run_pair(
        rt, reg, &src_w, &tgt,
        &[Method::Scratch, Method::Operator("direct_copy"), Method::Operator("net2net"),
          Method::Operator("aki"), Method::Ligo(super::common::ligo_scaled())],
        steps, pre, out,
    )?;
    for c in &mut width_curves {
        c.name = format!("width:{}", c.name);
    }
    let mut curves = depth_curves;
    curves.extend(width_curves);
    // report needs a "Scratch" curve: rename the depth one for the summary
    let mut summary = curves.clone();
    if let Some(c) = summary.iter_mut().find(|c| c.name == "depth:Scratch") {
        c.name = "Scratch".into();
    }
    report(
        "fig6", "Depth-only (a) and width-only (b) growth ablations",
        &summary,
        &[("depth:LiGO", 0.517), ("width:LiGO", 0.416)],
        false, out,
    )
}

/// Fig. 7 (Appendix C.1): reuse a small model trained for only a few steps.
pub fn fig7(rt: &Runtime, reg: &Registry, scale: f64, out: &Path) -> Result<()> {
    let small = reg.model("bert_small")?.clone();
    let large = reg.model("bert_base")?.clone();
    // "50k of 220k steps" -> ~23% of the usual source pretraining
    let short_pre = scaled(SMALL_PRETRAIN_STEPS / 4, scale);
    let curves = run_pair(
        rt, reg, &small, &large,
        &[Method::Scratch, Method::Ligo(super::common::ligo_scaled())],
        scaled(LARGE_TRAIN_STEPS, scale),
        short_pre,
        out,
    )?;
    report(
        "fig7", "LiGO from a briefly-trained (quarter-budget) BERT-Small",
        &curves,
        &[("LiGO", 0.352)],
        false, out,
    )
}

/// Fig. 8 (Appendix C.2): CaiT-XS -> CaiT-S.
pub fn fig8(rt: &Runtime, reg: &Registry, scale: f64, out: &Path) -> Result<()> {
    let small = reg.model("cait_xs")?.clone();
    let large = reg.model("cait_s")?.clone();
    let curves = run_pair(
        rt, reg, &small, &large,
        &[Method::Scratch, Method::Operator("aki"), Method::Ligo(super::common::ligo_scaled())],
        scaled(LARGE_TRAIN_STEPS, scale),
        scaled(SMALL_PRETRAIN_STEPS, scale),
        out,
    )?;
    report(
        "fig8", "CaiT-XS -> CaiT-S (accuracy vs FLOPs / wall)",
        &curves,
        &[("LiGO", 0.526)],
        true, out,
    )?;
    log_info!("fig8 done");
    Ok(())
}
