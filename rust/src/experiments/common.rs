//! Shared experiment machinery: pretrained-checkpoint cache, the method
//! zoo (scratch / growth operators / KI / LiGO), figure runner, and
//! paper-style report printing.

use std::path::{Path, PathBuf};

use crate::config::{ModelConfig, Registry, TrainConfig};
use crate::coordinator::growth_manager::LigoOptions;
use crate::coordinator::metrics::{savings, write_report, Curve};
use crate::coordinator::trainer::{Batches, Trainer};
use crate::data::batches::{lm_batch, mlm_batch};
use crate::data::corpus::Corpus;
use crate::data::vision::VisionTask;
use crate::error::Result;
use crate::growth;
use crate::runtime::Runtime;
use crate::tensor::{io, store::Store};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::{log_info, log_warn};

/// Default pretraining steps for source models (at scale=1.0).
pub const SMALL_PRETRAIN_STEPS: usize = 300;
/// Default large-model training steps (at scale=1.0).
pub const LARGE_TRAIN_STEPS: usize = 600;

/// A method column in a figure.
#[derive(Debug, Clone)]
pub enum Method {
    Scratch,
    /// A non-learned growth operator from the zoo by name.
    Operator(&'static str),
    /// Knowledge inheritance: train the large model with distillation from
    /// the small one (extra compute, as the paper finds: negative savings).
    Ki,
    /// The paper's contribution.
    Ligo(LigoOptions),
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::Scratch => "Scratch".into(),
            Method::Operator(n) => match *n {
                "stackbert" => "StackBERT".into(),
                "mslt" => "MSLT".into(),
                "aki" => "bert2BERT".into(),
                "net2net" => "Net2Net".into(),
                "interpolation" => "InterBERT".into(),
                "direct_copy" => "DirectCopy".into(),
                other => other.into(),
            },
            Method::Ki => "KI".into(),
            Method::Ligo(_) => "LiGO".into(),
        }
    }
}

/// LiGO options rescaled for this substrate's step budget: the paper's 100
/// M-steps are 0.025% of its 400k-step training budget; at our ~600-step
/// scale, 25 M-steps (~5% overhead) is the comparable operating point
/// (Table 3 reproduces the full step-count/savings tradeoff).
pub fn ligo_scaled() -> LigoOptions {
    LigoOptions { steps: 25, ..Default::default() }
}

/// The paper's Fig. 2/3 method set.
pub fn standard_methods() -> Vec<Method> {
    vec![
        Method::Scratch,
        Method::Operator("stackbert"),
        Method::Operator("mslt"),
        Method::Ki,
        Method::Operator("aki"),
        Method::Ligo(ligo_scaled()),
    ]
}

/// Batch generators for a text config (train/eval streams disjoint by seed).
pub fn text_batches(corpus: &Corpus, cfg: &ModelConfig, seed: u64) -> Batches {
    let is_lm = cfg.family == "gpt";
    let c1 = corpus.clone();
    let cfg1 = cfg.clone();
    let c2 = corpus.clone();
    let cfg2 = cfg.clone();
    // a shared source (pure in the global index) so LIGO_WORKERS can shard it
    Batches::shared(
        move |step| {
            let mut rng = Rng::new(seed ^ (step as u64).wrapping_mul(0x9E37_79B9));
            if is_lm { lm_batch(&c1, &cfg1, &mut rng) } else { mlm_batch(&c1, &cfg1, &mut rng) }
        },
        move |i| {
            let mut rng = Rng::new(0xEEAA_0000 + i as u64);
            if is_lm { lm_batch(&c2, &cfg2, &mut rng) } else { mlm_batch(&c2, &cfg2, &mut rng) }
        },
    )
}

/// Batch generators for a vision config.
pub fn vision_batches(task: &VisionTask, cfg: &ModelConfig, seed: u64) -> Batches {
    let t1 = task.clone();
    let cfg1 = cfg.clone();
    let t2 = task.clone();
    let cfg2 = cfg.clone();
    Batches::shared(
        move |step| {
            t1.batch(&cfg1, &mut Rng::new(seed ^ (step as u64).wrapping_mul(0x9E37_79B9)))
        },
        move |i| t2.batch(&cfg2, &mut Rng::new(0xEEAA_1000 + i as u64)),
    )
}

fn batches_for(cfg: &ModelConfig, corpus: &Corpus, seed: u64) -> Batches {
    if cfg.is_vision() {
        vision_batches(&VisionTask::pretrain(), cfg, seed)
    } else {
        text_batches(corpus, cfg, seed)
    }
}

/// Recipe appropriate for a config's family.
pub fn recipe_for(cfg: &ModelConfig, steps: usize) -> TrainConfig {
    match cfg.family.as_str() {
        "gpt" => TrainConfig::gpt(steps),
        "vit" | "cait" => TrainConfig::vision(steps),
        _ => TrainConfig::bert(steps),
    }
}

fn ckpt_path(out_dir: &Path, cfg: &ModelConfig, steps: usize) -> PathBuf {
    out_dir.join("ckpt").join(format!("{}_{}steps.lgck", cfg.name, steps))
}

/// The provenance stamp saved alongside a cached pretrain checkpoint and
/// required to match before the cache is reused.
fn pretrain_meta(cfg: &ModelConfig, steps: usize) -> Json {
    Json::obj(vec![("config", cfg.to_json()), ("steps", Json::Num(steps as f64))])
}

/// Pretrain (or load a cached checkpoint of) a source model. A cached file
/// is reused only if it passes the LGCK integrity checks **and** its meta
/// stamp matches this (config, steps) request — a corrupt, truncated, or
/// stale checkpoint (e.g. after a preset change) is re-pretrained, never
/// silently loaded.
pub fn ensure_pretrained(
    rt: &Runtime,
    cfg: &ModelConfig,
    corpus: &Corpus,
    steps: usize,
    out_dir: &Path,
) -> Result<Store> {
    let path = ckpt_path(out_dir, cfg, steps);
    let want = pretrain_meta(cfg, steps).to_string();
    if path.exists() {
        match io::load_with_meta(&path) {
            Ok((params, Some(meta))) if meta.to_string() == want => {
                log_info!("loading cached checkpoint {path:?}");
                return Ok(params);
            }
            Ok(_) => {
                log_warn!("cached checkpoint {path:?} has a stale or missing provenance stamp; re-pretraining");
            }
            Err(e) => {
                log_warn!("cached checkpoint {path:?} failed verification ({e}); re-pretraining");
            }
        }
    }
    log_info!("pretraining {} for {} steps", cfg.name, steps);
    let params = Trainer::scratch_params(rt, cfg, 0)?;
    let tc = recipe_for(cfg, steps);
    let mut tr = Trainer::new(rt, cfg, tc, params)?;
    let mut b = batches_for(cfg, corpus, 0x50A0);
    tr.run(&format!("pretrain_{}", cfg.name), &mut b, steps)?;
    io::save_with_meta(&tr.params, &path, &pretrain_meta(cfg, steps))?;
    Ok(tr.params)
}

/// Initialize the large model per `method`; returns (params, extra_flops,
/// extra KD bindings for training).
pub fn init_large(
    rt: &Runtime,
    method: &Method,
    small: &ModelConfig,
    large: &ModelConfig,
    small_params: &Store,
    corpus: &Corpus,
) -> Result<(Store, f64, Vec<(String, Store)>)> {
    match method {
        Method::Scratch => Ok((Trainer::scratch_params(rt, large, 1)?, 0.0, vec![])),
        Method::Operator(name) => {
            let op = growth::by_name(name)?;
            Ok((growth::grow_params(op.as_ref(), small_params, small, large)?, 0.0, vec![]))
        }
        Method::Ki => Ok((
            Trainer::scratch_params(rt, large, 1)?,
            0.0,
            vec![("teacher".to_string(), small_params.clone())],
        )),
        Method::Ligo(opts) => {
            let mut mk = {
                let c = corpus.clone();
                let l = large.clone();
                let is_vision = large.is_vision();
                move |s: usize| {
                    let mut rng = Rng::new(0x11C0_0000 + s as u64);
                    if is_vision {
                        VisionTask::pretrain().batch(&l, &mut rng)
                    } else if l.family == "gpt" {
                        lm_batch(&c, &l, &mut rng)
                    } else {
                        mlm_batch(&c, &l, &mut rng)
                    }
                }
            };
            let ctx = growth::GrowthContext::new(small_params, small, large)
                .with_runtime(rt)
                .with_batches(&mut mk)
                .with_opts(opts.clone());
            let grown = growth::by_name("ligo")?.grow(ctx)?;
            log_info!(
                "LiGO grew {}->{} in {:.1}s, M-loss {:.3} ({}), +{:.2e} FLOPs [{}]",
                small.name,
                large.name,
                grown.metrics.wall_s,
                grown.metrics.final_m_loss,
                grown.objective,
                grown.metrics.extra_flops,
                grown.route_summary()
            );
            Ok((grown.params, grown.metrics.extra_flops, vec![]))
        }
    }
}

/// Train `methods` on the (small -> large) pair and return their curves.
pub fn run_pair(
    rt: &Runtime,
    _reg: &Registry,
    small: &ModelConfig,
    large: &ModelConfig,
    methods: &[Method],
    steps: usize,
    pretrain_steps: usize,
    out_dir: &Path,
) -> Result<Vec<Curve>> {
    let corpus = Corpus::new(large.vocab.max(512), 0);
    let small_params = ensure_pretrained(rt, small, &corpus, pretrain_steps, out_dir)?;
    let mut curves = Vec::new();
    for method in methods {
        let label = method.label();
        log_info!("=== method {} on {}->{} ({} steps)", label, small.name, large.name, steps);
        let (params, extra_flops, extra) =
            init_large(rt, method, small, large, &small_params, &corpus)?;
        let tc = recipe_for(large, steps);
        let mut tr = if matches!(method, Method::Ki) {
            let grad = format!("kd_grad_{}__{}", small.name, large.name);
            let fwd = format!("fwd_{}", large.name);
            let mut t = Trainer::with_artifacts(rt, &grad, &fwd, large, tc, params)?;
            // KD costs a teacher forward on top of the student step
            t.flops_per_microbatch = crate::coordinator::flops::train_step_flops(large)
                + crate::coordinator::flops::forward_flops(small);
            t
        } else {
            Trainer::new(rt, large, tc, params)?
        };
        tr.flops_offset = extra_flops;
        tr.extra = extra;
        let mut b = batches_for(large, &corpus, 0x7A1A);
        let curve = tr.run(&label, &mut b, steps)?;
        curves.push(curve);
    }
    Ok(curves)
}

/// Print the paper-style savings table and write the report files.
pub fn report(
    experiment: &str,
    title: &str,
    curves: &[Curve],
    paper_savings: &[(&str, f64)],
    higher_better: bool,
    out_dir: &Path,
) -> Result<()> {
    println!("\n================================================================");
    println!("{experiment}: {title}");
    println!("================================================================");
    let scratch = curves.iter().find(|c| c.name == "Scratch");
    println!(
        "{:<12} {:>12} {:>14} {:>16} {:>16}",
        "method", "final", "savings(FLOPs)", "savings(wall)", "paper(FLOPs)"
    );
    for c in curves {
        let (s_f, s_w) = match scratch {
            Some(s) if c.name != "Scratch" => (
                savings(s, c, false, higher_better),
                savings(s, c, true, higher_better),
            ),
            _ => (None, None),
        };
        let paper = paper_savings
            .iter()
            .find(|(n, _)| *n == c.name)
            .map(|(_, v)| format!("{:+.1}%", v * 100.0))
            .unwrap_or_else(|| "-".into());
        let fin = if higher_better {
            c.final_metric().unwrap_or(f32::NAN)
        } else {
            c.final_loss()
        };
        println!(
            "{:<12} {:>12.4} {:>14} {:>16} {:>16}",
            c.name,
            fin,
            s_f.map(|v| format!("{:+.1}%", v * 100.0)).unwrap_or_else(|| "-".into()),
            s_w.map(|v| format!("{:+.1}%", v * 100.0)).unwrap_or_else(|| "-".into()),
            paper,
        );
    }
    write_report(out_dir, experiment, curves)?;
    println!("curves written to {}", out_dir.display());
    Ok(())
}

/// Scale a step count, keeping a sane floor.
pub fn scaled(steps: usize, scale: f64) -> usize {
    ((steps as f64 * scale) as usize).max(20)
}
