//! Table reproductions: downstream transfer (Tables 1/2/5/6) and the LiGO
//! step-count ablation (Table 3).

use std::path::Path;

use crate::error::Result;

use crate::config::{ModelConfig, Registry, TrainConfig};
use crate::coordinator::growth_manager::LigoOptions;
use crate::coordinator::flops;
use crate::coordinator::metrics::savings;
use crate::data::corpus::Corpus;
use crate::data::downstream::{Probe, SpanProbe, GLUE_SUITE};
use crate::data::vision::VisionTask;
use crate::eval::finetune::{finetune_adapters, finetune_probe, finetune_span};
use crate::runtime::Runtime;
use crate::tensor::{io, store::Store};
use crate::util::rng::Rng;
use crate::log_info;

use super::common::{
    ensure_pretrained, init_large, recipe_for, run_pair, scaled, standard_methods, Method,
    LARGE_TRAIN_STEPS, SMALL_PRETRAIN_STEPS,
};

const FT_STEPS: usize = 60;

/// Train (and cache) the large model under `method`, returning final params.
fn train_large_cached(
    rt: &Runtime,
    method: &Method,
    small: &ModelConfig,
    large: &ModelConfig,
    steps: usize,
    pre: usize,
    out: &Path,
) -> Result<Store> {
    let path = out
        .join("ckpt")
        .join(format!("{}_{}_{steps}steps.lgck", large.name, method.label()));
    if path.exists() {
        return io::load(&path);
    }
    let corpus = Corpus::new(large.vocab.max(512), 0);
    let small_params = ensure_pretrained(rt, small, &corpus, pre, out)?;
    let (params, extra_flops, extra) =
        init_large(rt, method, small, large, &small_params, &corpus)?;
    let tc = recipe_for(large, steps);
    let mut tr = if matches!(method, Method::Ki) {
        let grad = format!("kd_grad_{}__{}", small.name, large.name);
        let fwd = format!("fwd_{}", large.name);
        crate::coordinator::trainer::Trainer::with_artifacts(rt, &grad, &fwd, large, tc, params)?
    } else {
        crate::coordinator::trainer::Trainer::new(rt, large, tc, params)?
    };
    tr.flops_offset = extra_flops;
    tr.extra = extra;
    let mut b = if large.is_vision() {
        super::common::vision_batches(&VisionTask::pretrain(), large, 0x7A1A)
    } else {
        super::common::text_batches(&corpus, large, 0x7A1A)
    };
    tr.run(&method.label(), &mut b, steps)?;
    io::save(&tr.params, &path)?;
    Ok(tr.params)
}

fn probe_batchers(
    probe: Probe,
    cfg: &ModelConfig,
) -> (Box<dyn FnMut(usize) -> Store>, Box<dyn FnMut(usize) -> Store>) {
    let p1 = probe.clone();
    let c1 = cfg.clone();
    let p2 = probe;
    let c2 = cfg.clone();
    (
        Box::new(move |s| p1.batch(&c1, &mut Rng::new(0xF7 + s as u64))),
        Box::new(move |s| p2.batch(&c2, &mut Rng::new(0xE7A1_0000 + s as u64))),
    )
}

/// GLUE + SQuAD rows for one pretrained bert_base body.
fn glue_squad_row(
    rt: &Runtime,
    reg: &Registry,
    body: &Store,
    scale: f64,
) -> Result<(Vec<f32>, f32, Vec<f32>)> {
    let probe_cfg = reg.model("probe_bert_base")?.clone();
    let corpus = Corpus::new(512, 0);
    let tc = TrainConfig::finetune(scaled(FT_STEPS, scale));
    let mut accs = Vec::new();
    for (kind, name) in GLUE_SUITE {
        let (mut trb, mut evb) = probe_batchers(Probe::new(kind, corpus.clone()), &probe_cfg);
        let res = finetune_probe(rt, "probe_bert_base", name, body, &tc, &mut trb, &mut evb)?;
        accs.push(res.accuracy);
    }
    let avg = accs.iter().sum::<f32>() / accs.len() as f32;
    // SQuAD analogs
    let mut squad = Vec::new();
    for (probe, _name) in [
        (SpanProbe::v1(corpus.clone()), "SQuADv1.1"),
        (SpanProbe::v2(corpus.clone()), "SQuADv2.0"),
    ] {
        let cfg = probe_cfg.clone();
        let p1 = probe.clone();
        let c1 = cfg.clone();
        let mut trb = move |s: usize| p1.batch(&c1, &mut Rng::new(0xF8 + s as u64));
        let p2 = probe;
        let mut evb = move |s: usize| p2.batch(&cfg, &mut Rng::new(0xE7A2_0000 + s as u64));
        let res = finetune_span(rt, "span", body, &tc, &mut trb, &mut evb)?;
        squad.push(res.accuracy);
    }
    Ok((accs, avg, squad))
}

/// Table 1: downstream GLUE/SQuAD transfer of grown BERT-Base models.
pub fn table1(rt: &Runtime, reg: &Registry, scale: f64, out: &Path) -> Result<()> {
    let small = reg.model("bert_small")?.clone();
    let large = reg.model("bert_base")?.clone();
    let steps = scaled(LARGE_TRAIN_STEPS, scale);
    let pre = scaled(SMALL_PRETRAIN_STEPS, scale);
    println!("\n================================================================");
    println!("table1: GLUE + SQuAD transfer of BERT-Base by init method");
    println!("================================================================");
    let tasks: Vec<&str> = GLUE_SUITE.iter().map(|(_, n)| *n).collect();
    println!(
        "{:<12} {}  {:>8} {:>9} {:>9}",
        "method",
        tasks.iter().map(|t| format!("{t:>7}")).collect::<String>(),
        "AvgGLUE", "SQuAD1", "SQuAD2"
    );
    for method in standard_methods() {
        let body = train_large_cached(rt, &method, &small, &large, steps, pre, out)?;
        let (accs, avg, squad) = glue_squad_row(rt, reg, &body, scale)?;
        println!(
            "{:<12} {}  {:>8.2} {:>9.2} {:>9.2}",
            method.label(),
            accs.iter().map(|a| format!("{:>7.2}", a * 100.0)).collect::<String>(),
            avg * 100.0,
            squad[0] * 100.0,
            squad[1] * 100.0
        );
    }
    println!("(paper: LiGO matches Scratch within noise at 44.7% FLOPs savings)");
    Ok(())
}

/// Table 2: DeiT-B transfer to the 5 vision probe tasks.
pub fn table2(rt: &Runtime, reg: &Registry, scale: f64, out: &Path) -> Result<()> {
    let small = reg.model("vit_s")?.clone();
    let large = reg.model("vit_b")?.clone();
    let probe_cfg = reg.model("probe_vit_b")?.clone();
    let steps = scaled(LARGE_TRAIN_STEPS, scale);
    let pre = scaled(SMALL_PRETRAIN_STEPS, scale);
    let task_names = ["cifar10", "cifar100", "flowers", "cars", "chestxray"];
    println!("\n================================================================");
    println!("table2: DeiT-B transfer by init method (accuracy %)");
    println!("================================================================");
    println!(
        "{:<12} {}",
        "method",
        task_names.iter().map(|t| format!("{t:>11}")).collect::<String>()
    );
    let tc = TrainConfig::finetune(scaled(FT_STEPS, scale));
    for method in standard_methods() {
        let body = train_large_cached(rt, &method, &small, &large, steps, pre, out)?;
        let mut row = String::new();
        for t in task_names {
            let task = VisionTask::transfer(t);
            let t1 = task.clone();
            let c1 = probe_cfg.clone();
            let mut trb = move |s: usize| t1.batch(&c1, &mut Rng::new(0xF9 + s as u64));
            let t2 = task;
            let c2 = probe_cfg.clone();
            let mut evb = move |s: usize| t2.batch(&c2, &mut Rng::new(0xE7A3_0000 + s as u64));
            let res = finetune_probe(rt, "probe_vit_b", t, &body, &tc, &mut trb, &mut evb)?;
            row.push_str(&format!("{:>11.2}", res.accuracy * 100.0));
        }
        println!("{:<12} {}", method.label(), row);
    }
    println!("(paper: LiGO transfers on par with Scratch at 55.4% FLOPs savings)");
    Ok(())
}

/// Table 3: number of LiGO growing steps vs extra FLOPs and savings.
pub fn table3(rt: &Runtime, reg: &Registry, scale: f64, out: &Path) -> Result<()> {
    let small = reg.model("bert_small")?.clone();
    let large = reg.model("bert_base")?.clone();
    let steps = scaled(LARGE_TRAIN_STEPS, scale);
    let pre = scaled(SMALL_PRETRAIN_STEPS, scale);
    // paper sweeps {100, 500, 1000, 10000}; we sweep the scaled analog
    let m_steps = [25usize, 100, 250, 1000];
    let mut curves = run_pair(rt, reg, &small, &large, &[Method::Scratch], steps, pre, out)?;
    for ms in m_steps {
        let mut c = run_pair(
            rt, reg, &small, &large,
            &[Method::Ligo(LigoOptions { steps: ms, ..Default::default() })],
            steps, pre, out,
        )?;
        c[0].name = format!("LiGO@{ms}");
        curves.append(&mut c);
    }
    println!("\n================================================================");
    println!("table3: effect of LiGO M-learning step count (paper Table 3)");
    println!("================================================================");
    println!("{:<12} {:>14} {:>14}", "# M-steps", "+FLOPs", "savings(FLOPs)");
    let scratch = curves[0].clone();
    for c in &curves[1..] {
        let ms: usize = c.name.trim_start_matches("LiGO@").parse().unwrap_or(0);
        let extra = ms as f64 * flops::ligo_step_flops(&small, &large);
        let s = savings(&scratch, c, false, false)
            .map(|v| format!("{:+.1}%", v * 100.0))
            .unwrap_or_else(|| "-".into());
        println!("{:<12} {:>14.3e} {:>14}", ms, extra, s);
    }
    println!("(paper: 100 -> 44.7%, 500 -> 44.5%, 1000 -> 44.2%, 10000 -> 38.9%)");
    crate::coordinator::metrics::write_report(out, "table3", &curves)?;
    Ok(())
}

/// Table 5: fine-tuning the LiGO-initialized model WITHOUT further
/// pretraining, vs BERT-Small and fully-trained baselines.
pub fn table5(rt: &Runtime, reg: &Registry, scale: f64, out: &Path) -> Result<()> {
    let small = reg.model("bert_small")?.clone();
    let large = reg.model("bert_base")?.clone();
    let steps = scaled(LARGE_TRAIN_STEPS, scale);
    let pre = scaled(SMALL_PRETRAIN_STEPS, scale);
    let corpus = Corpus::new(512, 0);
    let small_params = ensure_pretrained(rt, &small, &corpus, pre, out)?;

    // row 1: BERT-Small (scratch-pretrained) fine-tuned directly
    // row 2: BERT-Base from LiGO init only (no further pretraining)
    // row 3: BERT-Base LiGO init + pretraining
    // row 4: BERT-Base scratch
    let (ligo_init, _, _) = init_large(
        rt, &Method::Ligo(super::common::ligo_scaled()), &small, &large, &small_params, &corpus,
    )?;
    let ligo_trained =
        train_large_cached(
            rt,
            &Method::Ligo(super::common::ligo_scaled()),
            &small,
            &large,
            steps,
            pre,
            out,
        )?;
    let scratch_trained =
        train_large_cached(rt, &Method::Scratch, &small, &large, steps, pre, out)?;

    let probe_small = reg.model("probe_bert_small")?.clone();
    let probe_base = reg.model("probe_bert_base")?.clone();
    let tc = TrainConfig::finetune(scaled(FT_STEPS, scale));
    println!("\n================================================================");
    println!("table5: task fine-tuning with LiGO init, no further pretraining");
    println!("================================================================");
    let tasks: Vec<&str> = GLUE_SUITE.iter().map(|(_, n)| *n).collect();
    println!(
        "{:<28} {}  {:>8}",
        "model",
        tasks.iter().map(|t| format!("{t:>7}")).collect::<String>(),
        "Average"
    );
    let rows: Vec<(&str, &Store, &ModelConfig, &str)> = vec![
        ("BERT-Small (Scratch)", &small_params, &probe_small, "probe_bert_small"),
        ("BERT-Base (LiGO Init)", &ligo_init, &probe_base, "probe_bert_base"),
        ("BERT-Base (LiGO Init+Pretrain)", &ligo_trained, &probe_base, "probe_bert_base"),
        ("BERT-Base (Scratch)", &scratch_trained, &probe_base, "probe_bert_base"),
    ];
    for (label, body, pcfg, artifact) in rows {
        let mut accs = Vec::new();
        for (kind, name) in GLUE_SUITE {
            let (mut trb, mut evb) = probe_batchers(Probe::new(kind, corpus.clone()), pcfg);
            let res = finetune_probe(rt, artifact, name, body, &tc, &mut trb, &mut evb)?;
            accs.push(res.accuracy);
        }
        let avg = accs.iter().sum::<f32>() / accs.len() as f32;
        println!(
            "{:<28} {}  {:>8.2}",
            label,
            accs.iter().map(|a| format!("{:>7.2}", a * 100.0)).collect::<String>(),
            avg * 100.0
        );
    }
    println!("(paper: LiGO-Init beats BERT-Small avg 81.04 vs 80.38, below full pretrain 82.57)");
    Ok(())
}

/// Table 6: adapter-based fine-tuning (AdapterFusion analog).
pub fn table6(rt: &Runtime, reg: &Registry, scale: f64, out: &Path) -> Result<()> {
    let small = reg.model("bert_small")?.clone();
    let large = reg.model("bert_base")?.clone();
    let steps = scaled(LARGE_TRAIN_STEPS, scale);
    let pre = scaled(SMALL_PRETRAIN_STEPS, scale);
    let corpus = Corpus::new(512, 0);
    let probe_cfg = reg.model("probe_bert_base")?.clone();
    let tc = TrainConfig::finetune(scaled(FT_STEPS * 2, scale)); // adapters need more steps
    println!("\n================================================================");
    println!("table6: adapter-only fine-tuning (AdapterFusion analog)");
    println!("================================================================");
    let tasks: Vec<&str> = GLUE_SUITE.iter().map(|(_, n)| *n).collect();
    println!(
        "{:<12} {}  {:>8}",
        "method",
        tasks.iter().map(|t| format!("{t:>7}")).collect::<String>(),
        "Average"
    );
    for method in [Method::Scratch, Method::Operator("stackbert"), Method::Operator("aki"),
                   Method::Ligo(super::common::ligo_scaled())] {
        let body = train_large_cached(rt, &method, &small, &large, steps, pre, out)?;
        let mut accs = Vec::new();
        for (kind, name) in GLUE_SUITE {
            let (mut trb, mut evb) = probe_batchers(Probe::new(kind, corpus.clone()), &probe_cfg);
            let res = finetune_adapters(rt, name, &body, &tc, &mut trb, &mut evb)?;
            accs.push(res.accuracy);
        }
        let avg = accs.iter().sum::<f32>() / accs.len() as f32;
        println!(
            "{:<12} {}  {:>8.2}",
            method.label(),
            accs.iter().map(|a| format!("{:>7.2}", a * 100.0)).collect::<String>(),
            avg * 100.0
        );
    }
    log_info!("table6 done");
    println!("(paper: LiGO 82.88 avg vs Scratch 82.51 under adapter tuning)");
    Ok(())
}
