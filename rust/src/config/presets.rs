//! Model presets, loaded from `artifacts/configs.json` (written by aot.py
//! from python/compile/configs.py — the single source of truth).

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Context, Error, Result};
use crate::util::json::Json;

/// Mirror of python `ModelConfig`.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub family: String,
    pub layers: usize,
    pub dim: usize,
    pub heads: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    pub img: usize,
    pub patch: usize,
    pub channels: usize,
    pub n_classes: usize,
    pub cls_layers: usize,
    pub ffn_mult: usize,
}

impl ModelConfig {
    pub fn ffn(&self) -> usize {
        self.ffn_mult * self.dim
    }

    /// Sequence length seen by the transformer body.
    pub fn tokens(&self) -> usize {
        if self.family == "vit" || self.family == "cait" {
            let n = (self.img / self.patch) * (self.img / self.patch);
            n + usize::from(self.family == "vit")
        } else {
            self.seq
        }
    }

    pub fn is_vision(&self) -> bool {
        self.family == "vit" || self.family == "cait"
    }

    /// Tokens processed per batch (for FLOPs/throughput accounting).
    pub fn tokens_per_batch(&self) -> usize {
        self.batch * self.tokens()
    }

    fn from_json(j: &Json) -> Result<ModelConfig> {
        let s = |k: &str| -> Result<String> {
            Ok(j.get(k).and_then(Json::as_str).context(k.to_string())?.to_string())
        };
        let u = |k: &str| -> usize { j.get(k).and_then(Json::as_usize).unwrap_or(0) };
        Ok(ModelConfig {
            name: s("name")?,
            family: s("family")?,
            layers: u("layers"),
            dim: u("dim"),
            heads: u("heads"),
            vocab: u("vocab"),
            seq: u("seq"),
            batch: u("batch").max(1),
            img: u("img"),
            patch: u("patch"),
            channels: u("channels").max(1),
            n_classes: u("n_classes"),
            cls_layers: u("cls_layers"),
            ffn_mult: u("ffn_mult").max(1),
        })
    }
}

/// The preset registry plus the LiGO growth pairs.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    pub models: BTreeMap<String, ModelConfig>,
    pub pairs: Vec<(String, String)>,
    pub kd_pairs: Vec<(String, String)>,
    pub param_counts: BTreeMap<String, usize>,
}

impl Registry {
    pub fn load(artifacts: &Path) -> Result<Registry> {
        let path = artifacts.join("configs.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(Error::msg)?;
        let mut models = BTreeMap::new();
        for (name, mj) in j.get("models").and_then(Json::as_obj).context("models")? {
            models.insert(name.clone(), ModelConfig::from_json(mj)?);
        }
        let pairs = j
            .get("pairs")
            .and_then(Json::as_arr)
            .context("pairs")?
            .iter()
            .filter_map(|p| {
                let a = p.as_arr()?;
                Some((a[0].as_str()?.to_string(), a[1].as_str()?.to_string()))
            })
            .collect();
        let kd_pairs = j
            .get("kd_pairs")
            .and_then(Json::as_arr)
            .map(|arr| {
                arr.iter()
                    .filter_map(|p| {
                        let a = p.as_arr()?;
                        Some((a[0].as_str()?.to_string(), a[1].as_str()?.to_string()))
                    })
                    .collect()
            })
            .unwrap_or_default();
        let param_counts = j
            .get("param_counts")
            .and_then(Json::as_obj)
            .map(|o| {
                o.iter()
                    .filter_map(|(k, v)| Some((k.clone(), v.as_usize()?)))
                    .collect()
            })
            .unwrap_or_default();
        Ok(Registry { models, pairs, kd_pairs, param_counts })
    }

    pub fn model(&self, name: &str) -> Result<&ModelConfig> {
        self.models
            .get(name)
            .with_context(|| format!("unknown model preset '{name}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> &'static str {
        r#"{
          "models": {"bert_small": {"name": "bert_small", "family": "bert",
            "layers": 3, "dim": 48, "heads": 4, "vocab": 512, "seq": 32,
            "batch": 16, "img": 0, "patch": 0, "channels": 3, "n_classes": 0,
            "cls_layers": 0, "ffn_mult": 4}},
          "pairs": [["bert_small", "bert_base"]],
          "kd_pairs": [["bert_small", "bert_base"]],
          "param_counts": {"bert_small": 12345}
        }"#
    }

    #[test]
    fn parses_registry() {
        let dir = std::env::temp_dir().join("ligo_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("configs.json"), sample_json()).unwrap();
        let r = Registry::load(&dir).unwrap();
        let m = r.model("bert_small").unwrap();
        assert_eq!(m.layers, 3);
        assert_eq!(m.ffn(), 192);
        assert_eq!(m.tokens(), 32);
        assert_eq!(r.pairs[0].1, "bert_base");
        assert_eq!(r.param_counts["bert_small"], 12345);
        assert!(r.model("nope").is_err());
    }

    #[test]
    fn vision_tokens_include_cls() {
        let m = ModelConfig {
            name: "v".into(),
            family: "vit".into(),
            layers: 6,
            dim: 48,
            heads: 4,
            vocab: 0,
            seq: 0,
            batch: 16,
            img: 32,
            patch: 8,
            channels: 3,
            n_classes: 10,
            cls_layers: 0,
            ffn_mult: 4,
        };
        assert_eq!(m.tokens(), 17);
        assert!(m.is_vision());
    }
}
