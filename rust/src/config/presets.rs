//! Model presets, loaded from `artifacts/configs.json` (written by aot.py
//! from python/compile/configs.py — the single source of truth).

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Context, Error, Result};
use crate::util::json::Json;

/// Mirror of python `ModelConfig`.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub family: String,
    pub layers: usize,
    pub dim: usize,
    pub heads: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    pub img: usize,
    pub patch: usize,
    pub channels: usize,
    pub n_classes: usize,
    pub cls_layers: usize,
    pub ffn_mult: usize,
}

impl ModelConfig {
    pub fn ffn(&self) -> usize {
        self.ffn_mult * self.dim
    }

    /// Sequence length seen by the transformer body.
    pub fn tokens(&self) -> usize {
        if self.family == "vit" || self.family == "cait" {
            let n = (self.img / self.patch) * (self.img / self.patch);
            n + usize::from(self.family == "vit")
        } else {
            self.seq
        }
    }

    pub fn is_vision(&self) -> bool {
        self.family == "vit" || self.family == "cait"
    }

    /// Tokens processed per batch (for FLOPs/throughput accounting).
    pub fn tokens_per_batch(&self) -> usize {
        self.batch * self.tokens()
    }

    /// Serialize the full geometry (every field, no registry indirection) —
    /// the shape a [`crate::coordinator::plan::GrowthPlan`] file embeds, so
    /// a synthesized search rung deserializes without a preset table.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("family", Json::Str(self.family.clone())),
            ("layers", Json::Num(self.layers as f64)),
            ("dim", Json::Num(self.dim as f64)),
            ("heads", Json::Num(self.heads as f64)),
            ("vocab", Json::Num(self.vocab as f64)),
            ("seq", Json::Num(self.seq as f64)),
            ("batch", Json::Num(self.batch as f64)),
            ("img", Json::Num(self.img as f64)),
            ("patch", Json::Num(self.patch as f64)),
            ("channels", Json::Num(self.channels as f64)),
            ("n_classes", Json::Num(self.n_classes as f64)),
            ("cls_layers", Json::Num(self.cls_layers as f64)),
            ("ffn_mult", Json::Num(self.ffn_mult as f64)),
        ])
    }

    /// Parse a config from its JSON object form (see [`ModelConfig::to_json`]
    /// and `artifacts/configs.json`).
    pub fn from_json(j: &Json) -> Result<ModelConfig> {
        let s = |k: &str| -> Result<String> {
            Ok(j.get(k).and_then(Json::as_str).context(k.to_string())?.to_string())
        };
        let u = |k: &str| -> usize { j.get(k).and_then(Json::as_usize).unwrap_or(0) };
        Ok(ModelConfig {
            name: s("name")?,
            family: s("family")?,
            layers: u("layers"),
            dim: u("dim"),
            heads: u("heads"),
            vocab: u("vocab"),
            seq: u("seq"),
            batch: u("batch").max(1),
            img: u("img"),
            patch: u("patch"),
            channels: u("channels").max(1),
            n_classes: u("n_classes"),
            cls_layers: u("cls_layers"),
            ffn_mult: u("ffn_mult").max(1),
        })
    }
}

/// The preset registry plus the LiGO growth pairs.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    pub models: BTreeMap<String, ModelConfig>,
    pub pairs: Vec<(String, String)>,
    pub kd_pairs: Vec<(String, String)>,
    pub param_counts: BTreeMap<String, usize>,
}

/// Build one preset row (channels 3 and ffn_mult 4 across the table).
#[allow(clippy::too_many_arguments)]
fn preset(
    name: &str,
    family: &str,
    layers: usize,
    dim: usize,
    heads: usize,
    vocab: usize,
    seq: usize,
    batch: usize,
    img: usize,
    patch: usize,
    n_classes: usize,
    cls_layers: usize,
) -> ModelConfig {
    ModelConfig {
        name: name.into(),
        family: family.into(),
        layers,
        dim,
        heads,
        vocab,
        seq,
        batch,
        img,
        patch,
        channels: 3,
        n_classes,
        cls_layers,
        ffn_mult: 4,
    }
}

impl Registry {
    /// The built-in preset table — the same rows `python/compile/configs.py`
    /// exports to `artifacts/configs.json`, compiled in so the native
    /// (no-artifact) path needs no files on disk. Param counts come from
    /// [`crate::model::param_shapes`], the engine's own tensor inventory.
    pub fn builtin() -> Registry {
        let presets = [
            // BERT family (paper: Small 6L/512, Base 12L/768, Large 24L/1024)
            preset("bert_small", "bert", 3, 48, 4, 512, 32, 16, 0, 0, 0, 0),
            preset("bert_base", "bert", 6, 72, 6, 512, 32, 16, 0, 0, 0, 0),
            preset("bert_large", "bert", 12, 96, 8, 512, 32, 16, 0, 0, 0, 0),
            // ablation sources: depth-only / width-only growth
            preset("bert_d3w72", "bert", 3, 72, 6, 512, 32, 16, 0, 0, 0, 0),
            preset("bert_d6w48", "bert", 6, 48, 4, 512, 32, 16, 0, 0, 0, 0),
            // GPT2 family
            preset("gpt_base", "gpt", 6, 64, 4, 512, 64, 8, 0, 0, 0, 0),
            preset("gpt_medium", "gpt", 12, 96, 6, 512, 64, 8, 0, 0, 0, 0),
            // DeiT family (width-dominant growth)
            preset("vit_s", "vit", 6, 48, 4, 0, 0, 16, 32, 8, 10, 0),
            preset("vit_b", "vit", 6, 96, 8, 0, 0, 16, 32, 8, 10, 0),
            // CaiT family (class-attention stage)
            preset("cait_xs", "cait", 6, 48, 4, 0, 0, 16, 32, 8, 10, 2),
            preset("cait_s", "cait", 6, 64, 4, 0, 0, 16, 32, 8, 10, 2),
            // end-to-end pair (~25M -> ~91M params)
            preset("e2e_small", "bert", 6, 512, 8, 8192, 64, 4, 0, 0, 0, 0),
            preset("e2e_base", "bert", 12, 768, 12, 8192, 64, 4, 0, 0, 0, 0),
            // transfer probes
            preset("probe_bert_base", "bert", 6, 72, 6, 512, 32, 16, 0, 0, 4, 0),
            preset("probe_bert_small", "bert", 3, 48, 4, 512, 32, 16, 0, 0, 4, 0),
            preset("probe_vit_b", "vit", 6, 96, 8, 0, 0, 16, 32, 8, 20, 0),
        ];
        let models: BTreeMap<String, ModelConfig> =
            presets.into_iter().map(|c| (c.name.clone(), c)).collect();
        let pair = |s: &str, t: &str| (s.to_string(), t.to_string());
        let pairs = vec![
            pair("bert_small", "bert_base"),
            pair("bert_small", "bert_large"),
            pair("bert_base", "bert_large"),
            pair("bert_d3w72", "bert_base"),
            pair("bert_d6w48", "bert_base"),
            pair("gpt_base", "gpt_medium"),
            pair("vit_s", "vit_b"),
            pair("cait_xs", "cait_s"),
            pair("e2e_small", "e2e_base"),
        ];
        let kd_pairs = vec![pair("bert_small", "bert_base"), pair("vit_s", "vit_b")];
        let param_counts = models
            .iter()
            .map(|(n, c)| {
                let count: usize = crate::model::param_shapes(c)
                    .iter()
                    .map(|(_, s)| crate::tensor::numel(s))
                    .sum();
                (n.clone(), count)
            })
            .collect();
        Registry { models, pairs, kd_pairs, param_counts }
    }

    /// Load the registry from `artifacts/configs.json` when present (the
    /// AOT source of truth), else fall back to the identical built-in
    /// table. A configs.json that exists but fails to parse is a real
    /// problem and is surfaced loudly before falling back — silently
    /// swapping preset dims would misconfigure every downstream shape.
    pub fn load_or_builtin(artifacts: &Path) -> Registry {
        if artifacts.join("configs.json").exists() {
            match Registry::load(artifacts) {
                Ok(r) => return r,
                Err(e) => crate::log_warn!(
                    "artifacts/configs.json present but unusable ({e}); using built-in presets"
                ),
            }
        }
        Registry::builtin()
    }

    pub fn load(artifacts: &Path) -> Result<Registry> {
        let path = artifacts.join("configs.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(Error::msg)?;
        let mut models = BTreeMap::new();
        for (name, mj) in j.get("models").and_then(Json::as_obj).context("models")? {
            models.insert(name.clone(), ModelConfig::from_json(mj)?);
        }
        let pairs = j
            .get("pairs")
            .and_then(Json::as_arr)
            .context("pairs")?
            .iter()
            .filter_map(|p| {
                let a = p.as_arr()?;
                Some((a[0].as_str()?.to_string(), a[1].as_str()?.to_string()))
            })
            .collect();
        let kd_pairs = j
            .get("kd_pairs")
            .and_then(Json::as_arr)
            .map(|arr| {
                arr.iter()
                    .filter_map(|p| {
                        let a = p.as_arr()?;
                        Some((a[0].as_str()?.to_string(), a[1].as_str()?.to_string()))
                    })
                    .collect()
            })
            .unwrap_or_default();
        let param_counts = j
            .get("param_counts")
            .and_then(Json::as_obj)
            .map(|o| {
                o.iter()
                    .filter_map(|(k, v)| Some((k.clone(), v.as_usize()?)))
                    .collect()
            })
            .unwrap_or_default();
        Ok(Registry { models, pairs, kd_pairs, param_counts })
    }

    pub fn model(&self, name: &str) -> Result<&ModelConfig> {
        self.models
            .get(name)
            .with_context(|| format!("unknown model preset '{name}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> &'static str {
        r#"{
          "models": {"bert_small": {"name": "bert_small", "family": "bert",
            "layers": 3, "dim": 48, "heads": 4, "vocab": 512, "seq": 32,
            "batch": 16, "img": 0, "patch": 0, "channels": 3, "n_classes": 0,
            "cls_layers": 0, "ffn_mult": 4}},
          "pairs": [["bert_small", "bert_base"]],
          "kd_pairs": [["bert_small", "bert_base"]],
          "param_counts": {"bert_small": 12345}
        }"#
    }

    #[test]
    fn parses_registry() {
        let dir = std::env::temp_dir().join("ligo_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("configs.json"), sample_json()).unwrap();
        let r = Registry::load(&dir).unwrap();
        let m = r.model("bert_small").unwrap();
        assert_eq!(m.layers, 3);
        assert_eq!(m.ffn(), 192);
        assert_eq!(m.tokens(), 32);
        assert_eq!(r.pairs[0].1, "bert_base");
        assert_eq!(r.param_counts["bert_small"], 12345);
        assert!(r.model("nope").is_err());
    }

    #[test]
    fn builtin_registry_mirrors_configs_py() {
        let r = Registry::builtin();
        assert_eq!(r.models.len(), 16);
        let base = r.model("bert_base").unwrap();
        assert_eq!((base.layers, base.dim, base.heads), (6, 72, 6));
        assert_eq!(r.model("cait_xs").unwrap().cls_layers, 2);
        assert_eq!(r.model("cait_xs").unwrap().tokens(), 16); // no CLS in body
        assert_eq!(r.model("vit_s").unwrap().tokens(), 17);
        // every pair endpoint resolves and grows upward in params
        for (s, t) in &r.pairs {
            let (ps, pt) = (r.param_counts[s], r.param_counts[t]);
            assert!(pt > ps, "{s} -> {t}: {ps} !< {pt}");
        }
        assert_eq!(r.kd_pairs.len(), 2);
        // param counts are the engine's own inventory — spot-check bert_small:
        // emb 512*48 + pos 32*48 + mlm 512 + 2*48 + 3 layers
        let small = r.model("bert_small").unwrap();
        let per_layer = 4 * 48 * 48 + 4 * 48 + 192 * 48 + 192 + 48 * 192 + 48 + 4 * 48;
        let want = 512 * 48 + 32 * 48 + 512 + 2 * 48 + 3 * per_layer;
        assert_eq!(r.param_counts[&small.name], want);
    }

    #[test]
    fn model_config_json_round_trips() {
        let r = Registry::builtin();
        for cfg in r.models.values() {
            let j = Json::parse(&cfg.to_json().to_string()).unwrap();
            let back = ModelConfig::from_json(&j).unwrap();
            assert_eq!(&back, cfg, "{}", cfg.name);
        }
    }

    #[test]
    fn load_or_builtin_falls_back() {
        let r = Registry::load_or_builtin(std::path::Path::new("/definitely/not/a/dir"));
        assert!(r.model("bert_small").is_ok());
    }

    #[test]
    fn vision_tokens_include_cls() {
        let m = ModelConfig {
            name: "v".into(),
            family: "vit".into(),
            layers: 6,
            dim: 48,
            heads: 4,
            vocab: 0,
            seq: 0,
            batch: 16,
            img: 32,
            patch: 8,
            channels: 3,
            n_classes: 10,
            cls_layers: 0,
            ffn_mult: 4,
        };
        assert_eq!(m.tokens(), 17);
        assert!(m.is_vision());
    }
}
