//! Training recipes: optimizer hyperparameters, schedule, accumulation.
//!
//! Mirrors the paper's §4.1 recipes, rescaled: BERT (batch 256, lr 2e-4,
//! 10k warmup of 400k) / RoBERTa (batch 1024 via accumulation, lr 8e-4) /
//! GPT2 / DeiT-on-ImageNet analog.

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    /// Linear warmup then linear decay to zero at `total_steps`.
    WarmupLinear,
    /// Linear warmup then cosine decay.
    WarmupCosine,
    /// Constant after warmup.
    Constant,
}

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub lr: f32,
    pub warmup_steps: usize,
    pub total_steps: usize,
    pub schedule: Schedule,
    pub weight_decay: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub grad_clip: f32,
    /// Microbatches accumulated per optimizer step (RoBERTa recipe = 4).
    pub grad_accum: usize,
    pub eval_every: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            // NOTE: lr values are the paper's recipes rescaled for the
            // ~600-step runs this substrate uses (paper: 2e-4 over 400k
            // steps); the *ratios* between recipes are preserved.
            lr: 4e-3,
            warmup_steps: 40,
            total_steps: 1500,
            schedule: Schedule::WarmupLinear,
            weight_decay: 0.01,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            grad_clip: 1.0,
            grad_accum: 1,
            eval_every: 25,
            seed: 0,
        }
    }
}

impl TrainConfig {
    /// The paper's BERT recipe (rescaled).
    pub fn bert(total_steps: usize) -> TrainConfig {
        TrainConfig { total_steps, warmup_steps: total_steps / 40, ..Default::default() }
    }

    /// RoBERTa: 4x batch (via accumulation) and 4x LR (paper §4.1).
    pub fn roberta(total_steps: usize) -> TrainConfig {
        TrainConfig {
            lr: 8e-3, // 2x bert + 4x batch via accumulation (paper ratio 4x lr)
            grad_accum: 4,
            total_steps,
            warmup_steps: total_steps / 40,
            ..Default::default()
        }
    }

    pub fn gpt(total_steps: usize) -> TrainConfig {
        TrainConfig {
            lr: 3e-3,
            schedule: Schedule::WarmupCosine,
            total_steps,
            warmup_steps: total_steps / 40,
            ..Default::default()
        }
    }

    pub fn vision(total_steps: usize) -> TrainConfig {
        TrainConfig {
            lr: 2e-3,
            schedule: Schedule::WarmupCosine,
            weight_decay: 0.05,
            total_steps,
            warmup_steps: total_steps / 20,
            ..Default::default()
        }
    }

    /// Fine-tuning recipe for downstream probes (Table 1/2/5/6).
    pub fn finetune(total_steps: usize) -> TrainConfig {
        TrainConfig {
            lr: 1e-3,
            schedule: Schedule::Constant,
            weight_decay: 0.0,
            total_steps,
            warmup_steps: 0,
            eval_every: total_steps.max(1),
            ..Default::default()
        }
    }

    /// The learning rate at a given step.
    pub fn lr_at(&self, step: usize) -> f32 {
        let warm = self.warmup_steps.max(0);
        if warm > 0 && step < warm {
            return self.lr * (step as f32 + 1.0) / warm as f32;
        }
        let progress = if self.total_steps > warm {
            ((step - warm) as f32 / (self.total_steps - warm) as f32).clamp(0.0, 1.0)
        } else {
            0.0
        };
        match self.schedule {
            Schedule::WarmupLinear => self.lr * (1.0 - progress),
            Schedule::WarmupCosine => {
                self.lr * 0.5 * (1.0 + (std::f32::consts::PI * progress).cos())
            }
            Schedule::Constant => self.lr,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn warmup_ramps_linearly() {
        let c = TrainConfig { lr: 1.0, warmup_steps: 10, total_steps: 100, ..Default::default() };
        assert!((c.lr_at(0) - 0.1).abs() < 1e-6);
        assert!((c.lr_at(9) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn linear_decays_to_zero() {
        let c = TrainConfig {
            lr: 1.0,
            warmup_steps: 0,
            total_steps: 100,
            schedule: Schedule::WarmupLinear,
            ..Default::default()
        };
        assert!(c.lr_at(99) < 0.02);
        assert_eq!(c.lr_at(100), 0.0);
    }

    #[test]
    fn cosine_halfway_is_half() {
        let c = TrainConfig {
            lr: 1.0,
            warmup_steps: 0,
            total_steps: 100,
            schedule: Schedule::WarmupCosine,
            ..Default::default()
        };
        assert!((c.lr_at(50) - 0.5).abs() < 0.02);
    }

    #[test]
    fn lr_nonnegative_and_bounded_prop() {
        prop::check("0 <= lr(t) <= lr", 50, |g| {
            let c = TrainConfig {
                lr: g.f32_in(1e-5, 1.0),
                warmup_steps: g.usize_in(0, 50),
                total_steps: g.usize_in(51, 500),
                schedule: *g
                    .pick(&[Schedule::WarmupLinear, Schedule::WarmupCosine, Schedule::Constant]),
                ..Default::default()
            };
            for step in 0..c.total_steps + 10 {
                let lr = c.lr_at(step);
                assert!(lr >= -1e-9 && lr <= c.lr + 1e-6, "step {step} lr {lr}");
            }
        });
    }

    #[test]
    fn roberta_recipe_scales_bert() {
        let b = TrainConfig::bert(400);
        let r = TrainConfig::roberta(400);
        assert!((r.lr / b.lr - 2.0).abs() < 1e-6);
        assert_eq!(r.grad_accum, 4);
    }
}
