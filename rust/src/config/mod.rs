//! Configuration: model presets (read from `artifacts/configs.json`, the
//! single source of truth shared with the python compile path) and training
//! recipes.

pub mod presets;
pub mod training;

pub use presets::{ModelConfig, Registry};
pub use training::TrainConfig;

/// Locate the artifacts directory: $LIGO_ARTIFACTS (via the knob
/// registry) or ./artifacts.
pub fn artifacts_dir() -> std::path::PathBuf {
    crate::util::knobs::raw("LIGO_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
