//! Downstream fine-tuning harnesses (GLUE/SQuAD/vision-transfer analogs).
//!
//! Each harness takes a pretrained *body* (the trainer's params), attaches a
//! fresh task head (det-init), fine-tunes with the finetune recipe, and
//! reports held-out accuracy — the numbers in Tables 1/2/5/6.
//!
//! On the native backend both the fine-tune steps and the held-out
//! accuracy pass stream the classifier head: the loss runs through
//! `Tape::lm_head_xent` and the metric through the tiled
//! `ops::lm_head_argmax`, so evaluating a large-vocab head never
//! materializes a `(rows, vocab)` logits tensor (see the memory-discipline
//! ledger in EXPERIMENTS.md).

use crate::config::TrainConfig;
use crate::error::Result;
use crate::coordinator::optim::AdamW;
use crate::coordinator::trainer::eval_store;
use crate::runtime::Runtime;
use crate::tensor::init::det_fill;
use crate::tensor::store::Store;

#[derive(Debug, Clone)]
pub struct FinetuneResult {
    pub task: String,
    pub accuracy: f32,
    pub final_loss: f32,
}

/// Assemble probe params: pretrained body tensors where names match, fresh
/// det-init for task-head (and any other missing) tensors.
pub fn attach_head(manifest_shapes: &[(String, Vec<usize>)], body: &Store, seed: u64) -> Store {
    let mut out = Store::new();
    for (name, shape) in manifest_shapes {
        match body.get(name) {
            Some(t) if &t.shape == shape => out.insert(name.clone(), t.clone()),
            _ => out.insert(name.clone(), det_fill(name, shape, seed ^ 0x4EAD)),
        }
    }
    out
}

/// Generic single-group fine-tune: artifact with (params, batch) signature.
fn finetune_generic(
    rt: &Runtime,
    grad_name: &str,
    fwd_name: &str,
    task: &str,
    body: &Store,
    tc: &TrainConfig,
    train_batches: &mut dyn FnMut(usize) -> Store,
    eval_batches: &mut dyn FnMut(usize) -> Store,
    eval_n: usize,
) -> Result<FinetuneResult> {
    let grad = rt.load(grad_name)?;
    let fwd = rt.load(fwd_name)?;
    let mut params = attach_head(&grad.manifest.shapes_of("params"), body, tc.seed);
    let mut opt = AdamW::from_train_config(&params, tc);
    for step in 0..tc.total_steps {
        let batch = train_batches(step);
        let out = grad.run(&[("params", &params), ("batch", &batch)])?;
        let grads = out.groups.get("grads").expect("grads");
        opt.step(&mut params, grads, tc.lr_at(step));
    }
    let (loss, metric) = eval_store(&fwd, &params, eval_batches, eval_n)?;
    Ok(FinetuneResult {
        task: task.to_string(),
        accuracy: metric.unwrap_or(f32::NAN),
        final_loss: loss,
    })
}

/// Classification probe (GLUE analog) on a bert body.
#[allow(clippy::too_many_arguments)]
pub fn finetune_probe(
    rt: &Runtime,
    artifact_model: &str, // e.g. "probe_bert_base"
    task: &str,
    body: &Store,
    tc: &TrainConfig,
    train_batches: &mut dyn FnMut(usize) -> Store,
    eval_batches: &mut dyn FnMut(usize) -> Store,
) -> Result<FinetuneResult> {
    finetune_generic(
        rt,
        &format!("grad_{artifact_model}"),
        &format!("fwd_{artifact_model}"),
        task,
        body,
        tc,
        train_batches,
        eval_batches,
        8,
    )
}

/// Span probe (SQuAD analog). Reports EM-style accuracy.
pub fn finetune_span(
    rt: &Runtime,
    task: &str,
    body: &Store,
    tc: &TrainConfig,
    train_batches: &mut dyn FnMut(usize) -> Store,
    eval_batches: &mut dyn FnMut(usize) -> Store,
) -> Result<FinetuneResult> {
    finetune_generic(
        rt,
        "span_grad_bert_base",
        "span_fwd_bert_base",
        task,
        body,
        tc,
        train_batches,
        eval_batches,
        8,
    )
}

/// AdapterFusion-style tuning (Table 6): only adapters + head receive
/// gradients; the pretrained body is a frozen input group.
pub fn finetune_adapters(
    rt: &Runtime,
    task: &str,
    body: &Store,
    tc: &TrainConfig,
    train_batches: &mut dyn FnMut(usize) -> Store,
    eval_batches: &mut dyn FnMut(usize) -> Store,
) -> Result<FinetuneResult> {
    let grad = rt.load("adapter_grad_bert_base")?;
    let fwd = rt.load("adapter_fwd_bert_base")?;
    let frozen = attach_head(&grad.manifest.shapes_of("frozen"), body, tc.seed);
    let mut trainable = Store::det_init(&grad.manifest.shapes_of("trainable"), tc.seed ^ 0xADA);
    let mut opt = AdamW::from_train_config(&trainable, tc);
    for step in 0..tc.total_steps {
        let batch = train_batches(step);
        let out = grad.run(&[("trainable", &trainable), ("frozen", &frozen), ("batch", &batch)])?;
        let grads = out.groups.get("grads").expect("grads");
        opt.step(&mut trainable, grads, tc.lr_at(step));
    }
    let mut loss = 0.0;
    let mut acc = 0.0;
    let n = 8;
    for i in 0..n {
        let batch = eval_batches(i);
        let out = fwd.run(&[("trainable", &trainable), ("frozen", &frozen), ("batch", &batch)])?;
        loss += out.scalar("loss").unwrap_or(f32::NAN);
        acc += out.scalar("metric").unwrap_or(f32::NAN);
    }
    Ok(FinetuneResult {
        task: task.to_string(),
        accuracy: acc / n as f32,
        final_loss: loss / n as f32,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn attach_head_reuses_body_and_inits_head() {
        let mut body = Store::new();
        body.insert("L00_q_w", Tensor::from_f32(&[2, 2], vec![9.0; 4]));
        let shapes = vec![
            ("L00_q_w".to_string(), vec![2, 2]),
            ("head_w".to_string(), vec![4, 2]),
        ];
        let p = attach_head(&shapes, &body, 0);
        assert_eq!(p.expect("L00_q_w").f32s(), &[9.0; 4]);
        assert_eq!(p.expect("head_w").shape, vec![4, 2]);
    }

    #[test]
    fn attach_head_replaces_mismatched_shapes() {
        let mut body = Store::new();
        body.insert("L00_q_w", Tensor::from_f32(&[3, 3], vec![9.0; 9]));
        let shapes = vec![("L00_q_w".to_string(), vec![2, 2])];
        let p = attach_head(&shapes, &body, 0);
        assert_eq!(p.expect("L00_q_w").shape, vec![2, 2]);
        assert_ne!(p.expect("L00_q_w").f32s()[0], 9.0);
    }
}
