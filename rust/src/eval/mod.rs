//! Evaluation & transfer: the fine-tuning harnesses behind Tables 1/2/5/6.

pub mod finetune;

pub use finetune::{finetune_probe, finetune_span, finetune_adapters, FinetuneResult};
