//! `ligo` — the coordinator CLI.
//!
//! Subcommands:
//!   train      --model NAME [--steps N --lr F --seed N --out DIR --resume]
//!              (LIGO_CKPT_EVERY=K writes crash-safe checkpoints under
//!               OUT/state/NAME; --resume continues from the latest good one)
//!   grow       --from SMALL --to LARGE [--op ligo|stackbert|...] [--m-steps N]
//!   eval       --model NAME --ckpt PATH
//!   experiment ID|all [--scale F --out DIR]     (fig2..fig8, table1..table6)
//!   experiment progressive --plan FILE          (execute a serialized GrowthPlan)
//!   search     [--smoke | --from A --to B] [--ops a,b --probe-steps N --budget N
//!              --topk K --steps N --seed N]     (growth-policy plan search)
//!   analyze    (static shape/plan verification: every preset, pair, operator)
//!   serve      --model NAME [--ckpt PATH --sessions N --max-new N --seed N
//!               --max-pages N | --self-test]
//!   inspect    configs|operators|artifacts|knobs
//!
//! Python never runs here: artifacts must exist (run `make artifacts` once).

use ligo::bail;
use ligo::config::{artifacts_dir, Registry};
use ligo::coordinator::plan::GrowthPlan;
use ligo::coordinator::trainer::Trainer;
use ligo::data::corpus::Corpus;
use ligo::error::{Context, Result};
use ligo::experiments;
use ligo::growth::{verify, GrowthContext, LigoOptions, Objective};
use ligo::runtime::Runtime;
use ligo::tensor::io;
use ligo::util::cli::Args;

fn main() {
    ligo::util::logging::init_from_env();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: ligo <train|grow|eval|experiment|search|analyze|serve|inspect> [options]\n\
         \n\
         ligo train --model bert_small --steps 300 --out reports\n\
         LIGO_CKPT_EVERY=10 ligo train --model bert_small --steps 300 --resume\n\
         ligo grow --from bert_small --to bert_base --op ligo --m-steps 100\n\
         ligo eval --model bert_base --ckpt reports/ckpt/bert_base_LiGO_600steps.lgck\n\
         ligo experiment fig2 --scale 1.0 --out reports\n\
         ligo experiment all --scale 0.25\n\
         ligo experiment progressive --plan reports/search/best_plan.json\n\
         ligo search --smoke\n\
         ligo search --from bert_small --to bert_base --ops stackbert,ligo --topk 4\n\
         ligo analyze\n\
         ligo serve --model gpt_base --sessions 4 --max-new 16\n\
         ligo serve --model gpt_base --self-test\n\
         ligo inspect configs|operators|artifacts|knobs"
    );
    std::process::exit(2);
}

fn run() -> Result<()> {
    let args = Args::from_env();
    let Some(cmd) = args.positional.first().map(String::as_str) else { usage() };
    let out_dir = std::path::PathBuf::from(args.get("out").unwrap_or("reports"));
    match cmd {
        "train" => {
            let rt = Runtime::cpu(artifacts_dir())?;
            let reg = Registry::load_or_builtin(&artifacts_dir());
            let name = args.get("model").context("--model required")?;
            let cfg = reg.model(name)?.clone();
            let steps = args.get_usize("steps", 300);
            let corpus = Corpus::new(cfg.vocab.max(512), args.get_u64("seed", 0));
            let params = Trainer::scratch_params(&rt, &cfg, args.get_u64("seed", 0))?;
            let mut tc = ligo::experiments::common::recipe_for(&cfg, steps);
            if let Some(lr) = args.get("lr") {
                tc.lr = lr.parse().context("--lr")?;
            }
            let state_dir = out_dir.join("state").join(name);
            let (mut tr, resumed) = if args.has_flag("resume") {
                let (tr, r) = Trainer::resume_latest(&rt, tc, &state_dir)?;
                (tr, Some(r))
            } else {
                (Trainer::new(&rt, &cfg, tc, params)?, None)
            };
            if let Some(every) = ligo::util::knobs::usize_env("LIGO_CKPT_EVERY") {
                tr.checkpoint_every(every, &state_dir);
            }
            let mut b = if cfg.is_vision() {
                ligo::experiments::common::vision_batches(
                    &ligo::data::vision::VisionTask::pretrain(), &cfg, 1)
            } else {
                ligo::experiments::common::text_batches(&corpus, &cfg, 1)
            };
            let curve = match resumed {
                Some(r) => tr.run_resumed(name, &mut b, steps, r)?,
                None => tr.run(name, &mut b, steps)?,
            };
            let path = out_dir.join("ckpt").join(format!("{name}_{steps}steps.lgck"));
            io::save(&tr.params, &path)?;
            println!(
                "trained {name} {steps} steps: loss {:.4} -> {:.4}; saved {}",
                curve.loss.first().unwrap(),
                curve.final_loss(),
                path.display()
            );
            ligo::coordinator::metrics::write_report(&out_dir, &format!("train_{name}"), &[curve])?;
        }
        "grow" => {
            let rt = Runtime::cpu(artifacts_dir())?;
            let reg = Registry::load_or_builtin(&artifacts_dir());
            let from = reg.model(args.get("from").context("--from required")?)?.clone();
            let to = reg.model(args.get("to").context("--to required")?)?.clone();
            let op = args.get("op").unwrap_or("ligo");
            let corpus = Corpus::new(to.vocab.max(512), 0);
            let ckpt = match args.get("ckpt") {
                Some(p) => io::load(p)?,
                None => ligo::experiments::common::ensure_pretrained(
                    &rt, &from, &corpus, args.get_usize("pretrain", 300), &out_dir)?,
            };
            // static precheck: schedule compatibility, operator regime and
            // a symbolic shape replay of both configs — a bad pair fails
            // here with a plan-time diagnostic, before any kernel runs
            verify::verify_pair(op, &from, &to)
                .with_context(|| format!("static verification of {} -> {}", from.name, to.name))?;
            // one entry point for every operator: the context carries the
            // runtime handle + a batch source, and the operator negotiates
            // its route (param-only ops simply ignore the extras)
            let oper = ligo::growth::by_name(op)?;
            let opts = LigoOptions {
                steps: args.get_usize("m-steps", 100),
                lr: args.get_f32("m-lr", 0.02),
                ..Default::default()
            };
            let c = corpus.clone();
            let t = to.clone();
            let mut mk = move |s: usize| {
                ligo::data::batches::mlm_batch(
                    &c, &t, &mut ligo::util::rng::Rng::new(7000 + s as u64))
            };
            let ctx = GrowthContext::new(&ckpt, &from, &to)
                .with_runtime(&rt)
                .with_batches(&mut mk)
                .with_opts(opts);
            let grown = oper.grow(ctx)?;
            println!("route: {}", grown.route_summary());
            if grown.objective != Objective::ParamOnly {
                println!(
                    "M-loss {:.4} ({}), +{:.3e} FLOPs, {:.1}s",
                    grown.metrics.final_m_loss,
                    grown.objective,
                    grown.metrics.extra_flops,
                    grown.metrics.wall_s
                );
            }
            let path = out_dir
                .join("ckpt")
                .join(format!("{}_from_{}_{op}.lgck", to.name, from.name));
            io::save(&grown.params, &path)?;
            println!("grew {} -> {} via {op}: {} params, saved {}",
                from.name, to.name, grown.params.param_count(), path.display());
        }
        "eval" => {
            let rt = Runtime::cpu(artifacts_dir())?;
            let reg = Registry::load_or_builtin(&artifacts_dir());
            let name = args.get("model").context("--model required")?;
            let cfg = reg.model(name)?.clone();
            let params = io::load(args.get("ckpt").context("--ckpt required")?)?;
            let fwd = rt.load(&format!("fwd_{name}"))?;
            let corpus = Corpus::new(cfg.vocab.max(512), 0);
            let cfg2 = cfg.clone();
            let mut eb = move |i: usize| {
                if cfg2.is_vision() {
                    ligo::data::vision::VisionTask::pretrain()
                        .batch(&cfg2, &mut ligo::util::rng::Rng::new(0xEEAA_0000 + i as u64))
                } else {
                    ligo::data::batches::mlm_batch(
                        &corpus, &cfg2, &mut ligo::util::rng::Rng::new(0xEEAA_0000 + i as u64))
                }
            };
            let (loss, metric) =
                ligo::coordinator::trainer::eval_store(&fwd, &params, &mut eb, 16)?;
            println!("{name}: loss {loss:.4} ppl {:.2} metric {metric:?}", loss.exp());
        }
        "experiment" => {
            let id = args.positional.get(1).map(String::as_str).unwrap_or("all");
            let scale = args.get_f32("scale", 0.25) as f64;
            if let Some(plan_file) = args.get("plan") {
                // a serialized plan (e.g. `ligo search` output) brings its
                // own configs — possibly synthesized rungs, not presets —
                // so this path builds its own runtime around the plan
                if id != "progressive" {
                    bail!("--plan is the progressive experiment's input \
                           (use `ligo experiment progressive --plan FILE`)");
                }
                experiments::progressive::from_plan_file(
                    std::path::Path::new(plan_file), scale, &out_dir)?;
            } else {
                let rt = Runtime::cpu(artifacts_dir())?;
                let reg = Registry::load_or_builtin(&artifacts_dir());
                experiments::run(&rt, &reg, id, scale, &out_dir)?;
            }
        }
        "search" => {
            // growth-policy search: enumerate operator x rung x fraction
            // schedules, statically filter them (symbolically — the driver
            // asserts zero kernel buffers), probe the survivors under
            // successive halving, emit the winner as an executable plan
            // file, then re-execute that file end-to-end as a round-trip
            // check. `--smoke` is the CI configuration: a small operator
            // set over the bert_small -> bert_base ladder.
            use ligo::search::{probe, ProbeConfig, SearchSpace};
            let reg = Registry::load_or_builtin(&artifacts_dir());
            let smoke = args.has_flag("smoke");
            let from_name = args.get("from").unwrap_or("bert_small");
            let to_name = args.get("to").unwrap_or("bert_base");
            let initial = reg.model(from_name)?.clone();
            let goal = reg.model(to_name)?.clone();
            let ops: Vec<String> = match args.get("ops") {
                Some(list) => list.split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect(),
                None if smoke => ["stackbert", "net2net", "ligo", "lemon"]
                    .map(String::from).to_vec(),
                None => ligo::growth::KNOWN.map(String::from).to_vec(),
            };
            let ops_ref: Vec<&str> = ops.iter().map(String::as_str).collect();
            let space = SearchSpace::ladder(&initial, &goal, &ops_ref);
            let mut pc = ProbeConfig::from_env();
            if smoke {
                // CI-sized defaults; explicit knobs still win
                if ligo::util::knobs::usize_env("LIGO_SEARCH_PROBE_STEPS").is_none() {
                    pc.horizon = 12;
                }
                if ligo::util::knobs::usize_env("LIGO_SEARCH_BUDGET").is_none() {
                    pc.budget_steps = 600;
                }
                pc.m_steps = 2;
            }
            if let Some(v) = args.get("probe-steps") {
                pc.horizon = v.parse().context("--probe-steps")?;
            }
            if let Some(v) = args.get("budget") {
                pc.budget_steps = v.parse().context("--budget")?;
            }
            if let Some(v) = args.get("topk") {
                pc.topk = v.parse().context("--topk")?;
            }
            pc.seed = args.get_u64("seed", pc.seed);
            // horizon the emitted plan schedules against (and the winner
            // re-execution length): short for smoke, a real budget otherwise
            let plan_horizon =
                args.get_usize("steps", if smoke { pc.horizon * 2 } else { 600 });

            let rep = ligo::search::run_and_write(&space, &pc, plan_horizon, &out_dir)?;
            println!("{}", rep.summary_line());
            if !rep.pruned.is_empty() {
                println!("statically pruned (typed diagnostics, zero kernels):");
                print!("{}", rep.prune_log());
            }
            println!("\nranked finalists ({} -> {}, probe horizon {}):",
                initial.name, goal.name, pc.horizon);
            print!("{}", rep.table());

            // round-trip: reload the persisted winner and run it for real
            let plan_path = out_dir.join("search").join("best_plan.json");
            let plan = GrowthPlan::load(&plan_path)?;
            let rt = probe::runtime_for(
                std::iter::once(plan.initial())
                    .chain(plan.stages().iter().map(|s| &s.target)),
            );
            let curve = probe::execute_plan(&rt, "winner", &plan, plan_horizon, pc.seed)?;
            if curve.marks.len() != plan.stages().len() {
                bail!(
                    "winner plan scheduled {} stage(s) but recorded {} growth mark(s)",
                    plan.stages().len(),
                    curve.marks.len()
                );
            }
            println!(
                "\nwinner re-executed from {}: {} steps, {} growth mark(s), \
                 loss {:.4} -> {:.4}",
                plan_path.display(),
                plan_horizon,
                curve.marks.len(),
                curve.loss.first().copied().unwrap_or(f32::NAN),
                curve.final_loss()
            );
        }
        "analyze" => {
            // Static shape/plan verification: replay every builtin preset,
            // every registry growth pair x every operator, and a
            // representative multi-stage plan through the symbolic shape
            // verifier. No kernels run and no parameter data is allocated —
            // the arena's fresh-allocation counter proves it at the end.
            let t0 = std::time::Instant::now();
            let reg = Registry::load_or_builtin(&artifacts_dir());
            ligo::tensor::arena::reset_stats();

            println!("model graphs (symbolic replay, current lowering):");
            let mut nodes = 0usize;
            for name in reg.models.keys() {
                let s = ligo::model::shape::summarize(reg.model(name)?)
                    .with_context(|| format!("preset '{name}'"))?;
                nodes += s.node_count();
                println!("  {}", s.brief());
            }

            println!("\ndecode graphs (gpt presets: prompt prefill + one step at seq-1):");
            for name in reg.models.keys() {
                let cfg = reg.model(name)?;
                if cfg.family != "gpt" || cfg.n_classes > 0 {
                    continue;
                }
                for phase in [
                    ligo::model::shape::DecodePhase::Prefill { tokens: cfg.seq },
                    ligo::model::shape::DecodePhase::Step { pos: cfg.seq - 1 },
                ] {
                    let s = ligo::model::shape::summarize_decode(cfg, phase)
                        .with_context(|| format!("decode graph of '{name}'"))?;
                    nodes += s.node_count();
                    println!("  {}", s.brief());
                }
            }

            println!("\ngrowth pairs x operators:");
            let (mut combos, mut misses) = (0usize, 0usize);
            for (s, t) in &reg.pairs {
                let from = reg.model(s)?;
                let to = reg.model(t)?;
                let mut ok: Vec<&str> = Vec::new();
                for op in ligo::growth::KNOWN {
                    match verify::verify_pair(op, from, to) {
                        Ok(_) => {
                            combos += 1;
                            ok.push(op);
                        }
                        // LEMON's exactness regime (integer width factors,
                        // fixed per-head dim) excludes most paper pairs by
                        // design: an expected, printed diagnostic
                        Err(e) if op == "lemon" => {
                            misses += 1;
                            println!("  {s} -> {t}: lemon outside exact regime\n      ({e:#})");
                        }
                        Err(e) => {
                            return Err(e)
                                .with_context(|| format!("pair {s} -> {t} via {op}"));
                        }
                    }
                }
                println!("  {s} -> {t}: ok via {}", ok.join(", "));
            }

            println!("\nmulti-stage plan (bert_small -> bert_d6w48 -> bert_base):");
            let small = reg.model("bert_small")?.clone();
            let mid = reg.model("bert_d6w48")?.clone();
            let large = reg.model("bert_base")?.clone();
            // the builder itself verifies every stage; verify_plan re-runs
            // the pairs to get the printable summaries back
            let plan = GrowthPlan::builder(&small)
                .grow_at(10, &mid, "stackbert")
                .grow_at(20, &large, "ligo")
                .build()?;
            for (i, pv) in verify::verify_plan(&plan)?.iter().enumerate() {
                println!(
                    "  stage {i}: {} -> {}  (params {} -> {}, peak arena x{:.2})",
                    pv.small.name, pv.large.name, pv.small.params, pv.large.params,
                    pv.peak_ratio()
                );
            }

            let (fresh, _) = ligo::tensor::arena::stats();
            println!(
                "\nverified {} presets ({nodes} graph nodes), {combos} pair x operator \
                 combos ({misses} expected lemon regime misses), 2-stage plan in {:.0?}; \
                 kernel buffers allocated: {fresh}",
                reg.models.len(),
                t0.elapsed()
            );
            if fresh > 0 {
                bail!("analyze must be purely symbolic but allocated {fresh} kernel buffers");
            }
        }
        "serve" => {
            // tape-free serving: no runtime/artifacts needed — the decoder
            // runs the native decode kernels directly over the checkpoint
            let reg = Registry::load_or_builtin(&artifacts_dir());
            let name = args.get("model").unwrap_or("gpt_base");
            let cfg = reg.model(name)?.clone();
            let params = match args.get("ckpt") {
                Some(p) => io::load(p)?,
                None => ligo::tensor::store::Store::det_init(
                    &ligo::model::param_shapes(&cfg),
                    args.get_u64("seed", 0),
                ),
            };
            if args.has_flag("self-test") {
                let line = ligo::coordinator::serve::self_test(&cfg, &params)?;
                println!("{name}: {line}");
                return Ok(());
            }
            use ligo::coordinator::serve::{Request, Scheduler, ServeOptions};
            let mut opts = ServeOptions::from_env();
            if let Some(s) = args.get("sessions") {
                opts.max_sessions = s.parse().context("--sessions")?;
            }
            if let Some(p) = args.get("max-pages") {
                opts.max_pages = p.parse().context("--max-pages")?;
            }
            let dec = ligo::model::decode::Decoder::new(&cfg, &params)?;
            let mut sched = Scheduler::new(&dec, opts);
            let n = args.get_usize("requests", opts.max_sessions.max(1));
            let max_new = args.get_usize("max-new", (cfg.seq / 4).clamp(1, 16));
            let mut rng = ligo::util::rng::Rng::new(args.get_u64("seed", 0) ^ 0x5e12e);
            for i in 0..n {
                let plen = (3 + (i * 5) % 11).min(cfg.seq.saturating_sub(max_new)).max(1);
                let prompt = (0..plen).map(|_| rng.below(cfg.vocab) as i32).collect();
                sched.submit(Request {
                    id: i as u64,
                    prompt,
                    max_new: max_new.min(cfg.seq - plen).max(1),
                    top_k: 8,
                    top_p: 0.95,
                    seed: 42 + i as u64,
                    deadline_steps: 0,
                })?;
            }
            let t0 = std::time::Instant::now();
            sched.run()?;
            let dt = t0.elapsed().as_secs_f64().max(1e-9);
            let mut done = sched.take_done();
            done.sort_by_key(|c| c.id);
            for c in &done {
                println!("session {}: {}-token prompt -> {:?}", c.id, c.prompt_len, c.tokens);
            }
            let (tokens, steps) = sched.stats();
            println!(
                "{name}: {tokens} tokens over {n} sessions in {steps} batched steps \
                 ({:.0} tok/s)",
                tokens as f64 / dt
            );
        }
        "inspect" => {
            let what = args.positional.get(1).map(String::as_str).unwrap_or("configs");
            match what {
                "configs" => {
                    let reg = Registry::load_or_builtin(&artifacts_dir());
                    println!("{:<16} {:>7} {:>6} {:>6} {:>9} {:>6} {:>12}",
                        "name", "family", "layers", "dim", "vocab/img", "seq", "params");
                    for (name, m) in &reg.models {
                        println!(
                            "{:<16} {:>7} {:>6} {:>6} {:>9} {:>6} {:>12}",
                            name, m.family, m.layers, m.dim,
                            if m.is_vision() { m.img } else { m.vocab },
                            m.tokens(),
                            reg.param_counts.get(name).copied().unwrap_or(0)
                        );
                    }
                    println!("\ngrowth pairs:");
                    for (s, t) in &reg.pairs {
                        println!("  {s} -> {t}");
                    }
                }
                "operators" => {
                    println!("{:<14} {:<34} {}", "operator", "capabilities", "static regime");
                    for name in ligo::growth::KNOWN {
                        let op = ligo::growth::by_name(name)?;
                        let caps: Vec<&str> =
                            op.capabilities().iter().map(|c| c.as_str()).collect();
                        println!(
                            "{:<14} {:<34} {}",
                            name,
                            caps.join(", "),
                            verify::regime_summary(name)
                        );
                    }
                    println!(
                        "\nall operators share one entry point: grow(GrowthContext). \
                         \"ligo\" negotiates its M-learning route from the context \
                         (artifact fast path -> native task loss -> surrogate); \
                         \"lemon\" is exactly loss-preserving on integer-factor pairs."
                    );
                }
                "artifacts" => {
                    let rt = Runtime::cpu(artifacts_dir())?;
                    for a in rt.available() {
                        println!("{a}");
                    }
                }
                "knobs" => {
                    println!("{:<26} {:<22} {:<28} {}", "knob", "type", "default", "current");
                    for k in ligo::util::knobs::REGISTRY {
                        let cur = ligo::util::knobs::raw(k.name)
                            .map(|v| format!("{v:?}"))
                            .unwrap_or_else(|| "(unset)".into());
                        println!("{:<26} {:<22} {:<28} {cur}", k.name, k.ty, k.default);
                        println!("{:<26}   {}", "", k.doc);
                    }
                }
                other => bail!("unknown inspect target '{other}'"),
            }
        }
        _ => usage(),
    }
    Ok(())
}
