//! In-tree error handling (`anyhow` is unavailable offline, like the other
//! external-crate substrates in `util/`): a single message-chain [`Error`]
//! with the [`Context`] extension trait and the [`bail!`](crate::bail) macro,
//! mirroring the `anyhow` surface the codebase uses.

use std::fmt;

/// A contextual error: the innermost cause prefixed by each `context` layer,
/// e.g. `"read artifacts/configs.json: No such file or directory"`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message (the `anyhow::Error::msg`
    /// equivalent).
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    fn wrap(self, c: impl fmt::Display) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Debug prints the message chain too: `unwrap()`/`expect()` and
// `fn main() -> Result<()>` show the human-readable chain, not a struct dump.
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Any std error converts via `?`, flattening its source chain. `Error`
// itself intentionally does NOT implement `std::error::Error`, so this
// blanket impl cannot collide with the reflexive `From<Error> for Error`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg = format!("{msg}: {s}");
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Context`-style helpers on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Return early with a formatted [`Error`] (the `anyhow::bail!` equivalent).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::error::Error::msg(format!($($arg)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 7)
    }

    #[test]
    fn bail_formats() {
        assert_eq!(fails().unwrap_err().to_string(), "boom 7");
    }

    #[test]
    fn context_chains_outermost_first() {
        let e: Result<()> = fails().context("outer");
        assert_eq!(e.unwrap_err().to_string(), "outer: boom 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing key").unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        assert_eq!(Some(3u32).context("x").unwrap(), 3);
    }

    #[test]
    fn std_errors_convert_with_source_chain() {
        let io = std::fs::read_to_string("/definitely/not/a/file");
        let e: Result<String> = io.with_context(|| format!("read {}", "f"));
        let msg = e.unwrap_err().to_string();
        assert!(msg.starts_with("read f: "), "{msg}");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<u32> {
            let n: u32 = "12".parse()?;
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 12);
    }
}
