//! Continuous-batching decode scheduler behind `ligo serve`.
//!
//! Many concurrent sessions are multiplexed through **one** batched
//! [`Decoder::decode_step`] per tick: new requests are admitted (prefill +
//! first sampled token) whenever a slot frees up, finished sessions are
//! evicted the step they complete, and every session keeps its own
//! sampling state (a seeded [`Rng`] driving [`ops::lm_head_sample`]'s
//! top-k/top-p draw). Because the decode kernels are batch-invariant and
//! the sampler's randomness is per-session, **any** admission/eviction
//! interleaving yields exactly the token stream each session would produce
//! alone — asserted by [`Scheduler::self_test`] (the CI
//! `ligo serve --self-test` command) and `tests/decode_parity.rs`.
//!
//! Memory discipline matches the trainer's: K/V pages come from one
//! [`PagePool`] (evicted sessions recycle their pages to the next admit)
//! and activations from the arena, so a warm serve loop performs zero
//! fresh allocations.
//!
//! **Degradation is graceful, never a panic.** When the pool is capped
//! (`ServeOptions::max_pages`), admission reserves each session's
//! worst-case page demand up front and applies strict-FIFO backpressure:
//! the queue head waits until enough reservation frees up, and later
//! requests wait behind it (head-of-line blocking keeps the admission
//! order — and therefore the batch composition — deterministic). A request
//! that could *never* fit is rejected at [`Scheduler::submit`] with a
//! typed error. Per-session `deadline_steps` budgets bound decode work:
//! a session that exhausts its budget is evicted with a partial
//! [`Completion`] (`complete == false`). Because every active session
//! participates in every batched step, the budget is counted in steps the
//! session itself ran — an interleaving-invariant measure — so the tokens
//! of a deadline-evicted session still match its solo stream prefix.

use std::collections::VecDeque;

use crate::bail;
use crate::config::ModelConfig;
use crate::error::Result;
use crate::model::decode::{Decoder, KvCache, StepInput};
use crate::model::ParamView;
use crate::tensor::arena;
use crate::tensor::ops::{self, SampleSpec};
use crate::tensor::paged::PagePool;
use crate::tensor::Tensor;
use crate::util::knobs;
use crate::util::rng::Rng;

/// Scheduler shape knobs (`LIGO_DECODE_*`).
#[derive(Debug, Clone, Copy)]
pub struct ServeOptions {
    /// Max concurrent sessions per batched step.
    pub max_sessions: usize,
    /// Tokens per KV page (per layer, per K/V side).
    pub page_tokens: usize,
    /// KV page-pool cap; 0 = unbounded. When set, admission reserves each
    /// session's worst-case pages and exerts backpressure at the cap.
    pub max_pages: usize,
}

impl ServeOptions {
    pub fn from_env() -> ServeOptions {
        ServeOptions {
            max_sessions: knobs::usize_env("LIGO_DECODE_SESSIONS").unwrap_or(4).max(1),
            page_tokens: knobs::usize_env("LIGO_DECODE_PAGE").unwrap_or(16).max(1),
            max_pages: 0,
        }
    }
}

/// One generation request. `seed` fully determines the sampling draws, so
/// a request replayed through any scheduler produces the same stream.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    /// Tokens to generate (>= 1).
    pub max_new: usize,
    pub top_k: usize,
    pub top_p: f32,
    pub seed: u64,
    /// Decode-step budget for this session; 0 = unlimited. A session that
    /// runs this many batched steps without finishing is evicted with a
    /// partial [`Completion`].
    pub deadline_steps: u64,
}

/// A finished session: the generated tokens (prompt excluded).
/// `complete == false` marks a deadline eviction — the stream is a prefix
/// of what the request would have produced with an unlimited budget.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Completion {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    pub complete: bool,
}

struct Session {
    id: u64,
    prompt_len: usize,
    max_new: usize,
    top_k: usize,
    top_p: f32,
    rng: Rng,
    /// Generated tokens so far; the last one is the next step's feed.
    generated: Vec<i32>,
    /// Worst-case pages reserved for this session at admission (0 when
    /// the pool is uncapped).
    reserved: usize,
    deadline_steps: u64,
    /// Batched decode steps this session has participated in. Every active
    /// session steps each tick, so this count is interleaving-invariant.
    steps_taken: u64,
}

impl Session {
    fn done(&self) -> bool {
        self.generated.len() >= self.max_new
    }

    fn expired(&self) -> bool {
        self.deadline_steps > 0 && self.steps_taken >= self.deadline_steps
    }
}

/// Worst-case KV pages a session can ever hold: one K and one V table per
/// layer, each spanning every position the session may write.
fn session_pages(cfg: &ModelConfig, page_tokens: usize, prompt_len: usize, max_new: usize) -> usize {
    cfg.layers * 2 * (prompt_len + max_new).div_ceil(page_tokens)
}

/// The continuous-batching scheduler: one decoder, one page pool, a FIFO
/// of pending requests, and the parallel `active`/`caches` session lists.
pub struct Scheduler<'a> {
    dec: &'a Decoder<'a>,
    opts: ServeOptions,
    pool: PagePool,
    queue: VecDeque<Request>,
    active: Vec<Session>,
    caches: Vec<KvCache>,
    done: Vec<Completion>,
    generated: u64,
    steps: u64,
    /// Sum of the active sessions' worst-case page reservations.
    reserved: usize,
}

impl<'a> Scheduler<'a> {
    pub fn new(dec: &'a Decoder<'a>, opts: ServeOptions) -> Scheduler<'a> {
        let page_floats = opts.page_tokens * dec.cfg().dim;
        Scheduler {
            dec,
            opts,
            pool: PagePool::with_capacity(page_floats, opts.max_pages),
            queue: VecDeque::new(),
            active: Vec::new(),
            caches: Vec::new(),
            done: Vec::new(),
            generated: 0,
            steps: 0,
            reserved: 0,
        }
    }

    /// Enqueue a request; validation happens here so `step` cannot fail on
    /// malformed input mid-flight.
    pub fn submit(&mut self, req: Request) -> Result<()> {
        let cfg = self.dec.cfg();
        if req.prompt.is_empty() {
            bail!("request {}: empty prompt", req.id);
        }
        if req.max_new == 0 {
            bail!("request {}: max_new must be >= 1", req.id);
        }
        if req.prompt.len() + req.max_new > cfg.seq {
            bail!(
                "request {}: prompt {} + max_new {} exceeds seq {}",
                req.id,
                req.prompt.len(),
                req.max_new,
                cfg.seq
            );
        }
        if let Some(&bad) = req.prompt.iter().find(|&&t| t < 0 || t as usize >= cfg.vocab) {
            bail!("request {}: token {bad} outside vocab {}", req.id, cfg.vocab);
        }
        if self.opts.max_pages > 0 {
            let need = session_pages(cfg, self.opts.page_tokens, req.prompt.len(), req.max_new);
            if need > self.opts.max_pages {
                bail!(
                    "request {}: needs {need} KV pages but the pool is capped at {} — \
                     can never be admitted",
                    req.id,
                    self.opts.max_pages
                );
            }
        }
        self.queue.push_back(req);
        Ok(())
    }

    /// Total tokens sampled (first tokens + decode steps) and batched
    /// steps run — the decode-throughput bench's numerator/denominator.
    pub fn stats(&self) -> (u64, u64) {
        (self.generated, self.steps)
    }

    pub fn pool(&self) -> &PagePool {
        &self.pool
    }

    pub fn active_sessions(&self) -> usize {
        self.active.len()
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Drain the finished sessions accumulated so far.
    pub fn take_done(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.done)
    }

    /// Sample one token per row of `xf` through the streaming head and
    /// recycle `xf`.
    fn sample(&mut self, xf: Tensor, specs: &[SampleSpec]) -> Vec<i32> {
        let (w, b) = self.dec.head();
        let toks = ops::lm_head_sample(&xf, w, Some(b), specs);
        arena::recycle(xf);
        self.generated += toks.len() as u64;
        toks.into_iter().map(|t| t as i32).collect()
    }

    fn admit(&mut self) -> Result<()> {
        let cfg = self.dec.cfg();
        while self.active.len() < self.opts.max_sessions {
            let Some(front) = self.queue.front() else { break };
            // capped pool: reserve the head's worst case or block. Strict
            // FIFO with head-of-line blocking — never skip ahead to a
            // smaller request, so the admission order (and with it every
            // batch composition downstream) is a pure function of the
            // submit order.
            let need = if self.opts.max_pages > 0 {
                let n =
                    session_pages(cfg, self.opts.page_tokens, front.prompt.len(), front.max_new);
                if self.reserved + n > self.opts.max_pages {
                    break;
                }
                n
            } else {
                0
            };
            let req = self.queue.pop_front().expect("front() was Some");
            let mut cache =
                KvCache::new(cfg.layers, self.opts.page_tokens, cfg.dim, cfg.seq);
            let xf = self.dec.prefill(&req.prompt, &mut cache, &mut self.pool)?;
            // sample the first token from the last prompt row only
            let d = cfg.dim;
            let last = &xf.f32s()[(req.prompt.len() - 1) * d..req.prompt.len() * d];
            let xrow = Tensor::from_f32(&[1, d], arena::alloc_copy(last));
            arena::recycle(xf);
            let mut sess = Session {
                id: req.id,
                prompt_len: req.prompt.len(),
                max_new: req.max_new,
                top_k: req.top_k,
                top_p: req.top_p,
                rng: Rng::new(req.seed),
                generated: Vec::new(),
                reserved: need,
                deadline_steps: req.deadline_steps,
                steps_taken: 0,
            };
            self.reserved += need;
            let spec = SampleSpec { top_k: sess.top_k, top_p: sess.top_p, u: sess.rng.next_f32() };
            let first = self.sample(xrow, &[spec])[0];
            sess.generated.push(first);
            self.active.push(sess);
            self.caches.push(cache);
        }
        Ok(())
    }

    fn evict_finished(&mut self) {
        let mut s = 0;
        while s < self.active.len() {
            if self.active[s].done() || self.active[s].expired() {
                let sess = self.active.swap_remove(s);
                let mut cache = self.caches.swap_remove(s);
                cache.release(&mut self.pool);
                self.reserved -= sess.reserved;
                self.done.push(Completion {
                    id: sess.id,
                    prompt_len: sess.prompt_len,
                    complete: sess.done(),
                    tokens: sess.generated,
                });
            } else {
                s += 1;
            }
        }
    }

    /// One scheduler tick: admit into free slots (subject to page
    /// backpressure), run one batched decode step over every active
    /// session, evict the finished and the deadline-expired. Returns
    /// `false` once both the queue and the active set are empty.
    pub fn step(&mut self) -> Result<bool> {
        self.admit()?;
        self.evict_finished(); // max_new == 1 sessions finish at admit
        if !self.active.is_empty() {
            let feeds: Vec<StepInput> = self
                .active
                .iter()
                .zip(&self.caches)
                .map(|(sess, cache)| StepInput {
                    token: *sess.generated.last().expect("active sessions hold >= 1 token"),
                    pos: cache.len(),
                })
                .collect();
            let xf = self.dec.decode_step(&feeds, &mut self.caches, &mut self.pool)?;
            let specs: Vec<SampleSpec> = self
                .active
                .iter_mut()
                .map(|sess| SampleSpec {
                    top_k: sess.top_k,
                    top_p: sess.top_p,
                    u: sess.rng.next_f32(),
                })
                .collect();
            let toks = self.sample(xf, &specs);
            for (sess, tok) in self.active.iter_mut().zip(toks) {
                sess.generated.push(tok);
                sess.steps_taken += 1;
            }
            self.steps += 1;
            self.evict_finished();
        }
        Ok(!(self.active.is_empty() && self.queue.is_empty()))
    }

    /// Run until every submitted request completes.
    pub fn run(&mut self) -> Result<()> {
        while self.step()? {}
        Ok(())
    }
}

/// Deterministic request mix for the self-test: mixed prompt lengths and
/// generation budgets, clamped into `cfg.seq`.
fn self_test_requests(cfg: &ModelConfig) -> Vec<Request> {
    let mut rng = Rng::new(0x5e12e);
    [(3usize, 5usize), (5, 3), (8, 6), (13, 2)]
        .iter()
        .enumerate()
        .map(|(i, &(plen, max_new))| {
            let plen = plen.min(cfg.seq.saturating_sub(max_new).max(1));
            let prompt: Vec<i32> = (0..plen).map(|_| rng.below(cfg.vocab) as i32).collect();
            Request {
                id: i as u64,
                prompt,
                max_new: max_new.min(cfg.seq - plen).max(1),
                top_k: [1, 4, 8, 2][i],
                top_p: [1.0, 0.9, 0.7, 1.0][i],
                seed: 1000 + i as u64,
                deadline_steps: 0,
            }
        })
        .collect()
}

fn run_requests<'a>(
    dec: &'a Decoder<'a>,
    opts: ServeOptions,
    reqs: &[Request],
    staggered: bool,
) -> Result<Vec<Completion>> {
    let mut sched = Scheduler::new(dec, opts);
    if staggered {
        // admit half, tick twice mid-flight, then admit the rest — an
        // interleaving with sessions at different depths per step
        for r in &reqs[..reqs.len() / 2] {
            sched.submit(r.clone())?;
        }
        sched.step()?;
        sched.step()?;
        for r in &reqs[reqs.len() / 2..] {
            sched.submit(r.clone())?;
        }
    } else {
        for r in reqs {
            sched.submit(r.clone())?;
        }
    }
    sched.run()?;
    if sched.pool().live() != 0 {
        bail!("scheduler leaked {} live pages", sched.pool().live());
    }
    let mut done = sched.take_done();
    done.sort_by_key(|c| c.id);
    Ok(done)
}

/// The CI `ligo serve --self-test` body: a scripted 4-session decode with
/// mixed prompt lengths, checked for scheduler-interleaving invariance
/// (batched and staggered runs must reproduce each session's solo stream),
/// page hygiene, and a zero-fresh-allocation steady state. Returns a
/// printable summary line.
pub fn self_test<P: ParamView>(cfg: &ModelConfig, params: &P) -> Result<String> {
    let dec = Decoder::new(cfg, params)?;
    let opts = ServeOptions {
        page_tokens: ServeOptions::from_env().page_tokens,
        max_sessions: 4,
        max_pages: 0,
    };
    let reqs = self_test_requests(cfg);

    // per-session ground truth: each request decoded entirely alone
    let solo_opts = ServeOptions { max_sessions: 1, ..opts };
    let mut solo = Vec::new();
    for r in &reqs {
        solo.extend(run_requests(&dec, solo_opts, std::slice::from_ref(r), false)?);
    }
    for interleaving in [false, true] {
        let got = run_requests(&dec, opts, &reqs, interleaving)?;
        if got != solo {
            bail!(
                "interleaving changed a token stream (staggered={interleaving}): \
                 {got:?} vs solo {solo:?}"
            );
        }
    }

    // steady state: a warmed scheduler re-running the same mix must touch
    // neither the allocator nor fresh pages
    let mut sched = Scheduler::new(&dec, opts);
    for r in &reqs {
        sched.submit(r.clone())?;
    }
    sched.run()?;
    let fresh_pages = sched.pool().stats().0;
    arena::reset_stats();
    for r in &reqs {
        sched.submit(r.clone())?;
    }
    sched.run()?;
    let (fresh, _) = arena::stats();
    if arena::enabled() && fresh != 0 {
        bail!("steady-state serve performed {fresh} fresh allocations");
    }
    if sched.pool().stats().0 != fresh_pages {
        bail!(
            "steady-state serve created fresh pages: {} -> {}",
            fresh_pages,
            sched.pool().stats().0
        );
    }
    let (tokens, steps) = sched.stats();
    Ok(format!(
        "serve self-test OK: {} sessions x2 runs, {tokens} tokens in {steps} batched steps, \
         {} pages pooled",
        reqs.len(),
        sched.pool().total()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::param_shapes;
    use crate::tensor::store::Store;

    fn gpt_cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny_gpt".into(),
            family: "gpt".into(),
            layers: 2,
            dim: 8,
            heads: 2,
            vocab: 24,
            seq: 16,
            batch: 2,
            img: 0,
            patch: 0,
            channels: 3,
            n_classes: 0,
            cls_layers: 0,
            ffn_mult: 4,
        }
    }

    #[test]
    fn submit_validates_requests() {
        let cfg = gpt_cfg();
        let params = Store::det_init(&param_shapes(&cfg), 1);
        let dec = Decoder::new(&cfg, &params).unwrap();
        let opts = ServeOptions { max_sessions: 2, page_tokens: 4, max_pages: 0 };
        let mut sched = Scheduler::new(&dec, opts);
        let ok = Request {
            id: 0,
            prompt: vec![1, 2],
            max_new: 3,
            top_k: 1,
            top_p: 1.0,
            seed: 7,
            deadline_steps: 0,
        };
        sched.submit(ok.clone()).unwrap();
        assert!(sched.submit(Request { prompt: vec![], ..ok.clone() }).is_err());
        assert!(sched.submit(Request { max_new: 0, ..ok.clone() }).is_err());
        assert!(sched.submit(Request { prompt: vec![99], ..ok.clone() }).is_err());
        assert!(sched
            .submit(Request { prompt: vec![1; cfg.seq], max_new: 1, ..ok.clone() })
            .is_err());
        sched.run().unwrap();
        let done = sched.take_done();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens.len(), 3);
        assert!(done[0].complete);
        assert_eq!(sched.pool().live(), 0);
    }

    #[test]
    fn capped_pool_backpressure_serializes_admission_without_changing_streams() {
        let cfg = gpt_cfg();
        let params = Store::det_init(&param_shapes(&cfg), 5);
        let dec = Decoder::new(&cfg, &params).unwrap();
        let mk = |i: u64| Request {
            id: i,
            prompt: vec![1, 2, 3, 4],
            max_new: 3,
            top_k: 4,
            top_p: 0.9,
            seed: 50 + i,
            deadline_steps: 0,
        };
        let uncapped = ServeOptions { max_sessions: 3, page_tokens: 4, max_pages: 0 };
        let mut solo = Vec::new();
        for i in 0..3 {
            let mut s = Scheduler::new(&dec, ServeOptions { max_sessions: 1, ..uncapped });
            s.submit(mk(i)).unwrap();
            s.run().unwrap();
            solo.extend(s.take_done());
        }
        // one session needs layers*2*ceil((4+3)/4) = 8 pages, so an 8-page
        // cap admits exactly one at a time even with 3 free slots
        let mut s = Scheduler::new(&dec, ServeOptions { max_pages: 8, ..uncapped });
        for i in 0..3 {
            s.submit(mk(i)).unwrap();
        }
        loop {
            let more = s.step().unwrap();
            assert!(s.active_sessions() <= 1, "backpressure must hold admissions at the cap");
            assert!(s.pool().total() <= 8, "pool grew past its cap");
            if !more {
                break;
            }
        }
        let mut done = s.take_done();
        done.sort_by_key(|c| c.id);
        assert_eq!(done, solo, "backpressure changed a token stream");
        assert!(done.iter().all(|c| c.complete));
        assert_eq!(s.pool().live(), 0);
    }

    #[test]
    fn deadline_evicts_with_a_partial_prefix_completion() {
        let cfg = gpt_cfg();
        let params = Store::det_init(&param_shapes(&cfg), 6);
        let dec = Decoder::new(&cfg, &params).unwrap();
        let opts = ServeOptions { max_sessions: 2, page_tokens: 4, max_pages: 0 };
        let full = Request {
            id: 0,
            prompt: vec![3, 1, 4],
            max_new: 8,
            top_k: 4,
            top_p: 0.9,
            seed: 9,
            deadline_steps: 0,
        };
        let mut s = Scheduler::new(&dec, opts);
        s.submit(full.clone()).unwrap();
        s.run().unwrap();
        let reference = s.take_done().pop().unwrap();
        assert!(reference.complete);
        assert_eq!(reference.tokens.len(), 8);

        // a 3-step budget yields 1 admit token + 3 decode tokens, then a
        // partial completion that prefixes the unlimited stream
        let mut s = Scheduler::new(&dec, opts);
        s.submit(Request { deadline_steps: 3, ..full.clone() }).unwrap();
        s.run().unwrap();
        let partial = s.take_done().pop().unwrap();
        assert!(!partial.complete);
        assert_eq!(partial.tokens.len(), 4);
        assert_eq!(partial.tokens[..], reference.tokens[..4], "partial stream must be a prefix");
        assert_eq!(s.pool().live(), 0, "deadline eviction must release its pages");

        // the cut point is interleaving-invariant: a long-running peer in
        // the same batch must not move it
        let peer = Request { id: 1, seed: 77, max_new: 6, ..full.clone() };
        let mut s = Scheduler::new(&dec, opts);
        s.submit(Request { deadline_steps: 3, ..full }).unwrap();
        s.submit(peer).unwrap();
        s.run().unwrap();
        let mut done = s.take_done();
        done.sort_by_key(|c| c.id);
        assert_eq!(done[0].tokens, partial.tokens, "peer interleaving moved the deadline cut");
        assert!(!done[0].complete);
        assert!(done[1].complete);
        assert_eq!(s.pool().live(), 0);
    }

    #[test]
    fn never_fitting_request_is_rejected_at_submit_not_mid_flight() {
        let cfg = gpt_cfg();
        let params = Store::det_init(&param_shapes(&cfg), 7);
        let dec = Decoder::new(&cfg, &params).unwrap();
        let opts = ServeOptions { max_sessions: 2, page_tokens: 4, max_pages: 4 };
        let mut s = Scheduler::new(&dec, opts);
        // needs layers*2*ceil((6+6)/4) = 12 pages against a 4-page cap
        let big = Request {
            id: 0,
            prompt: vec![1; 6],
            max_new: 6,
            top_k: 1,
            top_p: 1.0,
            seed: 1,
            deadline_steps: 0,
        };
        let err = s.submit(big).unwrap_err().to_string();
        assert!(err.contains("capped at 4"), "{err}");
        assert_eq!(s.queued(), 0, "rejected request must not enter the queue");
        // a fitting request (exactly 4 pages) still flows to completion
        let small = Request {
            id: 1,
            prompt: vec![2],
            max_new: 1,
            top_k: 1,
            top_p: 1.0,
            seed: 2,
            deadline_steps: 0,
        };
        s.submit(small).unwrap();
        s.run().unwrap();
        let done = s.take_done();
        assert_eq!(done.len(), 1);
        assert!(done[0].complete);
        assert_eq!(s.pool().live(), 0);
    }

    #[test]
    fn self_test_passes_on_a_tiny_gpt() {
        let cfg = gpt_cfg();
        let params = Store::det_init(&param_shapes(&cfg), 2);
        let line = self_test(&cfg, &params).unwrap();
        assert!(line.contains("OK"), "{line}");
    }

    #[test]
    fn serve_options_env_defaults_are_sane() {
        let o = ServeOptions::from_env();
        assert!(o.max_sessions >= 1);
        assert!(o.page_tokens >= 1);
    }
}
