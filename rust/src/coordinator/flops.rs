//! Analytic FLOPs model — the x-axis of every figure in the paper.
//!
//! Counts multiply-accumulates as 2 FLOPs. Backward ~= 2x forward, so a
//! training step is ~3x forward (the convention the paper's FLOPs savings
//! follow). The gated variants (layer/token dropping) and LiGO's own
//! M-step overhead (Table 3) are accounted explicitly.

use crate::config::ModelConfig;

/// Forward FLOPs per *token* for one transformer layer.
pub fn layer_flops_per_token(cfg: &ModelConfig) -> f64 {
    let d = cfg.dim as f64;
    let f = cfg.ffn() as f64;
    let s = cfg.tokens() as f64;
    // qkv + o projections: 4 matmuls (d x d)
    let proj = 8.0 * d * d;
    // attention scores + weighted values: 2 * (s * d) MACs per token
    let attn = 4.0 * s * d;
    // ffn: d->f and f->d
    let ffn = 4.0 * d * f;
    proj + attn + ffn
}

/// Forward FLOPs for one full batch.
pub fn forward_flops(cfg: &ModelConfig) -> f64 {
    let tokens = cfg.tokens_per_batch() as f64;
    let layers = (cfg.layers + cfg.cls_layers) as f64;
    let mut per_token = layers * layer_flops_per_token(cfg);
    if cfg.is_vision() {
        // patch embedding + head
        let pdim = (cfg.patch * cfg.patch * cfg.channels) as f64;
        per_token += 2.0 * pdim * cfg.dim as f64;
        per_token += 2.0 * (cfg.n_classes as f64) * cfg.dim as f64 / cfg.tokens() as f64;
    } else {
        // tied LM head: d x vocab per token
        per_token += 2.0 * cfg.dim as f64 * cfg.vocab as f64;
    }
    tokens * per_token
}

/// Training-step FLOPs (fwd + bwd ~ 3x fwd) for one batch.
pub fn train_step_flops(cfg: &ModelConfig) -> f64 {
    3.0 * forward_flops(cfg)
}

/// Training-step FLOPs with Fig. 5 gating: `layer_keep` = expected fraction
/// of layers active, `token_keep` = expected fraction of tokens kept in the
/// gated middle third.
pub fn gated_train_step_flops(cfg: &ModelConfig, layer_keep: f64, token_keep: f64) -> f64 {
    let body = train_step_flops(cfg) - head_flops(cfg);
    // middle third of layers sees reduced tokens
    let token_factor = (2.0 + token_keep) / 3.0;
    body * layer_keep * token_factor + head_flops(cfg)
}

fn head_flops(cfg: &ModelConfig) -> f64 {
    if cfg.is_vision() {
        3.0 * 2.0 * (cfg.n_classes * cfg.dim * cfg.batch) as f64
    } else {
        3.0 * 2.0 * (cfg.dim * cfg.vocab) as f64 * cfg.tokens_per_batch() as f64
    }
}

/// FLOPs of materializing the large model from (M, Theta_small) once:
/// per layer, six fused triple products B W A^T (two matmul stages each).
pub fn ligo_apply_flops(small: &ModelConfig, large: &ModelConfig) -> f64 {
    let (d1, d2) = (small.dim as f64, large.dim as f64);
    let (f1, f2) = (small.ffn() as f64, large.ffn() as f64);
    let l1 = small.layers as f64;
    // W A^T: (d1 x d1) @ (d1 x d2); B (...): (d2 x d1) @ (d1 x d2)
    let square = 2.0 * d1 * d1 * d2 + 2.0 * d2 * d1 * d2;
    let fc1 = 2.0 * f1 * d1 * d2 + 2.0 * f2 * f1 * d2;
    let fc2 = 2.0 * d1 * f1 * f2 + 2.0 * d2 * d1 * f2;
    let depth_blend = (large.layers as f64) * l1 * (4.0 * d2 * d2 + 2.0 * d2 * f2) * 2.0;
    l1 * (4.0 * square + fc1 + fc2) + depth_blend
        + 2.0 * (small.vocab as f64) * d1 * d2 // embedding growth
}

/// FLOPs of one LiGO M-gradient step (Table 3's "+FLOPs" column):
/// apply + large-model fwd/bwd + backprop through the expansion (~apply x2).
pub fn ligo_step_flops(small: &ModelConfig, large: &ModelConfig) -> f64 {
    3.0 * ligo_apply_flops(small, large) + train_step_flops(large)
}

/// FLOPs of one *native* surrogate M-step (growth_manager fallback path):
/// forward expansion + analytic gradients through `B W A^T` (~apply x2),
/// with no large-model fwd/bwd — that is exactly what the surrogate saves.
pub fn ligo_native_step_flops(small: &ModelConfig, large: &ModelConfig) -> f64 {
    3.0 * ligo_apply_flops(small, large)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::growth::testutil::mk_cfg;

    #[test]
    fn flops_monotonic_in_width_and_depth() {
        let base = train_step_flops(&mk_cfg(6, 72, 6));
        assert!(train_step_flops(&mk_cfg(6, 96, 6)) > base);
        assert!(train_step_flops(&mk_cfg(12, 72, 6)) > base);
        assert!(train_step_flops(&mk_cfg(3, 48, 4)) < base);
    }

    #[test]
    fn bwd_is_twice_fwd() {
        let cfg = mk_cfg(6, 72, 6);
        assert!((train_step_flops(&cfg) / forward_flops(&cfg) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn gating_reduces_flops() {
        let cfg = mk_cfg(6, 72, 6);
        let full = gated_train_step_flops(&cfg, 1.0, 1.0);
        let dropped = gated_train_step_flops(&cfg, 0.9, 0.85);
        assert!(dropped < full);
        assert!((full - train_step_flops(&cfg)).abs() / full < 1e-9);
    }

    #[test]
    fn ligo_step_overhead_is_modest_multiple_of_train_step() {
        // Table 3's premise: 100 M-steps are negligible vs 100s of thousands
        // of training steps; one M-step must be a small multiple of a train
        // step.
        let s = mk_cfg(3, 48, 4);
        let l = mk_cfg(6, 72, 6);
        let ratio = ligo_step_flops(&s, &l) / train_step_flops(&l);
        assert!(ratio > 1.0 && ratio < 10.0, "ratio {ratio}");
    }

    #[test]
    fn native_step_is_cheaper_than_task_loss_step() {
        let s = mk_cfg(3, 48, 4);
        let l = mk_cfg(6, 72, 6);
        assert!(ligo_native_step_flops(&s, &l) < ligo_step_flops(&s, &l));
        assert!(ligo_native_step_flops(&s, &l) > 0.0);
    }

    #[test]
    fn paper_scale_sanity() {
        // BERT-Base-scale config: step FLOPs should be ~1e11-1e12 per batch
        // of 16x32 tokens at dim 768 — the right order of magnitude.
        let mut cfg = mk_cfg(12, 768, 12);
        cfg.vocab = 30522;
        let f = train_step_flops(&cfg);
        assert!(f > 1e10 && f < 1e13, "{f:e}");
    }
}
