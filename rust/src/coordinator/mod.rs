//! The L3 coordinator: everything that happens at runtime happens here.
//!
//! * [`optim`] — AdamW / SGD-momentum over the named tensor store
//! * [`flops`] — the analytic FLOPs ledger behind every figure's x-axis
//! * [`metrics`] — loss curves, savings-at-threshold, CSV/JSON reports
//! * [`trainer`] — the step loop (accumulation, freezing, eval hooks)
//! * [`growth_manager`] — LiGO: init M, run the 100 M-SGD steps through the
//!   `ligo_grad` artifact, apply, hand off to the trainer
//! * [`strategies`] — layer dropping / token dropping / staged training (Fig. 5)

pub mod flops;
pub mod growth_manager;
pub mod metrics;
pub mod optim;
pub mod strategies;
pub mod trainer;
