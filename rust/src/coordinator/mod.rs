//! The L3 coordinator: everything that happens at runtime happens here.
//!
//! * [`optim`] — AdamW / SGD-momentum over the named tensor store
//! * [`flops`] — the analytic FLOPs ledger behind every figure's x-axis
//! * [`metrics`] — loss curves, savings-at-threshold, CSV/JSON reports
//! * [`trainer`] — the step loop (accumulation, freezing, eval hooks) and
//!   mid-run [`plan::GrowthPlan`] execution
//! * [`checkpoint`] — crash-safe full-state snapshots (params + optimizer
//!   moments + plan cursor + curve + FLOPs) with retention and
//!   corrupt-newest fallback; resume is bit-identical to an uninterrupted
//!   run
//! * [`parallel`] — the `LIGO_WORKERS` sharded data-parallel worker pool:
//!   per-worker microbatch shards feeding the deterministic tree all-reduce
//!   (`util::allreduce`), bit-identical to the serial path for any worker
//!   count
//! * [`growth_manager`] — LiGO route selection behind the unified
//!   `growth::GrowthContext` entry point: artifact / native task loss /
//!   surrogate, chosen exactly once per grow
//! * [`plan`] — builder-validated multi-stage growth schedules (2-stage
//!   LiGO, progressive stacking)
//! * [`strategies`] — layer dropping / token dropping / staged training (Fig. 5)
//! * [`serve`] — the `ligo serve` continuous-batching decode scheduler:
//!   paged KV sessions multiplexed through one batched decode step, with
//!   interleaving-invariant per-session token streams

pub mod checkpoint;
pub mod flops;
pub mod growth_manager;
pub mod metrics;
pub mod optim;
pub mod parallel;
pub mod plan;
pub mod serve;
pub mod strategies;
pub mod trainer;
