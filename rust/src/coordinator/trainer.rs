//! The training loop: runs the `grad_*` artifact per microbatch, accumulates,
//! applies AdamW with the schedule, and records (step, FLOPs, wall, loss)
//! into a [`Curve`]. Evaluation runs the `fwd_*` artifact on held-out
//! batches.

use std::sync::Arc;

use crate::bail;
use crate::config::{ModelConfig, TrainConfig};
use crate::error::Result;
use crate::coordinator::flops;
use crate::coordinator::metrics::Curve;
use crate::coordinator::optim::{accumulate, AdamW};
use crate::runtime::{Executable, Runtime};
use crate::tensor::store::Store;
use crate::util::timer::Timer;

/// Batch source abstraction: step -> batch Store (train) and eval batches.
pub struct Batches {
    pub train: Box<dyn FnMut(usize) -> Store>,
    pub eval: Box<dyn FnMut(usize) -> Store>,
}

/// Trainer state for one model.
pub struct Trainer {
    pub cfg: ModelConfig,
    pub tc: TrainConfig,
    pub params: Store,
    pub opt: AdamW,
    grad_exe: Arc<Executable>,
    fwd_exe: Arc<Executable>,
    /// FLOPs already spent before step 0 (growth cost, prior training).
    pub flops_offset: f64,
    pub wall_offset: f64,
    /// Override per-microbatch step FLOPs (gated strategies).
    pub flops_per_microbatch: f64,
    /// Extra input-group bindings (e.g. the KD teacher's parameters).
    pub extra: Vec<(String, Store)>,
    step: usize,
}

impl Trainer {
    /// Build a trainer for a preset; params must already be initialized
    /// (det-init for scratch, a growth operator's output otherwise).
    pub fn new(rt: &Runtime, cfg: &ModelConfig, tc: TrainConfig, params: Store) -> Result<Trainer> {
        let grad = format!("grad_{}", cfg.name);
        let fwd = format!("fwd_{}", cfg.name);
        Self::with_artifacts(rt, &grad, &fwd, cfg, tc, params)
    }

    /// Build against explicit artifact names (KD / gated variants).
    pub fn with_artifacts(
        rt: &Runtime,
        grad_name: &str,
        fwd_name: &str,
        cfg: &ModelConfig,
        tc: TrainConfig,
        params: Store,
    ) -> Result<Trainer> {
        let grad_exe = rt.load(grad_name)?;
        let fwd_exe = rt.load(fwd_name)?;
        let opt = AdamW::from_train_config(&params, &tc);
        Ok(Trainer {
            cfg: cfg.clone(),
            tc,
            params,
            opt,
            grad_exe,
            fwd_exe,
            flops_offset: 0.0,
            wall_offset: 0.0,
            flops_per_microbatch: flops::train_step_flops(cfg),
            extra: Vec::new(),
            step: 0,
        })
    }

    /// Scratch init from the artifact manifest shapes.
    pub fn scratch_params(rt: &Runtime, cfg: &ModelConfig, seed: u64) -> Result<Store> {
        let exe = rt.load(&format!("grad_{}", cfg.name))?;
        Ok(Store::det_init(&exe.manifest.shapes_of("params"), seed))
    }

    pub fn step_count(&self) -> usize {
        self.step
    }

    /// One optimizer step (grad_accum microbatches). Returns mean loss.
    ///
    /// Dead gradient stores (each microbatch's after accumulation, the
    /// accumulator after the optimizer consumed it) are recycled into the
    /// thread-local [`crate::tensor::arena`], so on the native backend the
    /// steady-state step allocates no fresh activation/gradient buffers.
    pub fn train_step(&mut self, batches: &mut dyn FnMut(usize) -> Store) -> Result<f32> {
        let accum = self.tc.grad_accum.max(1);
        let mut grads = Store::new();
        let mut loss_sum = 0.0f32;
        for micro in 0..accum {
            let batch = batches(self.step * accum + micro);
            let mut bindings: Vec<(&str, &Store)> =
                vec![("params", &self.params), ("batch", &batch)];
            for (g, s) in &self.extra {
                bindings.push((g.as_str(), s));
            }
            let mut out = self.grad_exe.run(&bindings)?;
            // A backend gap here must fail loudly: a missing loss would
            // silently poison the whole mean-loss curve with NaN, and a
            // missing grads group would previously panic.
            let Some(loss) = out.scalar("loss") else {
                bail!(
                    "grad executable for '{}' returned no 'loss' scalar (outputs: {:?})",
                    self.cfg.name,
                    out.scalars.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>()
                )
            };
            let Some(g) = out.take_group("grads") else {
                bail!(
                    "grad executable for '{}' returned no 'grads' group (groups: {:?})",
                    self.cfg.name,
                    out.groups.keys().collect::<Vec<_>>()
                )
            };
            loss_sum += loss;
            if accum == 1 {
                grads = g; // single microbatch: take ownership, no copy
            } else {
                accumulate(&mut grads, &g, 1.0 / accum as f32);
                crate::tensor::arena::recycle_store(g);
            }
        }
        let lr = self.tc.lr_at(self.step);
        self.opt.step(&mut self.params, &grads, lr);
        crate::tensor::arena::recycle_store(grads);
        self.step += 1;
        Ok(loss_sum / accum as f32)
    }

    /// Held-out evaluation: mean loss (and mean metric if present).
    pub fn evaluate(
        &self,
        eval_batches: &mut dyn FnMut(usize) -> Store,
        n_batches: usize,
    ) -> Result<(f32, Option<f32>)> {
        eval_store(&self.fwd_exe, &self.params, eval_batches, n_batches)
    }

    /// Full training run: returns the curve, evaluating every
    /// `tc.eval_every` steps.
    pub fn run(&mut self, name: &str, batches: &mut Batches, steps: usize) -> Result<Curve> {
        let mut curve = Curve::new(name);
        let timer = Timer::new();
        let accum = self.tc.grad_accum.max(1) as f64;
        let mut spent = self.flops_offset;
        // record the starting point (growth quality shows at step 0)
        let (l0, m0) = self.evaluate(&mut batches.eval, 4)?;
        curve.push(self.step, spent, self.wall_offset, l0, m0);
        for s in 0..steps {
            let _train_loss = self.train_step(&mut batches.train)?;
            spent += self.flops_per_microbatch * accum;
            if (s + 1) % self.tc.eval_every == 0 || s + 1 == steps {
                let (loss, metric) = self.evaluate(&mut batches.eval, 4)?;
                curve.push(self.step, spent, self.wall_offset + timer.elapsed(), loss, metric);
            }
        }
        Ok(curve)
    }
}

/// Evaluate a fwd artifact over n batches: mean loss + mean metric.
/// `n_batches == 0` is a caller bug (the division would push a NaN point
/// onto the curve) and reports an error instead; a missing `loss` output
/// likewise fails loudly rather than corrupting the mean.
pub fn eval_store(
    fwd: &Executable,
    params: &Store,
    eval_batches: &mut dyn FnMut(usize) -> Store,
    n_batches: usize,
) -> Result<(f32, Option<f32>)> {
    if n_batches == 0 {
        bail!("eval_store: n_batches must be > 0 (a 0-batch mean is NaN)");
    }
    let mut loss = 0.0f32;
    let mut metric = 0.0f32;
    let mut has_metric = false;
    for i in 0..n_batches {
        let batch = eval_batches(i);
        let out = fwd.run(&[("params", params), ("batch", &batch)])?;
        let Some(l) = out.scalar("loss") else {
            bail!(
                "fwd executable '{}' returned no 'loss' scalar",
                fwd.manifest.name
            )
        };
        loss += l;
        if let Some(m) = out.scalar("metric") {
            metric += m;
            has_metric = true;
        }
    }
    Ok((
        loss / n_batches as f32,
        has_metric.then_some(metric / n_batches as f32),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ExecEngine, Manifest, TensorSpec};
    use crate::tensor::Tensor;

    /// Engine returning a constant loss but NO grads group / NO loss,
    /// depending on the manifest it is paired with.
    struct Fixed;

    impl ExecEngine for Fixed {
        fn execute(&self, _inputs: &[&Tensor], outputs: &[TensorSpec]) -> Result<Vec<Tensor>> {
            Ok(outputs
                .iter()
                .map(|s| Tensor::from_f32(&s.shape, vec![0.5; s.numel()]))
                .collect())
        }
    }

    fn exe(outputs: &str) -> Executable {
        let manifest = Manifest::parse(&format!(
            r#"{{"name": "t", "inputs": [], "outputs": [{outputs}]}}"#
        ))
        .unwrap();
        Executable::new(manifest, Box::new(Fixed))
    }

    #[test]
    fn eval_store_rejects_zero_batches() {
        let fwd = exe(r#"{"name": "loss", "shape": [], "dtype": "float32"}"#);
        let mut eb = |_i: usize| Store::new();
        let err = eval_store(&fwd, &Store::new(), &mut eb, 0).unwrap_err();
        assert!(err.to_string().contains("n_batches"), "{err}");
        // and the happy path still averages
        let (l, m) = eval_store(&fwd, &Store::new(), &mut eb, 3).unwrap();
        assert_eq!(l, 0.5);
        assert!(m.is_none());
    }

    #[test]
    fn eval_store_errors_when_loss_is_missing() {
        let fwd = exe(r#"{"name": "metric", "shape": [], "dtype": "float32"}"#);
        let mut eb = |_i: usize| Store::new();
        let err = eval_store(&fwd, &Store::new(), &mut eb, 1).unwrap_err();
        assert!(err.to_string().contains("no 'loss'"), "{err}");
    }

    /// Backend whose grad executable omits the grads group (and whose fwd
    /// omits loss): the regression surface for the old panic/NaN paths.
    struct GapBackend;

    impl crate::runtime::Backend for GapBackend {
        fn name(&self) -> &'static str {
            "gap"
        }

        fn compile(
            &self,
            _manifest: &Manifest,
            _hlo: &std::path::Path,
        ) -> Result<Box<dyn ExecEngine>> {
            unreachable!("GapBackend synthesizes everything")
        }

        fn synthesize(&self, name: &str) -> Option<Result<(Manifest, Box<dyn ExecEngine>)>> {
            let outputs = if name.starts_with("grad_") {
                // loss present, grads group absent
                r#"{"name": "loss", "shape": [], "dtype": "float32"}"#
            } else {
                // loss absent entirely
                r#"{"name": "metric", "shape": [], "dtype": "float32"}"#
            };
            let manifest = Manifest::parse(&format!(
                r#"{{"name": "{name}", "inputs": [], "outputs": [{outputs}]}}"#
            ))
            .unwrap();
            Some(Ok((manifest, Box::new(Fixed) as Box<dyn ExecEngine>)))
        }
    }

    #[test]
    fn train_step_bails_on_missing_grads_instead_of_panicking() {
        let rt = crate::runtime::Runtime::with_backend(Box::new(GapBackend), "/tmp");
        let cfg = crate::growth::testutil::mk_cfg(1, 8, 2);
        let tc = TrainConfig::bert(10);
        let mut tr =
            Trainer::with_artifacts(&rt, "grad_x", "fwd_x", &cfg, tc, Store::new()).unwrap();
        let err = tr.train_step(&mut |_s| Store::new()).unwrap_err();
        assert!(err.to_string().contains("no 'grads' group"), "{err}");
    }
}
