//! The training loop: runs the `grad_*` artifact per microbatch, accumulates,
//! applies AdamW with the schedule, and records (step, FLOPs, wall, loss)
//! into a [`Curve`]. Evaluation runs the `fwd_*` artifact on held-out
//! batches.

use std::sync::Arc;

use crate::config::{ModelConfig, TrainConfig};
use crate::error::Result;
use crate::coordinator::flops;
use crate::coordinator::metrics::Curve;
use crate::coordinator::optim::{accumulate, AdamW};
use crate::runtime::{Executable, Runtime};
use crate::tensor::store::Store;
use crate::util::timer::Timer;

/// Batch source abstraction: step -> batch Store (train) and eval batches.
pub struct Batches {
    pub train: Box<dyn FnMut(usize) -> Store>,
    pub eval: Box<dyn FnMut(usize) -> Store>,
}

/// Trainer state for one model.
pub struct Trainer {
    pub cfg: ModelConfig,
    pub tc: TrainConfig,
    pub params: Store,
    pub opt: AdamW,
    grad_exe: Arc<Executable>,
    fwd_exe: Arc<Executable>,
    /// FLOPs already spent before step 0 (growth cost, prior training).
    pub flops_offset: f64,
    pub wall_offset: f64,
    /// Override per-microbatch step FLOPs (gated strategies).
    pub flops_per_microbatch: f64,
    /// Extra input-group bindings (e.g. the KD teacher's parameters).
    pub extra: Vec<(String, Store)>,
    step: usize,
}

impl Trainer {
    /// Build a trainer for a preset; params must already be initialized
    /// (det-init for scratch, a growth operator's output otherwise).
    pub fn new(rt: &Runtime, cfg: &ModelConfig, tc: TrainConfig, params: Store) -> Result<Trainer> {
        let grad = format!("grad_{}", cfg.name);
        let fwd = format!("fwd_{}", cfg.name);
        Self::with_artifacts(rt, &grad, &fwd, cfg, tc, params)
    }

    /// Build against explicit artifact names (KD / gated variants).
    pub fn with_artifacts(
        rt: &Runtime,
        grad_name: &str,
        fwd_name: &str,
        cfg: &ModelConfig,
        tc: TrainConfig,
        params: Store,
    ) -> Result<Trainer> {
        let grad_exe = rt.load(grad_name)?;
        let fwd_exe = rt.load(fwd_name)?;
        let opt = AdamW::from_train_config(&params, &tc);
        Ok(Trainer {
            cfg: cfg.clone(),
            tc,
            params,
            opt,
            grad_exe,
            fwd_exe,
            flops_offset: 0.0,
            wall_offset: 0.0,
            flops_per_microbatch: flops::train_step_flops(cfg),
            extra: Vec::new(),
            step: 0,
        })
    }

    /// Scratch init from the artifact manifest shapes.
    pub fn scratch_params(rt: &Runtime, cfg: &ModelConfig, seed: u64) -> Result<Store> {
        let exe = rt.load(&format!("grad_{}", cfg.name))?;
        Ok(Store::det_init(&exe.manifest.shapes_of("params"), seed))
    }

    pub fn step_count(&self) -> usize {
        self.step
    }

    /// One optimizer step (grad_accum microbatches). Returns mean loss.
    pub fn train_step(&mut self, batches: &mut dyn FnMut(usize) -> Store) -> Result<f32> {
        let accum = self.tc.grad_accum.max(1);
        let mut grads = Store::new();
        let mut loss_sum = 0.0f32;
        for micro in 0..accum {
            let batch = batches(self.step * accum + micro);
            let mut bindings: Vec<(&str, &Store)> =
                vec![("params", &self.params), ("batch", &batch)];
            for (g, s) in &self.extra {
                bindings.push((g.as_str(), s));
            }
            let out = self.grad_exe.run(&bindings)?;
            loss_sum += out.scalar("loss").unwrap_or(f32::NAN);
            let g = out.groups.get("grads").expect("grad artifact returns grads");
            accumulate(&mut grads, g, 1.0 / accum as f32);
        }
        let lr = self.tc.lr_at(self.step);
        self.opt.step(&mut self.params, &grads, lr);
        self.step += 1;
        Ok(loss_sum / accum as f32)
    }

    /// Held-out evaluation: mean loss (and mean metric if present).
    pub fn evaluate(
        &self,
        eval_batches: &mut dyn FnMut(usize) -> Store,
        n_batches: usize,
    ) -> Result<(f32, Option<f32>)> {
        eval_store(&self.fwd_exe, &self.params, eval_batches, n_batches)
    }

    /// Full training run: returns the curve, evaluating every
    /// `tc.eval_every` steps.
    pub fn run(&mut self, name: &str, batches: &mut Batches, steps: usize) -> Result<Curve> {
        let mut curve = Curve::new(name);
        let timer = Timer::new();
        let accum = self.tc.grad_accum.max(1) as f64;
        let mut spent = self.flops_offset;
        // record the starting point (growth quality shows at step 0)
        let (l0, m0) = self.evaluate(&mut batches.eval, 4)?;
        curve.push(self.step, spent, self.wall_offset, l0, m0);
        for s in 0..steps {
            let _train_loss = self.train_step(&mut batches.train)?;
            spent += self.flops_per_microbatch * accum;
            if (s + 1) % self.tc.eval_every == 0 || s + 1 == steps {
                let (loss, metric) = self.evaluate(&mut batches.eval, 4)?;
                curve.push(self.step, spent, self.wall_offset + timer.elapsed(), loss, metric);
            }
        }
        Ok(curve)
    }
}

/// Evaluate a fwd artifact over n batches: mean loss + mean metric.
pub fn eval_store(
    fwd: &Executable,
    params: &Store,
    eval_batches: &mut dyn FnMut(usize) -> Store,
    n_batches: usize,
) -> Result<(f32, Option<f32>)> {
    let mut loss = 0.0f32;
    let mut metric = 0.0f32;
    let mut has_metric = false;
    for i in 0..n_batches {
        let batch = eval_batches(i);
        let out = fwd.run(&[("params", params), ("batch", &batch)])?;
        loss += out.scalar("loss").unwrap_or(f32::NAN);
        if let Some(m) = out.scalar("metric") {
            metric += m;
            has_metric = true;
        }
    }
    Ok((
        loss / n_batches as f32,
        has_metric.then_some(metric / n_batches as f32),
    ))
}
