//! The training loop: runs the `grad_*` artifact per microbatch, accumulates,
//! applies AdamW with the schedule, and records (step, FLOPs, wall, loss)
//! into a [`Curve`]. Evaluation runs the `fwd_*` artifact on held-out
//! batches. [`Trainer::run_plan`] additionally executes a
//! [`GrowthPlan`] mid-run: at each stage's step the parameters grow through
//! the unified `growth` entry point, optimizer state and executables are
//! swapped for the target config, and training continues — with the growth
//! step recorded as a [`Curve`] mark.
//!
//! With `LIGO_WORKERS=N` set (and a [`Batches::shared`] train source) the
//! step loop instead fans each step's microbatches out across the
//! [`parallel`] worker pool, reduces the gradient leaves through the
//! deterministic tree in [`crate::util::allreduce`], and applies the
//! ZeRO-style [`ShardedAdamW`] — bit-identical across worker counts, and
//! resharded automatically when a mid-run growth stage swaps the parameter
//! set ([`Trainer::adopt_grown`]). Unset, the historical serial path runs
//! byte for byte.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::bail;
use crate::config::{ModelConfig, TrainConfig};
use crate::error::{Context, Result};
use crate::coordinator::checkpoint::{self, TrainState};
use crate::coordinator::flops;
use crate::coordinator::metrics::Curve;
use crate::coordinator::optim::{accumulate, ShardedAdamW};
use crate::coordinator::parallel::{self, SharedBatchFn};
use crate::coordinator::plan::{GrowthPlan, GrowthStage};
use crate::log_info;
use crate::runtime::{Executable, RunOutputs, Runtime};
use crate::tensor::{arena, store::Store};
use crate::util::allreduce;
use crate::util::timer::Timer;
use crate::util::{fault, knobs};

/// A train-batch source. [`Serial`](TrainSource::Serial) is the historical
/// stateful closure — it can only be consumed in order, on one thread.
/// [`Shared`](TrainSource::Shared) is a pure function of the global
/// microbatch index, so the `LIGO_WORKERS` pool can pull a worker's shard
/// of indices concurrently; every batch source in this repo that derives
/// its batch from a seeded RNG of the index qualifies.
pub enum TrainSource {
    Serial(Box<dyn FnMut(usize) -> Store>),
    Shared(SharedBatchFn),
}

impl TrainSource {
    /// The next batch for global microbatch index `i` (serial consumption).
    pub fn batch(&mut self, i: usize) -> Store {
        match self {
            TrainSource::Serial(f) => f(i),
            TrainSource::Shared(f) => f(i),
        }
    }

    /// The shareable view, if this source supports parallel consumption.
    pub fn as_shared(&self) -> Option<&SharedBatchFn> {
        match self {
            TrainSource::Serial(_) => None,
            TrainSource::Shared(f) => Some(f),
        }
    }
}

/// Batch source abstraction: step -> batch Store (train) and eval batches.
pub struct Batches {
    pub train: TrainSource,
    pub eval: Box<dyn FnMut(usize) -> Store>,
}

impl Batches {
    /// A serial (stateful) train source: always runs the single-worker
    /// step loop, even under `LIGO_WORKERS` (with a one-time warning).
    pub fn serial(
        train: impl FnMut(usize) -> Store + 'static,
        eval: impl FnMut(usize) -> Store + 'static,
    ) -> Batches {
        Batches { train: TrainSource::Serial(Box::new(train)), eval: Box::new(eval) }
    }

    /// A shareable train source — a pure function of the global microbatch
    /// index — eligible for the `LIGO_WORKERS` parallel step loop.
    pub fn shared(
        train: impl Fn(usize) -> Store + Send + Sync + 'static,
        eval: impl FnMut(usize) -> Store + 'static,
    ) -> Batches {
        Batches { train: TrainSource::Shared(Arc::new(train)), eval: Box::new(eval) }
    }
}

/// Trainer state for one model.
pub struct Trainer {
    pub cfg: ModelConfig,
    pub tc: TrainConfig,
    pub params: Store,
    pub opt: ShardedAdamW,
    grad_exe: Arc<Executable>,
    fwd_exe: Arc<Executable>,
    /// FLOPs already spent before step 0 (growth cost, prior training).
    pub flops_offset: f64,
    pub wall_offset: f64,
    /// Override per-microbatch step FLOPs (gated strategies).
    pub flops_per_microbatch: f64,
    /// Extra input-group bindings (e.g. the KD teacher's parameters).
    pub extra: Vec<(String, Store)>,
    /// Per-worker arena counters from the most recent sharded step
    /// (empty until [`Trainer::train_step_sharded`] has run).
    last_worker_stats: Vec<arena::WorkerStats>,
    step: usize,
    /// Periodic crash-safe checkpointing ([`Trainer::checkpoint_every`]).
    ckpt: Option<CkptCfg>,
}

/// Periodic checkpoint settings: cadence, directory, retention.
#[derive(Clone)]
struct CkptCfg {
    every: usize,
    dir: PathBuf,
    keep: usize,
}

/// The run-loop context a resumed run carries beyond the trainer fields:
/// the curve recorded so far, the growth-plan stage cursor, and the global
/// step at which the interrupted `run*` call started (which anchors the
/// eval cadence and the step budget).
pub struct Resumed {
    pub curve: Curve,
    pub next_stage: usize,
    pub run_start: usize,
}

impl Trainer {
    /// Build a trainer for a preset; params must already be initialized
    /// (det-init for scratch, a growth operator's output otherwise).
    pub fn new(rt: &Runtime, cfg: &ModelConfig, tc: TrainConfig, params: Store) -> Result<Trainer> {
        let grad = format!("grad_{}", cfg.name);
        let fwd = format!("fwd_{}", cfg.name);
        Self::with_artifacts(rt, &grad, &fwd, cfg, tc, params)
    }

    /// Build against explicit artifact names (KD / gated variants).
    pub fn with_artifacts(
        rt: &Runtime,
        grad_name: &str,
        fwd_name: &str,
        cfg: &ModelConfig,
        tc: TrainConfig,
        params: Store,
    ) -> Result<Trainer> {
        let grad_exe = rt.load(grad_name)?;
        let fwd_exe = rt.load(fwd_name)?;
        // moment shards sized for the requested worker pool up front; the
        // sharded step lazily reshards if the active count differs
        let shards = parallel::requested_workers().unwrap_or(1);
        let opt = ShardedAdamW::from_train_config(&params, &tc, shards);
        Ok(Trainer {
            cfg: cfg.clone(),
            tc,
            params,
            opt,
            grad_exe,
            fwd_exe,
            flops_offset: 0.0,
            wall_offset: 0.0,
            flops_per_microbatch: flops::train_step_flops(cfg),
            extra: Vec::new(),
            last_worker_stats: Vec::new(),
            step: 0,
            ckpt: None,
        })
    }

    /// Scratch init from the artifact manifest shapes.
    pub fn scratch_params(rt: &Runtime, cfg: &ModelConfig, seed: u64) -> Result<Store> {
        let exe = rt.load(&format!("grad_{}", cfg.name))?;
        Ok(Store::det_init(&exe.manifest.shapes_of("params"), seed))
    }

    pub fn step_count(&self) -> usize {
        self.step
    }

    /// Enable periodic crash-safe checkpointing: every `every` optimizer
    /// steps (0 disables) the full [`TrainState`] — params, optimizer
    /// moments + step, plan cursor, curve, FLOPs — is written atomically
    /// under `dir`, retaining the newest `LIGO_CKPT_KEEP` (default 3)
    /// snapshots. Resuming from any of them reproduces the uninterrupted
    /// run bit for bit ([`Trainer::resume`]).
    pub fn checkpoint_every(&mut self, every: usize, dir: impl Into<PathBuf>) {
        if every == 0 {
            self.ckpt = None;
            return;
        }
        let keep = knobs::usize_env("LIGO_CKPT_KEEP").unwrap_or(3).max(1);
        self.ckpt = Some(CkptCfg { every, dir: dir.into(), keep });
    }

    /// Capture the full training state at the current step (the data
    /// cursor is `step` itself: batch sources are index-pure).
    fn snapshot(
        &self,
        run_start: usize,
        next_stage: usize,
        flops_spent: f64,
        wall_s: f64,
        curve: &Curve,
    ) -> TrainState {
        let (opt_m, opt_v, opt_t) = self.opt.export_state();
        TrainState {
            cfg: self.cfg.clone(),
            step: self.step,
            next_stage,
            run_start,
            opt_t,
            grad_accum: self.tc.grad_accum.max(1),
            flops_spent,
            wall_s,
            params: self.params.clone(),
            opt_m,
            opt_v,
            curve: curve.clone(),
            rng_streams: Vec::new(),
        }
    }

    /// Rebuild a trainer from a verified [`TrainState`] snapshot,
    /// positioned exactly at the snapshot step: parameters, optimizer
    /// moments and bias-correction step, step counter, and FLOPs/wall
    /// offsets all restore bitwise. `tc` must be the recipe the
    /// interrupted run used — at minimum the same `grad_accum`, or the
    /// index-pure microbatch stream would silently shift. Returns the
    /// [`Resumed`] context to pass to [`run_resumed`](Self::run_resumed) /
    /// [`run_plan_resumed`](Self::run_plan_resumed).
    pub fn resume(rt: &Runtime, tc: TrainConfig, state: TrainState) -> Result<(Trainer, Resumed)> {
        if tc.grad_accum.max(1) != state.grad_accum {
            bail!(
                "resume: recipe grad_accum {} differs from the checkpoint's {} — \
                 the microbatch stream would not line up",
                tc.grad_accum.max(1),
                state.grad_accum
            );
        }
        let mut tr = Trainer::new(rt, &state.cfg, tc, state.params)?;
        tr.opt.import_state(state.opt_m, state.opt_v, state.opt_t)?;
        tr.step = state.step;
        tr.flops_offset = state.flops_spent;
        tr.wall_offset = state.wall_s;
        Ok((
            tr,
            Resumed {
                curve: state.curve,
                next_stage: state.next_stage,
                run_start: state.run_start,
            },
        ))
    }

    /// Resume from the newest checkpoint under `dir` that passes full
    /// verification ([`checkpoint::latest_good`] — a corrupt newest
    /// snapshot is skipped with a warning). Errors if none verifies.
    pub fn resume_latest(rt: &Runtime, tc: TrainConfig, dir: &Path) -> Result<(Trainer, Resumed)> {
        let (path, state) = checkpoint::latest_good(dir)?
            .with_context(|| format!("no usable checkpoint under {dir:?}"))?;
        log_info!("resuming from {path:?} (step {})", state.step);
        Self::resume(rt, tc, state)
    }

    /// One optimizer step (grad_accum microbatches). Returns mean loss.
    ///
    /// Dead gradient stores (each microbatch's after accumulation, the
    /// accumulator after the optimizer consumed it) are recycled into the
    /// thread-local [`crate::tensor::arena`], so on the native backend the
    /// steady-state step allocates no fresh activation/gradient buffers.
    pub fn train_step(&mut self, batches: &mut dyn FnMut(usize) -> Store) -> Result<f32> {
        let accum = self.tc.grad_accum.max(1);
        let mut grads = Store::new();
        let mut loss_sum = 0.0f32;
        for micro in 0..accum {
            let batch = batches(self.step * accum + micro);
            let mut bindings: Vec<(&str, &Store)> =
                vec![("params", &self.params), ("batch", &batch)];
            for (g, s) in &self.extra {
                bindings.push((g.as_str(), s));
            }
            let mut out = self.grad_exe.run(&bindings)?;
            let (loss, g) = take_loss_and_grads(&mut out, &self.cfg.name)?;
            loss_sum += loss;
            if accum == 1 {
                grads = g; // single microbatch: take ownership, no copy
            } else {
                accumulate(&mut grads, &g, 1.0 / accum as f32);
                crate::tensor::arena::recycle_store(g);
            }
        }
        let lr = self.tc.lr_at(self.step);
        self.opt.step(&mut self.params, &grads, lr);
        crate::tensor::arena::recycle_store(grads);
        self.step += 1;
        Ok(loss_sum / accum as f32)
    }

    /// One optimizer step with the microbatches sharded across `workers`
    /// scoped workers ([`parallel::run_microbatches`]). Gradient leaves and
    /// per-microbatch losses are reduced by the canonical tree
    /// ([`allreduce`]), whose shape depends only on `grad_accum` — so the
    /// result is **bit-identical for any worker count**, including 1.
    /// (With `grad_accum > 1` the tree brackets sums differently from the
    /// serial path's running left fold, so the two *paths* may differ in
    /// the last ulps; the guarantee is across worker counts, not across
    /// paths.) Optimizer moment shards are lazily resharded to match the
    /// active worker count.
    pub fn train_step_sharded(&mut self, batches: &SharedBatchFn, workers: usize) -> Result<f32> {
        let accum = self.tc.grad_accum.max(1);
        let active = workers.clamp(1, accum);
        if self.opt.shard_count() != active {
            self.opt.reshard(active);
        }
        let run = parallel::run_microbatches(
            &self.grad_exe,
            &self.params,
            &self.extra,
            batches,
            self.step * accum,
            accum,
            workers,
            &self.cfg.name,
        )?;
        self.last_worker_stats = run.stats;
        let (leaves, losses): (Vec<Store>, Vec<f32>) = run.leaves.into_iter().unzip();
        let mut grads = allreduce::tree_sum(leaves);
        if accum > 1 {
            // single scale after the tree sum: one rounding, same for any
            // worker count (the serial path scales per leaf instead)
            allreduce::scale_store(&mut grads, 1.0 / accum as f32);
        }
        let loss = allreduce::tree_sum_f32(&losses) / accum as f32;
        let lr = self.tc.lr_at(self.step);
        self.opt.step(&mut self.params, &grads, lr);
        arena::recycle_store_shared(grads);
        self.step += 1;
        Ok(loss)
    }

    /// Per-worker arena counters (fresh/reused/peak) from the most recent
    /// sharded step; empty if no sharded step has run.
    pub fn worker_arena_stats(&self) -> &[arena::WorkerStats] {
        &self.last_worker_stats
    }

    /// Held-out evaluation: mean loss (and mean metric if present).
    pub fn evaluate(
        &self,
        eval_batches: &mut dyn FnMut(usize) -> Store,
        n_batches: usize,
    ) -> Result<(f32, Option<f32>)> {
        eval_store(&self.fwd_exe, &self.params, eval_batches, n_batches)
    }

    /// Full training run: returns the curve, evaluating every
    /// `tc.eval_every` steps.
    pub fn run(&mut self, name: &str, batches: &mut Batches, steps: usize) -> Result<Curve> {
        self.run_inner(name, batches, steps, None, None)
    }

    /// Continue an interrupted [`run`](Self::run) from a [`Trainer::resume`]d
    /// trainer. `steps` is the interrupted run's ORIGINAL total budget —
    /// the resumed run completes the remaining
    /// `resumed.run_start + steps - step_count()` steps, so the eval
    /// cadence, final step, and returned curve line up bitwise with the
    /// uninterrupted run.
    pub fn run_resumed(
        &mut self,
        name: &str,
        batches: &mut Batches,
        steps: usize,
        resumed: Resumed,
    ) -> Result<Curve> {
        self.run_inner(name, batches, steps, None, Some(resumed))
    }

    /// Full training run executing a [`GrowthPlan`] mid-run: whenever the
    /// trainer's step count reaches a stage's `at_step`, the current
    /// parameters grow into the stage's target config through the unified
    /// growth entry point (runtime handle + this run's train batches +
    /// the stage's M-learning options), optimizer state is rebuilt for the
    /// grown parameters, the target's executables are re-bound, and
    /// training continues. Each growth is recorded as a [`Curve`] mark and
    /// charged to the FLOPs ledger. The plan must start on the trainer's
    /// current config; both are validated up front.
    pub fn run_plan(
        &mut self,
        rt: &Runtime,
        name: &str,
        batches: &mut Batches,
        steps: usize,
        plan: &GrowthPlan,
    ) -> Result<Curve> {
        if plan.initial().name != self.cfg.name {
            bail!(
                "growth plan starts on '{}' but the trainer holds '{}'",
                plan.initial().name,
                self.cfg.name
            );
        }
        // a stage this run can never reach would be skipped silently and
        // the run would "succeed" on an intermediate config — reject it
        // up front (stages fire while self.step < start + steps)
        if let Some(st) = plan.stages().iter().find(|st| st.at_step >= self.step + steps) {
            bail!(
                "growth plan stage at step {} is unreachable in this run \
                 (trainer steps {}..{}); extend `steps` or split the plan",
                st.at_step,
                self.step,
                self.step + steps
            );
        }
        self.run_inner(name, batches, steps, Some((rt, plan)), None)
    }

    /// Continue an interrupted [`run_plan`](Self::run_plan). `steps` is the
    /// ORIGINAL total budget (see [`run_resumed`](Self::run_resumed)); the
    /// stage cursor in `resumed` selects which stages are still pending —
    /// mid-plan the trainer holds a stage target config, not the plan's
    /// initial one, and is validated accordingly.
    pub fn run_plan_resumed(
        &mut self,
        rt: &Runtime,
        name: &str,
        batches: &mut Batches,
        steps: usize,
        plan: &GrowthPlan,
        resumed: Resumed,
    ) -> Result<Curve> {
        let stages = plan.stages();
        if resumed.next_stage > stages.len() {
            bail!(
                "resume: checkpoint stage cursor {} exceeds the plan's {} stages",
                resumed.next_stage,
                stages.len()
            );
        }
        let expected = if resumed.next_stage == 0 {
            &plan.initial().name
        } else {
            &stages[resumed.next_stage - 1].target.name
        };
        if *expected != self.cfg.name {
            bail!(
                "resume: checkpoint holds '{}' but the plan expects '{}' at stage cursor {}",
                self.cfg.name,
                expected,
                resumed.next_stage
            );
        }
        let end = resumed.run_start + steps;
        if let Some(st) = stages.iter().skip(resumed.next_stage).find(|st| st.at_step >= end) {
            bail!(
                "growth plan stage at step {} is unreachable in this resumed run \
                 (steps end at {}); extend `steps` or split the plan",
                st.at_step,
                end
            );
        }
        self.run_inner(name, batches, steps, Some((rt, plan)), Some(resumed))
    }

    fn run_inner(
        &mut self,
        name: &str,
        batches: &mut Batches,
        steps: usize,
        plan: Option<(&Runtime, &GrowthPlan)>,
        resumed: Option<Resumed>,
    ) -> Result<Curve> {
        let timer = Timer::new();
        let accum = self.tc.grad_accum.max(1) as f64;
        // resolve the worker pool once per run: Some(w) + a shared train
        // source takes the sharded step loop; a serial source under
        // LIGO_WORKERS falls back (warn once — results are still correct,
        // just single-worker)
        let workers = parallel::requested_workers();
        let pool = match (workers, batches.train.as_shared()) {
            (Some(w), Some(src)) => Some((w, src.clone())),
            (Some(w), None) => {
                static SERIAL_FALLBACK: std::sync::Once = std::sync::Once::new();
                SERIAL_FALLBACK.call_once(|| {
                    crate::log_warn!(
                        "LIGO_WORKERS={w} requested but this run's train source is serial \
                         (stateful closure); falling back to the single-worker step loop"
                    );
                });
                None
            }
            (None, _) => None,
        };
        let mut spent = self.flops_offset;
        // A fresh run records its starting point (growth quality shows at
        // step 0) and anchors the step budget at the current step. A
        // resumed run continues the saved curve — it already holds every
        // eval point up to the snapshot step — and keeps the interrupted
        // run's anchor, so `(self.step - run_start)` counts completed run
        // steps identically on both paths (for a fresh run it equals the
        // old loop's `s + 1` after each train step).
        let (mut curve, mut next_stage, run_start) = match resumed {
            Some(r) => (r.curve, r.next_stage, r.run_start),
            None => {
                let mut curve = Curve::new(name);
                let (l0, m0) = self.evaluate(&mut batches.eval, 4)?;
                curve.push(self.step, spent, self.wall_offset, l0, m0);
                (curve, 0usize, self.step)
            }
        };
        let end = run_start + steps;
        while self.step < end {
            if let Some((rt, plan)) = plan {
                // strictly-increasing stage steps: at most one fires per
                // step; `<=` also executes stages a resumed trainer is
                // already past, in order, rather than skipping them. A
                // checkpoint taken at a stage's `at_step` is written at the
                // end of the *previous* iteration, before the stage fires,
                // so resuming from it replays the growth exactly once.
                while next_stage < plan.stages().len()
                    && plan.stages()[next_stage].at_step <= self.step
                {
                    let stage = &plan.stages()[next_stage];
                    let train = &mut batches.train;
                    spent += self.execute_stage(rt, stage, &mut curve, &mut |i| train.batch(i))?;
                    // eval immediately: the swap's quality shows at this step
                    let (l, m) = self.evaluate(&mut batches.eval, 4)?;
                    curve.push(self.step, spent, self.wall_offset + timer.elapsed(), l, m);
                    next_stage += 1;
                }
            }
            let _train_loss = match &pool {
                Some((w, src)) => self.train_step_sharded(src, *w)?,
                None => {
                    let train = &mut batches.train;
                    self.train_step(&mut |i| train.batch(i))?
                }
            };
            spent += self.flops_per_microbatch * accum;
            let done = self.step - run_start; // completed steps of this run
            if done % self.tc.eval_every == 0 || self.step == end {
                let (loss, metric) = self.evaluate(&mut batches.eval, 4)?;
                curve.push(self.step, spent, self.wall_offset + timer.elapsed(), loss, metric);
            }
            // Checkpoint after the step's eval so the snapshot curve holds
            // this step's point; then honor an armed kill fault (the CI
            // crash probe dies right after the checkpoint it will resume
            // from).
            if let Some(ck) = &self.ckpt {
                if done % ck.every == 0 {
                    let wall = self.wall_offset + timer.elapsed();
                    let state = self.snapshot(run_start, next_stage, spent, wall, &curve);
                    checkpoint::write_retained(&state, &ck.dir, ck.keep)?;
                }
            }
            if fault::kill_due(self.step) {
                bail!("fault injection: killed training at step {}", self.step);
            }
        }
        Ok(curve)
    }

    /// Grow through one plan stage and swap the trainer onto the target.
    /// Returns the growth's extra FLOPs (for the caller's ledger).
    fn execute_stage(
        &mut self,
        rt: &Runtime,
        stage: &GrowthStage,
        curve: &mut Curve,
        train: &mut dyn FnMut(usize) -> Store,
    ) -> Result<f64> {
        let op = crate::growth::by_name(&stage.operator)?;
        let outcome = {
            let ctx = crate::growth::GrowthContext::new(&self.params, &self.cfg, &stage.target)
                .with_runtime(rt)
                .with_batches(train)
                .with_opts(stage.opts.clone());
            op.grow(ctx)?
        };
        log_info!(
            "growth plan @step {}: {} -> {} via {} [{}]",
            self.step,
            self.cfg.name,
            stage.target.name,
            stage.operator,
            outcome.route_summary()
        );
        curve.mark(
            self.step,
            format!(
                "grew {} -> {} via {} ({})",
                self.cfg.name, stage.target.name, stage.operator, outcome.objective
            ),
        );
        let extra = outcome.metrics.extra_flops;
        self.adopt_grown(rt, &stage.target, outcome.params)?;
        Ok(extra)
    }

    /// Swap this trainer onto a grown model mid-run: re-bind the target
    /// config's executables, rebuild optimizer state for the grown
    /// parameters ([`ShardedAdamW::rebuild`] — fresh moments re-partitioned
    /// over the grown tensor set, keeping the shard count, so a
    /// `LIGO_WORKERS` run stays sharded across growth), and update the
    /// per-step FLOPs.
    /// The step counter and LR schedule continue uninterrupted. Extra
    /// input-group bindings (`self.extra`, e.g. a KD teacher's parameters)
    /// were shaped for the *old* executable pair and are dropped — binding
    /// them into the grown model's executables would be a shape bug;
    /// callers that still want them must re-attach post-growth stores.
    pub fn adopt_grown(&mut self, rt: &Runtime, cfg: &ModelConfig, params: Store) -> Result<()> {
        self.grad_exe = rt.load(&format!("grad_{}", cfg.name))?;
        self.fwd_exe = rt.load(&format!("fwd_{}", cfg.name))?;
        self.opt.rebuild(&params);
        self.flops_per_microbatch = flops::train_step_flops(cfg);
        self.cfg = cfg.clone();
        self.params = params;
        self.extra.clear();
        Ok(())
    }
}

/// Pull `(loss, grads)` out of one grad-executable run. A backend gap here
/// must fail loudly: a missing loss would silently poison the whole
/// mean-loss curve with NaN, and a missing grads group would previously
/// panic. Shared by the serial step loop and the `LIGO_WORKERS` workers so
/// both paths report the same diagnostics.
pub(crate) fn take_loss_and_grads(out: &mut RunOutputs, cfg_name: &str) -> Result<(f32, Store)> {
    let Some(loss) = out.scalar("loss") else {
        bail!(
            "grad executable for '{}' returned no 'loss' scalar (outputs: {:?})",
            cfg_name,
            out.scalars.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>()
        )
    };
    let Some(g) = out.take_group("grads") else {
        bail!(
            "grad executable for '{}' returned no 'grads' group (groups: {:?})",
            cfg_name,
            out.groups.keys().collect::<Vec<_>>()
        )
    };
    Ok((loss, g))
}

/// Evaluate a fwd artifact over n batches: mean loss + mean metric.
/// `n_batches == 0` is a caller bug (the division would push a NaN point
/// onto the curve) and reports an error instead; a missing `loss` output
/// likewise fails loudly rather than corrupting the mean.
pub fn eval_store(
    fwd: &Executable,
    params: &Store,
    eval_batches: &mut dyn FnMut(usize) -> Store,
    n_batches: usize,
) -> Result<(f32, Option<f32>)> {
    if n_batches == 0 {
        bail!("eval_store: n_batches must be > 0 (a 0-batch mean is NaN)");
    }
    let mut loss = 0.0f32;
    let mut metric = 0.0f32;
    let mut has_metric = false;
    for i in 0..n_batches {
        let batch = eval_batches(i);
        let out = fwd.run(&[("params", params), ("batch", &batch)])?;
        let Some(l) = out.scalar("loss") else {
            bail!(
                "fwd executable '{}' returned no 'loss' scalar",
                fwd.manifest.name
            )
        };
        loss += l;
        if let Some(m) = out.scalar("metric") {
            metric += m;
            has_metric = true;
        }
    }
    Ok((
        loss / n_batches as f32,
        has_metric.then_some(metric / n_batches as f32),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ExecEngine, Manifest, TensorSpec};
    use crate::tensor::Tensor;

    /// Engine returning a constant loss but NO grads group / NO loss,
    /// depending on the manifest it is paired with.
    struct Fixed;

    impl ExecEngine for Fixed {
        fn execute(&self, _inputs: &[&Tensor], outputs: &[TensorSpec]) -> Result<Vec<Tensor>> {
            Ok(outputs
                .iter()
                .map(|s| Tensor::from_f32(&s.shape, vec![0.5; s.numel()]))
                .collect())
        }
    }

    fn exe(outputs: &str) -> Executable {
        let manifest = Manifest::parse(&format!(
            r#"{{"name": "t", "inputs": [], "outputs": [{outputs}]}}"#
        ))
        .unwrap();
        Executable::new(manifest, Box::new(Fixed))
    }

    #[test]
    fn eval_store_rejects_zero_batches() {
        let fwd = exe(r#"{"name": "loss", "shape": [], "dtype": "float32"}"#);
        let mut eb = |_i: usize| Store::new();
        let err = eval_store(&fwd, &Store::new(), &mut eb, 0).unwrap_err();
        assert!(err.to_string().contains("n_batches"), "{err}");
        // and the happy path still averages
        let (l, m) = eval_store(&fwd, &Store::new(), &mut eb, 3).unwrap();
        assert_eq!(l, 0.5);
        assert!(m.is_none());
    }

    #[test]
    fn eval_store_errors_when_loss_is_missing() {
        let fwd = exe(r#"{"name": "metric", "shape": [], "dtype": "float32"}"#);
        let mut eb = |_i: usize| Store::new();
        let err = eval_store(&fwd, &Store::new(), &mut eb, 1).unwrap_err();
        assert!(err.to_string().contains("no 'loss'"), "{err}");
    }

    /// Backend whose grad executable omits the grads group (and whose fwd
    /// omits loss): the regression surface for the old panic/NaN paths.
    struct GapBackend;

    impl crate::runtime::Backend for GapBackend {
        fn name(&self) -> &'static str {
            "gap"
        }

        fn compile(
            &self,
            _manifest: &Manifest,
            _hlo: &std::path::Path,
        ) -> Result<Box<dyn ExecEngine>> {
            unreachable!("GapBackend synthesizes everything")
        }

        fn synthesize(&self, name: &str) -> Option<Result<(Manifest, Box<dyn ExecEngine>)>> {
            let outputs = if name.starts_with("grad_") {
                // loss present, grads group absent
                r#"{"name": "loss", "shape": [], "dtype": "float32"}"#
            } else {
                // loss absent entirely
                r#"{"name": "metric", "shape": [], "dtype": "float32"}"#
            };
            let manifest = Manifest::parse(&format!(
                r#"{{"name": "{name}", "inputs": [], "outputs": [{outputs}]}}"#
            ))
            .unwrap();
            Some(Ok((manifest, Box::new(Fixed) as Box<dyn ExecEngine>)))
        }
    }

    #[test]
    fn train_step_bails_on_missing_grads_instead_of_panicking() {
        let rt = crate::runtime::Runtime::with_backend(Box::new(GapBackend), "/tmp");
        let cfg = crate::growth::testutil::mk_cfg(1, 8, 2);
        let tc = TrainConfig::bert(10);
        let mut tr =
            Trainer::with_artifacts(&rt, "grad_x", "fwd_x", &cfg, tc, Store::new()).unwrap();
        let err = tr.train_step(&mut |_s| Store::new()).unwrap_err();
        assert!(err.to_string().contains("no 'grads' group"), "{err}");
    }
}
