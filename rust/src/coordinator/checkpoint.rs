//! Full-state training checkpoints: crash-safe snapshot and resume.
//!
//! A [`TrainState`] captures everything a [`crate::coordinator::trainer::Trainer`]
//! needs to continue a run **bit-for-bit**: the model config and parameter
//! `Store`, the sharded AdamW moments and bias-correction step, the growth
//! plan stage cursor, the training curve (losses, FLOPs, wall, marks), the
//! FLOPs counter, and any named RNG streams. The data cursor needs no
//! separate state: batch sources are index-pure (`batch = f(global
//! microbatch index, seed)`), so restoring the step counter restores the
//! loader position exactly.
//!
//! On disk a snapshot is one LGCK v2 file (`tensor/io`) of five sections —
//! `meta` (JSON), `params` / `opt_m` / `opt_v` (tensor streams), `curve`
//! (JSON) — written atomically (temp file → fsync → rename) with a CRC32
//! per section. [`write_retained`] keeps the last `keep` snapshots;
//! [`latest_good`] scans newest-first and falls back past any snapshot
//! whose CRCs (or headers) fail verification, so a torn or bit-flipped
//! newest checkpoint degrades to the previous good one instead of killing
//! the resume.
//!
//! Exact-resume float round-trips: `f64`/`f32` scalars ride in JSON, which
//! this crate prints shortest-roundtrip (`util/json`), so `flops_spent`,
//! curve losses, etc. restore bitwise. `u64` RNG states are stored as
//! strings (a JSON number is an `f64` and cannot hold all of `u64`), the
//! same convention `coordinator/plan` uses for seeds.

use std::path::{Path, PathBuf};

use crate::bail;
use crate::config::ModelConfig;
use crate::coordinator::metrics::Curve;
use crate::error::{Context, Error, Result};
use crate::log_warn;
use crate::tensor::io;
use crate::tensor::store::Store;
use crate::util::json::Json;

/// Everything needed to resume training bit-for-bit. Field-for-field what
/// `Trainer::snapshot` captures and `Trainer::resume` restores.
#[derive(Debug, Clone)]
pub struct TrainState {
    /// Config of the model being trained *at the snapshot step* (mid-plan
    /// this is the current stage's target, not the plan's initial config).
    pub cfg: ModelConfig,
    /// Completed optimizer steps (the trainer's global step counter; also
    /// the data cursor — batch sources are indexed by `step * accum + µ`).
    pub step: usize,
    /// Index of the next unexecuted [`crate::coordinator::plan::GrowthPlan`]
    /// stage (0 = none executed; `stages().len()` = all done).
    pub next_stage: usize,
    /// Global step at which the interrupted `run*` call started (anchors
    /// eval cadence and the step budget).
    pub run_start: usize,
    /// Optimizer bias-correction step counter (resets at growth, so it is
    /// not derivable from `step`).
    pub opt_t: usize,
    /// Microbatches per optimizer step the run was using; resuming under a
    /// different accumulation would silently change the data stream.
    pub grad_accum: usize,
    /// Cumulative training FLOPs at the snapshot (bit-exact `f64`).
    pub flops_spent: f64,
    /// Wall seconds consumed before the snapshot (informational; wall time
    /// is the one series the bit-identity invariant does not cover).
    pub wall_s: f64,
    /// Model parameters.
    pub params: Store,
    /// AdamW first moments (merged across shards).
    pub opt_m: Store,
    /// AdamW second moments.
    pub opt_v: Store,
    /// The training curve so far, marks included.
    pub curve: Curve,
    /// Named RNG stream positions (`util/rng::Rng::state`). The core loop
    /// is RNG-free at step granularity, but callers with live streams
    /// (e.g. future data augmentation) snapshot them here.
    pub rng_streams: Vec<(String, u64)>,
}

impl TrainState {
    fn meta_json(&self) -> Json {
        Json::obj(vec![
            ("config", self.cfg.to_json()),
            ("step", Json::Num(self.step as f64)),
            ("next_stage", Json::Num(self.next_stage as f64)),
            ("run_start", Json::Num(self.run_start as f64)),
            ("opt_t", Json::Num(self.opt_t as f64)),
            ("grad_accum", Json::Num(self.grad_accum as f64)),
            ("flops_spent", Json::Num(self.flops_spent)),
            ("wall_s", Json::Num(self.wall_s)),
            (
                "rng",
                Json::Arr(
                    self.rng_streams
                        .iter()
                        .map(|(name, state)| {
                            Json::obj(vec![
                                ("name", Json::Str(name.clone())),
                                ("state", Json::Str(state.to_string())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Write the snapshot to `path` as one atomic LGCK v2 file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        io::write_sections(
            path,
            &[
                ("meta", self.meta_json().to_string().into_bytes()),
                ("params", io::encode_store(&self.params)),
                ("opt_m", io::encode_store(&self.opt_m)),
                ("opt_v", io::encode_store(&self.opt_v)),
                ("curve", self.curve.to_json().to_string().into_bytes()),
            ],
        )
    }

    /// Load and fully verify a snapshot. Any damage — CRC mismatch,
    /// truncation, missing section, malformed JSON — is a typed error.
    pub fn load(path: impl AsRef<Path>) -> Result<TrainState> {
        let path = path.as_ref();
        let mut meta = None;
        let mut params = None;
        let mut opt_m = None;
        let mut opt_v = None;
        let mut curve = None;
        for (name, payload) in io::read_sections(path)? {
            let ctx = |e: Error| Error::msg(format!("{path:?}: section '{name}': {e}"));
            match name.as_str() {
                "meta" => meta = Some(parse_json(&payload).map_err(ctx)?),
                "curve" => {
                    curve = Some(Curve::from_json(&parse_json(&payload).map_err(ctx)?).map_err(ctx)?)
                }
                "params" => params = Some(io::decode_store(&payload).map_err(ctx)?),
                "opt_m" => opt_m = Some(io::decode_store(&payload).map_err(ctx)?),
                "opt_v" => opt_v = Some(io::decode_store(&payload).map_err(ctx)?),
                _ => {}
            }
        }
        let missing = |what: &str| format!("{path:?}: snapshot has no '{what}' section");
        let meta = meta.with_context(|| missing("meta"))?;
        let params = params.with_context(|| missing("params"))?;
        let opt_m = opt_m.with_context(|| missing("opt_m"))?;
        let opt_v = opt_v.with_context(|| missing("opt_v"))?;
        let curve = curve.with_context(|| missing("curve"))?;

        let num = |k: &str| -> Result<f64> {
            meta.get(k).and_then(Json::as_f64).with_context(|| format!("{path:?}: meta missing '{k}'"))
        };
        let cfg = ModelConfig::from_json(
            meta.get("config").with_context(|| format!("{path:?}: meta missing 'config'"))?,
        )
        .with_context(|| format!("{path:?}: meta 'config'"))?;
        let mut rng_streams = Vec::new();
        if let Some(arr) = meta.get("rng").and_then(Json::as_arr) {
            for s in arr {
                let name = s
                    .get("name")
                    .and_then(Json::as_str)
                    .with_context(|| format!("{path:?}: rng stream missing 'name'"))?;
                let state = s
                    .get("state")
                    .and_then(Json::as_str)
                    .and_then(|v| v.parse::<u64>().ok())
                    .with_context(|| format!("{path:?}: rng stream '{name}' has a bad 'state'"))?;
                rng_streams.push((name.to_string(), state));
            }
        }
        Ok(TrainState {
            cfg,
            step: num("step")? as usize,
            next_stage: num("next_stage")? as usize,
            run_start: num("run_start")? as usize,
            opt_t: num("opt_t")? as usize,
            grad_accum: num("grad_accum")? as usize,
            flops_spent: num("flops_spent")?,
            wall_s: num("wall_s")?,
            params,
            opt_m,
            opt_v,
            curve,
            rng_streams,
        })
    }
}

fn parse_json(payload: &[u8]) -> Result<Json> {
    let text = std::str::from_utf8(payload).map_err(|e| Error::msg(format!("not UTF-8: {e}")))?;
    Json::parse(text).map_err(Error::msg)
}

/// Canonical snapshot file name for a step: `state_step00000120.lgck`
/// (zero-padded so lexicographic order is step order).
pub fn checkpoint_path(dir: &Path, step: usize) -> PathBuf {
    dir.join(format!("state_step{step:08}.lgck"))
}

/// All snapshot files under `dir`, ascending by step. A missing directory
/// is an empty list, not an error (nothing has been checkpointed yet).
pub fn list_checkpoints(dir: &Path) -> Result<Vec<(usize, PathBuf)>> {
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(out),
    };
    for entry in entries {
        let path = entry.with_context(|| format!("scan {dir:?}"))?.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
        let Some(step) = name
            .strip_prefix("state_step")
            .and_then(|s| s.strip_suffix(".lgck"))
            .and_then(|s| s.parse::<usize>().ok())
        else {
            continue;
        };
        out.push((step, path));
    }
    out.sort();
    Ok(out)
}

/// Save `state` under its canonical name in `dir`, then prune the oldest
/// snapshots beyond the newest `keep`. Returns the written path.
pub fn write_retained(state: &TrainState, dir: &Path, keep: usize) -> Result<PathBuf> {
    let keep = keep.max(1);
    let path = checkpoint_path(dir, state.step);
    state.save(&path)?;
    let all = list_checkpoints(dir)?;
    if all.len() > keep {
        for (_, old) in &all[..all.len() - keep] {
            if let Err(e) = std::fs::remove_file(old) {
                log_warn!("could not prune old checkpoint {old:?}: {e}");
            }
        }
    }
    Ok(path)
}

/// The newest snapshot in `dir` that passes full verification. A corrupt
/// newer snapshot (torn write, bit flip) logs a warning and falls back to
/// the next older one; `Ok(None)` means no usable snapshot exists.
pub fn latest_good(dir: &Path) -> Result<Option<(PathBuf, TrainState)>> {
    for (_, path) in list_checkpoints(dir)?.into_iter().rev() {
        match TrainState::load(&path) {
            Ok(state) => return Ok(Some((path, state))),
            Err(e) => log_warn!("checkpoint {path:?} failed verification ({e}); falling back"),
        }
    }
    Ok(None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::Registry;
    use crate::tensor::Tensor;
    use crate::util::fault::{self, Fault};

    fn sample_state(step: usize) -> TrainState {
        let cfg = Registry::builtin().model("bert_small").expect("builtin config").clone();
        let mut params = Store::new();
        params.insert("w", Tensor::from_f32(&[2, 2], vec![0.5, -1.25, 3.0, 0.1]));
        let mut opt_m = Store::new();
        opt_m.insert("w", Tensor::from_f32(&[2, 2], vec![0.01, 0.02, -0.03, 0.0]));
        let mut opt_v = Store::new();
        opt_v.insert("w", Tensor::from_f32(&[2, 2], vec![1e-4, 2e-4, 3e-4, 4e-4]));
        let mut curve = Curve::new("test");
        curve.push(0, 0.0, 0.0, 4.7, None);
        curve.push(step, 1.5e9, 2.25, 3.3, None);
        curve.mark(step, "grew a -> b via ligo (test)");
        TrainState {
            cfg,
            step,
            next_stage: 1,
            run_start: 0,
            opt_t: step,
            grad_accum: 2,
            flops_spent: 1.5e9 + 0.125,
            wall_s: 2.25,
            params,
            opt_m,
            opt_v,
            curve,
            rng_streams: vec![("aug".to_string(), u64::MAX - 3)],
        }
    }

    fn test_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("ligo_ckpt_test").join(name);
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn snapshot_roundtrip_is_bitwise() {
        let dir = test_dir("roundtrip");
        let s = sample_state(12);
        let path = checkpoint_path(&dir, s.step);
        s.save(&path).unwrap();
        let l = TrainState::load(&path).unwrap();
        assert_eq!(l.cfg.name, s.cfg.name);
        assert_eq!(
            (l.step, l.next_stage, l.run_start, l.opt_t, l.grad_accum),
            (s.step, s.next_stage, s.run_start, s.opt_t, s.grad_accum)
        );
        assert_eq!(l.flops_spent.to_bits(), s.flops_spent.to_bits());
        assert_eq!(l.wall_s.to_bits(), s.wall_s.to_bits());
        assert_eq!(l.params, s.params);
        assert_eq!(l.opt_m, s.opt_m);
        assert_eq!(l.opt_v, s.opt_v);
        assert_eq!(l.rng_streams, s.rng_streams);
        assert_eq!(l.curve.marks, s.curve.marks);
        for (a, b) in s.curve.loss.iter().zip(&l.curve.loss) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn retention_keeps_the_newest_k() {
        let dir = test_dir("retention");
        for step in [10, 20, 30, 40] {
            write_retained(&sample_state(step), &dir, 2).unwrap();
        }
        let steps: Vec<usize> = list_checkpoints(&dir).unwrap().into_iter().map(|(s, _)| s).collect();
        assert_eq!(steps, vec![30, 40]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn latest_good_falls_back_past_a_corrupted_newest() {
        let dir = test_dir("fallback");
        write_retained(&sample_state(10), &dir, 3).unwrap();
        write_retained(&sample_state(20), &dir, 3).unwrap();
        // Corrupt the newest on disk.
        let newest = checkpoint_path(&dir, 20);
        let mut bytes = std::fs::read(&newest).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&newest, &bytes).unwrap();
        let (path, state) = latest_good(&dir).unwrap().expect("older snapshot survives");
        assert_eq!(path, checkpoint_path(&dir, 10));
        assert_eq!(state.step, 10);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn latest_good_falls_back_past_an_injected_torn_write() {
        let dir = test_dir("torn");
        write_retained(&sample_state(10), &dir, 3).unwrap();
        fault::set_override(Some(Fault::TornWrite));
        write_retained(&sample_state(20), &dir, 3).unwrap();
        fault::clear_override();
        let (_, state) = latest_good(&dir).unwrap().expect("older snapshot survives");
        assert_eq!(state.step, 10, "torn newest must be skipped");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn latest_good_is_none_for_missing_or_empty_dir() {
        let dir = test_dir("empty");
        assert!(latest_good(&dir).unwrap().is_none());
        assert!(latest_good(&dir.join("never_created")).unwrap().is_none());
        std::fs::remove_dir_all(dir).ok();
    }
}
