//! Optimizers over the named tensor store. The AOT grad artifacts return
//! raw gradients; parameter/moment state and the update rule live here in
//! rust (so accumulation, freezing and growth re-initialization are
//! coordinator decisions, not baked into HLO).

use std::collections::BTreeSet;

use crate::tensor::store::Store;
use crate::tensor::{Tensor, TensorData};

/// AdamW with decoupled weight decay (Loshchilov & Hutter), plus optional
/// global-norm gradient clipping and per-name freezing.
pub struct AdamW {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    pub grad_clip: f32,
    /// Parameters excluded from weight decay (LN gains/biases, biases).
    m: Store,
    v: Store,
    t: usize,
    frozen: BTreeSet<String>,
}

/// Weight decay mask: decay only matrices (2D), never biases/LN vectors.
fn decays(name: &str, t: &Tensor) -> bool {
    t.shape.len() >= 2 && !name.ends_with("_b") && !name.ends_with("_g")
}

impl AdamW {
    pub fn new(
        params: &Store,
        beta1: f32,
        beta2: f32,
        eps: f32,
        weight_decay: f32,
        grad_clip: f32,
    ) -> AdamW {
        let mut m = Store::new();
        let mut v = Store::new();
        for (name, t) in params.iter() {
            if matches!(t.data, TensorData::F32(_)) {
                m.insert(name.clone(), Tensor::zeros(&t.shape));
                v.insert(name.clone(), Tensor::zeros(&t.shape));
            }
        }
        AdamW { beta1, beta2, eps, weight_decay, grad_clip, m, v, t: 0, frozen: BTreeSet::new() }
    }

    pub fn from_train_config(params: &Store, tc: &crate::config::TrainConfig) -> AdamW {
        Self::new(params, tc.beta1, tc.beta2, tc.eps, tc.weight_decay, tc.grad_clip)
    }

    /// Reset the optimizer for a new (grown) parameter set mid-run: fresh
    /// zero moments over the new shapes, bias-correction step count back to
    /// 0 (it tracks the new moments), freeze set cleared; hyperparameters
    /// are kept. This is how a [`crate::coordinator::plan::GrowthPlan`]
    /// stage swaps optimizer state through the grow machinery — the paper
    /// reinitializes optimizer state after growth rather than mapping
    /// moments through M.
    pub fn rebuild(&mut self, params: &Store) {
        self.m = Store::new();
        self.v = Store::new();
        for (name, t) in params.iter() {
            if matches!(t.data, TensorData::F32(_)) {
                self.m.insert(name.clone(), Tensor::zeros(&t.shape));
                self.v.insert(name.clone(), Tensor::zeros(&t.shape));
            }
        }
        self.t = 0;
        self.frozen.clear();
    }

    /// Freeze parameters matching a predicate (MSLT stages, adapter tuning).
    pub fn freeze_where(&mut self, params: &Store, pred: impl Fn(&str) -> bool) {
        self.frozen = params
            .iter()
            .filter(|(n, _)| pred(n))
            .map(|(n, _)| n.clone())
            .collect();
    }

    pub fn unfreeze_all(&mut self) {
        self.frozen.clear();
    }

    pub fn frozen_count(&self) -> usize {
        self.frozen.len()
    }

    /// One update step; `lr` comes from the schedule. Returns the global
    /// gradient norm (pre-clip) for diagnostics.
    pub fn step(&mut self, params: &mut Store, grads: &Store, lr: f32) -> f32 {
        self.t += 1;
        let gnorm = grads.global_norm();
        let clip_scale = if self.grad_clip > 0.0 && gnorm > self.grad_clip {
            self.grad_clip / (gnorm + 1e-12)
        } else {
            1.0
        };
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for (name, g) in grads.iter() {
            if self.frozen.contains(name) {
                continue;
            }
            let Some(p) = params.get_mut(name) else { continue };
            if !matches!(p.data, TensorData::F32(_)) {
                continue;
            }
            let decay = if decays(name, p) { self.weight_decay } else { 0.0 };
            let m = self.m.get_mut(name).expect("moment m").f32s_mut();
            let v = self.v.get_mut(name).expect("moment v").f32s_mut();
            let pv = p.f32s_mut();
            let gs = g.f32s();
            for i in 0..pv.len() {
                let gi = gs[i] * clip_scale;
                m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * gi;
                v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * gi * gi;
                let mh = m[i] / bc1;
                let vh = v[i] / bc2;
                pv[i] -= lr * (mh / (vh.sqrt() + self.eps) + decay * pv[i]);
            }
        }
        gnorm
    }
}

/// Plain SGD with momentum — what the paper uses for the 100 LiGO M-steps.
pub struct Sgd {
    pub momentum: f32,
    vel: Store,
}

impl Sgd {
    pub fn new(params: &Store, momentum: f32) -> Sgd {
        let mut vel = Store::new();
        for (name, t) in params.iter() {
            vel.insert(name.clone(), Tensor::zeros(&t.shape));
        }
        Sgd { momentum, vel }
    }

    pub fn step(&mut self, params: &mut Store, grads: &Store, lr: f32) {
        for (name, g) in grads.iter() {
            let Some(p) = params.get_mut(name) else { continue };
            let v = self.vel.get_mut(name).expect("velocity").f32s_mut();
            let pv = p.f32s_mut();
            for (i, gi) in g.f32s().iter().enumerate() {
                v[i] = self.momentum * v[i] + gi;
                pv[i] -= lr * v[i];
            }
        }
    }
}

/// Accumulate `src` gradients into `acc` (scaled), creating missing slots.
pub fn accumulate(acc: &mut Store, src: &Store, scale: f32) {
    for (name, g) in src.iter() {
        match acc.get_mut(name) {
            Some(t) => {
                for (a, s) in t.f32s_mut().iter_mut().zip(g.f32s()) {
                    *a += scale * s;
                }
            }
            None => {
                let mut t = g.clone();
                for x in t.f32s_mut() {
                    *x *= scale;
                }
                acc.insert(name.clone(), t);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_param(v: f32) -> Store {
        let mut s = Store::new();
        s.insert("w", Tensor::from_f32(&[2, 1], vec![v, v]));
        s
    }

    #[test]
    fn adamw_first_step_matches_closed_form() {
        // With g constant, first AdamW step is -lr * g/(|g| + eps) (+decay).
        let mut p = one_param(1.0);
        let mut g = Store::new();
        g.insert("w", Tensor::from_f32(&[2, 1], vec![0.5, 0.5]));
        let mut opt = AdamW::new(&p, 0.9, 0.999, 1e-8, 0.0, 0.0);
        opt.step(&mut p, &g, 0.1);
        // mh = g, vh = g^2 => update = lr * g/|g| = 0.1
        for x in p.expect("w").f32s() {
            assert!((x - 0.9).abs() < 1e-4, "{x}");
        }
    }

    #[test]
    fn weight_decay_only_on_matrices() {
        let mut p = Store::new();
        p.insert("w", Tensor::from_f32(&[1, 1], vec![1.0]));
        p.insert("ln_g", Tensor::from_f32(&[1], vec![1.0]));
        let mut g = Store::new();
        g.insert("w", Tensor::from_f32(&[1, 1], vec![0.0]));
        g.insert("ln_g", Tensor::from_f32(&[1], vec![0.0]));
        let mut opt = AdamW::new(&p, 0.9, 0.999, 1e-8, 0.1, 0.0);
        opt.step(&mut p, &g, 1.0);
        assert!(p.expect("w").f32s()[0] < 1.0); // decayed
        assert_eq!(p.expect("ln_g").f32s()[0], 1.0); // not decayed
    }

    #[test]
    fn clipping_bounds_update() {
        let mut p = one_param(0.0);
        let mut g = Store::new();
        g.insert("w", Tensor::from_f32(&[2, 1], vec![100.0, 0.0]));
        let mut opt = AdamW::new(&p, 0.0, 0.0, 1e-8, 0.0, 1.0);
        let gnorm = opt.step(&mut p, &g, 0.001);
        assert!((gnorm - 100.0).abs() < 1e-3);
        // clipped g = 1.0 -> beta=0 Adam: update = lr * 1/(1+eps)
        assert!(p.expect("w").f32s()[0].abs() <= 0.0011);
    }

    #[test]
    fn frozen_params_do_not_move() {
        let mut p = one_param(1.0);
        let mut g = Store::new();
        g.insert("w", Tensor::from_f32(&[2, 1], vec![1.0, 1.0]));
        let mut opt = AdamW::new(&p, 0.9, 0.999, 1e-8, 0.0, 0.0);
        opt.freeze_where(&p, |n| n == "w");
        opt.step(&mut p, &g, 0.1);
        assert_eq!(p.expect("w").f32s(), &[1.0, 1.0]);
        opt.unfreeze_all();
        opt.step(&mut p, &g, 0.1);
        assert_ne!(p.expect("w").f32s(), &[1.0, 1.0]);
    }

    #[test]
    fn rebuild_resets_moments_and_freezes_for_grown_params() {
        let mut p = one_param(1.0);
        let mut g = Store::new();
        g.insert("w", Tensor::from_f32(&[2, 1], vec![1.0, 1.0]));
        let mut opt = AdamW::new(&p, 0.9, 0.999, 1e-8, 0.0, 0.0);
        opt.freeze_where(&p, |n| n == "w");
        opt.step(&mut p, &g, 0.1);
        // grown params: different name set and shapes
        let mut grown = Store::new();
        grown.insert("w2", Tensor::from_f32(&[3, 1], vec![0.0; 3]));
        opt.rebuild(&grown);
        assert_eq!(opt.frozen_count(), 0, "freeze set must clear");
        let mut g2 = Store::new();
        g2.insert("w2", Tensor::from_f32(&[3, 1], vec![0.5; 3]));
        opt.step(&mut grown, &g2, 0.1);
        // first step after rebuild behaves like a fresh optimizer:
        // update = -lr * g/|g| (see adamw_first_step_matches_closed_form)
        for x in grown.expect("w2").f32s() {
            assert!((x + 0.1).abs() < 1e-4, "{x}");
        }
    }

    #[test]
    fn sgd_momentum_accumulates() {
        let mut p = one_param(0.0);
        let mut g = Store::new();
        g.insert("w", Tensor::from_f32(&[2, 1], vec![1.0, 1.0]));
        let mut opt = Sgd::new(&p, 0.9);
        opt.step(&mut p, &g, 0.1);
        assert!((p.expect("w").f32s()[0] + 0.1).abs() < 1e-6);
        opt.step(&mut p, &g, 0.1);
        // velocity = 0.9*1 + 1 = 1.9 -> total -0.1-0.19
        assert!((p.expect("w").f32s()[0] + 0.29).abs() < 1e-6);
    }

    #[test]
    fn accumulate_sums_and_creates() {
        let mut acc = Store::new();
        let mut g = Store::new();
        g.insert("w", Tensor::from_f32(&[2], vec![2.0, 4.0]));
        accumulate(&mut acc, &g, 0.5);
        accumulate(&mut acc, &g, 0.5);
        assert_eq!(acc.expect("w").f32s(), &[2.0, 4.0]);
    }

    #[test]
    fn adamw_converges_on_quadratic() {
        // minimize (w-3)^2: grad = 2(w-3)
        let mut p = one_param(0.0);
        let mut opt = AdamW::new(&p, 0.9, 0.999, 1e-8, 0.0, 0.0);
        for _ in 0..500 {
            let w = p.expect("w").f32s()[0];
            let mut g = Store::new();
            g.insert("w", Tensor::from_f32(&[2, 1], vec![2.0 * (w - 3.0); 2]));
            opt.step(&mut p, &g, 0.05);
        }
        assert!((p.expect("w").f32s()[0] - 3.0).abs() < 0.05);
    }
}
