//! Optimizers over the named tensor store. The AOT grad artifacts return
//! raw gradients; parameter/moment state and the update rule live here in
//! rust (so accumulation, freezing and growth re-initialization are
//! coordinator decisions, not baked into HLO).

use std::collections::BTreeSet;

use crate::tensor::store::Store;
use crate::tensor::{Tensor, TensorData};

/// AdamW with decoupled weight decay (Loshchilov & Hutter), plus optional
/// global-norm gradient clipping and per-name freezing.
pub struct AdamW {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    pub grad_clip: f32,
    /// Parameters excluded from weight decay (LN gains/biases, biases).
    m: Store,
    v: Store,
    t: usize,
    frozen: BTreeSet<String>,
}

/// Weight decay mask: decay only matrices (2D), never biases/LN vectors.
fn decays(name: &str, t: &Tensor) -> bool {
    t.shape.len() >= 2 && !name.ends_with("_b") && !name.ends_with("_g")
}

/// The elementwise AdamW update for one tensor — the single source of
/// truth shared by [`AdamW`] and [`ShardedAdamW`]. Everything global
/// (step count, clip scale, bias corrections) is computed by the caller
/// *before* any fan-out, so the sharded optimizer is bitwise-identical to
/// the unsharded one by construction: same floats, same order, per element.
#[allow(clippy::too_many_arguments)]
fn adamw_update(
    p: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    beta1: f32,
    beta2: f32,
    eps: f32,
    lr: f32,
    decay: f32,
    clip_scale: f32,
    bc1: f32,
    bc2: f32,
) {
    for i in 0..p.len() {
        let gi = g[i] * clip_scale;
        m[i] = beta1 * m[i] + (1.0 - beta1) * gi;
        v[i] = beta2 * v[i] + (1.0 - beta2) * gi * gi;
        let mh = m[i] / bc1;
        let vh = v[i] / bc2;
        p[i] -= lr * (mh / (vh.sqrt() + eps) + decay * p[i]);
    }
}

impl AdamW {
    pub fn new(
        params: &Store,
        beta1: f32,
        beta2: f32,
        eps: f32,
        weight_decay: f32,
        grad_clip: f32,
    ) -> AdamW {
        let mut m = Store::new();
        let mut v = Store::new();
        for (name, t) in params.iter() {
            if matches!(t.data, TensorData::F32(_)) {
                m.insert(name.clone(), Tensor::zeros(&t.shape));
                v.insert(name.clone(), Tensor::zeros(&t.shape));
            }
        }
        AdamW { beta1, beta2, eps, weight_decay, grad_clip, m, v, t: 0, frozen: BTreeSet::new() }
    }

    pub fn from_train_config(params: &Store, tc: &crate::config::TrainConfig) -> AdamW {
        Self::new(params, tc.beta1, tc.beta2, tc.eps, tc.weight_decay, tc.grad_clip)
    }

    /// Reset the optimizer for a new (grown) parameter set mid-run: fresh
    /// zero moments over the new shapes, bias-correction step count back to
    /// 0 (it tracks the new moments), freeze set cleared; hyperparameters
    /// are kept. This is how a [`crate::coordinator::plan::GrowthPlan`]
    /// stage swaps optimizer state through the grow machinery — the paper
    /// reinitializes optimizer state after growth rather than mapping
    /// moments through M.
    pub fn rebuild(&mut self, params: &Store) {
        self.m = Store::new();
        self.v = Store::new();
        for (name, t) in params.iter() {
            if matches!(t.data, TensorData::F32(_)) {
                self.m.insert(name.clone(), Tensor::zeros(&t.shape));
                self.v.insert(name.clone(), Tensor::zeros(&t.shape));
            }
        }
        self.t = 0;
        self.frozen.clear();
    }

    /// Freeze parameters matching a predicate (MSLT stages, adapter tuning).
    pub fn freeze_where(&mut self, params: &Store, pred: impl Fn(&str) -> bool) {
        self.frozen = params
            .iter()
            .filter(|(n, _)| pred(n))
            .map(|(n, _)| n.clone())
            .collect();
    }

    pub fn unfreeze_all(&mut self) {
        self.frozen.clear();
    }

    pub fn frozen_count(&self) -> usize {
        self.frozen.len()
    }

    /// One update step; `lr` comes from the schedule. Returns the global
    /// gradient norm (pre-clip) for diagnostics.
    pub fn step(&mut self, params: &mut Store, grads: &Store, lr: f32) -> f32 {
        self.t += 1;
        let gnorm = grads.global_norm();
        let clip_scale = if self.grad_clip > 0.0 && gnorm > self.grad_clip {
            self.grad_clip / (gnorm + 1e-12)
        } else {
            1.0
        };
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for (name, g) in grads.iter() {
            if self.frozen.contains(name) {
                continue;
            }
            let Some(p) = params.get_mut(name) else { continue };
            if !matches!(p.data, TensorData::F32(_)) {
                continue;
            }
            let decay = if decays(name, p) { self.weight_decay } else { 0.0 };
            let m = self.m.get_mut(name).expect("moment m").f32s_mut();
            let v = self.v.get_mut(name).expect("moment v").f32s_mut();
            adamw_update(
                p.f32s_mut(),
                g.f32s(),
                m,
                v,
                self.beta1,
                self.beta2,
                self.eps,
                lr,
                decay,
                clip_scale,
                bc1,
                bc2,
            );
        }
        gnorm
    }
}

/// ZeRO-style sharded AdamW for the `LIGO_WORKERS` data-parallel trainer:
/// the first/second-moment Stores are partitioned across `n` shards
/// (balanced by parameter count, assigned greedily over the sorted name
/// order so the partition is deterministic), and `step` updates each
/// shard's disjoint parameter slice on its own scoped thread.
///
/// Bit-identity across shard counts holds by construction: the global
/// quantities (step count, gradient norm, clip scale, bias corrections)
/// are computed once *before* the fan-out, and the per-element update is
/// the same [`adamw_update`] kernel [`AdamW`] runs — sharding only chooses
/// *which thread* touches a tensor, never the arithmetic order within one.
///
/// Growth-aware resharding: [`rebuild`](Self::rebuild) re-partitions the
/// grown parameter set over the existing shard count with fresh moments
/// (the mid-plan swap), and [`reshard`](Self::reshard) re-partitions the
/// *live* moments over a new shard count without touching their values
/// (the worker-count change).
pub struct ShardedAdamW {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    pub grad_clip: f32,
    shards: Vec<MomentShard>,
    /// param name -> shard index (f32 params only, total over the set).
    assign: std::collections::BTreeMap<String, usize>,
    t: usize,
    frozen: BTreeSet<String>,
}

/// One shard's slice of the optimizer state.
struct MomentShard {
    m: Store,
    v: Store,
}

/// Balanced greedy partition of `(name, numel)` entries (sorted order in,
/// least-loaded shard wins, first shard on ties) — deterministic, so every
/// run and every worker count agrees on who owns what.
fn partition<'a, I>(entries: I, n: usize) -> std::collections::BTreeMap<String, usize>
where
    I: Iterator<Item = (&'a String, usize)>,
{
    let mut load = vec![0usize; n.max(1)];
    let mut assign = std::collections::BTreeMap::new();
    for (name, numel) in entries {
        let s = (0..load.len()).min_by_key(|&i| load[i]).expect("n >= 1");
        load[s] += numel.max(1);
        assign.insert(name.clone(), s);
    }
    assign
}

/// The per-shard slice of one [`ShardedAdamW::step`] fan-out (a free
/// function so scoped threads borrow only what they need).
#[allow(clippy::too_many_arguments)]
fn update_shard(
    shard: &mut MomentShard,
    bucket: Vec<(&str, &mut Tensor)>,
    grads: &Store,
    frozen: &BTreeSet<String>,
    hyper: (f32, f32, f32, f32), // (beta1, beta2, eps, weight_decay)
    lr: f32,
    clip_scale: f32,
    bc1: f32,
    bc2: f32,
) {
    let (beta1, beta2, eps, weight_decay) = hyper;
    for (name, p) in bucket {
        if frozen.contains(name) {
            continue;
        }
        let g = grads.get(name).expect("bucketed params have grads");
        let decay = if decays(name, p) { weight_decay } else { 0.0 };
        let m = shard.m.get_mut(name).expect("moment m").f32s_mut();
        let v = shard.v.get_mut(name).expect("moment v").f32s_mut();
        let pv = p.f32s_mut();
        adamw_update(pv, g.f32s(), m, v, beta1, beta2, eps, lr, decay, clip_scale, bc1, bc2);
    }
}

impl ShardedAdamW {
    pub fn new(
        params: &Store,
        shards: usize,
        beta1: f32,
        beta2: f32,
        eps: f32,
        weight_decay: f32,
        grad_clip: f32,
    ) -> ShardedAdamW {
        let mut opt = ShardedAdamW {
            beta1,
            beta2,
            eps,
            weight_decay,
            grad_clip,
            shards: Vec::new(),
            assign: std::collections::BTreeMap::new(),
            t: 0,
            frozen: BTreeSet::new(),
        };
        opt.init_shards(params, shards.max(1));
        opt
    }

    pub fn from_train_config(
        params: &Store,
        tc: &crate::config::TrainConfig,
        shards: usize,
    ) -> ShardedAdamW {
        Self::new(params, shards, tc.beta1, tc.beta2, tc.eps, tc.weight_decay, tc.grad_clip)
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Zero moments over `params` partitioned into `n` shards.
    fn init_shards(&mut self, params: &Store, n: usize) {
        let f32s = |t: &Tensor| matches!(t.data, TensorData::F32(_));
        let entries = params.iter().filter(|(_, t)| f32s(t)).map(|(k, t)| (k, t.numel()));
        self.assign = partition(entries, n);
        self.shards = (0..n).map(|_| MomentShard { m: Store::new(), v: Store::new() }).collect();
        for (name, t) in params.iter() {
            if f32s(t) {
                let s = self.assign[name];
                self.shards[s].m.insert(name.clone(), Tensor::zeros(&t.shape));
                self.shards[s].v.insert(name.clone(), Tensor::zeros(&t.shape));
            }
        }
    }

    /// Reset for a new (grown) parameter set mid-run, exactly like
    /// [`AdamW::rebuild`]: fresh zero moments (re-partitioned over the
    /// *current* shard count), step counter back to 0 so bias correction
    /// restarts with the new moments, freeze set cleared, hyperparameters
    /// kept. Sharded and unsharded training therefore agree after growth
    /// too — the behavior `optim`'s rebuild bias-correction tests pin.
    pub fn rebuild(&mut self, params: &Store) {
        let n = self.shards.len().max(1);
        self.init_shards(params, n);
        self.t = 0;
        self.frozen.clear();
    }

    /// Re-partition the *live* moments over a new shard count (the
    /// `LIGO_WORKERS` count changed under a live optimizer). Tensors are
    /// moved, never recomputed, so training continues bit-for-bit.
    pub fn reshard(&mut self, n: usize) {
        let n = n.max(1);
        let mut all_m = Store::new();
        let mut all_v = Store::new();
        for sh in std::mem::take(&mut self.shards) {
            for (k, t) in sh.m.into_entries() {
                all_m.insert(k, t);
            }
            for (k, t) in sh.v.into_entries() {
                all_v.insert(k, t);
            }
        }
        self.assign = partition(all_m.iter().map(|(k, t)| (k, t.numel())), n);
        self.shards = (0..n).map(|_| MomentShard { m: Store::new(), v: Store::new() }).collect();
        for (k, t) in all_m.into_entries() {
            let s = self.assign[&k];
            self.shards[s].m.insert(k, t);
        }
        for (k, t) in all_v.into_entries() {
            let s = self.assign[&k];
            self.shards[s].v.insert(k, t);
        }
    }

    /// Freeze parameters matching a predicate (MSLT stages, adapter tuning).
    pub fn freeze_where(&mut self, params: &Store, pred: impl Fn(&str) -> bool) {
        self.frozen = params
            .iter()
            .filter(|(n, _)| pred(n))
            .map(|(n, _)| n.clone())
            .collect();
    }

    pub fn unfreeze_all(&mut self) {
        self.frozen.clear();
    }

    pub fn frozen_count(&self) -> usize {
        self.frozen.len()
    }

    /// One update step; `lr` comes from the schedule. Returns the global
    /// gradient norm (pre-clip), like [`AdamW::step`]. With one shard the
    /// update runs inline on the caller (no thread churn — this is the
    /// `LIGO_WORKERS` -unset-equivalent path); with `n` shards it fans out
    /// on scoped threads, one per shard.
    pub fn step(&mut self, params: &mut Store, grads: &Store, lr: f32) -> f32 {
        self.t += 1;
        let gnorm = grads.global_norm();
        let clip_scale = if self.grad_clip > 0.0 && gnorm > self.grad_clip {
            self.grad_clip / (gnorm + 1e-12)
        } else {
            1.0
        };
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let n = self.shards.len().max(1);
        // Bucket the updatable params by owning shard. A param that joined
        // after construction has no shard — that is the same caller bug
        // AdamW surfaces as a missing-moment panic; say so explicitly.
        let mut buckets: Vec<Vec<(&str, &mut Tensor)>> = (0..n).map(|_| Vec::new()).collect();
        for (name, p) in params.iter_mut() {
            if !matches!(p.data, TensorData::F32(_)) || grads.get(name).is_none() {
                continue;
            }
            let Some(&s) = self.assign.get(name.as_str()) else {
                panic!("no optimizer shard for '{name}': rebuild() after changing the param set")
            };
            buckets[s].push((name.as_str(), p));
        }
        let hyper = (self.beta1, self.beta2, self.eps, self.weight_decay);
        let frozen = &self.frozen;
        if n == 1 {
            let bucket = buckets.pop().expect("one bucket");
            let shard = &mut self.shards[0];
            update_shard(shard, bucket, grads, frozen, hyper, lr, clip_scale, bc1, bc2);
        } else {
            std::thread::scope(|sc| {
                for (shard, bucket) in self.shards.iter_mut().zip(buckets) {
                    sc.spawn(move || {
                        update_shard(shard, bucket, grads, frozen, hyper, lr, clip_scale, bc1, bc2);
                    });
                }
            });
        }
        gnorm
    }

    /// Snapshot the full optimizer state for checkpointing: the merged
    /// first/second moment Stores (cloned; the live shards are untouched)
    /// plus the bias-correction step counter.
    pub fn export_state(&self) -> (Store, Store, usize) {
        let mut m = Store::new();
        let mut v = Store::new();
        for sh in &self.shards {
            for (k, t) in sh.m.iter() {
                m.insert(k.clone(), t.clone());
            }
            for (k, t) in sh.v.iter() {
                v.insert(k.clone(), t.clone());
            }
        }
        (m, v, self.t)
    }

    /// Restore a snapshot captured by [`export_state`](Self::export_state).
    /// Moments are re-partitioned over the *current* shard count exactly
    /// like [`reshard`](Self::reshard) — tensors moved, never recomputed,
    /// so a resumed run continues bit-for-bit even under a different
    /// `LIGO_WORKERS` — and the step counter resumes bias correction where
    /// it left off. The freeze set is cleared (freezing is a schedule
    /// decision, re-applied by whoever drives the resumed run).
    pub fn import_state(&mut self, m: Store, v: Store, t: usize) -> crate::error::Result<()> {
        if m.len() != v.len()
            || m.iter().map(|(k, _)| k).ne(v.iter().map(|(k, _)| k))
        {
            crate::bail!(
                "optimizer state: m/v moment key sets disagree ({} vs {} entries)",
                m.len(),
                v.len()
            );
        }
        let n = self.shards.len().max(1);
        self.assign = partition(m.iter().map(|(k, t)| (k, t.numel())), n);
        self.shards = (0..n).map(|_| MomentShard { m: Store::new(), v: Store::new() }).collect();
        for (k, t) in m.into_entries() {
            let s = self.assign[&k];
            self.shards[s].m.insert(k, t);
        }
        for (k, t) in v.into_entries() {
            let s = self.assign[&k];
            self.shards[s].v.insert(k, t);
        }
        self.t = t;
        self.frozen.clear();
        Ok(())
    }
}

/// Plain SGD with momentum — what the paper uses for the 100 LiGO M-steps.
pub struct Sgd {
    pub momentum: f32,
    vel: Store,
}

impl Sgd {
    pub fn new(params: &Store, momentum: f32) -> Sgd {
        let mut vel = Store::new();
        for (name, t) in params.iter() {
            vel.insert(name.clone(), Tensor::zeros(&t.shape));
        }
        Sgd { momentum, vel }
    }

    pub fn step(&mut self, params: &mut Store, grads: &Store, lr: f32) {
        for (name, g) in grads.iter() {
            let Some(p) = params.get_mut(name) else { continue };
            let v = self.vel.get_mut(name).expect("velocity").f32s_mut();
            let pv = p.f32s_mut();
            for (i, gi) in g.f32s().iter().enumerate() {
                v[i] = self.momentum * v[i] + gi;
                pv[i] -= lr * v[i];
            }
        }
    }
}

/// Accumulate `src` gradients into `acc` (scaled), creating missing slots.
pub fn accumulate(acc: &mut Store, src: &Store, scale: f32) {
    for (name, g) in src.iter() {
        match acc.get_mut(name) {
            Some(t) => {
                for (a, s) in t.f32s_mut().iter_mut().zip(g.f32s()) {
                    *a += scale * s;
                }
            }
            None => {
                let mut t = g.clone();
                for x in t.f32s_mut() {
                    *x *= scale;
                }
                acc.insert(name.clone(), t);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_param(v: f32) -> Store {
        let mut s = Store::new();
        s.insert("w", Tensor::from_f32(&[2, 1], vec![v, v]));
        s
    }

    #[test]
    fn adamw_first_step_matches_closed_form() {
        // With g constant, first AdamW step is -lr * g/(|g| + eps) (+decay).
        let mut p = one_param(1.0);
        let mut g = Store::new();
        g.insert("w", Tensor::from_f32(&[2, 1], vec![0.5, 0.5]));
        let mut opt = AdamW::new(&p, 0.9, 0.999, 1e-8, 0.0, 0.0);
        opt.step(&mut p, &g, 0.1);
        // mh = g, vh = g^2 => update = lr * g/|g| = 0.1
        for x in p.expect("w").f32s() {
            assert!((x - 0.9).abs() < 1e-4, "{x}");
        }
    }

    #[test]
    fn weight_decay_only_on_matrices() {
        let mut p = Store::new();
        p.insert("w", Tensor::from_f32(&[1, 1], vec![1.0]));
        p.insert("ln_g", Tensor::from_f32(&[1], vec![1.0]));
        let mut g = Store::new();
        g.insert("w", Tensor::from_f32(&[1, 1], vec![0.0]));
        g.insert("ln_g", Tensor::from_f32(&[1], vec![0.0]));
        let mut opt = AdamW::new(&p, 0.9, 0.999, 1e-8, 0.1, 0.0);
        opt.step(&mut p, &g, 1.0);
        assert!(p.expect("w").f32s()[0] < 1.0); // decayed
        assert_eq!(p.expect("ln_g").f32s()[0], 1.0); // not decayed
    }

    #[test]
    fn clipping_bounds_update() {
        let mut p = one_param(0.0);
        let mut g = Store::new();
        g.insert("w", Tensor::from_f32(&[2, 1], vec![100.0, 0.0]));
        let mut opt = AdamW::new(&p, 0.0, 0.0, 1e-8, 0.0, 1.0);
        let gnorm = opt.step(&mut p, &g, 0.001);
        assert!((gnorm - 100.0).abs() < 1e-3);
        // clipped g = 1.0 -> beta=0 Adam: update = lr * 1/(1+eps)
        assert!(p.expect("w").f32s()[0].abs() <= 0.0011);
    }

    #[test]
    fn frozen_params_do_not_move() {
        let mut p = one_param(1.0);
        let mut g = Store::new();
        g.insert("w", Tensor::from_f32(&[2, 1], vec![1.0, 1.0]));
        let mut opt = AdamW::new(&p, 0.9, 0.999, 1e-8, 0.0, 0.0);
        opt.freeze_where(&p, |n| n == "w");
        opt.step(&mut p, &g, 0.1);
        assert_eq!(p.expect("w").f32s(), &[1.0, 1.0]);
        opt.unfreeze_all();
        opt.step(&mut p, &g, 0.1);
        assert_ne!(p.expect("w").f32s(), &[1.0, 1.0]);
    }

    #[test]
    fn rebuild_resets_moments_and_freezes_for_grown_params() {
        let mut p = one_param(1.0);
        let mut g = Store::new();
        g.insert("w", Tensor::from_f32(&[2, 1], vec![1.0, 1.0]));
        let mut opt = AdamW::new(&p, 0.9, 0.999, 1e-8, 0.0, 0.0);
        opt.freeze_where(&p, |n| n == "w");
        opt.step(&mut p, &g, 0.1);
        // grown params: different name set and shapes
        let mut grown = Store::new();
        grown.insert("w2", Tensor::from_f32(&[3, 1], vec![0.0; 3]));
        opt.rebuild(&grown);
        assert_eq!(opt.frozen_count(), 0, "freeze set must clear");
        let mut g2 = Store::new();
        g2.insert("w2", Tensor::from_f32(&[3, 1], vec![0.5; 3]));
        opt.step(&mut grown, &g2, 0.1);
        // first step after rebuild behaves like a fresh optimizer:
        // update = -lr * g/|g| (see adamw_first_step_matches_closed_form)
        for x in grown.expect("w2").f32s() {
            assert!((x + 0.1).abs() < 1e-4, "{x}");
        }
    }

    #[test]
    fn sgd_momentum_accumulates() {
        let mut p = one_param(0.0);
        let mut g = Store::new();
        g.insert("w", Tensor::from_f32(&[2, 1], vec![1.0, 1.0]));
        let mut opt = Sgd::new(&p, 0.9);
        opt.step(&mut p, &g, 0.1);
        assert!((p.expect("w").f32s()[0] + 0.1).abs() < 1e-6);
        opt.step(&mut p, &g, 0.1);
        // velocity = 0.9*1 + 1 = 1.9 -> total -0.1-0.19
        assert!((p.expect("w").f32s()[0] + 0.29).abs() < 1e-6);
    }

    #[test]
    fn accumulate_sums_and_creates() {
        let mut acc = Store::new();
        let mut g = Store::new();
        g.insert("w", Tensor::from_f32(&[2], vec![2.0, 4.0]));
        accumulate(&mut acc, &g, 0.5);
        accumulate(&mut acc, &g, 0.5);
        assert_eq!(acc.expect("w").f32s(), &[2.0, 4.0]);
    }

    /// A parameter set with enough tensors/shapes that 3 shards are all
    /// non-empty and the decay mask varies (matrices vs `_b`/`_g` vectors).
    fn varied_params() -> (Store, Store) {
        let mut p = Store::new();
        let mut g = Store::new();
        let specs: [(&str, &[usize]); 5] = [
            ("att_w", &[4, 3]),
            ("ffn_w", &[3, 5]),
            ("head_b", &[5]),
            ("ln_g", &[4]),
            ("emb_w", &[6, 2]),
        ];
        for (i, (name, shape)) in specs.iter().enumerate() {
            let n: usize = shape.iter().product();
            let pv: Vec<f32> = (0..n).map(|j| ((i * 31 + j * 7) as f32 * 0.37).sin()).collect();
            let gv: Vec<f32> = (0..n).map(|j| ((i * 17 + j * 11) as f32 * 0.73).cos()).collect();
            p.insert(*name, Tensor::from_f32(shape, pv));
            g.insert(*name, Tensor::from_f32(shape, gv));
        }
        (p, g)
    }

    fn bits(s: &Store) -> Vec<(String, Vec<u32>)> {
        s.iter()
            .map(|(n, t)| (n.clone(), t.f32s().iter().map(|x| x.to_bits()).collect()))
            .collect()
    }

    #[test]
    fn sharded_step_is_bitwise_identical_to_unsharded_for_any_shard_count() {
        // clip + decay on, several steps: the full update rule must agree
        // bit for bit, because global state is computed before the fan-out
        // and the per-element kernel is shared.
        let (p0, g) = varied_params();
        let mut reference = p0.clone();
        let mut opt = AdamW::new(&reference, 0.9, 0.999, 1e-8, 0.01, 0.5);
        for step in 0..4 {
            opt.step(&mut reference, &g, 1e-2 * (step + 1) as f32);
        }
        for shards in [1, 2, 3, 7] {
            let mut p = p0.clone();
            let mut sopt = ShardedAdamW::new(&p, shards, 0.9, 0.999, 1e-8, 0.01, 0.5);
            assert_eq!(sopt.shard_count(), shards);
            for step in 0..4 {
                sopt.step(&mut p, &g, 1e-2 * (step + 1) as f32);
            }
            assert_eq!(bits(&p), bits(&reference), "{shards} shards diverged");
        }
    }

    #[test]
    fn sharded_respects_freezing_and_reports_gnorm() {
        let (mut p, g) = varied_params();
        let before = p.expect("ln_g").f32s().to_vec();
        let mut sopt = ShardedAdamW::new(&p, 2, 0.9, 0.999, 1e-8, 0.0, 0.0);
        sopt.freeze_where(&p, |n| n == "ln_g");
        assert_eq!(sopt.frozen_count(), 1);
        let gnorm = sopt.step(&mut p, &g, 0.1);
        assert!((gnorm - g.global_norm()).abs() < 1e-6);
        assert_eq!(p.expect("ln_g").f32s(), &before[..], "frozen param moved");
        sopt.unfreeze_all();
        sopt.step(&mut p, &g, 0.1);
        assert_ne!(p.expect("ln_g").f32s(), &before[..]);
    }

    #[test]
    fn reshard_moves_moments_and_keeps_the_trajectory_bitwise() {
        // 2 steps on 2 shards, reshard to 3 mid-run, 2 more steps — must
        // equal 4 uninterrupted steps on 1 shard, bit for bit (reshard
        // moves tensors, never recomputes them).
        let (p0, g) = varied_params();
        let mut reference = p0.clone();
        let mut ropt = ShardedAdamW::new(&reference, 1, 0.9, 0.999, 1e-8, 0.01, 0.0);
        for _ in 0..4 {
            ropt.step(&mut reference, &g, 1e-2);
        }
        let mut p = p0.clone();
        let mut sopt = ShardedAdamW::new(&p, 2, 0.9, 0.999, 1e-8, 0.01, 0.0);
        sopt.step(&mut p, &g, 1e-2);
        sopt.step(&mut p, &g, 1e-2);
        sopt.reshard(3);
        assert_eq!(sopt.shard_count(), 3);
        sopt.step(&mut p, &g, 1e-2);
        sopt.step(&mut p, &g, 1e-2);
        assert_eq!(bits(&p), bits(&reference), "reshard changed the trajectory");
    }

    #[test]
    fn export_import_resumes_the_trajectory_bitwise_across_shard_counts() {
        // 2 steps, snapshot, 2 more steps == 4 uninterrupted steps, bit for
        // bit — including when the snapshot is imported into an optimizer
        // with a different shard count (the LIGO_WORKERS∈{1,2} resume case).
        let (p0, g) = varied_params();
        let mut reference = p0.clone();
        let mut ropt = ShardedAdamW::new(&reference, 1, 0.9, 0.999, 1e-8, 0.01, 0.5);
        for step in 0..4 {
            ropt.step(&mut reference, &g, 1e-2 * (step + 1) as f32);
        }
        let mut p = p0.clone();
        let mut opt = ShardedAdamW::new(&p, 2, 0.9, 0.999, 1e-8, 0.01, 0.5);
        opt.step(&mut p, &g, 1e-2);
        opt.step(&mut p, &g, 2e-2);
        let (m, v, t) = opt.export_state();
        assert_eq!(t, 2);
        for shards in [1, 2, 3] {
            let mut rp = p.clone();
            let mut ropt2 = ShardedAdamW::new(&rp, shards, 0.9, 0.999, 1e-8, 0.01, 0.5);
            ropt2.import_state(m.clone(), v.clone(), t).unwrap();
            ropt2.step(&mut rp, &g, 3e-2);
            ropt2.step(&mut rp, &g, 4e-2);
            assert_eq!(bits(&rp), bits(&reference), "resume on {shards} shards diverged");
        }
    }

    #[test]
    fn import_state_rejects_mismatched_moment_keys() {
        let (p, _) = varied_params();
        let mut opt = ShardedAdamW::new(&p, 2, 0.9, 0.999, 1e-8, 0.0, 0.0);
        let (m, mut v, t) = opt.export_state();
        v.remove("att_w");
        assert!(opt.import_state(m, v, t).is_err());
    }

    #[test]
    fn rebuild_restarts_bias_correction_identically_on_both_paths() {
        // The satellite audit: after a growth rebuild, the step counter
        // must restart at 0 so the first post-growth update uses t=1 bias
        // correction (a fresh-optimizer step), and the sharded path must
        // pin the exact same behavior. With constant g the fresh first
        // step is -lr * g/(|g|+eps) = -lr elementwise.
        let grown_shapes: [(&str, &[usize]); 2] = [("big_w", &[3, 2]), ("big_b", &[4])];
        let mk_grown = || {
            let mut s = Store::new();
            for (n, shape) in grown_shapes {
                s.insert(n, Tensor::from_f32(shape, vec![1.0; shape.iter().product()]));
            }
            s
        };
        let mut gg = Store::new();
        for (n, shape) in grown_shapes {
            gg.insert(n, Tensor::from_f32(shape, vec![0.5; shape.iter().product()]));
        }
        // unsharded: steps before rebuild must not leak into the first
        // post-rebuild update through t
        let (mut p, g) = varied_params();
        let mut opt = AdamW::new(&p, 0.9, 0.999, 1e-8, 0.0, 0.0);
        for _ in 0..3 {
            opt.step(&mut p, &g, 0.1);
        }
        let mut grown_a = mk_grown();
        opt.rebuild(&grown_a);
        opt.step(&mut grown_a, &gg, 0.1);
        for (_, t) in grown_a.iter() {
            for x in t.f32s() {
                assert!((x - 0.9).abs() < 1e-4, "unsharded rebuild must restart t: {x}");
            }
        }
        // sharded: same dance across a different shard count
        let (mut sp, _) = varied_params();
        let mut sopt = ShardedAdamW::new(&sp, 3, 0.9, 0.999, 1e-8, 0.0, 0.0);
        sopt.freeze_where(&sp, |n| n == "ln_g");
        for _ in 0..3 {
            sopt.step(&mut sp, &g, 0.1);
        }
        let mut grown_b = mk_grown();
        sopt.rebuild(&grown_b);
        assert_eq!(sopt.shard_count(), 3, "rebuild keeps the shard count");
        assert_eq!(sopt.frozen_count(), 0, "rebuild clears the freeze set");
        sopt.step(&mut grown_b, &gg, 0.1);
        // identical to the unsharded first post-rebuild step, bit for bit
        assert_eq!(bits(&grown_b), bits(&grown_a), "paths disagree after rebuild");
    }

    #[test]
    fn adamw_converges_on_quadratic() {
        // minimize (w-3)^2: grad = 2(w-3)
        let mut p = one_param(0.0);
        let mut opt = AdamW::new(&p, 0.9, 0.999, 1e-8, 0.0, 0.0);
        for _ in 0..500 {
            let w = p.expect("w").f32s()[0];
            let mut g = Store::new();
            g.insert("w", Tensor::from_f32(&[2, 1], vec![2.0 * (w - 3.0); 2]));
            opt.step(&mut p, &g, 0.05);
        }
        assert!((p.expect("w").f32s()[0] - 3.0).abs() < 0.05);
    }
}
