//! Orthogonal efficiency strategies (paper Fig. 5 / Appendix B.3):
//!
//! * **Progressive layer dropping** (Zhang & He 2020): per-step layer keep
//!   probability ramps down to `1 - max_drop` along training; implemented by
//!   sampling layer gates into the `grad_gated_*` artifact's batch.
//! * **Token dropping** (Hou et al. 2022): a fixed fraction of tokens is
//!   skipped in the middle third of layers.
//! * **Staged training** (Shen et al. 2022): train the small model for the
//!   first stage, grow (with any operator), train the large model for the
//!   rest. Since the growth-API redesign this is a one-stage
//!   [`GrowthPlan`] executed by `Trainer::run_plan`; the generalization —
//!   grow mid-run, repeatedly, as in "Stacking Your Transformers" (Du et
//!   al. 2024) — is [`progressive_plan`] below.

use crate::config::ModelConfig;
use crate::coordinator::flops;
use crate::coordinator::plan::GrowthPlan;
use crate::error::Result;
use crate::growth::LigoOptions;

/// Progressive layer-dropping schedule: drop probability at `step`.
/// Follows Zhang & He's ramp: theta(t) ramps from 0 to `max_drop` over the
/// first half of training, then stays flat.
pub fn layer_drop_p(step: usize, total: usize, max_drop: f32) -> f32 {
    let ramp = (total / 2).max(1);
    let frac = (step as f32 / ramp as f32).min(1.0);
    max_drop * frac
}

/// Expected training FLOPs per step under the combined strategies.
pub fn strategy_flops(
    cfg: &ModelConfig,
    step: usize,
    total: usize,
    max_layer_drop: f32,
    token_drop: f32,
) -> f64 {
    let keep = 1.0 - layer_drop_p(step, total, max_layer_drop) as f64;
    flops::gated_train_step_flops(cfg, keep, 1.0 - token_drop as f64)
}

/// Paper defaults: max layer-drop 0.1, token-drop 0.15 in the middle third.
pub const MAX_LAYER_DROP: f32 = 0.1;
pub const TOKEN_DROP: f32 = 0.15;

/// Build a progressive growth schedule through a chain of configs
/// (`models[0]` is the run's starting config): grow into `models[i]` at
/// step `i * grow_every` using `operator` with `opts` — StackBERT-style
/// progressive stacking when `operator == "stackbert"`, the paper's
/// multi-stage LiGO runs when `"ligo"`. Validation (monotone steps,
/// genuinely-growing compatible configs, known operator) comes from the
/// [`GrowthPlan`] builder.
pub fn progressive_plan(
    models: &[ModelConfig],
    grow_every: usize,
    operator: &str,
    opts: &LigoOptions,
) -> Result<GrowthPlan> {
    let Some((initial, targets)) = models.split_first() else {
        crate::bail!("progressive_plan: need at least the starting config");
    };
    let mut b = GrowthPlan::builder(initial);
    for (i, target) in targets.iter().enumerate() {
        b = b.grow_at_with((i + 1) * grow_every.max(1), target, operator, opts.clone());
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::growth::testutil::mk_cfg;

    #[test]
    fn drop_probability_ramps_then_flattens() {
        assert_eq!(layer_drop_p(0, 100, 0.1), 0.0);
        let mid = layer_drop_p(25, 100, 0.1);
        assert!(mid > 0.0 && mid < 0.1);
        assert!((layer_drop_p(50, 100, 0.1) - 0.1).abs() < 1e-6);
        assert!((layer_drop_p(99, 100, 0.1) - 0.1).abs() < 1e-6);
    }

    #[test]
    fn progressive_plan_builds_the_expected_stages() {
        let chain =
            [mk_cfg(2, 8, 2), mk_cfg(4, 8, 2), mk_cfg(4, 12, 3)];
        let plan =
            progressive_plan(&chain, 50, "stackbert", &LigoOptions::default()).unwrap();
        assert_eq!(plan.stages().len(), 2);
        assert_eq!(plan.stages()[0].at_step, 50);
        assert_eq!(plan.stages()[1].at_step, 100);
        assert_eq!(plan.final_config().dim, 12);
        // a shrinking chain is rejected by the builder
        let bad = [mk_cfg(4, 8, 2), mk_cfg(2, 8, 2)];
        assert!(progressive_plan(&bad, 50, "stackbert", &LigoOptions::default()).is_err());
    }

    #[test]
    fn strategy_flops_below_full() {
        let cfg = mk_cfg(6, 72, 6);
        let full = flops::train_step_flops(&cfg);
        let late = strategy_flops(&cfg, 90, 100, MAX_LAYER_DROP, TOKEN_DROP);
        assert!(late < full);
        let early = strategy_flops(&cfg, 0, 100, MAX_LAYER_DROP, TOKEN_DROP);
        assert!(late < early); // savings grow as dropping ramps
    }
}
