//! Orthogonal efficiency strategies (paper Fig. 5 / Appendix B.3):
//!
//! * **Progressive layer dropping** (Zhang & He 2020): per-step layer keep
//!   probability ramps down to `1 - max_drop` along training; implemented by
//!   sampling layer gates into the `grad_gated_*` artifact's batch.
//! * **Token dropping** (Hou et al. 2022): a fixed fraction of tokens is
//!   skipped in the middle third of layers.
//! * **Staged training** (Shen et al. 2022): train the small model for the
//!   first stage, grow (with any operator), train the large model for the
//!   rest — orchestrated by the experiment harness using the trainer.

use crate::config::ModelConfig;
use crate::coordinator::flops;

/// Progressive layer-dropping schedule: drop probability at `step`.
/// Follows Zhang & He's ramp: theta(t) ramps from 0 to `max_drop` over the
/// first half of training, then stays flat.
pub fn layer_drop_p(step: usize, total: usize, max_drop: f32) -> f32 {
    let ramp = (total / 2).max(1);
    let frac = (step as f32 / ramp as f32).min(1.0);
    max_drop * frac
}

/// Expected training FLOPs per step under the combined strategies.
pub fn strategy_flops(
    cfg: &ModelConfig,
    step: usize,
    total: usize,
    max_layer_drop: f32,
    token_drop: f32,
) -> f64 {
    let keep = 1.0 - layer_drop_p(step, total, max_layer_drop) as f64;
    flops::gated_train_step_flops(cfg, keep, 1.0 - token_drop as f64)
}

/// Paper defaults: max layer-drop 0.1, token-drop 0.15 in the middle third.
pub const MAX_LAYER_DROP: f32 = 0.1;
pub const TOKEN_DROP: f32 = 0.15;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::growth::testutil::mk_cfg;

    #[test]
    fn drop_probability_ramps_then_flattens() {
        assert_eq!(layer_drop_p(0, 100, 0.1), 0.0);
        let mid = layer_drop_p(25, 100, 0.1);
        assert!(mid > 0.0 && mid < 0.1);
        assert!((layer_drop_p(50, 100, 0.1) - 0.1).abs() < 1e-6);
        assert!((layer_drop_p(99, 100, 0.1) - 0.1).abs() < 1e-6);
    }

    #[test]
    fn strategy_flops_below_full() {
        let cfg = mk_cfg(6, 72, 6);
        let full = flops::train_step_flops(&cfg);
        let late = strategy_flops(&cfg, 90, 100, MAX_LAYER_DROP, TOKEN_DROP);
        assert!(late < full);
        let early = strategy_flops(&cfg, 0, 100, MAX_LAYER_DROP, TOKEN_DROP);
        assert!(late < early); // savings grow as dropping ramps
    }
}
