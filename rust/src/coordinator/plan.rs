//! Multi-stage growth schedules: grow mid-run, repeatedly.
//!
//! A [`GrowthPlan`] is a builder-validated schedule of
//! `(step, target ModelConfig, operator)` stages that
//! [`Trainer::run_plan`](crate::coordinator::trainer::Trainer::run_plan)
//! executes mid-run: at each stage's step the trainer grows its parameters
//! through the unified [`crate::growth::GrowthContext`] entry point, swaps
//! in the grown params with fresh optimizer state, re-binds the target
//! config's executables and keeps training — the paper's 2-stage LiGO runs
//! and "Stacking Your Transformers"-style progressive stacking (Du et al.
//! 2024) as data, not bespoke driver code.
//!
//! The builder rejects malformed schedules up front (non-monotone steps,
//! shrinking or batch-incompatible targets, unknown operators, operator
//! regimes the transition violates, and any stage target whose graph the
//! symbolic shape verifier cannot replay — see
//! [`crate::growth::verify`]) so a plan that builds is a plan the trainer
//! can execute.

use std::path::Path;

use crate::bail;
use crate::config::ModelConfig;
use crate::error::{Context, Error, Result};
use crate::growth::{verify, LigoOptions};
use crate::util::json::Json;

/// One growth stage: at `at_step`, grow into `target` via `operator`.
#[derive(Debug, Clone, PartialEq)]
pub struct GrowthStage {
    /// Optimizer step (absolute, within the run) at which to grow.
    pub at_step: usize,
    pub target: ModelConfig,
    /// Registry name resolved through [`crate::growth::by_name`].
    pub operator: String,
    /// M-learning budget for learned operators (ignored by the rest).
    pub opts: LigoOptions,
}

/// A validated multi-stage growth schedule (see the module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct GrowthPlan {
    initial: ModelConfig,
    stages: Vec<GrowthStage>,
}

fn opts_to_json(o: &LigoOptions) -> Json {
    Json::obj(vec![
        ("steps", Json::Num(o.steps as f64)),
        ("lr", Json::Num(o.lr as f64)),
        ("momentum", Json::Num(o.momentum as f64)),
        ("init_noise", Json::Num(o.init_noise as f64)),
        // seeds are u64: a string survives the f64 number representation
        ("seed", Json::Str(o.seed.to_string())),
    ])
}

fn opts_from_json(j: &Json) -> Result<LigoOptions> {
    let d = LigoOptions::default();
    let num = |k: &str, dflt: f64| j.get(k).and_then(Json::as_f64).unwrap_or(dflt);
    let seed = match j.get("seed") {
        None => d.seed,
        Some(Json::Str(s)) => s
            .parse::<u64>()
            .map_err(|_| Error::msg(format!("plan JSON: bad opts.seed {s:?}")))?,
        Some(v) => v.as_f64().context("plan JSON: opts.seed must be a number or string")?
            as u64,
    };
    Ok(LigoOptions {
        steps: num("steps", d.steps as f64) as usize,
        lr: num("lr", d.lr as f64) as f32,
        momentum: num("momentum", d.momentum as f64) as f32,
        init_noise: num("init_noise", d.init_noise as f64) as f32,
        seed,
    })
}

impl GrowthPlan {
    /// Start building a plan for a run that begins on `initial`.
    pub fn builder(initial: &ModelConfig) -> GrowthPlanBuilder {
        GrowthPlanBuilder { initial: initial.clone(), stages: Vec::new() }
    }

    /// The config the run must start on.
    pub fn initial(&self) -> &ModelConfig {
        &self.initial
    }

    pub fn stages(&self) -> &[GrowthStage] {
        &self.stages
    }

    /// The final config the run ends on.
    pub fn final_config(&self) -> &ModelConfig {
        self.stages.last().map(|s| &s.target).unwrap_or(&self.initial)
    }

    /// Serialize the whole schedule as an executable JSON document: full
    /// configs are embedded (not preset names), so a plan over synthesized
    /// search rungs loads on a machine with no registry entry for them.
    pub fn to_json(&self) -> Json {
        let stages = self
            .stages
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("at_step", Json::Num(s.at_step as f64)),
                    ("operator", Json::Str(s.operator.clone())),
                    ("target", s.target.to_json()),
                    ("opts", opts_to_json(&s.opts)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("initial", self.initial.to_json()),
            ("stages", Json::Arr(stages)),
        ])
    }

    /// Deserialize a plan by replaying the document through the builder —
    /// a hand-edited file gets exactly the plan-time diagnostics code gets
    /// (monotone steps, growing targets, operator regimes, symbolic shape
    /// replay), so a [`GrowthPlan`] from JSON is as validated as one built
    /// in-process.
    pub fn from_json(j: &Json) -> Result<GrowthPlan> {
        let initial = ModelConfig::from_json(j.get("initial").context("plan JSON: 'initial'")?)
            .context("plan JSON: initial config")?;
        let mut b = GrowthPlan::builder(&initial);
        let stages = j.get("stages").and_then(Json::as_arr).context("plan JSON: 'stages'")?;
        for (i, sj) in stages.iter().enumerate() {
            let at_step = sj
                .get("at_step")
                .and_then(Json::as_usize)
                .with_context(|| format!("plan JSON: stage {i} 'at_step'"))?;
            let operator = sj
                .get("operator")
                .and_then(Json::as_str)
                .with_context(|| format!("plan JSON: stage {i} 'operator'"))?;
            let target = ModelConfig::from_json(
                sj.get("target").with_context(|| format!("plan JSON: stage {i} 'target'"))?,
            )
            .with_context(|| format!("plan JSON: stage {i} target config"))?;
            let opts = match sj.get("opts") {
                Some(o) => opts_from_json(o).with_context(|| format!("plan JSON: stage {i}"))?,
                None => LigoOptions::default(),
            };
            b = b.grow_at_with(at_step, &target, operator, opts);
        }
        b.build().context("plan JSON: schedule validation")
    }

    /// Parse a plan from JSON text (see [`GrowthPlan::from_json`]).
    pub fn parse(text: &str) -> Result<GrowthPlan> {
        GrowthPlan::from_json(&Json::parse(text).map_err(Error::msg)?)
    }

    /// Write the plan as a JSON file (`ligo search` emits these; `ligo
    /// experiment progressive --plan FILE` executes them).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("create plan dir {dir:?}"))?;
        }
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("write plan {path:?}"))
    }

    /// Load and re-validate a plan file (see [`GrowthPlan::from_json`]).
    pub fn load(path: &Path) -> Result<GrowthPlan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read plan {path:?}"))?;
        GrowthPlan::parse(&text).with_context(|| format!("plan file {path:?}"))
    }
}

/// Builder for [`GrowthPlan`]; `build` validates the whole schedule.
#[derive(Debug)]
pub struct GrowthPlanBuilder {
    initial: ModelConfig,
    stages: Vec<GrowthStage>,
}

impl GrowthPlanBuilder {
    /// Add a stage with the default M-learning options.
    pub fn grow_at(self, at_step: usize, target: &ModelConfig, operator: &str) -> Self {
        self.grow_at_with(at_step, target, operator, LigoOptions::default())
    }

    /// Add a stage with explicit M-learning options.
    pub fn grow_at_with(
        mut self,
        at_step: usize,
        target: &ModelConfig,
        operator: &str,
        opts: LigoOptions,
    ) -> Self {
        self.stages.push(GrowthStage {
            at_step,
            target: target.clone(),
            operator: operator.to_string(),
            opts,
        });
        self
    }

    /// Validate and freeze the schedule. Rejects: steps that are zero or
    /// not strictly increasing, targets that shrink (or change family /
    /// batch geometry, which would break the run's batch source mid-way),
    /// operators the registry does not know, operator regimes the
    /// transition violates (e.g. LEMON's integer-factor widths), and any
    /// stage target the symbolic shape verifier cannot replay — every stage
    /// goes through [`verify::verify_pair`], so the whole schedule is
    /// statically executable before a single kernel runs.
    pub fn build(self) -> Result<GrowthPlan> {
        let mut prev = &self.initial;
        let mut prev_step = 0usize;
        for (i, stage) in self.stages.iter().enumerate() {
            if stage.at_step == 0 {
                bail!(
                    "growth plan stage {i}: at_step must be > 0 (grow before training \
                     starts by initializing the trainer with grown params instead)"
                );
            }
            if i > 0 && stage.at_step <= prev_step {
                bail!(
                    "growth plan stage {i}: steps must be strictly increasing \
                     ({prev_step} then {})",
                    stage.at_step
                );
            }
            verify::verify_pair(&stage.operator, prev, &stage.target)
                .with_context(|| format!("growth plan stage {i} ({} -> {})",
                    prev.name, stage.target.name))?;
            prev = &stage.target;
            prev_step = stage.at_step;
        }
        Ok(GrowthPlan { initial: self.initial, stages: self.stages })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::growth::testutil::mk_cfg;

    #[test]
    fn valid_two_stage_plan_builds() {
        let a = mk_cfg(2, 8, 2);
        let b = mk_cfg(4, 8, 2);
        let c = mk_cfg(4, 12, 3);
        let plan = GrowthPlan::builder(&a)
            .grow_at(10, &b, "stackbert")
            .grow_at(20, &c, "ligo")
            .build()
            .unwrap();
        assert_eq!(plan.stages().len(), 2);
        assert_eq!(plan.initial().name, a.name);
        assert_eq!(plan.final_config().name, c.name);
    }

    #[test]
    fn empty_plan_is_a_plain_run() {
        let a = mk_cfg(2, 8, 2);
        let plan = GrowthPlan::builder(&a).build().unwrap();
        assert!(plan.stages().is_empty());
        assert_eq!(plan.final_config().name, a.name);
    }

    #[test]
    fn rejects_non_monotone_steps() {
        let a = mk_cfg(2, 8, 2);
        let b = mk_cfg(3, 8, 2);
        let c = mk_cfg(4, 8, 2);
        let err = GrowthPlan::builder(&a)
            .grow_at(10, &b, "stackbert")
            .grow_at(10, &c, "stackbert")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("strictly increasing"), "{err}");
        let err = GrowthPlan::builder(&a)
            .grow_at(0, &b, "stackbert")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("at_step must be > 0"), "{err}");
    }

    #[test]
    fn rejects_shrinking_or_lateral_targets() {
        let a = mk_cfg(4, 12, 3);
        let smaller = mk_cfg(2, 8, 2);
        let err = GrowthPlan::builder(&a)
            .grow_at(10, &smaller, "stackbert")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("shrink"), "{err}");
        // identical target: growing nowhere is a schedule bug too
        let err = GrowthPlan::builder(&a)
            .grow_at(10, &a, "stackbert")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("not larger"), "{err}");
    }

    #[test]
    fn rejects_unknown_operators_with_registry_diagnostics() {
        let a = mk_cfg(2, 8, 2);
        let b = mk_cfg(4, 8, 2);
        let err = GrowthPlan::builder(&a)
            .grow_at(10, &b, "nope")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown growth operator"), "{err}");
        assert!(err.contains("stackbert"), "must list known names: {err}");
    }

    #[test]
    fn plan_json_round_trips_to_equality() {
        let a = mk_cfg(2, 8, 2);
        let b = mk_cfg(4, 8, 2);
        let c = mk_cfg(4, 12, 3);
        let opts = LigoOptions { steps: 7, lr: 0.5, seed: 0x9E37_79B9_7F4A_7C15, ..Default::default() };
        let plan = GrowthPlan::builder(&a)
            .grow_at(10, &b, "stackbert")
            .grow_at_with(20, &c, "ligo", opts)
            .build()
            .unwrap();
        let text = plan.to_json().to_string();
        let back = GrowthPlan::parse(&text).unwrap();
        assert_eq!(back, plan, "round-trip must be exact:\n{text}");
        // u64 seeds survive (string-encoded: f64 would round 2^63-ish seeds)
        assert_eq!(back.stages()[1].opts.seed, 0x9E37_79B9_7F4A_7C15);
        // and the empty plan round-trips too
        let empty = GrowthPlan::builder(&a).build().unwrap();
        assert_eq!(GrowthPlan::parse(&empty.to_json().to_string()).unwrap(), empty);
    }

    #[test]
    fn from_json_revalidates_through_the_builder() {
        let a = mk_cfg(2, 8, 2);
        let b = mk_cfg(4, 8, 2);
        let plan = GrowthPlan::builder(&a).grow_at(10, &b, "stackbert").build().unwrap();
        // tamper: at_step 0 must hit the builder's own diagnostic
        let text = plan.to_json().to_string().replace("\"at_step\":10", "\"at_step\":0");
        let err = GrowthPlan::parse(&text).unwrap_err().to_string();
        assert!(err.contains("at_step must be > 0"), "{err}");
        // tamper: unknown operator resolves through the registry listing
        let text = plan.to_json().to_string().replace("stackbert", "nope");
        let err = GrowthPlan::parse(&text).unwrap_err().to_string();
        assert!(err.contains("unknown growth operator"), "{err}");
        // malformed document: missing stages
        let err = GrowthPlan::parse("{\"initial\": {}}").unwrap_err().to_string();
        assert!(err.contains("plan JSON"), "{err}");
    }

    #[test]
    fn plan_files_save_and_load() {
        let dir = std::env::temp_dir().join("ligo_plan_io_test");
        let path = dir.join("plan.json");
        let a = mk_cfg(2, 8, 2);
        let b = mk_cfg(4, 8, 2);
        let plan = GrowthPlan::builder(&a).grow_at(5, &b, "net2net").build().unwrap();
        plan.save(&path).unwrap();
        assert_eq!(GrowthPlan::load(&path).unwrap(), plan);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_batch_geometry_changes() {
        let a = mk_cfg(2, 8, 2);
        let mut b = mk_cfg(4, 12, 3);
        b.vocab = 128; // different batch geometry mid-run
        let err = GrowthPlan::builder(&a)
            .grow_at(10, &b, "stackbert")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("batch geometry"), "{err}");
    }
}
