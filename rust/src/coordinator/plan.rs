//! Multi-stage growth schedules: grow mid-run, repeatedly.
//!
//! A [`GrowthPlan`] is a builder-validated schedule of
//! `(step, target ModelConfig, operator)` stages that
//! [`Trainer::run_plan`](crate::coordinator::trainer::Trainer::run_plan)
//! executes mid-run: at each stage's step the trainer grows its parameters
//! through the unified [`crate::growth::GrowthContext`] entry point, swaps
//! in the grown params with fresh optimizer state, re-binds the target
//! config's executables and keeps training — the paper's 2-stage LiGO runs
//! and "Stacking Your Transformers"-style progressive stacking (Du et al.
//! 2024) as data, not bespoke driver code.
//!
//! The builder rejects malformed schedules up front (non-monotone steps,
//! shrinking or batch-incompatible targets, unknown operators, operator
//! regimes the transition violates, and any stage target whose graph the
//! symbolic shape verifier cannot replay — see
//! [`crate::growth::verify`]) so a plan that builds is a plan the trainer
//! can execute.

use crate::bail;
use crate::config::ModelConfig;
use crate::error::{Context, Result};
use crate::growth::{verify, LigoOptions};

/// One growth stage: at `at_step`, grow into `target` via `operator`.
#[derive(Debug, Clone)]
pub struct GrowthStage {
    /// Optimizer step (absolute, within the run) at which to grow.
    pub at_step: usize,
    pub target: ModelConfig,
    /// Registry name resolved through [`crate::growth::by_name`].
    pub operator: String,
    /// M-learning budget for learned operators (ignored by the rest).
    pub opts: LigoOptions,
}

/// A validated multi-stage growth schedule (see the module docs).
#[derive(Debug, Clone)]
pub struct GrowthPlan {
    initial: ModelConfig,
    stages: Vec<GrowthStage>,
}

impl GrowthPlan {
    /// Start building a plan for a run that begins on `initial`.
    pub fn builder(initial: &ModelConfig) -> GrowthPlanBuilder {
        GrowthPlanBuilder { initial: initial.clone(), stages: Vec::new() }
    }

    /// The config the run must start on.
    pub fn initial(&self) -> &ModelConfig {
        &self.initial
    }

    pub fn stages(&self) -> &[GrowthStage] {
        &self.stages
    }

    /// The final config the run ends on.
    pub fn final_config(&self) -> &ModelConfig {
        self.stages.last().map(|s| &s.target).unwrap_or(&self.initial)
    }
}

/// Builder for [`GrowthPlan`]; `build` validates the whole schedule.
#[derive(Debug)]
pub struct GrowthPlanBuilder {
    initial: ModelConfig,
    stages: Vec<GrowthStage>,
}

impl GrowthPlanBuilder {
    /// Add a stage with the default M-learning options.
    pub fn grow_at(self, at_step: usize, target: &ModelConfig, operator: &str) -> Self {
        self.grow_at_with(at_step, target, operator, LigoOptions::default())
    }

    /// Add a stage with explicit M-learning options.
    pub fn grow_at_with(
        mut self,
        at_step: usize,
        target: &ModelConfig,
        operator: &str,
        opts: LigoOptions,
    ) -> Self {
        self.stages.push(GrowthStage {
            at_step,
            target: target.clone(),
            operator: operator.to_string(),
            opts,
        });
        self
    }

    /// Validate and freeze the schedule. Rejects: steps that are zero or
    /// not strictly increasing, targets that shrink (or change family /
    /// batch geometry, which would break the run's batch source mid-way),
    /// operators the registry does not know, operator regimes the
    /// transition violates (e.g. LEMON's integer-factor widths), and any
    /// stage target the symbolic shape verifier cannot replay — every stage
    /// goes through [`verify::verify_pair`], so the whole schedule is
    /// statically executable before a single kernel runs.
    pub fn build(self) -> Result<GrowthPlan> {
        let mut prev = &self.initial;
        let mut prev_step = 0usize;
        for (i, stage) in self.stages.iter().enumerate() {
            if stage.at_step == 0 {
                bail!(
                    "growth plan stage {i}: at_step must be > 0 (grow before training \
                     starts by initializing the trainer with grown params instead)"
                );
            }
            if i > 0 && stage.at_step <= prev_step {
                bail!(
                    "growth plan stage {i}: steps must be strictly increasing \
                     ({prev_step} then {})",
                    stage.at_step
                );
            }
            verify::verify_pair(&stage.operator, prev, &stage.target)
                .with_context(|| format!("growth plan stage {i} ({} -> {})",
                    prev.name, stage.target.name))?;
            prev = &stage.target;
            prev_step = stage.at_step;
        }
        Ok(GrowthPlan { initial: self.initial, stages: self.stages })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::growth::testutil::mk_cfg;

    #[test]
    fn valid_two_stage_plan_builds() {
        let a = mk_cfg(2, 8, 2);
        let b = mk_cfg(4, 8, 2);
        let c = mk_cfg(4, 12, 3);
        let plan = GrowthPlan::builder(&a)
            .grow_at(10, &b, "stackbert")
            .grow_at(20, &c, "ligo")
            .build()
            .unwrap();
        assert_eq!(plan.stages().len(), 2);
        assert_eq!(plan.initial().name, a.name);
        assert_eq!(plan.final_config().name, c.name);
    }

    #[test]
    fn empty_plan_is_a_plain_run() {
        let a = mk_cfg(2, 8, 2);
        let plan = GrowthPlan::builder(&a).build().unwrap();
        assert!(plan.stages().is_empty());
        assert_eq!(plan.final_config().name, a.name);
    }

    #[test]
    fn rejects_non_monotone_steps() {
        let a = mk_cfg(2, 8, 2);
        let b = mk_cfg(3, 8, 2);
        let c = mk_cfg(4, 8, 2);
        let err = GrowthPlan::builder(&a)
            .grow_at(10, &b, "stackbert")
            .grow_at(10, &c, "stackbert")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("strictly increasing"), "{err}");
        let err = GrowthPlan::builder(&a)
            .grow_at(0, &b, "stackbert")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("at_step must be > 0"), "{err}");
    }

    #[test]
    fn rejects_shrinking_or_lateral_targets() {
        let a = mk_cfg(4, 12, 3);
        let smaller = mk_cfg(2, 8, 2);
        let err = GrowthPlan::builder(&a)
            .grow_at(10, &smaller, "stackbert")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("shrink"), "{err}");
        // identical target: growing nowhere is a schedule bug too
        let err = GrowthPlan::builder(&a)
            .grow_at(10, &a, "stackbert")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("not larger"), "{err}");
    }

    #[test]
    fn rejects_unknown_operators_with_registry_diagnostics() {
        let a = mk_cfg(2, 8, 2);
        let b = mk_cfg(4, 8, 2);
        let err = GrowthPlan::builder(&a)
            .grow_at(10, &b, "nope")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown growth operator"), "{err}");
        assert!(err.contains("stackbert"), "must list known names: {err}");
    }

    #[test]
    fn rejects_batch_geometry_changes() {
        let a = mk_cfg(2, 8, 2);
        let mut b = mk_cfg(4, 12, 3);
        b.vocab = 128; // different batch geometry mid-run
        let err = GrowthPlan::builder(&a)
            .grow_at(10, &b, "stackbert")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("batch geometry"), "{err}");
    }
}
