//! Metrics: training curves over (step, FLOPs, wall time) and the paper's
//! headline statistic — savings-% at the scratch baseline's final quality.

use std::fmt::Write as _;

use crate::util::json::Json;

/// One training curve: parallel series indexed by evaluation points.
#[derive(Debug, Clone, Default)]
pub struct Curve {
    pub name: String,
    pub steps: Vec<usize>,
    pub flops: Vec<f64>,
    pub wall: Vec<f64>,
    pub loss: Vec<f32>,
    /// Optional task metric (accuracy / EM) aligned with `loss`.
    pub metric: Vec<f32>,
    /// Run events worth plotting as vertical markers — e.g. the growth
    /// steps of a [`crate::coordinator::plan::GrowthPlan`] run.
    pub marks: Vec<(usize, String)>,
}

impl Curve {
    pub fn new(name: impl Into<String>) -> Curve {
        Curve { name: name.into(), ..Default::default() }
    }

    pub fn push(&mut self, step: usize, flops: f64, wall: f64, loss: f32, metric: Option<f32>) {
        self.steps.push(step);
        self.flops.push(flops);
        self.wall.push(wall);
        self.loss.push(loss);
        if let Some(m) = metric {
            self.metric.push(m);
        }
    }

    /// Record a run event (growth step, stage switch) at `step`.
    pub fn mark(&mut self, step: usize, label: impl Into<String>) {
        self.marks.push((step, label.into()));
    }

    pub fn final_loss(&self) -> f32 {
        // average the last few points to de-noise the threshold
        let n = self.loss.len();
        let k = n.min(3);
        self.loss[n - k..].iter().sum::<f32>() / k as f32
    }

    pub fn final_metric(&self) -> Option<f32> {
        let n = self.metric.len();
        if n == 0 {
            return None;
        }
        let k = n.min(3);
        Some(self.metric[n - k..].iter().sum::<f32>() / k as f32)
    }

    /// First x (from `xs`) at which loss reaches `target` (<=). None if never.
    fn first_reach(&self, xs: &[f64], target: f32) -> Option<f64> {
        self.loss.iter().zip(xs).find(|(l, _)| **l <= target).map(|(_, x)| *x)
    }

    pub fn flops_to_reach(&self, target: f32) -> Option<f64> {
        self.first_reach(&self.flops, target)
    }

    pub fn wall_to_reach(&self, target: f32) -> Option<f64> {
        self.first_reach(&self.wall, target)
    }

    /// CSV serialization (step,flops,wall,loss[,metric]).
    pub fn to_csv(&self) -> String {
        let has_metric = !self.metric.is_empty();
        let mut out = String::from(if has_metric {
            "step,flops,wall_s,loss,metric\n"
        } else {
            "step,flops,wall_s,loss\n"
        });
        for i in 0..self.steps.len() {
            if has_metric {
                let _ = writeln!(
                    out,
                    "{},{:.6e},{:.3},{:.6},{:.6}",
                    self.steps[i], self.flops[i], self.wall[i], self.loss[i], self.metric[i]
                );
            } else {
                let _ = writeln!(
                    out,
                    "{},{:.6e},{:.3},{:.6}",
                    self.steps[i], self.flops[i], self.wall[i], self.loss[i]
                );
            }
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("steps", Json::Arr(self.steps.iter().map(|s| Json::Num(*s as f64)).collect())),
            ("flops", Json::arr_f64(&self.flops)),
            ("wall", Json::arr_f64(&self.wall)),
            ("loss", Json::Arr(self.loss.iter().map(|l| Json::Num(*l as f64)).collect())),
            ("metric", Json::Arr(self.metric.iter().map(|l| Json::Num(*l as f64)).collect())),
            (
                "marks",
                Json::Arr(
                    self.marks
                        .iter()
                        .map(|(s, l)| {
                            Json::obj(vec![
                                ("step", Json::Num(*s as f64)),
                                ("label", Json::Str(l.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Rebuild a curve from its [`Curve::to_json`] form. Numeric series
    /// round-trip bitwise (the JSON writer prints floats shortest-roundtrip
    /// and `f32` widens to `f64` exactly), which the checkpoint/resume
    /// bit-identity invariant relies on.
    pub fn from_json(j: &Json) -> crate::error::Result<Curve> {
        use crate::error::Context;
        let name =
            j.get("name").and_then(Json::as_str).context("curve: missing 'name'")?.to_string();
        let nums = |key: &str| -> crate::error::Result<Vec<f64>> {
            j.get(key)
                .and_then(Json::as_arr)
                .with_context(|| format!("curve '{name}': missing series '{key}'"))?
                .iter()
                .map(|v| {
                    v.as_f64()
                        .with_context(|| format!("curve '{name}': non-numeric '{key}' entry"))
                })
                .collect()
        };
        let steps: Vec<usize> = nums("steps")?.iter().map(|&x| x as usize).collect();
        let flops = nums("flops")?;
        let wall = nums("wall")?;
        let loss: Vec<f32> = nums("loss")?.iter().map(|&x| x as f32).collect();
        let metric: Vec<f32> = nums("metric")?.iter().map(|&x| x as f32).collect();
        if flops.len() != steps.len() || wall.len() != steps.len() || loss.len() != steps.len() {
            crate::bail!("curve '{name}': series lengths disagree");
        }
        let mut marks = Vec::new();
        if let Some(arr) = j.get("marks").and_then(Json::as_arr) {
            for m in arr {
                let step = m
                    .get("step")
                    .and_then(Json::as_usize)
                    .with_context(|| format!("curve '{name}': mark missing 'step'"))?;
                let label = m
                    .get("label")
                    .and_then(Json::as_str)
                    .with_context(|| format!("curve '{name}': mark missing 'label'"))?
                    .to_string();
                marks.push((step, label));
            }
        }
        Ok(Curve { name, steps, flops, wall, loss, metric, marks })
    }
}

/// The paper's savings statistic: 1 - cost(method)/cost(scratch), where cost
/// is FLOPs (or wall time) to reach the scratch run's final quality. For
/// `higher_better = true` (accuracy figures) the curve's `metric` series is
/// used when present (falling back to `loss`), and "reach" means >=.
pub fn savings(scratch: &Curve, method: &Curve, wall: bool, higher_better: bool) -> Option<f64> {
    let series = |c: &Curve| -> Vec<f32> {
        let raw = if higher_better && !c.metric.is_empty() { &c.metric } else { &c.loss };
        raw.iter().map(|x| if higher_better { -x } else { *x }).collect()
    };
    let s_series = series(scratch);
    let m_series = series(method);
    let target = {
        let n = s_series.len();
        let k = n.min(3);
        s_series[n - k..].iter().sum::<f32>() / k as f32
    };
    let xs_s: &[f64] = if wall { &scratch.wall } else { &scratch.flops };
    let xs_m: &[f64] = if wall { &method.wall } else { &method.flops };
    let reach = |vals: &[f32], xs: &[f64]| -> Option<f64> {
        vals.iter().zip(xs).find(|(l, _)| **l <= target).map(|(_, x)| *x)
    };
    let cost_scratch = reach(&s_series, xs_s)?;
    let cost_method = reach(&m_series, xs_m)?;
    Some(1.0 - cost_method / cost_scratch)
}

/// Write a set of curves as a JSON report + per-curve CSVs under `dir`.
pub fn write_report(
    dir: &std::path::Path,
    experiment: &str,
    curves: &[Curve],
) -> crate::error::Result<()> {
    std::fs::create_dir_all(dir)?;
    for c in curves {
        std::fs::write(dir.join(format!("{experiment}_{}.csv", c.name)), c.to_csv())?;
    }
    let j = Json::obj(vec![
        ("experiment", Json::Str(experiment.to_string())),
        ("curves", Json::Arr(curves.iter().map(Curve::to_json).collect())),
    ]);
    std::fs::write(dir.join(format!("{experiment}.json")), j.to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(name: &str, losses: &[f32], flops_per: f64) -> Curve {
        let mut c = Curve::new(name);
        for (i, l) in losses.iter().enumerate() {
            c.push(i, flops_per * (i as f64 + 1.0), 0.1 * (i as f64 + 1.0), *l, None);
        }
        c
    }

    #[test]
    fn savings_for_faster_method() {
        // target = mean of scratch's last 3 losses = 1.1333; scratch reaches
        // it at x=9 (loss 1.1), method at x=5 (loss 1.05) => 44.4% savings.
        let scratch = mk("scratch", &[5.0, 4.0, 3.0, 2.5, 2.0, 1.8, 1.5, 1.3, 1.1, 1.0], 1.0);
        let method = mk("ligo", &[3.0, 2.0, 1.5, 1.2, 1.05, 0.99, 0.9, 0.85, 0.8, 0.75], 1.0);
        let s = savings(&scratch, &method, false, false).unwrap();
        assert!((s - (1.0 - 5.0 / 9.0)).abs() < 1e-6, "{s}");
    }

    #[test]
    fn negative_savings_for_slower_method() {
        let scratch = mk("scratch", &[2.0, 1.0], 1.0);
        let slow = mk("kd", &[3.0, 2.0, 1.5, 1.0], 1.0);
        let s = savings(&scratch, &slow, false, false).unwrap();
        assert!(s < 0.0);
    }

    #[test]
    fn savings_none_if_never_reached() {
        let scratch = mk("scratch", &[2.0, 1.0], 1.0);
        let bad = mk("bad", &[3.0, 2.9, 2.8], 1.0);
        assert!(savings(&scratch, &bad, false, false).is_none());
    }

    #[test]
    fn accuracy_mode_flips_comparison() {
        // target acc = mean(0.2, 0.5, 0.8) = 0.5; scratch reaches at x=2,
        // method at x=1 => 50% savings.
        let scratch = mk("scratch", &[0.2, 0.5, 0.8], 1.0);
        let fast = mk("ligo", &[0.8, 0.85, 0.9], 1.0);
        let s = savings(&scratch, &fast, false, true).unwrap();
        assert!((s - 0.5).abs() < 1e-6, "{s}");
    }

    #[test]
    fn csv_roundtrip_shape() {
        let c = mk("x", &[1.0, 0.5], 2.0);
        let csv = c.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("step,flops"));
    }

    #[test]
    fn final_loss_averages_tail() {
        let c = mk("x", &[5.0, 1.0, 1.0, 1.0], 1.0);
        assert!((c.final_loss() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn json_roundtrip_is_bitwise() {
        let mut c = Curve::new("rt");
        // Deliberately awkward floats: non-terminating binary fractions,
        // tiny and huge magnitudes, values with no short decimal form.
        c.push(0, 1.0e12 + 0.3, 0.000_123_456, 1.234_567_9, Some(0.1));
        c.push(7, 2.5e15, 17.25, std::f32::consts::PI, None);
        c.mark(7, "grew bert_small -> bert_base via ligo (x)");
        let text = c.to_json().to_string();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        let back = Curve::from_json(&parsed).unwrap();
        assert_eq!(back.name, c.name);
        assert_eq!(back.steps, c.steps);
        assert_eq!(back.marks, c.marks);
        for (a, b) in c.flops.iter().zip(&back.flops) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in c.wall.iter().zip(&back.wall) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in c.loss.iter().zip(&back.loss) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(back.metric.len(), 1);
        assert_eq!(back.metric[0].to_bits(), 0.1f32.to_bits());
    }

    #[test]
    fn from_json_rejects_ragged_series() {
        let mut j = mk("x", &[1.0, 0.5], 2.0).to_json();
        if let crate::util::json::Json::Obj(m) = &mut j {
            m.insert("loss".into(), crate::util::json::Json::Arr(vec![]));
        }
        assert!(Curve::from_json(&j).is_err());
    }
}
