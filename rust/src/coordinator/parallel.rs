//! The `LIGO_WORKERS` data-parallel worker pool: one scoped worker per
//! shard of a train step's microbatches, each owning its own arena
//! (thread-local pool + shared overflow draw), its [`Shard`] of the global
//! microbatch stream, and a forward/backward through the existing tape
//! engine ([`Executable::run`] is stateless per call, so one grad
//! executable serves every worker concurrently).
//!
//! Determinism contract: workers only *compute* gradient leaves; they never
//! reduce. Leaves return to the coordinator tagged with their global
//! microbatch index and are summed by the canonical tree in
//! [`crate::util::allreduce`], whose shape depends on the microbatch count
//! alone — so `LIGO_WORKERS=1`, `=2` and `=4` produce bit-identical steps.
//! Each worker also caps its kernel fan-out at `threads()/workers`
//! ([`crate::util::par::set_thread_budget`]) so the pool never
//! oversubscribes the host, and pins the dispatching thread's effective
//! fused-kernel lowering ([`crate::tensor::ops`] overrides) so a test or
//! bench that A/Bs lowerings on the main thread governs its workers too.
//!
//! Resolution of the knob: [`requested_workers`] reads `LIGO_WORKERS` once
//! per process; `None` (unset) keeps the historical serial
//! `Trainer::train_step` path byte for byte, `Some(n)` routes the trainer
//! through [`run_microbatches`]. Tests pin a value per thread with
//! [`set_workers_override`].

use std::cell::Cell;
use std::sync::{Arc, OnceLock};

use crate::data::loader::Shard;
use crate::error::Result;
use crate::runtime::Executable;
use crate::tensor::ops;
use crate::tensor::{arena, store::Store};
use crate::util::par;

/// A shareable batch source: a pure function of the *global* microbatch
/// index, callable from any worker thread. The serial path's stateful
/// `FnMut` sources cannot be split across workers; batch closures that
/// derive everything from the index (the repo's seeded-RNG idiom) can.
pub type SharedBatchFn = Arc<dyn Fn(usize) -> Store + Send + Sync>;

thread_local! {
    /// Per-thread override of [`requested_workers`] (tests pin 1 vs N in
    /// one process without racing on the environment).
    static WORKERS_OVERRIDE: Cell<Option<usize>> = const { Cell::new(None) };
}

/// The `LIGO_WORKERS` resolution: `None` when unset (the serial trainer
/// path), `Some(n >= 1)` when set. Env is read once per process through
/// the [`crate::util::knobs`] registry — a non-numeric value warns once
/// (naming the knob and the rejected value) and keeps the serial path; the
/// thread-local [`set_workers_override`] wins when present.
pub fn requested_workers() -> Option<usize> {
    if let Some(n) = WORKERS_OVERRIDE.with(|c| c.get()) {
        return Some(n.max(1));
    }
    static WORKERS: OnceLock<Option<usize>> = OnceLock::new();
    *WORKERS.get_or_init(|| crate::util::knobs::usize_env("LIGO_WORKERS").map(|n| n.max(1)))
}

/// Pin [`requested_workers`] to `Some(n)` on this thread; `None` restores
/// the env default. The bit-identity tests run the same training twice in
/// one process, once per worker count, through this.
pub fn set_workers_override(v: Option<usize>) {
    WORKERS_OVERRIDE.with(|c| c.set(v));
}

/// One parallel step's raw material, back in deterministic order.
pub struct MicrobatchRun {
    /// `(gradient store, loss)` per microbatch, indexed by the *global
    /// microbatch position* within the step — worker-count independent.
    pub leaves: Vec<(Store, f32)>,
    /// Per-worker arena counters for this step (worker order).
    pub stats: Vec<arena::WorkerStats>,
}

/// Run one train step's `accum` microbatches across `workers` scoped
/// workers (capped at the microbatch count — extra workers would idle).
/// Worker `w` owns the leaves `m ≡ w (mod active)` per the [`Shard`] law;
/// each computes its leaves' forward/backward through `exe` and returns
/// them tagged, so the caller can reduce in canonical order. On error the
/// lowest-indexed failing worker's error wins (deterministic), after every
/// worker has finished.
#[allow(clippy::too_many_arguments)]
pub fn run_microbatches(
    exe: &Executable,
    params: &Store,
    extra: &[(String, Store)],
    batches: &SharedBatchFn,
    base: usize,
    accum: usize,
    workers: usize,
    cfg_name: &str,
) -> Result<MicrobatchRun> {
    let accum = accum.max(1);
    let active = workers.clamp(1, accum);
    let kernel_budget = (par::threads() / active).max(1);
    // effective lowering on the dispatching thread, pinned into workers
    let fused = ops::fused_enabled();
    let fused_xent = ops::fused_xent_enabled();

    type WorkerOut = Result<(Vec<(usize, Store, f32)>, arena::WorkerStats)>;
    let per_worker: Vec<WorkerOut> = std::thread::scope(|sc| {
        let handles: Vec<_> = (0..active)
            .map(|w| {
                let shard = Shard::new(w, active);
                sc.spawn(move || -> WorkerOut {
                    par::set_thread_budget(Some(kernel_budget));
                    ops::set_fused_override(Some(fused));
                    ops::set_fused_xent_override(Some(fused_xent));
                    arena::set_shared_draw(true);
                    let leaves =
                        worker_leaves(exe, params, extra, batches, base, accum, shard, cfg_name);
                    let stats =
                        arena::worker_stats(w, leaves.as_ref().map(Vec::len).unwrap_or(0));
                    // hand this worker's buffers to the next step's workers
                    arena::flush_to_shared();
                    leaves.map(|l| (l, stats))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|p| std::panic::resume_unwind(p)))
            .collect()
    });

    let mut slots: Vec<Option<(Store, f32)>> = (0..accum).map(|_| None).collect();
    // lint:allow(fresh_alloc) tiny per-step bookkeeping vec, not tensor data
    let mut stats = Vec::with_capacity(active);
    let mut first_err = None;
    for res in per_worker {
        match res {
            Ok((leaves, st)) => {
                stats.push(st);
                for (m, grads, loss) in leaves {
                    slots[m] = Some((grads, loss));
                }
            }
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    let leaves = slots
        .into_iter()
        .map(|s| s.expect("every microbatch is owned by exactly one worker"))
        .collect();
    Ok(MicrobatchRun { leaves, stats })
}

/// One worker's leaves: forward/backward per owned microbatch, tagged with
/// the global microbatch position within the step.
#[allow(clippy::too_many_arguments)]
fn worker_leaves(
    exe: &Executable,
    params: &Store,
    extra: &[(String, Store)],
    batches: &SharedBatchFn,
    base: usize,
    accum: usize,
    shard: Shard,
    cfg_name: &str,
) -> Result<Vec<(usize, Store, f32)>> {
    let mut leaves = Vec::new();
    for m in (0..accum).filter(|&m| shard.owns(m)) {
        let batch = batches(base + m);
        let mut bindings: Vec<(&str, &Store)> = vec![("params", params), ("batch", &batch)];
        for (g, s) in extra {
            bindings.push((g.as_str(), s));
        }
        let mut out = exe.run(&bindings)?;
        let (loss, grads) = super::trainer::take_loss_and_grads(&mut out, cfg_name)?;
        leaves.push((m, grads, loss));
    }
    Ok(leaves)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ExecEngine, Manifest, TensorSpec};
    use crate::tensor::Tensor;

    #[test]
    fn workers_override_pins_and_restores() {
        // (no LIGO_WORKERS in the test env; the override is thread-local)
        set_workers_override(Some(3));
        assert_eq!(requested_workers(), Some(3));
        set_workers_override(Some(0)); // clamped, never 0
        assert_eq!(requested_workers(), Some(1));
        set_workers_override(None);
    }

    /// Engine whose loss and gradient encode the batch it was given, so the
    /// test can prove every microbatch ran and came back in global order.
    struct Echo;

    impl ExecEngine for Echo {
        fn execute(&self, inputs: &[&Tensor], outputs: &[TensorSpec]) -> Result<Vec<Tensor>> {
            let tag = inputs[0].f32s()[0];
            Ok(outputs
                .iter()
                .map(|s| Tensor::from_f32(&s.shape, vec![tag; s.numel()]))
                .collect())
        }
    }

    fn echo_exe() -> Executable {
        let manifest = Manifest::parse(
            r#"{"name": "echo", "inputs": [
                 {"name": "batch/tag", "shape": [1], "dtype": "float32"}
               ], "outputs": [
                 {"name": "loss", "shape": [], "dtype": "float32"},
                 {"name": "grads/w", "shape": [2], "dtype": "float32"}
               ]}"#,
        )
        .unwrap();
        Executable::new(manifest, Box::new(Echo))
    }

    fn tag_batches() -> SharedBatchFn {
        Arc::new(|g: usize| {
            let mut s = Store::new();
            s.insert("tag", Tensor::from_f32(&[1], vec![g as f32]));
            s
        })
    }

    #[test]
    fn leaves_come_back_in_global_microbatch_order_for_any_worker_count() {
        let exe = echo_exe();
        let batches = tag_batches();
        let accum = 5;
        let base = 40;
        for workers in [1, 2, 4, 9] {
            let run =
                run_microbatches(&exe, &Store::new(), &[], &batches, base, accum, workers, "echo")
                    .unwrap();
            assert_eq!(run.leaves.len(), accum);
            for (m, (grads, loss)) in run.leaves.iter().enumerate() {
                let expect = (base + m) as f32;
                assert_eq!(*loss, expect, "loss leaf {m} with {workers} workers");
                assert_eq!(grads.expect("w").f32s(), &[expect; 2]);
            }
            let active = workers.min(accum);
            assert_eq!(run.stats.len(), active);
            let covered: usize = run.stats.iter().map(|s| s.microbatches).sum();
            assert_eq!(covered, accum, "workers must tile the microbatches");
        }
    }

    #[test]
    fn worker_errors_surface_deterministically() {
        // an executable with no grads group: every worker fails; the
        // reported error must be the familiar trainer bail text
        let manifest = Manifest::parse(
            r#"{"name": "gap", "inputs": [
                 {"name": "batch/tag", "shape": [1], "dtype": "float32"}
               ], "outputs": [
                 {"name": "loss", "shape": [], "dtype": "float32"}
               ]}"#,
        )
        .unwrap();
        let exe = Executable::new(manifest, Box::new(Echo));
        let err = run_microbatches(&exe, &Store::new(), &[], &tag_batches(), 0, 4, 2, "gap")
            .unwrap_err();
        assert!(err.to_string().contains("no 'grads' group"), "{err}");
    }
}
