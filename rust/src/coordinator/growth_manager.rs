//! The LiGO growth manager — the paper's §3.2/3.3 pipeline at runtime:
//!
//! 1. initialize M with the stacking + neuron-duplication pattern
//!    (Prop. 1: LiGO's family contains StackBERT/Net2Net, so this start
//!    point *is* the best non-learned baseline);
//! 2. run N (default 100) SGD-momentum steps on M;
//! 3. materialize Theta_large = M(Theta_small);
//! 4. account the extra FLOPs (Table 3) and hand the params to the trainer.
//!
//! Routing goes through the runtime's [`Backend`](crate::runtime::Backend):
//! when the `ligo_grad_{s}__{t}` / `ligo_apply_{s}__{t}` artifacts compile
//! (the `pjrt`-feature fast path), M trains against the expanded model's
//! *task loss*, exactly as the paper prescribes. Otherwise the manager
//! falls back to the native operator ([`crate::growth::ligo`]), which
//! learns M on the surrogate least-squares objective — no artifacts, no
//! XLA, same operator family.

use std::sync::Arc;

use crate::config::ModelConfig;
use crate::coordinator::flops;
use crate::coordinator::optim::Sgd;
use crate::error::{Context, Result};
use crate::log_info;
use crate::runtime::{Executable, Runtime};
use crate::tensor::{store::Store, Tensor};
use crate::util::rng::Rng;

/// Hyperparameters of the M-learning phase.
#[derive(Debug, Clone)]
pub struct LigoOptions {
    pub steps: usize,
    pub lr: f32,
    pub momentum: f32,
    pub init_noise: f32,
    pub seed: u64,
}

impl Default for LigoOptions {
    fn default() -> Self {
        // 100 steps of SGD, as in the paper (§3.2 "Training").
        LigoOptions { steps: 100, lr: 0.02, momentum: 0.9, init_noise: 0.01, seed: 0 }
    }
}

/// Result of a growth: the large params + cost accounting.
pub struct Grown {
    pub params: Store,
    pub extra_flops: f64,
    pub wall_s: f64,
    pub final_m_loss: f32,
}

/// Initialize the LiGO parameter store M from manifest shapes: width
/// matrices get the cyclic duplication pattern, depth matrices the stacking
/// pattern (both + symmetry-breaking noise) — mirrors python ligo_init.
pub fn ligo_init_store(shapes: &[(String, Vec<usize>)], noise: f32, seed: u64) -> Store {
    let mut rng = Rng::new(seed ^ 0x11C0);
    let mut store = Store::new();
    for (name, shape) in shapes {
        assert_eq!(shape.len(), 2, "LiGO params are matrices: {name}");
        let (rows, cols) = (shape[0], shape[1]);
        let mut data = vec![0.0f32; rows * cols];
        for r in 0..rows {
            data[r * cols + (r % cols)] = 1.0;
        }
        for v in data.iter_mut() {
            *v += noise * rng.normal();
        }
        store.insert(name.clone(), Tensor::from_f32(shape, data));
    }
    store
}

/// Grow `small_params` into the target config by learning M on batches from
/// `batches` (the pretraining distribution). Tries the artifact fast path
/// first; falls back to the native LiGO operator **only** when the backend
/// cannot load/compile the artifacts (default no-`pjrt` build, or artifacts
/// not built). Errors from the M-training loop itself are real failures and
/// propagate — they must not silently switch the training objective.
pub fn ligo_grow(
    rt: &Runtime,
    small: &ModelConfig,
    large: &ModelConfig,
    small_params: &Store,
    batches: &mut dyn FnMut(usize) -> Store,
    opts: &LigoOptions,
) -> Result<Grown> {
    let pair = format!("{}__{}", small.name, large.name);
    let loaded = rt
        .load(&format!("ligo_grad_{pair}"))
        .and_then(|grad| rt.load(&format!("ligo_apply_{pair}")).map(|apply| (grad, apply)));
    match loaded {
        Ok((grad, apply)) => {
            ligo_train_artifact(&grad, &apply, small, large, small_params, batches, opts)
        }
        Err(e) => {
            log_info!(
                "LiGO artifacts unavailable for {}->{} ({e}); using the native operator",
                small.name,
                large.name
            );
            ligo_grow_native(small, large, small_params, opts)
        }
    }
}

/// The `pjrt`-feature fast path: M trained on the expanded model's task
/// loss through the `ligo_grad_{s}__{t}` artifact, applied via
/// `ligo_apply_{s}__{t}`. No fallback: artifact-load errors surface here.
pub fn ligo_grow_artifact(
    rt: &Runtime,
    small: &ModelConfig,
    large: &ModelConfig,
    small_params: &Store,
    batches: &mut dyn FnMut(usize) -> Store,
    opts: &LigoOptions,
) -> Result<Grown> {
    let pair = format!("{}__{}", small.name, large.name);
    let grad = rt
        .load(&format!("ligo_grad_{pair}"))
        .with_context(|| format!("no ligo_grad artifact for pair {pair}"))?;
    let apply = rt.load(&format!("ligo_apply_{pair}"))?;
    ligo_train_artifact(&grad, &apply, small, large, small_params, batches, opts)
}

/// The M-training loop over loaded artifacts (shared by [`ligo_grow`] and
/// [`ligo_grow_artifact`]).
#[allow(clippy::too_many_arguments)]
fn ligo_train_artifact(
    grad: &Arc<Executable>,
    apply: &Arc<Executable>,
    small: &ModelConfig,
    large: &ModelConfig,
    small_params: &Store,
    batches: &mut dyn FnMut(usize) -> Store,
    opts: &LigoOptions,
) -> Result<Grown> {
    let timer = crate::util::timer::Timer::new();
    let mut m = ligo_init_store(&grad.manifest.shapes_of("ligo"), opts.init_noise, opts.seed);
    let mut sgd = Sgd::new(&m, opts.momentum);
    let mut last_loss = f32::NAN;
    for step in 0..opts.steps {
        let batch = batches(step);
        let out = grad.run(&[("ligo", &m), ("small", small_params), ("batch", &batch)])?;
        last_loss = out.scalar("loss").unwrap_or(f32::NAN);
        let grads = out.groups.get("grads").expect("ligo grads");
        // cosine-ish decay over the short M-learning phase (shared schedule)
        let lr = crate::growth::ligo::m_lr_at(opts.lr, step, opts.steps);
        sgd.step(&mut m, grads, lr);
        if step % 25 == 0 {
            log_info!("ligo M-step {step}: loss {last_loss:.4}");
        }
    }
    let out = apply.run(&[("ligo", &m), ("small", small_params)])?;
    let params = out
        .groups
        .get("out")
        .expect("ligo_apply returns params")
        .clone();
    let extra_flops = opts.steps as f64 * flops::ligo_step_flops(small, large)
        + flops::ligo_apply_flops(small, large);
    Ok(Grown { params, extra_flops, wall_s: timer.elapsed(), final_m_loss: last_loss })
}

/// The native path: the [`crate::growth::ligo::Ligo`] operator (surrogate
/// M-learning), with FLOPs accounted analytically — M-steps backprop only
/// through the expansion, not a large-model fwd/bwd, hence the cheaper
/// per-step cost.
pub fn ligo_grow_native(
    small: &ModelConfig,
    large: &ModelConfig,
    small_params: &Store,
    opts: &LigoOptions,
) -> Result<Grown> {
    let timer = crate::util::timer::Timer::new();
    let op = crate::growth::ligo::Ligo {
        steps: opts.steps,
        lr: opts.lr,
        momentum: opts.momentum,
        noise: opts.init_noise,
        seed: opts.seed,
    };
    let (params, final_m_loss) = op.grow_with_loss(small_params, small, large);
    let extra_flops = opts.steps as f64 * flops::ligo_native_step_flops(small, large)
        + flops::ligo_apply_flops(small, large);
    Ok(Grown { params, extra_flops, wall_s: timer.elapsed(), final_m_loss })
}

/// Depth-only / width-only variants (Fig. 6) use the same entry point with
/// the ablation pairs (bert_d3w72 -> bert_base, bert_d6w48 -> bert_base);
/// M simply lacks the other direction's parameters.

#[cfg(test)]
mod tests {
    use super::*;
    use crate::growth::testutil::{mk_cfg, small_store};

    #[test]
    fn init_pattern_is_stack_plus_noise() {
        let shapes = vec![
            ("w_q".to_string(), vec![6, 3]),
            ("B_emb".to_string(), vec![12, 8]),
        ];
        let m = ligo_init_store(&shapes, 0.0, 0);
        let w = m.expect("w_q");
        // rows 0..3 identity, rows 3..6 repeat (stacking pattern)
        for r in 0..6 {
            for c in 0..3 {
                let want = if c == r % 3 { 1.0 } else { 0.0 };
                assert_eq!(w.at2(r, c), want, "r{r} c{c}");
            }
        }
        let b = m.expect("B_emb");
        assert_eq!(b.at2(9, 1), 1.0); // 9 % 8 = 1
    }

    #[test]
    fn noise_breaks_symmetry_deterministically() {
        let shapes = vec![("B_emb".to_string(), vec![4, 2])];
        let a = ligo_init_store(&shapes, 0.01, 7);
        let b = ligo_init_store(&shapes, 0.01, 7);
        let c = ligo_init_store(&shapes, 0.01, 8);
        assert_eq!(a.expect("B_emb"), b.expect("B_emb"));
        assert_ne!(a.expect("B_emb"), c.expect("B_emb"));
    }

    #[test]
    fn default_options_match_paper() {
        assert_eq!(LigoOptions::default().steps, 100);
    }

    #[test]
    fn ligo_grow_falls_back_to_native_without_artifacts() {
        let rt = Runtime::cpu(std::env::temp_dir().join("ligo_gm_no_artifacts")).unwrap();
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let small = small_store(&cs);
        let opts = LigoOptions { steps: 5, ..Default::default() };
        let mut batches = |_s: usize| Store::new();
        let grown = ligo_grow(&rt, &cs, &cl, &small, &mut batches, &opts).unwrap();
        assert!(grown.final_m_loss.is_finite());
        assert!(grown.extra_flops > 0.0);
        assert_eq!(grown.params.len(), small_store(&cl).len());
        assert_eq!(grown.params.expect("L03_q_w").shape, vec![12, 12]);
    }

    #[test]
    fn native_flops_accounting_scales_with_steps() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let small = small_store(&cs);
        let g5 = ligo_grow_native(&cs, &cl, &small, &LigoOptions { steps: 5, ..Default::default() })
            .unwrap();
        let g9 = ligo_grow_native(&cs, &cl, &small, &LigoOptions { steps: 9, ..Default::default() })
            .unwrap();
        assert!(g9.extra_flops > g5.extra_flops);
    }
}
